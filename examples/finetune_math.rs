//! Method shoot-out on the math-chain task (Figure 2 / Table 2 style):
//! Full vs MLorc vs LoRA vs GaLore vs LDAdamW under AdamW, same budget.
//!
//!     cargo run --release --example finetune_math [-- --steps 150]

use anyhow::Result;
use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::{cli::Args, fsutil, logger};

fn main() -> Result<()> {
    logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 150)?;
    let dir = fsutil::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let preset = manifest.preset("tiny")?;

    let methods = [
        (Method::FullAdamW, 2e-3f32),
        (Method::MlorcAdamW, 2e-3),
        (Method::LoraAdamW, 4e-3),
        (Method::Galore, 4e-3),
        (Method::LdAdamW, 1e-3),
    ];

    println!("fine-tuning tiny ({} params) on math-chain for {steps} steps\n", preset.model.n_params());
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "method", "loss", "tok acc", "EM", "opt state", "time"
    );
    let mut rows = Vec::new();
    for (method, lr) in methods {
        let mut cfg = RunConfig::new("tiny", method, TaskKind::MathChain, steps).with_lr(lr);
        cfg.eval_batches = 16;
        cfg.log_every = 0;
        let mut tr = Trainer::new(&rt, preset, cfg)?;
        let out = tr.train()?;
        let ev = out.eval.as_ref().unwrap();
        println!(
            "{:<14} {:>10.4} {:>9.1}% {:>9.1}% {:>10.2}MB {:>9.1}s",
            method.name(),
            out.final_loss,
            ev.accuracy * 100.0,
            ev.exact_match * 100.0,
            out.memory_measured.opt_state_bytes as f64 / 1e6,
            out.wall_secs
        );
        rows.push((method, out.final_loss));
    }

    // the paper's qualitative claim
    let loss_of = |m: Method| rows.iter().find(|(x, _)| *x == m).unwrap().1;
    let gap_mlorc = (loss_of(Method::MlorcAdamW) - loss_of(Method::FullAdamW)).abs();
    let gap_galore = (loss_of(Method::Galore) - loss_of(Method::FullAdamW)).abs();
    println!(
        "\nMLorc-vs-Full loss gap: {gap_mlorc:.4}; GaLore-vs-Full gap: {gap_galore:.4} \
         (paper: MLorc tracks full fine-tuning most closely)"
    );
    Ok(())
}
