//! SynGLUE suite (Table 5 style): sequence classification across the
//! eight GLUE-analog tasks, comparing Full / MLorc / LoRA.
//!
//!     cargo run --release --example glue_suite [-- --steps 80 --tasks 4]

use anyhow::Result;
use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::data::SYNGLUE_NAMES;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::{cli::Args, fsutil, logger};

fn main() -> Result<()> {
    logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 80)?;
    let n_tasks = args.get_usize("tasks", 4)?.min(8);
    let dir = fsutil::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let preset = manifest.preset("tiny")?;

    let methods = [
        (Method::FullAdamW, 2e-3f32),
        (Method::MlorcAdamW, 2e-3),
        (Method::LoraAdamW, 4e-3),
    ];

    print!("{:<14}", "method");
    for i in 0..n_tasks {
        print!(" {:>7}", SYNGLUE_NAMES[i]);
    }
    println!(" {:>7}", "Avg");

    for (method, lr) in methods {
        print!("{:<14}", method.name());
        let mut accs = Vec::new();
        for i in 0..n_tasks {
            let mut cfg =
                RunConfig::new("tiny", method, TaskKind::SynGlue(i as u8), steps).with_lr(lr);
            cfg.eval_batches = 16;
            cfg.log_every = 0;
            let mut tr = Trainer::new(&rt, preset, cfg)?;
            let out = tr.train()?;
            let acc = out.eval.unwrap().accuracy * 100.0;
            print!(" {acc:>7.1}");
            accs.push(acc);
        }
        println!(" {:>7.1}", accs.iter().sum::<f32>() / accs.len() as f32);
    }
    println!("\n(accuracy %, {steps} steps per task; see `mlorc bench --experiment table5` for the full table)");
    Ok(())
}
