//! End-to-end system validation: train the ~100M-parameter `base100m`
//! preset with MLorc-AdamW (rank 4) on the math-chain corpus for a few
//! hundred steps, logging the loss curve, throughput, and the memory
//! split — proving all three layers compose at scale.
//!
//! Requires the big artifacts:  make artifacts-e2e
//! Run:  cargo run --release --example e2e_train [-- --steps 300 --method mlorc_adamw]
//!
//! The loss curve is written to results/e2e_loss.csv and the full metrics
//! to results/e2e_metrics.json (recorded in EXPERIMENTS.md).

use anyhow::{bail, Result};
use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::{cli::Args, fsutil, logger};

fn main() -> Result<()> {
    logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 300)?;
    let preset_name = args.get_or("preset", "base100m").to_string();
    let method = Method::parse(args.get_or("method", "mlorc_adamw"))?;
    let lr = args.get_f64("lr", 3e-4)? as f32;

    let dir = fsutil::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    if !manifest.presets.contains_key(&preset_name) {
        bail!(
            "preset '{preset_name}' not in artifacts — build it with `make artifacts-e2e` \
             (lowers the ~100M-param graphs; takes a few minutes)"
        );
    }
    let rt = Runtime::cpu(&dir)?;
    let preset = manifest.preset(&preset_name)?;
    let dims = preset.model;
    let n_params = dims.n_params();
    println!(
        "e2e: {} — {:.1}M params (d={}, L={}, vocab={}), batch {} x seq {}, method {}, rank {}",
        preset_name,
        n_params as f64 / 1e6,
        dims.d_model,
        dims.n_layers,
        dims.vocab,
        dims.batch,
        dims.seq,
        method.name(),
        dims.rank
    );

    let mut cfg = RunConfig::new(&preset_name, method, TaskKind::MathChain, steps).with_lr(lr);
    cfg.eval_every = (steps / 3).max(1);
    cfg.eval_batches = 4;
    cfg.log_every = 5;
    let mut tr = Trainer::new(&rt, preset, cfg)?;
    log::info!("compiling + first step (XLA compile of the 100M fwd/bwd takes a while)...");
    let outcome = tr.train()?;

    let tokens_per_step = (dims.batch * dims.seq) as f64;
    let ev = outcome.eval.as_ref().unwrap();
    println!("\n=== e2e results ===");
    println!("steps               : {steps}");
    println!("final training loss : {:.4}", outcome.final_loss);
    println!(
        "loss trajectory     : {:.3} -> {:.3}",
        tr.metrics.steps.first().map(|s| s.loss).unwrap_or(f32::NAN),
        outcome.final_loss
    );
    println!("eval loss / tok acc : {:.4} / {:.1}%", ev.loss, ev.accuracy * 100.0);
    println!(
        "throughput          : {:.0} tokens/s ({:.2}s per step)",
        tokens_per_step * steps as f64 / outcome.wall_secs,
        outcome.wall_secs / steps as f64
    );
    println!(
        "time split          : fwd/bwd {:.1}s, optimizer {:.1}s",
        tr.metrics.fwd_bwd_secs, tr.metrics.opt_secs
    );
    let mem = &outcome.memory_measured;
    println!(
        "memory              : weights {:.2} GB, opt state {:.3} GB ({}x smaller than AdamW's {:.2} GB), grads peak {:.3} GB",
        mem.weights_bytes as f64 / 1e9,
        mem.opt_state_bytes as f64 / 1e9,
        (2 * mem.weights_bytes) / mem.opt_state_bytes.max(1),
        2.0 * mem.weights_bytes as f64 / 1e9,
        mem.grads_peak_bytes as f64 / 1e9
    );

    let out_dir = fsutil::results_dir()?;
    std::fs::write(out_dir.join("e2e_loss.csv"), tr.metrics.loss_csv())?;
    tr.metrics.save(&out_dir.join("e2e_metrics.json"))?;
    println!(
        "loss curve -> {} ; metrics -> {}",
        out_dir.join("e2e_loss.csv").display(),
        out_dir.join("e2e_metrics.json").display()
    );
    Ok(())
}
