//! Quickstart: fine-tune the `tiny` preset on the math-chain task with
//! MLorc-AdamW, report loss, accuracy, and the memory split.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::{fsutil, logger};

fn main() -> Result<()> {
    logger::init();
    let dir = fsutil::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let preset = manifest.preset("tiny")?;

    let mut cfg = RunConfig::new("tiny", Method::MlorcAdamW, TaskKind::MathChain, 120);
    cfg.peak_lr = 2e-3;
    cfg.eval_every = 40;
    cfg.eval_batches = 8;

    println!(
        "MLorc quickstart: {} params, rank {} (compressed momentum = {:.1}% of AdamW's)",
        preset.model.n_params(),
        preset.model.rank,
        100.0 * (2 * preset.model.rank * (preset.model.d_model + preset.model.d_ff)) as f64
            / (2 * preset.model.d_model * preset.model.d_ff) as f64,
    );

    let mut trainer = Trainer::new(&rt, preset, cfg)?;
    let outcome = trainer.train()?;

    let ev = outcome.eval.as_ref().unwrap();
    println!("\n=== quickstart results ===");
    println!("final training loss : {:.4}", outcome.final_loss);
    println!("eval loss           : {:.4}", ev.loss);
    println!("answer token acc    : {:.1}%", ev.accuracy * 100.0);
    println!("exact match         : {:.1}%", ev.exact_match * 100.0);
    let mem = &outcome.memory_measured;
    println!(
        "memory              : weights {:.1} MB + optimizer state {:.2} MB + grads(peak) {:.2} MB",
        mem.weights_bytes as f64 / 1e6,
        mem.opt_state_bytes as f64 / 1e6,
        mem.grads_peak_bytes as f64 / 1e6
    );
    println!("wall clock          : {:.1}s ({:.0} ms/step)", outcome.wall_secs,
        outcome.wall_secs * 1e3 / 120.0);
    Ok(())
}
