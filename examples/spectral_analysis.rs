//! Figure 1 reproduction: track the top-8 singular-value concentration of
//! gradient / first moment / second moment during full-AdamW fine-tuning
//! on the STSB-analog task.
//!
//!     cargo run --release --example spectral_analysis [-- --steps 60]

use anyhow::Result;
use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::{cli::Args, fsutil, logger};

fn main() -> Result<()> {
    logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 60)?;
    let dir = fsutil::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let preset = manifest.preset("tiny")?;

    let mut cfg = RunConfig::new("tiny", Method::FullAdamW, TaskKind::SynGlue(7), steps); // stsb
    cfg.peak_lr = 1e-3;
    cfg.spectral_every = (steps / 12).max(1);
    cfg.log_every = 0;
    cfg.eval_batches = 2;

    println!("AdamW fine-tuning on synglue_stsb; probing singular spectra every {} steps\n", cfg.spectral_every);
    let mut tr = Trainer::new(&rt, preset, cfg)?;
    for _ in 0..steps {
        tr.train_step()?;
    }

    println!("{:>6} {:>12} {:>12} {:>12}", "step", "grad top-8", "m top-8", "v top-8");
    for rec in &tr.metrics.spectral {
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>12.3}",
            rec.step, rec.grad_ratio, rec.m_ratio, rec.v_ratio
        );
    }
    let last = tr.metrics.spectral.last().unwrap();
    println!(
        "\nFigure 1 shape check — v most concentrated, m ≈ g: v {} g ({:.3} vs {:.3})",
        if last.v_ratio >= last.grad_ratio { ">=" } else { "<" },
        last.v_ratio,
        last.grad_ratio
    );
    // persist the series for plotting
    let out = fsutil::results_dir()?.join("spectral_example.json");
    tr.metrics.save(&out)?;
    println!("series saved to {}", out.display());
    Ok(())
}
