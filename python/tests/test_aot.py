"""AOT pipeline: manifest structure, HLO purity (no custom-calls), and
IO-table consistency for artifacts built by `make artifacts`. Skips when
artifacts are absent (pure-python CI)."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        return json.load(f)


def test_scalar_layout_is_the_kernel_abi(manifest):
    assert manifest["scalar_layout"] == [
        "lr", "c1", "c2", "wd", "eps", "beta", "zeta", "unused",
    ]


def test_presets_have_all_graphs(manifest):
    for name in ("nano", "tiny", "small"):
        p = manifest["presets"][name]
        for g in ("fwd_bwd", "eval", "lora_fwd_bwd", "cls_fwd_bwd", "cls_eval"):
            assert g in p["graphs"], f"{name} missing {g}"


def test_fwd_bwd_io_matches_param_table(manifest):
    p = manifest["presets"]["nano"]
    lm_params = [q for q in p["params"] if q["kind"] != "head"]
    g = p["graphs"]["fwd_bwd"]
    assert len(g["inputs"]) == 2 + len(lm_params)
    assert g["inputs"][0]["name"] == "tokens"
    assert g["outputs"][0] == "loss"
    for q, io in zip(lm_params, g["inputs"][2:]):
        assert io["name"] == q["name"]
        assert io["shape"] == q["shape"]
    for q, out in zip(lm_params, g["outputs"][1:]):
        assert out == f"g:{q['name']}"


def test_every_compressed_param_has_mlorc_step(manifest):
    for name, p in manifest["presets"].items():
        if "mlorc_adamw" not in p["opt_steps"]:
            continue
        for q in p["params"]:
            if q["compressed"]:
                key = "x".join(str(d) for d in q["shape"])
                assert key in p["opt_steps"]["mlorc_adamw"], f"{name}/{q['name']}"


def test_hlo_files_exist_and_are_pure(manifest):
    checked = 0
    for p in manifest["presets"].values():
        entries = list(p["graphs"].values())
        for by_shape in p["opt_steps"].values():
            entries.extend(by_shape.values())
        for e in entries:
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), e["file"]
            if checked < 20:  # reading every file is slow; spot-check
                text = open(path).read()
                assert "custom-call" not in text, e["file"]
                assert text.startswith("HloModule"), e["file"]
                checked += 1
    assert checked > 0


def test_step_graph_outputs_echo_state(manifest):
    p = manifest["presets"]["nano"]
    sg = next(iter(p["opt_steps"]["mlorc_adamw"].values()))
    assert sg["outputs"] == ["w", "mq", "mb", "vq", "vb"]
    assert sg["rank"] >= 2
    assert sg["l"] >= sg["rank"]
    assert sg["hparams"]["beta1"] == 0.8  # the paper's MLorc-AdamW setting


def test_hparams_recorded_for_all_methods(manifest):
    hp = manifest["presets"]["nano"]["hparams"]
    assert hp["mlorc_adamw"]["beta1"] == 0.8
    assert hp["adamw"]["beta1"] == 0.9
    assert hp["lion"]["beta2"] == 0.99
    assert hp["galore"]["galore_scale"] == 0.25
