"""Optimizer step graphs vs independent numpy references, plus the
convergence-critical invariants (second-moment nonnegativity, exactness of
MLorc at full rank, GaLore/LDAdam projection algebra).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from compile import optim_steps as opt
from compile import rsvd_lib
from compile.configs import HPARAMS, OptHParams


def _np_mgs(y):
    m, l = y.shape
    q = np.zeros((m, l), np.float64)
    for j in range(l):
        v = y[:, j].astype(np.float64)
        for _ in range(2):
            for i in range(j):
                v -= q[:, i] * (q[:, i] @ v)
        n2 = v @ v
        q[:, j] = v / np.sqrt(n2) if n2 > 1e-30 else 0.0
    return q


def _np_rsvd_qb(a, om):
    y = a @ om
    q = _np_mgs(y)
    return q, q.T @ a


def _np_zeta(recon):
    neg = recon < 0
    if not neg.any():
        return 0.0
    return float(np.abs(recon[neg]).mean())


class TestMLorcAdamW:
    def _numpy_step(self, w, g, mq, mb, vq, vb, om_m, om_v, lr, c1, c2, hp):
        """Independent Algorithm 1 implementation (float64 numpy)."""
        m_rec = mq @ mb
        v_rec = vq @ vb
        zeta = _np_zeta(v_rec)
        v_fix = np.where(v_rec < 0, zeta, v_rec)
        mt = hp.beta1 * m_rec + (1 - hp.beta1) * g
        vt = hp.beta2 * v_fix + (1 - hp.beta2) * g * g
        mq2, mb2 = _np_rsvd_qb(mt, om_m)
        vq2, vb2 = _np_rsvd_qb(vt, om_v)
        w2 = w - lr * ((mt * c1) / (np.sqrt(vt * c2) + hp.eps) + hp.weight_decay * w)
        return w2, mq2 @ mb2, vq2 @ vb2

    @pytest.mark.parametrize("shape", [(16, 16), (16, 64), (64, 16)])
    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_matches_numpy(self, shape, use_pallas):
        rng = np.random.default_rng(0)
        m, n = shape
        r = 4
        hp = HPARAMS["mlorc_adamw"]
        sg = opt.build_mlorc_adamw(shape, r, 0, hp, use_pallas=use_pallas)
        w = rng.standard_normal(shape).astype(np.float32)
        g = rng.standard_normal(shape).astype(np.float32)
        mq = (rng.standard_normal((m, r)) * 0.1).astype(np.float32)
        mb = (rng.standard_normal((r, n)) * 0.1).astype(np.float32)
        vq = (rng.standard_normal((m, r)) * 0.01).astype(np.float32)
        vb = (rng.standard_normal((r, n)) * 0.01).astype(np.float32)
        om_m = rng.standard_normal((n, r)).astype(np.float32)
        om_v = rng.standard_normal((n, r)).astype(np.float32)
        outs = sg.fn(*map(jnp.asarray, (w, g, mq, mb, vq, vb, om_m, om_v)),
                     jnp.float32(1e-3), jnp.float32(1.2), jnp.float32(1.01))
        w2, mq2, mb2, vq2, vb2 = map(np.asarray, outs)
        rw2, rm_rec, rv_rec = self._numpy_step(
            w, g, mq, mb, vq, vb, om_m, om_v, 1e-3, 1.2, 1.01, hp
        )
        assert_allclose(w2, rw2, rtol=1e-4, atol=1e-5)
        assert_allclose(mq2 @ mb2, rm_rec, rtol=1e-3, atol=1e-4)
        assert_allclose(vq2 @ vb2, rv_rec, rtol=1e-3, atol=1e-5)

    def test_full_rank_equals_adamw_first_step(self):
        """With l = min(m, n) the QB compression is lossless, so from zero
        state one MLorc-AdamW step must equal one AdamW step exactly
        (with matched betas)."""
        rng = np.random.default_rng(1)
        m = n = 12
        hp = OptHParams(beta1=0.8, beta2=0.999)
        sg = opt.build_mlorc_adamw((m, n), n, 0, hp, use_pallas=False)
        ref = opt.build_adamw((m, n), hp, use_pallas=False)
        w = rng.standard_normal((m, n)).astype(np.float32)
        g = rng.standard_normal((m, n)).astype(np.float32)
        z = np.zeros((m, n), np.float32)
        zf = np.zeros((m, n), np.float32)
        om = rng.standard_normal((n, n)).astype(np.float32)
        out_m = sg.fn(*map(jnp.asarray, (w, g, z[:, :n], z[:n, :], z[:, :n], z[:n, :], om, om)),
                      jnp.float32(1e-2), jnp.float32(5.0), jnp.float32(1000.0))
        out_a = ref.fn(*map(jnp.asarray, (w, g, zf, zf)),
                       jnp.float32(1e-2), jnp.float32(5.0), jnp.float32(1000.0))
        assert_allclose(np.asarray(out_m[0]), np.asarray(out_a[0]), rtol=1e-5, atol=1e-6)

    def test_v_factors_reconstruct_nonneg_dominant(self):
        """After a step, the v reconstruction error must be small relative
        to v itself (rank-r momentum hypothesis on a low-rank gradient)."""
        rng = np.random.default_rng(2)
        m = n = 32
        r = 4
        hp = HPARAMS["mlorc_adamw"]
        sg = opt.build_mlorc_adamw((m, n), r, 0, hp, use_pallas=False)
        g = (rng.standard_normal((m, 2)) @ rng.standard_normal((2, n))).astype(np.float32)
        z = np.zeros((m, r), np.float32)
        zb = np.zeros((r, n), np.float32)
        w = rng.standard_normal((m, n)).astype(np.float32)
        om = rng.standard_normal((n, r)).astype(np.float32)
        outs = sg.fn(*map(jnp.asarray, (w, g, z, zb, z, zb, om, om)),
                     jnp.float32(1e-3), jnp.float32(1.0), jnp.float32(1.0))
        vq2, vb2 = np.asarray(outs[3]), np.asarray(outs[4])
        vt = (1 - hp.beta2) * g * g  # true v after first step (rank <= 4)
        assert_allclose(vq2 @ vb2, vt, rtol=1e-3, atol=1e-7)


class TestMLorcLion:
    def test_matches_numpy(self):
        rng = np.random.default_rng(3)
        m, n, r = 24, 40, 4
        hp = HPARAMS["mlorc_lion"]
        sg = opt.build_mlorc_lion((m, n), r, 0, hp, use_pallas=True)
        w = rng.standard_normal((m, n)).astype(np.float32)
        g = rng.standard_normal((m, n)).astype(np.float32)
        mq = (rng.standard_normal((m, r)) * 0.1).astype(np.float32)
        mb = (rng.standard_normal((r, n)) * 0.1).astype(np.float32)
        om = rng.standard_normal((n, r)).astype(np.float32)
        w2, mq2, mb2 = map(np.asarray, sg.fn(
            *map(jnp.asarray, (w, g, mq, mb, om)), jnp.float32(1e-3)))
        recon = mq @ mb
        c = hp.beta1 * recon + (1 - hp.beta1) * g
        mt = hp.beta2 * recon + (1 - hp.beta2) * g
        assert_allclose(w2, w - 1e-3 * np.sign(c), rtol=1e-5, atol=1e-6)
        q, b = _np_rsvd_qb(mt, om)
        assert_allclose(mq2 @ mb2, q @ b, rtol=1e-3, atol=1e-5)


class TestAblations:
    def test_mlorc_m_keeps_exact_v(self):
        rng = np.random.default_rng(4)
        m = n = 16
        hp = HPARAMS["mlorc_m"]
        sg = opt.build_mlorc_m((m, n), 4, 0, hp, use_pallas=False)
        w, g = (rng.standard_normal((m, n)).astype(np.float32) for _ in range(2))
        v = np.abs(rng.standard_normal((m, n))).astype(np.float32)
        mq = np.zeros((m, 4), np.float32)
        mb = np.zeros((4, n), np.float32)
        om = rng.standard_normal((n, 4)).astype(np.float32)
        outs = sg.fn(*map(jnp.asarray, (w, g, mq, mb, v, om)),
                     jnp.float32(1e-3), jnp.float32(1.0), jnp.float32(1.0))
        v2 = np.asarray(outs[3])
        assert_allclose(v2, hp.beta2 * v + (1 - hp.beta2) * g * g, rtol=1e-5, atol=1e-7)

    def test_mlorc_v_keeps_exact_m(self):
        rng = np.random.default_rng(5)
        m = n = 16
        hp = HPARAMS["mlorc_v"]
        sg = opt.build_mlorc_v((m, n), 4, 0, hp, use_pallas=False)
        w, g, m_ = (rng.standard_normal((m, n)).astype(np.float32) for _ in range(3))
        vq = np.zeros((m, 4), np.float32)
        vb = np.zeros((4, n), np.float32)
        om = rng.standard_normal((n, 4)).astype(np.float32)
        outs = sg.fn(*map(jnp.asarray, (w, g, m_, vq, vb, om)),
                     jnp.float32(1e-3), jnp.float32(1.0), jnp.float32(1.0))
        m2 = np.asarray(outs[1])
        assert_allclose(m2, hp.beta1 * m_ + (1 - hp.beta1) * g, rtol=1e-5, atol=1e-7)


class TestGaLore:
    @pytest.mark.parametrize("shape", [(16, 48), (48, 16)])
    def test_projection_algebra(self, shape):
        """One GaLore step from zero state equals AdamW on the projected
        gradient back-projected with scale alpha."""
        rng = np.random.default_rng(6)
        m, n = shape
        r = 4
        hp = HPARAMS["galore"]
        proj = opt.build_galore_project(shape, r, 0)
        sg = opt.build_galore(shape, r, 0, hp, use_pallas=False)
        g = rng.standard_normal(shape).astype(np.float32)
        w = rng.standard_normal(shape).astype(np.float32)
        left = opt.galore_left(shape)
        om = rng.standard_normal(((n if left else m), r)).astype(np.float32)
        (p,) = proj.fn(jnp.asarray(g), jnp.asarray(om))
        p = np.asarray(p)
        rshape = (r, n) if left else (m, r)
        M = np.zeros(rshape, np.float32)
        V = np.zeros(rshape, np.float32)
        w2, M2, V2 = map(np.asarray, sg.fn(
            *map(jnp.asarray, (w, g, p, M, V)),
            jnp.float32(1e-3), jnp.float32(10.0), jnp.float32(1000.0)))
        rproj = p.T @ g if left else g @ p
        assert_allclose(M2, 0.1 * rproj, rtol=1e-4, atol=1e-6)
        nhat = (M2 * 10.0) / (np.sqrt(V2 * 1000.0) + hp.eps)
        full = p @ nhat if left else nhat @ p.T
        assert_allclose(w2, w - 1e-3 * hp.galore_scale * full, rtol=1e-4, atol=1e-5)

    def test_projector_orthonormal(self):
        rng = np.random.default_rng(7)
        proj = opt.build_galore_project((32, 64), 4, 0)
        g = rng.standard_normal((32, 64)).astype(np.float32)
        om = rng.standard_normal((64, 4)).astype(np.float32)
        (p,) = proj.fn(jnp.asarray(g), jnp.asarray(om))
        assert_allclose(np.asarray(p.T @ p), np.eye(4), atol=5e-5)


class TestLDAdamW:
    def test_error_feedback_identity(self):
        """a_t = g_t + e_t must split exactly into P R + e_{t+1}."""
        rng = np.random.default_rng(8)
        m, n, r = 32, 24, 4
        hp = HPARAMS["ldadamw"]
        sg = opt.build_ldadamw((m, n), r, 0, hp, use_pallas=False)
        w, g, e = (rng.standard_normal((m, n)).astype(np.float32) for _ in range(3))
        left = opt.galore_left((m, n))
        pshape = (m, r) if left else (n, r)
        rshape = (r, n) if left else (m, r)
        p_old = _np_mgs(rng.standard_normal(pshape)).astype(np.float32)
        M = (rng.standard_normal(rshape) * 0.1).astype(np.float32)
        V = np.abs(rng.standard_normal(rshape) * 0.01).astype(np.float32)
        om = rng.standard_normal(((n, r) if left else (m, r))).astype(np.float32)
        w2, p2, M2, V2, e2 = map(np.asarray, sg.fn(
            *map(jnp.asarray, (w, g, p_old, M, V, e, om)),
            jnp.float32(1e-3), jnp.float32(1.0), jnp.float32(1.0)))
        a = g + e
        r_proj = p2.T @ a if left else a @ p2
        recon = p2 @ r_proj if left else r_proj @ p2.T
        assert_allclose(recon + e2, a, rtol=1e-4, atol=1e-5)
        assert np.all(V2 >= 0)


class TestVectorSteps:
    def test_adamw_vector(self):
        rng = np.random.default_rng(9)
        hp = HPARAMS["adamw"]
        sg = opt.build_adamw((32,), hp, use_pallas=True)  # falls back to ref on 1-D
        w, g = (rng.standard_normal(32).astype(np.float32) for _ in range(2))
        m = np.zeros(32, np.float32)
        v = np.zeros(32, np.float32)
        w2, m2, v2 = map(np.asarray, sg.fn(
            *map(jnp.asarray, (w, g, m, v)),
            jnp.float32(1e-2), jnp.float32(10.0), jnp.float32(1000.0)))
        assert_allclose(m2, 0.1 * g, rtol=1e-5)
        assert_allclose(v2, 0.001 * g * g, rtol=1e-4)
