"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (including non-square, tile-boundary and
tile-interior sizes) and value scales; assert_allclose throughout.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels import rsvd as k
from compile.kernels import update as u

DIMS = st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128])
LS = st.sampled_from([2, 4, 8])
SCALE = st.sampled_from([1e-3, 1.0, 1e3])


def _mat(rng, m, n, scale=1.0):
    return jnp.asarray(rng.standard_normal((m, n)) * scale, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, l=LS, scale=SCALE, seed=st.integers(0, 2**16))
def test_a_omega_matches_ref(m, n, l, scale, seed):
    rng = np.random.default_rng(seed)
    a, om = _mat(rng, m, n, scale), _mat(rng, n, l)
    assert_allclose(k.a_omega(a, om), ref.a_omega(a, om), rtol=2e-5, atol=2e-5 * scale)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, l=LS, seed=st.integers(0, 2**16))
def test_qt_a_matches_ref(m, n, l, seed):
    rng = np.random.default_rng(seed)
    q, a = _mat(rng, m, l), _mat(rng, m, n)
    assert_allclose(k.qt_a(q, a), ref.qt_a(q, a), rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, l=LS, seed=st.integers(0, 2**16))
def test_qb_matmul_matches_ref(m, n, l, seed):
    rng = np.random.default_rng(seed)
    q, b = _mat(rng, m, l), _mat(rng, l, n)
    assert_allclose(k.qb_matmul(q, b), q @ b, rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, l=LS, beta=st.floats(0.0, 1.0), seed=st.integers(0, 2**16))
def test_recon_axpy_matches_ref(m, n, l, beta, seed):
    rng = np.random.default_rng(seed)
    q, b, g = _mat(rng, m, l), _mat(rng, l, n), _mat(rng, m, n)
    assert_allclose(
        u.recon_axpy(q, b, g, beta), ref.recon_axpy(q, b, g, beta), rtol=2e-5, atol=2e-5
    )


@settings(max_examples=15, deadline=None)
@given(m=DIMS, n=DIMS, l=LS, seed=st.integers(0, 2**16))
def test_recon_neg_stats_matches_ref(m, n, l, seed):
    rng = np.random.default_rng(seed)
    q, b = _mat(rng, m, l), _mat(rng, l, n)
    neg, cnt = u.recon_neg_stats(q, b, n)
    rneg, rcnt = ref.recon_neg_stats(q, b)
    assert_allclose(jnp.sum(neg), rneg, rtol=1e-4, atol=1e-4)
    assert_allclose(jnp.sum(cnt), rcnt, rtol=0, atol=0)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, n=DIMS, l=LS, seed=st.integers(0, 2**16))
def test_recon_v_update_matches_ref_and_nonneg(m, n, l, seed):
    rng = np.random.default_rng(seed)
    q, b, g = _mat(rng, m, l), _mat(rng, l, n), _mat(rng, m, n)
    zeta = ref.zeta_of(q @ b)
    got = u.recon_v_update(q, b, g, zeta, 0.999)
    want = ref.recon_v_update(q, b, g, zeta, 0.999)
    assert_allclose(got, want, rtol=2e-5, atol=1e-6)
    # Eq. (2) invariant: the repaired second moment is strictly nonnegative.
    assert float(jnp.min(got)) >= 0.0


@settings(max_examples=15, deadline=None)
@given(
    m=DIMS,
    n=DIMS,
    lr=st.floats(1e-6, 1e-1),
    wd=st.floats(0.0, 0.2),
    seed=st.integers(0, 2**16),
)
def test_adamw_apply_matches_ref(m, n, lr, wd, seed):
    rng = np.random.default_rng(seed)
    w, mm = _mat(rng, m, n), _mat(rng, m, n)
    v = jnp.abs(_mat(rng, m, n))
    got = u.adamw_apply(w, mm, v, lr, 1.25, 1.002, wd, 1e-8)
    want = ref.adamw_apply(w, mm, v, lr, 1.25, 1.002, wd, 1e-8)
    assert_allclose(got, want, rtol=2e-5, atol=2e-7)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, n=DIMS, lr=st.floats(1e-6, 1e-1), seed=st.integers(0, 2**16))
def test_lion_apply_matches_ref(m, n, lr, seed):
    rng = np.random.default_rng(seed)
    w, c = _mat(rng, m, n), _mat(rng, m, n)
    got = u.lion_apply(w, c, lr, 0.1)
    want = ref.lion_apply(w, c, lr, 0.1)
    assert_allclose(got, want, rtol=2e-5, atol=2e-7)


def test_lion_apply_sign_edge_zero():
    """sign(0) must be 0 — a zero momentum+gradient entry must not move."""
    w = jnp.ones((8, 8), jnp.float32)
    c = jnp.zeros((8, 8), jnp.float32)
    out = u.lion_apply(w, c, 0.1, 0.0)
    assert_allclose(out, w)


def test_scalar_pack_layout_stable():
    """The (1,8) scalar-pack layout is a cross-language ABI with the rust
    coordinator; lock the indices."""
    s = u.pack_scalars(lr=1.0, c1=2.0, c2=3.0, wd=4.0, eps=5.0, beta=6.0, zeta=7.0)
    assert s.shape == (1, 8)
    assert_allclose(np.asarray(s)[0], [1, 2, 3, 4, 5, 6, 7, 0])
