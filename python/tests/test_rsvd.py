"""QB-RSVD properties: orthonormality, exactness on low-rank inputs, the
Halko tail bound (Lemma A.1 / B.1 of the paper), and equivalence of the QB
form to Algorithm 3's truncated-SVD reconstruction at p = 0.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import rsvd_lib

DIMS = st.sampled_from([16, 32, 48, 64, 128])


def _lowrank(rng, m, n, r, noise=0.0):
    a = rng.standard_normal((m, r)) @ rng.standard_normal((r, n))
    if noise:
        a = a + noise * rng.standard_normal((m, n))
    return jnp.asarray(a, jnp.float32)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, n=DIMS, l=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
def test_mgs_q_orthonormal(m, n, l, seed):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.standard_normal((m, l)), jnp.float32)
    q = rsvd_lib.mgs_qr(y)
    assert_allclose(q.T @ q, np.eye(l), atol=5e-5)


def test_mgs_zero_column_drops_rank():
    """An exactly-zero column (the momentum-starts-at-zero case) must yield
    a zero Q column rather than NaNs; the rest stays orthonormal."""
    rng = np.random.default_rng(0)
    y = np.asarray(rng.standard_normal((32, 4)), np.float32)
    y[:, 2] = 0.0
    q = np.asarray(rsvd_lib.mgs_qr(jnp.asarray(y)))
    assert np.isfinite(q).all()
    assert float(np.linalg.norm(q[:, 2])) == 0.0
    for j in (0, 1, 3):
        assert abs(float(q[:, j] @ q[:, j]) - 1.0) < 1e-4


def test_mgs_duplicate_column_keeps_orthonormality():
    """A numerically dependent column re-normalizes to *some* direction in
    f32; what matters is that Q stays orthonormal so QB is still a valid
    range projector."""
    rng = np.random.default_rng(0)
    y = np.asarray(rng.standard_normal((32, 4)), np.float32)
    y[:, 2] = y[:, 0]
    q = rsvd_lib.mgs_qr(jnp.asarray(y))
    assert_allclose(np.asarray(q.T @ q), np.eye(4), atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, n=DIMS, r=st.sampled_from([2, 4]), seed=st.integers(0, 2**16))
def test_rsvd_exact_on_lowrank(m, n, r, seed):
    """If rank(A) <= l the QB range finder reconstructs A exactly (w.p. 1)."""
    rng = np.random.default_rng(seed)
    a = _lowrank(rng, m, n, r)
    om = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)
    q, b = rsvd_lib.rsvd_qb(a, om)
    scale = float(jnp.linalg.norm(a))
    assert float(jnp.linalg.norm(a - q @ b)) <= 1e-3 * scale


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_halko_tail_bound_statistical(seed):
    """Lemma A.1: E||A - A_rs||_F <= (1 + r/(p-1))^(1/2) * tail. Checked in
    expectation over 20 draws with 3x slack (it is an expectation bound)."""
    rng = np.random.default_rng(seed)
    m = n = 48
    r, p = 4, 2
    a = np.asarray(_lowrank(rng, m, n, r, noise=0.05))
    s = np.linalg.svd(a, compute_uv=False)
    tail = np.sqrt(np.sum(s[r:] ** 2))
    gamma = np.sqrt(1.0 + r / (p - 1))
    errs = []
    for _ in range(20):
        om = jnp.asarray(rng.standard_normal((n, r + p)), jnp.float32)
        q, b = rsvd_lib.rsvd_qb(jnp.asarray(a), om)
        errs.append(float(jnp.linalg.norm(jnp.asarray(a) - q @ b)))
    assert np.mean(errs) <= 3.0 * gamma * tail


@settings(max_examples=10, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_qb_equals_alg3_at_p0(m, n, seed):
    """At p = 0 (the paper's experimental setting) the QB reconstruction is
    identical to Algorithm 3's U S V^T: the small SVD of B is a rotation."""
    rng = np.random.default_rng(seed)
    a = _lowrank(rng, m, n, 6, noise=0.1)
    r = 4
    om = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)
    q, b = rsvd_lib.rsvd_qb(a, om)
    # Algorithm 3: SVD of B = U~ S V^T, U = Q U~; reconstruction U S V^T.
    u_t, s, vt = np.linalg.svd(np.asarray(b), full_matrices=False)
    alg3 = (np.asarray(q) @ u_t) @ np.diag(s) @ vt
    assert_allclose(np.asarray(q @ b), alg3, rtol=1e-4, atol=1e-4)


def test_svd_truncate_matches_best_rank_r_of_qb():
    rng = np.random.default_rng(7)
    m, n, r, p = 64, 48, 4, 4
    a = _lowrank(rng, m, n, 8, noise=0.01)
    om = jnp.asarray(rng.standard_normal((n, r + p)), jnp.float32)
    q, b = rsvd_lib.rsvd_qb(a, om)
    q2, b2 = rsvd_lib.svd_truncate(q, b, r)
    assert q2.shape == (m, r) and b2.shape == (r, n)
    # truncation error of QB -> rank r is the tail of B's spectrum
    s = np.linalg.svd(np.asarray(b), compute_uv=False)
    err = float(jnp.linalg.norm(q @ b - q2 @ b2))
    assert_allclose(err, np.sqrt(np.sum(s[r:] ** 2)), rtol=1e-3, atol=1e-4)


def test_lemma_b1_momentum_error_bound():
    """Lemma B.1 shape: with m_t = beta2*QB(m_{t-1}) + (1-beta2) g_t, the
    compression error of m_t is bounded by gamma*(1-beta2)*||g_t||_F since
    the previous reconstruction is already rank l. Statistical check."""
    rng = np.random.default_rng(3)
    m, n, r, p = 48, 32, 4, 2
    beta2 = 0.99
    gamma = np.sqrt(1.0 + r / (p - 1))
    mq = jnp.asarray(rng.standard_normal((m, r + p)), jnp.float32) * 0.1
    mb = jnp.asarray(rng.standard_normal((r + p, n)), jnp.float32) * 0.1
    errs, bounds = [], []
    for i in range(20):
        g = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        mt = beta2 * (mq @ mb) + (1 - beta2) * g
        om = jnp.asarray(rng.standard_normal((n, r + p)), jnp.float32)
        q, b = rsvd_lib.rsvd_qb(mt, om)
        errs.append(float(jnp.linalg.norm(mt - q @ b)))
        bounds.append(gamma * (1 - beta2) * float(jnp.linalg.norm(g)))
    assert np.mean(errs) <= 3.0 * np.mean(bounds)
