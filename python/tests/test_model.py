"""L2 model graphs: shapes, masking semantics, gradient flow, LoRA
freezing, classification head — all on the `nano` preset."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from compile import model as mdl
from compile.configs import PRESETS

CFG = PRESETS["nano"]


@pytest.fixture(scope="module")
def params():
    return mdl.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def params_cls():
    return mdl.init_params(CFG, seed=0, cls_head=True)


def _flat(p, spec):
    return [p[name] for name, _, _ in spec]


def _batch(rng):
    toks = rng.integers(1, CFG.vocab, size=(CFG.batch, CFG.seq), dtype=np.int32)
    tgts = np.roll(toks, -1, axis=1).astype(np.int32)
    tgts[:, -1] = mdl.PAD_TARGET
    return jnp.asarray(toks), jnp.asarray(tgts)


def test_param_spec_counts():
    spec = mdl.param_spec(CFG)
    mats = [s for s in spec if s[2] == "matrix"]
    vecs = [s for s in spec if s[2] == "vector"]
    assert len(mats) == 6 * CFG.n_layers
    assert len(vecs) == 4 * CFG.n_layers + 2
    n_params = sum(int(np.prod(s)) for _, s, _ in spec)
    assert n_params > 0
    # cls variant appends exactly the head
    assert len(mdl.param_spec(CFG, cls_head=True)) == len(spec) + 1


def test_forward_shapes(params):
    rng = np.random.default_rng(0)
    toks, _ = _batch(rng)
    logits = mdl.forward(params, toks, CFG)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params):
    """Changing a future token must not affect past logits."""
    rng = np.random.default_rng(1)
    toks, _ = _batch(rng)
    logits1 = mdl.forward(params, toks, CFG)
    toks2 = np.asarray(toks).copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab
    logits2 = mdl.forward(params, jnp.asarray(toks2), CFG)
    assert_allclose(logits1[:, :-1], logits2[:, :-1], atol=1e-5)


def test_loss_mask_ignores_padding(params):
    rng = np.random.default_rng(2)
    toks, tgts = _batch(rng)
    loss1 = mdl.lm_loss(params, toks, tgts, CFG)
    # corrupt only padded positions: loss must not change
    t2 = np.asarray(tgts).copy()
    assert (t2[:, -1] == mdl.PAD_TARGET).all()
    loss2 = mdl.lm_loss(params, toks, jnp.asarray(t2), CFG)
    assert_allclose(loss1, loss2, rtol=1e-6)
    # fresh model: loss ~ ln(vocab)
    assert abs(float(loss1) - np.log(CFG.vocab)) < 1.0


def test_fwd_bwd_grads_flow(params):
    rng = np.random.default_rng(3)
    toks, tgts = _batch(rng)
    spec = mdl.param_spec(CFG)
    f = mdl.make_fwd_bwd(CFG)
    outs = f(toks, tgts, *_flat(params, spec))
    loss, grads = outs[0], outs[1:]
    assert len(grads) == len(spec)
    for (name, shape, _), g in zip(spec, grads):
        assert g.shape == tuple(shape), name
        assert bool(jnp.all(jnp.isfinite(g))), name
    nonzero = sum(float(jnp.linalg.norm(g)) > 0 for g in grads)
    assert nonzero >= len(spec) - 2  # pos_emb beyond T etc. may be tiny but not zero


def test_sgd_on_fwd_bwd_reduces_loss(params):
    """Three plain-SGD steps on one batch must reduce the loss — the
    definitive 'gradients point downhill' check for the lowered graph."""
    rng = np.random.default_rng(4)
    toks, tgts = _batch(rng)
    spec = mdl.param_spec(CFG)
    f = jax.jit(mdl.make_fwd_bwd(CFG))
    flat = _flat(params, spec)
    losses = []
    for _ in range(3):
        outs = f(toks, tgts, *flat)
        losses.append(float(outs[0]))
        flat = [w - 0.5 * g for w, g in zip(flat, outs[1:])]
    assert losses[-1] < losses[0]


def test_eval_graph_correct_mask(params):
    rng = np.random.default_rng(5)
    toks, tgts = _batch(rng)
    spec = mdl.param_spec(CFG)
    loss, mask = mdl.make_eval(CFG)(toks, tgts, *_flat(params, spec))
    assert mask.shape == (CFG.batch, CFG.seq)
    m = np.asarray(mask)
    assert ((m == 0) | (m == 1)).all()
    assert m[:, -1].sum() == 0  # padded positions are never "correct"


def test_lora_grads_only_adapters(params):
    rng = np.random.default_rng(6)
    toks, tgts = _batch(rng)
    spec = mdl.param_spec(CFG)
    aspec = mdl.lora_spec(CFG)
    adapters = []
    for name, shape in aspec:
        if name.endswith("lora_B"):
            adapters.append(jnp.zeros(shape, jnp.float32))
        else:
            adapters.append(jnp.asarray(rng.standard_normal(shape) * 0.02, jnp.float32))
    f = mdl.make_lora_fwd_bwd(CFG, alpha=16.0)
    outs = f(toks, tgts, *_flat(params, spec), *adapters)
    assert len(outs) == 1 + len(aspec)
    # with B = 0, dL/dA = 0 but dL/dB != 0 (standard LoRA init property)
    for (name, _), g in zip(aspec, outs[1:]):
        norm = float(jnp.linalg.norm(g))
        if name.endswith("lora_A"):
            assert norm < 1e-6, name
        else:
            assert norm > 0, name


def test_lora_zero_b_matches_base_forward(params):
    rng = np.random.default_rng(7)
    toks, tgts = _batch(rng)
    spec = mdl.param_spec(CFG)
    aspec = mdl.lora_spec(CFG)
    adapters = [jnp.zeros(shape, jnp.float32) for _, shape in aspec]
    loss_lora, _ = mdl.make_lora_eval(CFG, 16.0)(toks, tgts, *_flat(params, spec), *adapters)
    loss_base, _ = mdl.make_eval(CFG)(toks, tgts, *_flat(params, spec))
    assert_allclose(loss_lora, loss_base, rtol=1e-6)


def test_cls_graph(params_cls):
    rng = np.random.default_rng(8)
    toks = jnp.asarray(rng.integers(1, CFG.vocab, size=(CFG.batch, CFG.seq), dtype=np.int32))
    labels = jnp.asarray(rng.integers(0, CFG.n_cls, size=(CFG.batch,), dtype=np.int32))
    spec = mdl.param_spec(CFG, cls_head=True)
    f = mdl.make_cls_fwd_bwd(CFG)
    outs = f(toks, labels, *_flat(params_cls, spec))
    assert len(outs) == 1 + len(spec)
    assert abs(float(outs[0]) - np.log(CFG.n_cls)) < 0.7
    loss, correct = mdl.make_cls_eval(CFG)(toks, labels, *_flat(params_cls, spec))
    assert correct.shape == (CFG.batch,)


def test_cls_lora_head_trains(params_cls):
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(1, CFG.vocab, size=(CFG.batch, CFG.seq), dtype=np.int32))
    labels = jnp.asarray(rng.integers(0, CFG.n_cls, size=(CFG.batch,), dtype=np.int32))
    spec = mdl.param_spec(CFG, cls_head=True)
    aspec = mdl.lora_spec(CFG)
    adapters = [jnp.zeros(shape, jnp.float32) for _, shape in aspec]
    outs = mdl.make_cls_lora_fwd_bwd(CFG, 16.0)(toks, labels, *_flat(params_cls, spec), *adapters)
    ghead = outs[1]
    assert float(jnp.linalg.norm(ghead)) > 0
