"""Layer-2 optimizer step graphs — one pure function per (method, shape).

Every builder returns ``f(*arrays) -> tuple`` plus an IO table that aot.py
serializes into the manifest, so the rust coordinator drives steps entirely
table-driven. Conventions:

  * runtime scalars come last, each a rank-0 f32: lr, and for Adam-family
    the bias corrections c1 = 1/(1-beta1^t), c2 = 1/(1-beta2^t);
  * Gaussian test matrices ``omega`` are *inputs* (rust owns the RNG);
  * hyper-parameters (betas, eps, wd, scales) are baked constants recorded
    in the manifest;
  * outputs echo the updated weight first, then updated state, in the same
    order the state appeared in the inputs.

Methods:
  adamw, lion                          — uncompressed baselines (Alg. refs)
  mlorc_adamw (Alg. 1), mlorc_lion (Alg. 2)
  mlorc_m / mlorc_v                    — ablations (Table 7)
  galore (Zhao et al. 2024)            — projector refresh as its own graph
  ldadamw (Robert et al. 2024)         — projection-aware + error feedback
LoRA needs no bespoke step: its adapters run plain adamw/lion at their own
shapes.
"""

from dataclasses import dataclass, field
from typing import Callable, List

import jax.numpy as jnp

from . import rsvd_lib
from .configs import OptHParams
from .kernels import ref
from .kernels import rsvd as kern
from .kernels import update as upd


@dataclass
class StepGraph:
    """IO description for one lowered optimizer step graph."""

    method: str
    shape: tuple
    fn: Callable
    inputs: List[dict]  # [{name, shape, dtype}]
    outputs: List[str]
    hparams: dict
    rank: int = 0
    l: int = 0

    def example_args(self):
        import numpy as np

        out = []
        for spec in self.inputs:
            import jax

            out.append(jax.ShapeDtypeStruct(tuple(spec["shape"]), jnp.dtype(spec["dtype"])))
        return out


def _io(name, shape, dtype="float32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _scalar(name):
    return {"name": name, "shape": [], "dtype": "float32"}


def _zeta(vq, vb, n, use_pallas):
    if use_pallas:
        neg, cnt = upd.recon_neg_stats(vq, vb, n)
        return jnp.sum(neg) / jnp.maximum(jnp.sum(cnt), 1.0)
    return ref.zeta_of(vq @ vb)


# ------------------------------------------------------------ baselines ----


def build_adamw(shape, hp: OptHParams, use_pallas=True) -> StepGraph:
    """Uncompressed AdamW; serves full fine-tuning, vector params, LoRA
    adapters and the mlorc_m/_v uncompressed halves."""

    def f(w, g, m, v, lr, c1, c2):
        m2 = hp.beta1 * m + (1.0 - hp.beta1) * g
        v2 = hp.beta2 * v + (1.0 - hp.beta2) * g * g
        if use_pallas and len(shape) == 2:
            w2 = upd.adamw_apply(w, m2, v2, lr, c1, c2, hp.weight_decay, hp.eps)
        else:
            w2 = ref.adamw_apply(w, m2, v2, lr, c1, c2, hp.weight_decay, hp.eps)
        return w2, m2, v2

    ios = [_io("w", shape), _io("g", shape), _io("m", shape), _io("v", shape),
           _scalar("lr"), _scalar("c1"), _scalar("c2")]
    return StepGraph("adamw", shape, f, ios, ["w", "m", "v"], hp.to_json())


def build_lion(shape, hp: OptHParams, use_pallas=True) -> StepGraph:
    def f(w, g, m, lr):
        c = hp.beta1 * m + (1.0 - hp.beta1) * g
        m2 = hp.beta2 * m + (1.0 - hp.beta2) * g
        if use_pallas and len(shape) == 2:
            w2 = upd.lion_apply(w, c, lr, hp.weight_decay)
        else:
            w2 = ref.lion_apply(w, c, lr, hp.weight_decay)
        return w2, m2

    ios = [_io("w", shape), _io("g", shape), _io("m", shape), _scalar("lr")]
    return StepGraph("lion", shape, f, ios, ["w", "m"], hp.to_json())


# ---------------------------------------------------------------- MLorc ----


def build_mlorc_adamw(shape, rank, p_over, hp: OptHParams, use_pallas=True) -> StepGraph:
    """Algorithm 1. State: QB factors of both momenta. Note lines 13-15 use
    the *exact* updated m_t, v_t; compression only affects the next step."""
    m, n = shape
    l = rank + p_over

    def f(w, g, mq, mb, vq, vb, om_m, om_v, lr, c1, c2):
        if use_pallas:
            mt = upd.recon_axpy(mq, mb, g, hp.beta1)  # line 6 + 9 fused
        else:
            mt = ref.recon_axpy(mq, mb, g, hp.beta1)
        zeta = _zeta(vq, vb, n, use_pallas)  # lines 7-8 (Eq. 2), pass 1
        if use_pallas:
            vt = upd.recon_v_update(vq, vb, g, zeta, hp.beta2)  # pass 2 + line 10
        else:
            vt = ref.recon_v_update(vq, vb, g, zeta, hp.beta2)
        mq2, mb2 = rsvd_lib.rsvd_qb(mt, om_m, use_pallas)  # line 11
        vq2, vb2 = rsvd_lib.rsvd_qb(vt, om_v, use_pallas)  # line 12
        if use_pallas:
            w2 = upd.adamw_apply(w, mt, vt, lr, c1, c2, hp.weight_decay, hp.eps)
        else:
            w2 = ref.adamw_apply(w, mt, vt, lr, c1, c2, hp.weight_decay, hp.eps)
        return w2, mq2, mb2, vq2, vb2

    ios = [
        _io("w", shape), _io("g", shape),
        _io("mq", (m, l)), _io("mb", (l, n)),
        _io("vq", (m, l)), _io("vb", (l, n)),
        _io("om_m", (n, l)), _io("om_v", (n, l)),
        _scalar("lr"), _scalar("c1"), _scalar("c2"),
    ]
    return StepGraph("mlorc_adamw", shape, f, ios, ["w", "mq", "mb", "vq", "vb"],
                     hp.to_json(), rank, l)


def build_mlorc_lion(shape, rank, p_over, hp: OptHParams, use_pallas=True) -> StepGraph:
    """Algorithm 2: one momentum, two EMAs of the same reconstruction."""
    m, n = shape
    l = rank + p_over

    def f(w, g, mq, mb, om, lr):
        recon = kern.qb_matmul(mq, mb) if use_pallas else mq @ mb  # line 6 (shared)
        c = hp.beta1 * recon + (1.0 - hp.beta1) * g  # line 7
        mt = hp.beta2 * recon + (1.0 - hp.beta2) * g  # line 8
        mq2, mb2 = rsvd_lib.rsvd_qb(mt, om, use_pallas)  # line 9
        if use_pallas:
            w2 = upd.lion_apply(w, c, lr, hp.weight_decay)  # line 10
        else:
            w2 = ref.lion_apply(w, c, lr, hp.weight_decay)
        return w2, mq2, mb2

    ios = [
        _io("w", shape), _io("g", shape),
        _io("mq", (m, l)), _io("mb", (l, n)),
        _io("om", (n, l)), _scalar("lr"),
    ]
    return StepGraph("mlorc_lion", shape, f, ios, ["w", "mq", "mb"], hp.to_json(), rank, l)


def build_mlorc_m(shape, rank, p_over, hp: OptHParams, use_pallas=True) -> StepGraph:
    """Ablation (Table 7): compress the first moment only."""
    m, n = shape
    l = rank + p_over

    def f(w, g, mq, mb, v, om_m, lr, c1, c2):
        mt = upd.recon_axpy(mq, mb, g, hp.beta1) if use_pallas else ref.recon_axpy(mq, mb, g, hp.beta1)
        v2 = hp.beta2 * v + (1.0 - hp.beta2) * g * g
        mq2, mb2 = rsvd_lib.rsvd_qb(mt, om_m, use_pallas)
        if use_pallas:
            w2 = upd.adamw_apply(w, mt, v2, lr, c1, c2, hp.weight_decay, hp.eps)
        else:
            w2 = ref.adamw_apply(w, mt, v2, lr, c1, c2, hp.weight_decay, hp.eps)
        return w2, mq2, mb2, v2

    ios = [
        _io("w", shape), _io("g", shape),
        _io("mq", (m, l)), _io("mb", (l, n)), _io("v", shape),
        _io("om_m", (n, l)),
        _scalar("lr"), _scalar("c1"), _scalar("c2"),
    ]
    return StepGraph("mlorc_m", shape, f, ios, ["w", "mq", "mb", "v"], hp.to_json(), rank, l)


def build_mlorc_v(shape, rank, p_over, hp: OptHParams, use_pallas=True) -> StepGraph:
    """Ablation (Table 7): compress the second moment only."""
    m, n = shape
    l = rank + p_over

    def f(w, g, m_, vq, vb, om_v, lr, c1, c2):
        m2 = hp.beta1 * m_ + (1.0 - hp.beta1) * g
        zeta = _zeta(vq, vb, n, use_pallas)
        vt = upd.recon_v_update(vq, vb, g, zeta, hp.beta2) if use_pallas else ref.recon_v_update(vq, vb, g, zeta, hp.beta2)
        vq2, vb2 = rsvd_lib.rsvd_qb(vt, om_v, use_pallas)
        if use_pallas:
            w2 = upd.adamw_apply(w, m2, vt, lr, c1, c2, hp.weight_decay, hp.eps)
        else:
            w2 = ref.adamw_apply(w, m2, vt, lr, c1, c2, hp.weight_decay, hp.eps)
        return w2, m2, vq2, vb2

    ios = [
        _io("w", shape), _io("g", shape),
        _io("m", shape), _io("vq", (m, l)), _io("vb", (l, n)),
        _io("om_v", (n, l)),
        _scalar("lr"), _scalar("c1"), _scalar("c2"),
    ]
    return StepGraph("mlorc_v", shape, f, ios, ["w", "m", "vq", "vb"], hp.to_json(), rank, l)


# --------------------------------------------------------------- GaLore ----


def galore_left(shape) -> bool:
    """GaLore projects the shorter side (Zhao et al. 2024, App. A)."""
    m, n = shape
    return m <= n


def build_galore_project(shape, rank, p_over) -> StepGraph:
    """Projector refresh graph (every T steps, rust-scheduled): randomized
    range finder of the current gradient, replacing the paper's exact SVD —
    same dominant subspace up to the usual RSVD tail bound."""
    m, n = shape
    l = rank + p_over
    left = galore_left(shape)

    if left:
        def f(g, om):
            y = kern.a_omega(g, om)
            return (rsvd_lib.mgs_qr(y),)
        ios = [_io("g", shape), _io("om", (n, l))]
        pshape = (m, l)
    else:
        def f(g, om):
            y = jnp.transpose(g) @ om  # (n, l) — row-space range finder
            return (rsvd_lib.mgs_qr(y),)
        ios = [_io("g", shape), _io("om", (m, l))]
        pshape = (n, l)

    sg = StepGraph("galore_project", shape, f, ios, ["p"], {}, rank, l)
    sg.hparams = {"projector_shape": list(pshape), "left": left}
    return sg


def build_galore(shape, rank, p_over, hp: OptHParams, use_pallas=True) -> StepGraph:
    """AdamW in the projected subspace; back-projected full-parameter update
    scaled by galore_scale (the official alpha=0.25)."""
    m, n = shape
    l = rank + p_over
    left = galore_left(shape)
    pshape = (m, l) if left else (n, l)
    rshape = (l, n) if left else (m, l)

    def f(w, g, p, M, V, lr, c1, c2):
        if left:
            r = kern.qt_a(p, g) if use_pallas else p.T @ g  # (l, n)
        else:
            r = kern.a_omega(g, p) if use_pallas else g @ p  # (m, l)
        M2 = hp.beta1 * M + (1.0 - hp.beta1) * r
        V2 = hp.beta2 * V + (1.0 - hp.beta2) * r * r
        nhat = (M2 * c1) / (jnp.sqrt(V2 * c2) + hp.eps)
        if left:
            full = kern.qb_matmul(p, nhat) if use_pallas else p @ nhat
        else:
            full = nhat @ p.T
        w2 = w - lr * (hp.galore_scale * full + hp.weight_decay * w)
        return w2, M2, V2

    ios = [
        _io("w", shape), _io("g", shape), _io("p", pshape),
        _io("M", rshape), _io("V", rshape),
        _scalar("lr"), _scalar("c1"), _scalar("c2"),
    ]
    sg = StepGraph("galore", shape, f, ios, ["w", "M", "V"], hp.to_json(), rank, l)
    sg.hparams = dict(sg.hparams, left=left)
    return sg


# -------------------------------------------------------------- LDAdamW ----


def build_ldadamw(shape, rank, p_over, hp: OptHParams, use_pallas=True) -> StepGraph:
    """LDAdam-style baseline (Robert et al., 2024): per-step projector from
    the error-compensated gradient, projection-aware rotation of the
    low-dimensional optimizer state, and a full-size error-feedback buffer
    (which is exactly why it loses the memory comparison in Table 3)."""
    m, n = shape
    l = rank + p_over
    left = galore_left(shape)
    pshape = (m, l) if left else (n, l)
    rshape = (l, n) if left else (m, l)

    def f(w, g, p_old, M, V, e, om, lr, c1, c2):
        a = g + e
        if left:
            y = kern.a_omega(a, om) if use_pallas else a @ om
            p = rsvd_lib.mgs_qr(y)
            r = kern.qt_a(p, a) if use_pallas else p.T @ a  # (l, n)
            rot = p.T @ p_old  # (l, l) basis rotation
            M2 = hp.beta1 * (rot @ M) + (1.0 - hp.beta1) * r
            V2 = hp.beta2 * jnp.abs(rot @ V) + (1.0 - hp.beta2) * r * r
            nhat = (M2 * c1) / (jnp.sqrt(V2 * c2) + hp.eps)
            full = kern.qb_matmul(p, nhat) if use_pallas else p @ nhat
            e2 = a - (kern.qb_matmul(p, r) if use_pallas else p @ r)
        else:
            y = jnp.transpose(a) @ om
            p = rsvd_lib.mgs_qr(y)  # (n, l)
            r = a @ p  # (m, l)
            rot = p.T @ p_old
            M2 = hp.beta1 * (M @ rot.T) + (1.0 - hp.beta1) * r
            V2 = hp.beta2 * jnp.abs(V @ rot.T) + (1.0 - hp.beta2) * r * r
            nhat = (M2 * c1) / (jnp.sqrt(V2 * c2) + hp.eps)
            full = nhat @ p.T
            e2 = a - r @ p.T
        w2 = w - lr * (full + hp.weight_decay * w)
        return w2, p, M2, V2, e2

    ios = [
        _io("w", shape), _io("g", shape), _io("p", pshape),
        _io("M", rshape), _io("V", rshape), _io("e", shape),
        _io("om", ((n, l) if left else (m, l))),
        _scalar("lr"), _scalar("c1"), _scalar("c2"),
    ]
    sg = StepGraph("ldadamw", shape, f, ios, ["w", "p", "M", "V", "e"], hp.to_json(), rank, l)
    sg.hparams = dict(sg.hparams, left=left)
    return sg


# ------------------------------------------------------------- registry ----


def build_step(method: str, shape, rank: int, p_over: int, hp: OptHParams,
               use_pallas=True) -> StepGraph:
    if len(shape) == 1:
        # Vector parameters always use the uncompressed path.
        assert method in ("adamw", "lion"), method
    if method == "adamw":
        return build_adamw(shape, hp, use_pallas)
    if method == "lion":
        return build_lion(shape, hp, use_pallas)
    if method == "mlorc_adamw":
        return build_mlorc_adamw(shape, rank, p_over, hp, use_pallas)
    if method == "mlorc_lion":
        return build_mlorc_lion(shape, rank, p_over, hp, use_pallas)
    if method == "mlorc_m":
        return build_mlorc_m(shape, rank, p_over, hp, use_pallas)
    if method == "mlorc_v":
        return build_mlorc_v(shape, rank, p_over, hp, use_pallas)
    if method == "galore":
        return build_galore(shape, rank, p_over, hp, use_pallas)
    if method == "galore_project":
        return build_galore_project(shape, rank, p_over)
    if method == "ldadamw":
        return build_ldadamw(shape, rank, p_over, hp, use_pallas)
    raise ValueError(f"unknown method {method}")
