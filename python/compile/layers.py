"""Transformer building blocks (Layer-2, plain jnp).

Everything here must lower to pure HLO parseable by xla_extension 0.5.1:
no ``jnp.linalg``, no erf (tanh-GELU only), no jax.random on the graph path.
"""

import jax
import jax.numpy as jnp


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def gelu(x):
    """tanh-approximate GELU — avoids the erf HLO op, which the pinned
    xla_extension text parser predates."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def causal_attention(x, wq, wk, wv, wo, n_heads: int):
    """Multi-head causal self-attention; weights are (d, d) matrices."""
    B, T, D = x.shape
    H = n_heads
    dh = D // H

    def split(w):
        return (x @ w).reshape(B, T, H, dh).transpose(0, 2, 1, 3)  # B,H,T,dh

    q, k, v = split(wq), split(wk), split(wv)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.asarray(dh, x.dtype))
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(mask, scores, jnp.asarray(-1e9, x.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B, T, D)
    return ctx @ wo


def mlp(x, w1, w2):
    return gelu(x @ w1) @ w2


def block(x, p, i: int, n_heads: int):
    """Pre-LN transformer block; `p` is the params dict, `i` the layer idx."""
    h = layer_norm(x, p[f"blk{i}.ln1_g"], p[f"blk{i}.ln1_b"])
    x = x + causal_attention(
        h, p[f"blk{i}.wq"], p[f"blk{i}.wk"], p[f"blk{i}.wv"], p[f"blk{i}.wo"], n_heads
    )
    h = layer_norm(x, p[f"blk{i}.ln2_g"], p[f"blk{i}.ln2_b"])
    x = x + mlp(h, p[f"blk{i}.w1"], p[f"blk{i}.w2"])
    return x
