"""Model presets and optimizer hyper-parameter defaults shared by the AOT
pipeline and (via artifacts/manifest.json) the rust coordinator.

These are the single source of truth: `aot.py` embeds the full resolved
config into the manifest, and the rust side never re-declares dimensions.
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer LM (pre-LN, tanh-GELU MLP, tied LM head)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    vocab: int
    seq: int
    batch: int
    rank: int  # compression rank r for MLorc/GaLore/LoRA/LDAdamW
    oversample: int = 0  # RSVD oversampling p (paper uses p=0 everywhere)
    d_ff: int = 0  # defaults to 4*d_model
    n_cls: int = 2  # classification-head classes (SynGLUE)
    eval_batch: int = 0  # defaults to batch

    def __post_init__(self):
        if self.d_ff == 0:
            object.__setattr__(self, "d_ff", 4 * self.d_model)
        if self.eval_batch == 0:
            object.__setattr__(self, "eval_batch", self.batch)
        assert self.d_model % self.n_heads == 0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def l(self) -> int:
        """Stored factor width: rank + oversampling."""
        return self.rank + self.oversample


# Presets. `base100m` is the end-to-end target (~100M params); the smaller
# ones keep artifact builds and CI-style tests fast.
PRESETS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig("nano", d_model=64, n_layers=2, n_heads=2, vocab=256, seq=32, batch=4, rank=4),
        ModelConfig("tiny", d_model=128, n_layers=4, n_heads=4, vocab=512, seq=64, batch=8, rank=4),
        ModelConfig("small", d_model=256, n_layers=6, n_heads=8, vocab=1024, seq=128, batch=8, rank=8),
        ModelConfig(
            "base100m",
            d_model=768,
            n_layers=12,
            n_heads=12,
            vocab=16384,
            seq=256,
            batch=2,
            rank=4,
        ),
    ]
}


@dataclass(frozen=True)
class OptHParams:
    """Optimizer hyper-parameters baked into the lowered step graphs.

    Learning rate and Adam bias corrections are *runtime inputs* (the rust
    coordinator owns the schedule); everything here is a lowering-time
    constant, recorded in the manifest.
    """

    beta1: float
    beta2: float
    eps: float = 1e-8
    weight_decay: float = 0.0
    galore_scale: float = 0.25
    lora_alpha: float = 16.0

    def to_json(self):
        return asdict(self)


# Paper defaults: MLorc-AdamW uses beta1=0.8 (Section 4.1), AdamW otherwise
# 0.9/0.999; Lion uses 0.9/0.99 (Chen et al., 2023).
HPARAMS: Dict[str, OptHParams] = {
    "adamw": OptHParams(beta1=0.9, beta2=0.999),
    "mlorc_adamw": OptHParams(beta1=0.8, beta2=0.999),
    "mlorc_m": OptHParams(beta1=0.8, beta2=0.999),
    "mlorc_v": OptHParams(beta1=0.8, beta2=0.999),
    "lion": OptHParams(beta1=0.9, beta2=0.99, weight_decay=0.0),
    "mlorc_lion": OptHParams(beta1=0.9, beta2=0.99, weight_decay=0.0),
    "galore": OptHParams(beta1=0.9, beta2=0.999),
    "ldadamw": OptHParams(beta1=0.9, beta2=0.999),
    "lora_adamw": OptHParams(beta1=0.9, beta2=0.999),
    "lora_lion": OptHParams(beta1=0.9, beta2=0.99),
}

# Matrix-parameter optimizer methods and the per-shape state they carry.
# Used by aot.py to enumerate step graphs and by tests.
MATRIX_METHODS: List[str] = [
    "adamw",
    "lion",
    "mlorc_adamw",
    "mlorc_lion",
    "mlorc_m",
    "mlorc_v",
    "galore",
    "ldadamw",
]

# Vector (1-D) parameters always take the uncompressed path.
VECTOR_METHODS: List[str] = ["adamw", "lion"]


def pallas_tiles(m: int, n: int) -> Tuple[int, int]:
    """Block sizes for the Pallas kernels: largest power-of-two tiles that
    divide the operand (capped at 256) so interpret-mode grids stay small
    while the BlockSpec still expresses a real HBM->VMEM schedule."""

    def tile(x: int, cap: int = 256) -> int:
        t = 1
        while t * 2 <= min(x, cap) and x % (t * 2) == 0:
            t *= 2
        return t

    return tile(m), tile(n)
