# L2: the paper's compute graphs — decoder-only transformer LM (fwd/bwd,
# eval), LoRA variant, and a sequence-classification head for SynGLUE.
#
# Graphs are flat-argument functions (tokens/targets first, then parameters
# in `param_spec` order) so the rust coordinator's IO stays table-driven via
# the manifest. Losses mask padding with target id -1.
import jax
import jax.numpy as jnp

from . import layers
from .configs import ModelConfig

PAD_TARGET = -1  # masked-out position in `targets` / ignore label

# Matrix kinds inside a block, in spec order. All are momentum-compressed;
# vectors (LN gains/biases) and embeddings take the uncompressed path, and
# LoRA adapters attach to exactly these six matrices (alpha/r scaling).
BLOCK_MATS = ["wq", "wk", "wv", "wo", "w1", "w2"]


def param_spec(cfg: ModelConfig, cls_head: bool = False):
    """Ordered parameter table: (name, shape, kind) with kind in
    {"matrix", "vector", "embed"}. The manifest serializes this verbatim."""
    d, V, T = cfg.d_model, cfg.vocab, cfg.seq
    spec = [("tok_emb", (V, d), "embed"), ("pos_emb", (T, d), "embed")]
    for i in range(cfg.n_layers):
        for nm in ("ln1_g", "ln1_b"):
            spec.append((f"blk{i}.{nm}", (d,), "vector"))
        for nm in ("wq", "wk", "wv", "wo"):
            spec.append((f"blk{i}.{nm}", (d, d), "matrix"))
        for nm in ("ln2_g", "ln2_b"):
            spec.append((f"blk{i}.{nm}", (d,), "vector"))
        spec.append((f"blk{i}.w1", (d, cfg.d_ff), "matrix"))
        spec.append((f"blk{i}.w2", (cfg.d_ff, d), "matrix"))
    spec.append(("lnf_g", (d,), "vector"))
    spec.append(("lnf_b", (d,), "vector"))
    if cls_head:
        # kind "head": 2-D but never momentum-compressed (r would exceed n).
        spec.append(("cls_head", (d, cfg.n_cls), "head"))
    return spec


def lora_spec(cfg: ModelConfig):
    """Adapter table for the LoRA variant: (name, shape) — A is (r, n),
    B is (m, r), B zero-initialized (Hu et al., 2022)."""
    r = cfg.rank
    out = []
    shapes = {
        "wq": (cfg.d_model, cfg.d_model),
        "wk": (cfg.d_model, cfg.d_model),
        "wv": (cfg.d_model, cfg.d_model),
        "wo": (cfg.d_model, cfg.d_model),
        "w1": (cfg.d_model, cfg.d_ff),
        "w2": (cfg.d_ff, cfg.d_model),
    }
    for i in range(cfg.n_layers):
        for nm in BLOCK_MATS:
            m, n = shapes[nm]
            out.append((f"blk{i}.{nm}.lora_B", (m, r)))
            out.append((f"blk{i}.{nm}.lora_A", (r, n)))
    return out


def init_params(cfg: ModelConfig, seed: int = 0, cls_head: bool = False):
    """Test/build-time initializer (numpy); the production initializer is
    rust-side (linalg::rng) with the same scheme: N(0, 0.02), residual
    projections scaled by 1/sqrt(2L), LN gains 1, biases 0."""
    import numpy as np

    rng = np.random.default_rng(seed)
    out = {}
    for name, shape, kind in param_spec(cfg, cls_head):
        if kind == "vector":
            out[name] = np.ones(shape, np.float32) if name.endswith("_g") else np.zeros(shape, np.float32)
        else:
            scale = 0.02
            if name.endswith(".wo") or name.endswith(".w2"):
                scale = 0.02 / np.sqrt(2.0 * cfg.n_layers)
            out[name] = (rng.standard_normal(shape) * scale).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in out.items()}


def _params_dict(cfg, flat, cls_head=False):
    spec = param_spec(cfg, cls_head)
    assert len(flat) == len(spec), (len(flat), len(spec))
    return {name: x for (name, _, _), x in zip(spec, flat)}


def forward(p, tokens, cfg: ModelConfig):
    """Token logits (B, T, V); LM head tied to tok_emb."""
    B, T = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :T, :]
    for i in range(cfg.n_layers):
        x = layers.block(x, p, i, cfg.n_heads)
    x = layers.layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["tok_emb"].T


def hidden(p, tokens, cfg: ModelConfig):
    B, T = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :T, :]
    for i in range(cfg.n_layers):
        x = layers.block(x, p, i, cfg.n_heads)
    return layers.layer_norm(x, p["lnf_g"], p["lnf_b"])


def _masked_ce(logits, targets):
    """Mean cross-entropy over positions with target != PAD_TARGET."""
    mask = (targets != PAD_TARGET).astype(jnp.float32)
    safe = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def lm_loss(p, tokens, targets, cfg: ModelConfig):
    return _masked_ce(forward(p, tokens, cfg), targets)


def make_fwd_bwd(cfg: ModelConfig):
    """(tokens, targets, *params) -> (loss, *grads) in spec order."""

    def f(tokens, targets, *flat):
        p = _params_dict(cfg, flat)
        loss, grads = jax.value_and_grad(lambda q: lm_loss(q, tokens, targets, cfg))(p)
        order = [name for name, _, _ in param_spec(cfg)]
        return (loss, *[grads[name] for name in order])

    return f


def make_eval(cfg: ModelConfig):
    """(tokens, targets, *params) -> (loss, correct_mask f32[B,T]).

    correct_mask is 1 where argmax(logits) == target and the target is not
    padding; the rust side aggregates token accuracy and answer-region
    exact match from it (teacher-forced evaluation, see DESIGN.md §2)."""

    def f(tokens, targets, *flat):
        p = _params_dict(cfg, flat)
        logits = forward(p, tokens, cfg)
        loss = _masked_ce(logits, targets)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        ok = (pred == targets) & (targets != PAD_TARGET)
        return loss, ok.astype(jnp.float32)

    return f


# ---------------------------------------------------------------- LoRA ----


def _lora_forward(p, adapters, tokens, cfg: ModelConfig, alpha: float):
    scale = alpha / cfg.rank
    q = dict(p)
    for i in range(cfg.n_layers):
        for nm in BLOCK_MATS:
            key = f"blk{i}.{nm}"
            q[key] = p[key] + scale * (adapters[f"{key}.lora_B"] @ adapters[f"{key}.lora_A"])
    return forward(q, tokens, cfg)


def make_lora_fwd_bwd(cfg: ModelConfig, alpha: float):
    """(tokens, targets, *base_params, *adapters) -> (loss, *adapter_grads).

    Base weights are frozen inputs; only adapters receive gradients."""
    aspec = lora_spec(cfg)

    def f(tokens, targets, *flat):
        nbase = len(param_spec(cfg))
        p = _params_dict(cfg, flat[:nbase])
        a = {name: x for (name, _), x in zip(aspec, flat[nbase:])}

        def loss_of(a_):
            return _masked_ce(_lora_forward(p, a_, tokens, cfg, alpha), targets)

        loss, grads = jax.value_and_grad(loss_of)(a)
        return (loss, *[grads[name] for name, _ in aspec])

    return f


def make_lora_eval(cfg: ModelConfig, alpha: float):
    aspec = lora_spec(cfg)

    def f(tokens, targets, *flat):
        nbase = len(param_spec(cfg))
        p = _params_dict(cfg, flat[:nbase])
        a = {name: x for (name, _), x in zip(aspec, flat[nbase:])}
        logits = _lora_forward(p, a, tokens, cfg, alpha)
        loss = _masked_ce(logits, targets)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        ok = (pred == targets) & (targets != PAD_TARGET)
        return loss, ok.astype(jnp.float32)

    return f


# ------------------------------------------------- classification head ----


def cls_logits(p, tokens, cfg: ModelConfig):
    """Mean-pooled sequence classification (SynGLUE); pad token id 0 is
    excluded from the pool."""
    h = hidden(p, tokens, cfg)
    mask = (tokens != 0).astype(jnp.float32)[..., None]
    pooled = jnp.sum(h * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    return pooled @ p["cls_head"]


def _cls_ce(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def make_cls_fwd_bwd(cfg: ModelConfig):
    """(tokens, labels, *params_with_head) -> (loss, *grads)."""

    def f(tokens, labels, *flat):
        p = _params_dict(cfg, flat, cls_head=True)
        loss, grads = jax.value_and_grad(
            lambda q: _cls_ce(cls_logits(q, tokens, cfg), labels)
        )(p)
        order = [name for name, _, _ in param_spec(cfg, cls_head=True)]
        return (loss, *[grads[name] for name in order])

    return f


def make_cls_eval(cfg: ModelConfig):
    def f(tokens, labels, *flat):
        p = _params_dict(cfg, flat, cls_head=True)
        logits = cls_logits(p, tokens, cfg)
        loss = _cls_ce(logits, labels)
        ok = (jnp.argmax(logits, axis=-1).astype(jnp.int32) == labels)
        return loss, ok.astype(jnp.float32)

    return f


def _lora_merged(p, adapters, cfg: ModelConfig, alpha: float):
    scale = alpha / cfg.rank
    q = dict(p)
    for i in range(cfg.n_layers):
        for nm in BLOCK_MATS:
            key = f"blk{i}.{nm}"
            q[key] = p[key] + scale * (adapters[f"{key}.lora_B"] @ adapters[f"{key}.lora_A"])
    return q


def make_cls_lora_fwd_bwd(cfg: ModelConfig, alpha: float):
    """(tokens, labels, *base_params_with_head, *adapters) ->
    (loss, cls_head_grad, *adapter_grads). The tiny classification head
    stays trainable alongside the adapters (standard LoRA practice)."""
    aspec = lora_spec(cfg)

    def f(tokens, labels, *flat):
        nbase = len(param_spec(cfg, cls_head=True))
        p = _params_dict(cfg, flat[:nbase], cls_head=True)
        a = {name: x for (name, _), x in zip(aspec, flat[nbase:])}

        def loss_of(head, a_):
            q = _lora_merged(p, a_, cfg, alpha)
            q["cls_head"] = head
            return _cls_ce(cls_logits(q, tokens, cfg), labels)

        loss, (ghead, grads) = jax.value_and_grad(loss_of, argnums=(0, 1))(
            p["cls_head"], a
        )
        return (loss, ghead, *[grads[name] for name, _ in aspec])

    return f


def make_cls_lora_eval(cfg: ModelConfig, alpha: float):
    aspec = lora_spec(cfg)

    def f(tokens, labels, *flat):
        nbase = len(param_spec(cfg, cls_head=True))
        p = _params_dict(cfg, flat[:nbase], cls_head=True)
        a = {name: x for (name, _), x in zip(aspec, flat[nbase:])}
        q = _lora_merged(p, a, cfg, alpha)
        logits = cls_logits(q, tokens, cfg)
        loss = _cls_ce(logits, labels)
        ok = (jnp.argmax(logits, axis=-1).astype(jnp.int32) == labels)
        return loss, ok.astype(jnp.float32)

    return f
