# AOT lowering: every Layer-2 graph -> artifacts/*.hlo.txt + manifest.json.
#
# Interchange is HLO *text*, never `.serialize()`: jax >= 0.5 emits protos
# with 64-bit instruction ids that the pinned xla_extension 0.5.1 rejects
# (`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
# cleanly (see /opt/xla-example/README.md). Python runs exactly once per
# artifact build — the rust coordinator never imports it.
import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as mdl
from . import optim_steps as opt
from .configs import HPARAMS, MATRIX_METHODS, PRESETS, ModelConfig

SCALAR_LAYOUT = ["lr", "c1", "c2", "wd", "eps", "beta", "zeta", "unused"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype="float32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _check_pure(text: str, name: str):
    """Artifact-path graphs must be custom-call-free: LAPACK/Mosaic calls
    cannot execute on the pinned CPU PJRT client."""
    if "custom-call" in text:
        lines = [l.strip() for l in text.splitlines() if "custom-call" in l][:3]
        raise RuntimeError(f"graph {name} contains custom-call(s): {lines}")


def _write(out_dir: str, rel: str, text: str, name: str) -> dict:
    _check_pure(text, name)
    path = os.path.join(out_dir, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    return {
        "file": rel,
        "bytes": len(text),
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }


def lower_model_graphs(cfg: ModelConfig, out_dir: str, graphs: list, log) -> dict:
    """Lower the model-level graphs for one preset."""
    B, T = cfg.batch, cfg.seq
    spec = mdl.param_spec(cfg)
    spec_cls = mdl.param_spec(cfg, cls_head=True)
    aspec = mdl.lora_spec(cfg)
    alpha = HPARAMS["lora_adamw"].lora_alpha
    tok = _sds((B, T), "int32")
    tgt = _sds((B, T), "int32")
    lbl = _sds((B,), "int32")

    def params_sds(s):
        return [_sds(shape) for _, shape, _ in s]

    def adapters_sds():
        return [_sds(shape) for _, shape in aspec]

    defs = {
        "fwd_bwd": (
            mdl.make_fwd_bwd(cfg),
            [tok, tgt, *params_sds(spec)],
            ["tokens", "targets", *[n for n, _, _ in spec]],
            ["loss", *[f"g:{n}" for n, _, _ in spec]],
        ),
        "eval": (
            mdl.make_eval(cfg),
            [tok, tgt, *params_sds(spec)],
            ["tokens", "targets", *[n for n, _, _ in spec]],
            ["loss", "correct_mask"],
        ),
        "lora_fwd_bwd": (
            mdl.make_lora_fwd_bwd(cfg, alpha),
            [tok, tgt, *params_sds(spec), *adapters_sds()],
            ["tokens", "targets", *[n for n, _, _ in spec], *[n for n, _ in aspec]],
            ["loss", *[f"g:{n}" for n, _ in aspec]],
        ),
        "lora_eval": (
            mdl.make_lora_eval(cfg, alpha),
            [tok, tgt, *params_sds(spec), *adapters_sds()],
            ["tokens", "targets", *[n for n, _, _ in spec], *[n for n, _ in aspec]],
            ["loss", "correct_mask"],
        ),
        "cls_fwd_bwd": (
            mdl.make_cls_fwd_bwd(cfg),
            [tok, lbl, *params_sds(spec_cls)],
            ["tokens", "labels", *[n for n, _, _ in spec_cls]],
            ["loss", *[f"g:{n}" for n, _, _ in spec_cls]],
        ),
        "cls_eval": (
            mdl.make_cls_eval(cfg),
            [tok, lbl, *params_sds(spec_cls)],
            ["tokens", "labels", *[n for n, _, _ in spec_cls]],
            ["loss", "correct"],
        ),
        "cls_lora_fwd_bwd": (
            mdl.make_cls_lora_fwd_bwd(cfg, alpha),
            [tok, lbl, *params_sds(spec_cls), *adapters_sds()],
            ["tokens", "labels", *[n for n, _, _ in spec_cls], *[n for n, _ in aspec]],
            ["loss", "g:cls_head", *[f"g:{n}" for n, _ in aspec]],
        ),
        "cls_lora_eval": (
            mdl.make_cls_lora_eval(cfg, alpha),
            [tok, lbl, *params_sds(spec_cls), *adapters_sds()],
            ["tokens", "labels", *[n for n, _, _ in spec_cls], *[n for n, _ in aspec]],
            ["loss", "correct"],
        ),
    }

    out = {}
    for gname in graphs:
        fn, args, in_names, out_names = defs[gname]
        t0 = time.time()
        text = to_hlo_text(jax.jit(fn).lower(*args))
        entry = _write(out_dir, f"{cfg.name}/{gname}.hlo.txt", text, f"{cfg.name}/{gname}")
        entry["inputs"] = [
            {"name": nm, "shape": list(a.shape), "dtype": str(a.dtype)}
            for nm, a in zip(in_names, args)
        ]
        entry["outputs"] = out_names
        out[gname] = entry
        log(f"  [{cfg.name}] {gname}: {entry['bytes']/1e3:.0f} kB ({time.time()-t0:.1f}s)")
    return out


def matrix_shapes(cfg: ModelConfig) -> list:
    """Distinct compressed-matrix shapes for a preset."""
    d, ff = cfg.d_model, cfg.d_ff
    return sorted({(d, d), (d, ff), (ff, d)})


def uncompressed_shapes(cfg: ModelConfig) -> list:
    """2-D shapes updated by the plain optimizers: embeddings, cls head,
    LoRA adapter factors."""
    d, ff, r = cfg.d_model, cfg.d_ff, cfg.rank
    shapes = {(cfg.vocab, d), (cfg.seq, d), (d, cfg.n_cls)}
    shapes |= {(d, r), (r, d), (r, ff), (ff, r)}  # LoRA A/B factors
    return sorted(shapes)


def lower_opt_steps(cfg: ModelConfig, out_dir: str, methods: list, log) -> dict:
    """Lower optimizer step graphs for every (method, shape) this preset
    needs. Files are named by method/shape/rank so presets that share
    shapes share artifacts (identical content, idempotent overwrite)."""
    out = {}
    rank, p_over = cfg.rank, cfg.oversample

    def add(method, shape, sg: opt.StepGraph):
        key = "x".join(str(s) for s in shape)
        t0 = time.time()
        text = to_hlo_text(jax.jit(sg.fn).lower(*sg.example_args()))
        rel = f"opt/{method}_{key}_r{sg.rank}.hlo.txt"
        entry = _write(out_dir, rel, text, rel)
        entry.update(
            inputs=sg.inputs,
            outputs=sg.outputs,
            rank=sg.rank,
            l=sg.l,
            hparams=sg.hparams,
        )
        out.setdefault(method, {})[key] = entry
        log(f"  [opt] {method} {key}: {entry['bytes']/1e3:.0f} kB ({time.time()-t0:.1f}s)")

    for shape in matrix_shapes(cfg):
        for method in methods:
            hp = HPARAMS.get(method, HPARAMS["adamw"])
            add(method, shape, opt.build_step(method, shape, rank, p_over, hp))
        if "galore" in methods:
            add(
                "galore_project",
                shape,
                opt.build_step("galore_project", shape, rank, p_over, HPARAMS["galore"]),
            )

    # Plain AdamW/Lion serve embeddings, heads, LoRA factors and vectors
    # regardless of which compressed methods were requested.
    for shape in uncompressed_shapes(cfg):
        for method in ("adamw", "lion"):
            add(method, shape, opt.build_step(method, shape, 0, 0, HPARAMS[method]))
    for shape in [(cfg.d_model,)]:
        for method in ("adamw", "lion"):
            add(method, shape, opt.build_step(method, shape, 0, 0, HPARAMS[method]))
    return out


def preset_manifest(cfg: ModelConfig, graphs: dict, opt_steps: dict) -> dict:
    return {
        "model": {
            "name": cfg.name,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "rank": cfg.rank,
            "oversample": cfg.oversample,
            "d_ff": cfg.d_ff,
            "n_cls": cfg.n_cls,
        },
        "params": [
            {"name": n, "shape": list(s), "kind": k, "compressed": k == "matrix"}
            for n, s, k in mdl.param_spec(cfg, cls_head=True)
        ],
        "lora_params": [{"name": n, "shape": list(s)} for n, s in mdl.lora_spec(cfg)],
        "hparams": {k: v.to_json() for k, v in HPARAMS.items()},
        "graphs": graphs,
        "opt_steps": opt_steps,
    }


ALL_GRAPHS = [
    "fwd_bwd",
    "eval",
    "lora_fwd_bwd",
    "lora_eval",
    "cls_fwd_bwd",
    "cls_eval",
    "cls_lora_fwd_bwd",
    "cls_lora_eval",
]


def main(argv=None):
    ap = argparse.ArgumentParser(description="MLorc AOT artifact builder")
    ap.add_argument("--presets", default="nano,tiny,small")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--methods", default=",".join(MATRIX_METHODS))
    ap.add_argument(
        "--graphs",
        default=",".join(ALL_GRAPHS),
        help="model graphs to lower (lm-only presets can drop cls_*/lora_*)",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    log = (lambda *a: None) if args.quiet else (lambda *a: print(*a, flush=True))
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "scalar_layout": SCALAR_LAYOUT, "presets": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    methods = [m for m in args.methods.split(",") if m]
    graphs = [g for g in args.graphs.split(",") if g]
    t0 = time.time()
    for name in args.presets.split(","):
        cfg = PRESETS[name]
        log(f"preset {name}: lowering {len(graphs)} model graphs + opt steps")
        g = lower_model_graphs(cfg, out_dir, graphs, log)
        steps = lower_opt_steps(cfg, out_dir, methods, log)
        manifest["presets"][name] = preset_manifest(cfg, g, steps)
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
    log(f"manifest: {manifest_path} ({time.time()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
