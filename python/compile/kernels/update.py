"""Layer-1 Pallas kernels for the momentum update / weight apply hot path.

The MLorc step touches every matrix entry a handful of times; these kernels
fuse the reconstruction matmul with the exponential-average update so the
full-size reconstructed momentum is never written back to HBM:

  * ``recon_axpy``      : ``out = beta * (Q @ B) + (1 - beta) * g``
  * ``recon_neg_stats`` : per-tile negative mass/count of ``Q @ B`` (pass 1
                          of Eq. (2)'s zeta repair)
  * ``recon_v_update``  : ``v = beta2 * fix(Q @ B, zeta) + (1-beta2) * g^2``
                          where ``fix(x) = x if x >= 0 else zeta`` (pass 2)
  * ``adamw_apply``     : fused bias-corrected AdamW weight update
  * ``lion_apply``      : fused sign update

Runtime scalars (lr, bias corrections, zeta) arrive as a single (1, 8) f32
operand broadcast to every tile, so one lowered graph serves the whole
schedule.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import pallas_tiles
from .rsvd import INTERPRET

# Scalar-pack layout (keep in sync with rust coordinator::trainer and the
# manifest "scalar_layout" field).
S_LR, S_C1, S_C2, S_WD, S_EPS, S_BETA, S_ZETA, S_UNUSED = range(8)


def _scalar_spec():
    return pl.BlockSpec((1, 8), lambda i, j: (0, 0))


def pack_scalars(lr=0.0, c1=1.0, c2=1.0, wd=0.0, eps=1e-8, beta=0.0, zeta=0.0):
    return jnp.array([[lr, c1, c2, wd, eps, beta, zeta, 0.0]], dtype=jnp.float32)


def _recon_axpy_kernel(q_ref, b_ref, g_ref, s_ref, o_ref):
    beta = s_ref[0, S_BETA]
    recon = jnp.dot(q_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = beta * recon + (1.0 - beta) * g_ref[...]


def recon_axpy(q: jax.Array, b: jax.Array, g: jax.Array, beta: float | jax.Array) -> jax.Array:
    """Fused ``beta * (Q @ B) + (1 - beta) * g`` over (bm, bn) tiles."""
    m, n = g.shape
    l = q.shape[1]
    bm, bn = pallas_tiles(m, n)
    s = pack_scalars(beta=beta)
    return pl.pallas_call(
        _recon_axpy_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, l), lambda i, j: (i, 0)),
            pl.BlockSpec((l, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(q, b, g, s)


def _recon_neg_stats_kernel(q_ref, b_ref, neg_ref, cnt_ref):
    recon = jnp.dot(q_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    negpart = jnp.where(recon < 0.0, -recon, 0.0)
    neg_ref[0, 0] = jnp.sum(negpart)
    cnt_ref[0, 0] = jnp.sum(jnp.where(recon < 0.0, 1.0, 0.0))


def recon_neg_stats(q: jax.Array, b: jax.Array, n_cols: int):
    """Pass 1 of Eq. (2): per-tile (negative mass, negative count) of Q @ B.

    Returns two (grid_m, grid_n) partial grids; the caller reduces them to
    the scalar zeta = sum(negmass) / max(sum(negcount), 1).
    """
    m = q.shape[0]
    l = q.shape[1]
    n = n_cols
    bm, bn = pallas_tiles(m, n)
    gm, gn = m // bm, n // bn
    neg, cnt = pl.pallas_call(
        _recon_neg_stats_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, l), lambda i, j: (i, 0)),
            pl.BlockSpec((l, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, b)
    return neg, cnt


def _recon_v_update_kernel(q_ref, b_ref, g_ref, s_ref, o_ref):
    beta2 = s_ref[0, S_BETA]
    zeta = s_ref[0, S_ZETA]
    recon = jnp.dot(q_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    # Eq. (2): ReLU(recon) + zeta * 1{recon < 0}  ==  where(recon < 0, zeta, recon)
    fixed = jnp.where(recon < 0.0, zeta, recon)
    g = g_ref[...]
    o_ref[...] = beta2 * fixed + (1.0 - beta2) * g * g


def recon_v_update(
    q: jax.Array, b: jax.Array, g: jax.Array, zeta: jax.Array, beta2: float
) -> jax.Array:
    """Pass 2 of Eq. (2) fused with the second-moment EMA update."""
    m, n = g.shape
    l = q.shape[1]
    bm, bn = pallas_tiles(m, n)
    s = pack_scalars(beta=beta2, zeta=zeta)
    return pl.pallas_call(
        _recon_v_update_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, l), lambda i, j: (i, 0)),
            pl.BlockSpec((l, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(q, b, g, s)


def _adamw_apply_kernel(w_ref, m_ref, v_ref, s_ref, o_ref):
    lr = s_ref[0, S_LR]
    c1 = s_ref[0, S_C1]
    c2 = s_ref[0, S_C2]
    wd = s_ref[0, S_WD]
    eps = s_ref[0, S_EPS]
    mhat = m_ref[...] * c1
    vhat = v_ref[...] * c2
    w = w_ref[...]
    o_ref[...] = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w)


def adamw_apply(w, m, v, lr, c1, c2, wd, eps) -> jax.Array:
    """W' = W - lr * (mhat / (sqrt(vhat) + eps) + wd * W), tiled VPU pass."""
    mm, nn = w.shape
    bm, bn = pallas_tiles(mm, nn)
    s = pack_scalars(lr=lr, c1=c1, c2=c2, wd=wd, eps=eps)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _adamw_apply_kernel,
        grid=(mm // bm, nn // bn),
        in_specs=[tile, tile, tile, _scalar_spec()],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=INTERPRET,
    )(w, m, v, s)


def _lion_apply_kernel(w_ref, c_ref, s_ref, o_ref):
    lr = s_ref[0, S_LR]
    wd = s_ref[0, S_WD]
    w = w_ref[...]
    o_ref[...] = w - lr * (jnp.sign(c_ref[...]) + wd * w)


def lion_apply(w, c, lr, wd) -> jax.Array:
    """W' = W - lr * (sign(c) + wd * W) (Lion / Algorithm 2 line 10)."""
    mm, nn = w.shape
    bm, bn = pallas_tiles(mm, nn)
    s = pack_scalars(lr=lr, wd=wd)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _lion_apply_kernel,
        grid=(mm // bm, nn // bn),
        in_specs=[tile, tile, _scalar_spec()],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=INTERPRET,
    )(w, c, s)
