"""Layer-1 Pallas kernels for the MLorc compression hot path: the two
tall-skinny matmuls of the QB randomized range finder.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the skinny dimension
``l = r + p`` is at most ~16, so one VMEM tile always holds the full skinny
operand and each kernel is a *single sweep* over the large momentum matrix —
every HBM element of ``A`` is read exactly once per RSVD. On the MXU this is
a (bm x n) @ (n x l) systolic pass per tile.

All kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls that the CPU PJRT plugin cannot execute. Correctness against
the pure-jnp oracles in ``ref.py`` is enforced by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import pallas_tiles

INTERPRET = True  # CPU-PJRT requirement; flip for real-TPU compile targets.


def _a_omega_kernel(a_ref, om_ref, y_ref):
    """Y tile = A tile @ Omega (full skinny operand resident in VMEM)."""
    y_ref[...] = jnp.dot(a_ref[...], om_ref[...], preferred_element_type=jnp.float32)


def a_omega(a: jax.Array, omega: jax.Array) -> jax.Array:
    """Random projection ``Y = A @ Omega`` — (m, n) @ (n, l) -> (m, l).

    Grid sweeps the m dimension; Omega (n x l) is broadcast to every step.
    """
    m, n = a.shape
    n2, l = omega.shape
    assert n == n2, (a.shape, omega.shape)
    bm, _ = pallas_tiles(m, n)
    return pl.pallas_call(
        _a_omega_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n, l), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, l), jnp.float32),
        interpret=INTERPRET,
    )(a, omega)


def _qt_a_kernel(q_ref, a_ref, b_ref):
    """B tile = Q^T @ A tile (Q resident; contraction over the long m dim)."""
    b_ref[...] = jnp.dot(q_ref[...].T, a_ref[...], preferred_element_type=jnp.float32)


def qt_a(q: jax.Array, a: jax.Array) -> jax.Array:
    """Second RSVD factor ``B = Q^T A`` — (m, l)^T @ (m, n) -> (l, n)."""
    m, l = q.shape
    m2, n = a.shape
    assert m == m2, (q.shape, a.shape)
    _, bn = pallas_tiles(m, n)
    return pl.pallas_call(
        _qt_a_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, l), lambda j: (0, 0)),
            pl.BlockSpec((m, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((l, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((l, n), jnp.float32),
        interpret=INTERPRET,
    )(q, a)


def _qb_kernel(q_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(q_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def qb_matmul(q: jax.Array, b: jax.Array) -> jax.Array:
    """Dense reconstruction ``Q @ B`` — (m, l) @ (l, n) -> (m, n).

    Used where a full reconstruction must materialize (GaLore back-projection,
    Lion's shared reconstruction); the MLorc-AdamW path prefers the fused
    kernels in ``update.py`` that never write the reconstruction to HBM.
    """
    m, l = q.shape
    l2, n = b.shape
    assert l == l2
    bm, bn = pallas_tiles(m, n)
    return pl.pallas_call(
        _qb_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, l), lambda i, j: (i, 0)),
            pl.BlockSpec((l, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(q, b)
