"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (``python/tests/test_kernels.py``) asserts ``assert_allclose`` between
each kernel and its oracle across a hypothesis-driven sweep of shapes and
values. These are also the reference implementations the rust ``optim/``
mirrors are validated against (three-way agreement, see DESIGN.md §6).
"""

import jax.numpy as jnp


def a_omega(a, omega):
    return a @ omega


def qt_a(q, a):
    return q.T @ a


def qb_matmul(q, b):
    return q @ b


def recon_axpy(q, b, g, beta):
    return beta * (q @ b) + (1.0 - beta) * g


def zeta_of(recon):
    """Absolute mean of the negative part (denominator guarded for the
    all-nonnegative case, where Eq. (2) is the identity)."""
    neg = recon < 0.0
    negsum = jnp.sum(jnp.where(neg, -recon, 0.0))
    negcnt = jnp.sum(jnp.where(neg, 1.0, 0.0))
    return negsum / jnp.maximum(negcnt, 1.0)


def recon_neg_stats(q, b):
    recon = q @ b
    neg = recon < 0.0
    return (
        jnp.sum(jnp.where(neg, -recon, 0.0)),
        jnp.sum(jnp.where(neg, 1.0, 0.0)),
    )


def v_fix(recon, zeta):
    """Eq. (2): ReLU(recon) + zeta * indicator(recon < 0)."""
    return jnp.where(recon < 0.0, zeta, recon)


def recon_v_update(q, b, g, zeta, beta2):
    return beta2 * v_fix(q @ b, zeta) + (1.0 - beta2) * g * g


def adamw_apply(w, m, v, lr, c1, c2, wd, eps):
    mhat = m * c1
    vhat = v * c2
    return w - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w)


def lion_apply(w, c, lr, wd):
    return w - lr * (jnp.sign(c) + wd * w)


def mgs_qr(y):
    """Reference modified Gram-Schmidt with one reorthogonalization pass.

    Matches rsvd_lib.mgs_qr; kept here so tests can cross-check against
    numpy's QR on well-conditioned inputs.
    """
    m, l = y.shape
    cols = []
    for j in range(l):
        v = y[:, j]
        for _ in range(2):
            for qi in cols:
                v = v - qi * (qi @ v)
        nrm2 = v @ v
        inv = jnp.where(nrm2 > 1e-30, 1.0 / jnp.sqrt(jnp.maximum(nrm2, 1e-30)), 0.0)
        cols.append(v * inv)
    return jnp.stack(cols, axis=1)


def rsvd_qb(a, omega):
    """QB randomized range-finder reference: A ~= Q (Q^T A)."""
    y = a @ omega
    q = mgs_qr(y)
    return q, q.T @ a
