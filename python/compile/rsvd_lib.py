"""QB-form randomized SVD (Halko et al., 2011, Alg. 4.1) on top of the
Layer-1 Pallas kernels.

MLorc only ever *reconstructs* the compressed momentum (``m ~= U S V^T``),
so we store the rank-l approximation in QB form: ``Q`` from a Gram-Schmidt
QR of ``A @ Omega`` and ``B = Q^T A``; then ``A ~= Q B``. With the paper's
oversampling p = 0 (Section D.1) this is *exactly* the reconstruction of
Algorithm 3 — the small SVD of B only rotates factors without changing
``Q B``. For p > 0, ``svd_truncate`` performs the small-side truncation and
is validated against numpy in pytest (build-time only; it never reaches an
artifact, keeping lowered graphs free of LAPACK custom-calls).

The MGS QR is unrolled over the l <= ~16 skinny columns, so it lowers to a
short chain of dots — no ``jnp.linalg`` on the artifact path.
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels import rsvd as kern


def mgs_qr(y: jnp.ndarray) -> jnp.ndarray:
    """Column-orthonormal Q from modified Gram-Schmidt with one
    reorthogonalization pass (CGS2-grade stability for skinny Y).

    Zero (or numerically dead) columns yield zero Q columns, which simply
    drop rank — exactly the behaviour wanted when momentum starts at 0.
    """
    m, l = y.shape
    cols = []
    for j in range(l):
        v = y[:, j]
        for _ in range(2):  # reorthogonalize once: "twice is enough"
            for qi in cols:
                v = v - qi * (qi @ v)
        nrm2 = v @ v
        inv = jnp.where(nrm2 > 1e-30, 1.0 / jnp.sqrt(jnp.maximum(nrm2, 1e-30)), 0.0)
        cols.append(v * inv)
    return jnp.stack(cols, axis=1)


def rsvd_qb(a: jnp.ndarray, omega: jnp.ndarray, use_pallas: bool = True):
    """Rank-l range finder: returns (Q, B) with A ~= Q @ B.

    ``omega`` is a host-supplied Gaussian (n, l) matrix — the rust
    coordinator owns the RNG, so lowered graphs are pure functions.
    """
    if use_pallas:
        y = kern.a_omega(a, omega)
        q = mgs_qr(y)
        b = kern.qt_a(q, a)
    else:
        y = ref.a_omega(a, omega)
        q = mgs_qr(y)
        b = ref.qt_a(q, a)
    return q, b


def reconstruct(q: jnp.ndarray, b: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    return kern.qb_matmul(q, b) if use_pallas else q @ b


def svd_truncate(q, b, rank: int):
    """Oversampled (p > 0) path: truncate the QB factorization to `rank`
    via an SVD of the small (l x n) factor. Build/test-time only."""
    import numpy as np

    u, s, vt = np.linalg.svd(np.asarray(b), full_matrices=False)
    u, s, vt = u[:, :rank], s[:rank], vt[:rank, :]
    q2 = np.asarray(q) @ (u * s)  # absorb the singular values into Q
    return jnp.asarray(q2), jnp.asarray(vt)
