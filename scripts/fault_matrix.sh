#!/usr/bin/env bash
# Fault-injection matrix for the serve subsystem (CI `fault-matrix` job).
#
# Each case arms a failpoint grid (MLORC_FAILPOINT, see
# rust/src/util/fsutil.rs for the grammar), runs `mlorc serve` into the
# fault, restarts, and requires the spool to drain completely
# (`mlorc status --expect-all-done`) with intact checkpoints
# (`mlorc fsck`). Injected kills must exit with code 86 so a real crash
# is never mistaken for the simulated one.
#
# Usage: bash scripts/fault_matrix.sh   (after `cargo build --release`)
set -euo pipefail

BIN=${BIN:-$(pwd)/target/release/mlorc}
if [ ! -x "$BIN" ]; then
  echo "mlorc binary not found at $BIN — run 'cargo build --release' first" >&2
  exit 1
fi
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT
cd "$WORK"

submit_jobs() { # <spool> <count>
  local spool=$1 count=$2 i
  for i in $(seq 1 "$count"); do
    "$BIN" submit --spool "$spool" --engine host --method mlorc_adamw \
      --steps 30 --checkpoint-every 10 --seed "$i"
  done
}

expect_kill() { # <cmd...> — the command must die with the injected-kill code
  set +e
  "$@"
  local code=$?
  set -e
  if [ "$code" -ne 86 ]; then
    echo "FAULT-MATRIX: expected injected-kill exit code 86, got $code" >&2
    exit 1
  fi
  echo "crashed with exit 86 as instructed"
}

echo "== case 1: torn LATEST flip, then kill at the 2nd cadence checkpoint =="
submit_jobs fm-torn 2
expect_kill env MLORC_FAILPOINT="latest_write:torn@2,ckpt_cadence:kill@2" \
  "$BIN" serve --spool fm-torn --jobs 2 --drain
"$BIN" serve --spool fm-torn --jobs 2 --drain --lease-timeout-ms 1000
"$BIN" status --spool fm-torn --expect-all-done
"$BIN" fsck fm-torn

echo "== case 2: kill mid-rotation (6th checkpoint-file write) =="
submit_jobs fm-rot 2
expect_kill env MLORC_FAILPOINT="ckpt_write:kill@6" \
  "$BIN" serve --spool fm-rot --jobs 2 --drain
"$BIN" serve --spool fm-rot --jobs 2 --drain --lease-timeout-ms 1000
"$BIN" status --spool fm-rot --expect-all-done
"$BIN" fsck fm-rot

echo "== case 3: ENOSPC on every status-file write =="
# status files are best-effort observability; the jobs themselves must
# still drain, and the aggregator must fall back to spec + lifecycle dir
submit_jobs fm-status 2
MLORC_FAILPOINT="status_write:enospc@1+" \
  "$BIN" serve --spool fm-status --jobs 2 --drain
"$BIN" status --spool fm-status --expect-all-done
"$BIN" fsck fm-status

echo "== case 4: scheduler killed mid-lease, second scheduler takes over =="
submit_jobs fm-lease 3
expect_kill "$BIN" serve --spool fm-lease --jobs 2 --drain \
  --die-after-checkpoints 2 --lease-timeout-ms 1500
"$BIN" serve --spool fm-lease --jobs 2 --drain --lease-timeout-ms 1500
"$BIN" status --spool fm-lease --expect-all-done
"$BIN" fsck fm-lease

echo "== case 5: legacy single-scheduler mode (lease timeout 0) recovers a kill -9 =="
# timeout-0 claims must write no lease: a lease surviving the kill would
# make the restart's startup sweep skip the job forever and hang --drain
submit_jobs fm-legacy 2
expect_kill "$BIN" serve --spool fm-legacy --jobs 2 --drain \
  --die-after-checkpoints 2 --lease-timeout-ms 0
"$BIN" serve --spool fm-legacy --jobs 2 --drain --lease-timeout-ms 0
"$BIN" status --spool fm-legacy --expect-all-done
"$BIN" fsck fm-legacy

echo "== case 6: async writer torn commit marker, sync escape hatch drains the rest =="
# Cadence saves run on the async writer thread by default. torn@8 tears
# the 2nd snapshot's meta.json (its commit marker: 4 checkpoint-file
# writes per snapshot with one job), the cadence kill then dies after
# that save was recorded. The restart must fall back to the intact 1st
# snapshot — and it runs with --checkpoint-sync to prove the inline
# escape hatch drains an async writer's spool.
submit_jobs fm-async 1
expect_kill env MLORC_FAILPOINT="ckpt_write:torn@8,ckpt_cadence:kill@2" \
  "$BIN" serve --spool fm-async --jobs 1 --drain
"$BIN" serve --spool fm-async --jobs 1 --drain --lease-timeout-ms 1000 --checkpoint-sync
"$BIN" status --spool fm-async --expect-all-done
"$BIN" fsck fm-async

echo "fault matrix: all cases recovered to a clean, fully drained spool"
