#!/usr/bin/env bash
# CI gate for the docs book: every repo path referenced in docs/*.md
# must exist, so the paper→code map can never silently rot. A "repo
# path" is any slash-containing token ending in a source-ish extension;
# bare filenames (meta.json, LATEST, ...) and obvious globs are skipped.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
for doc in docs/*.md; do
  # tokens like rust/src/optim/mlorc.rs, python/compile/optim_steps.py,
  # docs/cli.md, scripts/check_docs_paths.sh — optionally with a :line
  # suffix, which is stripped before the existence check
  # `|| true`: a prose-only page with zero path tokens is fine, and must
  # not abort the whole check via set -e
  refs=$(grep -oE '[A-Za-z0-9_.-]+(/[A-Za-z0-9_.-]+)+\.(rs|py|md|sh|yml|json|toml)' "$doc" | sort -u || true)
  for ref in $refs; do
    case "$ref" in
      *'*'*) continue ;; # glob examples, not concrete paths
    esac
    if [ ! -e "$ref" ]; then
      echo "MISSING: $doc references '$ref' which does not exist" >&2
      fail=1
    fi
  done
done

if [ "$fail" -ne 0 ]; then
  echo "docs path check FAILED — fix the references above" >&2
  exit 1
fi
echo "docs path check OK: all referenced paths exist"
