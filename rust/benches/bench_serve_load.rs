//! Serve-fleet heavy-traffic load benchmark (ROADMAP item 2) and the
//! observability-overhead acceptance gate (PR 8).
//!
//!     cargo bench --bench bench_serve_load
//!
//! Phase 1 — obs overhead: the same deterministic host training run is
//! timed with observability force-enabled and force-disabled
//! (interleaved, best-of-3 per mode) and the final weights are asserted
//! bit-identical; an enabled-minus-disabled wall delta above 2% fails
//! the run (`MLORC_BENCH_LAX=1` downgrades to a warning).
//!
//! Phase 1b — async checkpoint step overhead: the same host run is
//! timed per-step with no checkpointing and with a cadence-1 async
//! double-buffered checkpoint writer ([`CkptWriter`]); the p99 step-time
//! ratio (`ckpt_step_overhead`) gates at 1.15x (lax downgrades to a
//! warning) and the final weights are asserted bit-identical.
//!
//! Phase 2 — heavy traffic: `MLORC_LOAD_JOBS` host jobs (default 60)
//! with mixed methods, priorities and checkpoint cadences are queued in
//! one spool, then drained by the *real* `mlorc serve` binary: a first
//! 4-worker scheduler is killed mid-drain via `--die-after-checkpoints`
//! (it must exit with [`CRASH_EXIT_CODE`]) and a restarted scheduler
//! finishes the queue, stealing the dead peer's expired leases. The
//! spool's own observability exhaust is then the benchmark's
//! measurement: `metrics/*.json` snapshots are merged for step-latency
//! percentiles, RSS and counters, and `events/*.jsonl` journals are
//! schema-checked line by line (exactly one `complete` per job).
//!
//! Emits `BENCH_SERVE.json` at the repo root and appends a record to
//! the committed `BENCH_HISTORY.json`. Absolute numbers (jobs/sec, µs
//! percentiles) are machine-dependent and only warn; the normalized
//! `serve_step_utilization` — summed `serve.step_us` over wall-clock ×
//! workers, i.e. the fraction of scheduler capacity spent inside
//! `train_step` rather than polling, claiming, checkpointing or
//! recovering — gates at <0.9x the last serve entry under
//! `MLORC_BENCH_STRICT=1`.
//!
//! Knobs: `MLORC_LOAD_JOBS`, `MLORC_LOAD_STEPS` (steps per job),
//! `MLORC_LOAD_SPOOL` (use this spool path and keep it afterwards — the
//! CI job points schema validators at it; default is a temp dir,
//! removed on success).

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::Instant;

use mlorc::bench_harness::write_bench_json;
use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::CkptWriter;
use mlorc::linalg::{simd, threads};
use mlorc::obs::{self, registry};
use mlorc::serve::{Engine, HostTrainer, JobSpec, Spool, CRASH_EXIT_CODE};
use mlorc::util::fsutil;
use mlorc::util::json::Json;

/// Workers per scheduler process (`serve --jobs`).
const WORKERS: usize = 4;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

// ------------------------------------------------ phase 1: obs overhead

/// Time one fixed-seed host run; returns (wall seconds, final weights).
fn timed_host_run(obs_on: bool, steps: usize) -> (f64, Vec<Vec<f32>>) {
    obs::force_enabled(obs_on);
    let mut cfg = RunConfig::new("host-nano", Method::MlorcAdamW, TaskKind::MathChain, steps);
    cfg.peak_lr = 0.03;
    cfg.log_every = 0;
    cfg.seed = 5;
    let mut tr = HostTrainer::new(cfg).expect("host trainer");
    let t0 = Instant::now();
    for _ in 0..steps {
        tr.train_step().expect("train step");
    }
    let wall = t0.elapsed().as_secs_f64();
    (wall, tr.params.values.iter().map(|t| t.data.clone()).collect())
}

/// The <2% contract: spans/counters on vs off, interleaved best-of-3,
/// identical weights either way. Returns (overhead fraction, failed).
fn obs_overhead_gate(lax: bool) -> (f64, bool) {
    let steps = env_usize("MLORC_LOAD_OVERHEAD_STEPS", 60);
    // one untimed pair warms the pool, pages and workspace pools
    let _ = timed_host_run(true, steps);
    let _ = timed_host_run(false, steps);
    let (mut best_on, mut best_off) = (f64::INFINITY, f64::INFINITY);
    let (mut w_on, mut w_off) = (Vec::new(), Vec::new());
    for _ in 0..3 {
        let (t, w) = timed_host_run(true, steps);
        best_on = best_on.min(t);
        w_on = w;
        let (t, w) = timed_host_run(false, steps);
        best_off = best_off.min(t);
        w_off = w;
    }
    obs::force_enabled(true);
    assert_eq!(w_on, w_off, "obs-on weights must be bit-identical to obs-off");
    let overhead = (best_on - best_off) / best_off;
    println!(
        "obs overhead ({steps}-step host run, best of 3): enabled {:.1}ms, disabled {:.1}ms \
         -> {:+.2}%",
        best_on * 1e3,
        best_off * 1e3,
        overhead * 100.0
    );
    let mut failed = false;
    if overhead > 0.02 {
        let msg = format!(
            "acceptance: observability adds {:.2}% to the host step, target < 2%",
            overhead * 100.0
        );
        if lax {
            eprintln!("WARN (MLORC_BENCH_LAX=1): {msg}");
        } else {
            eprintln!("FAIL: {msg}");
            failed = true;
        }
    }
    (overhead, failed)
}

// -------------------------------- phase 1b: async checkpoint step overhead

/// One fixed-seed host-small run timed per step; `cadence_1` submits a
/// snapshot to the async double-buffered writer after every step, so the
/// timed path includes the capture memcpy and any backpressure stall,
/// while commits run on the writer thread. Returns (p99 step seconds,
/// final weights).
fn ckpt_step_run(cadence_1: bool, steps: usize, root: &Path) -> (f64, Vec<Vec<f32>>) {
    let mut cfg = RunConfig::new("host-small", Method::MlorcAdamW, TaskKind::MathChain, steps);
    cfg.peak_lr = 0.03;
    cfg.log_every = 0;
    cfg.seed = 11;
    let mut tr = HostTrainer::new(cfg).expect("host trainer");
    let _ = std::fs::remove_dir_all(root);
    let mut writer = cadence_1.then(|| CkptWriter::new(root));
    let mut times = Vec::with_capacity(steps);
    for _ in 0..steps {
        let t0 = Instant::now();
        tr.train_step().expect("train step");
        if let Some(w) = writer.as_mut() {
            for oc in w.submit(|b| tr.capture_snapshot(b)).expect("submit snapshot") {
                oc.dir.expect("async checkpoint commit");
            }
        }
        times.push(t0.elapsed().as_secs_f64());
    }
    if let Some(w) = writer.as_mut() {
        for oc in w.join().expect("join checkpoint writer") {
            oc.dir.expect("async checkpoint commit");
        }
    }
    drop(writer);
    let _ = std::fs::remove_dir_all(root);
    times.sort_by(f64::total_cmp);
    let idx = ((times.len() as f64 * 0.99).ceil() as usize).clamp(1, times.len()) - 1;
    (times[idx], tr.params.values.iter().map(|t| t.data.clone()).collect())
}

/// The async-writer contract in one number: with a full v2 checkpoint
/// submitted on *every* step, the step path pays only the snapshot
/// capture, so cadence-1 step p99 must stay within 1.15x of the
/// cadence-0 baseline — and checkpointing must not perturb the weights.
/// Returns (p99 ratio, failed).
fn ckpt_overhead_gate(lax: bool) -> (f64, bool) {
    let steps = env_usize("MLORC_LOAD_CKPT_STEPS", 120);
    let root = std::env::temp_dir().join(format!("mlorc_ckpt_bench_{}", std::process::id()));
    // one untimed pair warms the worker pool and page cache
    let _ = ckpt_step_run(false, steps.min(30), &root);
    let _ = ckpt_step_run(true, steps.min(30), &root);
    let (mut best_off, mut best_on) = (f64::INFINITY, f64::INFINITY);
    let (mut w_off, mut w_on) = (Vec::new(), Vec::new());
    for _ in 0..3 {
        let (p, w) = ckpt_step_run(false, steps, &root);
        best_off = best_off.min(p);
        w_off = w;
        let (p, w) = ckpt_step_run(true, steps, &root);
        best_on = best_on.min(p);
        w_on = w;
    }
    assert_eq!(w_on, w_off, "cadence-1 async checkpointing must not perturb the weights");
    let ratio = best_on / best_off;
    println!(
        "ckpt step overhead ({steps}-step host-small run, best of 3): cadence-1 async p99 \
         {:.0}us vs cadence-0 p99 {:.0}us -> {ratio:.3}x",
        best_on * 1e6,
        best_off * 1e6
    );
    let mut failed = false;
    if ratio > 1.15 {
        let msg = format!(
            "acceptance: cadence-1 async checkpointing puts step p99 at {ratio:.3}x the \
             cadence-0 baseline, target <= 1.15x"
        );
        if lax {
            eprintln!("WARN (MLORC_BENCH_LAX=1): {msg}");
        } else {
            eprintln!("FAIL: {msg}");
            failed = true;
        }
    }
    (ratio, failed)
}

// ------------------------------------------------ phase 2: load scenario

/// Queue `jobs` host jobs with mixed methods / priorities / cadences.
fn submit_jobs(spool: &Spool, jobs: usize, steps: usize) {
    const METHODS: [Method; 3] = [Method::MlorcAdamW, Method::MlorcLion, Method::MlorcSgdM];
    const PRIORITIES: [i64; 3] = [0, 7, -1];
    const CADENCES: [usize; 3] = [5, 0, 4];
    for i in 0..jobs {
        let mut cfg = RunConfig::new("host-nano", METHODS[i % 3], TaskKind::MathChain, steps);
        cfg.peak_lr = 0.03;
        cfg.log_every = 0;
        cfg.seed = 1000 + i as u64;
        let spec = JobSpec {
            id: format!("load{i:04}"),
            engine: Engine::Host,
            checkpoint_every: CADENCES[i % 3],
            priority: PRIORITIES[i % 3],
            attempts: Vec::new(),
            not_before_unix_ms: 0,
            cfg,
        };
        spool.submit(&spec).expect("submit job");
    }
}

/// Spawn the real `mlorc serve` binary against `root`; returns its exit
/// code. Lease timeout stays > 0 on BOTH runs: the restarted scheduler
/// must steal the killed peer's leases by expiry — legacy timeout-0
/// recovery deliberately skips leased jobs and would hang the drain.
fn run_serve(root: &Path, die_after_checkpoints: usize) -> i32 {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_mlorc"));
    cmd.arg("serve")
        .arg("--spool")
        .arg(root)
        .arg("--jobs")
        .arg(WORKERS.to_string())
        .arg("--drain")
        .arg("--poll-ms")
        .arg("25")
        .arg("--lease-timeout-ms")
        .arg("1000")
        .arg("--retry-backoff-ms")
        .arg("50")
        .env_remove("MLORC_NO_OBS")
        .env_remove("MLORC_FAILPOINT")
        .env("MLORC_LOG_FILE", root.join("serve.log"));
    if die_after_checkpoints > 0 {
        cmd.arg("--die-after-checkpoints").arg(die_after_checkpoints.to_string());
    }
    let status = cmd.status().expect("spawn mlorc serve");
    status.code().unwrap_or(-1)
}

struct LoadStats {
    jobs: usize,
    steps: usize,
    wall_secs: f64,
    jobs_per_sec: f64,
    step_p50_us: u64,
    step_p99_us: u64,
    step_count: f64,
    utilization: f64,
    rss_bytes: f64,
    journal_events: usize,
    journal_claims: usize,
    journal_checkpoints: usize,
    journal_lease_steals: usize,
}

fn load_bench() -> LoadStats {
    let jobs = env_usize("MLORC_LOAD_JOBS", 60);
    let steps = env_usize("MLORC_LOAD_STEPS", 16);
    let (root, keep): (PathBuf, bool) = match std::env::var("MLORC_LOAD_SPOOL") {
        Ok(p) if !p.is_empty() => (PathBuf::from(p), true),
        _ => (std::env::temp_dir().join(format!("mlorc_load_{}", std::process::id())), false),
    };
    let _ = std::fs::remove_dir_all(&root);
    let spool = Spool::open(&root).expect("open spool");
    submit_jobs(&spool, jobs, steps);
    println!(
        "\nload: {jobs} jobs x {steps} steps queued at {} ({WORKERS} workers/scheduler)",
        root.display()
    );

    // Scheduler 1 is armed to die mid-drain after enough cadence
    // checkpoints to be well inside the traffic (the 5- and 4-step
    // cadence jobs contribute 3-4 saves each, so jobs/3 always fires).
    let die_after = (jobs / 3).max(2);
    let t0 = Instant::now();
    let code1 = run_serve(&root, die_after);
    assert_eq!(
        code1, CRASH_EXIT_CODE,
        "scheduler 1 must die via the injected kill (exit {CRASH_EXIT_CODE}), got {code1}"
    );
    println!(
        "scheduler 1 killed after {die_after} cadence checkpoints ({:.2}s in); restarting",
        t0.elapsed().as_secs_f64()
    );
    let code2 = run_serve(&root, 0);
    assert_eq!(code2, 0, "restarted scheduler must drain cleanly, got exit {code2}");
    let wall_secs = t0.elapsed().as_secs_f64();

    // exactly-once drain despite the mid-flight kill
    let done = spool.jobs_in("done").expect("list done");
    assert_eq!(done.len(), jobs, "all {jobs} jobs must land in done/, got {}", done.len());
    for state in ["queue", "running", "failed"] {
        let left = spool.jobs_in(state).expect("list spool state");
        assert!(left.is_empty(), "{state}/ not empty after drain: {left:?}");
    }

    // journals: every line parses and carries the envelope; one
    // `complete` per job (the kill makes *claims* exceed jobs, never
    // completes)
    let (mut events, mut claims, mut completes, mut checkpoints, mut steals) = (0, 0, 0, 0, 0);
    for entry in std::fs::read_dir(spool.events_dir()).expect("events dir") {
        let path = entry.expect("events entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("jsonl") {
            continue;
        }
        for line in std::fs::read_to_string(&path).expect("read journal").lines() {
            let ev = Json::parse(line).unwrap_or_else(|e| {
                panic!("unparseable journal line in {}: {e:#}\n{line}", path.display())
            });
            assert!(
                ev.get("unix_ms").is_some() && ev.get("owner").is_some() && ev.get("ev").is_some(),
                "journal line missing unix_ms/owner/ev envelope: {line}"
            );
            match ev.get("ev").and_then(|v| v.as_str().ok()).unwrap_or("") {
                "claim" => claims += 1,
                "complete" => completes += 1,
                "checkpoint" => checkpoints += 1,
                "lease_steal" => steals += 1,
                _ => {}
            }
            events += 1;
        }
    }
    assert_eq!(completes, jobs, "exactly one journaled complete per job");
    assert!(claims >= jobs, "at least one journaled claim per job ({claims} < {jobs})");
    assert!(checkpoints >= die_after, "cadence checkpoints must be journaled");
    println!(
        "journal: {events} events — {claims} claims, {completes} completes, \
         {checkpoints} checkpoints, {steals} lease steals"
    );

    // metrics: merge both schedulers' snapshots, read the step
    // histogram back out of the merged exhaust
    let mut snaps = Vec::new();
    let mut owners = Vec::new();
    for entry in std::fs::read_dir(spool.metrics_dir()).expect("metrics dir") {
        let path = entry.expect("metrics entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let snap = Json::from_file(&path).expect("parse metrics snapshot");
        assert_eq!(
            snap.get("schema").and_then(|s| s.as_str().ok()).unwrap_or(""),
            "mlorc_metrics/v1",
            "bad snapshot schema in {}",
            path.display()
        );
        owners.push(path.file_stem().and_then(|s| s.to_str()).unwrap_or("?").to_string());
        snaps.push(snap);
    }
    assert!(
        snaps.len() >= 2,
        "expected snapshots from both schedulers (killed one saves at checkpoint cadence), \
         got {owners:?}"
    );
    let merged = registry::merge_snapshots(&snaps);
    let hist = merged
        .get("histograms")
        .and_then(|h| h.get("serve.step_us"))
        .cloned()
        .unwrap_or_else(|| Json::obj(vec![]));
    let step_count = hist.get("count").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    let step_sum_us = hist.get("sum").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    assert!(step_count > 0.0, "merged snapshots carry no serve.step_us samples");
    let step_p50_us = registry::snapshot_percentile(&hist, 0.50);
    let step_p99_us = registry::snapshot_percentile(&hist, 0.99);
    let rss_bytes = merged
        .get("gauges")
        .and_then(|g| g.get("proc.rss_bytes"))
        .and_then(|v| v.as_f64().ok())
        .unwrap_or(0.0);
    let jobs_per_sec = jobs as f64 / wall_secs;
    let utilization = step_sum_us / (wall_secs * 1e6 * WORKERS as f64);
    println!(
        "drained {jobs} jobs in {wall_secs:.2}s ({jobs_per_sec:.1} jobs/s) — step p50 \
         {step_p50_us}us p99 {step_p99_us}us ({step_count:.0} steps), utilization {:.1}% of \
         {WORKERS} workers, peak scheduler RSS {:.1} MB",
        utilization * 100.0,
        rss_bytes / (1 << 20) as f64
    );

    if keep {
        println!("spool kept at {} (MLORC_LOAD_SPOOL)", root.display());
    } else {
        let _ = std::fs::remove_dir_all(&root);
    }
    LoadStats {
        jobs,
        steps,
        wall_secs,
        jobs_per_sec,
        step_p50_us,
        step_p99_us,
        step_count,
        utilization,
        rss_bytes,
        journal_events: events,
        journal_claims: claims,
        journal_checkpoints: checkpoints,
        journal_lease_steals: steals,
    }
}

// -------------------------------------------------------- history tracking

/// Append this run to `BENCH_HISTORY.json`. Entries in that file are
/// heterogeneous (the opt-step bench appends its own), so the previous
/// value is the last entry *carrying* `serve_step_utilization`, not
/// `entries.last()`. A >10% utilization drop is the strict-gate flag;
/// jobs/sec and µs percentiles are machine-dependent and recorded
/// without gating.
fn track_history(stats: &LoadStats, overhead: f64, ckpt_overhead: f64) -> bool {
    let path = match fsutil::find_repo_root() {
        Ok(root) => root.join("BENCH_HISTORY.json"),
        Err(e) => {
            eprintln!("bench history skipped: {e:#}");
            return false;
        }
    };
    let mut entries: Vec<Json> = if path.exists() {
        match Json::from_file(&path) {
            Ok(j) => j
                .get("entries")
                .and_then(|e| e.as_arr().ok())
                .map(|a| a.to_vec())
                .unwrap_or_default(),
            Err(e) => {
                // Never clobber an existing-but-unparseable baseline:
                // that would silently disable the regression gate.
                eprintln!(
                    "bench history NOT updated: {} exists but is unreadable ({e:#}); \
                     fix or delete it to resume tracking",
                    path.display()
                );
                return false;
            }
        }
    } else {
        Vec::new()
    };

    let mut regressed = false;
    let prev = entries
        .iter()
        .rev()
        .find_map(|e| e.get("serve_step_utilization").and_then(|v| v.as_f64().ok()));
    if let Some(p) = prev {
        if stats.utilization < 0.9 * p {
            regressed = true;
            println!(
                "REGRESSION: serve_step_utilization is {:.3} vs {p:.3} in the last serve entry \
                 ({:.0}% drop, >10% gate)",
                stats.utilization,
                (1.0 - stats.utilization / p) * 100.0
            );
        }
    }

    let prev_ckpt = entries
        .iter()
        .rev()
        .find_map(|e| e.get("ckpt_step_overhead").and_then(|v| v.as_f64().ok()));
    if let Some(p) = prev_ckpt {
        if ckpt_overhead > p * 1.1 {
            regressed = true;
            println!(
                "REGRESSION: ckpt_step_overhead is {ckpt_overhead:.3}x vs {p:.3}x in the last \
                 serve entry (>10% gate)"
            );
        }
    }

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let entry = Json::obj(vec![
        ("unix_time", Json::num(unix_time as f64)),
        ("thread_budget", Json::num(threads::budget() as f64)),
        ("simd_tier", Json::str(simd::simd_tier())),
        ("serve_step_utilization", Json::num(stats.utilization)),
        ("serve_jobs_per_sec", Json::num(stats.jobs_per_sec)),
        ("serve_step_p50_us", Json::num(stats.step_p50_us as f64)),
        ("serve_step_p99_us", Json::num(stats.step_p99_us as f64)),
        ("obs_overhead_pct", Json::num(overhead * 100.0)),
        ("ckpt_step_overhead", Json::num(ckpt_overhead)),
    ]);
    println!("appended BENCH_HISTORY entry:\n{}", entry.to_string_pretty());
    entries.push(entry);
    let hist = Json::obj(vec![
        ("schema", Json::str("bench_history/v1")),
        ("entries", Json::Arr(entries)),
    ]);
    match write_bench_json("BENCH_HISTORY.json", &hist) {
        Ok(p) => println!("appended run to {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_HISTORY.json: {e:#}"),
    }
    regressed
}

fn main() {
    let lax = std::env::var("MLORC_BENCH_LAX").map(|v| v == "1").unwrap_or(false);
    let strict = std::env::var("MLORC_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);

    let (overhead, mut failed) = obs_overhead_gate(lax);
    let (ckpt_overhead, ckpt_failed) = ckpt_overhead_gate(lax);
    failed |= ckpt_failed;
    let stats = load_bench();

    let payload = Json::obj(vec![
        ("schema", Json::str("bench_serve/v1")),
        ("jobs", Json::num(stats.jobs as f64)),
        ("steps_per_job", Json::num(stats.steps as f64)),
        ("workers_per_scheduler", Json::num(WORKERS as f64)),
        ("wall_secs", Json::num(stats.wall_secs)),
        ("jobs_per_sec", Json::num(stats.jobs_per_sec)),
        ("serve_step_p50_us", Json::num(stats.step_p50_us as f64)),
        ("serve_step_p99_us", Json::num(stats.step_p99_us as f64)),
        ("serve_step_count", Json::num(stats.step_count)),
        ("serve_step_utilization", Json::num(stats.utilization)),
        ("rss_bytes", Json::num(stats.rss_bytes)),
        ("obs_overhead_pct", Json::num(overhead * 100.0)),
        ("ckpt_step_overhead", Json::num(ckpt_overhead)),
        ("crash_exit_code", Json::num(CRASH_EXIT_CODE as f64)),
        ("journal_events", Json::num(stats.journal_events as f64)),
        ("journal_claims", Json::num(stats.journal_claims as f64)),
        ("journal_checkpoints", Json::num(stats.journal_checkpoints as f64)),
        ("journal_lease_steals", Json::num(stats.journal_lease_steals as f64)),
        ("thread_budget", Json::num(threads::budget() as f64)),
        ("simd_tier", Json::str(simd::simd_tier())),
    ]);
    match write_bench_json("BENCH_SERVE.json", &payload) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_SERVE.json: {e:#}"),
    }

    let regressed = track_history(&stats, overhead, ckpt_overhead);
    if regressed && strict {
        eprintln!(
            "FAIL (MLORC_BENCH_STRICT=1): >10% serve_step_utilization regression vs the last \
             BENCH_HISTORY serve entry"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
