//! Compression-path microbench: host-side QB-RSVD plus the lowered MLorc
//! step vs the uncompressed AdamW step across the preset matrix shapes —
//! the paper's "overhead of compression is negligible" claim (Table 4) at
//! the kernel level.
//!
//!     cargo bench --bench bench_rsvd

use std::time::Instant;

use mlorc::linalg::{rsvd_qb, Rng};
use mlorc::runtime::{HostValue, Manifest, Runtime};
use mlorc::tensor::Tensor;
use mlorc::util::fsutil;

fn time_it(mut f: impl FnMut(), iters: usize) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let mut rng = Rng::new(0);
    println!("== host QB-RSVD (pure rust reference) ==");
    println!("{:>12} {:>6} {:>12} {:>14}", "shape", "l", "per call", "GB/s touched");
    for (m, n) in [(128, 128), (128, 512), (512, 128), (768, 3072)] {
        for l in [4usize, 8] {
            let a = rng.gaussian_tensor(&[m, n], 1.0);
            let om = rng.gaussian_tensor(&[n, l], 1.0);
            let secs = time_it(|| std::hint::black_box({ let _ = rsvd_qb(&a, &om); }), 10);
            // QB reads A twice (A@Omega, Q^T A): 2*m*n*4 bytes
            let gbs = (2 * m * n * 4) as f64 / secs / 1e9;
            println!("{m:>6}x{n:<5} {l:>6} {:>10.2}us {gbs:>13.2}", secs * 1e6);
        }
    }

    let Ok(dir) = fsutil::artifacts_dir() else { return };
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing — skipping HLO step benches)");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    let preset = manifest.preset("tiny").unwrap();

    println!("\n== lowered step graphs (PJRT CPU), tiny shapes ==");
    println!("{:>12} {:>14} {:>14} {:>10}", "shape", "adamw", "mlorc_adamw", "overhead");
    for key in ["128x128", "128x512", "512x128"] {
        let dims: Vec<usize> = key.split('x').map(|s| s.parse().unwrap()).collect();
        let (m, n) = (dims[0], dims[1]);
        let l = preset.model.l();
        let w = rng.gaussian_tensor(&[m, n], 0.1);
        let g = rng.gaussian_tensor(&[m, n], 0.1);

        let sg_a = preset.opt_step("adamw", key).unwrap();
        let ga = rt.load(sg_a).unwrap();
        let adamw_in: Vec<HostValue> = vec![
            w.clone().into(),
            g.clone().into(),
            Tensor::zeros(&[m, n]).into(),
            Tensor::zeros(&[m, n]).into(),
            HostValue::scalar_f32(1e-3),
            HostValue::scalar_f32(1.0),
            HostValue::scalar_f32(1.0),
        ];
        let t_adamw = time_it(|| { let _ = rt.execute(&ga, &adamw_in).unwrap(); }, 20);

        let sg_m = preset.opt_step("mlorc_adamw", key).unwrap();
        let gm = rt.load(sg_m).unwrap();
        let mlorc_in: Vec<HostValue> = vec![
            w.clone().into(),
            g.clone().into(),
            Tensor::zeros(&[m, l]).into(),
            Tensor::zeros(&[l, n]).into(),
            Tensor::zeros(&[m, l]).into(),
            Tensor::zeros(&[l, n]).into(),
            rng.gaussian_tensor(&[n, l], 1.0).into(),
            rng.gaussian_tensor(&[n, l], 1.0).into(),
            HostValue::scalar_f32(1e-3),
            HostValue::scalar_f32(1.0),
            HostValue::scalar_f32(1.0),
        ];
        let t_mlorc = time_it(|| { let _ = rt.execute(&gm, &mlorc_in).unwrap(); }, 20);
        println!(
            "{key:>12} {:>12.2}us {:>12.2}us {:>9.2}x",
            t_adamw * 1e6,
            t_mlorc * 1e6,
            t_mlorc / t_adamw
        );
    }
    println!("\npaper expectation: MLorc step within a small constant of plain AdamW (O(mnr) extra work)");
}
