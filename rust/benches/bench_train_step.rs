//! End-to-end training-step latency per method (tiny preset) with the
//! fwd/bwd vs optimizer time split — the whole-stack view of Table 4.
//!
//!     cargo bench --bench bench_train_step

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::fsutil;

fn main() {
    let Ok(dir) = fsutil::artifacts_dir() else { return };
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    let preset = manifest.preset("tiny").unwrap();
    let steps = 15usize;

    println!("end-to-end train step, tiny preset ({} steps each):", steps);
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>14}",
        "method", "ms/step", "fwd/bwd ms", "opt ms", "tokens/s"
    );
    for &method in Method::all() {
        if !method.desc().graphed {
            // host-only registry combos have no lowered step graphs
            continue;
        }
        let mut cfg = RunConfig::new("tiny", method, TaskKind::MathChain, steps);
        cfg.log_every = 0;
        cfg.eval_batches = 1;
        let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
        // warmup (includes XLA compile)
        tr.train_step().unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            tr.train_step().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let per = wall / steps as f64;
        let toks = (preset.model.batch * preset.model.seq) as f64 / per;
        // first warmup step included in the split totals; subtract nothing,
        // report the split proportionally
        let split = tr.metrics.fwd_bwd_secs + tr.metrics.opt_secs;
        let f = tr.metrics.fwd_bwd_secs / split;
        println!(
            "{:<14} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>12.0}",
            method.name(),
            per * 1e3,
            per * 1e3 * f,
            per * 1e3 * (1.0 - f),
            toks
        );
    }
    println!("\npaper expectation (Table 4): mlorc ≈ lora < galore; full fastest per-step but 3x the state memory");
}
