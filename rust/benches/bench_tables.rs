//! Regenerate every paper table/figure at quick scale — the `cargo bench`
//! entry point for the full experiment suite. Full-scale runs go through
//! `mlorc bench --experiment <id>`.
//!
//!     cargo bench --bench bench_tables            # all, quick scale
//!     cargo bench --bench bench_tables -- fig2    # one experiment

use mlorc::bench_harness::{run_experiment, Scale, EXPERIMENT_IDS};
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::fsutil;

fn main() {
    mlorc::util::logger::init();
    let Ok(dir) = fsutil::artifacts_dir() else { return };
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    let out_dir = fsutil::results_dir().unwrap();

    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let ids: Vec<&str> = if args.is_empty() {
        EXPERIMENT_IDS.to_vec()
    } else {
        EXPERIMENT_IDS
            .iter()
            .copied()
            .filter(|id| args.iter().any(|a| a == id))
            .collect()
    };

    for id in ids {
        let t0 = std::time::Instant::now();
        match run_experiment(id, &manifest, &rt, Scale::Quick, None, None) {
            Ok(report) => {
                report.save(&out_dir).unwrap();
                println!(
                    "=== {id} ({:.1}s) -> results/{id}.md ===\n{}",
                    t0.elapsed().as_secs_f64(),
                    report.to_markdown()
                );
            }
            Err(e) => println!("=== {id} FAILED: {e:#} ==="),
        }
    }
}
