//! Optimizer step latency — the per-parameter cost table behind Table 4,
//! and the MLorc host fast-path acceptance gate.
//!
//!     cargo bench --bench bench_opt_step
//!
//! Always runs the pure-host benchmark (no artifacts needed): the factored
//! + fused MLorc-AdamW step against (a) the direct algorithm on the same
//! blocked kernels and (b) the pre-change scalar-kernel baseline, plus
//! Lion/AdamW references, across the tiny-preset matrix shapes. Emits the
//! machine-readable `BENCH_OPT.json` at the repo root so later PRs can
//! track the trajectory, and *asserts* the acceptance criteria:
//!
//!  * GEMM audit: one dense O(m·n·l) reconstruction per moment on the
//!    512x128 step (fused m-moment + v-moment), thin sketch/projections;
//!  * timing: >= 3x over the scalar baseline on the 512x128 MLorc-AdamW
//!    step (set MLORC_BENCH_LAX=1 to downgrade to a warning on
//!    constrained machines).
//!
//! When XLA artifacts are present (`make artifacts`), the step-graph
//! latency table is measured as well and folded into the JSON.

use std::collections::BTreeMap;
use std::time::Instant;

use mlorc::bench_harness::write_bench_json;
use mlorc::linalg::{flops, mgs_qr, scalar_matmul, scalar_matmul_at_b, threads, Rng};
use mlorc::optim::{
    adamw_apply, bias_corrections, mlorc_adamw_step_direct, zeta_fix, AdamWState,
    MlorcAdamWState, MlorcLionState, OptHp,
};
use mlorc::runtime::{GraphSpec, HostValue, Manifest, Runtime};
use mlorc::tensor::Tensor;
use mlorc::util::fsutil;
use mlorc::util::json::Json;

const SHAPES: [(usize, usize); 3] = [(128, 128), (128, 512), (512, 128)];
const L: usize = 8;
const ITERS: usize = 20;

fn time_us(mut f: impl FnMut(), iters: usize) -> f64 {
    f();
    f(); // warmup: fill workspace pools, fault pages
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64 * 1e6
}

/// The seed's MLorc-AdamW step verbatim: scalar single-threaded kernels,
/// every intermediate re-allocated — the pre-change baseline.
#[allow(clippy::too_many_arguments)]
fn scalar_direct_step(
    w: &mut Tensor,
    g: &Tensor,
    mq: &mut Tensor,
    mb: &mut Tensor,
    vq: &mut Tensor,
    vb: &mut Tensor,
    t: usize,
    lr: f32,
    hp: &OptHp,
    om_m: &Tensor,
    om_v: &Tensor,
) {
    let mut mt = scalar_matmul(mq, mb);
    mt.axpy(1.0 - hp.beta1, g, hp.beta1);
    let mut vt = scalar_matmul(vq, vb);
    zeta_fix(&mut vt);
    for (vi, gi) in vt.data.iter_mut().zip(&g.data) {
        *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
    }
    let y_m = scalar_matmul(&mt, om_m);
    let q_m = mgs_qr(&y_m);
    let b_m = scalar_matmul_at_b(&q_m, &mt);
    let y_v = scalar_matmul(&vt, om_v);
    let q_v = mgs_qr(&y_v);
    let b_v = scalar_matmul_at_b(&q_v, &vt);
    *mq = q_m;
    *mb = b_m;
    *vq = q_v;
    *vb = b_v;
    let (c1, c2) = bias_corrections(hp, t);
    adamw_apply(w, &mt, &vt, lr, c1, c2, hp);
}

struct Case {
    w: Tensor,
    g: Tensor,
    om_m: Tensor,
    om_v: Tensor,
}

fn case(m: usize, n: usize, rng: &mut Rng) -> Case {
    Case {
        w: rng.gaussian_tensor(&[m, n], 0.5),
        g: rng.gaussian_tensor(&[m, n], 1.0),
        om_m: rng.gaussian_tensor(&[n, L], 1.0),
        om_v: rng.gaussian_tensor(&[n, L], 1.0),
    }
}

fn host_bench(rng: &mut Rng) -> (Json, f64) {
    let hp = OptHp::mlorc_adamw();
    let hp_lion = OptHp::lion();
    let mut by_shape: BTreeMap<String, Json> = BTreeMap::new();
    let mut speedup_512 = 0.0f64;

    println!("host optimizer step (us/step), l = {L}:");
    println!(
        "{:>10} {:>16} {:>18} {:>18} {:>14} {:>12}",
        "shape", "mlorc_adamw", "mlorc_adamw_dir", "mlorc_adamw_scl", "mlorc_lion", "adamw"
    );
    for &(m, n) in &SHAPES {
        let c = case(m, n, rng);

        let mut fast_state = MlorcAdamWState::new(&[m, n], L);
        let mut w = c.w.clone();
        let fast = time_us(
            || fast_state.step_with_omegas(&mut w, &c.g, 1e-3, &hp, &c.om_m, &c.om_v),
            ITERS,
        );

        let (mut mq, mut mb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let (mut vq, mut vb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let mut w2 = c.w.clone();
        let mut t = 0usize;
        let direct = time_us(
            || {
                t += 1;
                mlorc_adamw_step_direct(
                    &mut w2, &c.g, &mut mq, &mut mb, &mut vq, &mut vb, t, 1e-3, &hp, &c.om_m,
                    &c.om_v,
                );
            },
            ITERS,
        );

        let (mut smq, mut smb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let (mut svq, mut svb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let mut w3 = c.w.clone();
        let mut ts = 0usize;
        let scalar = time_us(
            || {
                ts += 1;
                scalar_direct_step(
                    &mut w3, &c.g, &mut smq, &mut smb, &mut svq, &mut svb, ts, 1e-3, &hp,
                    &c.om_m, &c.om_v,
                );
            },
            ITERS,
        );

        let mut lion_state = MlorcLionState::new(&[m, n], L);
        let mut w4 = c.w.clone();
        let lion = time_us(
            || lion_state.step_with_omega(&mut w4, &c.g, 1e-3, &hp_lion, &c.om_m),
            ITERS,
        );

        let mut adamw_state = AdamWState::new(&[m, n]);
        let mut w5 = c.w.clone();
        let adamw = time_us(|| adamw_state.step(&mut w5, &c.g, 1e-3, &hp), ITERS);

        println!(
            "{:>10} {:>14.1}us {:>16.1}us {:>16.1}us {:>12.1}us {:>10.1}us",
            format!("{m}x{n}"),
            fast,
            direct,
            scalar,
            lion,
            adamw
        );
        if (m, n) == (512, 128) {
            speedup_512 = scalar / fast;
        }
        by_shape.insert(
            format!("{m}x{n}"),
            Json::obj(vec![
                ("mlorc_adamw_us", Json::num(fast)),
                ("mlorc_adamw_direct_us", Json::num(direct)),
                ("mlorc_adamw_scalar_us", Json::num(scalar)),
                ("mlorc_lion_us", Json::num(lion)),
                ("adamw_us", Json::num(adamw)),
                ("speedup_vs_scalar", Json::num(scalar / fast)),
            ]),
        );
    }
    (Json::Obj(by_shape), speedup_512)
}

/// GEMM-shape audit of the 512x128 fast step (the FLOP-count acceptance
/// assertion): per moment exactly one dense O(m·n·l) reconstruction, thin
/// sketches/projections everywhere else.
fn gemm_audit(rng: &mut Rng) -> Json {
    let (m, n) = (512usize, 128usize);
    let hp = OptHp::mlorc_adamw();
    let c = case(m, n, rng);
    let mut st = MlorcAdamWState::new(&[m, n], L);
    let mut w = c.w.clone();
    st.step_with_omegas(&mut w, &c.g, 1e-3, &hp, &c.om_m, &c.om_v); // warm factors
    flops::start_recording();
    st.step_with_omegas(&mut w, &c.g, 1e-3, &hp, &c.om_m, &c.om_v);
    let recs = flops::finish_recording();

    let dense = m * n;
    let thin_cap = m.max(n) * L;
    let dense_recons = recs.iter().filter(|r| !r.is_fused() && r.out_elems() == dense).count();
    let fused_recons = recs.iter().filter(|r| r.is_fused()).count();
    let fat_sketches = recs
        .iter()
        .filter(|r| !r.is_fused() && r.out_elems() != dense && r.out_elems() > thin_cap)
        .count();
    let madds = flops::total_madds(&recs);
    println!(
        "gemm audit (512x128, l={L}): {} GEMMs, {madds} madds, dense recons {dense_recons} \
         (+{fused_recons} fused), fat sketches {fat_sketches}",
        recs.len()
    );
    assert_eq!(
        dense_recons, 1,
        "fast path must materialize exactly one dense recon (v moment): {recs:?}"
    );
    assert_eq!(fused_recons, 1, "fast path must fuse the m-moment recon: {recs:?}");
    assert_eq!(fat_sketches, 0, "sketch/projection GEMMs must be thin: {recs:?}");
    Json::obj(vec![
        ("gemms", Json::num(recs.len() as f64)),
        ("madds", Json::num(madds as f64)),
        ("dense_recon_gemms", Json::num(dense_recons as f64)),
        ("fused_recon_gemms", Json::num(fused_recons as f64)),
    ])
}

/// Build zero/random inputs matching a step graph's IO table.
fn inputs_for(spec: &GraphSpec, rng: &mut Rng) -> Vec<HostValue> {
    spec.inputs
        .iter()
        .map(|io| {
            if io.shape.is_empty() {
                HostValue::scalar_f32(match io.name.as_str() {
                    "lr" => 1e-3,
                    _ => 1.0,
                })
            } else if io.name.starts_with("om") {
                rng.gaussian_tensor(&io.shape, 1.0).into()
            } else if io.name == "w" || io.name == "g" {
                rng.gaussian_tensor(&io.shape, 0.1).into()
            } else {
                Tensor::zeros(&io.shape).into()
            }
        })
        .collect()
}

fn graph_bench(rng: &mut Rng) -> Option<Json> {
    let dir = fsutil::artifacts_dir().ok()?;
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — skipping step-graph latency (host bench above still ran)");
        return None;
    }
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping step-graph latency: manifest unreadable: {e:#}");
            return None;
        }
    };
    let rt = match Runtime::cpu(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping step-graph latency: {e:#}");
            return None;
        }
    };
    let preset = match manifest.preset("tiny") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping step-graph latency: no tiny preset: {e:#}");
            return None;
        }
    };
    let mut methods: BTreeMap<String, Json> = BTreeMap::new();

    println!("\nstep-graph latency (us/step), tiny preset:");
    print!("{:>16}", "method");
    let shapes = ["128x128", "128x512", "512x128"];
    for s in &shapes {
        print!(" {s:>12}");
    }
    println!();
    for (method, by_shape) in &preset.opt_steps {
        print!("{method:>16}");
        let mut row: BTreeMap<String, Json> = BTreeMap::new();
        for key in &shapes {
            match by_shape.get(*key) {
                Some(spec) => {
                    let g = rt.load(spec).unwrap();
                    let inputs = inputs_for(spec, rng);
                    let _ = rt.execute(&g, &inputs).unwrap();
                    let t0 = Instant::now();
                    for _ in 0..ITERS {
                        let _ = rt.execute(&g, &inputs).unwrap();
                    }
                    let us = t0.elapsed().as_secs_f64() / ITERS as f64 * 1e6;
                    print!(" {us:>10.1}us");
                    row.insert((*key).to_string(), Json::num(us));
                }
                None => print!(" {:>12}", "-"),
            }
        }
        println!();
        methods.insert(method.clone(), Json::Obj(row));
    }
    Some(Json::Obj(methods))
}

fn main() {
    let mut rng = Rng::new(0);
    let (host, speedup_512) = host_bench(&mut rng);
    let audit = gemm_audit(&mut rng);
    let graphs = graph_bench(&mut rng);

    println!("\n512x128 mlorc_adamw speedup vs pre-change scalar step: {speedup_512:.2}x");
    let mut root = vec![
        ("schema", Json::str("bench_opt/v1")),
        ("l", Json::num(L as f64)),
        ("thread_budget", Json::num(threads::budget() as f64)),
        ("iters", Json::num(ITERS as f64)),
        ("host_us_per_step", host),
        ("gemm_audit_512x128", audit),
        ("speedup_512x128_vs_scalar", Json::num(speedup_512)),
    ];
    if let Some(g) = graphs {
        root.push(("graph_us_per_step", g));
    }
    match write_bench_json("BENCH_OPT.json", &Json::obj(root)) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_OPT.json: {e:#}"),
    }

    let lax = std::env::var("MLORC_BENCH_LAX").map(|v| v == "1").unwrap_or(false);
    if speedup_512 < 3.0 {
        let msg = format!(
            "acceptance: 512x128 mlorc_adamw host step is {speedup_512:.2}x vs the scalar \
             baseline, target >= 3x"
        );
        if lax {
            eprintln!("WARN (MLORC_BENCH_LAX=1): {msg}");
        } else {
            eprintln!("FAIL: {msg}");
            std::process::exit(1);
        }
    }
}
