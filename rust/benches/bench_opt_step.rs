//! Optimizer step latency — the per-parameter cost table behind Table 4,
//! and the MLorc host fast-path acceptance gate.
//!
//!     cargo bench --bench bench_opt_step
//!
//! Always runs the pure-host benchmark (no artifacts needed): the factored
//! + fused MLorc-AdamW step against (a) the direct algorithm on the same
//! blocked kernels and (b) the pre-change scalar-kernel baseline, plus
//! Lion/AdamW references, across the tiny-preset matrix shapes. Emits the
//! machine-readable `BENCH_OPT.json` at the repo root, appends a run
//! record to the committed `BENCH_HISTORY.json` (printing the appended
//! entry so CI logs carry it), and *asserts* the acceptance criteria:
//!
//! History gating: absolute µs comparisons against the previous entry
//! are always warnings — they mix machines and are meaningless across
//! runners. The machine-normalized *ratios* (`speedup_512x128_vs_scalar`,
//! `pool_vs_spawn_512x128_r4`, `batched_vs_per_param_48x256x64_r4`) are
//! comparable anywhere; a drop below 0.9x the previous entry's ratio
//! fails the run under `MLORC_BENCH_STRICT=1` (the CI bench job sets it).
//!
//! Acceptance criteria:
//!
//!  * GEMM audit: one dense O(m·n·l) reconstruction per moment on the
//!    512x128 step (fused m-moment + v-moment), thin sketch/projections;
//!  * timing: >= 3x over the scalar baseline on the 512x128 MLorc-AdamW
//!    step, >= 1.5x for the pooled parallel-site mix (512x128, r=4)
//!    over the same kernels driven by the PR-1 per-call
//!    `std::thread::scope` spawn scaffold, and >= 1.5x for shape-class
//!    batched stepping on the many-small-params fleet (48 x 256x64, r=4)
//!    over the PR-6 per-parameter fan-out (set MLORC_BENCH_LAX=1 to
//!    downgrade all three to warnings on constrained machines).
//!
//! When XLA artifacts are present (`make artifacts`), the step-graph
//! latency table is measured as well and folded into the JSON.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use mlorc::bench_harness::write_bench_json;
use mlorc::coordinator::{host_step_all, HostStepJob, OptState};
use mlorc::linalg::matmul::{gemm_nn_band, gemm_tn_band};
use mlorc::linalg::{
    flops, matmul_at_b_into, matmul_into, mgs_qr, pool, scalar_matmul, scalar_matmul_at_b, simd,
    threads, Rng, Workspace,
};
use mlorc::optim::{
    adamw_apply, bias_corrections, fused_adamw_band, fused_recon_adamw_apply,
    mlorc_adamw_step_direct, zeta_fix, AdamWState, MlorcAdamWState, MlorcLionState, OptHp,
};
use mlorc::runtime::{GraphSpec, HostValue, Manifest, Runtime};
use mlorc::tensor::Tensor;
use mlorc::util::fsutil;
use mlorc::util::json::Json;

const SHAPES: [(usize, usize); 3] = [(128, 128), (128, 512), (512, 128)];
const L: usize = 8;
const ITERS: usize = 20;

fn time_us(mut f: impl FnMut(), iters: usize) -> f64 {
    f();
    f(); // warmup: fill workspace pools, fault pages, start the pool
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64 * 1e6
}

/// The seed's MLorc-AdamW step verbatim: scalar single-threaded kernels,
/// every intermediate re-allocated — the pre-change baseline.
#[allow(clippy::too_many_arguments)]
fn scalar_direct_step(
    w: &mut Tensor,
    g: &Tensor,
    mq: &mut Tensor,
    mb: &mut Tensor,
    vq: &mut Tensor,
    vb: &mut Tensor,
    t: usize,
    lr: f32,
    hp: &OptHp,
    om_m: &Tensor,
    om_v: &Tensor,
) {
    let mut mt = scalar_matmul(mq, mb);
    mt.axpy(1.0 - hp.beta1, g, hp.beta1);
    let mut vt = scalar_matmul(vq, vb);
    zeta_fix(&mut vt);
    for (vi, gi) in vt.data.iter_mut().zip(&g.data) {
        *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
    }
    let y_m = scalar_matmul(&mt, om_m);
    let q_m = mgs_qr(&y_m);
    let b_m = scalar_matmul_at_b(&q_m, &mt);
    let y_v = scalar_matmul(&vt, om_v);
    let q_v = mgs_qr(&y_v);
    let b_v = scalar_matmul_at_b(&q_v, &vt);
    *mq = q_m;
    *mb = b_m;
    *vq = q_v;
    *vb = b_v;
    let (c1, c2) = bias_corrections(hp, t);
    adamw_apply(w, &mt, &vt, lr, c1, c2, hp);
}

struct Case {
    w: Tensor,
    g: Tensor,
    om_m: Tensor,
    om_v: Tensor,
}

fn case(m: usize, n: usize, rng: &mut Rng) -> Case {
    Case {
        w: rng.gaussian_tensor(&[m, n], 0.5),
        g: rng.gaussian_tensor(&[m, n], 1.0),
        om_m: rng.gaussian_tensor(&[n, L], 1.0),
        om_v: rng.gaussian_tensor(&[n, L], 1.0),
    }
}

fn host_bench(rng: &mut Rng) -> (Json, f64) {
    let hp = OptHp::mlorc_adamw();
    let hp_lion = OptHp::lion();
    let mut by_shape: BTreeMap<String, Json> = BTreeMap::new();
    let mut speedup_512 = 0.0f64;

    println!("host optimizer step (us/step), l = {L}:");
    println!(
        "{:>10} {:>16} {:>18} {:>18} {:>14} {:>12}",
        "shape", "mlorc_adamw", "mlorc_adamw_dir", "mlorc_adamw_scl", "mlorc_lion", "adamw"
    );
    for &(m, n) in &SHAPES {
        let c = case(m, n, rng);

        let mut fast_state = MlorcAdamWState::new(&[m, n], L);
        let mut w = c.w.clone();
        let fast = time_us(
            || fast_state.step_with_omegas(&mut w, &c.g, 1e-3, &hp, &c.om_m, &c.om_v),
            ITERS,
        );

        let (mut mq, mut mb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let (mut vq, mut vb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let mut w2 = c.w.clone();
        let mut t = 0usize;
        let direct = time_us(
            || {
                t += 1;
                mlorc_adamw_step_direct(
                    &mut w2, &c.g, &mut mq, &mut mb, &mut vq, &mut vb, t, 1e-3, &hp, &c.om_m,
                    &c.om_v,
                );
            },
            ITERS,
        );

        let (mut smq, mut smb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let (mut svq, mut svb) = (Tensor::zeros(&[m, L]), Tensor::zeros(&[L, n]));
        let mut w3 = c.w.clone();
        let mut ts = 0usize;
        let scalar = time_us(
            || {
                ts += 1;
                scalar_direct_step(
                    &mut w3, &c.g, &mut smq, &mut smb, &mut svq, &mut svb, ts, 1e-3, &hp,
                    &c.om_m, &c.om_v,
                );
            },
            ITERS,
        );

        let mut lion_state = MlorcLionState::new(&[m, n], L);
        let mut w4 = c.w.clone();
        let lion = time_us(
            || lion_state.step_with_omega(&mut w4, &c.g, 1e-3, &hp_lion, &c.om_m),
            ITERS,
        );

        let mut adamw_state = AdamWState::new(&[m, n]);
        let mut w5 = c.w.clone();
        let adamw = time_us(|| adamw_state.step(&mut w5, &c.g, 1e-3, &hp), ITERS);

        println!(
            "{:>10} {:>14.1}us {:>16.1}us {:>16.1}us {:>12.1}us {:>10.1}us",
            format!("{m}x{n}"),
            fast,
            direct,
            scalar,
            lion,
            adamw
        );
        if (m, n) == (512, 128) {
            speedup_512 = scalar / fast;
        }
        by_shape.insert(
            format!("{m}x{n}"),
            Json::obj(vec![
                ("mlorc_adamw_us", Json::num(fast)),
                ("mlorc_adamw_direct_us", Json::num(direct)),
                ("mlorc_adamw_scalar_us", Json::num(scalar)),
                ("mlorc_lion_us", Json::num(lion)),
                ("adamw_us", Json::num(adamw)),
                ("speedup_vs_scalar", Json::num(scalar / fast)),
            ]),
        );
    }
    (Json::Obj(by_shape), speedup_512)
}

// ------------------------------------------------ pool vs spawn (PR-1 ref)

/// PR-1's thread policy: ~10µs per spawned thread amortized at 192k madds
/// per thread (the pool runs the same shapes at a 64k threshold because a
/// band handoff is ~10x cheaper).
fn spawn_threads_for(madds: usize, rows: usize) -> usize {
    const MIN_MADDS_PER_THREAD: usize = 192 * 1024;
    if rows < 2 {
        return 1;
    }
    threads::budget().min((madds / MIN_MADDS_PER_THREAD).max(1)).min(rows).max(1)
}

/// PR-1's `matmul_into`: same band kernel, fresh `std::thread::scope`
/// spawns on every call.
fn spawn_matmul_into(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    let (m, k) = a.dims2().unwrap();
    let (_, n) = b.dims2().unwrap();
    c.data.fill(0.0);
    let nt = spawn_threads_for(m * k * n, m);
    if nt <= 1 {
        gemm_nn_band(&a.data, &b.data, &mut c.data, 0, k, n);
        return;
    }
    let rows_per = m.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, chunk) in c.data.chunks_mut(rows_per * n).enumerate() {
            let (ad, bd) = (&a.data[..], &b.data[..]);
            s.spawn(move || gemm_nn_band(ad, bd, chunk, t * rows_per, k, n));
        }
    });
}

/// PR-1's `matmul_at_b_into` with per-call spawns.
fn spawn_matmul_at_b_into(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    let (m, k) = a.dims2().unwrap();
    let (_, n) = b.dims2().unwrap();
    c.data.fill(0.0);
    let nt = spawn_threads_for(m * k * n, k);
    if nt <= 1 {
        gemm_tn_band(&a.data, &b.data, &mut c.data, 0, m, k, n);
        return;
    }
    let rows_per = k.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, chunk) in c.data.chunks_mut(rows_per * n).enumerate() {
            let (ad, bd) = (&a.data[..], &b.data[..]);
            s.spawn(move || gemm_tn_band(ad, bd, chunk, t * rows_per, m, k, n));
        }
    });
}

/// PR-1's fused reconstruction+AdamW apply with per-call spawns.
#[allow(clippy::too_many_arguments)]
fn spawn_fused_adamw(
    w: &mut Tensor,
    g: &Tensor,
    vt: &Tensor,
    mq: &Tensor,
    mb: &Tensor,
    beta1: f32,
    lr: f32,
    c1: f32,
    c2: f32,
    hp: &OptHp,
) {
    let (m, n) = w.dims2().unwrap();
    let (_, l) = mq.dims2().unwrap();
    let nt = spawn_threads_for(m * n * (l + 4), m);
    let mut scratch = vec![0.0f32; nt * n];
    if nt <= 1 {
        fused_adamw_band(
            &mut w.data, &g.data, &vt.data, &mq.data, &mb.data, &mut scratch, l, n, beta1, lr,
            c1, c2, hp,
        );
        return;
    }
    let rows_per = m.div_ceil(nt);
    std::thread::scope(|s| {
        let bands = w
            .data
            .chunks_mut(rows_per * n)
            .zip(g.data.chunks(rows_per * n))
            .zip(vt.data.chunks(rows_per * n))
            .zip(mq.data.chunks(rows_per * l))
            .zip(scratch.chunks_mut(n));
        for ((((w_band, g_band), vt_band), mq_band), row_buf) in bands {
            let mb_all = &mb.data[..];
            s.spawn(move || {
                fused_adamw_band(
                    w_band, g_band, vt_band, mq_band, mb_all, row_buf, l, n, beta1, lr, c1, c2,
                    hp,
                )
            });
        }
    });
}

/// The parallel-site mix of one factored MLorc-AdamW step at (512, 128),
/// r = 4 — v-moment reconstruction, gradient sketch `G·Ω`, projection
/// `QᵀG`, fused reconstruction+apply — timed on the persistent pool vs
/// the identical band kernels driven by PR-1's per-call spawn scaffold.
/// (The ζ-fix is elementwise/serial in both variants, so it is left out;
/// nonnegative v factors keep the apply's sqrt well-defined without it.)
/// Returns (json, pooled_speedup).
fn pool_vs_spawn_bench(rng: &mut Rng) -> (Json, f64) {
    let (m, n, l) = (512usize, 128usize, 4usize);
    let hp = OptHp::mlorc_adamw();
    let (c1f, c2f) = bias_corrections(&hp, 3);
    let g = rng.gaussian_tensor(&[m, n], 1.0);
    let om = rng.gaussian_tensor(&[n, l], 1.0);
    // elementwise |.| makes vt = vq·vb nonnegative (sums of positive terms)
    let vq = rng.gaussian_tensor(&[m, l], 0.5).map(f32::abs);
    let vb = rng.gaussian_tensor(&[l, n], 0.5).map(f32::abs);
    let mq = rng.gaussian_tensor(&[m, l], 0.5);
    let mb = rng.gaussian_tensor(&[l, n], 0.5);
    let mut vt = Tensor::zeros(&[m, n]);
    let mut y = Tensor::zeros(&[m, l]);
    let mut bproj = Tensor::zeros(&[l, n]);
    let mut ws = Workspace::new();

    let mut w_pool = rng.gaussian_tensor(&[m, n], 0.5);
    let pooled = time_us(
        || {
            matmul_into(&mut vt, &vq, &vb);
            matmul_into(&mut y, &g, &om);
            matmul_at_b_into(&mut bproj, &mq, &g);
            fused_recon_adamw_apply(
                &mut w_pool, &g, &vt, &mq, &mb, hp.beta1, 1e-3, c1f, c2f, &hp, &mut ws,
            );
        },
        ITERS,
    );

    let mut w_spawn = rng.gaussian_tensor(&[m, n], 0.5);
    let spawned = time_us(
        || {
            spawn_matmul_into(&mut vt, &vq, &vb);
            spawn_matmul_into(&mut y, &g, &om);
            spawn_matmul_at_b_into(&mut bproj, &mq, &g);
            spawn_fused_adamw(&mut w_spawn, &g, &vt, &mq, &mb, hp.beta1, 1e-3, c1f, c2f, &hp);
        },
        ITERS,
    );

    let speedup = spawned / pooled;
    println!(
        "\npool vs spawn (512x128, r=4 parallel-site mix): pooled {pooled:.1}us, \
         spawn-scaffold {spawned:.1}us -> {speedup:.2}x"
    );
    (
        Json::obj(vec![
            ("pooled_us", Json::num(pooled)),
            ("spawn_us", Json::num(spawned)),
            ("speedup", Json::num(speedup)),
        ]),
        speedup,
    )
}

// --------------------------- batched vs per-parameter (PR-6 fan-out ref)

const BATCH_COUNT: usize = 48;
const BATCH_SHAPE: (usize, usize, usize) = (256, 64, 4);

/// Fresh fleet for one schedule. Both schedules call this with the same
/// constants, so their weights, states and per-parameter Omega streams
/// start identical and the bit-identity assert is meaningful.
fn small_param_fleet() -> Vec<(Tensor, OptState, Rng)> {
    let (m, n, r) = BATCH_SHAPE;
    let mut seeder = Rng::new(4242);
    (0..BATCH_COUNT)
        .map(|i| {
            let mut rng = seeder.split(900 + i as u64);
            let w = rng.gaussian_tensor(&[m, n], 0.5);
            let state = OptState::for_variant("mlorc_adamw", &[m, n], r).unwrap();
            (w, state, rng)
        })
        .collect()
}

/// PR-6's `host_step_all` fan-out verbatim: contiguous job chunks paired
/// with workspaces, each chunk's optimizer steps forced into
/// `threads::serial` — the per-parameter baseline the shape-class
/// planner replaced.
fn per_param_step_all(
    params: &mut [(Tensor, OptState, Rng)],
    grads: &[Tensor],
    lr: f32,
    t: usize,
    workspaces: &mut [Workspace],
) {
    let nt = workspaces.len().min(params.len());
    if nt <= 1 {
        let ws = &mut workspaces[0];
        for ((w, state, rng), g) in params.iter_mut().zip(grads) {
            state.host_step(w, g, lr, t, rng, ws).expect("per-param host step");
        }
        return;
    }
    let chunk = params.len().div_ceil(nt);
    let bands: Vec<_> = params
        .chunks_mut(chunk)
        .zip(grads.chunks(chunk))
        .zip(workspaces.iter_mut())
        .map(|(band, ws)| Mutex::new(Some((band, ws))))
        .collect();
    let nbands = bands.len();
    threads::with_budget(nbands, || {
        pool::par_row_bands(nbands, usize::MAX / 4, |_, range| {
            for idx in range {
                let Some(((band, gband), ws)) = bands[idx].lock().unwrap().take() else {
                    continue;
                };
                threads::serial(|| {
                    for ((w, state, rng), g) in band.iter_mut().zip(gband) {
                        state.host_step(w, g, lr, t, rng, ws).expect("per-param host step");
                    }
                });
            }
        });
    });
}

/// The many-small-parameters scenario the shape-class planner targets:
/// 48 mlorc_adamw parameters of 256x64 at r=4 — each matrix too small
/// for its own kernels to engage the pool, the fleet large enough for
/// one stacked banded invocation per class to. Both schedules step
/// identical fleets for the same number of steps; weights are asserted
/// bit-identical before the speedup is reported. Returns
/// (json, batched_speedup).
fn batched_vs_per_param_bench(rng: &mut Rng) -> (Json, f64) {
    let (m, n, r) = BATCH_SHAPE;
    let grads: Vec<Tensor> =
        (0..BATCH_COUNT).map(|_| rng.gaussian_tensor(&[m, n], 1.0)).collect();
    let nws = threads::budget().max(1);
    let mut workspaces: Vec<Workspace> = (0..nws).map(|_| Workspace::new()).collect();

    let mut fleet_pp = small_param_fleet();
    let mut t_pp = 0usize;
    let per_param = time_us(
        || {
            t_pp += 1;
            per_param_step_all(&mut fleet_pp, &grads, 1e-3, t_pp, &mut workspaces);
        },
        ITERS,
    );

    let mut fleet_cls = small_param_fleet();
    let mut t_cls = 0usize;
    let batched = time_us(
        || {
            t_cls += 1;
            let mut jobs: Vec<HostStepJob> = fleet_cls
                .iter_mut()
                .zip(&grads)
                .map(|((w, state, rng), g)| HostStepJob {
                    w,
                    grad: g,
                    state,
                    rng,
                    lr: 1e-3,
                    t: t_cls,
                })
                .collect();
            host_step_all(&mut jobs, &mut workspaces).expect("batched host step");
        },
        ITERS,
    );

    for (i, ((wa, _, _), (wb, _, _))) in fleet_pp.iter().zip(&fleet_cls).enumerate() {
        assert_eq!(
            wa.data, wb.data,
            "param {i}: shape-class batched step must be bit-identical to per-parameter"
        );
    }

    let speedup = per_param / batched;
    println!(
        "\nbatched vs per-parameter ({BATCH_COUNT} x {m}x{n}, r={r} mlorc_adamw): \
         class-batched {batched:.1}us, per-param {per_param:.1}us -> {speedup:.2}x"
    );
    (
        Json::obj(vec![
            ("per_param_us", Json::num(per_param)),
            ("batched_us", Json::num(batched)),
            ("speedup", Json::num(speedup)),
        ]),
        speedup,
    )
}

/// Momentum-state footprint at the acceptance shape (512x128, r=4):
/// layout formula (`VariantDesc::state_bytes`) cross-checked against a
/// live state's `state_bytes()`, and the PR-5 gate — `mlorc_q8` momentum
/// state at most 0.3x dense AdamW (it lands near 0.01x: 1-byte codes on
/// rank-4 factors vs two dense f32 moments).
fn state_bytes_bench() -> Json {
    use mlorc::optim::registry;
    let (m, n, r) = (512usize, 128usize, 4usize);
    let dense = registry::variant("adamw").unwrap().state_bytes(m, n, r);
    let mut rows: BTreeMap<String, Json> = BTreeMap::new();
    println!("\nmomentum state bytes (512x128, r=4):");
    // wrapper_bytes covers the second-wave states outside the compressor
    // (Prodigy sliced statistics, bf16 weight planes) — zero for the rest
    for id in [
        "adamw",
        "mlorc_adamw",
        "mlorc_adarank",
        "mlorc_q8",
        "mlorc_prodigy",
        "mlorc_adamw_bf16",
    ] {
        let v = registry::variant(id).unwrap();
        let formula = v.state_bytes(m, n, r) + v.wrapper_bytes(m * n);
        let live = OptState::for_variant(id, &[m, n], r).unwrap().state_bytes();
        assert_eq!(live, formula, "{id}: live state bytes vs layout formula");
        println!("{id:>16} {formula:>9}B  ({:.4}x dense adamw)", formula as f64 / dense as f64);
        rows.insert(
            id.to_string(),
            Json::obj(vec![
                ("bytes", Json::num(formula as f64)),
                ("vs_dense_adamw", Json::num(formula as f64 / dense as f64)),
            ]),
        );
    }
    let q8 = registry::variant("mlorc_q8").unwrap().state_bytes(m, n, r);
    assert!(
        10 * q8 <= 3 * dense,
        "acceptance: mlorc_q8 momentum state {q8}B must be <= 0.3x dense AdamW {dense}B"
    );
    Json::Obj(rows)
}

/// GEMM-shape audit of the 512x128 fast step (the FLOP-count acceptance
/// assertion): per moment exactly one dense O(m·n·l) reconstruction, thin
/// sketches/projections everywhere else.
fn gemm_audit(rng: &mut Rng) -> Json {
    let (m, n) = (512usize, 128usize);
    let hp = OptHp::mlorc_adamw();
    let c = case(m, n, rng);
    let mut st = MlorcAdamWState::new(&[m, n], L);
    let mut w = c.w.clone();
    st.step_with_omegas(&mut w, &c.g, 1e-3, &hp, &c.om_m, &c.om_v); // warm factors
    flops::start_recording();
    st.step_with_omegas(&mut w, &c.g, 1e-3, &hp, &c.om_m, &c.om_v);
    let recs = flops::finish_recording();

    let dense = m * n;
    let thin_cap = m.max(n) * L;
    let dense_recons = recs.iter().filter(|r| !r.is_fused() && r.out_elems() == dense).count();
    let fused_recons = recs.iter().filter(|r| r.is_fused()).count();
    let fat_sketches = recs
        .iter()
        .filter(|r| !r.is_fused() && r.out_elems() != dense && r.out_elems() > thin_cap)
        .count();
    let madds = flops::total_madds(&recs);
    println!(
        "gemm audit (512x128, l={L}): {} GEMMs, {madds} madds, dense recons {dense_recons} \
         (+{fused_recons} fused), fat sketches {fat_sketches}",
        recs.len()
    );
    assert_eq!(
        dense_recons, 1,
        "fast path must materialize exactly one dense recon (v moment): {recs:?}"
    );
    assert_eq!(fused_recons, 1, "fast path must fuse the m-moment recon: {recs:?}");
    assert_eq!(fat_sketches, 0, "sketch/projection GEMMs must be thin: {recs:?}");
    Json::obj(vec![
        ("gemms", Json::num(recs.len() as f64)),
        ("madds", Json::num(madds as f64)),
        ("dense_recon_gemms", Json::num(dense_recons as f64)),
        ("fused_recon_gemms", Json::num(fused_recons as f64)),
    ])
}

/// Build zero/random inputs matching a step graph's IO table.
fn inputs_for(spec: &GraphSpec, rng: &mut Rng) -> Vec<HostValue> {
    spec.inputs
        .iter()
        .map(|io| {
            if io.shape.is_empty() {
                HostValue::scalar_f32(match io.name.as_str() {
                    "lr" => 1e-3,
                    _ => 1.0,
                })
            } else if io.name.starts_with("om") {
                rng.gaussian_tensor(&io.shape, 1.0).into()
            } else if io.name == "w" || io.name == "g" {
                rng.gaussian_tensor(&io.shape, 0.1).into()
            } else {
                Tensor::zeros(&io.shape).into()
            }
        })
        .collect()
}

fn graph_bench(rng: &mut Rng) -> Option<Json> {
    let dir = fsutil::artifacts_dir().ok()?;
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — skipping step-graph latency (host bench above still ran)");
        return None;
    }
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping step-graph latency: manifest unreadable: {e:#}");
            return None;
        }
    };
    let rt = match Runtime::cpu(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping step-graph latency: {e:#}");
            return None;
        }
    };
    let preset = match manifest.preset("tiny") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping step-graph latency: no tiny preset: {e:#}");
            return None;
        }
    };
    let mut methods: BTreeMap<String, Json> = BTreeMap::new();

    println!("\nstep-graph latency (us/step), tiny preset:");
    print!("{:>16}", "method");
    let shapes = ["128x128", "128x512", "512x128"];
    for s in &shapes {
        print!(" {s:>12}");
    }
    println!();
    for (method, by_shape) in &preset.opt_steps {
        print!("{method:>16}");
        let mut row: BTreeMap<String, Json> = BTreeMap::new();
        for key in &shapes {
            match by_shape.get(*key) {
                Some(spec) => {
                    let g = rt.load(spec).unwrap();
                    let inputs = inputs_for(spec, rng);
                    let _ = rt.execute(&g, &inputs).unwrap();
                    let t0 = Instant::now();
                    for _ in 0..ITERS {
                        let _ = rt.execute(&g, &inputs).unwrap();
                    }
                    let us = t0.elapsed().as_secs_f64() / ITERS as f64 * 1e6;
                    print!(" {us:>10.1}us");
                    row.insert((*key).to_string(), Json::num(us));
                }
                None => print!(" {:>12}", "-"),
            }
        }
        println!();
        methods.insert(method.clone(), Json::Obj(row));
    }
    Some(Json::Obj(methods))
}

// -------------------------------------------------------- history tracking

/// Append this run to `BENCH_HISTORY.json` and compare against the
/// previous entry: absolute µs drifts (machine-dependent) are printed as
/// warnings, machine-normalized ratio drops below 0.9x the previous
/// entry are returned as the strict-gate regression flag.
fn track_history(
    host: &Json,
    speedup_512: f64,
    pool_vs_spawn: f64,
    batched_vs_per_param: f64,
) -> bool {
    let path = match fsutil::find_repo_root() {
        Ok(root) => root.join("BENCH_HISTORY.json"),
        Err(e) => {
            eprintln!("bench history skipped: {e:#}");
            return false;
        }
    };
    let mut entries: Vec<Json> = if path.exists() {
        match Json::from_file(&path) {
            Ok(j) => j
                .get("entries")
                .and_then(|e| e.as_arr().ok())
                .map(|a| a.to_vec())
                .unwrap_or_default(),
            Err(e) => {
                // Never clobber an existing-but-unparseable baseline: that
                // would silently disable the regression gate.
                eprintln!(
                    "bench history NOT updated: {} exists but is unreadable ({e:#}); \
                     fix or delete it to resume tracking",
                    path.display()
                );
                return false;
            }
        }
    } else {
        Vec::new() // first run: start fresh
    };

    // Entries are heterogeneous (the serve-load bench appends its own
    // records to the same file), so "previous" means the last entry
    // carrying each key, not `entries.last()`.
    let mut regressed = false;
    {
        // absolute µs: warn only — a different runner legitimately moves
        // every number
        let prev_host = entries.iter().rev().find_map(|e| e.get("host_us_per_step"));
        for &(m, n) in &SHAPES {
            let key = format!("{m}x{n}");
            let prev_us = prev_host
                .and_then(|h| h.get(&key))
                .and_then(|s| s.get("mlorc_adamw_us"))
                .and_then(|v| v.as_f64().ok());
            let cur_us = host
                .get(&key)
                .and_then(|s| s.get("mlorc_adamw_us"))
                .and_then(|v| v.as_f64().ok());
            if let (Some(p), Some(c)) = (prev_us, cur_us) {
                if c > 1.10 * p {
                    println!(
                        "WARNING (absolute, machine-dependent): mlorc_adamw {key} host step \
                         {c:.1}us vs {p:.1}us in the previous entry (+{:.0}%)",
                        (c / p - 1.0) * 100.0
                    );
                }
            }
        }
        // normalized ratios: comparable across machines — these gate CI
        for (name, cur) in [
            ("speedup_512x128_vs_scalar", speedup_512),
            ("pool_vs_spawn_512x128_r4", pool_vs_spawn),
            ("batched_vs_per_param_48x256x64_r4", batched_vs_per_param),
        ] {
            let prev =
                entries.iter().rev().find_map(|e| e.get(name).and_then(|v| v.as_f64().ok()));
            if let Some(p) = prev {
                if cur < 0.9 * p {
                    regressed = true;
                    println!(
                        "REGRESSION: {name} is {cur:.2} vs {p:.2} in the previous entry \
                         ({:.0}% drop, >10% gate)",
                        (1.0 - cur / p) * 100.0
                    );
                }
            }
        }
    }

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let entry = Json::obj(vec![
        ("unix_time", Json::num(unix_time as f64)),
        ("thread_budget", Json::num(threads::budget() as f64)),
        ("simd_tier", Json::str(simd::simd_tier())),
        ("speedup_512x128_vs_scalar", Json::num(speedup_512)),
        ("pool_vs_spawn_512x128_r4", Json::num(pool_vs_spawn)),
        ("batched_vs_per_param_48x256x64_r4", Json::num(batched_vs_per_param)),
        ("host_us_per_step", host.clone()),
    ]);
    println!("appended BENCH_HISTORY entry:\n{}", entry.to_string_pretty());
    entries.push(entry);
    let hist = Json::obj(vec![
        ("schema", Json::str("bench_history/v1")),
        ("entries", Json::Arr(entries)),
    ]);
    match write_bench_json("BENCH_HISTORY.json", &hist) {
        Ok(p) => println!("appended run to {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_HISTORY.json: {e:#}"),
    }
    regressed
}

fn main() {
    let mut rng = Rng::new(0);
    let (host, speedup_512) = host_bench(&mut rng);
    let (pvs_json, pvs_speedup) = pool_vs_spawn_bench(&mut rng);
    let (bvp_json, bvp_speedup) = batched_vs_per_param_bench(&mut rng);
    let audit = gemm_audit(&mut rng);
    let state_bytes = state_bytes_bench();
    let graphs = graph_bench(&mut rng);

    println!("\n512x128 mlorc_adamw speedup vs pre-change scalar step: {speedup_512:.2}x");
    println!("simd tier: {}, pool budget: {}", simd::simd_tier(), threads::budget());
    let mut root = vec![
        ("schema", Json::str("bench_opt/v2")),
        ("l", Json::num(L as f64)),
        ("thread_budget", Json::num(threads::budget() as f64)),
        ("simd_tier", Json::str(simd::simd_tier())),
        ("iters", Json::num(ITERS as f64)),
        ("host_us_per_step", host.clone()),
        ("pool_vs_spawn_512x128_r4", pvs_json),
        ("batched_vs_per_param_48x256x64_r4", bvp_json),
        ("gemm_audit_512x128", audit),
        ("state_bytes_512x128_r4", state_bytes),
        ("speedup_512x128_vs_scalar", Json::num(speedup_512)),
    ];
    if let Some(g) = graphs {
        root.push(("graph_us_per_step", g));
    }
    match write_bench_json("BENCH_OPT.json", &Json::obj(root)) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_OPT.json: {e:#}"),
    }

    let regressed = track_history(&host, speedup_512, pvs_speedup, bvp_speedup);

    let lax = std::env::var("MLORC_BENCH_LAX").map(|v| v == "1").unwrap_or(false);
    let strict = std::env::var("MLORC_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let mut failed = false;
    if speedup_512 < 3.0 {
        let msg = format!(
            "acceptance: 512x128 mlorc_adamw host step is {speedup_512:.2}x vs the scalar \
             baseline, target >= 3x"
        );
        if lax {
            eprintln!("WARN (MLORC_BENCH_LAX=1): {msg}");
        } else {
            eprintln!("FAIL: {msg}");
            failed = true;
        }
    }
    if pvs_speedup < 1.5 {
        let msg = format!(
            "acceptance: pooled parallel-site mix (512x128, r=4) is {pvs_speedup:.2}x vs the \
             PR-1 spawn scaffold, target >= 1.5x"
        );
        if lax {
            eprintln!("WARN (MLORC_BENCH_LAX=1): {msg}");
        } else {
            eprintln!("FAIL: {msg}");
            failed = true;
        }
    }
    if bvp_speedup < 1.5 {
        let msg = format!(
            "acceptance: shape-class batched stepping (48 x 256x64, r=4) is {bvp_speedup:.2}x \
             vs the per-parameter fan-out, target >= 1.5x"
        );
        if lax {
            eprintln!("WARN (MLORC_BENCH_LAX=1): {msg}");
        } else {
            eprintln!("FAIL: {msg}");
            failed = true;
        }
    }
    if regressed && strict {
        eprintln!(
            "FAIL (MLORC_BENCH_STRICT=1): >10% normalized-ratio regression vs the previous \
             BENCH_HISTORY entry"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
