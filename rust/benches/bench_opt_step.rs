//! Optimizer step-graph latency across every method and matrix shape of
//! the tiny preset — the per-parameter cost table behind Table 4.
//!
//!     cargo bench --bench bench_opt_step

use std::time::Instant;

use mlorc::linalg::Rng;
use mlorc::runtime::{GraphSpec, HostValue, Manifest, Runtime};
use mlorc::tensor::Tensor;
use mlorc::util::fsutil;

/// Build zero/random inputs matching a step graph's IO table.
fn inputs_for(spec: &GraphSpec, rng: &mut Rng) -> Vec<HostValue> {
    spec.inputs
        .iter()
        .map(|io| {
            if io.shape.is_empty() {
                HostValue::scalar_f32(match io.name.as_str() {
                    "lr" => 1e-3,
                    _ => 1.0,
                })
            } else if io.name.starts_with("om") {
                rng.gaussian_tensor(&io.shape, 1.0).into()
            } else if io.name == "w" || io.name == "g" {
                rng.gaussian_tensor(&io.shape, 0.1).into()
            } else {
                Tensor::zeros(&io.shape).into()
            }
        })
        .collect()
}

fn main() {
    let Ok(dir) = fsutil::artifacts_dir() else { return };
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    let preset = manifest.preset("tiny").unwrap();
    let mut rng = Rng::new(0);

    println!("step-graph latency (us/step), tiny preset:");
    print!("{:>16}", "method");
    let shapes = ["128x128", "128x512", "512x128"];
    for s in &shapes {
        print!(" {s:>12}");
    }
    println!();
    for (method, by_shape) in &preset.opt_steps {
        print!("{method:>16}");
        for key in &shapes {
            match by_shape.get(*key) {
                Some(spec) => {
                    let g = rt.load(spec).unwrap();
                    let inputs = inputs_for(spec, &mut rng);
                    // warmup
                    let _ = rt.execute(&g, &inputs).unwrap();
                    let iters = 20;
                    let t0 = Instant::now();
                    for _ in 0..iters {
                        let _ = rt.execute(&g, &inputs).unwrap();
                    }
                    print!(" {:>10.1}us", t0.elapsed().as_secs_f64() / iters as f64 * 1e6);
                }
                None => print!(" {:>12}", "-"),
            }
        }
        println!();
    }
}
