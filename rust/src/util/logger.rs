//! Tiny `log`-facade backend (no `env_logger` in the vendor set).
//!
//! Level comes from `MLORC_LOG` (error|warn|info|debug|trace), default
//! info. Every line carries a unix-epoch-ms timestamp (so logs from
//! several scheduler processes sharing one spool can be interleaved by
//! time) and a process tag — `pid:<pid>` until [`set_tag`] installs
//! something better; `mlorc serve` sets its scheduler owner id. Output
//! goes to stderr, or appends to the file named by `MLORC_LOG_FILE`
//! when that is set (file-only, so child schedulers spawned by tests
//! and benches don't scribble over the parent's terminal).

use std::fs::File;
use std::io::Write;
use std::sync::{Mutex, OnceLock};

use log::{Level, LevelFilter, Log, Metadata, Record};

use super::fsutil;

/// Process tag stamped on every line; empty means "use pid:<pid>".
static TAG: Mutex<String> = Mutex::new(String::new());

/// Set the per-process log tag (e.g. the serve scheduler's owner id) so
/// interleaved multi-process logs attribute cleanly.
pub fn set_tag(tag: &str) {
    if let Ok(mut t) = TAG.lock() {
        *t = tag.to_string();
    }
}

struct Logger {
    level: LevelFilter,
    /// `MLORC_LOG_FILE` append sink; `None` logs to stderr.
    sink: Option<Mutex<File>>,
}

impl Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let tag = TAG.lock().map(|t| t.clone()).unwrap_or_default();
        let line = if tag.is_empty() {
            format!("[{} pid:{} {lvl}] {}", fsutil::unix_ms(), std::process::id(), record.args())
        } else {
            format!("[{} {tag} {lvl}] {}", fsutil::unix_ms(), record.args())
        };
        match &self.sink {
            Some(f) => {
                if let Ok(mut f) = f.lock() {
                    let _ = writeln!(f, "{line}");
                }
            }
            None => eprintln!("{line}"),
        }
    }

    fn flush(&self) {
        if let Some(f) = &self.sink {
            if let Ok(mut f) = f.lock() {
                let _ = f.flush();
            }
        }
    }
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Install the logger; idempotent (tests may race to call it).
pub fn init() {
    let level = match std::env::var("MLORC_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let sink = std::env::var("MLORC_LOG_FILE").ok().filter(|p| !p.is_empty()).and_then(|p| {
        std::fs::OpenOptions::new().create(true).append(true).open(&p).ok().map(Mutex::new)
    });
    let logger = LOGGER.get_or_init(|| Logger { level, sink });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }

    #[test]
    fn tag_is_settable_and_clearable() {
        super::set_tag("sched-test");
        assert_eq!(super::TAG.lock().unwrap().as_str(), "sched-test");
        super::set_tag("");
        assert!(super::TAG.lock().unwrap().is_empty());
    }
}
