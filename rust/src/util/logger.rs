//! Tiny `log`-facade backend (no `env_logger` in the vendor set).
//!
//! Level comes from `MLORC_LOG` (error|warn|info|debug|trace), default info.
//! Output goes to stderr with elapsed-seconds timestamps so training logs
//! interleave cleanly with metrics on stdout.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct Logger {
    start: Instant,
    level: LevelFilter,
}

impl Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl}] {}", record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Install the logger; idempotent (tests may race to call it).
pub fn init() {
    let level = match std::env::var("MLORC_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| Logger { start: Instant::now(), level });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
