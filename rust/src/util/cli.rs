//! Tiny argv parser (no `clap` in the offline vendor set).
//!
//! Grammar: `mlorc <subcommand> [positional]... [--key value | --key=value | --flag]...`
//!
//! Positionals must precede options: once the first `--` token appears, a
//! bare token binds as the value of the preceding `--key` (there is no
//! reliable way to distinguish a flag from a key-with-value otherwise).
//! Boolean flags that must precede a positional can be written `--flag=1`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys the command actually read — for unknown-option errors.
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{key}"),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
            None => Ok(default),
        }
    }

    pub fn get_i64(&self, key: &str, default: i64) -> Result<i64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
            None => Ok(default),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str, default: &str) -> Vec<String> {
        self.get_or(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }

    /// After a command has pulled everything it knows, reject leftovers so
    /// typos fail loudly instead of silently using defaults.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.options.keys() {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !seen.iter().any(|s| s == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("train data.bin --preset tiny --steps=100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("preset"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["data.bin"]);
    }

    #[test]
    fn bare_token_after_option_binds_as_value() {
        // documented grammar: positionals precede options
        let a = parse("train --verbose data.bin");
        assert_eq!(a.get("verbose"), Some("data.bin"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn value_starting_with_dashes_via_equals() {
        let a = parse("x --note=--weird--");
        assert_eq!(a.get("note"), Some("--weird--"));
    }

    #[test]
    fn trailing_flag_is_flag_not_option() {
        let a = parse("bench --quiet");
        assert!(a.flag("quiet"));
        assert!(a.get("quiet").is_none());
    }

    #[test]
    fn typed_getters_error_on_garbage() {
        let a = parse("t --steps abc");
        assert!(a.get_usize("steps", 0).is_err());
        let a = parse("t --lr 1e-3");
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 1e-3);
    }

    #[test]
    fn reject_unknown_catches_typos() {
        let a = parse("train --prset tiny");
        let _ = a.get("preset");
        assert!(a.reject_unknown().is_err());
        let a = parse("train --preset tiny");
        let _ = a.get("preset");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn list_option() {
        let a = parse("bench --methods mlorc_adamw,lora,galore");
        assert_eq!(a.get_list("methods", ""), vec!["mlorc_adamw", "lora", "galore"]);
        assert_eq!(a.get_list("missing", "a,b"), vec!["a", "b"]);
    }
}
