//! Minimal, dependency-free JSON parser/serializer.
//!
//! The offline vendor set has no `serde` facade, so the manifest
//! (`artifacts/manifest.json`), run configs and metrics files go through
//! this module. It supports the full JSON grammar (RFC 8259) minus
//! surrogate-pair escapes beyond the BMP round-trip; numbers are kept as
//! f64 (adequate: the manifest never encodes integers above 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key is missing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {}", self.kind()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {}", self.kind()),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {}", self.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {}", self.kind()),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {}", self.kind()),
        }
    }

    pub fn shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|d| d.as_usize()).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // -------------------------------------------------------- construction

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        }
    }

    // --------------------------------------------------------- serializing

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        push_indent(out, ind + 1);
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    push_indent(out, ind);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        push_indent(out, ind + 1);
                    }
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent.map(|i| i + 1));
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    push_indent(out, ind);
                }
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push(' ');
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: must be followed by \uXXXX low
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate pair"))?);
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the original slice
                    let start = self.i - 1;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| anyhow!("invalid utf-8 at byte {start}"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => bail!("bad hex digit at byte {}", self.i),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let x: f64 = text
            .parse()
            .map_err(|_| anyhow!("invalid number '{text}' at byte {start}"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"arr":[1,2.5,-3],"s":"q\"uote","t":true,"n":null,"o":{"k":7}}"#;
        let v = Json::parse(src).unwrap();
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse("\"caf\\u00e9 \\ud83d\\ude00 ünï\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀 ünï");
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn accessor_errors_name_the_problem() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        let err = v.req("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
        assert!(v.get("a").unwrap().as_str().is_err());
    }

    #[test]
    fn usize_rejects_fractions_and_negatives() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn shape_helper() {
        let v = Json::parse("[64, 256]").unwrap();
        assert_eq!(v.shape().unwrap(), vec![64, 256]);
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        let v = Json::Num(3.0);
        assert_eq!(v.to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }
}
