//! Dependency-free plumbing: JSON, CLI parsing, logging, filesystem.

pub mod cli;
pub mod fsutil;
pub mod json;
pub mod logger;
