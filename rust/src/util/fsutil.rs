//! Small filesystem helpers shared by checkpointing, metrics and benches,
//! plus the deterministic fault-injection (failpoint) harness that the
//! robustness tests and the CI fault-matrix drive.
//!
//! # Failpoints
//!
//! A failpoint is a named site in the IO path (`ckpt_write`,
//! `latest_write`, `status_write`, `spool_rename`, `lease_write`,
//! `ckpt_cadence`) where a fault can be injected on the Nth hit. Specs
//! are armed programmatically ([`failpoints::arm`]) or via the
//! `MLORC_FAILPOINT` environment variable:
//!
//! ```text
//! MLORC_FAILPOINT="ckpt_write:torn@3,status_write:enospc@1+"
//! ```
//!
//! Grammar: `site:action@N` fires on the Nth hit only; `site:action@N+`
//! fires on every hit from the Nth on; `@N` defaults to `@1`. Actions:
//!
//! * `torn`   — write only the first half of the bytes, report success
//!   (silent corruption, what a power cut mid-write leaves behind)
//! * `rename` — leave the `.tmp` file behind and fail the rename
//! * `enospc` — fail the write as if the disk were full
//! * `kill`   — abort the process with exit code [`KILL_EXIT_CODE`]
//! * `slow`   — sleep [`SLOW_ACTION_MS`] ms, then proceed normally (a
//!   congested disk; used to exercise the async checkpoint writer's
//!   backpressure path)
//!
//! Hit counters are per-spec, independent, and process-global: every
//! armed spec matching a site counts every hit on that site, so
//! `ckpt_write:kill@6` means "die on the 6th checkpoint file write
//! anywhere in the process" — which is exactly how a crash lands in
//! production — and `ckpt_write:torn@1+,ckpt_write:kill@5` tears writes
//! 1–4 then kills on the 5th (when several specs fire on the same hit, a
//! one-shot `@N` takes precedence over a repeat `@N+`; ties go to the
//! earlier-armed spec). Tests that arm failpoints must serialize on a
//! shared lock and [`failpoints::clear`] when done.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Exit code used by the `kill` failpoint action — same code the serve
/// crash hook uses, so harness scripts can assert on one value.
pub const KILL_EXIT_CODE: i32 = 86;

/// How long the `slow` failpoint action stalls an IO site.
pub const SLOW_ACTION_MS: u64 = 25;

/// What an armed failpoint does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailAction {
    /// Write the first half of the payload to the final path and report
    /// success.
    Torn,
    /// Write the tmp file, then fail the rename.
    RenameFail,
    /// Fail as if the device were out of space.
    Enospc,
    /// Abort the process with [`KILL_EXIT_CODE`].
    Kill,
    /// Sleep [`SLOW_ACTION_MS`] ms, then carry on normally.
    Slow,
}

#[derive(Debug, Clone)]
struct Failpoint {
    site: String,
    action: FailAction,
    /// Fires on the `at`-th hit (1-based).
    at: u64,
    /// `@N+`: keep firing on every hit from the `at`-th on.
    repeat: bool,
    hits: u64,
    done: bool,
}

/// `None` = the `MLORC_FAILPOINT` env var has not been consulted yet.
static REGISTRY: Mutex<Option<Vec<Failpoint>>> = Mutex::new(None);

pub mod failpoints {
    use super::*;

    fn parse_one(tok: &str) -> Result<Failpoint> {
        let (site, rest) = tok
            .split_once(':')
            .with_context(|| format!("failpoint '{tok}': want site:action[@N]"))?;
        let (action_s, count_s) = match rest.split_once('@') {
            Some((a, c)) => (a, c),
            None => (rest, "1"),
        };
        let action = match action_s {
            "torn" => FailAction::Torn,
            "rename" => FailAction::RenameFail,
            "enospc" => FailAction::Enospc,
            "kill" => FailAction::Kill,
            "slow" => FailAction::Slow,
            other => bail!(
                "failpoint '{tok}': unknown action '{other}' \
                 (want torn|rename|enospc|kill|slow)"
            ),
        };
        let (count_s, repeat) = match count_s.strip_suffix('+') {
            Some(c) => (c, true),
            None => (count_s, false),
        };
        let at: u64 = count_s
            .parse()
            .with_context(|| format!("failpoint '{tok}': bad hit count '{count_s}'"))?;
        if at == 0 {
            bail!("failpoint '{tok}': hit count is 1-based");
        }
        Ok(Failpoint { site: site.to_string(), action, at, repeat, hits: 0, done: false })
    }

    fn parse_spec(spec: &str) -> Result<Vec<Failpoint>> {
        spec.split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(parse_one)
            .collect()
    }

    fn with_registry<T>(f: impl FnOnce(&mut Vec<Failpoint>) -> T) -> T {
        let mut guard = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        if guard.is_none() {
            let mut initial = Vec::new();
            if let Ok(spec) = std::env::var("MLORC_FAILPOINT") {
                match parse_spec(&spec) {
                    Ok(fps) => initial = fps,
                    Err(e) => log::warn!("ignoring bad MLORC_FAILPOINT: {e:#}"),
                }
            }
            *guard = Some(initial);
        }
        f(guard.as_mut().unwrap())
    }

    /// Arm additional failpoints (same grammar as `MLORC_FAILPOINT`).
    pub fn arm(spec: &str) -> Result<()> {
        let fps = parse_spec(spec)?;
        with_registry(|reg| reg.extend(fps));
        Ok(())
    }

    /// Disarm everything (the env var is *not* re-read afterwards).
    pub fn clear() {
        let mut guard = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        *guard = Some(Vec::new());
    }

    /// True if any failpoint is currently armed (fired one-shots count
    /// as disarmed).
    pub fn active() -> bool {
        with_registry(|reg| reg.iter().any(|fp| !fp.done))
    }

    /// True if an armed failpoint targets `site`. The async checkpoint
    /// writer uses this to hard-join pending commits before a crash hook
    /// (`ckpt_cadence`) could fire, keeping injected-kill semantics
    /// identical to the synchronous path. Does NOT count a hit.
    pub fn armed_on(site: &str) -> bool {
        with_registry(|reg| reg.iter().any(|fp| !fp.done && fp.site == site))
    }

    /// Record one hit on `site`; returns the action to perform if an
    /// armed failpoint fires. Every spec matching the site counts the
    /// hit on its own counter (so a repeat spec never shadows a later
    /// one-shot on the same site); when several specs fire on the same
    /// hit, a one-shot (`@N`) wins over a repeat (`@N+`), ties going to
    /// the earlier-armed spec.
    pub(super) fn hit(site: &str) -> Option<FailAction> {
        if site.is_empty() {
            return None;
        }
        with_registry(|reg| {
            let mut one_shot = None;
            let mut repeat = None;
            for fp in reg.iter_mut() {
                if fp.site != site {
                    continue;
                }
                fp.hits += 1;
                let fires =
                    if fp.repeat { fp.hits >= fp.at } else { fp.hits == fp.at };
                if !fp.repeat && fp.hits >= fp.at {
                    fp.done = true;
                }
                if fires {
                    let slot = if fp.repeat { &mut repeat } else { &mut one_shot };
                    if slot.is_none() {
                        *slot = Some(fp.action);
                    }
                }
            }
            one_shot.or(repeat)
        })
    }
}

fn kill_now(site: &str) -> ! {
    eprintln!("failpoint '{site}': injected kill (exit {KILL_EXIT_CODE})");
    std::process::exit(KILL_EXIT_CODE);
}

/// Generic failpoint trigger for sites that are not file writes (e.g.
/// `ckpt_cadence`). `kill` aborts the process; every other action
/// surfaces as an error.
pub fn failpoint(site: &str) -> Result<()> {
    match failpoints::hit(site) {
        None => Ok(()),
        Some(FailAction::Kill) => kill_now(site),
        Some(FailAction::Slow) => {
            std::thread::sleep(std::time::Duration::from_millis(SLOW_ACTION_MS));
            Ok(())
        }
        Some(action) => bail!("failpoint '{site}': injected {action:?}"),
    }
}

/// Create all parent directories of `path`.
pub fn ensure_parent(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    Ok(())
}

/// Atomic-ish write: write to `<path>.tmp` then rename. Keeps partially
/// written metrics/checkpoints from being picked up by a reader.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    write_atomic_site(path, bytes, "")
}

/// [`write_atomic`] with a failpoint site attached; the checkpoint and
/// spool writers route through this so faults land on the real IO path.
pub fn write_atomic_site(path: &Path, bytes: &[u8], site: &str) -> Result<()> {
    ensure_parent(path)?;
    match failpoints::hit(site) {
        Some(FailAction::Kill) => kill_now(site),
        Some(FailAction::Slow) => {
            std::thread::sleep(std::time::Duration::from_millis(SLOW_ACTION_MS));
        }
        Some(FailAction::Torn) => {
            // what a power cut mid-write leaves: a half-written file at
            // the final path, and no error anyone saw
            let half = &bytes[..bytes.len() / 2];
            std::fs::write(path, half)
                .with_context(|| format!("writing {}", path.display()))?;
            return Ok(());
        }
        Some(FailAction::Enospc) => {
            bail!(
                "failpoint '{site}': injected ENOSPC (no space left on device) \
                 writing {}",
                path.display()
            );
        }
        Some(FailAction::RenameFail) => {
            let tmp = path.with_extension("tmp");
            let _ = std::fs::write(&tmp, bytes);
            bail!("failpoint '{site}': injected rename failure for {}", path.display());
        }
        None => {}
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
    Ok(())
}

/// fsync a directory, making previously renamed entries inside it
/// durable across power loss. `write_atomic`'s rename orders the data
/// before the name, but the *name* itself only survives a power cut once
/// the parent directory's metadata is synced — the checkpoint commit
/// path calls this after each snapshot's `meta.json` commit marker and
/// after the `LATEST` flip (on the writer thread, where the stall is
/// free).
pub fn fsync_dir(dir: &Path) -> Result<()> {
    let f = std::fs::File::open(dir)
        .with_context(|| format!("opening {} for fsync", dir.display()))?;
    f.sync_all().with_context(|| format!("fsync {}", dir.display()))?;
    Ok(())
}

/// `std::fs::rename` with a failpoint site attached (spool lifecycle
/// transitions go through this).
pub fn rename_site(from: &Path, to: &Path, site: &str) -> Result<()> {
    match failpoints::hit(site) {
        Some(FailAction::Kill) => kill_now(site),
        Some(FailAction::Slow) => {
            std::thread::sleep(std::time::Duration::from_millis(SLOW_ACTION_MS));
        }
        Some(action) => bail!(
            "failpoint '{site}': injected {action:?} renaming {} -> {}",
            from.display(),
            to.display()
        ),
        None => {}
    }
    std::fs::rename(from, to)
        .with_context(|| format!("renaming {} -> {}", from.display(), to.display()))?;
    Ok(())
}

// --------------------------------------------------------------- hashing

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3) — the integrity checksum of RTEN footers and
/// snapshot manifests.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// FNV-1a 64-bit — cheap stable hash for per-job lease jitter.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Milliseconds since the Unix epoch (0 if the clock is before it).
pub fn unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

// ------------------------------------------------------------ repo paths

/// Locate the repository root (directory containing `artifacts/`) from the
/// current dir upwards — lets examples and benches run from anywhere in the
/// workspace.
pub fn find_repo_root() -> Result<PathBuf> {
    if let Ok(root) = std::env::var("MLORC_ROOT") {
        return Ok(PathBuf::from(root));
    }
    let mut dir = std::env::current_dir()?;
    loop {
        if dir.join("artifacts").is_dir() || dir.join("Cargo.toml").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            anyhow::bail!("could not locate repo root (set MLORC_ROOT)");
        }
    }
}

/// Default artifacts directory.
pub fn artifacts_dir() -> Result<PathBuf> {
    Ok(find_repo_root()?.join("artifacts"))
}

/// results/ output directory for benches and experiments.
pub fn results_dir() -> Result<PathBuf> {
    let d = find_repo_root()?.join("results");
    std::fs::create_dir_all(&d)?;
    Ok(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_roundtrip() {
        let dir = std::env::temp_dir().join(format!("mlorc_fs_{}", std::process::id()));
        let path = dir.join("a/b/c.json");
        write_atomic(&path, b"{\"x\":1}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"x\":1}");
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crc32_known_vector() {
        // the canonical CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn failpoint_spec_parsing_and_firing() {
        // NOTE: failpoint state is process-global; this test and
        // `torn_write_leaves_half_a_file` are the only in-crate users and
        // both run under the same #[cfg(test)] binary, so serialize them.
        let _g = FP_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        failpoints::clear();
        failpoints::arm("siteA:enospc@2, siteB:torn@1+").unwrap();
        assert!(failpoints::active());
        // one-shot @2: 1st hit passes, 2nd fires, 3rd passes again
        assert!(failpoint("siteA").is_ok());
        assert!(failpoint("siteA").is_err());
        assert!(failpoint("siteA").is_ok());
        // repeat @1+: fires every time
        assert_eq!(failpoints::hit("siteB"), Some(FailAction::Torn));
        assert_eq!(failpoints::hit("siteB"), Some(FailAction::Torn));
        // unknown site never fires
        assert!(failpoint("siteC").is_ok());
        // a repeat spec must not shadow a later one-shot on the same
        // site: counters are per-spec, and the one-shot wins its hit
        failpoints::clear();
        failpoints::arm("siteD:torn@1+,siteD:enospc@3").unwrap();
        assert_eq!(failpoints::hit("siteD"), Some(FailAction::Torn));
        assert_eq!(failpoints::hit("siteD"), Some(FailAction::Torn));
        assert_eq!(failpoints::hit("siteD"), Some(FailAction::Enospc));
        assert_eq!(failpoints::hit("siteD"), Some(FailAction::Torn));
        // bad specs are rejected
        assert!(failpoints::arm("no_action").is_err());
        assert!(failpoints::arm("s:explode@1").is_err());
        assert!(failpoints::arm("s:torn@0").is_err());
        failpoints::clear();
        assert!(!failpoints::active());
    }

    #[test]
    fn torn_write_leaves_half_a_file() {
        let _g = FP_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        failpoints::clear();
        let dir = std::env::temp_dir().join(format!("mlorc_fp_{}", std::process::id()));
        let path = dir.join("torn.bin");
        failpoints::arm("t_write:torn@2,t_write:enospc@1").unwrap();
        // hit 1: both specs count it; only enospc@1 fires, so the write
        // fails and nothing lands on disk
        assert!(write_atomic_site(&path, b"0123456789", "t_write").is_err());
        assert!(!path.exists());
        // hit 2: torn@2 fires — half the payload lands, call succeeds
        assert!(write_atomic_site(&path, b"0123456789", "t_write").is_ok());
        assert_eq!(std::fs::read(&path).unwrap(), b"01234");
        // hit 3: both specs exhausted, the write goes through intact
        assert!(write_atomic_site(&path, b"0123456789", "t_write").is_ok());
        assert_eq!(std::fs::read(&path).unwrap(), b"0123456789");
        failpoints::clear();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    static FP_TEST_LOCK: Mutex<()> = Mutex::new(());
}
