//! Small filesystem helpers shared by checkpointing, metrics and benches.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Create all parent directories of `path`.
pub fn ensure_parent(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    Ok(())
}

/// Atomic-ish write: write to `<path>.tmp` then rename. Keeps partially
/// written metrics/checkpoints from being picked up by a reader.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    ensure_parent(path)?;
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
    Ok(())
}

/// Locate the repository root (directory containing `artifacts/`) from the
/// current dir upwards — lets examples and benches run from anywhere in the
/// workspace.
pub fn find_repo_root() -> Result<PathBuf> {
    if let Ok(root) = std::env::var("MLORC_ROOT") {
        return Ok(PathBuf::from(root));
    }
    let mut dir = std::env::current_dir()?;
    loop {
        if dir.join("artifacts").is_dir() || dir.join("Cargo.toml").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            anyhow::bail!("could not locate repo root (set MLORC_ROOT)");
        }
    }
}

/// Default artifacts directory.
pub fn artifacts_dir() -> Result<PathBuf> {
    Ok(find_repo_root()?.join("artifacts"))
}

/// results/ output directory for benches and experiments.
pub fn results_dir() -> Result<PathBuf> {
    let d = find_repo_root()?.join("results");
    std::fs::create_dir_all(&d)?;
    Ok(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_roundtrip() {
        let dir = std::env::temp_dir().join(format!("mlorc_fs_{}", std::process::id()));
        let path = dir.join("a/b/c.json");
        write_atomic(&path, b"{\"x\":1}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"x\":1}");
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
