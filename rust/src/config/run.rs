//! RunConfig: one training/evaluation run, JSON-serializable so the
//! launcher, examples and the bench harness share the exact same spec.

use anyhow::{bail, Result};

use crate::util::json::Json;

use super::LrSchedule;

/// Optimization method — the rows of the paper's tables. The type (and
/// every id, alias, routing flag and default LR) lives in the optimizer
/// registry; see `optim::registry` for the method/variant tables and how
/// to register a new (rule × compressor) combination.
pub use crate::optim::registry::Method;

/// Which synthetic workload to run (DESIGN.md §2 substitutions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// MetaMathQA -> GSM8K analog: arithmetic chains, exact-match eval.
    MathChain,
    /// CodeFeedback -> HumanEval analog: typed-bracket closing, exact match.
    StackCode,
    /// One of the 8 SynGLUE classification tasks (Table 5).
    SynGlue(u8),
}

impl TaskKind {
    pub fn name(&self) -> String {
        match self {
            TaskKind::MathChain => "math_chain".to_string(),
            TaskKind::StackCode => "stack_code".to_string(),
            TaskKind::SynGlue(i) => format!("synglue_{}", crate::data::SYNGLUE_NAMES[*i as usize]),
        }
    }

    pub fn parse(s: &str) -> Result<TaskKind> {
        if s == "math_chain" || s == "math" {
            return Ok(TaskKind::MathChain);
        }
        if s == "stack_code" || s == "code" {
            return Ok(TaskKind::StackCode);
        }
        if let Some(rest) = s.strip_prefix("synglue_") {
            if let Some(i) = crate::data::SYNGLUE_NAMES.iter().position(|n| *n == rest) {
                return Ok(TaskKind::SynGlue(i as u8));
            }
        }
        bail!("unknown task '{s}'")
    }

    pub fn is_classification(&self) -> bool {
        matches!(self, TaskKind::SynGlue(_))
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub preset: String,
    pub method: Method,
    pub task: TaskKind,
    pub steps: usize,
    pub peak_lr: f32,
    pub schedule: LrSchedule,
    pub seed: u64,
    /// evaluate every N steps (0 = only at the end)
    pub eval_every: usize,
    pub eval_batches: usize,
    /// GaLore subspace refresh period T (paper: 50-300)
    pub galore_update_freq: usize,
    /// spectral probe cadence (0 = off) — Figures 1/4
    pub spectral_every: usize,
    /// adaptive-rank floor for AdaRank layouts (`--rank-min`; fixed-rank
    /// layouts ignore it)
    pub rank_min: usize,
    /// free gradient buffers eagerly, layer by layer (per-layer updates)
    pub per_layer_updates: bool,
    /// step optimizer states on the host (rust reference mirrors, factored
    /// MLorc fast path) in parallel, instead of per-layer step graphs
    pub host_opt: bool,
    /// host stepping worker count (0 = auto: available cores, capped at 8)
    pub opt_threads: usize,
    pub log_every: usize,
}

impl RunConfig {
    pub fn new(preset: &str, method: Method, task: TaskKind, steps: usize) -> RunConfig {
        RunConfig {
            preset: preset.to_string(),
            method,
            task,
            steps,
            peak_lr: method.default_lr(),
            schedule: LrSchedule::paper_default(steps),
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            galore_update_freq: 50,
            spectral_every: 0,
            rank_min: 1,
            per_layer_updates: true,
            host_opt: false,
            opt_threads: 0,
            log_every: 10,
        }
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.peak_lr = lr;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("method", Json::str(self.method.name())),
            ("task", Json::str(self.task.name())),
            ("steps", Json::num(self.steps as f64)),
            ("peak_lr", Json::num(self.peak_lr as f64)),
            ("schedule", self.schedule.to_json()),
            ("seed", Json::num(self.seed as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("galore_update_freq", Json::num(self.galore_update_freq as f64)),
            ("spectral_every", Json::num(self.spectral_every as f64)),
            ("rank_min", Json::num(self.rank_min as f64)),
            ("per_layer_updates", Json::Bool(self.per_layer_updates)),
            ("host_opt", Json::Bool(self.host_opt)),
            ("opt_threads", Json::num(self.opt_threads as f64)),
            ("log_every", Json::num(self.log_every as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        Ok(RunConfig {
            preset: j.req("preset")?.as_str()?.to_string(),
            method: Method::parse(j.req("method")?.as_str()?)?,
            task: TaskKind::parse(j.req("task")?.as_str()?)?,
            steps: j.req("steps")?.as_usize()?,
            peak_lr: j.req("peak_lr")?.as_f64()? as f32,
            schedule: LrSchedule::from_json(j.req("schedule")?)?,
            seed: j.req("seed")?.as_f64()? as u64,
            eval_every: j.req("eval_every")?.as_usize()?,
            eval_batches: j.req("eval_batches")?.as_usize()?,
            galore_update_freq: j.req("galore_update_freq")?.as_usize()?,
            spectral_every: j.req("spectral_every")?.as_usize()?,
            // optional for checkpoints/specs written before adaptive rank
            rank_min: match j.get("rank_min") {
                Some(v) => v.as_usize()?,
                None => 1,
            },
            per_layer_updates: j.req("per_layer_updates")?.as_bool()?,
            // optional for checkpoints written before host stepping existed
            host_opt: match j.get("host_opt") {
                Some(v) => v.as_bool()?,
                None => false,
            },
            opt_threads: match j.get("opt_threads") {
                Some(v) => v.as_usize()?,
                None => 0,
            },
            log_every: j.req("log_every")?.as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()).unwrap(), *m);
        }
        assert!(Method::parse("sgd").is_err());
    }

    #[test]
    fn task_parse_roundtrip() {
        for t in [
            TaskKind::MathChain,
            TaskKind::StackCode,
            TaskKind::SynGlue(0),
            TaskKind::SynGlue(7),
        ] {
            assert_eq!(TaskKind::parse(&t.name()).unwrap(), t);
        }
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = RunConfig::new("tiny", Method::MlorcAdamW, TaskKind::MathChain, 100)
            .with_lr(3e-4)
            .with_seed(7);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.preset, "tiny");
        assert_eq!(back.method, Method::MlorcAdamW);
        assert_eq!(back.peak_lr, 3e-4);
        assert_eq!(back.seed, 7);
        assert_eq!(back.schedule, cfg.schedule);
    }

    #[test]
    fn lora_routing() {
        assert!(Method::LoraAdamW.is_lora());
        assert_eq!(Method::LoraAdamW.matrix_step(), "adamw");
        assert_eq!(Method::MlorcLion.plain_step(), "lion");
        assert_eq!(Method::Galore.plain_step(), "adamw");
    }
}
