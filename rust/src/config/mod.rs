//! Run configuration: what to train, with which method, for how long.
//!
//! Model *dimensions* come from the manifest (single source of truth);
//! this module owns everything else — method selection, schedule, seeds,
//! task, eval cadence — loadable from JSON or built in code by examples.

mod run;
mod schedule;

pub use run::{Method, RunConfig, TaskKind};
pub use schedule::LrSchedule;
