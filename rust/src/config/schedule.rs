//! Learning-rate schedules. The paper uses linear decay with a 0.03 warmup
//! ratio for all fine-tuning runs (Section 4.1).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Constant,
    /// Linear warmup to peak over `warmup` steps, then linear decay to 0
    /// at `total` steps (the paper's scheduler).
    LinearWarmupDecay { warmup: usize, total: usize },
    /// Inverse-sqrt decay after warmup (pre-training style; extension).
    InverseSqrt { warmup: usize },
}

impl LrSchedule {
    /// Paper defaults: warmup_ratio 0.03 of total steps.
    pub fn paper_default(total_steps: usize) -> LrSchedule {
        LrSchedule::LinearWarmupDecay {
            warmup: ((total_steps as f64) * 0.03).ceil() as usize,
            total: total_steps,
        }
    }

    /// Multiplier applied to the peak learning rate at step `t` (0-based).
    pub fn factor(&self, t: usize) -> f32 {
        match *self {
            LrSchedule::Constant => 1.0,
            LrSchedule::LinearWarmupDecay { warmup, total } => {
                let t1 = t + 1;
                if warmup > 0 && t1 <= warmup {
                    t1 as f32 / warmup as f32
                } else if t1 >= total {
                    0.0
                } else {
                    let rem = (total - t1) as f32;
                    let span = (total.max(warmup + 1) - warmup) as f32;
                    rem / span
                }
            }
            LrSchedule::InverseSqrt { warmup } => {
                let t1 = (t + 1) as f32;
                let w = warmup.max(1) as f32;
                if t1 <= w {
                    t1 / w
                } else {
                    (w / t1).sqrt()
                }
            }
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match *self {
            LrSchedule::Constant => Json::obj(vec![("kind", Json::str("constant"))]),
            LrSchedule::LinearWarmupDecay { warmup, total } => Json::obj(vec![
                ("kind", Json::str("linear_warmup_decay")),
                ("warmup", Json::num(warmup as f64)),
                ("total", Json::num(total as f64)),
            ]),
            LrSchedule::InverseSqrt { warmup } => Json::obj(vec![
                ("kind", Json::str("inverse_sqrt")),
                ("warmup", Json::num(warmup as f64)),
            ]),
        }
    }

    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<LrSchedule> {
        match j.req("kind")?.as_str()? {
            "constant" => Ok(LrSchedule::Constant),
            "linear_warmup_decay" => Ok(LrSchedule::LinearWarmupDecay {
                warmup: j.req("warmup")?.as_usize()?,
                total: j.req("total")?.as_usize()?,
            }),
            "inverse_sqrt" => Ok(LrSchedule::InverseSqrt { warmup: j.req("warmup")?.as_usize()? }),
            k => anyhow::bail!("unknown schedule kind '{k}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_decay() {
        let s = LrSchedule::LinearWarmupDecay { warmup: 10, total: 110 };
        assert!((s.factor(0) - 0.1).abs() < 1e-6);
        assert!((s.factor(9) - 1.0).abs() < 1e-6);
        assert!(s.factor(10) < 1.0);
        assert!(s.factor(50) > s.factor(100));
        assert_eq!(s.factor(109), 0.0);
        assert_eq!(s.factor(500), 0.0);
    }

    #[test]
    fn paper_default_ratio() {
        let s = LrSchedule::paper_default(1000);
        match s {
            LrSchedule::LinearWarmupDecay { warmup, total } => {
                assert_eq!(warmup, 30);
                assert_eq!(total, 1000);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn monotone_during_warmup_nonincreasing_after() {
        let s = LrSchedule::paper_default(200);
        let f: Vec<f32> = (0..200).map(|t| s.factor(t)).collect();
        for t in 1..6 {
            assert!(f[t] >= f[t - 1]);
        }
        for t in 7..200 {
            assert!(f[t] <= f[t - 1] + 1e-6);
        }
    }

    #[test]
    fn json_roundtrip() {
        for s in [
            LrSchedule::Constant,
            LrSchedule::LinearWarmupDecay { warmup: 5, total: 50 },
            LrSchedule::InverseSqrt { warmup: 7 },
        ] {
            assert_eq!(LrSchedule::from_json(&s.to_json()).unwrap(), s);
        }
    }
}
