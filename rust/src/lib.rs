//! MLorc: Momentum Low-rank Compression — a rust + JAX + Pallas
//! reproduction of Shen et al., AISTATS 2026.
//!
//! Three layers (see DESIGN.md):
//!  * L1 Pallas kernels and L2 JAX graphs live in `python/compile/` and are
//!    AOT-lowered once (`make artifacts`) to HLO text;
//!  * this crate is L3: it loads the artifacts through PJRT (`runtime`),
//!    owns the training loop, data pipeline, RNG and all state
//!    (`coordinator`), and regenerates every table/figure of the paper
//!    (`bench_harness`). Python never runs at training time.

pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
pub mod util;
