//! File-backed job spool with atomic claim-by-rename.
//!
//! Layout under the spool root:
//!
//! ```text
//! queue/<id>.json      submitted, unclaimed
//! running/<id>.json    claimed by a scheduler worker (renamed from queue/)
//! done/<id>.json       finished successfully
//! failed/<id>.json     finished with an error (status/<id>.json has why)
//! cancelled/<id>.json  tombstoned while queued (`mlorc cancel`)
//! status/<id>.json     latest per-job progress (serve::status)
//! work/<id>/           job scratch: rotated v2 checkpoints, metrics
//! ```
//!
//! Lifecycle is `queued -> running -> done|failed`, with a side exit
//! `queued -> cancelled`. Claims and cancellations are each a single
//! `rename(2)`: exactly one scheduler worker (or canceller) wins a given
//! spec file, which is the entire concurrency story — no locks, no
//! daemon, no registry. Claim order is (priority desc, id asc), so
//! late-submitted urgent jobs overtake the backlog. A `kill -9` leaves
//! at worst a spec stranded in `running/`; the next scheduler start
//! sweeps those back into `queue/` ([`Spool::recover_interrupted`]) and
//! the job resumes from its latest v2 checkpoint under `work/<id>/ckpt/`.
//!
//! Deployment note: submitters and status readers can share a spool
//! freely, but run one *scheduler* per spool — the recovery sweep cannot
//! tell a crashed scheduler's jobs from a live one's, so a second
//! scheduler would re-queue work the first is still running.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::util::fsutil;
use crate::util::json::Json;

/// The lifecycle directories, in pipeline order.
pub const LIFECYCLE_DIRS: [&str; 5] = ["queue", "running", "done", "failed", "cancelled"];

/// Which trainer executes a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Synthetic least-squares fine-tuning entirely on the host
    /// (`serve::HostTrainer`) — no artifacts required.
    Host,
    /// The real graph trainer (`coordinator::Trainer`) — needs `make
    /// artifacts` and a `pjrt`-enabled build.
    Graph,
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Host => "host",
            Engine::Graph => "graph",
        }
    }

    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "host" => Engine::Host,
            "graph" => Engine::Graph,
            _ => bail!("unknown engine '{s}' (host | graph)"),
        })
    }
}

/// One queued fine-tuning run: a `RunConfig` plus serve-level knobs.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: String,
    pub engine: Engine,
    /// Checkpoint cadence in steps (0 = final snapshot only).
    pub checkpoint_every: usize,
    /// Claim priority: higher claims first; ties break by id (ascending).
    /// 0 is the default for jobs that don't care. Stored as a JSON number
    /// (f64), so values are clamped to the exactly-representable integer
    /// range (±2^53) on both serialize and parse — a spec always
    /// roundtrips to the priority the claim order actually uses.
    pub priority: i64,
    pub cfg: RunConfig,
}

/// Largest priority magnitude that survives the JSON f64 encoding exactly.
const PRIORITY_CLAMP: i64 = 1 << 53;

impl JobSpec {
    pub fn to_json(&self) -> Json {
        let priority = self.priority.clamp(-PRIORITY_CLAMP, PRIORITY_CLAMP);
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("engine", Json::str(self.engine.name())),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("priority", Json::num(priority as f64)),
            ("config", self.cfg.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobSpec> {
        Ok(JobSpec {
            id: j.req("id")?.as_str()?.to_string(),
            engine: Engine::parse(j.req("engine")?.as_str()?)?,
            checkpoint_every: j.req("checkpoint_every")?.as_usize()?,
            // optional for specs submitted before priorities existed
            priority: match j.get("priority") {
                Some(v) => (v.as_f64()?.clamp(-(PRIORITY_CLAMP as f64), PRIORITY_CLAMP as f64))
                    as i64,
                None => 0,
            },
            cfg: RunConfig::from_json(j.req("config")?)?,
        })
    }
}

/// Handle on a spool directory. Cheap to open; all state is on disk, so
/// any number of submitters/schedulers/status readers can share one.
pub struct Spool {
    root: PathBuf,
}

impl Spool {
    /// Open (creating if needed) a spool rooted at `root`.
    pub fn open(root: &Path) -> Result<Spool> {
        for d in ["queue", "running", "done", "failed", "cancelled", "status", "work"] {
            let p = root.join(d);
            std::fs::create_dir_all(&p)
                .with_context(|| format!("creating spool dir {}", p.display()))?;
        }
        Ok(Spool { root: root.to_path_buf() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn dir(&self, which: &str) -> PathBuf {
        self.root.join(which)
    }

    fn spec_path(&self, state: &str, id: &str) -> PathBuf {
        self.dir(state).join(format!("{id}.json"))
    }

    /// Per-job scratch directory (checkpoints, metrics).
    pub fn work_dir(&self, id: &str) -> PathBuf {
        self.dir("work").join(id)
    }

    /// Rotated v2 checkpoint root for a job.
    pub fn checkpoint_root(&self, id: &str) -> PathBuf {
        self.work_dir(id).join("ckpt")
    }

    pub fn status_path(&self, id: &str) -> PathBuf {
        self.dir("status").join(format!("{id}.json"))
    }

    /// Enqueue a job. Fails if any lifecycle dir already holds the id.
    pub fn submit(&self, spec: &JobSpec) -> Result<PathBuf> {
        if spec.id.is_empty()
            || spec.id.chars().any(|c| c == '/' || c == '\\')
            || spec.id.contains("..")
        {
            bail!("job id '{}' must be a plain file name", spec.id);
        }
        for state in LIFECYCLE_DIRS {
            if self.spec_path(state, &spec.id).exists() {
                bail!("job '{}' already exists in {state}/", spec.id);
            }
        }
        let path = self.spec_path("queue", &spec.id);
        fsutil::write_atomic(&path, spec.to_json().to_string_pretty().as_bytes())?;
        Ok(path)
    }

    /// A fresh sequential id `jobNNN_<suffix>` (scans every lifecycle dir
    /// so ids never collide with finished jobs).
    pub fn next_job_id(&self, suffix: &str) -> Result<String> {
        let mut max = 0usize;
        for state in LIFECYCLE_DIRS {
            for id in self.jobs_in(state)? {
                if let Some(rest) = id.strip_prefix("job") {
                    let digits: String =
                        rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                    if let Ok(n) = digits.parse::<usize>() {
                        max = max.max(n);
                    }
                }
            }
        }
        Ok(format!("job{:03}_{suffix}", max + 1))
    }

    /// Sorted job ids currently in a lifecycle dir.
    pub fn jobs_in(&self, state: &str) -> Result<Vec<String>> {
        let dir = self.dir(state);
        let entries =
            std::fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?;
        let mut ids = Vec::new();
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(stem) = name.strip_suffix(".json") {
                ids.push(stem.to_string());
            }
        }
        ids.sort();
        Ok(ids)
    }

    /// Load a job spec from a lifecycle dir (the file name is the id of
    /// record; a drifted `id` field inside the file is overridden).
    pub fn load_spec(&self, state: &str, id: &str) -> Result<JobSpec> {
        let path = self.spec_path(state, id);
        let mut spec = JobSpec::from_json(&Json::from_file(&path)?)
            .with_context(|| format!("job spec {}", path.display()))?;
        spec.id = id.to_string();
        Ok(spec)
    }

    /// Claim the next queued job by renaming its spec into `running/`.
    /// Candidates are tried in (priority desc, id asc) order — the spec
    /// is re-read under `running/` after the rename, so a priority edit
    /// racing the claim can at worst reorder, never corrupt. Rename is
    /// atomic, so under concurrent schedulers each spec is won by exactly
    /// one caller; losing a race just moves on to the next candidate.
    /// Returns `None` when the queue is empty.
    pub fn claim_next(&self) -> Result<Option<JobSpec>> {
        loop {
            // Order the snapshot by (priority desc, id asc). A spec that
            // vanishes (claimed elsewhere) or fails to parse sorts at
            // priority 0; the parse error resurfaces on claim and the
            // spec is quarantined below. This parses every queued spec
            // per claim — O(queue) per poll, fine for the tens-of-jobs
            // spools this serves; cache (mtime -> priority) here if
            // spools ever grow to thousands of queued specs.
            let mut candidates: Vec<(i64, String)> = Vec::new();
            for id in self.jobs_in("queue")? {
                let priority =
                    self.load_spec("queue", &id).map(|s| s.priority).unwrap_or(0);
                candidates.push((priority, id));
            }
            candidates.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
            let mut claimed = None;
            for (_, id) in candidates {
                let from = self.spec_path("queue", &id);
                let to = self.spec_path("running", &id);
                match std::fs::rename(&from, &to) {
                    Ok(()) => {
                        claimed = Some(id);
                        break;
                    }
                    // another worker won this spec; try the next one
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                    Err(e) => {
                        return Err(e).with_context(|| format!("claiming job {id}"));
                    }
                }
            }
            let Some(id) = claimed else { return Ok(None) };
            match self.load_spec("running", &id) {
                Ok(spec) => return Ok(Some(spec)),
                Err(e) => {
                    // Quarantine unreadable specs instead of wedging the
                    // worker; the parse error lands in the log.
                    log::error!("job {id}: unreadable spec ({e:#}); moving to failed/");
                    let _ = self.finish(&id, false);
                }
            }
        }
    }

    /// Tombstone a queued job: one atomic rename into `cancelled/`, so a
    /// cancel racing a scheduler claim is won by exactly one side. Only
    /// queued jobs can be cancelled; anything else reports where the job
    /// actually is.
    pub fn cancel(&self, id: &str) -> Result<()> {
        let from = self.spec_path("queue", id);
        let to = self.spec_path("cancelled", id);
        match std::fs::rename(&from, &to) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                for state in ["running", "done", "failed", "cancelled"] {
                    if self.spec_path(state, id).exists() {
                        bail!("job '{id}' is in {state}/ — only queued jobs can be cancelled");
                    }
                }
                bail!("no queued job '{id}' in this spool")
            }
            Err(e) => Err(e).with_context(|| format!("cancelling job {id}")),
        }
    }

    /// Move a running job to its terminal state.
    pub fn finish(&self, id: &str, ok: bool) -> Result<()> {
        let from = self.spec_path("running", id);
        let to = self.spec_path(if ok { "done" } else { "failed" }, id);
        std::fs::rename(&from, &to).with_context(|| format!("finishing job {id}"))?;
        Ok(())
    }

    /// Sweep `running/` back into `queue/` — called once at scheduler
    /// startup, when anything still "running" is a crash leftover. The
    /// re-queued jobs resume from their latest checkpoint when claimed.
    pub fn recover_interrupted(&self) -> Result<Vec<String>> {
        let mut recovered = Vec::new();
        for id in self.jobs_in("running")? {
            let from = self.spec_path("running", &id);
            let to = self.spec_path("queue", &id);
            match std::fs::rename(&from, &to) {
                Ok(()) => recovered.push(id),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => {
                    return Err(e).with_context(|| format!("recovering job {id}"));
                }
            }
        }
        Ok(recovered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, TaskKind};

    fn tmp_spool(tag: &str) -> (PathBuf, Spool) {
        let root =
            std::env::temp_dir().join(format!("mlorc_spool_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let spool = Spool::open(&root).unwrap();
        (root, spool)
    }

    fn spec(id: &str) -> JobSpec {
        spec_pri(id, 0)
    }

    fn spec_pri(id: &str, priority: i64) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            engine: Engine::Host,
            checkpoint_every: 5,
            priority,
            cfg: RunConfig::new("host-nano", Method::MlorcAdamW, TaskKind::MathChain, 20),
        }
    }

    #[test]
    fn submit_claim_finish_lifecycle() {
        let (root, spool) = tmp_spool("life");
        spool.submit(&spec("job001_a")).unwrap();
        spool.submit(&spec("job002_b")).unwrap();
        // duplicate ids are rejected
        assert!(spool.submit(&spec("job001_a")).is_err());
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_a", "job002_b"]);

        // claims come in sorted order and move the spec to running/
        let first = spool.claim_next().unwrap().unwrap();
        assert_eq!(first.id, "job001_a");
        assert_eq!(first.engine, Engine::Host);
        assert_eq!(spool.jobs_in("running").unwrap(), vec!["job001_a"]);

        spool.finish("job001_a", true).unwrap();
        assert_eq!(spool.jobs_in("done").unwrap(), vec!["job001_a"]);

        let second = spool.claim_next().unwrap().unwrap();
        spool.finish(&second.id, false).unwrap();
        assert_eq!(spool.jobs_in("failed").unwrap(), vec!["job002_b"]);
        assert!(spool.claim_next().unwrap().is_none());

        // a finished id cannot be resubmitted
        assert!(spool.submit(&spec("job002_b")).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn recover_moves_running_back_to_queue() {
        let (root, spool) = tmp_spool("recover");
        spool.submit(&spec("job001_x")).unwrap();
        let _ = spool.claim_next().unwrap().unwrap();
        assert!(spool.jobs_in("queue").unwrap().is_empty());
        // simulate a crash: the running spec is still there on "restart"
        let recovered = spool.recover_interrupted().unwrap();
        assert_eq!(recovered, vec!["job001_x"]);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_x"]);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn next_job_id_scans_all_lifecycle_dirs() {
        let (root, spool) = tmp_spool("ids");
        assert_eq!(spool.next_job_id("mlorc_adamw").unwrap(), "job001_mlorc_adamw");
        spool.submit(&spec("job004_z")).unwrap();
        let _ = spool.claim_next().unwrap();
        spool.finish("job004_z", true).unwrap();
        assert_eq!(spool.next_job_id("lion").unwrap(), "job005_lion");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn claim_order_is_priority_then_id() {
        let (root, spool) = tmp_spool("prio");
        spool.submit(&spec_pri("job001_low", -1)).unwrap();
        spool.submit(&spec_pri("job002_default", 0)).unwrap();
        spool.submit(&spec_pri("job003_urgent", 7)).unwrap();
        spool.submit(&spec_pri("job004_urgent_too", 7)).unwrap();
        let order: Vec<String> = (0..4)
            .map(|_| spool.claim_next().unwrap().unwrap().id)
            .collect();
        // highest priority first; equal priorities fall back to id order
        assert_eq!(
            order,
            vec!["job003_urgent", "job004_urgent_too", "job002_default", "job001_low"]
        );
        assert!(spool.claim_next().unwrap().is_none());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn cancel_tombstones_queued_jobs_only() {
        let (root, spool) = tmp_spool("cancel");
        spool.submit(&spec("job001_a")).unwrap();
        spool.submit(&spec("job002_b")).unwrap();
        spool.cancel("job001_a").unwrap();
        assert_eq!(spool.jobs_in("cancelled").unwrap(), vec!["job001_a"]);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job002_b"]);
        // a cancelled job is never claimed
        let claimed = spool.claim_next().unwrap().unwrap();
        assert_eq!(claimed.id, "job002_b");
        assert!(spool.claim_next().unwrap().is_none());
        // cannot cancel running/missing/already-cancelled jobs
        let err = spool.cancel("job002_b").unwrap_err();
        assert!(format!("{err:#}").contains("running"), "{err:#}");
        assert!(spool.cancel("job009_nope").is_err());
        let err = spool.cancel("job001_a").unwrap_err();
        assert!(format!("{err:#}").contains("cancelled"), "{err:#}");
        // a cancelled id stays burned (no resubmission)
        assert!(spool.submit(&spec("job001_a")).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn spec_json_roundtrip_and_bad_ids() {
        let s = spec_pri("job007_rt", 3);
        let back = JobSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back.id, s.id);
        assert_eq!(back.engine, s.engine);
        assert_eq!(back.checkpoint_every, 5);
        assert_eq!(back.priority, 3);
        assert_eq!(back.cfg.method, s.cfg.method);
        assert!(Engine::parse("tpu").is_err());

        // specs submitted before priorities existed default to 0
        let mut j = spec("job008_old").to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("priority");
        }
        assert_eq!(JobSpec::from_json(&j).unwrap().priority, 0);

        let (root, spool) = tmp_spool("badid");
        assert!(spool.submit(&spec("../escape")).is_err());
        assert!(spool.submit(&spec("a/b")).is_err());
        assert!(spool.submit(&spec("")).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
