//! File-backed job spool with atomic claim-by-rename.
//!
//! Layout under the spool root:
//!
//! ```text
//! queue/<id>.json      submitted, unclaimed
//! running/<id>.json    claimed by a scheduler worker (renamed from queue/)
//! done/<id>.json       finished successfully
//! failed/<id>.json     finished with an error (status/<id>.json has why)
//! cancelled/<id>.json  tombstoned while queued (`mlorc cancel`)
//! status/<id>.json     latest per-job progress (serve::status)
//! leases/<id>.json     owner + heartbeat of the worker running the job
//! work/<id>/           job scratch: rotated v2 checkpoints, metrics
//! events/<sched>.jsonl per-scheduler append-only event journal (obs)
//! metrics/<sched>.json per-scheduler metrics snapshot (obs)
//! ```
//!
//! Lifecycle is `queued -> running -> done|failed`, with a side exit
//! `queued -> cancelled` and a retry edge `running -> queue` (attempt
//! history + exponential backoff recorded in the spec). Claims and
//! cancellations are each a single `rename(2)`: exactly one scheduler
//! worker (or canceller) wins a given spec file, which is the entire
//! concurrency story — no locks, no daemon, no registry. Claim order is
//! (priority desc, id asc), so late-submitted urgent jobs overtake the
//! backlog.
//!
//! Deployment note: any number of submitters, status readers *and
//! schedulers* can share one spool. In lease mode (timeout > 0) each
//! claim is backed by a lease (`leases/<id>.json`, heartbeat-refreshed
//! by the worker), and the recovery sweep
//! ([`Spool::recover_interrupted`]) only re-queues a running job once
//! both its lease heartbeat and its claim rename are older than the
//! lease timeout (plus a deterministic per-id jitter) — so a crashed
//! scheduler's jobs are stolen after the timeout, while a live peer's
//! jobs are left alone. In legacy single-scheduler mode (timeout 0)
//! claims write no lease at all, and the startup sweep re-queues every
//! running job immediately — crash recovery needs no timeout to elapse.
//! The re-queued job resumes from its latest intact v2 checkpoint under
//! `work/<id>/ckpt/` when re-claimed.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::util::fsutil;
use crate::util::json::Json;

/// The lifecycle directories, in pipeline order.
pub const LIFECYCLE_DIRS: [&str; 5] = ["queue", "running", "done", "failed", "cancelled"];

/// Which trainer executes a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Synthetic least-squares fine-tuning entirely on the host
    /// (`serve::HostTrainer`) — no artifacts required.
    Host,
    /// The real graph trainer (`coordinator::Trainer`) — needs `make
    /// artifacts` and a `pjrt`-enabled build.
    Graph,
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Host => "host",
            Engine::Graph => "graph",
        }
    }

    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "host" => Engine::Host,
            "graph" => Engine::Graph,
            _ => bail!("unknown engine '{s}' (host | graph)"),
        })
    }
}

/// One failed run of a job, recorded in its spec when the scheduler
/// re-queues it for retry (or quarantines it to `failed/`).
#[derive(Debug, Clone)]
pub struct Attempt {
    pub at_unix_ms: u64,
    pub error: String,
    /// Backoff applied after this failure (0 for the terminal one).
    pub backoff_ms: u64,
}

impl Attempt {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_unix_ms", Json::num(self.at_unix_ms as f64)),
            ("error", Json::str(self.error.clone())),
            ("backoff_ms", Json::num(self.backoff_ms as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Attempt> {
        Ok(Attempt {
            at_unix_ms: j.req("at_unix_ms")?.as_usize()? as u64,
            error: j.req("error")?.as_str()?.to_string(),
            backoff_ms: j.req("backoff_ms")?.as_usize()? as u64,
        })
    }
}

/// One queued fine-tuning run: a `RunConfig` plus serve-level knobs.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: String,
    pub engine: Engine,
    /// Checkpoint cadence in steps (0 = final snapshot only).
    pub checkpoint_every: usize,
    /// Claim priority: higher claims first; ties break by id (ascending).
    /// 0 is the default for jobs that don't care. Stored as a JSON number
    /// (f64), so values are clamped to the exactly-representable integer
    /// range (±2^53) on both serialize and parse — a spec always
    /// roundtrips to the priority the claim order actually uses.
    pub priority: i64,
    /// Failed-run history, oldest first ([`Spool::requeue_failed`]).
    pub attempts: Vec<Attempt>,
    /// Retry backoff gate: the spec is not claimable before this time
    /// (ms since epoch; 0 = no gate).
    pub not_before_unix_ms: u64,
    pub cfg: RunConfig,
}

/// Largest priority magnitude that survives the JSON f64 encoding exactly.
const PRIORITY_CLAMP: i64 = 1 << 53;

impl JobSpec {
    pub fn to_json(&self) -> Json {
        let priority = self.priority.clamp(-PRIORITY_CLAMP, PRIORITY_CLAMP);
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("engine", Json::str(self.engine.name())),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("priority", Json::num(priority as f64)),
            ("attempts", Json::arr(self.attempts.iter().map(Attempt::to_json))),
            ("not_before_unix_ms", Json::num(self.not_before_unix_ms as f64)),
            ("config", self.cfg.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobSpec> {
        Ok(JobSpec {
            id: j.req("id")?.as_str()?.to_string(),
            engine: Engine::parse(j.req("engine")?.as_str()?)?,
            checkpoint_every: j.req("checkpoint_every")?.as_usize()?,
            // optional for specs submitted before priorities existed
            priority: match j.get("priority") {
                Some(v) => (v.as_f64()?.clamp(-(PRIORITY_CLAMP as f64), PRIORITY_CLAMP as f64))
                    as i64,
                None => 0,
            },
            // both optional: specs submitted before retries existed
            attempts: match j.get("attempts") {
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(Attempt::from_json)
                    .collect::<Result<Vec<_>>>()?,
                None => Vec::new(),
            },
            not_before_unix_ms: match j.get("not_before_unix_ms") {
                Some(v) => v.as_usize()? as u64,
                None => 0,
            },
            cfg: RunConfig::from_json(j.req("config")?)?,
        })
    }
}

/// Ownership record for a running job: which scheduler worker holds it
/// and when it last proved it was alive.
#[derive(Debug, Clone)]
pub struct Lease {
    pub owner: String,
    pub heartbeat_unix_ms: u64,
    pub timeout_ms: u64,
}

/// Handle on a spool directory. Cheap to open; all state is on disk, so
/// any number of submitters/schedulers/status readers can share one.
pub struct Spool {
    root: PathBuf,
}

impl Spool {
    /// Open (creating if needed) a spool rooted at `root`.
    pub fn open(root: &Path) -> Result<Spool> {
        for d in [
            "queue", "running", "done", "failed", "cancelled", "status", "leases", "work",
            "events", "metrics",
        ] {
            let p = root.join(d);
            std::fs::create_dir_all(&p)
                .with_context(|| format!("creating spool dir {}", p.display()))?;
        }
        Ok(Spool { root: root.to_path_buf() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn dir(&self, which: &str) -> PathBuf {
        self.root.join(which)
    }

    fn spec_path(&self, state: &str, id: &str) -> PathBuf {
        self.dir(state).join(format!("{id}.json"))
    }

    /// Per-job scratch directory (checkpoints, metrics).
    pub fn work_dir(&self, id: &str) -> PathBuf {
        self.dir("work").join(id)
    }

    /// Rotated v2 checkpoint root for a job.
    pub fn checkpoint_root(&self, id: &str) -> PathBuf {
        self.work_dir(id).join("ckpt")
    }

    pub fn status_path(&self, id: &str) -> PathBuf {
        self.dir("status").join(format!("{id}.json"))
    }

    /// Per-scheduler JSONL event journals (`events/<scheduler-id>.jsonl`).
    pub fn events_dir(&self) -> PathBuf {
        self.dir("events")
    }

    /// Per-scheduler metrics snapshots (`metrics/<scheduler-id>.json`),
    /// merged fleet-wide by `mlorc top`.
    pub fn metrics_dir(&self) -> PathBuf {
        self.dir("metrics")
    }

    /// This scheduler's atomic metrics snapshot file.
    pub fn metrics_path(&self, owner: &str) -> PathBuf {
        self.metrics_dir().join(format!("{owner}.json"))
    }

    fn lease_path(&self, id: &str) -> PathBuf {
        self.dir("leases").join(format!("{id}.json"))
    }

    /// Write (or heartbeat-refresh) the lease for a running job.
    pub fn write_lease(&self, id: &str, owner: &str, timeout_ms: u64) -> Result<()> {
        let j = Json::obj(vec![
            ("owner", Json::str(owner)),
            ("heartbeat_unix_ms", Json::num(fsutil::unix_ms() as f64)),
            ("timeout_ms", Json::num(timeout_ms as f64)),
        ]);
        fsutil::write_atomic_site(
            &self.lease_path(id),
            j.to_string_pretty().as_bytes(),
            "lease_write",
        )
    }

    /// Read a job's lease; `None` when absent or unreadable (an
    /// unreadable lease counts as no lease — recovery treats the job as
    /// unowned once its claim is old enough).
    pub fn read_lease(&self, id: &str) -> Option<Lease> {
        let j = Json::from_file(&self.lease_path(id)).ok()?;
        Some(Lease {
            owner: j.req("owner").ok()?.as_str().ok()?.to_string(),
            heartbeat_unix_ms: j.req("heartbeat_unix_ms").ok()?.as_usize().ok()? as u64,
            timeout_ms: j.req("timeout_ms").ok()?.as_usize().ok()? as u64,
        })
    }

    /// True when `owner` may still act on the running job: either it
    /// holds the lease, or there is no lease to hold (legacy mode, or a
    /// claim whose lease write failed).
    pub fn owns_lease(&self, id: &str, owner: &str) -> bool {
        self.read_lease(id).is_none_or(|l| l.owner == owner)
    }

    fn remove_lease(&self, id: &str) {
        let _ = std::fs::remove_file(self.lease_path(id));
    }

    /// Enqueue a job. Fails if any lifecycle dir already holds the id.
    pub fn submit(&self, spec: &JobSpec) -> Result<PathBuf> {
        if spec.id.is_empty()
            || spec.id.chars().any(|c| c == '/' || c == '\\')
            || spec.id.contains("..")
        {
            bail!("job id '{}' must be a plain file name", spec.id);
        }
        for state in LIFECYCLE_DIRS {
            if self.spec_path(state, &spec.id).exists() {
                bail!("job '{}' already exists in {state}/", spec.id);
            }
        }
        let path = self.spec_path("queue", &spec.id);
        fsutil::write_atomic(&path, spec.to_json().to_string_pretty().as_bytes())?;
        Ok(path)
    }

    /// A fresh sequential id `jobNNN_<suffix>` (scans every lifecycle dir
    /// so ids never collide with finished jobs).
    pub fn next_job_id(&self, suffix: &str) -> Result<String> {
        let mut max = 0usize;
        for state in LIFECYCLE_DIRS {
            for id in self.jobs_in(state)? {
                if let Some(rest) = id.strip_prefix("job") {
                    let digits: String =
                        rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                    if let Ok(n) = digits.parse::<usize>() {
                        max = max.max(n);
                    }
                }
            }
        }
        Ok(format!("job{:03}_{suffix}", max + 1))
    }

    /// Sorted job ids currently in a lifecycle dir.
    pub fn jobs_in(&self, state: &str) -> Result<Vec<String>> {
        let dir = self.dir(state);
        let entries =
            std::fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?;
        let mut ids = Vec::new();
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(stem) = name.strip_suffix(".json") {
                ids.push(stem.to_string());
            }
        }
        ids.sort();
        Ok(ids)
    }

    /// Load a job spec from a lifecycle dir (the file name is the id of
    /// record; a drifted `id` field inside the file is overridden).
    pub fn load_spec(&self, state: &str, id: &str) -> Result<JobSpec> {
        let path = self.spec_path(state, id);
        let mut spec = JobSpec::from_json(&Json::from_file(&path)?)
            .with_context(|| format!("job spec {}", path.display()))?;
        spec.id = id.to_string();
        Ok(spec)
    }

    /// Claim the next queued job by renaming its spec into `running/`.
    /// Candidates are tried in (priority desc, id asc) order — the spec
    /// is re-read under `running/` after the rename, so a priority edit
    /// racing the claim can at worst reorder, never corrupt. Rename is
    /// atomic, so under concurrent schedulers each spec is won by exactly
    /// one caller; losing a race just moves on to the next candidate.
    /// Returns `None` when the queue is empty (or holds only jobs still
    /// inside their retry backoff window).
    pub fn claim_next(&self) -> Result<Option<JobSpec>> {
        self.claim_next_as(None, 0)
    }

    /// [`Spool::claim_next`] with lease bookkeeping: when `owner` is
    /// given and `lease_timeout_ms > 0`, the winning claim writes
    /// `leases/<id>.json` so concurrent schedulers' recovery sweeps
    /// leave this job alone until the lease expires. With a zero
    /// timeout (legacy single-scheduler mode) no lease is written —
    /// claims carry no liveness promise, and the startup sweep
    /// re-queues crash leftovers unconditionally.
    pub fn claim_next_as(
        &self,
        owner: Option<&str>,
        lease_timeout_ms: u64,
    ) -> Result<Option<JobSpec>> {
        loop {
            // Order the snapshot by (priority desc, id asc). A spec that
            // vanishes (claimed elsewhere) or fails to parse sorts at
            // priority 0; the parse error resurfaces on claim and the
            // spec is quarantined below. Specs still inside their retry
            // backoff window are skipped. This parses every queued spec
            // per claim — O(queue) per poll, fine for the tens-of-jobs
            // spools this serves; cache (mtime -> priority) here if
            // spools ever grow to thousands of queued specs.
            let now = fsutil::unix_ms();
            let mut candidates: Vec<(i64, String)> = Vec::new();
            for id in self.jobs_in("queue")? {
                match self.load_spec("queue", &id) {
                    Ok(s) if s.not_before_unix_ms > now => continue,
                    Ok(s) => candidates.push((s.priority, id)),
                    Err(_) => candidates.push((0, id)),
                }
            }
            candidates.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
            let mut claimed = None;
            for (_, id) in candidates {
                let from = self.spec_path("queue", &id);
                let to = self.spec_path("running", &id);
                fsutil::failpoint("spool_rename")?;
                match std::fs::rename(&from, &to) {
                    Ok(()) => {
                        // rename(2) does not update mtime, so on targets
                        // without ctime the claim-age fallback would see
                        // the submit-time stamp; rewrite the spec in
                        // place (we exclusively own it post-rename) so
                        // the stamp marks the claim
                        #[cfg(not(unix))]
                        if let Ok(bytes) = std::fs::read(&to) {
                            let _ = std::fs::write(&to, bytes);
                        }
                        claimed = Some(id);
                        break;
                    }
                    // another worker won this spec; try the next one
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                    Err(e) => {
                        return Err(e).with_context(|| format!("claiming job {id}"));
                    }
                }
            }
            let Some(id) = claimed else { return Ok(None) };
            // Legacy single-scheduler mode (timeout 0) must not write a
            // lease: `recover_interrupted(0)` skips leased jobs, so a
            // lease surviving a kill -9 would hold the job hostage
            // forever. The timeout-0 sweep runs at startup only, before
            // any claim, so the lease-less window is safe.
            if lease_timeout_ms > 0 {
                if let Some(owner) = owner {
                    // the claim rename's ctime shields the job from
                    // recovery until the lease lands, so a failed write
                    // only narrows the protection window rather than
                    // losing the claim
                    if let Err(e) = self.write_lease(&id, owner, lease_timeout_ms) {
                        log::warn!("job {id}: could not write lease ({e:#})");
                    }
                }
            }
            match self.load_spec("running", &id) {
                Ok(spec) => return Ok(Some(spec)),
                Err(e) => {
                    // Quarantine unreadable specs instead of wedging the
                    // worker; the parse error lands in the log.
                    log::error!("job {id}: unreadable spec ({e:#}); moving to failed/");
                    let _ = self.finish(&id, false);
                }
            }
        }
    }

    /// Tombstone a queued job: one atomic rename into `cancelled/`, so a
    /// cancel racing a scheduler claim is won by exactly one side. Only
    /// queued jobs can be cancelled; anything else reports where the job
    /// actually is.
    pub fn cancel(&self, id: &str) -> Result<()> {
        let from = self.spec_path("queue", id);
        let to = self.spec_path("cancelled", id);
        match std::fs::rename(&from, &to) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                for state in ["running", "done", "failed", "cancelled"] {
                    if self.spec_path(state, id).exists() {
                        bail!("job '{id}' is in {state}/ — only queued jobs can be cancelled");
                    }
                }
                bail!("no queued job '{id}' in this spool")
            }
            Err(e) => Err(e).with_context(|| format!("cancelling job {id}")),
        }
    }

    /// Verify that a running job's lease, if present, is held by
    /// `owner`. A worker whose job was stolen after lease expiry (e.g.
    /// one step outlived the timeout) must not complete, re-queue or
    /// quarantine the new claimant's in-flight spec. A missing lease
    /// passes: legacy timeout-0 mode writes none, and a claim whose
    /// lease write failed still owns its rename.
    fn check_lease_owner(&self, id: &str, owner: Option<&str>) -> Result<()> {
        let Some(owner) = owner else { return Ok(()) };
        if let Some(lease) = self.read_lease(id) {
            if lease.owner != owner {
                bail!(
                    "job {id}: lease is held by {} (this worker is {owner}); \
                     the job was stolen after lease expiry — refusing to move it",
                    lease.owner
                );
            }
        }
        Ok(())
    }

    /// Move a running job to its terminal state (no ownership check —
    /// single-scheduler callers and the unreadable-spec quarantine).
    pub fn finish(&self, id: &str, ok: bool) -> Result<()> {
        self.finish_as(id, ok, None)
    }

    /// [`Spool::finish`] verifying first that `owner` (when given) still
    /// holds the job's lease, so a stale owner cannot rename a stolen
    /// job out from under its new claimant.
    pub fn finish_as(&self, id: &str, ok: bool, owner: Option<&str>) -> Result<()> {
        self.check_lease_owner(id, owner)?;
        let from = self.spec_path("running", id);
        let to = self.spec_path(if ok { "done" } else { "failed" }, id);
        fsutil::failpoint("spool_rename")?;
        std::fs::rename(&from, &to).with_context(|| format!("finishing job {id}"))?;
        self.remove_lease(id);
        Ok(())
    }

    /// Re-queue a failed running job for retry: its spec gains an
    /// [`Attempt`] record and a `not_before` backoff gate, then moves
    /// `running/ -> queue/`. When `owner` is given the caller must still
    /// hold the job's lease. Returns the updated spec (for status).
    pub fn requeue_failed(
        &self,
        spec: &JobSpec,
        error: &str,
        backoff_ms: u64,
        owner: Option<&str>,
    ) -> Result<JobSpec> {
        self.check_lease_owner(&spec.id, owner)?;
        let now = fsutil::unix_ms();
        let mut updated = spec.clone();
        updated
            .attempts
            .push(Attempt { at_unix_ms: now, error: error.to_string(), backoff_ms });
        updated.not_before_unix_ms = now + backoff_ms;
        let from = self.spec_path("running", &spec.id);
        fsutil::write_atomic(&from, updated.to_json().to_string_pretty().as_bytes())?;
        fsutil::failpoint("spool_rename")?;
        std::fs::rename(&from, self.spec_path("queue", &spec.id))
            .with_context(|| format!("re-queueing job {}", spec.id))?;
        self.remove_lease(&spec.id);
        Ok(updated)
    }

    /// Quarantine a running job whose retry budget is exhausted: the
    /// final [`Attempt`] is recorded and the spec moves to `failed/`
    /// with its full attempt history. When `owner` is given the caller
    /// must still hold the job's lease. Returns the updated spec.
    pub fn fail_terminal(
        &self,
        spec: &JobSpec,
        error: &str,
        owner: Option<&str>,
    ) -> Result<JobSpec> {
        self.check_lease_owner(&spec.id, owner)?;
        let mut updated = spec.clone();
        updated.attempts.push(Attempt {
            at_unix_ms: fsutil::unix_ms(),
            error: error.to_string(),
            backoff_ms: 0,
        });
        updated.not_before_unix_ms = 0;
        let from = self.spec_path("running", &spec.id);
        fsutil::write_atomic(&from, updated.to_json().to_string_pretty().as_bytes())?;
        fsutil::failpoint("spool_rename")?;
        std::fs::rename(&from, self.spec_path("failed", &spec.id))
            .with_context(|| format!("quarantining job {}", spec.id))?;
        self.remove_lease(&spec.id);
        Ok(updated)
    }

    /// Age of a running job's claim (the `queue/ -> running/` rename),
    /// from the spec file's change time — on non-unix targets, from its
    /// modified time, which [`Spool::claim_next_as`] refreshes at claim
    /// time because rename(2) leaves mtime untouched. This shields a
    /// freshly claimed job from recovery even before its lease file
    /// lands.
    fn claim_age_ms(&self, id: &str, now: u64) -> u64 {
        let path = self.spec_path("running", id);
        let Ok(meta) = std::fs::metadata(&path) else {
            return u64::MAX; // vanished: the recovery rename will no-op
        };
        #[cfg(unix)]
        let stamp_ms = {
            use std::os::unix::fs::MetadataExt;
            (meta.ctime().max(0) as u64) * 1000 + (meta.ctime_nsec().max(0) as u64) / 1_000_000
        };
        #[cfg(not(unix))]
        let stamp_ms = meta
            .modified()
            .ok()
            .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        now.saturating_sub(stamp_ms)
    }

    /// Sweep expired `running/` jobs back into `queue/`. With
    /// `lease_timeout_ms == 0` this is the legacy single-scheduler
    /// startup sweep: every running job is a crash leftover and is
    /// re-queued immediately — timeout-0 claims write no lease, and a
    /// stale lease whose own `timeout_ms` is 0 never promised liveness,
    /// so only a lease with a real timeout (a live lease-mode peer
    /// sharing the spool) protects a job from this sweep. With a
    /// timeout, a job is only recovered once both its lease heartbeat
    /// and its claim rename are older than the timeout plus a
    /// deterministic per-id jitter — safe to call from concurrent
    /// schedulers mid-drain. Re-queued jobs resume from their latest
    /// intact checkpoint when re-claimed.
    pub fn recover_interrupted(&self, lease_timeout_ms: u64) -> Result<Vec<String>> {
        let now = fsutil::unix_ms();
        let mut recovered = Vec::new();
        for id in self.jobs_in("running")? {
            let lease = self.read_lease(&id);
            if lease_timeout_ms == 0 {
                if lease.as_ref().is_some_and(|l| l.timeout_ms > 0) {
                    continue;
                }
            } else {
                let expiry = lease_timeout_ms + lease_jitter(&id, lease_timeout_ms);
                let hb_age = match &lease {
                    Some(l) => now.saturating_sub(l.heartbeat_unix_ms),
                    None => u64::MAX,
                };
                if hb_age.min(self.claim_age_ms(&id, now)) <= expiry {
                    continue;
                }
            }
            let from = self.spec_path("running", &id);
            let to = self.spec_path("queue", &id);
            fsutil::failpoint("spool_rename")?;
            match std::fs::rename(&from, &to) {
                Ok(()) => {
                    self.remove_lease(&id);
                    recovered.push(id);
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => {
                    return Err(e).with_context(|| format!("recovering job {id}"));
                }
            }
        }
        Ok(recovered)
    }

    /// Append one line to `work/<id>/claims.log` — the exactly-once
    /// audit trail the multi-scheduler tests assert on.
    pub fn note_claim(&self, id: &str, owner: &str, attempt: usize) -> Result<()> {
        use std::io::Write;
        let dir = self.work_dir(id);
        std::fs::create_dir_all(&dir)?;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join("claims.log"))?;
        writeln!(f, "{} {owner} attempt={attempt}", fsutil::unix_ms())?;
        Ok(())
    }

    /// `work/<id>/` directories whose id no longer exists in any
    /// lifecycle dir — scratch left behind by quarantined unreadable
    /// specs (or manual deletion). `mlorc fsck --repair` reaps these.
    pub fn orphan_work_dirs(&self) -> Result<Vec<String>> {
        let dir = self.dir("work");
        let entries =
            std::fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?;
        let mut orphans = Vec::new();
        for entry in entries.flatten() {
            let Ok(name) = entry.file_name().into_string() else { continue };
            if LIFECYCLE_DIRS.iter().all(|state| !self.spec_path(state, &name).exists()) {
                orphans.push(name);
            }
        }
        orphans.sort();
        Ok(orphans)
    }
}

/// Deterministic per-id recovery jitter, between an eighth and ~three
/// eighths of the timeout: keeps a pack of schedulers from stampeding
/// the same expired jobs at the same instant. The floor matters as much
/// as the spread — a zero jitter would let a sweep steal a job the
/// moment its heartbeat is exactly one timeout old, leaving no headroom
/// for a heartbeat that is merely late rather than dead.
fn lease_jitter(id: &str, timeout_ms: u64) -> u64 {
    timeout_ms / 8 + 1 + fsutil::fnv1a64(id.as_bytes()) % (timeout_ms / 4 + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, TaskKind};

    fn tmp_spool(tag: &str) -> (PathBuf, Spool) {
        let root =
            std::env::temp_dir().join(format!("mlorc_spool_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let spool = Spool::open(&root).unwrap();
        (root, spool)
    }

    fn spec(id: &str) -> JobSpec {
        spec_pri(id, 0)
    }

    fn spec_pri(id: &str, priority: i64) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            engine: Engine::Host,
            checkpoint_every: 5,
            priority,
            attempts: Vec::new(),
            not_before_unix_ms: 0,
            cfg: RunConfig::new("host-nano", Method::MlorcAdamW, TaskKind::MathChain, 20),
        }
    }

    #[test]
    fn submit_claim_finish_lifecycle() {
        let (root, spool) = tmp_spool("life");
        spool.submit(&spec("job001_a")).unwrap();
        spool.submit(&spec("job002_b")).unwrap();
        // duplicate ids are rejected
        assert!(spool.submit(&spec("job001_a")).is_err());
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_a", "job002_b"]);

        // claims come in sorted order and move the spec to running/
        let first = spool.claim_next().unwrap().unwrap();
        assert_eq!(first.id, "job001_a");
        assert_eq!(first.engine, Engine::Host);
        assert_eq!(spool.jobs_in("running").unwrap(), vec!["job001_a"]);

        spool.finish("job001_a", true).unwrap();
        assert_eq!(spool.jobs_in("done").unwrap(), vec!["job001_a"]);

        let second = spool.claim_next().unwrap().unwrap();
        spool.finish(&second.id, false).unwrap();
        assert_eq!(spool.jobs_in("failed").unwrap(), vec!["job002_b"]);
        assert!(spool.claim_next().unwrap().is_none());

        // a finished id cannot be resubmitted
        assert!(spool.submit(&spec("job002_b")).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn recover_moves_running_back_to_queue() {
        let (root, spool) = tmp_spool("recover");
        spool.submit(&spec("job001_x")).unwrap();
        let _ = spool.claim_next().unwrap().unwrap();
        assert!(spool.jobs_in("queue").unwrap().is_empty());
        // simulate a crash: the running spec is still there on "restart"
        let recovered = spool.recover_interrupted(0).unwrap();
        assert_eq!(recovered, vec!["job001_x"]);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_x"]);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn leases_gate_recovery() {
        let (root, spool) = tmp_spool("lease");
        spool.submit(&spec("job001_leased")).unwrap();
        let claimed = spool.claim_next_as(Some("sched-A"), 50).unwrap().unwrap();
        assert_eq!(claimed.id, "job001_leased");
        let lease = spool.read_lease("job001_leased").unwrap();
        assert_eq!(lease.owner, "sched-A");
        assert_eq!(lease.timeout_ms, 50);

        // a live-mode lease (timeout > 0) shields the job from the
        // legacy startup sweep of a peer running at timeout 0...
        assert!(spool.recover_interrupted(0).unwrap().is_empty());
        // ...and to a timed sweep while the heartbeat is fresh
        assert!(spool.recover_interrupted(50).unwrap().is_empty());
        assert_eq!(spool.jobs_in("running").unwrap(), vec!["job001_leased"]);

        // once the heartbeat AND the claim are stale past
        // timeout + jitter (jitter < timeout/2), the job is stolen
        std::thread::sleep(std::time::Duration::from_millis(200));
        let recovered = spool.recover_interrupted(50).unwrap();
        assert_eq!(recovered, vec!["job001_leased"]);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_leased"]);
        assert!(spool.read_lease("job001_leased").is_none(), "recovery must drop the lease");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn legacy_claims_write_no_lease_and_recover_unconditionally() {
        let (root, spool) = tmp_spool("legacy");
        spool.submit(&spec("job001_legacy")).unwrap();
        // timeout 0: the claim must NOT write a lease — a lease
        // surviving a kill -9 would make the startup sweep skip the job
        // forever (there is no expiry at timeout 0)
        let claimed = spool.claim_next_as(Some("sched-A"), 0).unwrap().unwrap();
        assert_eq!(claimed.id, "job001_legacy");
        assert!(spool.read_lease("job001_legacy").is_none(), "timeout-0 claim wrote a lease");
        // "crash": restart sweeps the job back immediately
        assert_eq!(spool.recover_interrupted(0).unwrap(), vec!["job001_legacy"]);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_legacy"]);

        // a stale timeout-0 lease left behind by an older build never
        // promised liveness: the legacy sweep ignores it and drops it
        let again = spool.claim_next_as(Some("sched-A"), 0).unwrap().unwrap();
        spool.write_lease(&again.id, "sched-A", 0).unwrap();
        assert_eq!(spool.recover_interrupted(0).unwrap(), vec!["job001_legacy"]);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_legacy"]);
        assert!(spool.read_lease("job001_legacy").is_none(), "sweep must drop the stale lease");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn stale_owner_cannot_move_a_stolen_job() {
        let (root, spool) = tmp_spool("stolen");
        spool.submit(&spec("job001_hot")).unwrap();
        let claimed = spool.claim_next_as(Some("sched-A/w0"), 50).unwrap().unwrap();
        // simulate the steal: A's lease expired, a peer re-queued and
        // re-claimed the job — running/ now holds B's in-flight spec
        spool.write_lease(&claimed.id, "sched-B/w1", 50).unwrap();

        // the stale owner must not complete, retry or quarantine it
        let err = spool.finish_as(&claimed.id, true, Some("sched-A/w0")).unwrap_err();
        assert!(format!("{err:#}").contains("sched-B/w1"), "{err:#}");
        assert!(spool.requeue_failed(&claimed, "boom", 10, Some("sched-A/w0")).is_err());
        assert!(spool.fail_terminal(&claimed, "boom", Some("sched-A/w0")).is_err());
        assert_eq!(spool.jobs_in("running").unwrap(), vec!["job001_hot"]);
        assert_eq!(spool.read_lease("job001_hot").unwrap().owner, "sched-B/w1");

        // the live owner finishes it normally
        spool.finish_as(&claimed.id, true, Some("sched-B/w1")).unwrap();
        assert_eq!(spool.jobs_in("done").unwrap(), vec!["job001_hot"]);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn lease_jitter_has_a_floor_and_a_cap() {
        for id in ["job001_a", "job002_b", "job003_c", "x"] {
            for timeout in [8u64, 50, 1000, 30_000] {
                let j = lease_jitter(id, timeout);
                assert!(j >= timeout / 8 + 1, "jitter {j} below floor for {id}@{timeout}");
                assert!(j <= timeout / 8 + 1 + timeout / 4, "jitter {j} above cap");
            }
        }
    }

    #[test]
    fn retry_requeue_records_attempts_and_backoff() {
        let (root, spool) = tmp_spool("retry");
        spool.submit(&spec("job001_flaky")).unwrap();
        let claimed = spool.claim_next().unwrap().unwrap();

        // first failure: re-queued with a long backoff -> not claimable
        let updated = spool.requeue_failed(&claimed, "injected ENOSPC", 60_000, None).unwrap();
        assert_eq!(updated.attempts.len(), 1);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job001_flaky"]);
        assert!(spool.claim_next().unwrap().is_none(), "backoff gate must hold");
        let on_disk = spool.load_spec("queue", "job001_flaky").unwrap();
        assert_eq!(on_disk.attempts.len(), 1);
        assert!(on_disk.attempts[0].error.contains("ENOSPC"));
        assert_eq!(on_disk.attempts[0].backoff_ms, 60_000);
        assert!(on_disk.not_before_unix_ms > fsutil::unix_ms());

        // zero the gate (as if the backoff elapsed) and fail again,
        // terminally this time: full history lands in failed/
        let mut ungated = on_disk.clone();
        ungated.not_before_unix_ms = 0;
        fsutil::write_atomic(
            &spool.spec_path("queue", "job001_flaky"),
            ungated.to_json().to_string_pretty().as_bytes(),
        )
        .unwrap();
        let again = spool.claim_next().unwrap().unwrap();
        assert_eq!(again.attempts.len(), 1);
        let terminal = spool.fail_terminal(&again, "injected ENOSPC again", None).unwrap();
        assert_eq!(terminal.attempts.len(), 2);
        assert_eq!(spool.jobs_in("failed").unwrap(), vec!["job001_flaky"]);
        let dead = spool.load_spec("failed", "job001_flaky").unwrap();
        assert_eq!(dead.attempts.len(), 2);
        assert!(dead.attempts[1].error.contains("again"));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn orphan_work_dirs_are_reported() {
        let (root, spool) = tmp_spool("orphan");
        spool.submit(&spec("job001_live")).unwrap();
        std::fs::create_dir_all(spool.work_dir("job001_live")).unwrap();
        std::fs::create_dir_all(spool.work_dir("job999_ghost")).unwrap();
        assert_eq!(spool.orphan_work_dirs().unwrap(), vec!["job999_ghost"]);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn next_job_id_scans_all_lifecycle_dirs() {
        let (root, spool) = tmp_spool("ids");
        assert_eq!(spool.next_job_id("mlorc_adamw").unwrap(), "job001_mlorc_adamw");
        spool.submit(&spec("job004_z")).unwrap();
        let _ = spool.claim_next().unwrap();
        spool.finish("job004_z", true).unwrap();
        assert_eq!(spool.next_job_id("lion").unwrap(), "job005_lion");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn claim_order_is_priority_then_id() {
        let (root, spool) = tmp_spool("prio");
        spool.submit(&spec_pri("job001_low", -1)).unwrap();
        spool.submit(&spec_pri("job002_default", 0)).unwrap();
        spool.submit(&spec_pri("job003_urgent", 7)).unwrap();
        spool.submit(&spec_pri("job004_urgent_too", 7)).unwrap();
        let order: Vec<String> = (0..4)
            .map(|_| spool.claim_next().unwrap().unwrap().id)
            .collect();
        // highest priority first; equal priorities fall back to id order
        assert_eq!(
            order,
            vec!["job003_urgent", "job004_urgent_too", "job002_default", "job001_low"]
        );
        assert!(spool.claim_next().unwrap().is_none());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn cancel_tombstones_queued_jobs_only() {
        let (root, spool) = tmp_spool("cancel");
        spool.submit(&spec("job001_a")).unwrap();
        spool.submit(&spec("job002_b")).unwrap();
        spool.cancel("job001_a").unwrap();
        assert_eq!(spool.jobs_in("cancelled").unwrap(), vec!["job001_a"]);
        assert_eq!(spool.jobs_in("queue").unwrap(), vec!["job002_b"]);
        // a cancelled job is never claimed
        let claimed = spool.claim_next().unwrap().unwrap();
        assert_eq!(claimed.id, "job002_b");
        assert!(spool.claim_next().unwrap().is_none());
        // cannot cancel running/missing/already-cancelled jobs
        let err = spool.cancel("job002_b").unwrap_err();
        assert!(format!("{err:#}").contains("running"), "{err:#}");
        assert!(spool.cancel("job009_nope").is_err());
        let err = spool.cancel("job001_a").unwrap_err();
        assert!(format!("{err:#}").contains("cancelled"), "{err:#}");
        // a cancelled id stays burned (no resubmission)
        assert!(spool.submit(&spec("job001_a")).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn spec_json_roundtrip_and_bad_ids() {
        let s = spec_pri("job007_rt", 3);
        let back = JobSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back.id, s.id);
        assert_eq!(back.engine, s.engine);
        assert_eq!(back.checkpoint_every, 5);
        assert_eq!(back.priority, 3);
        assert_eq!(back.cfg.method, s.cfg.method);
        assert!(Engine::parse("tpu").is_err());

        // specs submitted before priorities existed default to 0
        let mut j = spec("job008_old").to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("priority");
        }
        assert_eq!(JobSpec::from_json(&j).unwrap().priority, 0);

        let (root, spool) = tmp_spool("badid");
        assert!(spool.submit(&spec("../escape")).is_err());
        assert!(spool.submit(&spec("a/b")).is_err());
        assert!(spool.submit(&spec("")).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
