//! `mlorc serve` — a multi-job fine-tuning service with crash-safe
//! compressed-momentum checkpoints.
//!
//! MLorc's core observation (paper §3, Table 1) is that the momentum of
//! matrix parameters compresses to rank-l factors at full-parameter
//! quality — which means the *entire* optimizer state is small enough to
//! checkpoint every few steps. That turns cheap preemption/resume into
//! the natural serving model: a file-backed job spool ([`queue`]), a
//! scheduler draining it with N concurrent trainers on fair thread
//! slices ([`scheduler`]), per-job status files plus an aggregator
//! ([`status`]), and a host-only engine ([`host`]) so the whole service
//! runs — and is CI-tested — without AOT artifacts. Claims are backed
//! by heartbeat-refreshed leases so multiple schedulers can share one
//! spool, failed jobs are retried with exponential backoff before
//! quarantine, and [`fsck`] verifies (and repairs) the checksummed
//! checkpoint snapshots offline.
//!
//! Determinism contract: a job served concurrently is bit-identical to
//! the same config run solo, and a job killed mid-run resumes from its
//! latest v2 checkpoint to bit-identical final parameters
//! (`tests/serve_spool.rs`, `tests/checkpoint_v2.rs`).

pub mod fsck;
pub mod host;
pub mod queue;
pub mod scheduler;
pub mod status;

pub use fsck::{fsck, render_report, FsckReport, SnapshotProblem};
pub use host::{host_preset_names, preset_momentum_bytes, HostTrainer};
pub use queue::{Attempt, Engine, JobSpec, Lease, Spool, LIFECYCLE_DIRS};
pub use scheduler::{serve, ServeOpts, ServeSummary, CRASH_EXIT_CODE};
pub use status::{aggregate, render_table, JobStatus};
