//! `mlorc fsck` — offline spool integrity checker.
//!
//! Walks every job's `work/<id>/ckpt/` tree and verifies each snapshot
//! against its checksum manifest (`coordinator::verify_snapshot`), flags
//! `LATEST` pointers that dangle or target a corrupt snapshot, and
//! reports orphaned `work/<id>/` scratch dirs whose job spec is gone
//! from every lifecycle dir (the residue of a quarantined unreadable
//! submission). With `repair`, corrupt snapshots are dropped, `LATEST`
//! is repointed to the newest intact snapshot, and orphaned work dirs
//! are reaped — i.e. the spool is rolled back to its last good state
//! rather than patched forward.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::verify_snapshot;
use crate::util::fsutil;
use crate::util::json::Json;

use super::queue::{Spool, LIFECYCLE_DIRS};

/// One corrupt (or dangling) snapshot found under a job's checkpoint root.
#[derive(Debug, Clone)]
pub struct SnapshotProblem {
    pub job: String,
    /// Snapshot dir name (`step-NNNNNNNN`), or `LATEST` for a dangling
    /// pointer with no intact target to repoint at.
    pub snapshot: String,
    pub error: String,
    /// What repair did: "dropped", "repointed", or "" when running
    /// report-only (or nothing could be done).
    pub action: String,
}

#[derive(Debug, Clone, Default)]
pub struct FsckReport {
    /// Job ids whose checkpoint tree was examined (jobs that never ran
    /// have no work dir and are skipped).
    pub jobs_checked: usize,
    /// Snapshots that passed manifest + checksum verification.
    pub snapshots_ok: usize,
    pub problems: Vec<SnapshotProblem>,
    /// `work/<id>/` dirs with no spec in any lifecycle dir.
    pub orphans: Vec<String>,
    pub orphans_reaped: bool,
}

impl FsckReport {
    /// True when the spool needs no attention.
    pub fn clean(&self) -> bool {
        self.problems.is_empty() && (self.orphans.is_empty() || self.orphans_reaped)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs_checked", Json::num(self.jobs_checked as f64)),
            ("snapshots_ok", Json::num(self.snapshots_ok as f64)),
            (
                "problems",
                Json::arr(self.problems.iter().map(|p| {
                    Json::obj(vec![
                        ("job", Json::str(p.job.clone())),
                        ("snapshot", Json::str(p.snapshot.clone())),
                        ("error", Json::str(p.error.clone())),
                        ("action", Json::str(p.action.clone())),
                    ])
                })),
            ),
            ("orphans", Json::arr(self.orphans.iter().map(|o| Json::str(o.clone())))),
            ("orphans_reaped", Json::Bool(self.orphans_reaped)),
            ("clean", Json::Bool(self.clean())),
        ])
    }
}

/// Verify every checkpoint snapshot in the spool; with `repair`, drop
/// broken snapshots back to the last intact one and reap orphaned work
/// dirs.
pub fn fsck(spool: &Spool, repair: bool) -> Result<FsckReport> {
    let mut report = FsckReport::default();
    let mut ids = Vec::new();
    for dir in LIFECYCLE_DIRS {
        ids.extend(spool.jobs_in(dir)?);
    }
    ids.sort();
    ids.dedup();
    for id in &ids {
        let root = spool.checkpoint_root(id);
        if !root.exists() {
            continue;
        }
        report.jobs_checked += 1;
        check_ckpt_root(id, &root, repair, &mut report)?;
    }
    report.orphans = spool.orphan_work_dirs()?;
    if repair && !report.orphans.is_empty() {
        for id in &report.orphans {
            std::fs::remove_dir_all(spool.work_dir(id))?;
        }
        report.orphans_reaped = true;
    }
    Ok(report)
}

fn check_ckpt_root(id: &str, root: &Path, repair: bool, report: &mut FsckReport) -> Result<()> {
    let latest_path = root.join("LATEST");
    if !latest_path.exists() {
        // direct (un-rotated) snapshot: verify in place; there is no
        // older snapshot to fall back to, so repair can only report
        if root.join("meta.json").exists() {
            match verify_snapshot(root) {
                Ok(()) => report.snapshots_ok += 1,
                Err(e) => report.problems.push(SnapshotProblem {
                    job: id.to_string(),
                    snapshot: ".".to_string(),
                    error: format!("{e:#}"),
                    action: String::new(),
                }),
            }
        }
        return Ok(());
    }
    // rotated root: verify every step-* snapshot
    let mut names: Vec<String> = std::fs::read_dir(root)?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("step-"))
        .collect();
    names.sort(); // zero-padded step numbers: lexical == numeric order
    let mut intact = Vec::new();
    for name in &names {
        match verify_snapshot(&root.join(name)) {
            Ok(()) => {
                report.snapshots_ok += 1;
                intact.push(name.clone());
            }
            Err(e) => {
                let action = if repair {
                    std::fs::remove_dir_all(root.join(name))?;
                    "dropped".to_string()
                } else {
                    String::new()
                };
                report.problems.push(SnapshotProblem {
                    job: id.to_string(),
                    snapshot: name.clone(),
                    error: format!("{e:#}"),
                    action,
                });
            }
        }
    }
    // LATEST must name an intact snapshot
    let target = std::fs::read_to_string(&latest_path)
        .map(|s| s.trim().to_string())
        .unwrap_or_default();
    if !intact.iter().any(|n| n == &target) {
        let error = format!(
            "LATEST -> '{target}' is not an intact snapshot ({} intact candidate(s))",
            intact.len()
        );
        let action = if repair {
            if let Some(newest) = intact.last() {
                fsutil::write_atomic(&latest_path, newest.as_bytes())?;
                format!("repointed to {newest}")
            } else {
                String::new()
            }
        } else {
            String::new()
        };
        report.problems.push(SnapshotProblem {
            job: id.to_string(),
            snapshot: "LATEST".to_string(),
            error,
            action,
        });
    }
    Ok(())
}

/// Human-readable report.
pub fn render_report(r: &FsckReport) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "fsck: {} job(s) with checkpoints, {} intact snapshot(s)",
        r.jobs_checked, r.snapshots_ok
    );
    for p in &r.problems {
        let action = if p.action.is_empty() { String::new() } else { format!(" [{}]", p.action) };
        let _ = writeln!(s, "  CORRUPT {}/{}: {}{}", p.job, p.snapshot, p.error, action);
    }
    if !r.orphans.is_empty() {
        let _ = writeln!(
            s,
            "  ORPHANS {} work dir(s) with no spec: {}{}",
            r.orphans.len(),
            r.orphans.join(", "),
            if r.orphans_reaped { " [reaped]" } else { " (use --repair to reap)" }
        );
    }
    let _ = write!(s, "{}", if r.clean() { "spool is clean" } else { "spool needs attention" });
    s
}
