//! Host-only training engine for the serve scheduler.
//!
//! The graph engine (`coordinator::Trainer`) needs AOT artifacts and a
//! `pjrt`-enabled build; hermetic builds have neither. The host engine
//! gives `mlorc serve` a real optimizer workload with zero artifacts:
//! per-parameter synthetic least-squares fine-tuning. Each matrix
//! parameter `W` chases a hidden target `W*` under a fresh Gaussian probe
//! batch `X` every step:
//!
//! ```text
//! R = (W - W*) X          loss_i = ||R||_F^2 / (m * batch)
//! G = R X^T / batch
//! ```
//!
//! so the gradients are full-rank, step-dependent matrices exercising the
//! exact production update path: the shape-class planner
//! ([`host_step_all`]) batches same-shape parameters into stacked kernel
//! invocations on the worker pool (every preset repeats a matrix shape,
//! so class size > 1 is always exercised), with per-parameter Omega RNG
//! streams. Everything is bit-deterministic across thread budgets and
//! worker counts, and checkpoints use the same v2 format as the real
//! trainer — which is what lets the serve acceptance tests pin
//! "concurrent == solo" and "kill/resume == uninterrupted" to the bit.

use std::path::Path;

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::coordinator::{
    capture_snapshot, host_step_all, load_for_resume, save_checkpoint_v2_rotated, HostStepJob,
    OptSnapshot, OptState, ParamStore, SnapshotBuf,
};
use crate::linalg::{matmul, matmul_a_bt, threads, Rng, Workspace};
use crate::runtime::ParamSpec;
use crate::tensor::Tensor;

/// Per-worker `Workspace` retention cap (mirrors the trainer's).
const HOST_WS_TRIM_BYTES: usize = 8 << 20;

/// Shapes + batch + sketch width for one synthetic host preset. Mixed
/// tall/wide/square matrices keep both GaLore/LDAdamW projector sides and
/// the MLorc left/right factors honest; 1-D entries take the plain
/// vector path like LN gains do in the real model. Every preset repeats
/// at least one matrix shape so the shape-class planner's batched path
/// (class size > 1) is exercised by each serve job, smoke runs included.
struct HostPreset {
    shapes: &'static [&'static [usize]],
    batch: usize,
    l: usize,
}

fn host_preset(name: &str) -> Result<HostPreset> {
    Ok(match name {
        "host-nano" => HostPreset {
            shapes: &[&[48, 20], &[20, 48], &[48, 20], &[32, 32], &[16]],
            batch: 8,
            l: 4,
        },
        "host-tiny" => HostPreset {
            shapes: &[&[96, 64], &[64, 96], &[96, 64], &[64, 64], &[128, 32], &[32]],
            batch: 16,
            l: 4,
        },
        "host-small" => HostPreset {
            shapes: &[&[192, 128], &[128, 192], &[192, 128], &[128, 128], &[256, 64], &[64]],
            batch: 32,
            l: 8,
        },
        other => bail!(
            "unknown host preset '{other}' (host engine presets: {})",
            host_preset_names().join(", ")
        ),
    })
}

/// The presets the host engine understands.
pub fn host_preset_names() -> Vec<&'static str> {
    vec!["host-nano", "host-tiny", "host-small"]
}

/// Analytic momentum-state bytes of a (host preset × method) job,
/// derived from the registered variant layouts
/// (`VariantDesc::state_bytes`, which knows the quantized layouts'
/// 1-byte codes). `None` for non-host presets. This is what `mlorc
/// status` reports for jobs that have not produced a live measurement
/// yet, so the memory-savings story is observable straight from the
/// queue.
pub fn preset_momentum_bytes(preset: &str, method: crate::config::Method) -> Option<usize> {
    use crate::optim::registry;
    let hp = host_preset(preset).ok()?;
    let desc = method.desc();
    let matrix = registry::variant(desc.matrix).ok()?;
    let plain = registry::variant(desc.plain).ok()?;
    let mut bytes = 0usize;
    for shape in hp.shapes {
        match shape {
            [m, n] => bytes += matrix.state_bytes(*m, *n, hp.l) + matrix.wrapper_bytes(m * n),
            other => {
                let numel: usize = other.iter().product();
                // Same routing as `OptState::for_param_cfg`: foldable 1D
                // parameters of fold methods take the matrix variant on
                // their 2D effective shape; everything else stays plain.
                // Wrapper bytes (Prodigy statistics, bf16 planes) count
                // on both paths.
                match registry::effective_shape(numel, hp.l) {
                    Some([a, b]) if desc.fold => {
                        bytes += matrix.state_bytes(a, b, hp.l) + matrix.wrapper_bytes(numel)
                    }
                    _ => bytes += 4 * plain.n_moments() * numel + plain.wrapper_bytes(numel),
                }
            }
        }
    }
    Some(bytes)
}

/// A self-contained host-side trainer: same step/checkpoint/resume
/// surface as `coordinator::Trainer`, no runtime or artifacts.
pub struct HostTrainer {
    pub cfg: RunConfig,
    pub params: ParamStore,
    targets: Vec<Tensor>,
    states: Vec<OptState>,
    rng_data: Rng,
    omega_streams: Vec<Rng>,
    host_ws: Vec<Workspace>,
    batch: usize,
    step: usize,
    last_loss: f32,
}

impl HostTrainer {
    pub fn new(mut cfg: RunConfig) -> Result<HostTrainer> {
        cfg.galore_update_freq = cfg.galore_update_freq.max(1);
        if cfg.method.is_lora() {
            bail!(
                "host engine has no adapter graphs; method '{}' needs the graph engine",
                cfg.method.name()
            );
        }
        let hp = host_preset(&cfg.preset)?;
        // Same stream-splitting scheme as Trainer::new: init / data /
        // omega tags, plus a target stream the graph path has no use for.
        let mut rng = Rng::new(cfg.seed);
        let mut init_rng = rng.split(1);
        let rng_data = rng.split(2);
        let mut rng_omega = rng.split(3);
        let mut tgt_rng = rng.split(4);

        let mut specs = Vec::new();
        let mut values = Vec::new();
        let mut targets = Vec::new();
        for (i, shape) in hp.shapes.iter().enumerate() {
            let matrix = shape.len() == 2;
            specs.push(ParamSpec {
                name: format!("p{i}.{}", if matrix { "w" } else { "b" }),
                shape: shape.to_vec(),
                kind: if matrix { "matrix" } else { "vector" }.to_string(),
                compressed: matrix,
            });
            values.push(init_rng.gaussian_tensor(shape, 0.1));
            targets.push(tgt_rng.gaussian_tensor(shape, 0.5));
        }
        let params = ParamStore { specs, values };
        let states = params
            .specs
            .iter()
            .map(|s| OptState::for_param_cfg(cfg.method, s, hp.l, cfg.rank_min))
            .collect::<Result<Vec<_>>>()?;
        let omega_streams: Vec<Rng> =
            (0..params.len()).map(|i| rng_omega.split(i as u64 + 1)).collect();
        // Workspace pool sized by the job's thread slice (the serve
        // scheduler pins one via threads::with_budget); worker count
        // never changes the bits, only the wall clock.
        let pool = if cfg.opt_threads > 0 { cfg.opt_threads } else { threads::effective_budget() };
        let host_ws: Vec<Workspace> = (0..pool.max(1)).map(|_| Workspace::new()).collect();

        Ok(HostTrainer {
            cfg,
            params,
            targets,
            states,
            rng_data,
            omega_streams,
            host_ws,
            batch: hp.batch,
            step: 0,
            last_loss: f32::NAN,
        })
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn last_loss(&self) -> f32 {
        self.last_loss
    }

    /// Optimizer-state footprint in bytes (what a checkpoint cadence
    /// pays per snapshot, on top of the parameters).
    pub fn opt_state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.state_bytes()).sum()
    }

    /// Per-parameter optimizer states, in parameter order — the
    /// registry combo-matrix test inspects checkpoint roundtrips
    /// field-by-field through this.
    pub fn opt_states(&self) -> &[OptState] {
        &self.states
    }

    /// Total adaptive-rank shrink events across all parameters (0 for
    /// fixed-rank layouts) — surfaced by `mlorc status`.
    pub fn shrink_events(&self) -> usize {
        self.states.iter().map(|s| s.shrink_events()).sum()
    }

    /// One synthetic training step; returns the mean per-parameter loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let step = self.step;
        let lr = self.cfg.peak_lr * self.cfg.schedule.factor(step);
        let t = step + 1;
        let batch = self.batch;

        // Batch draws happen in fixed parameter order from the single
        // data stream, so they are independent of the stepping schedule —
        // the same property the graph trainer's Omega streams have.
        let mut grads: Vec<Tensor> = Vec::with_capacity(self.params.len());
        let mut loss_sum = 0.0f64;
        {
            let HostTrainer { params, targets, rng_data, .. } = self;
            for (w, tgt) in params.values.iter().zip(targets.iter()) {
                if w.shape.len() == 2 {
                    let (m, n) = w.dims2()?;
                    let x = rng_data.gaussian_tensor(&[n, batch], 1.0);
                    let mut diff = w.clone();
                    for (d, ti) in diff.data.iter_mut().zip(&tgt.data) {
                        *d -= ti;
                    }
                    let r = matmul(&diff, &x); // m x batch residual
                    loss_sum += (r.norm_fro() as f64).powi(2) / (m * batch) as f64;
                    let mut g = matmul_a_bt(&r, &x); // m x n
                    let inv_b = 1.0 / batch as f32;
                    for gi in g.data.iter_mut() {
                        *gi *= inv_b;
                    }
                    grads.push(g);
                } else {
                    let mut g = w.clone();
                    for (gi, ti) in g.data.iter_mut().zip(&tgt.data) {
                        *gi -= ti;
                    }
                    loss_sum +=
                        g.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
                            / g.len().max(1) as f64;
                    grads.push(g);
                }
            }
        }
        let loss = (loss_sum / self.params.len().max(1) as f64) as f32;

        // GaLore projector cadence, mirroring Trainer::apply_updates_host
        // (no-op for layouts without a cached projector).
        if step % self.cfg.galore_update_freq == 0 {
            for state in self.states.iter_mut() {
                state.invalidate_projector();
            }
        }

        let HostTrainer { params, states, omega_streams, host_ws, .. } = self;
        let mut jobs: Vec<HostStepJob> = params
            .values
            .iter_mut()
            .zip(states.iter_mut())
            .zip(omega_streams.iter_mut())
            .zip(grads.iter())
            .map(|(((w, state), rng), grad)| HostStepJob { w, grad, state, rng, lr, t })
            .collect();
        host_step_all(&mut jobs, host_ws)?;
        drop(jobs);
        for ws in host_ws.iter_mut() {
            ws.trim(HOST_WS_TRIM_BYTES);
        }

        self.step += 1;
        self.last_loss = loss;
        Ok(loss)
    }

    /// Write a full v2 snapshot into the rotated checkpoint root.
    pub fn save_checkpoint(&self, root: &Path) -> Result<()> {
        let opt: Vec<(String, &OptState)> = self
            .params
            .specs
            .iter()
            .zip(&self.states)
            .map(|(spec, st)| (spec.name.clone(), st))
            .collect();
        let snap = OptSnapshot { opt, rng_data: &self.rng_data, omega: &self.omega_streams };
        save_checkpoint_v2_rotated(root, self.step, &self.cfg, &self.params, None, &snap)?;
        Ok(())
    }

    /// Capture the full v2 snapshot state into a reusable scratch buffer
    /// (the cheap half of [`HostTrainer::save_checkpoint`]); committing
    /// the buffer is bit-identical to an inline save.
    pub fn capture_snapshot(&self, buf: &mut SnapshotBuf) -> Result<()> {
        let opt: Vec<(String, &OptState)> = self
            .params
            .specs
            .iter()
            .zip(&self.states)
            .map(|(spec, st)| (spec.name.clone(), st))
            .collect();
        let snap = OptSnapshot { opt, rng_data: &self.rng_data, omega: &self.omega_streams };
        capture_snapshot(buf, self.step, &self.cfg, &self.params, None, &snap)
    }

    /// Resume from a v2 checkpoint (direct snapshot dir or rotated
    /// root); the continued run is bit-identical to an uninterrupted one.
    pub fn resume_from(&mut self, dir: &Path) -> Result<usize> {
        let ck = load_for_resume(
            dir,
            &self.cfg,
            &mut self.params,
            None,
            self.omega_streams.len(),
        )?;
        for (spec, state) in self.params.specs.iter().zip(self.states.iter_mut()) {
            match ck.opt.get(&spec.name) {
                Some(st) => *state = st.clone(),
                None => bail!("checkpoint missing optimizer state for '{}'", spec.name),
            }
        }
        self.omega_streams = ck.omega;
        self.rng_data = ck.rng_data;
        self.step = ck.step;
        Ok(ck.step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, TaskKind};

    fn cfg(method: Method, steps: usize) -> RunConfig {
        let mut c = RunConfig::new("host-nano", method, TaskKind::MathChain, steps);
        c.peak_lr = 0.05;
        c.log_every = 0;
        c
    }

    #[test]
    fn loss_decreases_on_least_squares() {
        let mut tr = HostTrainer::new(cfg(Method::MlorcAdamW, 40)).unwrap();
        let first = tr.train_step().unwrap();
        let mut last = first;
        for _ in 0..39 {
            last = tr.train_step().unwrap();
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(last < first * 0.9, "loss did not decrease: {first} -> {last}");
        assert_eq!(tr.step_count(), 40);
        assert!(tr.opt_state_bytes() > 0);
    }

    #[test]
    fn every_nonlora_method_steps() {
        for &method in Method::all() {
            if method.is_lora() {
                assert!(HostTrainer::new(cfg(method, 2)).is_err());
                continue;
            }
            let mut tr = HostTrainer::new(cfg(method, 2)).unwrap();
            for _ in 0..2 {
                let loss = tr.train_step().unwrap_or_else(|e| panic!("{method:?}: {e:#}"));
                assert!(loss.is_finite(), "{method:?} loss not finite");
            }
        }
    }

    #[test]
    fn deterministic_across_thread_budgets() {
        let run = |budget: usize| {
            threads::with_budget(budget, || {
                let mut tr = HostTrainer::new(cfg(Method::MlorcLion, 6)).unwrap();
                for _ in 0..6 {
                    tr.train_step().unwrap();
                }
                tr.params.values.clone()
            })
        };
        let base = run(1);
        for budget in [2usize, 8] {
            let got = run(budget);
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.data, b.data, "budget {budget} diverged");
            }
        }
    }
}
