//! The serve scheduler: drains the spool with up to `jobs` concurrent
//! workers, each driving one trainer pinned to a fair slice of the
//! machine's thread budget.
//!
//! Fairness and determinism: every worker wraps its job in
//! `threads::with_budget(budget / jobs)`, so N concurrent jobs split the
//! kernel thread budget instead of oversubscribing N-fold — and because
//! the linalg kernels are bit-deterministic across band counts, a job's
//! results are bit-identical to running it solo at any budget (pinned by
//! `tests/serve_spool.rs`).
//!
//! Crash safety: workers checkpoint running jobs every
//! `JobSpec::checkpoint_every` steps through the rotated v2 writer, and
//! in lease mode every claim is backed by a lease that a dedicated
//! per-job thread heartbeats (so a long step or checkpoint save cannot
//! starve it). Expired leases are swept back into the queue (at startup
//! and whenever a worker goes idle), so any number of `mlorc serve`
//! processes can share one spool: a crashed peer's jobs are stolen
//! after the lease timeout and resume from their latest intact
//! checkpoint, and the terminal transitions re-verify lease ownership
//! so a stale worker can never move a stolen job. Failed jobs are
//! retried with exponential backoff up to `max_retries` before
//! quarantine in `failed/`, with the attempt history recorded in the
//! spec.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{has_checkpoint, CkptWriter, SnapshotBuf, Trainer};
use crate::linalg::threads;
use crate::obs::{self, registry, Journal};
use crate::runtime::{Manifest, Runtime};
use crate::util::fsutil;
use crate::util::json::Json;

use super::host::HostTrainer;
use super::queue::{Engine, JobSpec, Spool};
use super::status::JobStatus;

/// Exit code of an injected-kill crash (`--die-after-checkpoints`, any
/// `kill` failpoint) — CI uses it to tell "crashed as instructed" from a
/// real failure.
pub const CRASH_EXIT_CODE: i32 = fsutil::KILL_EXIT_CODE;

pub struct ServeOpts {
    /// Max concurrent jobs.
    pub jobs: usize,
    /// Exit once the queue is empty instead of polling for new work.
    pub drain: bool,
    /// Idle poll period when not draining.
    pub poll_ms: u64,
    /// Test hook: exit the whole process with [`CRASH_EXIT_CODE`] after
    /// this many cadence checkpoints across all jobs (0 = off). Sugar
    /// for arming the `ckpt_cadence:kill@N` failpoint.
    pub die_after_checkpoints: usize,
    /// Failed-job retry budget: a job is re-queued with backoff until it
    /// has failed `max_retries + 1` times, then quarantined to `failed/`.
    pub max_retries: usize,
    /// Base retry backoff; doubles per recorded attempt.
    pub retry_backoff_ms: u64,
    /// Force inline (synchronous) cadence checkpoints instead of the
    /// async double-buffered writer — the `--checkpoint-sync` escape
    /// hatch. Snapshots are bit-identical either way; sync trades step
    /// latency for the simplest possible failure timing.
    pub checkpoint_sync: bool,
    /// Lease liveness window. 0 = legacy single-scheduler mode: claims
    /// write no lease, and recovery (startup only) re-queues every
    /// running job immediately — crash leftovers need no timeout to
    /// elapse. > 0 = multi-scheduler mode: workers heartbeat their
    /// leases and sweep expired peers' jobs back into the queue
    /// mid-drain.
    pub lease_timeout_ms: u64,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            jobs: 2,
            drain: false,
            poll_ms: 500,
            die_after_checkpoints: 0,
            max_retries: 2,
            retry_backoff_ms: 500,
            checkpoint_sync: false,
            lease_timeout_ms: 30_000,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ServeSummary {
    pub done: usize,
    /// Jobs quarantined to `failed/` with their retry budget exhausted.
    pub failed: usize,
    /// Interrupted jobs swept back into the queue at startup.
    pub recovered: usize,
    /// Failed runs re-queued for retry (not terminal).
    pub retried: usize,
}

/// Run the scheduler until the spool drains (`opts.drain`) or forever.
pub fn serve(spool: &Spool, opts: &ServeOpts) -> Result<ServeSummary> {
    if opts.die_after_checkpoints > 0 {
        fsutil::failpoints::arm(&format!("ckpt_cadence:kill@{}", opts.die_after_checkpoints))?;
    }
    let recovered = spool.recover_interrupted(opts.lease_timeout_ms)?;
    for id in &recovered {
        log::info!("serve: recovered interrupted job {id}; it will resume from its latest checkpoint");
    }
    match spool.orphan_work_dirs() {
        Ok(orphans) if !orphans.is_empty() => log::warn!(
            "serve: {} orphaned work dir(s) with no spec in any lifecycle dir \
             (run `mlorc fsck --repair` to reap): {}",
            orphans.len(),
            orphans.join(", ")
        ),
        Ok(_) => {}
        Err(e) => log::warn!("serve: orphan sweep failed: {e:#}"),
    }
    let owner = format!("sched-{}-{:x}", std::process::id(), fsutil::unix_ms());
    crate::util::logger::set_tag(&owner);
    let journal = Journal::open(&spool.events_dir(), &owner);
    for id in &recovered {
        registry::SERVE_LEASE_STEALS.add(1);
        journal.event("lease_steal", vec![("job", Json::str(id.as_str()))]);
    }
    let n = opts.jobs.max(1);
    let slice = (threads::budget() / n).max(1);
    log::info!(
        "serve: up to {n} concurrent jobs, {slice} kernel threads each (budget {}), owner {owner}",
        threads::budget()
    );
    let counters = Counters::default();
    std::thread::scope(|s| {
        for worker in 0..n {
            let counters = &counters;
            let owner = owner.as_str();
            let journal = &journal;
            s.spawn(move || worker_loop(spool, opts, slice, worker, owner, journal, counters));
        }
    });
    // Final snapshot so short drains leave a metrics file even when no
    // checkpoint cadence ever fired.
    write_metrics_snapshot(spool, &journal);
    // A worker that dies on a spool error must not masquerade as a clean
    // drain: jobs may still be queued while we report success.
    let claim_errors = counters.claim_errors.into_inner();
    if claim_errors > 0 {
        bail!(
            "{claim_errors} scheduler worker(s) stopped on spool errors (see log); \
             the queue may not be drained"
        );
    }
    Ok(ServeSummary {
        done: counters.done.into_inner(),
        failed: counters.failed.into_inner(),
        recovered: recovered.len(),
        retried: counters.retried.into_inner(),
    })
}

/// Cross-worker tallies shared through the scheduler's thread scope.
#[derive(Default)]
struct Counters {
    ckpts: AtomicUsize,
    done: AtomicUsize,
    failed: AtomicUsize,
    retried: AtomicUsize,
    claim_errors: AtomicUsize,
}

/// Exponential backoff for the `attempts`-th retry (0-based).
fn backoff_ms(base: u64, attempts: usize) -> u64 {
    base.saturating_mul(1u64 << attempts.min(16) as u32)
}

/// Atomically (re)write this scheduler's `metrics/<owner>.json` snapshot.
/// Best-effort and inert when observability is disabled — a failed write
/// must never fail a job.
fn write_metrics_snapshot(spool: &Spool, journal: &Journal) {
    if !obs::enabled() {
        return;
    }
    let path = spool.metrics_path(journal.owner());
    let snap = registry::snapshot();
    if let Err(e) = fsutil::write_atomic(&path, snap.to_string_pretty().as_bytes()) {
        log::warn!("serve: metrics snapshot write failed: {e:#}");
    }
}

fn worker_loop(
    spool: &Spool,
    opts: &ServeOpts,
    slice: usize,
    worker: usize,
    owner: &str,
    journal: &Journal,
    counters: &Counters,
) {
    let worker_owner = format!("{owner}/w{worker}");
    loop {
        let claimed = match spool.claim_next_as(Some(&worker_owner), opts.lease_timeout_ms) {
            Ok(c) => c,
            Err(e) => {
                log::error!("serve worker {worker}: claiming from the spool failed: {e:#}");
                counters.claim_errors.fetch_add(1, Ordering::SeqCst);
                return;
            }
        };
        let Some(spec) = claimed else {
            // nothing claimable; a dead peer's expired leases may still
            // be holding jobs hostage in running/ (only meaningful in
            // lease mode — with timeout 0 our own claims would look
            // expired, so the sweep runs at startup only)
            if opts.lease_timeout_ms > 0 {
                match spool.recover_interrupted(opts.lease_timeout_ms) {
                    Ok(r) if !r.is_empty() => {
                        for id in &r {
                            registry::SERVE_LEASE_STEALS.add(1);
                            journal.event("lease_steal", vec![("job", Json::str(id.as_str()))]);
                        }
                        log::info!(
                            "serve worker {worker}: recovered {} expired-lease job(s)",
                            r.len()
                        );
                        continue;
                    }
                    Ok(_) => {}
                    Err(e) => {
                        log::warn!("serve worker {worker}: recovery sweep failed: {e:#}");
                    }
                }
            }
            if opts.drain {
                // the drain is only complete once nothing is queued
                // (retry backoffs included) and nothing is running
                // (here or on a peer)
                let busy = spool.jobs_in("queue").map(|v| !v.is_empty()).unwrap_or(true)
                    || spool.jobs_in("running").map(|v| !v.is_empty()).unwrap_or(true);
                if !busy {
                    return;
                }
            }
            std::thread::sleep(Duration::from_millis(opts.poll_ms.max(10)));
            continue;
        };
        if let Err(e) = spool.note_claim(&spec.id, &worker_owner, spec.attempts.len()) {
            log::warn!("serve worker {worker}: claims.log append failed for {}: {e:#}", spec.id);
        }
        registry::SERVE_CLAIMS.add(1);
        journal.event(
            "claim",
            vec![
                ("job", Json::str(spec.id.as_str())),
                ("worker", Json::num(worker as f64)),
                ("attempt", Json::num((spec.attempts.len() + 1) as f64)),
            ],
        );
        log::info!(
            "serve worker {worker}: job {} ({} / {} / {} steps, engine {}, attempt {})",
            spec.id,
            spec.cfg.preset,
            spec.cfg.method.name(),
            spec.cfg.steps,
            spec.engine.name(),
            spec.attempts.len() + 1
        );
        let job_t0 = Instant::now();
        let result = threads::with_budget(slice, || {
            run_job(spool, &spec, opts, &worker_owner, journal, &counters.ckpts)
        });
        registry::SERVE_JOB_US.record(job_t0.elapsed().as_micros() as u64);
        // A run that outlived its lease may have been stolen by a peer's
        // recovery sweep; its outcome is the thief's to report now. The
        // owner-checked transitions below re-verify, but bailing here
        // keeps the done/failed tallies honest.
        if opts.lease_timeout_ms > 0 && !spool.owns_lease(&spec.id, &worker_owner) {
            log::error!(
                "serve worker {worker}: job {} was stolen after its lease expired; \
                 discarding this run's outcome",
                spec.id
            );
            continue;
        }
        match result {
            Ok(status) => {
                let _ = status.write(spool);
                match spool.finish_as(&spec.id, true, Some(&worker_owner)) {
                    Ok(()) => {
                        counters.done.fetch_add(1, Ordering::SeqCst);
                        registry::SERVE_JOBS_DONE.add(1);
                        journal.event(
                            "complete",
                            vec![
                                ("job", Json::str(spec.id.as_str())),
                                ("step", Json::num(status.step as f64)),
                            ],
                        );
                        write_metrics_snapshot(spool, journal);
                        log::info!("serve worker {worker}: job {} done", spec.id);
                    }
                    Err(e) => {
                        log::error!("serve worker {worker}: moving {} to done/: {e:#}", spec.id);
                    }
                }
            }
            Err(e) => {
                let err_text = format!("{e:#}");
                let failures = spec.attempts.len() + 1;
                if failures <= opts.max_retries {
                    let backoff = backoff_ms(opts.retry_backoff_ms, spec.attempts.len());
                    match spool.requeue_failed(&spec, &err_text, backoff, Some(&worker_owner)) {
                        Ok(updated) => {
                            let mut status = JobStatus::from_spec(&updated, "queued");
                            status.error = Some(err_text.clone());
                            let _ = status.write(spool);
                            counters.retried.fetch_add(1, Ordering::SeqCst);
                            registry::SERVE_RETRIES.add(1);
                            journal.event(
                                "retry",
                                vec![
                                    ("job", Json::str(spec.id.as_str())),
                                    ("attempt", Json::num(failures as f64)),
                                    ("backoff_ms", Json::num(backoff as f64)),
                                    ("error", Json::str(err_text.as_str())),
                                ],
                            );
                            log::warn!(
                                "serve worker {worker}: job {} failed (attempt {failures} of {}), \
                                 retrying in {backoff} ms: {err_text}",
                                spec.id,
                                opts.max_retries + 1
                            );
                            continue;
                        }
                        Err(e2) => {
                            log::error!(
                                "serve worker {worker}: could not re-queue {} ({e2:#}); \
                                 quarantining instead",
                                spec.id
                            );
                        }
                    }
                }
                // retry budget exhausted (or the re-queue itself failed)
                match spool.fail_terminal(&spec, &err_text, Some(&worker_owner)) {
                    Ok(updated) => {
                        let mut status = JobStatus::from_spec(&updated, "failed");
                        status.error = Some(err_text.clone());
                        let _ = status.write(spool);
                        registry::SERVE_QUARANTINES.add(1);
                        journal.event(
                            "quarantine",
                            vec![
                                ("job", Json::str(spec.id.as_str())),
                                ("error", Json::str(err_text.as_str())),
                            ],
                        );
                    }
                    Err(e2) => {
                        log::error!(
                            "serve worker {worker}: quarantining {} failed ({e2:#}); \
                             falling back to a bare finish",
                            spec.id
                        );
                        let mut status = JobStatus::from_spec(&spec, "failed");
                        status.error = Some(err_text.clone());
                        let _ = status.write(spool);
                        let _ = spool.finish_as(&spec.id, false, Some(&worker_owner));
                        journal.event(
                            "fail",
                            vec![
                                ("job", Json::str(spec.id.as_str())),
                                ("error", Json::str(err_text.as_str())),
                            ],
                        );
                    }
                }
                counters.failed.fetch_add(1, Ordering::SeqCst);
                registry::SERVE_JOBS_FAILED.add(1);
                write_metrics_snapshot(spool, journal);
                log::error!("serve worker {worker}: job {} failed terminally: {err_text}", spec.id);
            }
        }
    }
}

/// What the drive loop needs from a trainer — implemented by both the
/// host engine and the graph `Trainer`.
trait ServeEngine {
    fn step(&mut self) -> Result<f32>;
    fn step_count(&self) -> usize;
    fn save(&self, root: &Path) -> Result<()>;
    /// Capture full snapshot state into a reusable scratch buffer — the
    /// cheap half of `save`; committing the buffer is bit-identical.
    fn capture(&self, buf: &mut SnapshotBuf) -> Result<()>;
    fn resume(&mut self, root: &Path) -> Result<usize>;
    fn opt_state_bytes(&self) -> usize;
    /// Adaptive-rank shrink events so far (0 for fixed-rank layouts).
    fn shrink_events(&self) -> usize;
}

impl ServeEngine for HostTrainer {
    fn step(&mut self) -> Result<f32> {
        self.train_step()
    }
    fn step_count(&self) -> usize {
        HostTrainer::step_count(self)
    }
    fn save(&self, root: &Path) -> Result<()> {
        self.save_checkpoint(root)
    }
    fn capture(&self, buf: &mut SnapshotBuf) -> Result<()> {
        self.capture_snapshot(buf)
    }
    fn resume(&mut self, root: &Path) -> Result<usize> {
        self.resume_from(root)
    }
    fn opt_state_bytes(&self) -> usize {
        HostTrainer::opt_state_bytes(self)
    }
    fn shrink_events(&self) -> usize {
        HostTrainer::shrink_events(self)
    }
}

impl ServeEngine for Trainer<'_> {
    fn step(&mut self) -> Result<f32> {
        self.train_step()
    }
    fn step_count(&self) -> usize {
        Trainer::step_count(self)
    }
    fn save(&self, root: &Path) -> Result<()> {
        self.save_full_checkpoint(root)
    }
    fn capture(&self, buf: &mut SnapshotBuf) -> Result<()> {
        self.capture_snapshot(buf)
    }
    fn resume(&mut self, root: &Path) -> Result<usize> {
        self.resume_from(root)
    }
    fn opt_state_bytes(&self) -> usize {
        self.memory_measured().opt_state_bytes
    }
    fn shrink_events(&self) -> usize {
        Trainer::opt_shrink_events(self)
    }
}

fn run_job(
    spool: &Spool,
    spec: &JobSpec,
    opts: &ServeOpts,
    worker_owner: &str,
    journal: &Journal,
    ckpts: &AtomicUsize,
) -> Result<JobStatus> {
    match spec.engine {
        Engine::Host => {
            let mut tr = HostTrainer::new(spec.cfg.clone())?;
            drive(&mut tr, spool, spec, opts, worker_owner, journal, ckpts)
        }
        Engine::Graph => {
            let dir = fsutil::artifacts_dir()?;
            if !dir.join("manifest.json").exists() {
                bail!(
                    "graph engine needs AOT artifacts at {} (run `make artifacts`), \
                     or submit with --engine host",
                    dir.display()
                );
            }
            let manifest = Manifest::load(&dir)?;
            let rt = Runtime::cpu(&dir)?;
            let preset = manifest.preset(&spec.cfg.preset)?;
            let mut tr = Trainer::new(&rt, preset, spec.cfg.clone())?;
            drive(&mut tr, spool, spec, opts, worker_owner, journal, ckpts)
        }
    }
}

/// Shared step/checkpoint/status loop for both engines.
fn drive(
    tr: &mut dyn ServeEngine,
    spool: &Spool,
    spec: &JobSpec,
    opts: &ServeOpts,
    worker_owner: &str,
    journal: &Journal,
    ckpts: &AtomicUsize,
) -> Result<JobStatus> {
    let t0 = Instant::now();
    let ckpt_root = spool.checkpoint_root(&spec.id);
    if has_checkpoint(&ckpt_root) {
        let step = tr.resume(&ckpt_root)?;
        log::info!("job {}: resuming from step {step}", spec.id);
    }
    let mut status = JobStatus::from_spec(spec, "running");
    status.opt_state_bytes = tr.opt_state_bytes();
    status.rank_shrink_events = tr.shrink_events();
    status.step = tr.step_count();
    let _ = status.write(spool);

    // Heartbeat from a dedicated thread at a third of the lease timeout
    // (two missed beats of headroom before a peer's sweep could consider
    // this job dead). It must not ride the step loop: a single step or
    // checkpoint save longer than the timeout would starve the lease and
    // let a peer steal — and concurrently re-run — a perfectly live job.
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        if opts.lease_timeout_ms > 0 {
            let stop = &stop;
            let id = spec.id.as_str();
            scope.spawn(move || {
                let hb_period = Duration::from_millis((opts.lease_timeout_ms / 3).max(1));
                let tick = hb_period.min(Duration::from_millis(25));
                let mut last_hb = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    if last_hb.elapsed() >= hb_period {
                        match spool.write_lease(id, worker_owner, opts.lease_timeout_ms) {
                            Ok(()) => {
                                registry::SERVE_LEASE_RENEWS.add(1);
                                journal.event(
                                    "lease_renew",
                                    vec![("job", Json::str(id))],
                                );
                            }
                            Err(e) => {
                                log::warn!("job {id}: lease heartbeat failed: {e:#}");
                            }
                        }
                        last_hb = Instant::now();
                    }
                    std::thread::sleep(tick);
                }
            });
        }
        // the closure keeps `?`-failures from skipping the stop flag —
        // an early return from the scope itself would deadlock the join
        let result = (|| -> Result<JobStatus> {
            let mut writer = (!opts.checkpoint_sync && spec.checkpoint_every > 0)
                .then(|| CkptWriter::new(&ckpt_root));
            // journal + metrics land right after a snapshot commits
            // (never at capture time), before the injected-kill hook —
            // a crash never loses the record of a committed save
            let record_commit = |step: usize| {
                ckpts.fetch_add(1, Ordering::SeqCst);
                journal.event(
                    "checkpoint",
                    vec![
                        ("job", Json::str(spec.id.as_str())),
                        ("step", Json::num(step as f64)),
                    ],
                );
                write_metrics_snapshot(spool, journal);
            };
            let mut last_loss = None;
            while tr.step_count() < spec.cfg.steps {
                let loss = {
                    let _span = obs::span(&registry::SERVE_STEP_US);
                    tr.step()?
                };
                last_loss = Some(loss as f64);
                let s = tr.step_count();
                if spec.checkpoint_every > 0 && s % spec.checkpoint_every == 0 && s < spec.cfg.steps
                {
                    match writer.as_mut() {
                        Some(w) => {
                            let mut outcomes = w.submit(|b| tr.capture(b))?;
                            // `--die-after-checkpoints N` means "die after
                            // N *committed* saves": with a ckpt_cadence
                            // failpoint armed the async path hard-joins so
                            // the crash below sees the synchronous path's
                            // on-disk state; otherwise reclaim lazily
                            if fsutil::failpoints::armed_on("ckpt_cadence") {
                                outcomes.extend(w.join()?);
                            } else {
                                outcomes.extend(w.drain());
                            }
                            for oc in outcomes {
                                let step = oc.step;
                                oc.dir?;
                                record_commit(step);
                            }
                        }
                        None => {
                            tr.save(&ckpt_root)?;
                            record_commit(s);
                        }
                    }
                    // the crash hook (`--die-after-checkpoints` /
                    // MLORC_FAILPOINT=ckpt_cadence:...) fires after the
                    // snapshot is committed, like a real mid-run kill
                    fsutil::failpoint("ckpt_cadence")?;
                    status.step = s;
                    status.loss = last_loss;
                    // adaptive-rank layouts shrink their state mid-run
                    status.opt_state_bytes = tr.opt_state_bytes();
                    status.rank_shrink_events = tr.shrink_events();
                    status.wall_secs = t0.elapsed().as_secs_f64();
                    let _ = status.write(spool);
                }
            }
            // Hard join before the terminal transition: writer-thread
            // failures must fail (and retry) the job, not vanish on drop.
            if let Some(w) = writer.as_mut() {
                for oc in w.join()? {
                    let step = oc.step;
                    oc.dir?;
                    record_commit(step);
                }
            }
            drop(writer);
            // Final snapshot: the job's resumable (and verifiable) result.
            tr.save(&ckpt_root)?;
            status.state = "done".to_string();
            status.step = tr.step_count();
            status.loss = last_loss;
            status.opt_state_bytes = tr.opt_state_bytes();
            status.rank_shrink_events = tr.shrink_events();
            status.wall_secs = t0.elapsed().as_secs_f64();
            Ok(status)
        })();
        stop.store(true, Ordering::Relaxed);
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_saturates() {
        assert_eq!(backoff_ms(500, 0), 500);
        assert_eq!(backoff_ms(500, 1), 1000);
        assert_eq!(backoff_ms(500, 3), 4000);
        // deep attempt counts must not overflow
        assert!(backoff_ms(u64::MAX / 2, 40) >= u64::MAX / 2);
    }
}
