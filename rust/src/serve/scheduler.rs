//! The serve scheduler: drains the spool with up to `jobs` concurrent
//! workers, each driving one trainer pinned to a fair slice of the
//! machine's thread budget.
//!
//! Fairness and determinism: every worker wraps its job in
//! `threads::with_budget(budget / jobs)`, so N concurrent jobs split the
//! kernel thread budget instead of oversubscribing N-fold — and because
//! the linalg kernels are bit-deterministic across band counts, a job's
//! results are bit-identical to running it solo at any budget (pinned by
//! `tests/serve_spool.rs`).
//!
//! Crash safety: workers checkpoint running jobs every
//! `JobSpec::checkpoint_every` steps through the rotated v2 writer; on
//! startup the scheduler sweeps crash-stranded `running/` specs back
//! into the queue, and a re-claimed job resumes from its latest
//! checkpoint instead of restarting.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{has_checkpoint, Trainer};
use crate::linalg::threads;
use crate::runtime::{Manifest, Runtime};
use crate::util::fsutil;

use super::host::HostTrainer;
use super::queue::{Engine, JobSpec, Spool};
use super::status::JobStatus;

/// Exit code of the `--die-after-checkpoints` simulated crash (CI uses it
/// to tell "crashed as instructed" from a real failure).
pub const CRASH_EXIT_CODE: i32 = 86;

pub struct ServeOpts {
    /// Max concurrent jobs.
    pub jobs: usize,
    /// Exit once the queue is empty instead of polling for new work.
    pub drain: bool,
    /// Idle poll period when not draining.
    pub poll_ms: u64,
    /// Test hook: exit the whole process with [`CRASH_EXIT_CODE`] after
    /// this many cadence checkpoints across all jobs (0 = off). Makes
    /// the CI kill/restart smoke test deterministic.
    pub die_after_checkpoints: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts { jobs: 2, drain: false, poll_ms: 500, die_after_checkpoints: 0 }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ServeSummary {
    pub done: usize,
    pub failed: usize,
    /// Crash-stranded jobs swept back into the queue at startup.
    pub recovered: usize,
}

/// Run the scheduler until the spool drains (`opts.drain`) or forever.
pub fn serve(spool: &Spool, opts: &ServeOpts) -> Result<ServeSummary> {
    let recovered = spool.recover_interrupted()?;
    for id in &recovered {
        log::info!("serve: recovered interrupted job {id}; it will resume from its latest checkpoint");
    }
    let n = opts.jobs.max(1);
    let slice = (threads::budget() / n).max(1);
    log::info!(
        "serve: up to {n} concurrent jobs, {slice} kernel threads each (budget {})",
        threads::budget()
    );
    let counters = Counters::default();
    std::thread::scope(|s| {
        for worker in 0..n {
            let counters = &counters;
            s.spawn(move || worker_loop(spool, opts, slice, worker, counters));
        }
    });
    // A worker that dies on a spool error must not masquerade as a clean
    // drain: jobs may still be queued while we report success.
    let claim_errors = counters.claim_errors.into_inner();
    if claim_errors > 0 {
        bail!(
            "{claim_errors} scheduler worker(s) stopped on spool errors (see log); \
             the queue may not be drained"
        );
    }
    Ok(ServeSummary {
        done: counters.done.into_inner(),
        failed: counters.failed.into_inner(),
        recovered: recovered.len(),
    })
}

/// Cross-worker tallies shared through the scheduler's thread scope.
#[derive(Default)]
struct Counters {
    ckpts: AtomicUsize,
    done: AtomicUsize,
    failed: AtomicUsize,
    claim_errors: AtomicUsize,
}

fn worker_loop(spool: &Spool, opts: &ServeOpts, slice: usize, worker: usize, counters: &Counters) {
    loop {
        let claimed = match spool.claim_next() {
            Ok(c) => c,
            Err(e) => {
                log::error!("serve worker {worker}: claiming from the spool failed: {e:#}");
                counters.claim_errors.fetch_add(1, Ordering::SeqCst);
                return;
            }
        };
        let Some(spec) = claimed else {
            if opts.drain {
                return;
            }
            std::thread::sleep(Duration::from_millis(opts.poll_ms.max(10)));
            continue;
        };
        log::info!(
            "serve worker {worker}: job {} ({} / {} / {} steps, engine {})",
            spec.id,
            spec.cfg.preset,
            spec.cfg.method.name(),
            spec.cfg.steps,
            spec.engine.name()
        );
        let result = threads::with_budget(slice, || run_job(spool, &spec, opts, &counters.ckpts));
        match result {
            Ok(status) => {
                let _ = status.write(spool);
                if let Err(e) = spool.finish(&spec.id, true) {
                    log::error!("serve worker {worker}: moving {} to done/: {e:#}", spec.id);
                }
                counters.done.fetch_add(1, Ordering::SeqCst);
                log::info!("serve worker {worker}: job {} done", spec.id);
            }
            Err(e) => {
                let mut status = JobStatus::from_spec(&spec, "failed");
                status.error = Some(format!("{e:#}"));
                let _ = status.write(spool);
                if let Err(e2) = spool.finish(&spec.id, false) {
                    log::error!("serve worker {worker}: moving {} to failed/: {e2:#}", spec.id);
                }
                counters.failed.fetch_add(1, Ordering::SeqCst);
                log::error!("serve worker {worker}: job {} failed: {e:#}", spec.id);
            }
        }
    }
}

/// What the drive loop needs from a trainer — implemented by both the
/// host engine and the graph `Trainer`.
trait ServeEngine {
    fn step(&mut self) -> Result<f32>;
    fn step_count(&self) -> usize;
    fn save(&self, root: &Path) -> Result<()>;
    fn resume(&mut self, root: &Path) -> Result<usize>;
    fn opt_state_bytes(&self) -> usize;
    /// Adaptive-rank shrink events so far (0 for fixed-rank layouts).
    fn shrink_events(&self) -> usize;
}

impl ServeEngine for HostTrainer {
    fn step(&mut self) -> Result<f32> {
        self.train_step()
    }
    fn step_count(&self) -> usize {
        HostTrainer::step_count(self)
    }
    fn save(&self, root: &Path) -> Result<()> {
        self.save_checkpoint(root)
    }
    fn resume(&mut self, root: &Path) -> Result<usize> {
        self.resume_from(root)
    }
    fn opt_state_bytes(&self) -> usize {
        HostTrainer::opt_state_bytes(self)
    }
    fn shrink_events(&self) -> usize {
        HostTrainer::shrink_events(self)
    }
}

impl ServeEngine for Trainer<'_> {
    fn step(&mut self) -> Result<f32> {
        self.train_step()
    }
    fn step_count(&self) -> usize {
        Trainer::step_count(self)
    }
    fn save(&self, root: &Path) -> Result<()> {
        self.save_full_checkpoint(root)
    }
    fn resume(&mut self, root: &Path) -> Result<usize> {
        self.resume_from(root)
    }
    fn opt_state_bytes(&self) -> usize {
        self.memory_measured().opt_state_bytes
    }
    fn shrink_events(&self) -> usize {
        Trainer::opt_shrink_events(self)
    }
}

fn run_job(
    spool: &Spool,
    spec: &JobSpec,
    opts: &ServeOpts,
    ckpts: &AtomicUsize,
) -> Result<JobStatus> {
    match spec.engine {
        Engine::Host => {
            let mut tr = HostTrainer::new(spec.cfg.clone())?;
            drive(&mut tr, spool, spec, opts, ckpts)
        }
        Engine::Graph => {
            let dir = fsutil::artifacts_dir()?;
            if !dir.join("manifest.json").exists() {
                bail!(
                    "graph engine needs AOT artifacts at {} (run `make artifacts`), \
                     or submit with --engine host",
                    dir.display()
                );
            }
            let manifest = Manifest::load(&dir)?;
            let rt = Runtime::cpu(&dir)?;
            let preset = manifest.preset(&spec.cfg.preset)?;
            let mut tr = Trainer::new(&rt, preset, spec.cfg.clone())?;
            drive(&mut tr, spool, spec, opts, ckpts)
        }
    }
}

/// Shared step/checkpoint/status loop for both engines.
fn drive(
    tr: &mut dyn ServeEngine,
    spool: &Spool,
    spec: &JobSpec,
    opts: &ServeOpts,
    ckpts: &AtomicUsize,
) -> Result<JobStatus> {
    let t0 = Instant::now();
    let ckpt_root = spool.checkpoint_root(&spec.id);
    if has_checkpoint(&ckpt_root) {
        let step = tr.resume(&ckpt_root)?;
        log::info!("job {}: resuming from step {step}", spec.id);
    }
    let mut status = JobStatus::from_spec(spec, "running");
    status.opt_state_bytes = tr.opt_state_bytes();
    status.rank_shrink_events = tr.shrink_events();
    status.step = tr.step_count();
    let _ = status.write(spool);

    let mut last_loss = None;
    while tr.step_count() < spec.cfg.steps {
        let loss = tr.step()?;
        last_loss = Some(loss as f64);
        let s = tr.step_count();
        if spec.checkpoint_every > 0 && s % spec.checkpoint_every == 0 && s < spec.cfg.steps {
            tr.save(&ckpt_root)?;
            note_checkpoint(opts, ckpts, &spec.id);
            status.step = s;
            status.loss = last_loss;
            // adaptive-rank layouts shrink their state over the run
            status.opt_state_bytes = tr.opt_state_bytes();
            status.rank_shrink_events = tr.shrink_events();
            status.wall_secs = t0.elapsed().as_secs_f64();
            let _ = status.write(spool);
        }
    }
    // Final snapshot: the job's resumable (and verifiable) result.
    tr.save(&ckpt_root)?;
    status.state = "done".to_string();
    status.step = tr.step_count();
    status.loss = last_loss;
    status.opt_state_bytes = tr.opt_state_bytes();
    status.rank_shrink_events = tr.shrink_events();
    status.wall_secs = t0.elapsed().as_secs_f64();
    Ok(status)
}

/// Count a cadence checkpoint; with the `--die-after-checkpoints` test
/// hook armed, simulate a hard crash once the count is reached.
fn note_checkpoint(opts: &ServeOpts, ckpts: &AtomicUsize, id: &str) {
    let n = ckpts.fetch_add(1, Ordering::SeqCst) + 1;
    if opts.die_after_checkpoints > 0 && n >= opts.die_after_checkpoints {
        log::warn!(
            "serve: simulated crash after {n} checkpoints (while running {id}) — exiting {CRASH_EXIT_CODE}"
        );
        std::process::exit(CRASH_EXIT_CODE);
    }
}
