//! Per-job status files + the `mlorc status` aggregator.
//!
//! Workers write `status/<id>.json` atomically at claim time, on every
//! cadence checkpoint and at completion, so an external observer (or the
//! aggregator) always sees a coherent snapshot. The lifecycle directory a
//! spec sits in is the source of truth for `state`; the status file only
//! contributes progress numbers.

use anyhow::Result;

use crate::util::fsutil;
use crate::util::json::Json;

use super::queue::{Attempt, JobSpec, Spool, LIFECYCLE_DIRS};

#[derive(Debug, Clone)]
pub struct JobStatus {
    pub id: String,
    /// queued | running | done | failed
    pub state: String,
    pub step: usize,
    pub steps: usize,
    pub loss: Option<f64>,
    pub preset: String,
    pub method: String,
    pub task: String,
    pub engine: String,
    /// Optimizer-state bytes — what each cadence checkpoint pays on top
    /// of the parameters (small for MLorc: rank-l momentum factors).
    /// 0 until a worker measures the live states.
    pub opt_state_bytes: usize,
    /// Analytic momentum-state bytes from the registered variant layouts
    /// (`VariantDesc::state_bytes`, quantized layouts included) — known
    /// at submit time, so queued jobs report their memory budget too.
    pub momentum_state_bytes: usize,
    /// Adaptive-rank shrink events across the job's parameters (0 for
    /// fixed-rank layouts).
    pub rank_shrink_events: usize,
    pub wall_secs: f64,
    pub error: Option<String>,
    /// Failed-run history from the spec (retry/backoff bookkeeping).
    pub attempts: Vec<Attempt>,
}

impl JobStatus {
    pub fn from_spec(spec: &JobSpec, state: &str) -> JobStatus {
        JobStatus {
            id: spec.id.clone(),
            state: state.to_string(),
            step: 0,
            steps: spec.cfg.steps,
            loss: None,
            preset: spec.cfg.preset.clone(),
            method: spec.cfg.method.name().to_string(),
            task: spec.cfg.task.name(),
            engine: spec.engine.name().to_string(),
            opt_state_bytes: 0,
            momentum_state_bytes: super::host::preset_momentum_bytes(
                &spec.cfg.preset,
                spec.cfg.method,
            )
            .unwrap_or(0),
            rank_shrink_events: 0,
            wall_secs: 0.0,
            error: None,
            attempts: spec.attempts.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("state", Json::str(self.state.clone())),
            ("step", Json::num(self.step as f64)),
            ("steps", Json::num(self.steps as f64)),
            (
                "loss",
                match self.loss {
                    Some(x) if x.is_finite() => Json::num(x),
                    _ => Json::Null,
                },
            ),
            ("preset", Json::str(self.preset.clone())),
            ("method", Json::str(self.method.clone())),
            ("task", Json::str(self.task.clone())),
            ("engine", Json::str(self.engine.clone())),
            ("opt_state_bytes", Json::num(self.opt_state_bytes as f64)),
            ("momentum_state_bytes", Json::num(self.momentum_state_bytes as f64)),
            ("rank_shrink_events", Json::num(self.rank_shrink_events as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            (
                "error",
                match &self.error {
                    Some(e) => Json::str(e.clone()),
                    None => Json::Null,
                },
            ),
            ("attempts", Json::arr(self.attempts.iter().map(Attempt::to_json))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobStatus> {
        Ok(JobStatus {
            id: j.req("id")?.as_str()?.to_string(),
            state: j.req("state")?.as_str()?.to_string(),
            step: j.req("step")?.as_usize()?,
            steps: j.req("steps")?.as_usize()?,
            loss: match j.req("loss")? {
                Json::Null => None,
                v => Some(v.as_f64()?),
            },
            preset: j.req("preset")?.as_str()?.to_string(),
            method: j.req("method")?.as_str()?.to_string(),
            task: j.req("task")?.as_str()?.to_string(),
            engine: j.req("engine")?.as_str()?.to_string(),
            opt_state_bytes: j.req("opt_state_bytes")?.as_usize()?,
            // optional: status files written before these fields existed
            momentum_state_bytes: match j.get("momentum_state_bytes") {
                Some(v) => v.as_usize()?,
                None => 0,
            },
            rank_shrink_events: match j.get("rank_shrink_events") {
                Some(v) => v.as_usize()?,
                None => 0,
            },
            wall_secs: j.req("wall_secs")?.as_f64()?,
            error: match j.req("error")? {
                Json::Null => None,
                v => Some(v.as_str()?.to_string()),
            },
            // optional: status files written before retries existed
            attempts: match j.get("attempts") {
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(Attempt::from_json)
                    .collect::<Result<Vec<_>>>()?,
                None => Vec::new(),
            },
        })
    }

    pub fn write(&self, spool: &Spool) -> Result<()> {
        fsutil::write_atomic_site(
            &spool.status_path(&self.id),
            self.to_json().to_string_pretty().as_bytes(),
            "status_write",
        )
    }
}

fn state_of_dir(dir: &str) -> &'static str {
    match dir {
        "queue" => "queued",
        "running" => "running",
        "done" => "done",
        "cancelled" => "cancelled",
        _ => "failed",
    }
}

/// One status row per job in the spool, sorted by id. Unreadable specs
/// (e.g. a quarantined submission) still get a row carrying the parse
/// error instead of breaking the whole aggregation.
pub fn aggregate(spool: &Spool) -> Result<Vec<JobStatus>> {
    let mut out = Vec::new();
    for dir in LIFECYCLE_DIRS {
        let state = state_of_dir(dir);
        for id in spool.jobs_in(dir)? {
            let from_status = Json::from_file(&spool.status_path(&id))
                .ok()
                .and_then(|j| JobStatus::from_json(&j).ok());
            let mut st = match from_status {
                Some(st) => st,
                None => match spool.load_spec(dir, &id) {
                    Ok(spec) => JobStatus::from_spec(&spec, state),
                    Err(e) => {
                        let mut st = JobStatus {
                            id: id.clone(),
                            state: state.to_string(),
                            step: 0,
                            steps: 0,
                            loss: None,
                            preset: String::new(),
                            method: String::new(),
                            task: String::new(),
                            engine: String::new(),
                            opt_state_bytes: 0,
                            momentum_state_bytes: 0,
                            rank_shrink_events: 0,
                            wall_secs: 0.0,
                            error: None,
                            attempts: Vec::new(),
                        };
                        st.error = Some(format!("unreadable job spec: {e:#}"));
                        st
                    }
                },
            };
            st.state = state.to_string();
            // the spec is the attempt history of record: a status file
            // can lag (or never land, e.g. under injected ENOSPC)
            if let Ok(spec) = spool.load_spec(dir, &id) {
                if spec.attempts.len() > st.attempts.len() {
                    st.attempts = spec.attempts;
                }
            }
            out.push(st);
        }
    }
    out.sort_by(|a, b| a.id.cmp(&b.id));
    Ok(out)
}

/// Human-readable table + summary line.
pub fn render_table(rows: &[JobStatus]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<24} {:<8} {:>11} {:>10} {:>10} {:<12} {:<6}",
        "job", "state", "step", "loss", "opt-state", "method", "engine"
    );
    for r in rows {
        let loss = r.loss.map(|x| format!("{x:.4}")).unwrap_or_else(|| "-".to_string());
        // live measurement once a worker ran; analytic layout estimate
        // ("~") before that, so queued jobs still show their budget
        let opt = if r.opt_state_bytes > 0 {
            format!("{:.1}KB", r.opt_state_bytes as f64 / 1e3)
        } else if r.momentum_state_bytes > 0 {
            format!("~{:.1}KB", r.momentum_state_bytes as f64 / 1e3)
        } else {
            "-".to_string()
        };
        let _ = writeln!(
            s,
            "{:<24} {:<8} {:>6}/{:<4} {:>10} {:>10} {:<12} {:<6}",
            r.id, r.state, r.step, r.steps, loss, opt, r.method, r.engine
        );
        if let Some(err) = &r.error {
            let _ = writeln!(s, "    error: {err}");
        }
        if !r.attempts.is_empty() {
            let last = r.attempts.last().unwrap();
            let _ = writeln!(
                s,
                "    attempts: {} failed run(s); last: {}",
                r.attempts.len(),
                last.error
            );
        }
    }
    let count = |st: &str| rows.iter().filter(|r| r.state == st).count();
    let _ = write!(
        s,
        "jobs: {} total — {} queued, {} running, {} done, {} failed, {} cancelled",
        rows.len(),
        count("queued"),
        count("running"),
        count("done"),
        count("failed"),
        count("cancelled")
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, RunConfig, TaskKind};
    use crate::serve::queue::Engine;

    fn spec(id: &str) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            engine: Engine::Host,
            checkpoint_every: 5,
            priority: 0,
            attempts: Vec::new(),
            not_before_unix_ms: 0,
            cfg: RunConfig::new("host-nano", Method::MlorcLion, TaskKind::MathChain, 30),
        }
    }

    #[test]
    fn status_json_roundtrip() {
        let mut st = JobStatus::from_spec(&spec("job001_x"), "running");
        st.step = 12;
        st.loss = Some(0.25);
        st.opt_state_bytes = 4096;
        let back = JobStatus::from_json(&st.to_json()).unwrap();
        assert_eq!(back.id, "job001_x");
        assert_eq!(back.step, 12);
        assert_eq!(back.loss, Some(0.25));
        assert_eq!(back.error, None);
        // NaN loss must serialize as null, not invalid JSON
        st.loss = Some(f64::NAN);
        let text = st.to_json().to_string_compact();
        assert!(Json::parse(&text).is_ok(), "unparseable: {text}");
    }

    #[test]
    fn aggregate_reads_lifecycle_dirs() {
        let root =
            std::env::temp_dir().join(format!("mlorc_status_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let spool = Spool::open(&root).unwrap();
        spool.submit(&spec("job001_a")).unwrap();
        spool.submit(&spec("job002_b")).unwrap();
        spool.submit(&spec("job003_c")).unwrap();
        let claimed = spool.claim_next().unwrap().unwrap();
        let mut st = JobStatus::from_spec(&claimed, "running");
        st.step = 7;
        st.write(&spool).unwrap();
        spool.cancel("job003_c").unwrap();

        let rows = aggregate(&spool).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].state, "running");
        assert_eq!(rows[0].step, 7);
        assert_eq!(rows[1].state, "queued");
        assert_eq!(rows[2].state, "cancelled");
        let table = render_table(&rows);
        assert!(table.contains("1 queued"), "{table}");
        assert!(table.contains("1 running"), "{table}");
        assert!(table.contains("1 cancelled"), "{table}");
        std::fs::remove_dir_all(&root).unwrap();
    }
}
