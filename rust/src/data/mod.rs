//! Synthetic workloads standing in for the paper's datasets
//! (DESIGN.md §2): math-chain (MetaMathQA/GSM8K analog), stack-code
//! (CodeFeedback/HumanEval analog), and SynGLUE (GLUE analog).
//!
//! All generation is deterministic from the run seed; train and eval
//! streams use disjoint RNG streams so eval examples are held out by
//! construction.

pub mod batcher;
mod mathchain;
mod stackcode;
mod synglue;
mod tokenizer;

pub use batcher::{Batch, ClsBatch, ClsDataset, LmDataset};
pub use mathchain::MathChain;
pub use stackcode::StackCode;
pub use synglue::{SynGlueTask, SYNGLUE_NAMES};
pub use tokenizer::{Tok, Tokenizer};

use crate::config::TaskKind;
use crate::linalg::Rng;

/// Instantiate the LM dataset for a generation task.
pub fn lm_dataset(task: TaskKind, seq: usize, seed: u64) -> Box<dyn LmDataset> {
    match task {
        TaskKind::MathChain => Box::new(MathChain::new(seq, seed)),
        TaskKind::StackCode => Box::new(StackCode::new(seq, seed)),
        TaskKind::SynGlue(_) => panic!("SynGLUE is a classification task"),
    }
}

/// Instantiate a SynGLUE classification dataset.
pub fn cls_dataset(task: TaskKind, seq: usize, seed: u64) -> SynGlueTask {
    match task {
        TaskKind::SynGlue(i) => SynGlueTask::new(i as usize, seq, seed),
        _ => panic!("{task:?} is not a classification task"),
    }
}

/// Derive the eval-stream RNG for a given run seed (disjoint from train).
pub fn eval_rng(seed: u64) -> Rng {
    Rng::new(seed ^ 0xE7A1_BEEF_CAFE_0001)
}
