//! Character-level tokenizer over a fixed symbol alphabet.
//!
//! Ids are stable across presets (all token ids < 64 <= smallest vocab);
//! larger-vocab presets simply leave the tail of the embedding unused,
//! mimicking fine-tuning a big-vocab model on a narrow domain — which is
//! exactly the regime where momentum is strongly low-rank.

use anyhow::{anyhow, Result};

/// Reserved control tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tok;

impl Tok {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const EOS: i32 = 2;
    pub const SEP: i32 = 3; // question/answer or sentence-pair separator
}

const ALPHABET: &str = "0123456789+-*/=()[]{}<>abcdefghijklmnopqrstuvwxyz.,!? ";
const BASE: i32 = 4;

#[derive(Debug, Clone)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn vocab_used() -> usize {
        BASE as usize + ALPHABET.len()
    }

    pub fn encode_char(c: char) -> Result<i32> {
        ALPHABET
            .find(c)
            .map(|i| BASE + i as i32)
            .ok_or_else(|| anyhow!("character '{c}' not in alphabet"))
    }

    pub fn encode(s: &str) -> Result<Vec<i32>> {
        s.chars().map(Self::encode_char).collect()
    }

    pub fn decode(ids: &[i32]) -> String {
        ids.iter()
            .map(|&id| match id {
                Tok::PAD => '_',
                Tok::BOS => '^',
                Tok::EOS => '$',
                Tok::SEP => '|',
                id => ALPHABET
                    .chars()
                    .nth((id - BASE) as usize)
                    .unwrap_or('?'),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = "12+(34*5)=x? ok";
        let ids = Tokenizer::encode(s).unwrap();
        assert_eq!(Tokenizer::decode(&ids), s);
        assert!(ids.iter().all(|&i| i >= BASE && (i as usize) < Tokenizer::vocab_used()));
    }

    #[test]
    fn control_tokens_disjoint_from_alphabet() {
        let ids = Tokenizer::encode(ALPHABET).unwrap();
        for ctl in [Tok::PAD, Tok::BOS, Tok::EOS, Tok::SEP] {
            assert!(!ids.contains(&ctl));
        }
    }

    #[test]
    fn fits_smallest_vocab() {
        assert!(Tokenizer::vocab_used() <= 256, "{}", Tokenizer::vocab_used());
    }

    #[test]
    fn rejects_unknown() {
        assert!(Tokenizer::encode("京").is_err());
    }
}
