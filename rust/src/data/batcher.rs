//! Batch assembly: pack generated examples into the fixed (B, T) int32
//! tensors the lowered graphs expect.

use crate::linalg::Rng;
use crate::tensor::TensorI32;

use super::Tok;

/// One LM example: full token sequence plus the half-open answer region
/// [ans_start, ans_end) that the loss/eval mask covers.
#[derive(Debug, Clone)]
pub struct LmExample {
    pub tokens: Vec<i32>,
    pub ans_start: usize,
    pub ans_end: usize,
}

pub trait LmDataset {
    /// Generate one example; must fit in `seq` tokens.
    fn sample(&self, rng: &mut Rng) -> LmExample;
    fn seq(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// LM training batch. `targets[t] = tokens[t+1]` inside the answer region,
/// `PAD_TARGET` (-1) elsewhere — fine-tuning on answers only, exactly like
/// instruction-tuning on MetaMathQA/CodeFeedback responses.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: TensorI32,
    pub targets: TensorI32,
    /// per-row answer regions (for exact-match scoring of `correct_mask`)
    pub answers: Vec<(usize, usize)>,
}

pub const PAD_TARGET: i32 = -1;

pub fn make_lm_batch(ds: &dyn LmDataset, batch: usize, rng: &mut Rng) -> Batch {
    let t = ds.seq();
    let mut tokens = vec![Tok::PAD; batch * t];
    let mut targets = vec![PAD_TARGET; batch * t];
    let mut answers = Vec::with_capacity(batch);
    for b in 0..batch {
        let ex = ds.sample(rng);
        debug_assert!(ex.tokens.len() <= t, "{} > {}", ex.tokens.len(), t);
        debug_assert!(ex.ans_start < ex.ans_end && ex.ans_end <= ex.tokens.len());
        let row = &mut tokens[b * t..(b + 1) * t];
        row[..ex.tokens.len()].copy_from_slice(&ex.tokens);
        // next-token targets restricted to the answer region: position p
        // predicts token p+1, so the supervised positions are
        // [ans_start - 1, ans_end - 1).
        let trow = &mut targets[b * t..(b + 1) * t];
        for p in (ex.ans_start - 1)..(ex.ans_end - 1) {
            trow[p] = ex.tokens[p + 1];
        }
        answers.push((ex.ans_start - 1, ex.ans_end - 1));
    }
    Batch {
        tokens: TensorI32::new(vec![batch, t], tokens).unwrap(),
        targets: TensorI32::new(vec![batch, t], targets).unwrap(),
        answers,
    }
}

/// Exact-match rate given the eval graph's `correct_mask` (B, T).
pub fn exact_match(batch: &Batch, correct_mask: &crate::tensor::Tensor) -> f32 {
    let (b, t) = (batch.tokens.shape[0], batch.tokens.shape[1]);
    assert_eq!(correct_mask.shape, vec![b, t]);
    let mut hits = 0usize;
    for (row, (s, e)) in batch.answers.iter().enumerate() {
        let all = (*s..*e).all(|p| correct_mask.data[row * t + p] > 0.5);
        hits += all as usize;
    }
    hits as f32 / b as f32
}

/// Token-level accuracy over supervised positions.
pub fn token_accuracy(batch: &Batch, correct_mask: &crate::tensor::Tensor) -> f32 {
    let t = batch.tokens.shape[1];
    let mut num = 0.0f32;
    let mut den = 0.0f32;
    for (row, (s, e)) in batch.answers.iter().enumerate() {
        for p in *s..*e {
            num += correct_mask.data[row * t + p];
            den += 1.0;
        }
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

// --------------------------------------------------- classification ----

#[derive(Debug, Clone)]
pub struct ClsExample {
    pub tokens: Vec<i32>,
    pub label: i32,
}

pub trait ClsDataset {
    fn sample(&self, rng: &mut Rng) -> ClsExample;
    fn seq(&self) -> usize;
    fn n_cls(&self) -> usize;
    fn name(&self) -> &'static str;
}

#[derive(Debug, Clone)]
pub struct ClsBatch {
    pub tokens: TensorI32,
    pub labels: TensorI32,
}

pub fn make_cls_batch(ds: &dyn ClsDataset, batch: usize, rng: &mut Rng) -> ClsBatch {
    let t = ds.seq();
    let mut tokens = vec![Tok::PAD; batch * t];
    let mut labels = vec![0i32; batch];
    for b in 0..batch {
        let ex = ds.sample(rng);
        debug_assert!(ex.tokens.len() <= t);
        tokens[b * t..b * t + ex.tokens.len()].copy_from_slice(&ex.tokens);
        labels[b] = ex.label;
    }
    ClsBatch {
        tokens: TensorI32::new(vec![batch, t], tokens).unwrap(),
        labels: TensorI32::new(vec![batch], labels).unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    struct Fixed;
    impl LmDataset for Fixed {
        fn sample(&self, _rng: &mut Rng) -> LmExample {
            // ^ 5 5 | 7 $  with answer "7 $"
            LmExample { tokens: vec![1, 9, 9, 3, 11, 2], ans_start: 4, ans_end: 6 }
        }
        fn seq(&self) -> usize {
            8
        }
        fn name(&self) -> &'static str {
            "fixed"
        }
    }

    #[test]
    fn lm_batch_layout() {
        let mut rng = Rng::new(0);
        let b = make_lm_batch(&Fixed, 2, &mut rng);
        assert_eq!(b.tokens.shape, vec![2, 8]);
        // positions 3 and 4 predict tokens 4 and 5 (the answer region)
        let trow = &b.targets.data[0..8];
        assert_eq!(trow, &[-1, -1, -1, 11, 2, -1, -1, -1]);
        // padding after EOS
        assert_eq!(b.tokens.data[6], Tok::PAD);
    }

    #[test]
    fn exact_match_requires_all_positions() {
        let mut rng = Rng::new(0);
        let b = make_lm_batch(&Fixed, 2, &mut rng);
        let mut mask = Tensor::zeros(&[2, 8]);
        // row 0: both answer positions correct; row 1: one of two
        mask.data[3] = 1.0;
        mask.data[4] = 1.0;
        mask.data[8 + 3] = 1.0;
        assert!((exact_match(&b, &mask) - 0.5).abs() < 1e-6);
        assert!((token_accuracy(&b, &mask) - 0.75).abs() < 1e-6);
    }
}
