//! Stack-code corpus — the CodeFeedback/HumanEval stand-in.
//!
//! Each example is a random typed-bracket "program" (identifiers
//! interleaved with nested `()[]{}<>` scopes); the task is to emit the
//! exact closing sequence for all currently-open scopes. Solving it
//! requires a pushdown model of the prefix — the classic structured
//! analogue of code completion.
//!
//! `^ (ab[cd{e | }])  $`  — prompt before SEP, closing sequence after.

use crate::linalg::Rng;

use super::batcher::{LmDataset, LmExample};
use super::tokenizer::{Tok, Tokenizer};

const OPEN: [char; 4] = ['(', '[', '{', '<'];
const CLOSE: [char; 4] = [')', ']', '}', '>'];
const IDENT: &str = "abcdefghij";

#[derive(Debug, Clone)]
pub struct StackCode {
    seq: usize,
    max_depth: usize,
    _seed: u64,
}

impl StackCode {
    pub fn new(seq: usize, seed: u64) -> StackCode {
        let max_depth = ((seq.saturating_sub(8)) / 6).clamp(2, 6);
        StackCode { seq, max_depth, _seed: seed }
    }
}

impl LmDataset for StackCode {
    fn sample(&self, rng: &mut Rng) -> LmExample {
        // Build prompt with a random walk over open/ident/close moves,
        // keeping the final stack non-empty so there is something to close.
        let budget = self.seq - 6; // BOS, SEP, EOS + closing worst case
        let mut prompt = String::new();
        let mut stack: Vec<usize> = Vec::new();
        let target_len = rng.range(budget / 2, budget - self.max_depth);
        while prompt.len() + stack.len() + 1 < target_len {
            let can_open = stack.len() < self.max_depth;
            let can_close = stack.len() > 1; // keep at least one open scope
            let r = rng.uniform();
            if can_open && r < 0.35 {
                let k = rng.below(4);
                prompt.push(OPEN[k]);
                stack.push(k);
            } else if can_close && r < 0.5 {
                let k = stack.pop().unwrap();
                prompt.push(CLOSE[k]);
            } else {
                let c = IDENT.as_bytes()[rng.below(IDENT.len())] as char;
                prompt.push(c);
                if stack.is_empty() {
                    // ensure at least one scope opens early
                    let k = rng.below(4);
                    prompt.push(OPEN[k]);
                    stack.push(k);
                }
            }
        }
        let answer: String = stack.iter().rev().map(|&k| CLOSE[k]).collect();
        let mut tokens = vec![Tok::BOS];
        tokens.extend(Tokenizer::encode(&prompt).unwrap());
        tokens.push(Tok::SEP);
        let ans_start = tokens.len();
        tokens.extend(Tokenizer::encode(&answer).unwrap());
        tokens.push(Tok::EOS);
        let ans_end = tokens.len();
        debug_assert!(tokens.len() <= self.seq, "stack example too long: {}", tokens.len());
        LmExample { tokens, ans_start, ans_end }
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn name(&self) -> &'static str {
        "stack_code"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_closes(prompt: &str, answer: &str) {
        let mut stack = Vec::new();
        for c in prompt.chars().chain(answer.chars()) {
            if let Some(k) = OPEN.iter().position(|&o| o == c) {
                stack.push(k);
            } else if let Some(k) = CLOSE.iter().position(|&cl| cl == c) {
                assert_eq!(stack.pop(), Some(k), "mismatched close in {prompt}|{answer}");
            }
        }
        assert!(stack.is_empty(), "unclosed scopes in {prompt}|{answer}");
    }

    #[test]
    fn answers_close_all_scopes() {
        let ds = StackCode::new(48, 0);
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let ex = ds.sample(&mut rng);
            assert!(ex.tokens.len() <= 48);
            let prompt = Tokenizer::decode(&ex.tokens[1..ex.ans_start - 1]);
            let answer = Tokenizer::decode(&ex.tokens[ex.ans_start..ex.ans_end - 1]);
            assert!(!answer.is_empty());
            check_closes(&prompt, &answer);
        }
    }

    #[test]
    fn answer_length_varies() {
        // the closing sequence must not be constant-length, or the task
        // degenerates into copying
        let ds = StackCode::new(64, 0);
        let mut rng = Rng::new(4);
        let lens: Vec<usize> = (0..50)
            .map(|_| {
                let ex = ds.sample(&mut rng);
                ex.ans_end - ex.ans_start
            })
            .collect();
        assert!(lens.iter().max() > lens.iter().min());
    }
}
