//! SynGLUE — eight synthetic binary sequence-classification tasks standing
//! in for GLUE (Table 5). Each mirrors the *shape* of its namesake:
//! single-sentence acceptability/sentiment, sentence-pair
//! paraphrase/entailment/similarity — with graded difficulty so the task
//! suite is heterogeneous like the real benchmark.

use crate::linalg::Rng;

use super::batcher::{ClsDataset, ClsExample};
use super::tokenizer::{Tok, Tokenizer};

pub const SYNGLUE_NAMES: [&str; 8] =
    ["cola", "mnli", "mrpc", "qnli", "qqp", "rte", "sst2", "stsb"];

#[derive(Debug, Clone)]
pub struct SynGlueTask {
    pub index: usize,
    seq: usize,
    _seed: u64,
}

impl SynGlueTask {
    pub fn new(index: usize, seq: usize, seed: u64) -> SynGlueTask {
        assert!(index < 8);
        SynGlueTask { index, seq, _seed: seed }
    }

    fn seg_len(&self) -> usize {
        ((self.seq - 4) / 2).clamp(4, 24)
    }

    fn rand_word(&self, rng: &mut Rng, n: usize) -> Vec<i32> {
        (0..n)
            .map(|_| Tokenizer::encode_char((b'a' + rng.below(26) as u8) as char).unwrap())
            .collect()
    }

    // ---- single-sentence tasks -------------------------------------

    /// CoLA analog: "acceptability" = brackets in the sentence are balanced.
    fn gen_cola(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len();
        let mut s = String::new();
        let mut depth: usize = 0;
        for _ in 0..n {
            if depth > 0 && rng.chance(0.4) {
                s.push(')');
                depth -= 1;
            } else if rng.chance(0.35) {
                s.push('(');
                depth += 1;
            } else {
                s.push((b'a' + rng.below(8) as u8) as char);
            }
        }
        while depth > 0 && s.len() < n + 4 {
            s.push(')');
            depth -= 1;
        }
        let mut label = 1;
        if rng.chance(0.5) {
            // corrupt: flip one bracket or drop a closer
            label = 0;
            let mut chars: Vec<char> = s.chars().collect();
            let pos = rng.below(chars.len());
            match chars[pos] {
                '(' => chars[pos] = ')',
                ')' => chars[pos] = '(',
                _ => chars.push('('),
            }
            s = chars.into_iter().collect();
        }
        (Tokenizer::encode(&s).unwrap(), label)
    }

    /// SST-2 analog: sentiment = majority polarity among +/- marks buried
    /// in identifier noise.
    fn gen_sst2(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len() + 4;
        let pos_count = rng.range(0, n / 2);
        let neg_count = {
            let mut c = rng.range(0, n / 2);
            if c == pos_count {
                c = if rng.chance(0.5) { c + 1 } else { c.saturating_sub(1) };
                if c == pos_count {
                    c += 1;
                }
            }
            c
        };
        let mut chars: Vec<char> = Vec::new();
        chars.extend(std::iter::repeat('+').take(pos_count));
        chars.extend(std::iter::repeat('-').take(neg_count));
        while chars.len() < n {
            chars.push((b'a' + rng.below(12) as u8) as char);
        }
        rng.shuffle(&mut chars);
        let s: String = chars.into_iter().collect();
        let label = (pos_count > neg_count) as i32;
        (Tokenizer::encode(&s).unwrap(), label)
    }

    // ---- sentence-pair tasks ---------------------------------------

    fn pair(&self, a: &[i32], b: &[i32]) -> Vec<i32> {
        let mut out = a.to_vec();
        out.push(Tok::SEP);
        out.extend_from_slice(b);
        out
    }

    /// MRPC analog: paraphrase = second segment is a rotation of the first.
    fn gen_mrpc(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len();
        let a = self.rand_word(rng, n);
        if rng.chance(0.5) {
            let mut b = a.clone();
            b.rotate_left(rng.range(1, n));
            (self.pair(&a, &b), 1)
        } else {
            (self.pair(&a, &self.rand_word(rng, n)), 0)
        }
    }

    /// QQP analog: duplicate = rotation with up to 2 substitutions (harder
    /// positive class than MRPC).
    fn gen_qqp(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len();
        let a = self.rand_word(rng, n);
        if rng.chance(0.5) {
            let mut b = a.clone();
            b.rotate_left(rng.range(1, n));
            for _ in 0..rng.range(0, 3) {
                let p = rng.below(n);
                b[p] = self.rand_word(rng, 1)[0];
            }
            (self.pair(&a, &b), 1)
        } else {
            (self.pair(&a, &self.rand_word(rng, n)), 0)
        }
    }

    /// MNLI analog: entailment = every token of the second segment occurs
    /// in the first.
    fn gen_mnli(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len();
        let a = self.rand_word(rng, n);
        let m = n / 2;
        if rng.chance(0.5) {
            let b: Vec<i32> = (0..m).map(|_| a[rng.below(n)]).collect();
            (self.pair(&a, &b), 1)
        } else {
            let mut b: Vec<i32> = (0..m).map(|_| a[rng.below(n)]).collect();
            // inject a token guaranteed absent from a
            let absent = loop {
                let c = self.rand_word(rng, 1)[0];
                if !a.contains(&c) {
                    break c;
                }
            };
            b[rng.below(m)] = absent;
            (self.pair(&a, &b), 0)
        }
    }

    /// QNLI analog: does the "question" token occur in the "passage"?
    fn gen_qnli(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len() + 6;
        let passage = self.rand_word(rng, n);
        let (q, label) = if rng.chance(0.5) {
            (passage[rng.below(n)], 1)
        } else {
            let absent = loop {
                let c = self.rand_word(rng, 1)[0];
                if !passage.contains(&c) {
                    break c;
                }
            };
            (absent, 0)
        };
        (self.pair(&[q], &passage), label)
    }

    /// RTE analog: MNLI with a shorter hypothesis and distractor overlap —
    /// the hardest pair task (RTE is the weakest GLUE score in the paper).
    fn gen_rte(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len();
        let a = self.rand_word(rng, n);
        let m = 3.max(n / 3);
        if rng.chance(0.5) {
            let b: Vec<i32> = (0..m).map(|_| a[rng.below(n)]).collect();
            (self.pair(&a, &b), 1)
        } else {
            // all-but-one token from a: high superficial overlap
            let mut b: Vec<i32> = (0..m).map(|_| a[rng.below(n)]).collect();
            let absent = loop {
                let c = self.rand_word(rng, 1)[0];
                if !a.contains(&c) {
                    break c;
                }
            };
            let p = rng.below(m);
            b[p] = absent;
            (self.pair(&a, &b), 0)
        }
    }

    /// STS-B analog (binarized): label is a deterministic function of the
    /// *observable* multiset token overlap between the two segments
    /// (threshold 0.7·n, the balance point given alphabet collisions).
    fn gen_stsb(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let n = self.seg_len();
        let a = self.rand_word(rng, n);
        let k = rng.range(0, n + 1); // copy k tokens, randomize the rest
        let mut b = a.clone();
        for i in k..n {
            b[i] = self.rand_word(rng, 1)[0];
        }
        rng.shuffle(&mut b);
        let label = (10 * multiset_overlap(&a, &b) > 7 * n) as i32;
        (self.pair(&a, &b), label)
    }
}

/// Size of the multiset intersection of two token sequences.
pub fn multiset_overlap(a: &[i32], b: &[i32]) -> usize {
    let mut counts = std::collections::BTreeMap::new();
    for t in a {
        *counts.entry(*t).or_insert(0usize) += 1;
    }
    let mut overlap = 0;
    for t in b {
        if let Some(c) = counts.get_mut(t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    overlap
}

impl ClsDataset for SynGlueTask {
    fn sample(&self, rng: &mut Rng) -> ClsExample {
        let (body, label) = match SYNGLUE_NAMES[self.index] {
            "cola" => self.gen_cola(rng),
            "mnli" => self.gen_mnli(rng),
            "mrpc" => self.gen_mrpc(rng),
            "qnli" => self.gen_qnli(rng),
            "qqp" => self.gen_qqp(rng),
            "rte" => self.gen_rte(rng),
            "sst2" => self.gen_sst2(rng),
            "stsb" => self.gen_stsb(rng),
            _ => unreachable!(),
        };
        let mut tokens = vec![Tok::BOS];
        tokens.extend(body);
        tokens.push(Tok::EOS);
        tokens.truncate(self.seq);
        ClsExample { tokens, label }
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn n_cls(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        SYNGLUE_NAMES[self.index]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn all_tasks_generate_valid_examples() {
        for idx in 0..8 {
            let ds = SynGlueTask::new(idx, 32, 0);
            let mut rng = Rng::new(idx as u64);
            let mut labels = [0usize; 2];
            for _ in 0..200 {
                let ex = ds.sample(&mut rng);
                assert!(ex.tokens.len() <= 32, "{} too long", ds.name());
                assert!(ex.label == 0 || ex.label == 1);
                assert_eq!(ex.tokens[0], Tok::BOS);
                labels[ex.label as usize] += 1;
            }
            // both classes occur, neither with < 20% mass
            assert!(labels[0] >= 40 && labels[1] >= 40, "{}: {labels:?}", ds.name());
        }
    }

    #[test]
    fn qnli_label_matches_membership() {
        prop::check(50, |rng| {
            let ds = SynGlueTask::new(3, 32, 0); // qnli
            let ex = ds.sample(rng);
            // layout: BOS q SEP passage... EOS
            let q = ex.tokens[1];
            let sep = 2;
            assert_eq!(ex.tokens[sep], Tok::SEP);
            let end = ex.tokens.len() - 1;
            let present = ex.tokens[sep + 1..end].contains(&q);
            prop::assert_true(present == (ex.label == 1), "qnli label consistency")
        });
    }

    #[test]
    fn stsb_label_is_function_of_observable_overlap() {
        // the label must be exactly recoverable from the input pair —
        // otherwise the task has irreducible label noise
        let ds = SynGlueTask::new(7, 40, 0);
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let ex = ds.sample(&mut rng);
            let sep = ex.tokens.iter().position(|&t| t == Tok::SEP).unwrap();
            let a = &ex.tokens[1..sep];
            let b = &ex.tokens[sep + 1..ex.tokens.len() - 1];
            let want = (10 * multiset_overlap(a, b) > 7 * a.len()) as i32;
            assert_eq!(want, ex.label);
        }
    }
}
