//! Math-chain corpus — the MetaMathQA/GSM8K stand-in.
//!
//! Each example is an arithmetic chain whose evaluation requires carrying
//! intermediate state, e.g. `^ 17+28*3 = | 101 $`. Multiplication binds
//! first (standard precedence) and operands are sized so answers stay
//! within a few digits; the model must learn multi-digit arithmetic with
//! carries — hard enough that fine-tuning methods separate, easy enough
//! that a small transformer reaches non-trivial exact match in hundreds of
//! steps.

use crate::linalg::Rng;

use super::batcher::{LmDataset, LmExample};
use super::tokenizer::{Tok, Tokenizer};

#[derive(Debug, Clone)]
pub struct MathChain {
    seq: usize,
    /// number of binary ops in the chain (1..=max_ops, scaled by seq)
    max_ops: usize,
    _seed: u64,
}

impl MathChain {
    pub fn new(seq: usize, seed: u64) -> MathChain {
        // keep prompt+answer comfortably under seq
        let max_ops = ((seq.saturating_sub(12)) / 8).clamp(1, 4);
        MathChain { seq, max_ops, _seed: seed }
    }

    fn gen_expr(&self, rng: &mut Rng) -> (String, i64) {
        let n_ops = rng.range(1, self.max_ops + 1);
        let mut expr = String::new();
        // terms joined by + or -, each term either a number or a product
        let mut value = 0i64;
        let mut sign = 1i64;
        for i in 0..=n_ops {
            if i > 0 {
                if rng.chance(0.5) {
                    expr.push('+');
                    sign = 1;
                } else {
                    expr.push('-');
                    sign = -1;
                }
            }
            let term_val = if rng.chance(0.35) {
                let a = rng.range(2, 13) as i64;
                let b = rng.range(2, 13) as i64;
                expr.push_str(&format!("{a}*{b}"));
                a * b
            } else {
                let a = rng.range(1, 100) as i64;
                expr.push_str(&a.to_string());
                a
            };
            value += sign * term_val;
        }
        (expr, value)
    }
}

impl LmDataset for MathChain {
    fn sample(&self, rng: &mut Rng) -> LmExample {
        let (expr, value) = self.gen_expr(rng);
        let prompt = format!("{expr}=");
        let answer = value.to_string();
        let mut tokens = vec![Tok::BOS];
        tokens.extend(Tokenizer::encode(&prompt).unwrap());
        tokens.push(Tok::SEP);
        let ans_start = tokens.len();
        tokens.extend(Tokenizer::encode(&answer).unwrap());
        tokens.push(Tok::EOS);
        let ans_end = tokens.len();
        debug_assert!(tokens.len() <= self.seq, "math example too long: {}", tokens.len());
        LmExample { tokens, ans_start, ans_end }
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn name(&self) -> &'static str {
        "math_chain"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::make_lm_batch;

    #[test]
    fn examples_fit_and_answers_parse() {
        let ds = MathChain::new(32, 0);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let ex = ds.sample(&mut rng);
            assert!(ex.tokens.len() <= 32);
            assert_eq!(ex.tokens[0], Tok::BOS);
            assert_eq!(ex.tokens[ex.ans_end - 1], Tok::EOS);
            // decode and verify arithmetic correctness end-to-end
            let text = Tokenizer::decode(&ex.tokens[1..ex.ans_start - 1]);
            let ans: i64 = Tokenizer::decode(&ex.tokens[ex.ans_start..ex.ans_end - 1])
                .parse()
                .unwrap();
            let expr = text.strip_suffix('=').unwrap();
            assert_eq!(eval_expr(expr), ans, "{expr} = {ans}");
        }
    }

    #[test]
    fn deterministic_given_rng() {
        let ds = MathChain::new(32, 0);
        let a = ds.sample(&mut Rng::new(5));
        let b = ds.sample(&mut Rng::new(5));
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn batches_have_supervision() {
        let ds = MathChain::new(32, 0);
        let mut rng = Rng::new(2);
        let b = make_lm_batch(&ds, 8, &mut rng);
        assert!(b.targets.data.iter().any(|&t| t >= 0));
    }

    /// tiny independent evaluator: + - with * precedence
    fn eval_expr(expr: &str) -> i64 {
        let mut total = 0i64;
        let mut sign = 1i64;
        let mut i = 0;
        let bytes = expr.as_bytes();
        while i < bytes.len() {
            match bytes[i] {
                b'+' => {
                    sign = 1;
                    i += 1;
                }
                b'-' => {
                    sign = -1;
                    i += 1;
                }
                _ => {
                    let start = i;
                    while i < bytes.len() && !matches!(bytes[i], b'+' | b'-') {
                        i += 1;
                    }
                    let term = &expr[start..i];
                    let prod: i64 = term.split('*').map(|x| x.parse::<i64>().unwrap()).product();
                    total += sign * prod;
                }
            }
        }
        total
    }
}
