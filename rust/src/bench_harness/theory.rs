//! Theorem 3.3 validation (extension experiment): MLorc-Lion on a smooth
//! nonconvex objective, tracking the average entrywise l1 gradient norm.
//!
//! Objective: f(W) = mean_i softplus-like smooth loss of <W, X_i> against
//! a planted low-rank signal — L-smooth, nonconvex through a tanh link,
//! with minibatch noise controlled by batch size b. Predictions checked:
//!   (1) avg ||grad f||_{1,1} decays ~ 1/sqrt(T) in the large-batch regime;
//!   (2) the noise floor scales like sigma * sqrt(d) / sqrt(b);
//!   (3) the beta1 <= 1/(4 gamma sqrt(d)) regime is stable.

use crate::linalg::{matmul, Rng};
use crate::optim::{MlorcLionState, OptHp};
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::report::Report;

pub struct TheoryOutcome {
    /// running-average series (t, avg ||grad||_1,1) for plotting
    #[allow(dead_code)]
    pub avg_grad_l11: Vec<(usize, f32)>,
    pub final_avg: f32,
}

/// Planted problem: y_i = tanh(<A_i, W*>) observed; loss = 0.5 (tanh(<A_i, W>) - y_i)^2.
struct Problem {
    targets: Tensor,
    m: usize,
    n: usize,
    noise: f32,
}

impl Problem {
    /// Full-batch gradient plus optional minibatch noise of scale
    /// `noise / sqrt(b)` (models Assumption 3.2's sigma^2 / b variance).
    fn grad(&self, w: &Tensor, b: usize, rng: &mut Rng) -> Tensor {
        // grad of 0.5||tanh(W) - tanh(W*)||^2 elementwise (diagonal A):
        // (tanh(w) - y) * (1 - tanh(w)^2) — smooth and nonconvex.
        let mut g = Tensor::zeros(&[self.m, self.n]);
        for ((gi, wi), ti) in g.data.iter_mut().zip(&w.data).zip(&self.targets.data) {
            let th = wi.tanh();
            *gi = (th - ti) * (1.0 - th * th);
        }
        if self.noise > 0.0 {
            let scale = self.noise / (b as f32).sqrt();
            for gi in g.data.iter_mut() {
                *gi += rng.normal_f32(scale);
            }
        }
        g
    }

    fn true_grad_l11(&self, w: &Tensor) -> f32 {
        let mut s = 0.0f64;
        for (wi, ti) in w.data.iter().zip(&self.targets.data) {
            let th = wi.tanh();
            s += (((th - ti) * (1.0 - th * th)) as f64).abs();
        }
        s as f32
    }
}

pub fn run_mlorc_lion_theory(
    m: usize,
    n: usize,
    rank: usize,
    steps: usize,
    batch: usize,
    noise: f32,
    seed: u64,
) -> TheoryOutcome {
    let mut rng = Rng::new(seed);
    // low-rank planted signal (the fine-tuning regime)
    let u = rng.gaussian_tensor(&[m, 2], 1.0);
    let v = rng.gaussian_tensor(&[2, n], 1.0);
    let mut targets = matmul(&u, &v);
    for t in targets.data.iter_mut() {
        *t = t.tanh();
    }
    let prob = Problem { targets, m, n, noise };

    let d = (m * n) as f32;
    // Theorem 3.3 parameter regime: alpha ~ sqrt(Delta / (L d T))
    let alpha = (1.0 / (d * steps as f32)).sqrt();
    let hp = OptHp { beta1: 0.9, beta2: 0.99, ..OptHp::lion() };
    let mut w = rng.gaussian_tensor(&[m, n], 0.5);
    let mut st = MlorcLionState::new(&[m, n], rank);
    let mut series = Vec::new();
    let mut acc = 0.0f64;
    for t in 0..steps {
        acc += prob.true_grad_l11(&w) as f64;
        let g = prob.grad(&w, batch, &mut rng);
        st.step(&mut w, &g, alpha, &hp, &mut rng);
        if (t + 1) % (steps / 20).max(1) == 0 {
            series.push((t + 1, (acc / (t + 1) as f64) as f32));
        }
    }
    let final_avg = (acc / steps as f64) as f32;
    TheoryOutcome { avg_grad_l11: series, final_avg }
}

pub fn run_theory(quick: bool) -> Report {
    let mut rep = Report::new(
        "theory",
        "MLorc-Lion convergence (Theorem 3.3)",
        "Theorem 3.3 / Section B",
    );
    let (m, n, r) = (24, 32, 4);
    let horizons: &[usize] = if quick { &[50, 200, 800] } else { &[50, 200, 800, 3200] };
    let batches: &[usize] = if quick { &[1, 16] } else { &[1, 4, 16, 64] };

    // (1) deterministic decay: avg ||grad||_1,1 after T steps ~ C/sqrt(T)
    let mut rows = Vec::new();
    let mut decays = Vec::new();
    for &t_max in horizons {
        let out = run_mlorc_lion_theory(m, n, r, t_max, 1, 0.0, 7);
        decays.push(out.final_avg);
        rows.push(vec![
            t_max.to_string(),
            format!("{:.4}", out.final_avg),
            format!("{:.4}", out.final_avg * (t_max as f32).sqrt()),
        ]);
    }
    rep.line("\n## Deterministic case (sigma = 0)\n");
    rep.table(&["T", "avg ||∇f||_1,1", "avg * sqrt(T) (should be ~flat/decreasing)"], &rows);

    // (2) stochastic floor vs batch size
    let mut rows = Vec::new();
    let mut floors = Vec::new();
    let t_max = if quick { 400 } else { 1600 };
    for &b in batches {
        let out = run_mlorc_lion_theory(m, n, r, t_max, b, 0.5, 11);
        floors.push(out.final_avg);
        rows.push(vec![b.to_string(), format!("{:.4}", out.final_avg)]);
    }
    rep.line("\n## Stochastic case: noise floor vs batch size (sigma > 0)\n");
    rep.table(&["batch b", "avg ||∇f||_1,1 (should shrink with b)"], &rows);

    let decay_ok = decays.windows(2).all(|w| w[1] < w[0]);
    let floor_ok = floors.first().unwrap() > floors.last().unwrap();
    rep.note(&format!(
        "decay monotone in T: {decay_ok}; noise floor shrinks with batch: {floor_ok}"
    ));
    rep.data = Json::obj(vec![
        ("decay", Json::arr(decays.iter().map(|x| Json::num(*x as f64)))),
        ("floors", Json::arr(floors.iter().map(|x| Json::num(*x as f64)))),
        ("decay_monotone", Json::Bool(decay_ok)),
        ("floor_shrinks", Json::Bool(floor_ok)),
    ]);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_norm_decays_with_horizon() {
        let short = run_mlorc_lion_theory(16, 16, 4, 50, 1, 0.0, 3);
        let long = run_mlorc_lion_theory(16, 16, 4, 800, 1, 0.0, 3);
        assert!(
            long.final_avg < short.final_avg,
            "{} !< {}",
            long.final_avg,
            short.final_avg
        );
    }

    #[test]
    fn larger_batch_lowers_noise_floor() {
        let small = run_mlorc_lion_theory(16, 16, 4, 400, 1, 0.5, 5);
        let big = run_mlorc_lion_theory(16, 16, 4, 400, 64, 0.5, 5);
        assert!(big.final_avg < small.final_avg);
    }
}
