//! Bench harness — regenerates every table and figure of the paper
//! (DESIGN.md §5 experiment index) on the synthetic substrate.
//!
//! Entry points: `mlorc bench --experiment <id>` (full scale) and the
//! `cargo bench` binaries (quick scale).

mod experiments;
pub mod plot;
mod report;
mod theory;

pub use experiments::{run_experiment, Scale, EXPERIMENT_IDS};
pub use report::{write_bench_json, Report};
pub use theory::run_theory;
