//! ASCII line plots for loss curves and σ-ratio series — the repo has no
//! plotting stack, so figure experiments render directly into the
//! markdown reports (and the e2e example's console output).

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str, points: Vec<(f64, f64)>) -> Series {
        Series { name: name.to_string(), points }
    }
}

/// Render series into a fixed-size character grid. Each series gets a
/// distinct glyph; overlapping cells show the later series.
pub fn ascii_plot(series: &[Series], width: usize, height: usize, title: &str) -> String {
    const GLYPHS: [char; 8] = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let pts: Vec<&(f64, f64)> = series.iter().flat_map(|s| &s.points).collect();
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in pts {
        xmin = xmin.min(*x);
        xmax = xmax.max(*x);
        ymin = ymin.min(*y);
        ymax = ymax.max(*y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for (x, y) in &s.points {
            let col = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let row = (((ymax - y) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = glyph;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>10.3} |")
        } else if i == height - 1 {
            format!("{ymin:>10.3} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>11}+{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>12}{:<w$.0}{:>8.0}\n", "", xmin, xmax, w = width - 7));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", GLYPHS[si % GLYPHS.len()], s.name));
    }
    out
}

/// Downsample a series to at most `n` evenly spaced points (plots stay
/// legible; loss curves carry thousands of steps).
pub fn decimate(points: &[(f64, f64)], n: usize) -> Vec<(f64, f64)> {
    if points.len() <= n || n == 0 {
        return points.to_vec();
    }
    let stride = points.len() as f64 / n as f64;
    (0..n)
        .map(|i| points[((i as f64 * stride) as usize).min(points.len() - 1)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_contains_series_glyphs_and_legend() {
        let s1 = Series::new("full", vec![(0.0, 5.0), (10.0, 1.0)]);
        let s2 = Series::new("mlorc", vec![(0.0, 5.0), (10.0, 1.2)]);
        let out = ascii_plot(&[s1, s2], 40, 10, "loss");
        assert!(out.contains('*') && out.contains('o'));
        assert!(out.contains("* = full"));
        assert!(out.contains("o = mlorc"));
        assert!(out.lines().count() > 10);
    }

    #[test]
    fn extremes_map_to_grid_corners() {
        let s = Series::new("x", vec![(0.0, 0.0), (1.0, 1.0)]);
        let out = ascii_plot(&[s], 20, 5, "t");
        let lines: Vec<&str> = out.lines().collect();
        // max y on the first grid row, min on the last
        assert!(lines[1].ends_with('*') || lines[1].contains('*'));
        assert!(lines[5].contains('*'));
    }

    #[test]
    fn empty_and_degenerate_input() {
        assert!(ascii_plot(&[], 10, 5, "t").contains("no data"));
        let s = Series::new("const", vec![(1.0, 2.0), (2.0, 2.0)]);
        let out = ascii_plot(&[s], 10, 5, "t");
        assert!(out.contains('*')); // flat series still renders
    }

    #[test]
    fn decimate_preserves_bounds() {
        let pts: Vec<(f64, f64)> = (0..1000).map(|i| (i as f64, i as f64)).collect();
        let d = decimate(&pts, 50);
        assert_eq!(d.len(), 50);
        assert_eq!(d[0], (0.0, 0.0));
        assert!(d.last().unwrap().0 > 950.0);
        assert_eq!(decimate(&pts[..10], 50).len(), 10);
    }
}
