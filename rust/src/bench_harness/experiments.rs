//! Experiment registry: one entry per paper table/figure.
//!
//! Absolute numbers live on a different substrate than the paper's
//! (synthetic corpora, CPU PJRT, presets instead of 7B models) — what must
//! reproduce is the *shape*: who wins, rough factors, orderings. Each
//! report records both the measurement and that expectation.

use anyhow::{bail, Result};

use crate::config::{Method, RunConfig, TaskKind};
use crate::coordinator::{MemoryAccountant, Trainer};
use crate::data::SYNGLUE_NAMES;
use crate::runtime::{Manifest, Runtime};
use crate::util::json::Json;

use super::plot::{ascii_plot, decimate, Series};
use super::report::{fmt_bytes, mean_std, Report};
use super::theory::run_theory;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scale {
    /// cargo-bench scale: minutes
    Quick,
    /// paper scale (for this substrate): tens of minutes
    Full,
}

pub const EXPERIMENT_IDS: [&str; 13] = [
    "fig1", "fig2", "fig3", "fig4", "table1", "table2", "table3", "table4", "table5", "table6",
    "table7", "table8", "theory",
];

pub fn run_experiment(
    id: &str,
    manifest: &Manifest,
    rt: &Runtime,
    scale: Scale,
    steps_override: Option<usize>,
    seeds_override: Option<usize>,
) -> Result<Report> {
    let ctx = Ctx { manifest, rt, scale, steps_override, seeds_override };
    match id {
        "fig1" => fig_spectral(&ctx, "fig1", &[TaskKind::SynGlue(7)]),
        "fig4" => fig_spectral(
            &ctx,
            "fig4",
            &[TaskKind::SynGlue(0), TaskKind::SynGlue(2), TaskKind::SynGlue(5), TaskKind::SynGlue(7)],
        ),
        "fig2" => fig_loss_curves(&ctx, "fig2", adamw_family(), "AdamW family"),
        "fig3" => fig_loss_curves(&ctx, "fig3", lion_family(), "Lion family"),
        "table1" => table1(&ctx),
        "table2" => table2(&ctx),
        "table3" => table3(&ctx),
        "table4" => table4(&ctx),
        "table5" => table5(&ctx),
        "table6" => table6(&ctx),
        "table7" => table7(&ctx),
        "table8" => table8(&ctx),
        "theory" => Ok(run_theory(ctx.scale == Scale::Quick)),
        other => bail!("unknown experiment '{other}' (have: {EXPERIMENT_IDS:?})"),
    }
}

struct Ctx<'a> {
    manifest: &'a Manifest,
    rt: &'a Runtime,
    scale: Scale,
    steps_override: Option<usize>,
    seeds_override: Option<usize>,
}

impl<'a> Ctx<'a> {
    fn steps(&self, quick: usize, full: usize) -> usize {
        self.steps_override
            .unwrap_or(match self.scale {
                Scale::Quick => quick,
                Scale::Full => full,
            })
    }

    fn seeds(&self, quick: usize, full: usize) -> usize {
        self.seeds_override
            .unwrap_or(match self.scale {
                Scale::Quick => quick,
                Scale::Full => full,
            })
    }

    /// LM-benchmark preset (tiny keeps full-table sweeps tractable on one
    /// CPU core; bump with MLORC_BENCH_PRESET).
    fn preset_name(&self) -> String {
        std::env::var("MLORC_BENCH_PRESET").unwrap_or_else(|_| "tiny".to_string())
    }

    fn run(&self, mut cfg: RunConfig) -> Result<crate::coordinator::TrainOutcome> {
        cfg.log_every = 0;
        let preset = self.manifest.preset(&cfg.preset)?;
        let mut tr = Trainer::new(self.rt, preset, cfg)?;
        tr.train()
    }

    /// nano-/tiny-scale LRs (Table 8 sweep confirms these). Keyed by
    /// registry id; unlisted (new) methods fall back to the AdamW-family
    /// scale.
    fn lr_for(&self, m: Method) -> f32 {
        match m.name() {
            "full_lion" | "mlorc_lion" | "galore_lion" => 2e-4,
            "lora_adamw" => 4e-3,
            "lora_lion" => 4e-4,
            "galore" => 4e-3,
            "ldadamw" => 1e-3,
            _ => 2e-3,
        }
    }
}

/// Brief full-AdamW "pretraining" of the backbone on the task corpus.
/// The paper fine-tunes *pretrained* models; starting every method from a
/// shared warm checkpoint restores that regime — without it, LoRA (frozen
/// random base + rank-4 adapters) cannot learn at all and the comparison
/// is meaningless. Returns the warmed parameter tensors.
fn warm_start(
    ctx: &Ctx,
    task: TaskKind,
    steps: usize,
) -> Result<Vec<crate::tensor::Tensor>> {
    let mut cfg = RunConfig::new(&ctx.preset_name(), Method::FullAdamW, task, steps);
    cfg.peak_lr = ctx.lr_for(Method::FullAdamW);
    cfg.seed = 9999; // disjoint from the per-method run seeds
    cfg.log_every = 0;
    cfg.eval_batches = 1;
    let preset = ctx.manifest.preset(&cfg.preset)?;
    let mut tr = Trainer::new(ctx.rt, preset, cfg)?;
    for _ in 0..steps {
        tr.train_step()?;
    }
    Ok(tr.params.values.clone())
}

/// Overwrite a trainer's backbone with warmed weights (shapes align by
/// construction: same preset, same spec order; cls runs share the LM
/// prefix and keep their fresh head).
fn apply_warm(tr: &mut Trainer, warm: &[crate::tensor::Tensor]) {
    for (v, w) in tr.params.values.iter_mut().zip(warm) {
        if v.shape == w.shape {
            *v = w.clone();
        }
    }
}

fn adamw_family() -> Vec<Method> {
    vec![
        Method::FullAdamW,
        Method::MlorcAdamW,
        Method::LoraAdamW,
        Method::Galore,
        Method::LdAdamW,
    ]
}

fn lion_family() -> Vec<Method> {
    vec![Method::FullLion, Method::MlorcLion, Method::LoraLion]
}

// ------------------------------------------------------------- figures ----

/// Figures 1 & 4: top-8 singular-value concentration of g, m, v during
/// full-AdamW fine-tuning on SynGLUE task(s).
fn fig_spectral(ctx: &Ctx, id: &str, tasks: &[TaskKind]) -> Result<Report> {
    let title = "top-8 singular value ratio of gradient / first / second moment";
    let mut rep = Report::new(id, title, if id == "fig1" { "Figure 1" } else { "Figure 4" });
    let steps = ctx.steps(20, 120);
    let mut all = Vec::new();
    for &task in tasks {
        let mut cfg = RunConfig::new(&ctx.preset_name(), Method::FullAdamW, task, steps);
        cfg.peak_lr = ctx.lr_for(Method::FullAdamW);
        cfg.spectral_every = (steps / 10).max(1);
        cfg.eval_batches = 1;
        cfg.log_every = 0;
        let preset = ctx.manifest.preset(&cfg.preset)?;
        let mut tr = Trainer::new(ctx.rt, preset, cfg)?;
        for _ in 0..steps {
            tr.train_step()?;
        }
        let mut rows = Vec::new();
        for rec in &tr.metrics.spectral {
            rows.push(vec![
                rec.step.to_string(),
                format!("{:.3}", rec.grad_ratio),
                format!("{:.3}", rec.m_ratio),
                format!("{:.3}", rec.v_ratio),
            ]);
        }
        rep.line(&format!("\n## task {}\n", task.name()));
        rep.table(&["step", "grad top-8 ratio", "m top-8 ratio", "v top-8 ratio"], &rows);
        // paper shape: v-ratio >= grad-ratio on average (second moment is
        // the most concentrated), m tracks grad
        let mean = |f: fn(&crate::coordinator::SpectralRecord) -> f32| {
            let xs: Vec<f32> = tr.metrics.spectral.iter().map(f).collect();
            xs.iter().sum::<f32>() / xs.len().max(1) as f32
        };
        let (g, m, v) = (mean(|r| r.grad_ratio), mean(|r| r.m_ratio), mean(|r| r.v_ratio));
        rep.note(&format!(
            "{}: mean ratios g={g:.3} m={m:.3} v={v:.3}; paper expectation v >= g: {}",
            task.name(),
            v >= g
        ));
        all.push(Json::obj(vec![
            ("task", Json::str(task.name())),
            ("g", Json::num(g as f64)),
            ("m", Json::num(m as f64)),
            ("v", Json::num(v as f64)),
        ]));
    }
    rep.data = Json::obj(vec![("tasks", Json::Arr(all))]);
    Ok(rep)
}

/// Figures 2 & 3: training-loss curves per method on math + code tasks.
fn fig_loss_curves(ctx: &Ctx, id: &str, methods: Vec<Method>, family: &str) -> Result<Report> {
    let mut rep = Report::new(
        id,
        &format!("training loss curves — {family}"),
        if id == "fig2" { "Figure 2" } else { "Figure 3" },
    );
    let steps = ctx.steps(30, 200);
    let warm_steps = ctx.steps(20, 80);
    let mut data_tasks = Vec::new();
    for task in [TaskKind::MathChain, TaskKind::StackCode] {
        let warm = warm_start(ctx, task, warm_steps)?;
        rep.line(&format!("\n## {} (final/smoothed training loss)\n", task.name()));
        let mut rows = Vec::new();
        let mut series_json = Vec::new();
        let mut finals = Vec::new();
        let mut plot_series = Vec::new();
        for &m in &methods {
            let mut cfg = RunConfig::new(&ctx.preset_name(), m, task, steps);
            cfg.peak_lr = ctx.lr_for(m);
            cfg.eval_batches = 2;
            let preset = ctx.manifest.preset(&cfg.preset)?;
            let mut tr = Trainer::new(ctx.rt, preset, cfg)?;
            apply_warm(&mut tr, &warm);
            for _ in 0..steps {
                tr.train_step()?;
            }
            let fin = tr.metrics.smoothed_final_loss(10).unwrap();
            finals.push((m, fin));
            let pts: Vec<(f64, f64)> = tr
                .metrics
                .steps
                .iter()
                .map(|s| (s.step as f64, s.loss as f64))
                .collect();
            plot_series.push(Series::new(m.name(), decimate(&pts, 60)));
            rows.push(vec![m.name().to_string(), format!("{fin:.4}")]);
            // decimated loss series for the JSON payload
            let series: Vec<Json> = tr
                .metrics
                .steps
                .iter()
                .step_by((steps / 40).max(1))
                .map(|s| Json::arr([Json::num(s.step as f64), Json::num(s.loss as f64)]))
                .collect();
            series_json.push(Json::obj(vec![
                ("method", Json::str(m.name())),
                ("series", Json::Arr(series)),
            ]));
        }
        rep.table(&["method", "final training loss"], &rows);
        rep.line("\n```");
        rep.line(&ascii_plot(&plot_series, 68, 16, &format!("training loss — {}", task.name())));
        rep.line("```");
        // shape check: mlorc close to full, galore worst (paper ordering)
        let get = |m: Method| finals.iter().find(|(x, _)| *x == m).map(|(_, l)| *l);
        if let (Some(full), Some(mlorc)) = (
            get(Method::FullAdamW).or(get(Method::FullLion)),
            get(Method::MlorcAdamW).or(get(Method::MlorcLion)),
        ) {
            rep.note(&format!(
                "{}: |mlorc - full| = {:.4} (paper: MLorc tracks full fine-tuning)",
                task.name(),
                (mlorc - full).abs()
            ));
        }
        data_tasks.push(Json::obj(vec![
            ("task", Json::str(task.name())),
            ("methods", Json::Arr(series_json)),
        ]));
    }
    rep.data = Json::obj(vec![("tasks", Json::Arr(data_tasks))]);
    Ok(rep)
}

// -------------------------------------------------------------- tables ----

/// Table 1: analytic memory formulas, instantiated per preset shape, and
/// cross-checked against the live coordinator state.
fn table1(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table1", "memory comparison (weights / optimizer states)", "Table 1");
    let preset = ctx.manifest.preset(&ctx.preset_name())?;
    let dims = preset.model;
    let (m, n, r) = (dims.d_model, dims.d_ff, dims.rank);
    rep.line(&format!("\nPer-matrix floats for W ∈ R^{{{m}x{n}}}, rank r={r}:\n"));
    let mut rows = Vec::new();
    for method in [Method::FullAdamW, Method::LoraAdamW, Method::Galore, Method::MlorcAdamW] {
        let (w, o) = MemoryAccountant::table1_row(method, m, n, r);
        rows.push(vec![method.name().to_string(), w.to_string(), o.to_string()]);
    }
    rep.table(&["method", "weights (floats)", "optimizer states (floats)"], &rows);

    rep.line("\nWhole-model analytic totals (per-layer updates on):\n");
    let mut rows = Vec::new();
    for method in [Method::FullAdamW, Method::LoraAdamW, Method::Galore, Method::MlorcAdamW, Method::LdAdamW] {
        let rep_m = MemoryAccountant::analytic(preset, method, true, false);
        rows.push(vec![
            method.name().to_string(),
            fmt_bytes(rep_m.weights_bytes + rep_m.lora_extra_weights_bytes),
            fmt_bytes(rep_m.opt_state_bytes),
            fmt_bytes(rep_m.grads_peak_bytes),
            fmt_bytes(rep_m.total()),
        ]);
    }
    rep.table(&["method", "weights", "opt states", "grads (peak)", "total"], &rows);
    rep.note("paper expectation: LoRA ≈ GaLore ≈ MLorc opt-state << Full; LDAdamW pays a full-size error buffer");
    Ok(rep)
}

/// Table 2: fine-tune on math-chain and stack-code; exact match mean±std
/// over seeds, 8 methods.
fn table2(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table2", "math (GSM8K-analog) and code (HumanEval-analog) exact match", "Table 2");
    let steps = ctx.steps(40, 300);
    let n_seeds = ctx.seeds(1, 4);
    let methods = [
        Method::FullAdamW,
        Method::MlorcAdamW,
        Method::LoraAdamW,
        Method::Galore,
        Method::LdAdamW,
        Method::FullLion,
        Method::MlorcLion,
        Method::LoraLion,
    ];
    let mut rows = Vec::new();
    let mut payload = Vec::new();
    for &m in &methods {
        let mut cells = vec![format!("{} (r={})", m.name(), ctx.manifest.preset(&ctx.preset_name())?.model.rank)];
        let mut task_json = Vec::new();
        for task in [TaskKind::MathChain, TaskKind::StackCode] {
            let warm = warm_start(ctx, task, ctx.steps(20, 80))?;
            let mut ems = Vec::new();
            let mut accs = Vec::new();
            for seed in 0..n_seeds {
                let mut cfg = RunConfig::new(&ctx.preset_name(), m, task, steps).with_seed(seed as u64);
                cfg.peak_lr = ctx.lr_for(m);
                cfg.eval_batches = 16;
                cfg.log_every = 0;
                let preset = ctx.manifest.preset(&cfg.preset)?;
                let mut tr = Trainer::new(ctx.rt, preset, cfg)?;
                apply_warm(&mut tr, &warm);
                let out = tr.train()?;
                let ev = out.eval.unwrap();
                ems.push(ev.exact_match * 100.0);
                accs.push(ev.accuracy * 100.0);
            }
            // EM needs long training to leave 0 at small scale; token
            // accuracy is the discriminating metric at quick scale.
            let (mean, std) = mean_std(&ems);
            let (amean, astd) = mean_std(&accs);
            cells.push(format!("{mean:.2} ± {std:.2}"));
            cells.push(format!("{amean:.2} ± {astd:.2}"));
            task_json.push(Json::obj(vec![
                ("task", Json::str(task.name())),
                ("mean", Json::num(mean as f64)),
                ("std", Json::num(std as f64)),
                ("acc_mean", Json::num(amean as f64)),
                ("acc_std", Json::num(astd as f64)),
            ]));
        }
        payload.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("tasks", Json::Arr(task_json)),
        ]));
        rows.push(cells);
    }
    rep.table(
        &["method", "math EM (%)", "math tok-acc (%)", "code EM (%)", "code tok-acc (%)"],
        &rows,
    );
    rep.note("paper shape: Full ≈ MLorc > LoRA > LDAdamW > GaLore; Lion family mirrors AdamW family");
    rep.data = Json::obj(vec![("rows", Json::Arr(payload))]);
    Ok(rep)
}

/// Table 3: memory footprint per method (measured state + modeled peak).
fn table3(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table3", "memory consumption on the math task", "Table 3");
    let mut rows = Vec::new();
    let mut payload = Vec::new();
    for m in [Method::MlorcAdamW, Method::LoraAdamW, Method::Galore, Method::LdAdamW, Method::FullAdamW] {
        let mut cfg = RunConfig::new(&ctx.preset_name(), m, TaskKind::MathChain, 2);
        cfg.peak_lr = ctx.lr_for(m);
        cfg.eval_batches = 1;
        cfg.log_every = 0;
        let preset = ctx.manifest.preset(&cfg.preset)?;
        let mut tr = Trainer::new(ctx.rt, preset, cfg)?;
        tr.train_step()?;
        tr.train_step()?;
        let mem = tr.memory_measured();
        rows.push(vec![
            m.name().to_string(),
            fmt_bytes(mem.weights_bytes),
            fmt_bytes(mem.opt_state_bytes),
            fmt_bytes(mem.grads_peak_bytes),
            fmt_bytes(mem.total()),
        ]);
        payload.push(mem.to_json());
    }
    rep.table(&["method", "weights", "opt state (measured)", "grads peak", "total"], &rows);
    rep.note("paper shape: MLorc ≈ GaLore ≈ LoRA < LDAdamW < Full");
    rep.data = Json::obj(vec![("rows", Json::Arr(payload))]);
    Ok(rep)
}

/// Table 4: wall-clock per method for a fixed step budget.
fn table4(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table4", "training time per method (fixed steps)", "Table 4");
    let steps = ctx.steps(15, 100);
    let mut rows = Vec::new();
    let mut payload = Vec::new();
    for m in [Method::MlorcAdamW, Method::LoraAdamW, Method::Galore, Method::LdAdamW, Method::FullAdamW] {
        let mut cfg = RunConfig::new(&ctx.preset_name(), m, TaskKind::MathChain, steps);
        cfg.peak_lr = ctx.lr_for(m);
        cfg.eval_batches = 1;
        let out = ctx.run(cfg)?;
        rows.push(vec![
            m.name().to_string(),
            format!("{:.1}s", out.wall_secs),
            format!("{:.0}ms", out.wall_secs * 1e3 / steps as f64),
        ]);
        payload.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("wall_secs", Json::num(out.wall_secs)),
        ]));
    }
    rep.table(&["method", "total", "per step"], &rows);
    rep.note("paper shape: MLorc ≈ LoRA < GaLore (projector SVD refresh); LDAdamW between");
    rep.data = Json::obj(vec![("rows", Json::Arr(payload))]);
    Ok(rep)
}

/// Table 5: SynGLUE accuracy across 8 tasks x 5 methods.
fn table5(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table5", "SynGLUE (GLUE analog) accuracy", "Table 5");
    let steps = ctx.steps(30, 250);
    let methods = [
        Method::FullAdamW,
        Method::MlorcAdamW,
        Method::LoraAdamW,
        Method::Galore,
        Method::LdAdamW,
    ];
    let task_range = match ctx.scale {
        Scale::Quick => 0..3usize,
        Scale::Full => 0..8usize,
    };
    let mut headers: Vec<&str> = vec!["method"];
    let names: Vec<&str> = task_range.clone().map(|i| SYNGLUE_NAMES[i]).collect();
    headers.extend(names.iter());
    let mut rows = Vec::new();
    let mut payload = Vec::new();
    for &m in &methods {
        let mut cells = vec![m.name().to_string()];
        let mut accs = Vec::new();
        for i in task_range.clone() {
            let mut cfg = RunConfig::new(&ctx.preset_name(), m, TaskKind::SynGlue(i as u8), steps);
            cfg.peak_lr = ctx.lr_for(m);
            cfg.eval_batches = 16;
            let out = ctx.run(cfg)?;
            let acc = out.eval.unwrap().accuracy * 100.0;
            accs.push(acc);
            cells.push(format!("{acc:.1}"));
        }
        let avg = accs.iter().sum::<f32>() / accs.len() as f32;
        cells.push(format!("{avg:.1}"));
        payload.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("avg", Json::num(avg as f64)),
            ("accs", Json::arr(accs.iter().map(|a| Json::num(*a as f64)))),
        ]));
        rows.push(cells);
    }
    let mut headers = headers;
    headers.push("Avg");
    rep.table(&headers, &rows);
    rep.note("paper shape: MLorc avg ≈ Full avg, > LoRA/LDAdamW > GaLore");
    rep.data = Json::obj(vec![("rows", Json::Arr(payload))]);
    Ok(rep)
}

/// Table 6: per-layer weight updates — MLorc vs LoRA peak footprint.
fn table6(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table6", "per-layer weight updates: MLorc vs LoRA", "Table 6");
    let preset = ctx.manifest.preset(&ctx.preset_name())?;
    let mut rows = Vec::new();
    let mut payload = Vec::new();
    for (label, method, per_layer) in [
        ("MLorc (per-layer update)", Method::MlorcAdamW, true),
        ("MLorc (full-grad)", Method::MlorcAdamW, false),
        ("LoRA", Method::LoraAdamW, false),
    ] {
        let mem = MemoryAccountant::analytic(preset, method, per_layer, false);
        rows.push(vec![
            label.to_string(),
            fmt_bytes(mem.weights_bytes + mem.lora_extra_weights_bytes),
            fmt_bytes(mem.opt_state_bytes),
            fmt_bytes(mem.grads_peak_bytes),
            fmt_bytes(mem.total()),
        ]);
        payload.push(Json::obj(vec![
            ("label", Json::str(label)),
            ("total", Json::num(mem.total() as f64)),
        ]));
    }
    rep.table(&["setting", "weights", "opt state", "grads peak", "total"], &rows);
    let mlorc_pl = payload[0].req("total").unwrap().as_f64().unwrap();
    let lora = payload[2].req("total").unwrap().as_f64().unwrap();
    rep.note(&format!(
        "paper claim (Table 6): MLorc with per-layer updates can beat LoRA: {} (here: mlorc={}, lora={})",
        mlorc_pl <= lora,
        fmt_bytes(mlorc_pl as usize),
        fmt_bytes(lora as usize)
    ));
    rep.data = Json::obj(vec![("rows", Json::Arr(payload))]);
    Ok(rep)
}

/// Table 7: ablations — compress m only / v only / both, on SynGLUE.
fn table7(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table7", "ablation: which momentum to compress", "Table 7");
    let steps = ctx.steps(30, 250);
    let methods = [Method::FullAdamW, Method::MlorcAdamW, Method::MlorcM, Method::MlorcV];
    let task_range = match ctx.scale {
        Scale::Quick => 0..3usize,
        Scale::Full => 0..8usize,
    };
    let mut rows = Vec::new();
    let mut payload = Vec::new();
    for &m in &methods {
        let mut cells = vec![m.name().to_string()];
        let mut accs = Vec::new();
        let mut state_bytes = 0usize;
        for i in task_range.clone() {
            let mut cfg = RunConfig::new(&ctx.preset_name(), m, TaskKind::SynGlue(i as u8), steps);
            cfg.peak_lr = ctx.lr_for(m);
            cfg.eval_batches = 16;
            let preset = ctx.manifest.preset(&cfg.preset)?;
            let mut tr = Trainer::new(ctx.rt, preset, cfg)?;
            let out = tr.train()?;
            state_bytes = tr.memory_measured().opt_state_bytes;
            accs.push(out.eval.unwrap().accuracy * 100.0);
        }
        let avg = accs.iter().sum::<f32>() / accs.len() as f32;
        for a in &accs {
            cells.push(format!("{a:.1}"));
        }
        cells.push(format!("{avg:.1}"));
        cells.push(fmt_bytes(state_bytes));
        payload.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("avg", Json::num(avg as f64)),
            ("opt_state_bytes", Json::num(state_bytes as f64)),
        ]));
        rows.push(cells);
    }
    let mut headers: Vec<&str> = vec!["method"];
    let names: Vec<&str> = task_range.clone().map(|i| SYNGLUE_NAMES[i]).collect();
    headers.extend(names.iter());
    headers.push("Avg");
    headers.push("opt state");
    rep.table(&headers, &rows);
    rep.note("paper shape: accuracies within ~1 point; full MLorc uses markedly less state than either half-ablation");
    rep.data = Json::obj(vec![("rows", Json::Arr(payload))]);
    Ok(rep)
}

/// Table 8/9: per-method learning-rate sweep (reports best LR + loss).
fn table8(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("table8", "tuned learning rates per method", "Tables 8-9");
    let steps = ctx.steps(15, 120);
    let grid = [1e-4f32, 3e-4, 1e-3, 2e-3, 4e-3, 8e-3];
    let methods = [Method::FullAdamW, Method::MlorcAdamW, Method::LoraAdamW, Method::Galore, Method::LdAdamW];
    let mut rows = Vec::new();
    let mut payload = Vec::new();
    for &m in &methods {
        let mut best = (f32::INFINITY, 0.0f32);
        let mut cells = vec![m.name().to_string()];
        for &lr in &grid {
            let mut cfg = RunConfig::new(&ctx.preset_name(), m, TaskKind::MathChain, steps).with_lr(lr);
            cfg.eval_batches = 1;
            let loss = match ctx.run(cfg) {
                Ok(out) => out.final_loss,
                Err(_) => f32::INFINITY, // divergence at this LR
            };
            if loss < best.0 {
                best = (loss, lr);
            }
        }
        cells.push(format!("{:.0e}", best.1));
        cells.push(if best.0.is_finite() { format!("{:.4}", best.0) } else { "diverged".into() });
        payload.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("best_lr", Json::num(best.1 as f64)),
            ("best_loss", Json::num(best.0 as f64)),
        ]));
        rows.push(cells);
    }
    rep.table(&["method", "best LR", "loss at best LR"], &rows);
    let lr_of = |name: &str| {
        payload
            .iter()
            .find(|p| p.req("method").unwrap().as_str().unwrap() == name)
            .map(|p| p.req("best_lr").unwrap().as_f64().unwrap())
    };
    if let (Some(full), Some(mlorc), Some(lora)) =
        (lr_of("full_adamw"), lr_of("mlorc_adamw"), lr_of("lora_adamw"))
    {
        rep.note(&format!(
            "paper claim: MLorc's best LR is closer to Full's than LoRA's is: |log ratio| mlorc={:.2} lora={:.2}",
            (mlorc / full).ln().abs(),
            (lora / full).ln().abs()
        ));
    }
    rep.data = Json::obj(vec![("rows", Json::Arr(payload))]);
    Ok(rep)
}
