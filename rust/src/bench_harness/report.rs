//! Markdown/JSON report writer for experiment outputs.

use std::path::Path;

use anyhow::Result;

use crate::util::fsutil;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub paper_ref: String,
    /// markdown body (tables, series)
    pub body: String,
    /// machine-readable payload
    pub data: Json,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, paper_ref: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            paper_ref: paper_ref.to_string(),
            body: String::new(),
            data: Json::Obj(Default::default()),
            notes: Vec::new(),
        }
    }

    /// Append a markdown table: header row + rows.
    pub fn table(&mut self, headers: &[&str], rows: &[Vec<String>]) {
        self.body.push_str("\n| ");
        self.body.push_str(&headers.join(" | "));
        self.body.push_str(" |\n|");
        for _ in headers {
            self.body.push_str("---|");
        }
        self.body.push('\n');
        for row in rows {
            self.body.push_str("| ");
            self.body.push_str(&row.join(" | "));
            self.body.push_str(" |\n");
        }
    }

    pub fn line(&mut self, s: &str) {
        self.body.push_str(s);
        self.body.push('\n');
    }

    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("# {} — {}\n\nreproduces: {}\n", self.id, self.title, self.paper_ref);
        out.push_str(&self.body);
        if !self.notes.is_empty() {
            out.push_str("\nNotes:\n");
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
        }
        out
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        fsutil::write_atomic(&dir.join(format!("{}.md", self.id)), self.to_markdown().as_bytes())?;
        fsutil::write_atomic(
            &dir.join(format!("{}.json", self.id)),
            self.data.to_string_pretty().as_bytes(),
        )
    }
}

/// Write a machine-readable benchmark payload (`BENCH_*.json`) at the
/// repository root, where the cross-PR perf trajectory is tracked.
/// Returns the path written.
pub fn write_bench_json(file_name: &str, data: &Json) -> Result<std::path::PathBuf> {
    let path = fsutil::find_repo_root()?.join(file_name);
    fsutil::write_atomic(&path, data.to_string_pretty().as_bytes())?;
    Ok(path)
}

/// Format bytes as GB/MB with 1 decimal.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1} MB", b as f64 / (1u64 << 20) as f64)
    } else {
        format!("{:.1} kB", b as f64 / 1024.0)
    }
}

/// mean ± std over a sample.
pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    let n = xs.len().max(1) as f32;
    let mean = xs.iter().sum::<f32>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_table_shape() {
        let mut r = Report::new("tX", "Test", "Table X");
        r.table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        let md = r.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn stats_and_bytes() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-6);
        assert!((s - (2.0f32 / 3.0).sqrt()).abs() < 1e-6);
        assert_eq!(fmt_bytes(2 * 1024 * 1024), "2.0 MB");
        assert!(fmt_bytes(3 << 30).contains("GB"));
    }
}
