//! Serve-fleet observability: a std-only, allocation-free-on-the-hot-path
//! metrics + tracing layer (ROADMAP item 2 prerequisite).
//!
//! Three pieces:
//!  * [`registry`] — a process-global registry of atomic [`Counter`]s,
//!    [`Gauge`]s and fixed-bucket log2 [`Histogram`]s. Recording is a
//!    handful of `Relaxed` atomic adds: no locks, no allocation, and the
//!    statics are `const`-constructed so there is no registration phase.
//!    [`registry::snapshot`] renders everything to JSON (including raw
//!    histogram buckets, so snapshots from different schedulers can be
//!    merged exactly before percentiles are taken).
//!  * [`span`] — RAII timers feeding those histograms. A [`Span`] holds
//!    `Option<Instant>`: `None` when observability is disabled, so a
//!    compiled-but-idle span costs one branch and no clock read.
//!  * [`journal`] — a per-scheduler append-only JSONL event journal
//!    (`events/<scheduler-id>.jsonl` under the spool) recording the job
//!    lifecycle: claim, lease renew/steal, retry, quarantine, checkpoint,
//!    complete.
//!
//! The contract (pinned by `tests/obs_identity.rs` and the
//! `bench_serve_load` overhead gate): instrumentation never changes
//! numerics — enabled or disabled, weights and optimizer state are
//! bitwise identical — and costs <2% step time when enabled, ~0 when
//! compiled but idle.
//!
//! Disable at runtime with `MLORC_NO_OBS=1` (any value other than `0`
//! counts as "set"). Tests and benches flip the gate in-process via
//! [`force_enabled`].

pub mod journal;
pub mod registry;
pub mod span;

pub use journal::Journal;
pub use registry::{snapshot, Counter, Gauge, Histogram};
pub use span::{span, Span};

use std::sync::atomic::{AtomicU8, Ordering};

/// 0 = unresolved (read `MLORC_NO_OBS` on first use), 1 = enabled,
/// 2 = disabled.
static STATE: AtomicU8 = AtomicU8::new(0);

/// Whether observability is on. Resolved once from `MLORC_NO_OBS` and
/// cached; afterwards a single `Relaxed` load.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => resolve_from_env(),
    }
}

#[cold]
fn resolve_from_env() -> bool {
    let off = std::env::var("MLORC_NO_OBS").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let state = if off { 2 } else { 1 };
    // A racing force_enabled() may have stored already; don't clobber it.
    let _ = STATE.compare_exchange(0, state, Ordering::Relaxed, Ordering::Relaxed);
    STATE.load(Ordering::Relaxed) == 1
}

/// Override the `MLORC_NO_OBS` gate in-process (tests / benches measuring
/// on-vs-off overhead and bit-identity without re-exec).
pub fn force_enabled(on: bool) {
    STATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// Serializes unit tests that flip [`force_enabled`] — the gate is
/// process-global and cargo runs tests on parallel threads.
#[cfg(test)]
pub(crate) fn test_gate_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}
