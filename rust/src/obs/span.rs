//! Scoped span timers: RAII guards that record elapsed microseconds into
//! a registry [`Histogram`] on drop.
//!
//! When observability is disabled the guard holds `None` — no clock read
//! on entry, one branch on drop. The guard is `#[must_use]`: binding it
//! (`let _span = span(&H);`) keeps it alive to the end of the scope,
//! which is the measured region.

use std::time::Instant;

use super::registry::Histogram;

/// Live span guard; see [`span`].
#[must_use = "a span records on drop — bind it to keep the scope timed"]
pub struct Span {
    t0: Option<Instant>,
    hist: &'static Histogram,
}

/// Start timing a scope into `hist` (microseconds).
#[inline]
pub fn span(hist: &'static Histogram) -> Span {
    let t0 = if super::enabled() { Some(Instant::now()) } else { None };
    Span { t0, hist }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.t0 {
            self.hist.record(t0.elapsed().as_micros() as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_once_into_its_histogram() {
        let _gate = crate::obs::test_gate_lock();
        crate::obs::force_enabled(true);
        static H: Histogram = Histogram::new();
        {
            let _span = span(&H);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(H.count(), 1);
        assert!(H.sum() >= 2_000, "slept 2ms but recorded {}us", H.sum());
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _gate = crate::obs::test_gate_lock();
        static H: Histogram = Histogram::new();
        crate::obs::force_enabled(false);
        {
            let _span = span(&H);
        }
        crate::obs::force_enabled(true);
        assert_eq!(H.count(), 0);
    }
}
