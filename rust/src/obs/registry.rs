//! Process-global metric registry: const-constructible atomic counters,
//! gauges and fixed-bucket log2 histograms.
//!
//! Recording never locks and never allocates: a [`Counter::add`] is one
//! `Relaxed` `fetch_add`, a [`Histogram::record`] is two. The registry is
//! a hand-maintained static table (no runtime registration), rendered to
//! JSON by [`snapshot`]. Histogram snapshots carry the raw bucket counts
//! so per-scheduler `metrics.json` files can be merged *exactly* (bucket
//! by bucket) before percentiles are extracted — `mlorc top` and
//! `bench_serve_load` both go through [`merge_snapshots`].
//!
//! Bucket scheme (fixed, 40 buckets): bucket 0 holds the value 0; bucket
//! `i >= 1` holds values in `[2^(i-1), 2^i)`; the last bucket is
//! open-ended. For microsecond timings that spans 1µs .. ~2^38µs (about
//! 3 days), which is more than any span we time. Percentiles are read
//! back as the *inclusive upper bound* of the bucket holding the target
//! rank (`2^i - 1`), a deterministic ≤2x overestimate — good enough for
//! p50/p90/p99 latency tracking and perfectly mergeable.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::util::fsutil;
use crate::util::json::Json;

/// Number of log2 buckets in every [`Histogram`].
pub const HIST_BUCKETS: usize = 40;

/// A monotone event counter.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Self {
        Self { v: AtomicU64::new(0) }
    }

    /// Add `n` (no-op while observability is disabled).
    #[inline]
    pub fn add(&self, n: u64) {
        if super::enabled() {
            self.v.fetch_add(n, Relaxed);
        }
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

/// A last-writer-wins instantaneous value.
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Self {
        Self { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: u64) {
        if super::enabled() {
            self.v.store(v, Relaxed);
        }
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Fixed-bucket log2 histogram; see the module docs for the bucket
/// scheme. `count`/`sum` totals are exact under concurrent recording
/// (each is a single atomic add), only interleaving order varies.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
}

/// `AtomicU64::new(0)` spelled once so the array below can be `const`.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);

impl Histogram {
    pub const fn new() -> Self {
        Self { buckets: [ZERO; HIST_BUCKETS], sum: AtomicU64::new(0) }
    }

    /// Bucket index for a value: 0 -> 0, else `1 + floor(log2 v)`,
    /// clamped to the open-ended last bucket.
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Inclusive upper bound of bucket `i` (the value percentiles report).
    pub fn bucket_upper(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one observation (no-op while observability is disabled).
    #[inline]
    pub fn record(&self, v: u64) {
        if super::enabled() {
            self.buckets[Self::bucket_index(v)].fetch_add(1, Relaxed);
            self.sum.fetch_add(v, Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Relaxed)).sum()
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    /// Percentile `q` in `[0, 1]` from the live buckets (0 if empty).
    pub fn percentile(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Relaxed)).collect();
        percentile_from_buckets(&counts, q)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Percentile from raw bucket counts (shared by live histograms and
/// merged snapshot buckets). Returns the inclusive upper bound of the
/// bucket holding rank `ceil(q * total)`.
pub fn percentile_from_buckets(counts: &[u64], q: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return Histogram::bucket_upper(i);
        }
    }
    Histogram::bucket_upper(counts.len().saturating_sub(1))
}

// ------------------------------------------------------------------ the
// registry proper: every metric in the process, by name.

pub static STEP_CLASSES: Counter = Counter::new();
pub static STEP_MEMBERS: Counter = Counter::new();
pub static POOL_DISPATCHES: Counter = Counter::new();
pub static POOL_BANDS: Counter = Counter::new();
pub static CKPT_SAVES: Counter = Counter::new();
pub static SERVE_CLAIMS: Counter = Counter::new();
pub static SERVE_JOBS_DONE: Counter = Counter::new();
pub static SERVE_JOBS_FAILED: Counter = Counter::new();
pub static SERVE_RETRIES: Counter = Counter::new();
pub static SERVE_LEASE_RENEWS: Counter = Counter::new();
pub static SERVE_LEASE_STEALS: Counter = Counter::new();
pub static SERVE_QUARANTINES: Counter = Counter::new();
pub static GEMM_CALLS: Counter = Counter::new();
pub static GEMM_MADDS: Counter = Counter::new();
/// Times the step loop blocked because both checkpoint scratch buffers
/// were in flight (the async writer's only hot-path stall).
pub static CKPT_BACKPRESSURE_STALLS: Counter = Counter::new();

pub static POOL_WORKERS: Gauge = Gauge::new();
pub static PROC_RSS_BYTES: Gauge = Gauge::new();
/// Checkpoint commits currently queued or running on the writer thread.
pub static CKPT_INFLIGHT: Gauge = Gauge::new();

pub static STEP_CLASS_US: Histogram = Histogram::new();
pub static STEP_RECONSTRUCT_US: Histogram = Histogram::new();
pub static STEP_FUSED_APPLY_US: Histogram = Histogram::new();
pub static RSVD_SKETCH_US: Histogram = Histogram::new();
pub static RSVD_QR_US: Histogram = Histogram::new();
pub static RSVD_PROJECT_US: Histogram = Histogram::new();
pub static POOL_DISPATCH_US: Histogram = Histogram::new();
pub static POOL_WAIT_US: Histogram = Histogram::new();
pub static CKPT_SAVE_US: Histogram = Histogram::new();
/// The step-path half of an async save: state copy into a scratch buffer.
pub static CKPT_SNAPSHOT_US: Histogram = Histogram::new();
/// The writer-thread half: encode, checksum, write, flip, fsync, prune.
pub static CKPT_COMMIT_US: Histogram = Histogram::new();
pub static SERVE_STEP_US: Histogram = Histogram::new();
pub static SERVE_JOB_US: Histogram = Histogram::new();

static COUNTERS: &[(&str, &Counter)] = &[
    ("step.classes", &STEP_CLASSES),
    ("step.members", &STEP_MEMBERS),
    ("pool.dispatches", &POOL_DISPATCHES),
    ("pool.bands", &POOL_BANDS),
    ("ckpt.saves", &CKPT_SAVES),
    ("serve.claims", &SERVE_CLAIMS),
    ("serve.jobs_done", &SERVE_JOBS_DONE),
    ("serve.jobs_failed", &SERVE_JOBS_FAILED),
    ("serve.retries", &SERVE_RETRIES),
    ("serve.lease_renews", &SERVE_LEASE_RENEWS),
    ("serve.lease_steals", &SERVE_LEASE_STEALS),
    ("serve.quarantines", &SERVE_QUARANTINES),
    ("gemm.calls", &GEMM_CALLS),
    ("gemm.madds", &GEMM_MADDS),
    ("ckpt.backpressure_stalls", &CKPT_BACKPRESSURE_STALLS),
];

static GAUGES: &[(&str, &Gauge)] = &[
    ("pool.workers", &POOL_WORKERS),
    ("proc.rss_bytes", &PROC_RSS_BYTES),
    ("ckpt.inflight", &CKPT_INFLIGHT),
];

static HISTOGRAMS: &[(&str, &Histogram)] = &[
    ("step.class_us", &STEP_CLASS_US),
    ("step.reconstruct_us", &STEP_RECONSTRUCT_US),
    ("step.fused_apply_us", &STEP_FUSED_APPLY_US),
    ("rsvd.sketch_us", &RSVD_SKETCH_US),
    ("rsvd.qr_us", &RSVD_QR_US),
    ("rsvd.project_us", &RSVD_PROJECT_US),
    ("pool.dispatch_us", &POOL_DISPATCH_US),
    ("pool.wait_us", &POOL_WAIT_US),
    ("ckpt.save_us", &CKPT_SAVE_US),
    ("ckpt.snapshot_us", &CKPT_SNAPSHOT_US),
    ("ckpt.commit_us", &CKPT_COMMIT_US),
    ("serve.step_us", &SERVE_STEP_US),
    ("serve.job_us", &SERVE_JOB_US),
];

/// Resident set size of this process in bytes (`/proc/self/statm` field
/// 2 × page size); 0 where procfs is unavailable.
pub fn rss_bytes() -> u64 {
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1).and_then(|f| f.parse::<u64>().ok()))
        .map(|pages| pages * 4096)
        .unwrap_or(0)
}

/// Render the whole registry to a `mlorc_metrics/v1` JSON snapshot.
/// Refreshes `proc.rss_bytes` first so every snapshot carries a live RSS
/// reading. Histograms serialize their raw buckets for exact merging.
pub fn snapshot() -> Json {
    PROC_RSS_BYTES.set(rss_bytes());
    let counters =
        COUNTERS.iter().map(|(n, c)| (*n, Json::num(c.get() as f64))).collect::<Vec<_>>();
    let gauges = GAUGES.iter().map(|(n, g)| (*n, Json::num(g.get() as f64))).collect::<Vec<_>>();
    let hists = HISTOGRAMS
        .iter()
        .map(|(n, h)| {
            let buckets: Vec<Json> =
                h.buckets.iter().map(|b| Json::num(b.load(Relaxed) as f64)).collect();
            (
                *n,
                Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("sum", Json::num(h.sum() as f64)),
                    ("buckets", Json::Arr(buckets)),
                ]),
            )
        })
        .collect::<Vec<_>>();
    Json::obj(vec![
        ("schema", Json::str("mlorc_metrics/v1")),
        ("unix_ms", Json::num(fsutil::unix_ms() as f64)),
        ("counters", Json::obj(counters)),
        ("gauges", Json::obj(gauges)),
        ("histograms", Json::obj(hists)),
    ])
}

/// Merge `mlorc_metrics/v1` snapshots from several schedulers into one:
/// counters and histogram buckets/sums add exactly; gauges take the
/// per-key maximum (RSS: the biggest process; workers: the widest pool).
pub fn merge_snapshots(snaps: &[Json]) -> Json {
    use std::collections::BTreeMap;
    let mut counters: BTreeMap<String, f64> = BTreeMap::new();
    let mut gauges: BTreeMap<String, f64> = BTreeMap::new();
    let mut hists: BTreeMap<String, (f64, f64, Vec<f64>)> = BTreeMap::new();
    let mut latest_ms = 0f64;
    for s in snaps {
        if let Some(ms) = s.get("unix_ms").and_then(|j| j.as_f64().ok()) {
            latest_ms = latest_ms.max(ms);
        }
        if let Some(obj) = s.get("counters").and_then(|j| j.as_obj().ok()) {
            for (k, v) in obj {
                if let Ok(x) = v.as_f64() {
                    *counters.entry(k.clone()).or_insert(0.0) += x;
                }
            }
        }
        if let Some(obj) = s.get("gauges").and_then(|j| j.as_obj().ok()) {
            for (k, v) in obj {
                if let Ok(x) = v.as_f64() {
                    let e = gauges.entry(k.clone()).or_insert(0.0);
                    *e = e.max(x);
                }
            }
        }
        if let Some(obj) = s.get("histograms").and_then(|j| j.as_obj().ok()) {
            for (k, v) in obj {
                let count = v.get("count").and_then(|j| j.as_f64().ok()).unwrap_or(0.0);
                let sum = v.get("sum").and_then(|j| j.as_f64().ok()).unwrap_or(0.0);
                let buckets: Vec<f64> = v
                    .get("buckets")
                    .and_then(|j| j.as_arr().ok())
                    .map(|a| a.iter().map(|b| b.as_f64().unwrap_or(0.0)).collect())
                    .unwrap_or_default();
                let e = hists.entry(k.clone()).or_insert((0.0, 0.0, vec![0.0; HIST_BUCKETS]));
                e.0 += count;
                e.1 += sum;
                for (slot, b) in e.2.iter_mut().zip(buckets) {
                    *slot += b;
                }
            }
        }
    }
    let counters = counters.into_iter().map(|(k, v)| (k, Json::num(v))).collect();
    let gauges = gauges.into_iter().map(|(k, v)| (k, Json::num(v))).collect();
    let hists = hists
        .into_iter()
        .map(|(k, (count, sum, buckets))| {
            let buckets: Vec<Json> = buckets.into_iter().map(Json::num).collect();
            (
                k,
                Json::obj(vec![
                    ("count", Json::num(count)),
                    ("sum", Json::num(sum)),
                    ("buckets", Json::Arr(buckets)),
                ]),
            )
        })
        .collect();
    Json::Obj(
        [
            ("schema".to_string(), Json::str("mlorc_metrics/v1")),
            ("unix_ms".to_string(), Json::num(latest_ms)),
            ("counters".to_string(), Json::Obj(counters)),
            ("gauges".to_string(), Json::Obj(gauges)),
            ("histograms".to_string(), Json::Obj(hists)),
        ]
        .into_iter()
        .collect(),
    )
}

/// Percentile from a snapshot histogram entry (`{count, sum, buckets}`),
/// as produced by [`snapshot`] or [`merge_snapshots`].
pub fn snapshot_percentile(hist: &Json, q: f64) -> u64 {
    let counts: Vec<u64> = hist
        .get("buckets")
        .and_then(|j| j.as_arr().ok())
        .map(|a| a.iter().map(|b| b.as_f64().unwrap_or(0.0) as u64).collect())
        .unwrap_or_default();
    percentile_from_buckets(&counts, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_log2_with_zero_bucket() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(1023), 10);
        assert_eq!(Histogram::bucket_index(1024), 11);
        // the last bucket is open-ended
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // bucket i's inclusive upper bound really is the largest value
        // that maps to bucket i
        for i in 1..HIST_BUCKETS - 1 {
            assert_eq!(Histogram::bucket_index(Histogram::bucket_upper(i)), i);
            assert_eq!(Histogram::bucket_index(Histogram::bucket_upper(i) + 1), i + 1);
        }
    }

    #[test]
    fn percentiles_from_buckets() {
        let _gate = crate::obs::test_gate_lock();
        crate::obs::force_enabled(true);
        static H: Histogram = Histogram::new();
        // 90 values in [256, 511] (bucket 9), 10 values in [4096, 8191]
        // (bucket 13): p50 lands in the low bucket, p99 in the tail.
        for _ in 0..90 {
            H.record(300);
        }
        for _ in 0..10 {
            H.record(5000);
        }
        assert_eq!(H.count(), 100);
        assert_eq!(H.sum(), 90 * 300 + 10 * 5000);
        assert_eq!(H.percentile(0.50), 511);
        assert_eq!(H.percentile(0.90), 511);
        assert_eq!(H.percentile(0.99), 8191);
        assert_eq!(H.percentile(1.0), 8191);
        // empty histogram reports 0
        static EMPTY: Histogram = Histogram::new();
        assert_eq!(EMPTY.percentile(0.99), 0);
    }

    #[test]
    fn concurrent_records_keep_exact_totals() {
        let _gate = crate::obs::test_gate_lock();
        crate::obs::force_enabled(true);
        static H: Histogram = Histogram::new();
        static C: Counter = Counter::new();
        std::thread::scope(|s| {
            for t in 0..8 {
                s.spawn(move || {
                    for i in 0..1000u64 {
                        H.record(t * 1000 + i);
                        C.add(1);
                    }
                });
            }
        });
        // totals are deterministic regardless of interleaving
        assert_eq!(C.get(), 8000);
        assert_eq!(H.count(), 8000);
        let expect: u64 = (0..8u64).map(|t| (0..1000u64).map(|i| t * 1000 + i).sum::<u64>()).sum();
        assert_eq!(H.sum(), expect);
    }

    #[test]
    fn snapshot_merge_is_exact() {
        let _gate = crate::obs::test_gate_lock();
        crate::obs::force_enabled(true);
        let a = Json::obj(vec![
            ("schema", Json::str("mlorc_metrics/v1")),
            ("unix_ms", Json::num(5.0)),
            ("counters", Json::obj(vec![("serve.claims", Json::num(3.0))])),
            ("gauges", Json::obj(vec![("proc.rss_bytes", Json::num(100.0))])),
            (
                "histograms",
                Json::obj(vec![(
                    "serve.step_us",
                    Json::obj(vec![
                        ("count", Json::num(2.0)),
                        ("sum", Json::num(600.0)),
                        ("buckets", Json::Arr(vec![Json::num(0.0), Json::num(2.0)])),
                    ]),
                )]),
            ),
        ]);
        let b = Json::obj(vec![
            ("schema", Json::str("mlorc_metrics/v1")),
            ("unix_ms", Json::num(9.0)),
            ("counters", Json::obj(vec![("serve.claims", Json::num(4.0))])),
            ("gauges", Json::obj(vec![("proc.rss_bytes", Json::num(50.0))])),
            (
                "histograms",
                Json::obj(vec![(
                    "serve.step_us",
                    Json::obj(vec![
                        ("count", Json::num(1.0)),
                        ("sum", Json::num(1.0)),
                        ("buckets", Json::Arr(vec![Json::num(0.0), Json::num(1.0)])),
                    ]),
                )]),
            ),
        ]);
        let m = merge_snapshots(&[a, b]);
        let claims = m.get("counters").unwrap().get("serve.claims").unwrap();
        assert_eq!(claims.as_f64().unwrap(), 7.0);
        let rss = m.get("gauges").unwrap().get("proc.rss_bytes").unwrap();
        assert_eq!(rss.as_f64().unwrap(), 100.0);
        let h = m.get("histograms").unwrap().get("serve.step_us").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(h.get("sum").unwrap().as_f64().unwrap(), 601.0);
        assert_eq!(snapshot_percentile(h, 0.5), 1);
        assert_eq!(m.get("unix_ms").unwrap().as_f64().unwrap(), 9.0);
    }
}
