//! Per-scheduler append-only JSONL event journal.
//!
//! One file per scheduler under the spool (`events/<scheduler-id>.jsonl`),
//! one JSON object per line:
//!
//! ```json
//! {"unix_ms": 1754550000123, "owner": "sched-42-1a2b", "ev": "claim",
//!  "job": "job0007", "attempt": 1}
//! ```
//!
//! `unix_ms`, `owner` and `ev` are always present; the rest are
//! event-specific. Event kinds emitted by the scheduler: `claim`,
//! `lease_renew`, `lease_steal`, `retry`, `quarantine`, `checkpoint`,
//! `complete`, `fail`. This journal supersedes the ad-hoc per-job
//! `work/<id>/claims.log` as the fleet-wide audit trail (claims.log is
//! kept for per-job exactly-once forensics).
//!
//! Appends take a `Mutex<File>` — the journal is deliberately *off* the
//! step hot path (a handful of events per job, not per step). When
//! observability is disabled ([`crate::obs::enabled`] is false) the
//! journal is inert: `open` creates no file and `event` is a no-op.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;

use crate::util::fsutil;
use crate::util::json::Json;

pub struct Journal {
    sink: Option<Mutex<File>>,
    owner: String,
}

impl Journal {
    /// Open (append) `dir/<owner>.jsonl`, creating `dir` if needed.
    /// Returns an inert journal when observability is disabled or the
    /// file cannot be opened (observability must never fail a job).
    pub fn open(dir: &Path, owner: &str) -> Journal {
        if !super::enabled() {
            return Self::disabled(owner);
        }
        let sink = std::fs::create_dir_all(dir)
            .ok()
            .and_then(|_| {
                let path = dir.join(format!("{owner}.jsonl"));
                OpenOptions::new().create(true).append(true).open(path).ok()
            })
            .map(Mutex::new);
        Journal { sink, owner: owner.to_string() }
    }

    /// A journal that records nothing (disabled observability, tests).
    pub fn disabled(owner: &str) -> Journal {
        Journal { sink: None, owner: owner.to_string() }
    }

    /// The scheduler id this journal stamps on every event (set even
    /// when the journal is inert — metrics snapshots reuse it).
    pub fn owner(&self) -> &str {
        &self.owner
    }

    /// Append one event line. `fields` are event-specific extras; the
    /// timestamp, owner and event kind are added here.
    pub fn event(&self, ev: &str, fields: Vec<(&str, Json)>) {
        let Some(sink) = &self.sink else { return };
        let mut obj = vec![
            ("unix_ms", Json::num(fsutil::unix_ms() as f64)),
            ("owner", Json::str(self.owner.as_str())),
            ("ev", Json::str(ev)),
        ];
        obj.extend(fields);
        let line = Json::obj(obj).to_string_compact();
        if let Ok(mut f) = sink.lock() {
            let _ = writeln!(f, "{line}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn journal_appends_one_json_object_per_line() {
        let _gate = crate::obs::test_gate_lock();
        crate::obs::force_enabled(true);
        let dir = std::env::temp_dir().join(format!("mlorc_journal_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let j = Journal::open(&dir, "sched-test");
        j.event("claim", vec![("job", Json::str("job001")), ("attempt", Json::num(1.0))]);
        j.event("complete", vec![("job", Json::str("job001"))]);
        let text = std::fs::read_to_string(dir.join("sched-test.jsonl")).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let v = Json::parse(line).unwrap();
            assert!(v.get("unix_ms").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(v.get("owner").unwrap().as_str().unwrap(), "sched-test");
            assert_eq!(v.get("job").unwrap().as_str().unwrap(), "job001");
        }
        assert_eq!(Json::parse(lines[0]).unwrap().get("ev").unwrap().as_str().unwrap(), "claim");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_journal_writes_no_file() {
        let _gate = crate::obs::test_gate_lock();
        crate::obs::force_enabled(false);
        let dir = std::env::temp_dir().join(format!("mlorc_journal_off_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let j = Journal::open(&dir, "sched-test");
        j.event("claim", vec![]);
        crate::obs::force_enabled(true);
        assert!(!dir.exists());
    }
}
