//! GEMM accounting for the optimizer fast-path audits.
//!
//! Every entry into a linalg GEMM (including the fused
//! reconstruction+apply kernels in `optim`) records its logical dims on
//! the *calling* thread when recording is armed. The MLorc acceptance
//! audit replays one optimizer step under recording and asserts the
//! factored recompression shape: per moment, exactly one O(m·n·l) GEMM
//! materializes (or is fused into) a dense m×n result, while every sketch
//! and projection GEMM has a thin (≤ (m+n)·l sized) output.
//!
//! Recording is thread-local so concurrent tests do not pollute each
//! other; kernels record once at entry, before any worker threads spawn.

use std::cell::RefCell;

/// One recorded GEMM: `out = lhs · rhs` with `out` of `out_rows × out_cols`
/// and a shared inner dimension, plus the op label for readability.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmRecord {
    pub op: &'static str,
    pub out_rows: usize,
    pub inner: usize,
    pub out_cols: usize,
}

impl GemmRecord {
    /// Multiply-add count of this GEMM.
    pub fn madds(&self) -> usize {
        self.out_rows * self.inner * self.out_cols
    }

    /// Number of elements the GEMM materializes.
    pub fn out_elems(&self) -> usize {
        self.out_rows * self.out_cols
    }

    /// True when the op is a fused reconstruction (writes no standalone
    /// dense intermediate — the product is consumed in-register by the
    /// optimizer apply epilogue).
    pub fn is_fused(&self) -> bool {
        self.op.starts_with("fused_")
    }
}

thread_local! {
    static RECORDS: RefCell<Option<Vec<GemmRecord>>> = const { RefCell::new(None) };
}

/// Arm recording on the current thread (clears any prior records).
pub fn start_recording() {
    RECORDS.with(|r| *r.borrow_mut() = Some(Vec::new()));
}

/// Disarm recording and return everything recorded since
/// [`start_recording`]. Returns an empty vec if recording was never armed.
pub fn finish_recording() -> Vec<GemmRecord> {
    RECORDS.with(|r| r.borrow_mut().take().unwrap_or_default())
}

/// Record one GEMM if recording is armed on this thread. Cheap when off.
///
/// Independently of the thread-local audit log, every call feeds the
/// process-global `gemm.calls` / `gemm.madds` observability counters
/// (`obs::registry`) so metrics snapshots carry cumulative GEMM work;
/// those are two relaxed atomic adds, disabled under `MLORC_NO_OBS`.
pub fn record(op: &'static str, out_rows: usize, inner: usize, out_cols: usize) {
    crate::obs::registry::GEMM_CALLS.add(1);
    crate::obs::registry::GEMM_MADDS.add((out_rows * inner * out_cols) as u64);
    RECORDS.with(|r| {
        if let Some(log) = r.borrow_mut().as_mut() {
            log.push(GemmRecord { op, out_rows, inner, out_cols });
        }
    });
}

/// Total multiply-adds across a record set.
pub fn total_madds(records: &[GemmRecord]) -> usize {
    records.iter().map(|r| r.madds()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_roundtrip() {
        assert!(finish_recording().is_empty());
        record("matmul", 3, 4, 5); // not armed: dropped
        start_recording();
        record("matmul", 3, 4, 5);
        record("fused_recon_adamw", 6, 2, 7);
        let recs = finish_recording();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].madds(), 60);
        assert!(!recs[0].is_fused());
        assert!(recs[1].is_fused());
        assert_eq!(total_madds(&recs), 60 + 84);
        assert!(finish_recording().is_empty());
    }
}
