//! 8-lane SIMD microkernels for the GEMM inner loops.
//!
//! Three tiers, selected once per process:
//!
//!  * **portable** — unrolled 8-wide lane arrays (`[f32; 8]` chunks with
//!    independent accumulators) that LLVM reliably autovectorizes without
//!    fast-math, on every architecture;
//!  * **x86-64 AVX2+FMA** — explicit `std::arch` intrinsics behind
//!    *runtime* feature detection (`is_x86_feature_detected!`), used when
//!    the CPU has them and `MLORC_NO_SIMD` is unset;
//!  * **aarch64 NEON** — explicit `std::arch` intrinsics, each 8-lane
//!    body as two 128-bit `float32x4` quads (quad 0 = lanes 0–3, quad 1 =
//!    lanes 4–7, so the dot summation tree is lane-compatible with the
//!    other tiers). NEON is baseline on aarch64, so there is no feature
//!    probe — only the `MLORC_NO_SIMD` escape hatch.
//!
//! Determinism contract: tier selection is process-global and every
//! routine fixes its per-element operation order by position only (8-wide
//! body from index 0, scalar tail) — never by band start — so banded
//! kernels stay bit-identical across thread counts. Tiers may differ
//! from each other in the last ulp (FMA contraction, dot-tree
//! rounding); the scalar-oracle property tests compare with tolerance.
//!
//! No multiply is ever skipped on a zero operand: `0 · NaN = NaN` and
//! `0 · Inf = NaN` propagate through every tier (pinned by the kernel
//! regression tests).

/// SIMD width in f32 lanes (one AVX 256-bit register, two NEON quads).
pub const LANES: usize = 8;

#[cfg(target_arch = "x86_64")]
fn avx_ok() -> bool {
    use std::sync::OnceLock;
    static OK: OnceLock<bool> = OnceLock::new();
    *OK.get_or_init(|| {
        if std::env::var_os("MLORC_NO_SIMD").is_some() {
            return false;
        }
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    })
}

#[cfg(not(target_arch = "x86_64"))]
fn avx_ok() -> bool {
    false
}

// NEON is baseline on aarch64, so there is nothing to feature-detect —
// only the MLORC_NO_SIMD escape hatch can turn the tier off.
#[cfg(target_arch = "aarch64")]
fn neon_ok() -> bool {
    use std::sync::OnceLock;
    static OK: OnceLock<bool> = OnceLock::new();
    *OK.get_or_init(|| std::env::var_os("MLORC_NO_SIMD").is_none())
}

/// True when the explicit `std::arch` tier is active (diagnostics/bench).
pub fn simd_tier() -> &'static str {
    if avx_ok() {
        return "avx2+fma";
    }
    #[cfg(target_arch = "aarch64")]
    if neon_ok() {
        return "neon";
    }
    "portable8"
}

// ------------------------------------------------------------------- axpy

/// `c[j] += a * b[j]` — the row-update workhorse of `gemm_nn`/`gemm_tn`
/// and the fused reconstruction rows.
#[inline]
pub fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if avx_ok() {
        unsafe { axpy_avx(c, a, b) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if neon_ok() {
        unsafe { axpy_neon(c, a, b) };
        return;
    }
    axpy_portable(c, a, b);
}

#[inline]
fn axpy_portable(c: &mut [f32], a: f32, b: &[f32]) {
    let mut cc = c.chunks_exact_mut(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (c8, b8) in (&mut cc).zip(&mut bc) {
        for i in 0..LANES {
            c8[i] += a * b8[i];
        }
    }
    for (cv, &bv) in cc.into_remainder().iter_mut().zip(bc.remainder()) {
        *cv += a * bv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx(c: &mut [f32], a: f32, b: &[f32]) {
    use std::arch::x86_64::*;
    let n = c.len().min(b.len());
    let va = _mm256_set1_ps(a);
    let mut j = 0;
    while j + LANES <= n {
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        let vc = _mm256_loadu_ps(c.as_ptr().add(j));
        _mm256_storeu_ps(c.as_mut_ptr().add(j), _mm256_fmadd_ps(va, vb, vc));
        j += LANES;
    }
    while j < n {
        *c.get_unchecked_mut(j) += a * *b.get_unchecked(j);
        j += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(c: &mut [f32], a: f32, b: &[f32]) {
    use std::arch::aarch64::*;
    let n = c.len().min(b.len());
    let va = vdupq_n_f32(a);
    let mut j = 0;
    while j + LANES <= n {
        let b0 = vld1q_f32(b.as_ptr().add(j));
        let b1 = vld1q_f32(b.as_ptr().add(j + 4));
        let c0 = vld1q_f32(c.as_ptr().add(j));
        let c1 = vld1q_f32(c.as_ptr().add(j + 4));
        vst1q_f32(c.as_mut_ptr().add(j), vfmaq_f32(c0, va, b0));
        vst1q_f32(c.as_mut_ptr().add(j + 4), vfmaq_f32(c1, va, b1));
        j += LANES;
    }
    while j < n {
        *c.get_unchecked_mut(j) += a * *b.get_unchecked(j);
        j += 1;
    }
}

/// Four simultaneous axpys against one shared `b` row:
/// `c_i[j] += v_i * b[j]` — the 4-row register tile of `gemm_nn` (loads
/// each `b` lane once per four output rows).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn axpy4(
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    v0: f32,
    v1: f32,
    v2: f32,
    v3: f32,
    b: &[f32],
) {
    debug_assert!(c0.len() == b.len() && c1.len() == b.len());
    debug_assert!(c2.len() == b.len() && c3.len() == b.len());
    #[cfg(target_arch = "x86_64")]
    if avx_ok() {
        unsafe { axpy4_avx(c0, c1, c2, c3, v0, v1, v2, v3, b) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if neon_ok() {
        unsafe { axpy4_neon(c0, c1, c2, c3, v0, v1, v2, v3, b) };
        return;
    }
    axpy4_portable(c0, c1, c2, c3, v0, v1, v2, v3, b);
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn axpy4_portable(
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    v0: f32,
    v1: f32,
    v2: f32,
    v3: f32,
    b: &[f32],
) {
    let n = b.len();
    let mut j = 0;
    while j + LANES <= n {
        for i in 0..LANES {
            let bv = b[j + i];
            c0[j + i] += v0 * bv;
            c1[j + i] += v1 * bv;
            c2[j + i] += v2 * bv;
            c3[j + i] += v3 * bv;
        }
        j += LANES;
    }
    while j < n {
        let bv = b[j];
        c0[j] += v0 * bv;
        c1[j] += v1 * bv;
        c2[j] += v2 * bv;
        c3[j] += v3 * bv;
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn axpy4_avx(
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    v0: f32,
    v1: f32,
    v2: f32,
    v3: f32,
    b: &[f32],
) {
    use std::arch::x86_64::*;
    // clamp like axpy_avx/dot_avx: never trust one operand's length alone
    let n = b.len().min(c0.len()).min(c1.len()).min(c2.len()).min(c3.len());
    let (w0, w1, w2, w3) =
        (_mm256_set1_ps(v0), _mm256_set1_ps(v1), _mm256_set1_ps(v2), _mm256_set1_ps(v3));
    let mut j = 0;
    while j + LANES <= n {
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        let x0 = _mm256_loadu_ps(c0.as_ptr().add(j));
        let x1 = _mm256_loadu_ps(c1.as_ptr().add(j));
        let x2 = _mm256_loadu_ps(c2.as_ptr().add(j));
        let x3 = _mm256_loadu_ps(c3.as_ptr().add(j));
        _mm256_storeu_ps(c0.as_mut_ptr().add(j), _mm256_fmadd_ps(w0, vb, x0));
        _mm256_storeu_ps(c1.as_mut_ptr().add(j), _mm256_fmadd_ps(w1, vb, x1));
        _mm256_storeu_ps(c2.as_mut_ptr().add(j), _mm256_fmadd_ps(w2, vb, x2));
        _mm256_storeu_ps(c3.as_mut_ptr().add(j), _mm256_fmadd_ps(w3, vb, x3));
        j += LANES;
    }
    while j < n {
        let bv = *b.get_unchecked(j);
        *c0.get_unchecked_mut(j) += v0 * bv;
        *c1.get_unchecked_mut(j) += v1 * bv;
        *c2.get_unchecked_mut(j) += v2 * bv;
        *c3.get_unchecked_mut(j) += v3 * bv;
        j += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn axpy4_neon(
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    v0: f32,
    v1: f32,
    v2: f32,
    v3: f32,
    b: &[f32],
) {
    use std::arch::aarch64::*;
    // clamp like axpy_neon/dot_neon: never trust one operand's length alone
    let n = b.len().min(c0.len()).min(c1.len()).min(c2.len()).min(c3.len());
    let (w0, w1, w2, w3) = (vdupq_n_f32(v0), vdupq_n_f32(v1), vdupq_n_f32(v2), vdupq_n_f32(v3));
    let mut j = 0;
    while j + LANES <= n {
        let b0 = vld1q_f32(b.as_ptr().add(j));
        let b1 = vld1q_f32(b.as_ptr().add(j + 4));
        vst1q_f32(c0.as_mut_ptr().add(j), vfmaq_f32(vld1q_f32(c0.as_ptr().add(j)), w0, b0));
        vst1q_f32(c0.as_mut_ptr().add(j + 4), vfmaq_f32(vld1q_f32(c0.as_ptr().add(j + 4)), w0, b1));
        vst1q_f32(c1.as_mut_ptr().add(j), vfmaq_f32(vld1q_f32(c1.as_ptr().add(j)), w1, b0));
        vst1q_f32(c1.as_mut_ptr().add(j + 4), vfmaq_f32(vld1q_f32(c1.as_ptr().add(j + 4)), w1, b1));
        vst1q_f32(c2.as_mut_ptr().add(j), vfmaq_f32(vld1q_f32(c2.as_ptr().add(j)), w2, b0));
        vst1q_f32(c2.as_mut_ptr().add(j + 4), vfmaq_f32(vld1q_f32(c2.as_ptr().add(j + 4)), w2, b1));
        vst1q_f32(c3.as_mut_ptr().add(j), vfmaq_f32(vld1q_f32(c3.as_ptr().add(j)), w3, b0));
        vst1q_f32(c3.as_mut_ptr().add(j + 4), vfmaq_f32(vld1q_f32(c3.as_ptr().add(j + 4)), w3, b1));
        j += LANES;
    }
    while j < n {
        let bv = *b.get_unchecked(j);
        *c0.get_unchecked_mut(j) += v0 * bv;
        *c1.get_unchecked_mut(j) += v1 * bv;
        *c2.get_unchecked_mut(j) += v2 * bv;
        *c3.get_unchecked_mut(j) += v3 * bv;
        j += 1;
    }
}

// -------------------------------------------------------------------- dot

/// `Σ a[j]·b[j]` with a fixed 8-lane split-accumulator summation tree
/// (band-independent: the tree depends only on the slice length) — the
/// `gemm_nt` inner product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if avx_ok() {
        return unsafe { dot_avx(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if neon_ok() {
        return unsafe { dot_neon(a, b) };
    }
    dot_portable(a, b)
}

#[inline]
fn lane_tree(s: [f32; LANES]) -> f32 {
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
}

#[inline]
fn dot_portable(a: &[f32], b: &[f32]) -> f32 {
    let mut s = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (a8, b8) in (&mut ca).zip(&mut cb) {
        for i in 0..LANES {
            s[i] += a8[i] * b8[i];
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    lane_tree(s) + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let mut acc = _mm256_setzero_ps();
    let mut j = 0;
    while j + LANES <= n {
        let va = _mm256_loadu_ps(a.as_ptr().add(j));
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        acc = _mm256_fmadd_ps(va, vb, acc);
        j += LANES;
    }
    let mut s = [0.0f32; LANES];
    _mm256_storeu_ps(s.as_mut_ptr(), acc);
    let mut tail = 0.0f32;
    while j < n {
        tail += *a.get_unchecked(j) * *b.get_unchecked(j);
        j += 1;
    }
    lane_tree(s) + tail
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = a.len().min(b.len());
    // acc0 holds lanes 0–3, acc1 lanes 4–7, so lane_tree sees the same
    // lane layout as the portable and AVX tiers.
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut j = 0;
    while j + LANES <= n {
        let a0 = vld1q_f32(a.as_ptr().add(j));
        let a1 = vld1q_f32(a.as_ptr().add(j + 4));
        let b0 = vld1q_f32(b.as_ptr().add(j));
        let b1 = vld1q_f32(b.as_ptr().add(j + 4));
        acc0 = vfmaq_f32(acc0, a0, b0);
        acc1 = vfmaq_f32(acc1, a1, b1);
        j += LANES;
    }
    let mut s = [0.0f32; LANES];
    vst1q_f32(s.as_mut_ptr(), acc0);
    vst1q_f32(s.as_mut_ptr().add(4), acc1);
    let mut tail = 0.0f32;
    while j < n {
        tail += *a.get_unchecked(j) * *b.get_unchecked(j);
        j += 1;
    }
    lane_tree(s) + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_scalar() {
        for n in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let b: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
            let mut c: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
            let mut want = c.clone();
            for (w, &bv) in want.iter_mut().zip(&b) {
                *w += 1.5 * bv;
            }
            axpy(&mut c, 1.5, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-5, "n={n}");
            }
        }
    }

    #[test]
    fn axpy4_matches_four_axpys() {
        let n = 37;
        let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).sin()).collect();
        let base: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        let mut rows = vec![base.clone(), base.clone(), base.clone(), base.clone()];
        let vs = [0.5f32, -1.25, 2.0, 0.0];
        let mut want = rows.clone();
        for (r, &v) in want.iter_mut().zip(&vs) {
            for (x, &bv) in r.iter_mut().zip(&b) {
                *x += v * bv;
            }
        }
        let (r0, rest) = rows.split_at_mut(1);
        let (r1, rest) = rest.split_at_mut(1);
        let (r2, r3) = rest.split_at_mut(1);
        axpy4(
            &mut r0[0], &mut r1[0], &mut r2[0], &mut r3[0], vs[0], vs[1], vs[2], vs[3], &b,
        );
        for (r, w) in rows.iter().zip(&want) {
            for (x, y) in r.iter().zip(w) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn dot_matches_f64_reference() {
        for n in [0usize, 1, 5, 8, 16, 23, 200] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.4).cos()).collect();
            let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot(&a, &b) as f64;
            assert!((got - want).abs() < 1e-4 * (n as f64).sqrt().max(1.0), "n={n}");
        }
    }

    #[test]
    fn zero_times_nan_is_nan() {
        let mut c = vec![0.0f32; 4];
        axpy(&mut c, 0.0, &[f32::NAN, 1.0, f32::INFINITY, 2.0]);
        assert!(c[0].is_nan());
        assert!(c[2].is_nan(), "0 * Inf must be NaN");
        assert_eq!(c[1], 0.0);
        assert!(dot(&[0.0, 0.0], &[f32::NAN, 1.0]).is_nan());
    }
}
