//! Pure-rust dense linear algebra substrate.
//!
//! Three consumers:
//!  * the spectral probe (Figures 1/4) — `svd::singular_values` on momenta
//!    fetched from the runtime;
//!  * cross-validation — the `optim` reference mirrors re-implement every
//!    optimizer step on host tensors and must agree with the HLO graphs;
//!  * the coordinator's RNG — Gaussian Omega inputs for RSVD (the lowered
//!    graphs are pure functions; all randomness is rust-owned).

pub mod matmul;
pub mod qr;
pub mod rng;
pub mod rsvd;
pub mod svd;

pub use matmul::{matmul, matmul_at_b, matmul_a_bt};
pub use qr::mgs_qr;
pub use rng::Rng;
pub use rsvd::rsvd_qb;
pub use svd::{singular_values, top_k_ratio};
