//! Pure-rust dense linear algebra substrate.
//!
//! Consumers:
//!  * the spectral probe (Figures 1/4) — `svd::singular_values` on momenta
//!    fetched from the runtime;
//!  * cross-validation — the `optim` reference mirrors re-implement every
//!    optimizer step on host tensors and must agree with the HLO graphs;
//!  * the coordinator's RNG — Gaussian Omega inputs for RSVD (the lowered
//!    graphs are pure functions; all randomness is rust-owned);
//!  * the host fast path — band-parallel GEMMs (`matmul`) on the
//!    persistent worker pool (`pool`) with 8-lane SIMD microkernels
//!    (`simd`), the factored QB recompression (`rsvd`), pooled scratch
//!    (`workspace`), thread budgeting (`threads`) and GEMM accounting
//!    (`flops`) behind the MLorc optimizer hot loop.

pub mod flops;
pub mod matmul;
pub mod pool;
pub mod qr;
pub mod rng;
pub mod rsvd;
pub mod simd;
pub mod svd;
pub mod threads;
pub mod workspace;

pub use matmul::{
    matmul, matmul_a_bt, matmul_a_bt_into, matmul_at_b, matmul_at_b_into, matmul_class_at_b_into,
    matmul_class_into, matmul_into, scalar_matmul, scalar_matmul_a_bt, scalar_matmul_at_b,
};
pub use qr::{mgs_qr, mgs_qr_class, mgs_qr_into, mgs_qr_ws};
pub use rng::Rng;
pub use rsvd::{rsvd_qb, rsvd_qb_class, rsvd_qb_factored, rsvd_qb_factored_class, rsvd_qb_ws};
pub use svd::{singular_values, top_k_ratio};
pub use workspace::Workspace;
