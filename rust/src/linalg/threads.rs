//! Thread-budget policy for the host linalg kernels.
//!
//! The kernels parallelize across disjoint output row bands on the
//! persistent worker pool (`linalg::pool`). Because banding only
//! partitions *which* rows a band computes — never the reduction order
//! within a row — results are bit-identical for every thread count, so
//! the budget here is purely a performance knob, not a numerics one.
//!
//! Controls:
//!  * `MLORC_THREADS=<n>` caps the global budget (default: available
//!    parallelism, capped at 8 — these are latency-bound mid-size GEMMs,
//!    not HPC kernels);
//!  * [`serial`] forces single-threaded kernels on the current thread —
//!    used by the coordinator's per-parameter parallel stepping so worker
//!    threads do not oversubscribe the machine with nested bands;
//!  * [`with_budget`] overrides the budget on the current thread — the
//!    determinism tests use it to exercise several band counts inside one
//!    process (the env var is latched once). It changes how many *bands*
//!    a kernel is split into, not the pool's worker count; bands beyond
//!    the workers are drained by the claim cursor.

use std::cell::Cell;
use std::sync::OnceLock;

/// Handing a band to a pooled worker costs ~1µs (vs ~10µs for the old
/// per-call thread spawn); split work when each extra band gets at least
/// this many multiply-adds.
const MIN_MADDS_PER_THREAD: usize = 64 * 1024;

fn global_budget() -> usize {
    static BUDGET: OnceLock<usize> = OnceLock::new();
    *BUDGET.get_or_init(|| {
        if let Ok(v) = std::env::var("MLORC_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    })
}

/// The configured global thread budget (env override or detected cores).
/// This also sizes the persistent pool: `budget() - 1` workers, the
/// calling thread executes bands too.
pub fn budget() -> usize {
    global_budget()
}

thread_local! {
    static FORCE_SERIAL: Cell<bool> = const { Cell::new(false) };
    /// 0 = no override; otherwise the per-thread budget used by
    /// [`for_work`] in place of the global one.
    static BUDGET_OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

/// Run `f` with kernel threading disabled on this thread (nested calls ok).
pub fn serial<R>(f: impl FnOnce() -> R) -> R {
    FORCE_SERIAL.with(|s| {
        let prev = s.replace(true);
        let out = f();
        s.set(prev);
        out
    })
}

/// True while inside a [`serial`] scope on this thread.
pub fn in_serial() -> bool {
    FORCE_SERIAL.with(|s| s.get())
}

/// Run `f` with the thread budget forced to `n` on this thread (nested
/// calls ok; [`serial`] still wins). Test hook for banding determinism:
/// kernels called inside see `budget() == n` and plan their bands
/// accordingly, regardless of `MLORC_THREADS` or core count.
pub fn with_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    BUDGET_OVERRIDE.with(|b| {
        let prev = b.replace(n.max(1));
        let out = f();
        b.set(prev);
        out
    })
}

/// The budget [`for_work`] sees on this thread (override or global).
pub fn effective_budget() -> usize {
    let ov = BUDGET_OVERRIDE.with(|b| b.get());
    if ov > 0 {
        ov
    } else {
        global_budget()
    }
}

/// Band count for a kernel of `madds` multiply-adds spanning `rows`
/// independent output rows. Returns 1 inside [`serial`] scopes.
pub fn for_work(madds: usize, rows: usize) -> usize {
    if in_serial() || rows < 2 {
        return 1;
    }
    let by_size = (madds / MIN_MADDS_PER_THREAD).max(1);
    effective_budget().min(by_size).min(rows).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_scope_forces_one_thread() {
        assert!(!in_serial());
        let n = serial(|| {
            assert!(in_serial());
            for_work(usize::MAX / 2, 1024)
        });
        assert_eq!(n, 1);
        assert!(!in_serial());
    }

    #[test]
    fn small_work_stays_single_threaded() {
        assert_eq!(for_work(1000, 1024), 1);
        assert!(for_work(64 << 20, 1024) >= 1);
        // never more threads than rows
        assert_eq!(for_work(usize::MAX / 2, 1), 1);
    }

    #[test]
    fn budget_override_scopes_and_nests() {
        assert_eq!(effective_budget(), global_budget());
        let n = with_budget(5, || {
            assert_eq!(effective_budget(), 5);
            let inner = with_budget(2, || for_work(usize::MAX / 2, 1024));
            assert_eq!(inner, 2);
            for_work(usize::MAX / 2, 1024)
        });
        assert_eq!(n, 5);
        assert_eq!(effective_budget(), global_budget());
        // serial still wins over an override
        let s = with_budget(8, || serial(|| for_work(usize::MAX / 2, 1024)));
        assert_eq!(s, 1);
    }
}
