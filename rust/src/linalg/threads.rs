//! Thread-budget policy for the host linalg kernels.
//!
//! The kernels parallelize across disjoint output row bands with
//! `std::thread::scope` (no pool dependency). Because banding only
//! partitions *which* rows a thread computes — never the reduction order
//! within a row — results are bit-identical for every thread count, so
//! the budget here is purely a performance knob, not a numerics one.
//!
//! Controls:
//!  * `MLORC_THREADS=<n>` caps the global budget (default: available
//!    parallelism, capped at 8 — these are latency-bound mid-size GEMMs,
//!    not HPC kernels);
//!  * [`serial`] forces single-threaded kernels on the current thread —
//!    used by the coordinator's per-parameter parallel stepping so worker
//!    threads do not oversubscribe the machine with nested spawns.

use std::cell::Cell;
use std::sync::OnceLock;

/// Spawning a thread costs ~10µs; only split work when each extra thread
/// gets at least this many multiply-adds.
const MIN_MADDS_PER_THREAD: usize = 192 * 1024;

fn global_budget() -> usize {
    static BUDGET: OnceLock<usize> = OnceLock::new();
    *BUDGET.get_or_init(|| {
        if let Ok(v) = std::env::var("MLORC_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    })
}

/// The configured global thread budget (env override or detected cores).
pub fn budget() -> usize {
    global_budget()
}

thread_local! {
    static FORCE_SERIAL: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with kernel threading disabled on this thread (nested calls ok).
pub fn serial<R>(f: impl FnOnce() -> R) -> R {
    FORCE_SERIAL.with(|s| {
        let prev = s.replace(true);
        let out = f();
        s.set(prev);
        out
    })
}

/// True while inside a [`serial`] scope on this thread.
pub fn in_serial() -> bool {
    FORCE_SERIAL.with(|s| s.get())
}

/// Thread count for a kernel of `madds` multiply-adds spanning `rows`
/// independent output rows. Returns 1 inside [`serial`] scopes.
pub fn for_work(madds: usize, rows: usize) -> usize {
    if in_serial() || rows < 2 {
        return 1;
    }
    let by_size = (madds / MIN_MADDS_PER_THREAD).max(1);
    global_budget().min(by_size).min(rows).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_scope_forces_one_thread() {
        assert!(!in_serial());
        let n = serial(|| {
            assert!(in_serial());
            for_work(usize::MAX / 2, 1024)
        });
        assert_eq!(n, 1);
        assert!(!in_serial());
    }

    #[test]
    fn small_work_stays_single_threaded() {
        assert_eq!(for_work(1000, 1024), 1);
        assert!(for_work(64 << 20, 1024) >= 1);
        // never more threads than rows
        assert_eq!(for_work(usize::MAX / 2, 1), 1);
    }
}
