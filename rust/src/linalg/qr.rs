//! Skinny QR via modified Gram-Schmidt with one reorthogonalization pass —
//! the exact algorithm the Layer-2 graphs unroll, so the rust reference
//! optimizers reproduce the HLO bit-for-bit up to f32 reassociation.

use crate::tensor::Tensor;

/// Column-orthonormal Q of a (m, l) matrix, l small. Dead columns (norm^2
/// <= 1e-30) become zero columns — rank simply drops, matching rsvd_lib.
pub fn mgs_qr(y: &Tensor) -> Tensor {
    let (m, l) = y.dims2().expect("mgs_qr input");
    // column-major scratch for locality
    let mut cols: Vec<Vec<f32>> = (0..l)
        .map(|j| (0..m).map(|i| y.at2(i, j)).collect())
        .collect();
    for j in 0..l {
        for _pass in 0..2 {
            for i in 0..j {
                let (head, tail) = cols.split_at_mut(j);
                let qi = &head[i];
                let vj = &mut tail[0];
                let dot: f64 = qi.iter().zip(vj.iter()).map(|(a, b)| *a as f64 * *b as f64).sum();
                let dot = dot as f32;
                for (v, q) in vj.iter_mut().zip(qi) {
                    *v -= q * dot;
                }
            }
        }
        let nrm2: f64 = cols[j].iter().map(|x| (*x as f64) * (*x as f64)).sum();
        let inv = if nrm2 > 1e-30 { 1.0 / nrm2.sqrt() } else { 0.0 } as f32;
        for v in cols[j].iter_mut() {
            *v *= inv;
        }
    }
    let mut q = Tensor::zeros(&[m, l]);
    for j in 0..l {
        for i in 0..m {
            q.set2(i, j, cols[j][i]);
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_at_b, Rng};

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(1);
        for (m, l) in [(32, 4), (64, 8), (100, 3)] {
            let y = rng.gaussian_tensor(&[m, l], 1.0);
            let q = mgs_qr(&y);
            let qtq = matmul_at_b(&q, &q);
            for i in 0..l {
                for j in 0..l {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((qtq.at2(i, j) - want).abs() < 5e-5, "qtq[{i},{j}]={}", qtq.at2(i, j));
                }
            }
        }
    }

    #[test]
    fn spans_input_columns() {
        // Every input column must be reproduced by Q Q^T y_j.
        let mut rng = Rng::new(2);
        let y = rng.gaussian_tensor(&[48, 4], 1.0);
        let q = mgs_qr(&y);
        let proj = crate::linalg::matmul(&q, &matmul_at_b(&q, &y));
        assert!(proj.rel_err(&y) < 1e-4);
    }

    #[test]
    fn zero_column_stays_zero() {
        let mut rng = Rng::new(3);
        let mut y = rng.gaussian_tensor(&[16, 3], 1.0);
        for i in 0..16 {
            y.set2(i, 1, 0.0);
        }
        let q = mgs_qr(&y);
        for i in 0..16 {
            assert_eq!(q.at2(i, 1), 0.0);
            assert!(q.at2(i, 0).is_finite() && q.at2(i, 2).is_finite());
        }
    }
}
