//! Skinny QR via modified Gram-Schmidt with one reorthogonalization pass —
//! the exact algorithm the Layer-2 graphs unroll, so the rust reference
//! optimizers reproduce the HLO bit-for-bit up to f32 reassociation.
//!
//! The factorization runs on a flat column-major scratch taken from a
//! [`Workspace`], so the per-step cost is two strided copies and zero heap
//! allocations in steady state (the original version built a `Vec<Vec>`
//! and copied element-by-element through bounds-checked `at2`/`set2`).

// Index loops over the flat column-major scratch are intentional (see matmul.rs).
#![allow(clippy::needless_range_loop)]

use crate::tensor::Tensor;

use super::{flops, pool, Workspace};

/// Column-orthonormal Q of a (m, l) matrix, l small. Dead columns (norm^2
/// <= 1e-30) become zero columns — rank simply drops, matching rsvd_lib.
pub fn mgs_qr(y: &Tensor) -> Tensor {
    let mut ws = Workspace::new();
    mgs_qr_ws(y, &mut ws)
}

/// `mgs_qr` on pooled scratch. The returned Q is backed by a workspace
/// buffer; give it back with `ws.give_tensor` when it dies.
pub fn mgs_qr_ws(y: &Tensor, ws: &mut Workspace) -> Tensor {
    let (m, l) = y.dims2().expect("mgs_qr input");
    // MGS is ~2 passes of j dots+axpys per column: ~m*l*l madds. Recorded
    // with the same formula as the class path so batched-vs-sequential
    // flop totals match exactly (tests/obs_identity.rs pins this).
    flops::record("mgs_qr", m, l, l);
    let mut cols = ws.take(m * l);
    let mut q = ws.take_tensor(&[m, l]);
    mgs_qr_into(y, &mut q, &mut cols);
    ws.give(cols);
    q
}

/// The MGS core, writing into a caller-shaped Q and a caller-provided
/// `m * l` column-major scratch. Both are fully overwritten before any
/// read, so dirty scratch (reused across the members of a shape class)
/// cannot perturb bits.
pub fn mgs_qr_into(y: &Tensor, q: &mut Tensor, cols: &mut [f32]) {
    let (m, l) = y.dims2().expect("mgs_qr input");
    assert_eq!(q.dims2().expect("mgs_qr out"), (m, l), "mgs_qr out shape");
    let cols = &mut cols[..m * l];
    // gather to column-major: cols[j*m + i] = y[i, j]
    for (i, row) in y.data.chunks_exact(l.max(1)).enumerate().take(m) {
        for (j, &v) in row.iter().enumerate() {
            cols[j * m + i] = v;
        }
    }
    for j in 0..l {
        let (head, tail) = cols.split_at_mut(j * m);
        let vj = &mut tail[..m];
        for _pass in 0..2 {
            for i in 0..j {
                let qi = &head[i * m..(i + 1) * m];
                let dot: f64 =
                    qi.iter().zip(vj.iter()).map(|(a, b)| *a as f64 * *b as f64).sum();
                let dot = dot as f32;
                for (v, q) in vj.iter_mut().zip(qi) {
                    *v -= q * dot;
                }
            }
        }
        let nrm2: f64 = vj.iter().map(|x| (*x as f64) * (*x as f64)).sum();
        let inv = if nrm2 > 1e-30 { 1.0 / nrm2.sqrt() } else { 0.0 } as f32;
        for v in vj.iter_mut() {
            *v *= inv;
        }
    }
    // scatter back to row-major
    for j in 0..l {
        let col = &cols[j * m..(j + 1) * m];
        for (i, &v) in col.iter().enumerate() {
            q.data[i * l + j] = v;
        }
    }
}

/// Batched MGS QR over a shape class: factor every `ys[i]` into the
/// pre-shaped `qs[i]`. MGS is inherently serial *within* a member, so the
/// class runs one member per atomically-claimed pool task
/// (`pool::par_member_tasks`), each task reusing a per-slot column-major
/// scratch from its `workspaces` slot. Bit-identical to per-member
/// [`mgs_qr_ws`] calls: members are independent and `mgs_qr_into` fully
/// overwrites its scratch.
pub fn mgs_qr_class(ys: &[Tensor], qs: &mut [Tensor], workspaces: &mut [Workspace]) {
    let count = ys.len();
    assert_eq!(count, qs.len(), "mgs_qr_class member count");
    if count == 0 {
        return;
    }
    let (m, l) = ys[0].dims2().expect("mgs_qr_class input");
    // Flop accounting happens here on the calling thread (one record per
    // member, identical to the per-member mgs_qr_ws records): thread-local
    // audit records made inside pool worker tasks would be dropped.
    for _ in 0..count {
        flops::record("mgs_qr", m, l, l);
    }
    let nslots = workspaces.len().min(count);
    if nslots <= 1 || count == 1 {
        let ws = workspaces.first_mut().expect("mgs_qr_class needs a workspace");
        let mut cols = ws.take(m * l);
        for (y, q) in ys.iter().zip(qs.iter_mut()) {
            mgs_qr_into(y, q, &mut cols);
        }
        ws.give(cols);
        return;
    }
    let out = pool::DisjointMut::new(qs);
    let slots: Vec<&mut Workspace> = workspaces.iter_mut().take(nslots).collect();
    pool::par_member_tasks(slots, count, |i, ws| {
        let mut cols = ws.take(m * l);
        mgs_qr_into(&ys[i], unsafe { out.item(i) }, &mut cols);
        ws.give(cols);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_at_b, Rng};

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(1);
        for (m, l) in [(32, 4), (64, 8), (100, 3)] {
            let y = rng.gaussian_tensor(&[m, l], 1.0);
            let q = mgs_qr(&y);
            let qtq = matmul_at_b(&q, &q);
            for i in 0..l {
                for j in 0..l {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((qtq.at2(i, j) - want).abs() < 5e-5, "qtq[{i},{j}]={}", qtq.at2(i, j));
                }
            }
        }
    }

    #[test]
    fn spans_input_columns() {
        // Every input column must be reproduced by Q Q^T y_j.
        let mut rng = Rng::new(2);
        let y = rng.gaussian_tensor(&[48, 4], 1.0);
        let q = mgs_qr(&y);
        let proj = crate::linalg::matmul(&q, &matmul_at_b(&q, &y));
        assert!(proj.rel_err(&y) < 1e-4);
    }

    #[test]
    fn zero_column_stays_zero() {
        let mut rng = Rng::new(3);
        let mut y = rng.gaussian_tensor(&[16, 3], 1.0);
        for i in 0..16 {
            y.set2(i, 1, 0.0);
        }
        let q = mgs_qr(&y);
        for i in 0..16 {
            assert_eq!(q.at2(i, 1), 0.0);
            assert!(q.at2(i, 0).is_finite() && q.at2(i, 2).is_finite());
        }
    }

    #[test]
    fn class_qr_bit_matches_per_member_calls() {
        let mut rng = Rng::new(5);
        let ys: Vec<Tensor> = (0..6).map(|_| rng.gaussian_tensor(&[40, 5], 1.0)).collect();
        let mut ws = Workspace::new();
        let want: Vec<Vec<f32>> = ys
            .iter()
            .map(|y| {
                let q = mgs_qr_ws(y, &mut ws);
                let d = q.data.clone();
                ws.give_tensor(q);
                d
            })
            .collect();
        for nws in [1usize, 3] {
            let mut workspaces: Vec<Workspace> = (0..nws).map(|_| Workspace::new()).collect();
            let mut qs: Vec<Tensor> = (0..6).map(|_| Tensor::zeros(&[40, 5])).collect();
            mgs_qr_class(&ys, &mut qs, &mut workspaces);
            for (i, q) in qs.iter().enumerate() {
                assert_eq!(q.data, want[i], "member {i} with {nws} workspaces");
            }
        }
    }

    #[test]
    fn workspace_reuse_is_exact() {
        // Same input through a warm workspace must give bitwise-equal Q.
        let mut rng = Rng::new(4);
        let y = rng.gaussian_tensor(&[40, 5], 1.0);
        let mut ws = Workspace::new();
        let q1 = mgs_qr_ws(&y, &mut ws);
        let q1_data = q1.data.clone();
        ws.give_tensor(q1);
        let q2 = mgs_qr_ws(&y, &mut ws);
        assert_eq!(q1_data, q2.data);
        assert!(ws.reuse_ratio() > 0.4, "warm pool must be reused");
    }
}
