//! Host GEMM kernels.
//!
//! Two tiers:
//!  * `matmul` / `matmul_at_b` / `matmul_a_bt` (and their `_into`
//!    variants): cache-blocked, register-tiled kernels parallelized across
//!    disjoint output row bands on the persistent worker pool
//!    (`linalg::pool::par_row_bands` — one entry point, no per-call thread
//!    spawns). Inner loops run on the 8-lane SIMD microkernels
//!    (`linalg::simd`); the TN/NT kernels read their strided KC-panels
//!    through packed, 32-byte aligned `Workspace` scratch (the NN panel is
//!    already contiguous, so it is read in place). Banding never changes
//!    the reduction order inside a row, and packing never changes the
//!    order values are combined in, so results are bit-identical for every
//!    thread count (see `linalg::threads`).
//!  * `scalar_*`: the straightforward single-threaded loops — the
//!    pre-optimization baseline kept as the correctness oracle for
//!    property tests and the speedup reference for `bench_opt_step`.
//!
//! The per-band kernels (`gemm_nn_band` & co.) are public so
//! `bench_opt_step` can wrap them in the PR-1-era `std::thread::scope`
//! spawn scaffold and measure the pool against it; library code must only
//! enter them through the `_into` fronts.
//!
//! Historical note: the original kernels skipped `a == 0.0` multiplies,
//! which silently dropped NaN/Inf propagation from the B operand
//! (0 · NaN must be NaN). Neither tier does that anymore; the regression
//! is pinned by `nan_propagates_through_zero_lhs` below.

// Index loops over banded raw slices are intentional here: the iterator
// forms obscure the blocking structure and the banding determinism argument.
#![allow(clippy::needless_range_loop)]

use crate::tensor::Tensor;

use super::workspace::with_kernel_ws;
use super::{flops, pool, simd};

/// k-panel size for the blocked kernels (KC · 4 rows of A ≈ L1-resident).
const KC: usize = 256;
/// Outputs at most this wide accumulate whole C rows in registers.
const SMALL_N: usize = 16;
/// Pack a KC-panel into aligned scratch only when the band has at least
/// this many output rows to amortize the copy. The pack changes *where*
/// operands are read from, never the order they are combined in, so this
/// band-size-dependent choice cannot perturb bits.
const PACK_MIN_ROWS: usize = 8;

// --------------------------------------------------------------- C = A @ B

/// C = A @ B — (m, k) @ (k, n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, _) = a.dims2().expect("matmul lhs");
    let (_, n) = b.dims2().expect("matmul rhs");
    let mut c = Tensor { shape: vec![m, n], data: vec![0.0; m * n] };
    matmul_into(&mut c, a, b);
    c
}

/// C = A @ B into a caller-provided (workspace) tensor; overwrites `c`.
pub fn matmul_into(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    let (m, k) = a.dims2().expect("matmul lhs");
    let (k2, n) = b.dims2().expect("matmul rhs");
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let (cm, cn) = c.dims2().expect("matmul out");
    assert_eq!((cm, cn), (m, n), "matmul out shape");
    flops::record("matmul", m, k, n);
    c.data.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let bands = pool::BandedMut::new(&mut c.data);
    let (ad, bd) = (&a.data[..], &b.data[..]);
    pool::par_row_bands(m, m * k * n, move |_, r| {
        let chunk = unsafe { bands.rows(r.clone(), n) };
        gemm_nn_band(ad, bd, chunk, r.start, k, n);
    });
}

/// One band of C = A @ B: rows `i0 ..` of C (band length from `c.len()`).
/// Public only as the bench's spawn-scaffold baseline building block.
pub fn gemm_nn_band(a: &[f32], b: &[f32], c: &mut [f32], i0: usize, k: usize, n: usize) {
    let rows = c.len() / n;
    if n <= SMALL_N {
        // Thin output: keep the whole C row in registers across the k loop
        // (the RSVD sketch G·Ω lives here — n = l is small).
        for i in 0..rows {
            let arow = &a[(i0 + i) * k..(i0 + i) * k + k];
            let mut acc = [0.0f32; SMALL_N];
            let acc = &mut acc[..n];
            for (p, &av) in arow.iter().enumerate() {
                simd::axpy(acc, av, &b[p * n..p * n + n]);
            }
            c[i * n..i * n + n].copy_from_slice(acc);
        }
        return;
    }
    // 4-row register tile over KC-wide k panels: each B row is loaded once
    // per 4 rows of A, and C tiles stay hot across the panel. No pack here:
    // the NN panel `b[kk*n .. kend*n]` is already contiguous and read in
    // p-order, so a copy would be pure overhead — packing lives in the
    // TN/NT kernels, where it genuinely de-strides the operand.
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let bsrc = &b[kk * n..kend * n];
        for (q4, c4) in c.chunks_mut(4 * n).enumerate() {
            let r = i0 + q4 * 4;
            let rows_here = c4.len() / n;
            if rows_here == 4 {
                let (c0, rest) = c4.split_at_mut(n);
                let (c1, rest) = rest.split_at_mut(n);
                let (c2, c3) = rest.split_at_mut(n);
                let a0 = &a[r * k..(r + 1) * k];
                let a1 = &a[(r + 1) * k..(r + 2) * k];
                let a2 = &a[(r + 2) * k..(r + 3) * k];
                let a3 = &a[(r + 3) * k..(r + 4) * k];
                for p in kk..kend {
                    let brow = &bsrc[(p - kk) * n..(p - kk) * n + n];
                    simd::axpy4(c0, c1, c2, c3, a0[p], a1[p], a2[p], a3[p], brow);
                }
            } else {
                // 1-3 tail rows: plain axpy per row, same p order as the
                // 4-row tile so banding stays bit-deterministic.
                for (ri, crow) in c4.chunks_mut(n).enumerate() {
                    let arow = &a[(r + ri) * k..(r + ri + 1) * k];
                    for p in kk..kend {
                        let brow = &bsrc[(p - kk) * n..(p - kk) * n + n];
                        simd::axpy(crow, arow[p], brow);
                    }
                }
            }
        }
        kk = kend;
    }
}

/// Stacked C_i = A_i @ B_i over a shape class: every member shares (m, k, n)
/// and the whole class runs as **one** banded invocation over the stacked
/// `members * m` row space (`pool::par_stacked_rows`) — pool dispatch and
/// the band plan are paid once per class instead of once per member. Band
/// splits at member boundaries keep each `gemm_nn_band` call inside one
/// member, so every member's bits match a scalar [`matmul_into`] call.
pub fn matmul_class_into(cs: &mut [Tensor], a: &[&Tensor], b: &[&Tensor]) {
    let count = cs.len();
    assert_eq!(count, a.len(), "matmul_class lhs count");
    assert_eq!(count, b.len(), "matmul_class rhs count");
    if count == 0 {
        return;
    }
    let (m, k) = a[0].dims2().expect("matmul_class lhs");
    let (k2, n) = b[0].dims2().expect("matmul_class rhs");
    assert_eq!(k, k2, "matmul_class inner dims {k} vs {k2}");
    for (i, c) in cs.iter_mut().enumerate() {
        assert_eq!(a[i].dims2().expect("matmul_class lhs"), (m, k), "class lhs {i}");
        assert_eq!(b[i].dims2().expect("matmul_class rhs"), (k, n), "class rhs {i}");
        assert_eq!(c.dims2().expect("matmul_class out"), (m, n), "class out {i}");
        flops::record("matmul", m, k, n);
        c.data.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let stacked = pool::StackedMut::new(cs.iter_mut().map(|c| c.data.as_mut_slice()), m * n);
    pool::par_stacked_rows(count, m, count * m * k * n, move |_, i, r| {
        let chunk = unsafe { stacked.rows(i, r.clone(), n) };
        gemm_nn_band(&a[i].data, &b[i].data, chunk, r.start, k, n);
    });
}

// ------------------------------------------------------------ C = A^T @ B

/// C = A^T @ B — (m, k)^T @ (m, n) -> (k, n).
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let (_, k) = a.dims2().expect("matmul_at_b lhs");
    let (_, n) = b.dims2().expect("matmul_at_b rhs");
    let mut c = Tensor { shape: vec![k, n], data: vec![0.0; k * n] };
    matmul_at_b_into(&mut c, a, b);
    c
}

/// C = A^T @ B into a caller-provided tensor; overwrites `c`.
pub fn matmul_at_b_into(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    let (m, k) = a.dims2().expect("matmul_at_b lhs");
    let (m2, n) = b.dims2().expect("matmul_at_b rhs");
    assert_eq!(m, m2, "matmul_at_b outer dims {m} vs {m2}");
    let (ck, cn) = c.dims2().expect("matmul_at_b out");
    assert_eq!((ck, cn), (k, n), "matmul_at_b out shape");
    flops::record("matmul_at_b", k, m, n);
    c.data.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Parallelize across output rows (columns of A); each band scans all
    // of A and B once, accumulating its own k-rows of C.
    let bands = pool::BandedMut::new(&mut c.data);
    let (ad, bd) = (&a.data[..], &b.data[..]);
    pool::par_row_bands(k, m * k * n, move |_, r| {
        let chunk = unsafe { bands.rows(r.clone(), n) };
        gemm_tn_band(ad, bd, chunk, r.start, m, k, n);
    });
}

/// One band of C = A^T @ B: output rows `p0 ..` (band length from
/// `c.len()`). The band's column slice of A is packed into contiguous
/// aligned scratch per KC-panel of the reduction dim, turning the strided
/// `a[i, p0+dp]` reads into sequential ones. Public for the bench spawn
/// baseline only.
pub fn gemm_tn_band(a: &[f32], b: &[f32], c: &mut [f32], p0: usize, m: usize, k: usize, n: usize) {
    let prows = c.len() / n;
    let mut ii = 0;
    while ii < m {
        let iend = (ii + KC).min(m);
        let mc = iend - ii;
        with_kernel_ws(|ws| {
            let panel = if prows >= 2 && mc >= PACK_MIN_ROWS {
                // dirty take: the loop below writes every element
                let mut p = ws.take_aligned_dirty(mc * prows);
                let dst = p.as_mut_slice();
                for i in ii..iend {
                    let src = &a[i * k + p0..i * k + p0 + prows];
                    dst[(i - ii) * prows..(i - ii) * prows + prows].copy_from_slice(src);
                }
                Some(p)
            } else {
                None
            };
            for i in ii..iend {
                let brow = &b[i * n..(i + 1) * n];
                match &panel {
                    Some(p) => {
                        let arow = &p.as_slice()[(i - ii) * prows..(i - ii) * prows + prows];
                        for dp in 0..prows {
                            simd::axpy(&mut c[dp * n..(dp + 1) * n], arow[dp], brow);
                        }
                    }
                    None => {
                        for dp in 0..prows {
                            let av = a[i * k + p0 + dp];
                            simd::axpy(&mut c[dp * n..(dp + 1) * n], av, brow);
                        }
                    }
                }
            }
            if let Some(p) = panel {
                ws.give_aligned(p);
            }
        });
        ii = iend;
    }
}

/// Stacked C_i = A_i^T @ B_i over a shape class — the class sibling of
/// [`matmul_at_b_into`], banding the stacked `members * k` output row
/// space in one pool invocation. Same bit-identity argument as
/// [`matmul_class_into`].
pub fn matmul_class_at_b_into(cs: &mut [Tensor], a: &[&Tensor], b: &[&Tensor]) {
    let count = cs.len();
    assert_eq!(count, a.len(), "matmul_class_at_b lhs count");
    assert_eq!(count, b.len(), "matmul_class_at_b rhs count");
    if count == 0 {
        return;
    }
    let (m, k) = a[0].dims2().expect("matmul_class_at_b lhs");
    let (m2, n) = b[0].dims2().expect("matmul_class_at_b rhs");
    assert_eq!(m, m2, "matmul_class_at_b outer dims {m} vs {m2}");
    for (i, c) in cs.iter_mut().enumerate() {
        assert_eq!(a[i].dims2().expect("matmul_class_at_b lhs"), (m, k), "class lhs {i}");
        assert_eq!(b[i].dims2().expect("matmul_class_at_b rhs"), (m, n), "class rhs {i}");
        assert_eq!(c.dims2().expect("matmul_class_at_b out"), (k, n), "class out {i}");
        flops::record("matmul_at_b", k, m, n);
        c.data.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let stacked = pool::StackedMut::new(cs.iter_mut().map(|c| c.data.as_mut_slice()), k * n);
    pool::par_stacked_rows(count, k, count * m * k * n, move |_, i, r| {
        let chunk = unsafe { stacked.rows(i, r.clone(), n) };
        gemm_tn_band(&a[i].data, &b[i].data, chunk, r.start, m, k, n);
    });
}

// ------------------------------------------------------------ C = A @ B^T

/// C = A @ B^T — (m, k) @ (n, k)^T -> (m, n).
pub fn matmul_a_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, _) = a.dims2().expect("matmul_a_bt lhs");
    let (n, _) = b.dims2().expect("matmul_a_bt rhs");
    let mut c = Tensor { shape: vec![m, n], data: vec![0.0; m * n] };
    matmul_a_bt_into(&mut c, a, b);
    c
}

/// C = A @ B^T into a caller-provided tensor; overwrites `c`.
pub fn matmul_a_bt_into(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    let (m, k) = a.dims2().expect("matmul_a_bt lhs");
    let (n, k2) = b.dims2().expect("matmul_a_bt rhs");
    assert_eq!(k, k2, "matmul_a_bt inner dims {k} vs {k2}");
    let (cm, cn) = c.dims2().expect("matmul_a_bt out");
    assert_eq!((cm, cn), (m, n), "matmul_a_bt out shape");
    flops::record("matmul_a_bt", m, k, n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.data.fill(0.0);
        return;
    }
    let bands = pool::BandedMut::new(&mut c.data);
    let (ad, bd) = (&a.data[..], &b.data[..]);
    pool::par_row_bands(m, m * k * n, move |_, r| {
        let chunk = unsafe { bands.rows(r.clone(), n) };
        gemm_nt_band(ad, bd, chunk, r.start, k, n);
    });
}

/// One band of C = A @ B^T: rows of contiguous-by-contiguous dot products
/// with the fixed 8-lane split-accumulator tree (`simd::dot`), accumulated
/// per KC-panel of the reduction dim. The summation shape depends only on
/// (k, KC) — never on the band — so banding stays bit-deterministic.
/// Public for the bench spawn baseline only.
pub fn gemm_nt_band(a: &[f32], b: &[f32], c: &mut [f32], i0: usize, k: usize, n: usize) {
    let rows = c.len() / n;
    c.fill(0.0);
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let kc = kend - kk;
        with_kernel_ws(|ws| {
            // pack the n × kc column-slice of B^T rows into one dense panel
            let panel = if rows >= PACK_MIN_ROWS {
                // dirty take: the loop below writes every element
                let mut p = ws.take_aligned_dirty(n * kc);
                let dst = p.as_mut_slice();
                for j in 0..n {
                    dst[j * kc..j * kc + kc].copy_from_slice(&b[j * k + kk..j * k + kend]);
                }
                Some(p)
            } else {
                None
            };
            for i in 0..rows {
                let arow = &a[(i0 + i) * k + kk..(i0 + i) * k + kend];
                let crow = &mut c[i * n..i * n + n];
                for (j, cv) in crow.iter_mut().enumerate() {
                    let bslice = match &panel {
                        Some(p) => &p.as_slice()[j * kc..j * kc + kc],
                        None => &b[j * k + kk..j * k + kend],
                    };
                    *cv += simd::dot(arow, bslice);
                }
            }
            if let Some(p) = panel {
                ws.give_aligned(p);
            }
        });
        kk = kend;
    }
}

// ------------------------------------------------- scalar reference tier

/// Reference C = A @ B: single-threaded ikj loops (pre-optimization
/// baseline; the zero-skip NaN bug of the original kernel is fixed).
pub fn scalar_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2().expect("matmul lhs");
    let (k2, n) = b.dims2().expect("matmul rhs");
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    flops::record("scalar_matmul", m, k, n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Tensor { shape: vec![m, n], data: c }
}

/// Reference C = A^T @ B — (m, k)^T @ (m, n) -> (k, n).
pub fn scalar_matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2().expect("matmul_at_b lhs");
    let (m2, n) = b.dims2().expect("matmul_at_b rhs");
    assert_eq!(m, m2);
    flops::record("scalar_matmul_at_b", k, m, n);
    let mut c = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let brow = &b.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let crow = &mut c[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Tensor { shape: vec![k, n], data: c }
}

/// Reference C = A @ B^T — (m, k) @ (n, k)^T -> (m, n), f64 dot.
pub fn scalar_matmul_a_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2().expect("matmul_a_bt lhs");
    let (n, k2) = b.dims2().expect("matmul_a_bt rhs");
    assert_eq!(k, k2);
    flops::record("scalar_matmul_a_bt", m, k, n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f64;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av as f64 * bv as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    Tensor { shape: vec![m, n], data: c }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{threads, Rng};

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2().unwrap();
        let (_, n) = b.dims2().unwrap();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..k {
                    s += a.at2(i, p) as f64 * b.at2(p, j) as f64;
                }
                c.set2(i, j, s as f32);
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(1);
        // hit both the small-n and the 4-row-tile paths, plus odd tails
        for (m, k, n) in [(3, 4, 5), (8, 8, 8), (17, 3, 9), (33, 7, 40), (5, 300, 24)] {
            let a = rng.gaussian_tensor(&[m, k], 1.0);
            let b = rng.gaussian_tensor(&[k, n], 1.0);
            let c = matmul(&a, &b);
            assert!(c.rel_err(&naive(&a, &b)) < 1e-5, "({m},{k},{n})");
            assert!(scalar_matmul(&a, &b).rel_err(&c) < 1e-5, "scalar ({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = Rng::new(2);
        let a = rng.gaussian_tensor(&[7, 5], 1.0);
        let b = rng.gaussian_tensor(&[7, 6], 1.0);
        let c1 = matmul_at_b(&a, &b);
        let c2 = matmul(&a.transpose2().unwrap(), &b);
        assert!(c1.rel_err(&c2) < 1e-5);
        assert!(scalar_matmul_at_b(&a, &b).rel_err(&c2) < 1e-5);

        let d = rng.gaussian_tensor(&[6, 5], 1.0);
        let e1 = matmul_a_bt(&a, &d);
        let e2 = matmul(&a, &d.transpose2().unwrap());
        assert!(e1.rel_err(&e2) < 1e-5);
        assert!(scalar_matmul_a_bt(&a, &d).rel_err(&e2) < 1e-5);
    }

    #[test]
    fn identity() {
        let mut rng = Rng::new(3);
        let a = rng.gaussian_tensor(&[4, 4], 1.0);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        assert!(matmul(&a, &eye).rel_err(&a) < 1e-6);
    }

    #[test]
    fn nan_propagates_through_zero_lhs() {
        // Regression: the original kernels skipped a==0.0 multiplies, so a
        // zero row in A masked NaN/Inf in B. IEEE: 0 * NaN = NaN.
        let a = Tensor::new(vec![2, 2], vec![0.0, 0.0, 1.0, 2.0]).unwrap();
        let mut b = Tensor::new(vec![2, 3], vec![f32::NAN, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        b.set2(1, 1, f32::INFINITY);
        for f in [matmul, scalar_matmul] {
            let c = f(&a, &b);
            assert!(c.at2(0, 0).is_nan(), "0*NaN row must stay NaN");
            assert!(c.at2(0, 1).is_nan(), "0*Inf is NaN and must not be skipped");
            assert!(c.at2(1, 0).is_nan());
        }
        // A^T @ B with a zero column in A
        let at = a.transpose2().unwrap();
        for f in [matmul_at_b, scalar_matmul_at_b] {
            let c = f(&at, &b);
            assert!(c.at2(0, 0).is_nan());
        }
    }

    #[test]
    fn banding_is_bit_deterministic() {
        // Pooled and forced-serial kernels must agree exactly, not just
        // within tolerance — the parallel trainer relies on this.
        let mut rng = Rng::new(4);
        let a = rng.gaussian_tensor(&[97, 53], 1.0);
        let b = rng.gaussian_tensor(&[53, 41], 1.0);
        let threaded = matmul(&a, &b);
        let serial = threads::serial(|| matmul(&a, &b));
        assert_eq!(threaded.data, serial.data);

        let bt = rng.gaussian_tensor(&[41, 53], 1.0);
        assert_eq!(
            matmul_a_bt(&a, &bt).data,
            threads::serial(|| matmul_a_bt(&a, &bt)).data
        );
        let b2 = rng.gaussian_tensor(&[97, 19], 1.0);
        assert_eq!(
            matmul_at_b(&a, &b2).data,
            threads::serial(|| matmul_at_b(&a, &b2)).data
        );
    }

    #[test]
    fn class_gemms_bit_match_per_member_calls() {
        let mut rng = Rng::new(6);
        for budget in [1usize, 2, 3, 8] {
            threads::with_budget(budget, || {
                let lhs: Vec<Tensor> =
                    (0..5).map(|_| rng.gaussian_tensor(&[33, 20], 1.0)).collect();
                let rhs: Vec<Tensor> =
                    (0..5).map(|_| rng.gaussian_tensor(&[20, 24], 1.0)).collect();
                let mut stacked: Vec<Tensor> = (0..5).map(|_| Tensor::zeros(&[33, 24])).collect();
                let la: Vec<&Tensor> = lhs.iter().collect();
                let lb: Vec<&Tensor> = rhs.iter().collect();
                matmul_class_into(&mut stacked, &la, &lb);
                for i in 0..5 {
                    assert_eq!(stacked[i].data, matmul(&lhs[i], &rhs[i]).data, "nn member {i}");
                }

                let tall: Vec<Tensor> =
                    (0..4).map(|_| rng.gaussian_tensor(&[33, 7], 1.0)).collect();
                let wide: Vec<Tensor> =
                    (0..4).map(|_| rng.gaussian_tensor(&[33, 24], 1.0)).collect();
                let mut tn: Vec<Tensor> = (0..4).map(|_| Tensor::zeros(&[7, 24])).collect();
                let ta: Vec<&Tensor> = tall.iter().collect();
                let tb: Vec<&Tensor> = wide.iter().collect();
                matmul_class_at_b_into(&mut tn, &ta, &tb);
                for i in 0..4 {
                    assert_eq!(
                        tn[i].data,
                        matmul_at_b(&tall[i], &wide[i]).data,
                        "tn member {i} (budget {budget})"
                    );
                }
            });
        }
    }

    #[test]
    fn degenerate_shapes() {
        let mut rng = Rng::new(5);
        for (m, k, n) in [(1, 1, 1), (1, 9, 33), (33, 9, 1), (2, 1, 2), (64, 2, 3)] {
            let a = rng.gaussian_tensor(&[m, k], 1.0);
            let b = rng.gaussian_tensor(&[k, n], 1.0);
            assert!(matmul(&a, &b).rel_err(&naive(&a, &b)) < 1e-5, "({m},{k},{n})");
        }
    }
}
