//! Host matmuls (ikj loop order, f64 accumulation on the k-panel).
//!
//! These back the reference optimizers and the spectral probe; the training
//! hot path runs inside XLA. Sizes here are at most (vocab x d_model), so a
//! cache-friendly scalar kernel is plenty.

use crate::tensor::Tensor;

/// C = A @ B — (m, k) @ (k, n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2().expect("matmul lhs");
    let (k2, n) = b.dims2().expect("matmul rhs");
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Tensor { shape: vec![m, n], data: c }
}

/// C = A^T @ B — (m, k)^T @ (m, n) -> (k, n).
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2().expect("matmul_at_b lhs");
    let (m2, n) = b.dims2().expect("matmul_at_b rhs");
    assert_eq!(m, m2);
    let mut c = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let brow = &b.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Tensor { shape: vec![k, n], data: c }
}

/// C = A @ B^T — (m, k) @ (n, k)^T -> (m, n).
pub fn matmul_a_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2().expect("matmul_a_bt lhs");
    let (n, k2) = b.dims2().expect("matmul_a_bt rhs");
    assert_eq!(k, k2);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f64;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av as f64 * bv as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    Tensor { shape: vec![m, n], data: c }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2().unwrap();
        let (_, n) = b.dims2().unwrap();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..k {
                    s += a.at2(i, p) as f64 * b.at2(p, j) as f64;
                }
                c.set2(i, j, s as f32);
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (8, 8, 8), (17, 3, 9)] {
            let a = rng.gaussian_tensor(&[m, k], 1.0);
            let b = rng.gaussian_tensor(&[k, n], 1.0);
            let c = matmul(&a, &b);
            assert!(c.rel_err(&naive(&a, &b)) < 1e-5);
        }
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = Rng::new(2);
        let a = rng.gaussian_tensor(&[7, 5], 1.0);
        let b = rng.gaussian_tensor(&[7, 6], 1.0);
        let c1 = matmul_at_b(&a, &b);
        let c2 = matmul(&a.transpose2().unwrap(), &b);
        assert!(c1.rel_err(&c2) < 1e-5);

        let d = rng.gaussian_tensor(&[6, 5], 1.0);
        let e1 = matmul_a_bt(&a, &d);
        let e2 = matmul(&a, &d.transpose2().unwrap());
        assert!(e1.rel_err(&e2) < 1e-5);
    }

    #[test]
    fn identity() {
        let mut rng = Rng::new(3);
        let a = rng.gaussian_tensor(&[4, 4], 1.0);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        assert!(matmul(&a, &eye).rel_err(&a) < 1e-6);
    }
}
