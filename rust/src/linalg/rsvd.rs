//! Host-side QB randomized range finder — the rust mirror of
//! `python/compile/rsvd_lib.py`, used by the reference optimizers and the
//! Lemma B.1 property tests.
//!
//! Two paths:
//!  * [`rsvd_qb`] / [`rsvd_qb_ws`]: the direct recompression `Y = A Ω`,
//!    `Q = qr(Y)`, `B = Qᵀ A` on a materialized A.
//!  * [`rsvd_qb_factored`]: the MLorc fast path. The matrix being
//!    recompressed every optimizer step is never arbitrary — it is
//!    `A = β·Q_prev B_prev + (1−β)·G`. Exploiting that factor structure:
//!
//!    ```text
//!    Y  = A Ω  = β·Q_prev (B_prev Ω) + (1−β)·(G Ω)
//!    B  = Qᵀ A = β·(Qᵀ Q_prev) B_prev + (1−β)·(Qᵀ G)
//!    ```
//!
//!    so A is never materialized: the previous-state terms collapse to
//!    O((m+n)·l²) small GEMMs, the only O(m·n·l) contractions left are the
//!    two thin-output gradient sketches `G Ω` and `Qᵀ G`, and the single
//!    dense reconstruction that remains is fused into the optimizer apply
//!    (see `optim::mlorc`). Up to f32 reassociation this is algebraically
//!    identical to the direct path.

use crate::obs;
use crate::tensor::Tensor;

use super::matmul::{matmul_class_at_b_into, matmul_class_into};
use super::qr::mgs_qr_class;
use super::{matmul, matmul_at_b_into, matmul_into, mgs_qr_ws, Rng, Workspace};

/// A ~= Q @ B with Q (m, l) column-orthonormal, B = Q^T A (l, n).
/// `omega` must be (n, l) Gaussian.
pub fn rsvd_qb(a: &Tensor, omega: &Tensor) -> (Tensor, Tensor) {
    let mut ws = Workspace::new();
    rsvd_qb_ws(a, omega, &mut ws)
}

/// Direct QB recompression on pooled scratch; Q and B are backed by
/// workspace buffers (return them with `ws.give_tensor` when replaced).
pub fn rsvd_qb_ws(a: &Tensor, omega: &Tensor, ws: &mut Workspace) -> (Tensor, Tensor) {
    let (m, n) = a.dims2().expect("rsvd input");
    let (n2, l) = omega.dims2().expect("rsvd omega");
    assert_eq!(n, n2, "rsvd omega rows {n2} vs input cols {n}");
    let mut y = ws.take_tensor(&[m, l]);
    matmul_into(&mut y, a, omega);
    let q = mgs_qr_ws(&y, ws);
    ws.give_tensor(y);
    let mut b = ws.take_tensor(&[l, n]);
    matmul_at_b_into(&mut b, &q, a);
    (q, b)
}

/// Factored QB recompression of `A = beta·qp bp + (1−beta)·g` without
/// materializing A. Returns the new (Q, B) factor pair.
pub fn rsvd_qb_factored(
    qp: &Tensor,
    bp: &Tensor,
    beta: f32,
    g: &Tensor,
    omega: &Tensor,
    ws: &mut Workspace,
) -> (Tensor, Tensor) {
    let (m, l) = qp.dims2().expect("factored rsvd q_prev");
    let (l2, n) = bp.dims2().expect("factored rsvd b_prev");
    let (gm, gn) = g.dims2().expect("factored rsvd g");
    let (on, ol) = omega.dims2().expect("factored rsvd omega");
    assert_eq!(l, l2, "factor rank mismatch {l} vs {l2}");
    assert_eq!((gm, gn), (m, n), "gradient shape vs factors");
    assert_eq!((on, ol), (n, l), "omega shape vs factors");

    // Y = beta * qp (bp Ω) + (1-beta) * g Ω
    let mut t1 = ws.take_tensor(&[l, l]);
    matmul_into(&mut t1, bp, omega); // O(n·l²)
    let mut y = ws.take_tensor(&[m, l]);
    matmul_into(&mut y, qp, &t1); // O(m·l²)
    ws.give_tensor(t1);
    let mut gom = ws.take_tensor(&[m, l]);
    matmul_into(&mut gom, g, omega); // thin gradient sketch
    for (yv, &gv) in y.data.iter_mut().zip(&gom.data) {
        *yv = beta * *yv + (1.0 - beta) * gv;
    }
    ws.give_tensor(gom);

    let q = mgs_qr_ws(&y, ws);
    ws.give_tensor(y);

    // B = beta * (Qᵀ qp) bp + (1-beta) * Qᵀ g
    let mut rot = ws.take_tensor(&[l, l]);
    matmul_at_b_into(&mut rot, &q, qp); // O(m·l²)
    let mut b = ws.take_tensor(&[l, n]);
    matmul_into(&mut b, &rot, bp); // O(n·l²)
    ws.give_tensor(rot);
    let mut gproj = ws.take_tensor(&[l, n]);
    matmul_at_b_into(&mut gproj, &q, g); // thin gradient projection
    for (bv, &gv) in b.data.iter_mut().zip(&gproj.data) {
        *bv = beta * *bv + (1.0 - beta) * gv;
    }
    ws.give_tensor(gproj);
    (q, b)
}

/// Batched [`rsvd_qb_ws`] over a shape class: every member shares (m, n, l)
/// and each phase (sketch GEMM, MGS QR, projection GEMM) runs as one
/// stacked pool invocation for the whole class. Per member the phase order
/// and arithmetic are exactly the scalar path's, so each returned (Q, B)
/// pair is bit-identical to a per-member call. Factors are backed by
/// `workspaces[0]` buffers.
pub fn rsvd_qb_class(
    inputs: &[&Tensor],
    omegas: &[&Tensor],
    workspaces: &mut [Workspace],
) -> Vec<(Tensor, Tensor)> {
    let count = inputs.len();
    assert_eq!(count, omegas.len(), "rsvd_qb_class omega count");
    if count == 0 {
        return Vec::new();
    }
    let (m, n) = inputs[0].dims2().expect("rsvd_qb_class input");
    let (n2, l) = omegas[0].dims2().expect("rsvd_qb_class omega");
    assert_eq!(n, n2, "rsvd_qb_class omega rows {n2} vs input cols {n}");

    // Y_i = A_i Ω_i (stacked sketch)
    let mut ys: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[m, l])).collect();
    {
        let _span = obs::span(&obs::registry::RSVD_SKETCH_US);
        matmul_class_into(&mut ys, inputs, omegas);
    }
    // Q_i = qr(Y_i)
    let mut qs: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[m, l])).collect();
    {
        let _span = obs::span(&obs::registry::RSVD_QR_US);
        mgs_qr_class(&ys, &mut qs, workspaces);
    }
    for y in ys {
        workspaces[0].give_tensor(y);
    }
    // B_i = Q_iᵀ A_i (stacked projection)
    let mut bs: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[l, n])).collect();
    {
        let _span = obs::span(&obs::registry::RSVD_PROJECT_US);
        let q_refs: Vec<&Tensor> = qs.iter().collect();
        matmul_class_at_b_into(&mut bs, &q_refs, inputs);
    }
    qs.into_iter().zip(bs).collect()
}

/// Batched [`rsvd_qb_factored`] over a shape class — the MLorc fast path
/// with every small GEMM, gradient sketch, QR, and blend stacked across
/// members. Phase order per member mirrors the scalar function exactly
/// (bit-identity), and the elementwise β-blends use the identical
/// expression.
pub fn rsvd_qb_factored_class(
    qps: &[&Tensor],
    bps: &[&Tensor],
    beta: f32,
    gs: &[&Tensor],
    omegas: &[&Tensor],
    workspaces: &mut [Workspace],
) -> Vec<(Tensor, Tensor)> {
    let count = qps.len();
    assert_eq!(count, bps.len(), "rsvd_factored_class b_prev count");
    assert_eq!(count, gs.len(), "rsvd_factored_class grad count");
    assert_eq!(count, omegas.len(), "rsvd_factored_class omega count");
    if count == 0 {
        return Vec::new();
    }
    let (m, l) = qps[0].dims2().expect("factored class q_prev");
    let (_, n) = bps[0].dims2().expect("factored class b_prev");

    // Y = beta * qp (bp Ω) + (1-beta) * g Ω
    let sketch_span = obs::span(&obs::registry::RSVD_SKETCH_US);
    let mut t1s: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[l, l])).collect();
    matmul_class_into(&mut t1s, bps, omegas);
    let mut ys: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[m, l])).collect();
    {
        let t1_refs: Vec<&Tensor> = t1s.iter().collect();
        matmul_class_into(&mut ys, qps, &t1_refs);
    }
    for t in t1s {
        workspaces[0].give_tensor(t);
    }
    let mut goms: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[m, l])).collect();
    matmul_class_into(&mut goms, gs, omegas);
    for (y, gom) in ys.iter_mut().zip(&goms) {
        for (yv, &gv) in y.data.iter_mut().zip(&gom.data) {
            *yv = beta * *yv + (1.0 - beta) * gv;
        }
    }
    for t in goms {
        workspaces[0].give_tensor(t);
    }
    drop(sketch_span);

    let mut qs: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[m, l])).collect();
    {
        let _span = obs::span(&obs::registry::RSVD_QR_US);
        mgs_qr_class(&ys, &mut qs, workspaces);
    }
    for y in ys {
        workspaces[0].give_tensor(y);
    }

    // B = beta * (Qᵀ qp) bp + (1-beta) * Qᵀ g
    let project_span = obs::span(&obs::registry::RSVD_PROJECT_US);
    let mut rots: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[l, l])).collect();
    {
        let q_refs: Vec<&Tensor> = qs.iter().collect();
        matmul_class_at_b_into(&mut rots, &q_refs, qps);
    }
    let mut bs: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[l, n])).collect();
    {
        let rot_refs: Vec<&Tensor> = rots.iter().collect();
        matmul_class_into(&mut bs, &rot_refs, bps);
    }
    for t in rots {
        workspaces[0].give_tensor(t);
    }
    let mut gprojs: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[l, n])).collect();
    {
        let q_refs: Vec<&Tensor> = qs.iter().collect();
        matmul_class_at_b_into(&mut gprojs, &q_refs, gs);
    }
    for (b, gproj) in bs.iter_mut().zip(&gprojs) {
        for (bv, &gv) in b.data.iter_mut().zip(&gproj.data) {
            *bv = beta * *bv + (1.0 - beta) * gv;
        }
    }
    for t in gprojs {
        workspaces[0].give_tensor(t);
    }
    drop(project_span);
    qs.into_iter().zip(bs).collect()
}

/// Convenience: draw Omega from `rng` and return the reconstruction QB.
pub fn rsvd_reconstruct(a: &Tensor, l: usize, rng: &mut Rng) -> Tensor {
    let (_, n) = a.dims2().expect("rsvd input");
    let omega = rng.gaussian_tensor(&[n, l], 1.0);
    let (q, b) = rsvd_qb(a, &omega);
    matmul(&q, &b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn exact_on_lowrank_inputs() {
        prop::check(32, |rng| {
            let m = rng.range(8, 48);
            let n = rng.range(8, 48);
            let r = rng.range(1, 5);
            let u = rng.gaussian_tensor(&[m, r], 1.0);
            let v = rng.gaussian_tensor(&[r, n], 1.0);
            let a = matmul(&u, &v);
            let omega = rng.gaussian_tensor(&[n, r], 1.0);
            let (q, b) = rsvd_qb(&a, &omega);
            let rec = matmul(&q, &b);
            let rel = rec.rel_err(&a);
            prop::assert_lt(rel as f64, 1e-3, "rank-r input reconstructs exactly")
        });
    }

    #[test]
    fn reconstruction_never_beats_input_norm() {
        // ||QB||_F <= ||A||_F since QB is an orthogonal projection of A.
        prop::check(32, |rng| {
            let m = rng.range(4, 40);
            let n = rng.range(4, 40);
            // Precondition from the paper (r + p <= min(m, n)); beyond it the
            // range finder has more columns than the space has dimensions.
            let l = rng.range(1, 9).min(n).min(m);
            let a = rng.gaussian_tensor(&[m, n], 1.0);
            let omega = rng.gaussian_tensor(&[n, l], 1.0);
            let (q, b) = rsvd_qb(&a, &omega);
            let rec = matmul(&q, &b);
            prop::assert_lt(
                rec.norm_fro() as f64,
                a.norm_fro() as f64 * (1.0 + 1e-4),
                "projection is a contraction",
            )
        });
    }

    #[test]
    fn factored_path_matches_direct() {
        // The factored recompression must agree with the direct one on the
        // materialized A = beta*QpBp + (1-beta)*G, up to f32 reassociation.
        prop::check(24, |rng| {
            let m = rng.range(6, 40);
            let n = rng.range(6, 40);
            let l = rng.range(1, 7).min(m).min(n);
            let beta = 0.8f32;
            let qp = mgs_qr_ws(&rng.gaussian_tensor(&[m, l], 1.0), &mut Workspace::new());
            let bp = rng.gaussian_tensor(&[l, n], 1.0);
            let g = rng.gaussian_tensor(&[m, n], 1.0);
            let omega = rng.gaussian_tensor(&[n, l], 1.0);

            let mut a = matmul(&qp, &bp);
            a.axpy(1.0 - beta, &g, beta);
            let (qd, bd) = rsvd_qb(&a, &omega);
            let direct = matmul(&qd, &bd);

            let mut ws = Workspace::new();
            let (qf, bf) = rsvd_qb_factored(&qp, &bp, beta, &g, &omega, &mut ws);
            let fact = matmul(&qf, &bf);
            prop::assert_lt(
                fact.rel_err(&direct) as f64,
                5e-4,
                "factored recompression equals direct",
            )
        });
    }

    #[test]
    fn factored_path_zero_state_first_step() {
        // With zero previous factors the factored path must reduce to the
        // direct recompression of (1-beta)*G.
        let mut rng = Rng::new(9);
        let (m, n, l) = (24, 18, 4);
        let beta = 0.8f32;
        let qp = Tensor::zeros(&[m, l]);
        let bp = Tensor::zeros(&[l, n]);
        let g = rng.gaussian_tensor(&[m, n], 1.0);
        let omega = rng.gaussian_tensor(&[n, l], 1.0);
        let mut ws = Workspace::new();
        let (qf, bf) = rsvd_qb_factored(&qp, &bp, beta, &g, &omega, &mut ws);
        let mut scaled = g.clone();
        for x in scaled.data.iter_mut() {
            *x *= 1.0 - beta;
        }
        let (qd, bd) = rsvd_qb(&scaled, &omega);
        let rel = matmul(&qf, &bf).rel_err(&matmul(&qd, &bd));
        assert!(rel < 1e-5, "rel {rel}");
    }

    #[test]
    fn class_paths_bit_match_scalar_paths() {
        let mut rng = Rng::new(21);
        let (m, n, l) = (28, 22, 4);
        let count = 5;
        let mats: Vec<Tensor> = (0..count).map(|_| rng.gaussian_tensor(&[m, n], 1.0)).collect();
        let omegas: Vec<Tensor> =
            (0..count).map(|_| rng.gaussian_tensor(&[n, l], 1.0)).collect();
        let mut ws = Workspace::new();
        let want: Vec<(Vec<f32>, Vec<f32>)> = mats
            .iter()
            .zip(&omegas)
            .map(|(a, om)| {
                let (q, b) = rsvd_qb_ws(a, om, &mut ws);
                let out = (q.data.clone(), b.data.clone());
                ws.give_tensor(q);
                ws.give_tensor(b);
                out
            })
            .collect();
        let mut workspaces: Vec<Workspace> = (0..3).map(|_| Workspace::new()).collect();
        let a_refs: Vec<&Tensor> = mats.iter().collect();
        let om_refs: Vec<&Tensor> = omegas.iter().collect();
        let got = rsvd_qb_class(&a_refs, &om_refs, &mut workspaces);
        for (i, (q, b)) in got.iter().enumerate() {
            assert_eq!(q.data, want[i].0, "direct class Q member {i}");
            assert_eq!(b.data, want[i].1, "direct class B member {i}");
        }

        // factored path
        let beta = 0.9f32;
        let qps: Vec<Tensor> = (0..count)
            .map(|_| mgs_qr_ws(&rng.gaussian_tensor(&[m, l], 1.0), &mut ws))
            .collect();
        let bps: Vec<Tensor> = (0..count).map(|_| rng.gaussian_tensor(&[l, n], 1.0)).collect();
        let gs: Vec<Tensor> = (0..count).map(|_| rng.gaussian_tensor(&[m, n], 1.0)).collect();
        let want_f: Vec<(Vec<f32>, Vec<f32>)> = (0..count)
            .map(|i| {
                let (q, b) = rsvd_qb_factored(&qps[i], &bps[i], beta, &gs[i], &omegas[i], &mut ws);
                let out = (q.data.clone(), b.data.clone());
                ws.give_tensor(q);
                ws.give_tensor(b);
                out
            })
            .collect();
        let qp_refs: Vec<&Tensor> = qps.iter().collect();
        let bp_refs: Vec<&Tensor> = bps.iter().collect();
        let g_refs: Vec<&Tensor> = gs.iter().collect();
        let got_f =
            rsvd_qb_factored_class(&qp_refs, &bp_refs, beta, &g_refs, &om_refs, &mut workspaces);
        for (i, (q, b)) in got_f.iter().enumerate() {
            assert_eq!(q.data, want_f[i].0, "factored class Q member {i}");
            assert_eq!(b.data, want_f[i].1, "factored class B member {i}");
        }
    }

    #[test]
    fn lemma_b1_error_bound_statistical() {
        // E||m_t - QB(m_t)||_F <= gamma (1 - beta2) ||g_t||_F when the
        // previous factor pair is rank l. 20-draw average with 3x slack.
        let (m, n, r, p) = (40, 28, 4, 2);
        let l = r + p;
        let gamma = (1.0 + r as f64 / (p as f64 - 1.0)).sqrt();
        let beta2 = 0.99f32;
        let mut rng = Rng::new(17);
        let q0 = crate::linalg::mgs_qr(&rng.gaussian_tensor(&[m, l], 1.0));
        let b0 = rng.gaussian_tensor(&[l, n], 0.1);
        let recon0 = matmul(&q0, &b0);
        let mut errs = 0.0f64;
        let mut bounds = 0.0f64;
        for _ in 0..20 {
            let g = rng.gaussian_tensor(&[m, n], 1.0);
            let mut mt = recon0.clone();
            mt.axpy(1.0 - beta2, &g, beta2);
            let omega = rng.gaussian_tensor(&[n, l], 1.0);
            let (q, b) = rsvd_qb(&mt, &omega);
            let mut diff = matmul(&q, &b);
            diff.axpy(1.0, &mt, -1.0);
            errs += diff.norm_fro() as f64;
            bounds += gamma * (1.0 - beta2 as f64) * g.norm_fro() as f64;
        }
        assert!(errs <= 3.0 * bounds, "E err {errs} vs bound {bounds}");

        // Same statistic on the factored fast path: the bound must hold
        // there too (it is the same operator up to reassociation).
        let mut errs_f = 0.0f64;
        let mut bounds_f = 0.0f64;
        let mut ws = Workspace::new();
        for _ in 0..20 {
            let g = rng.gaussian_tensor(&[m, n], 1.0);
            let mut mt = recon0.clone();
            mt.axpy(1.0 - beta2, &g, beta2);
            let omega = rng.gaussian_tensor(&[n, l], 1.0);
            let (q, b) = rsvd_qb_factored(&q0, &b0, beta2, &g, &omega, &mut ws);
            let mut diff = matmul(&q, &b);
            diff.axpy(1.0, &mt, -1.0);
            errs_f += diff.norm_fro() as f64;
            bounds_f += gamma * (1.0 - beta2 as f64) * g.norm_fro() as f64;
        }
        assert!(errs_f <= 3.0 * bounds_f, "factored E err {errs_f} vs bound {bounds_f}");
    }
}
