//! Host-side QB randomized range finder — the rust mirror of
//! `python/compile/rsvd_lib.py`, used by the reference optimizers and the
//! Lemma B.1 property tests.

use crate::tensor::Tensor;

use super::{matmul, matmul_at_b, mgs_qr, Rng};

/// A ~= Q @ B with Q (m, l) column-orthonormal, B = Q^T A (l, n).
/// `omega` must be (n, l) Gaussian.
pub fn rsvd_qb(a: &Tensor, omega: &Tensor) -> (Tensor, Tensor) {
    let y = matmul(a, omega);
    let q = mgs_qr(&y);
    let b = matmul_at_b(&q, a);
    (q, b)
}

/// Convenience: draw Omega from `rng` and return the reconstruction QB.
pub fn rsvd_reconstruct(a: &Tensor, l: usize, rng: &mut Rng) -> Tensor {
    let (_, n) = a.dims2().expect("rsvd input");
    let omega = rng.gaussian_tensor(&[n, l], 1.0);
    let (q, b) = rsvd_qb(a, &omega);
    matmul(&q, &b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn exact_on_lowrank_inputs() {
        prop::check(32, |rng| {
            let m = rng.range(8, 48);
            let n = rng.range(8, 48);
            let r = rng.range(1, 5);
            let u = rng.gaussian_tensor(&[m, r], 1.0);
            let v = rng.gaussian_tensor(&[r, n], 1.0);
            let a = matmul(&u, &v);
            let omega = rng.gaussian_tensor(&[n, r], 1.0);
            let (q, b) = rsvd_qb(&a, &omega);
            let rec = matmul(&q, &b);
            let rel = rec.rel_err(&a);
            prop::assert_lt(rel as f64, 1e-3, "rank-r input reconstructs exactly")
        });
    }

    #[test]
    fn reconstruction_never_beats_input_norm() {
        // ||QB||_F <= ||A||_F since QB is an orthogonal projection of A.
        prop::check(32, |rng| {
            let m = rng.range(4, 40);
            let n = rng.range(4, 40);
            // Precondition from the paper (r + p <= min(m, n)); beyond it the
            // range finder has more columns than the space has dimensions.
            let l = rng.range(1, 9).min(n).min(m);
            let a = rng.gaussian_tensor(&[m, n], 1.0);
            let omega = rng.gaussian_tensor(&[n, l], 1.0);
            let (q, b) = rsvd_qb(&a, &omega);
            let rec = matmul(&q, &b);
            prop::assert_lt(
                rec.norm_fro() as f64,
                a.norm_fro() as f64 * (1.0 + 1e-4),
                "projection is a contraction",
            )
        });
    }

    #[test]
    fn lemma_b1_error_bound_statistical() {
        // E||m_t - QB(m_t)||_F <= gamma (1 - beta2) ||g_t||_F when the
        // previous factor pair is rank l. 20-draw average with 3x slack.
        let (m, n, r, p) = (40, 28, 4, 2);
        let l = r + p;
        let gamma = (1.0 + r as f64 / (p as f64 - 1.0)).sqrt();
        let beta2 = 0.99f32;
        let mut rng = Rng::new(17);
        let q0 = mgs_qr(&rng.gaussian_tensor(&[m, l], 1.0));
        let b0 = rng.gaussian_tensor(&[l, n], 0.1);
        let recon0 = matmul(&q0, &b0);
        let mut errs = 0.0f64;
        let mut bounds = 0.0f64;
        for _ in 0..20 {
            let g = rng.gaussian_tensor(&[m, n], 1.0);
            let mut mt = recon0.clone();
            mt.axpy(1.0 - beta2, &g, beta2);
            let omega = rng.gaussian_tensor(&[n, l], 1.0);
            let (q, b) = rsvd_qb(&mt, &omega);
            let mut diff = matmul(&q, &b);
            diff.axpy(1.0, &mt, -1.0);
            errs += diff.norm_fro() as f64;
            bounds += gamma * (1.0 - beta2 as f64) * g.norm_fro() as f64;
        }
        assert!(errs <= 3.0 * bounds, "E err {errs} vs bound {bounds}");
    }
}
