//! Reusable f32 buffer pool for the optimizer hot path.
//!
//! Every MLorc step needs a handful of scratch matrices (sketches,
//! projections, the dense second-moment buffer, QR column scratch). The
//! seed implementation re-allocated all of them every step; a `Workspace`
//! keeps returned buffers on a free list so steady-state steps perform no
//! heap allocation at all.
//!
//! Usage discipline: `take`/`take_tensor` hands out a zeroed buffer of the
//! requested size; `give`/`give_tensor` returns it. Buffers are matched by
//! capacity (first fit), so one pool serves mixed shapes. The pool is
//! deliberately not thread-safe — each worker owns its own `Workspace`.

use crate::tensor::Tensor;

pub struct Workspace {
    free: Vec<Vec<f32>>,
    /// buffers handed out since construction (diagnostics)
    taken: usize,
    /// buffers served from the free list rather than the allocator
    reused: usize,
}

impl Default for Workspace {
    fn default() -> Workspace {
        Workspace::new()
    }
}

impl Clone for Workspace {
    /// A cloned workspace starts with an empty pool: pooled scratch is an
    /// optimization, not state, and cloning optimizer states must not
    /// double their resident footprint.
    fn clone(&self) -> Workspace {
        Workspace::new()
    }
}

impl std::fmt::Debug for Workspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workspace")
            .field("pooled", &self.free.len())
            .field("taken", &self.taken)
            .field("reused", &self.reused)
            .finish()
    }
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace { free: Vec::new(), taken: 0, reused: 0 }
    }

    /// A zeroed buffer of exactly `len` elements (best-fit from the pool).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        self.taken += 1;
        let pos = self
            .free
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        match pos {
            Some(i) => {
                self.reused += 1;
                let mut buf = self.free.swap_remove(i);
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer to the pool.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// A zeroed tensor of `shape`, backed by a pooled buffer.
    pub fn take_tensor(&mut self, shape: &[usize]) -> Tensor {
        let len = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: self.take(len) }
    }

    /// Return a tensor's backing buffer to the pool.
    pub fn give_tensor(&mut self, t: Tensor) {
        self.give(t.data);
    }

    /// Fraction of takes served without allocating (1.0 in steady state).
    pub fn reuse_ratio(&self) -> f64 {
        if self.taken == 0 {
            return 1.0;
        }
        self.reused as f64 / self.taken as f64
    }

    /// Bytes currently held on the free list.
    pub fn pooled_bytes(&self) -> usize {
        self.free.iter().map(|b| b.capacity() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_allocates_nothing() {
        let mut ws = Workspace::new();
        for round in 0..4 {
            let a = ws.take(128);
            let b = ws.take_tensor(&[8, 4]);
            assert!(a.iter().all(|x| *x == 0.0), "buffers are zeroed");
            assert!(b.data.iter().all(|x| *x == 0.0));
            ws.give(a);
            ws.give_tensor(b);
            if round > 0 {
                assert_eq!(ws.reuse_ratio(), (2 * round) as f64 / (2 * round + 2) as f64);
            }
        }
        // after warmup every take was a reuse
        let before = ws.pooled_bytes();
        let c = ws.take(100); // fits in the 128-capacity buffer
        ws.give(c);
        assert_eq!(ws.pooled_bytes(), before);
    }

    #[test]
    fn dirty_buffers_come_back_zeroed() {
        let mut ws = Workspace::new();
        let mut t = ws.take_tensor(&[4, 4]);
        t.data.iter_mut().for_each(|x| *x = f32::NAN);
        ws.give_tensor(t);
        let t2 = ws.take_tensor(&[2, 8]);
        assert!(t2.data.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn clone_is_empty() {
        let mut ws = Workspace::new();
        let b = ws.take(64);
        ws.give(b);
        assert!(ws.pooled_bytes() > 0);
        assert_eq!(ws.clone().pooled_bytes(), 0);
    }
}
