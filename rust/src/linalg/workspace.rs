//! Reusable f32 buffer pool for the optimizer hot path.
//!
//! Every MLorc step needs a handful of scratch matrices (sketches,
//! projections, the dense second-moment buffer, QR column scratch). The
//! seed implementation re-allocated all of them every step; a `Workspace`
//! keeps returned buffers on a free list so steady-state steps perform no
//! heap allocation at all.
//!
//! Usage discipline: `take`/`take_tensor` hands out a zeroed buffer of the
//! requested size; `give`/`give_tensor` returns it. Buffers are matched by
//! capacity (first fit), so one pool serves mixed shapes. The pool is
//! deliberately not thread-safe — each worker owns its own `Workspace`;
//! the GEMM kernels' panel-packing scratch comes from a per-thread
//! workspace ([`with_kernel_ws`]) so pool workers never contend.
//!
//! Retention is bounded: [`Workspace::trim`] drops the largest pooled
//! buffers until the free list fits a byte budget — the coordinator calls
//! it after every optimizer step so a one-off large parameter cannot pin
//! its scratch forever.

use std::cell::RefCell;

use crate::tensor::Tensor;

// ------------------------------------------------------------ aligned buf

/// One 32-byte-aligned lane of 8 f32 — the allocation unit of
/// [`AlignedBuf`], matching the SIMD width (`simd::LANES`).
#[repr(C, align(32))]
#[derive(Clone, Copy)]
struct Lane([f32; 8]);

const ZERO_LANE: Lane = Lane([0.0; 8]);

/// A 32-byte-aligned f32 scratch buffer for packed GEMM panels. Backed by
/// `Vec<Lane>` so the start of the slice is always SIMD-aligned; exposed
/// as plain `&[f32]` / `&mut [f32]` views of the first `len` elements.
pub struct AlignedBuf {
    lanes: Vec<Lane>,
    len: usize,
}

impl AlignedBuf {
    fn with_len(len: usize) -> AlignedBuf {
        AlignedBuf { lanes: vec![ZERO_LANE; len.div_ceil(8)], len }
    }

    /// Reset to `len` zeroed elements, reusing the lane allocation.
    fn reset(&mut self, len: usize) {
        let lanes = len.div_ceil(8);
        self.lanes.clear();
        self.lanes.resize(lanes, ZERO_LANE);
        self.len = len;
    }

    /// Reset to `len` elements of *unspecified* content (stale pool data),
    /// skipping the zero pass — for pack panels that are fully overwritten
    /// before any read.
    fn reset_dirty(&mut self, len: usize) {
        let lanes = len.div_ceil(8);
        self.lanes.resize(lanes, ZERO_LANE);
        self.len = len;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn cap_bytes(&self) -> usize {
        self.lanes.capacity() * std::mem::size_of::<Lane>()
    }

    pub fn as_slice(&self) -> &[f32] {
        // Lane is repr(C) over [f32; 8]: a lane slice reinterprets as a
        // contiguous f32 slice of 8x the length.
        unsafe { std::slice::from_raw_parts(self.lanes.as_ptr() as *const f32, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.lanes.as_mut_ptr() as *mut f32, self.len) }
    }
}

// -------------------------------------------------------------- workspace

pub struct Workspace {
    free: Vec<Vec<f32>>,
    /// aligned pack-panel buffers, pooled separately from plain scratch
    free_aligned: Vec<AlignedBuf>,
    /// buffers handed out since construction (diagnostics)
    taken: usize,
    /// buffers served from the free list rather than the allocator
    reused: usize,
}

impl Default for Workspace {
    fn default() -> Workspace {
        Workspace::new()
    }
}

impl Clone for Workspace {
    /// A cloned workspace starts with an empty pool: pooled scratch is an
    /// optimization, not state, and cloning optimizer states must not
    /// double their resident footprint.
    fn clone(&self) -> Workspace {
        Workspace::new()
    }
}

impl std::fmt::Debug for Workspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workspace")
            .field("pooled", &self.free.len())
            .field("pooled_aligned", &self.free_aligned.len())
            .field("taken", &self.taken)
            .field("reused", &self.reused)
            .finish()
    }
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace { free: Vec::new(), free_aligned: Vec::new(), taken: 0, reused: 0 }
    }

    /// A zeroed buffer of exactly `len` elements (best-fit from the pool).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        self.taken += 1;
        let pos = self
            .free
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        match pos {
            Some(i) => {
                self.reused += 1;
                let mut buf = self.free.swap_remove(i);
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer to the pool.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// A zeroed 32-byte-aligned buffer of `len` elements (best-fit from
    /// the aligned pool) — GEMM panel-packing scratch.
    pub fn take_aligned(&mut self, len: usize) -> AlignedBuf {
        self.taken += 1;
        let need = len.div_ceil(8);
        let pos = self
            .free_aligned
            .iter()
            .enumerate()
            .filter(|(_, b)| b.lanes.capacity() >= need)
            .min_by_key(|(_, b)| b.lanes.capacity())
            .map(|(i, _)| i);
        match pos {
            Some(i) => {
                self.reused += 1;
                let mut buf = self.free_aligned.swap_remove(i);
                buf.reset(len);
                buf
            }
            None => AlignedBuf::with_len(len),
        }
    }

    /// Like [`take_aligned`](Workspace::take_aligned) but with
    /// *unspecified* contents (stale pool data) — skips the zero pass for
    /// callers that fully overwrite the buffer before reading it (the
    /// GEMM pack panels, which would otherwise pay ~50% extra memory
    /// traffic per KC-panel).
    pub fn take_aligned_dirty(&mut self, len: usize) -> AlignedBuf {
        self.taken += 1;
        let need = len.div_ceil(8);
        let pos = self
            .free_aligned
            .iter()
            .enumerate()
            .filter(|(_, b)| b.lanes.capacity() >= need)
            .min_by_key(|(_, b)| b.lanes.capacity())
            .map(|(i, _)| i);
        match pos {
            Some(i) => {
                self.reused += 1;
                let mut buf = self.free_aligned.swap_remove(i);
                buf.reset_dirty(len);
                buf
            }
            None => AlignedBuf::with_len(len),
        }
    }

    /// Return an aligned buffer to the pool.
    pub fn give_aligned(&mut self, buf: AlignedBuf) {
        if buf.lanes.capacity() > 0 {
            self.free_aligned.push(buf);
        }
    }

    /// A zeroed tensor of `shape`, backed by a pooled buffer.
    pub fn take_tensor(&mut self, shape: &[usize]) -> Tensor {
        let len = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: self.take(len) }
    }

    /// Return a tensor's backing buffer to the pool.
    pub fn give_tensor(&mut self, t: Tensor) {
        self.give(t.data);
    }

    /// Fraction of takes served without allocating (1.0 in steady state).
    pub fn reuse_ratio(&self) -> f64 {
        if self.taken == 0 {
            return 1.0;
        }
        self.reused as f64 / self.taken as f64
    }

    /// Bytes currently held on the free lists (plain + aligned).
    pub fn pooled_bytes(&self) -> usize {
        self.free.iter().map(|b| b.capacity() * 4).sum::<usize>()
            + self.free_aligned.iter().map(|b| b.cap_bytes()).sum::<usize>()
    }

    /// Drop pooled buffers, largest first, until the free lists hold at
    /// most `max_bytes`. Buffers currently handed out are unaffected; the
    /// next `give` may push retention above the bound again until the next
    /// trim (the coordinator trims after every step).
    pub fn trim(&mut self, max_bytes: usize) {
        while self.pooled_bytes() > max_bytes {
            // largest buffer across both pools
            let big_plain = self
                .free
                .iter()
                .enumerate()
                .max_by_key(|(_, b)| b.capacity() * 4)
                .map(|(i, b)| (i, b.capacity() * 4));
            let big_aligned = self
                .free_aligned
                .iter()
                .enumerate()
                .max_by_key(|(_, b)| b.cap_bytes())
                .map(|(i, b)| (i, b.cap_bytes()));
            match (big_plain, big_aligned) {
                (Some((i, pb)), Some((j, ab))) => {
                    if pb >= ab {
                        self.free.swap_remove(i);
                    } else {
                        self.free_aligned.swap_remove(j);
                    }
                }
                (Some((i, _)), None) => {
                    self.free.swap_remove(i);
                }
                (None, Some((j, _))) => {
                    self.free_aligned.swap_remove(j);
                }
                (None, None) => return, // nothing pooled; bound unreachable
            }
        }
    }
}

// ----------------------------------------------------- per-thread scratch

/// Retention cap for each thread's kernel workspace, applied after every
/// `with_kernel_ws` scope. Pack panels are at most KC rows × the band's
/// row width, so a single wide operand can pool several MB per thread
/// (pool workers live for the process); the trim keeps that bounded
/// independently of the coordinator's own `Workspace::trim` calls.
const KERNEL_WS_TRIM_BYTES: usize = 8 << 20;

thread_local! {
    /// Kernel-internal scratch (packed TN/NT panels). Per-thread so pool
    /// workers and the caller never contend; retained across calls like
    /// any workspace, trimmed to [`KERNEL_WS_TRIM_BYTES`] on scope exit.
    static KERNEL_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

/// Run `f` with this thread's kernel workspace. Not re-entrant: kernel
/// band bodies must not nest `with_kernel_ws` calls (they don't — bands
/// never invoke other GEMMs).
pub fn with_kernel_ws<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    KERNEL_WS.with(|ws| {
        let ws = &mut ws.borrow_mut();
        let out = f(ws);
        ws.trim(KERNEL_WS_TRIM_BYTES);
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_allocates_nothing() {
        let mut ws = Workspace::new();
        for round in 0..4 {
            let a = ws.take(128);
            let b = ws.take_tensor(&[8, 4]);
            assert!(a.iter().all(|x| *x == 0.0), "buffers are zeroed");
            assert!(b.data.iter().all(|x| *x == 0.0));
            ws.give(a);
            ws.give_tensor(b);
            if round > 0 {
                assert_eq!(ws.reuse_ratio(), (2 * round) as f64 / (2 * round + 2) as f64);
            }
        }
        // after warmup every take was a reuse
        let before = ws.pooled_bytes();
        let c = ws.take(100); // fits in the 128-capacity buffer
        ws.give(c);
        assert_eq!(ws.pooled_bytes(), before);
    }

    #[test]
    fn dirty_buffers_come_back_zeroed() {
        let mut ws = Workspace::new();
        let mut t = ws.take_tensor(&[4, 4]);
        t.data.iter_mut().for_each(|x| *x = f32::NAN);
        ws.give_tensor(t);
        let t2 = ws.take_tensor(&[2, 8]);
        assert!(t2.data.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn clone_is_empty() {
        let mut ws = Workspace::new();
        let b = ws.take(64);
        ws.give(b);
        assert!(ws.pooled_bytes() > 0);
        assert_eq!(ws.clone().pooled_bytes(), 0);
    }

    #[test]
    fn aligned_buffers_are_aligned_zeroed_and_reused() {
        let mut ws = Workspace::new();
        for len in [1usize, 7, 8, 9, 100] {
            let mut b = ws.take_aligned(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_slice().as_ptr() as usize % 32, 0, "32B alignment");
            assert!(b.as_slice().iter().all(|x| *x == 0.0));
            b.as_mut_slice().iter_mut().for_each(|x| *x = f32::NAN);
            ws.give_aligned(b);
        }
        let taken_before = ws.taken;
        let b = ws.take_aligned(64); // reuse of the 100-elem buffer
        assert!(b.as_slice().iter().all(|x| *x == 0.0), "reused buffers are re-zeroed");
        assert_eq!(ws.taken, taken_before + 1);
        assert!(ws.reused > 0);
        ws.give_aligned(b);
        // dirty variant: length/alignment guaranteed, contents unspecified
        let d = ws.take_aligned_dirty(32);
        assert_eq!(d.len(), 32);
        assert_eq!(d.as_slice().as_ptr() as usize % 32, 0);
        ws.give_aligned(d);
    }

    #[test]
    fn trim_bounds_retention() {
        let mut ws = Workspace::new();
        for len in [1024usize, 2048, 4096, 512] {
            let b = ws.take(len);
            ws.give(b);
        }
        let a = ws.take_aligned(4096);
        ws.give_aligned(a);
        assert!(ws.pooled_bytes() > 8 * 1024);
        ws.trim(8 * 1024);
        assert!(ws.pooled_bytes() <= 8 * 1024, "pooled {}", ws.pooled_bytes());
        // the small buffers survive (largest dropped first)
        assert!(ws.free.iter().any(|b| b.capacity() == 512));
        ws.trim(0);
        assert_eq!(ws.pooled_bytes(), 0);
        // trimming an empty pool is a no-op, not a hang
        ws.trim(0);
    }

    #[test]
    fn kernel_ws_is_per_thread_and_reuses() {
        let cap_before = with_kernel_ws(|ws| {
            let b = ws.take_aligned(256);
            let p = b.as_slice().as_ptr() as usize;
            ws.give_aligned(b);
            p
        });
        let cap_after = with_kernel_ws(|ws| {
            let b = ws.take_aligned(200);
            let p = b.as_slice().as_ptr() as usize;
            ws.give_aligned(b);
            p
        });
        assert_eq!(cap_before, cap_after, "same thread reuses the pack buffer");
    }
}
