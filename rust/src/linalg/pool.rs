//! Persistent worker pool behind every band-parallel kernel.
//!
//! PR 1 parallelized the GEMM and fused-apply kernels by spawning fresh OS
//! threads with `std::thread::scope` on every call — ~10µs per thread per
//! call, duplicated across five call sites. This module replaces all of
//! that with one lazily-initialized, std-only pool (`budget() - 1` workers,
//! the calling thread executes bands too) and a single entry point:
//!
//! ```ignore
//! pool::par_row_bands(rows, madds, |band, range| { /* rows range of C */ });
//! ```
//!
//! Contracts preserved from the spawn-era kernels:
//!
//!  * **Banding determinism.** The band plan (`plan`) partitions `rows`
//!    into `div_ceil` chunks exactly like the old `chunks_mut(rows_per*n)`
//!    scaffolds, and band execution only decides *which* rows a thread
//!    computes, never the reduction order within a row — results are
//!    bit-identical for every thread count (see `linalg::threads`).
//!  * **No nested oversubscription.** `threads::for_work` still returns 1
//!    inside [`threads::serial`] scopes (the coordinator's per-parameter
//!    workers), and a band closure that itself reaches a kernel runs it
//!    inline: `par_row_bands` called from a pool worker never re-enters
//!    the queue, so total live parallelism never exceeds
//!    `threads::budget()`.
//!  * **No deadlock by construction.** Bands are claimed from a shared
//!    atomic cursor; the submitting thread claims bands alongside the
//!    workers and then waits on a per-batch latch, so a busy pool only
//!    means the caller does more of its own work.
//!
//! Mutable outputs cross into the band closure through [`BandedMut`], a
//! send/sync wrapper whose (unsafe) accessor hands out the sub-slice for a
//! row range — sound because `plan` produces disjoint ranges and every
//! band index is claimed exactly once.
//!
//! On top of the single-matrix entry point sit the *shape-class* helpers
//! behind batched multi-parameter stepping: [`par_stacked_rows`] bands the
//! concatenated row space of N equally-shaped members and splits every
//! claimed band at member boundaries (so a kernel invocation always works
//! rows of exactly one member — banding determinism carries over verbatim,
//! because per-row arithmetic never depends on where a band starts), and
//! [`par_member_tasks`] claims whole members from an atomic cursor with a
//! per-thread scratch slot (for inherently-serial per-member work like MGS
//! QR). [`StackedMut`] / [`DisjointMut`] are the matching row-range /
//! whole-member mutable accessors.

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::obs;

use super::threads;

// ------------------------------------------------------------------ batch

/// One parallel region: a lifetime-erased band closure plus claim/finish
/// bookkeeping. Lives in an `Arc` shared by the queue, the workers and the
/// submitting thread.
struct Batch {
    /// Borrow of the caller's closure, erased to `'static`. Only
    /// dereferenced while executing a claimed band; the caller blocks
    /// until `finished == nbands`, so the borrow cannot dangle.
    f: &'static (dyn Fn(usize, Range<usize>) + Sync),
    rows: usize,
    rows_per: usize,
    nbands: usize,
    /// Next unclaimed band index (may overshoot `nbands`).
    next: AtomicUsize,
    /// Set if any band panicked; the submitter re-panics.
    panicked: AtomicBool,
    /// Count of completed bands + the latch the submitter waits on.
    finished: Mutex<usize>,
    done_cv: Condvar,
}

impl Batch {
    /// Claim and run bands until the cursor is exhausted. Returns how many
    /// bands this thread executed.
    fn work(&self) -> usize {
        let mut ran = 0;
        loop {
            let band = self.next.fetch_add(1, Ordering::Relaxed);
            if band >= self.nbands {
                return ran;
            }
            let lo = band * self.rows_per;
            let hi = self.rows.min(lo + self.rows_per);
            let r = catch_unwind(AssertUnwindSafe(|| (self.f)(band, lo..hi)));
            if r.is_err() {
                self.panicked.store(true, Ordering::SeqCst);
            }
            ran += 1;
            let mut fin = self.finished.lock().unwrap();
            *fin += 1;
            if *fin == self.nbands {
                self.done_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.nbands
    }
}

// ------------------------------------------------------------------- pool

struct Shared {
    /// Batches with unclaimed bands, oldest first.
    queue: Mutex<Vec<Arc<Batch>>>,
    work_cv: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    workers: usize,
}

thread_local! {
    /// True on pool worker threads: kernels called from inside a band run
    /// inline instead of re-entering the queue (no nested parallelism, no
    /// self-deadlock).
    static ON_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// True when the current thread is a pool worker.
pub fn on_worker() -> bool {
    ON_WORKER.with(|w| w.get())
}

fn worker_loop(shared: Arc<Shared>) {
    ON_WORKER.with(|w| w.set(true));
    loop {
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // Drop exhausted batches, pick the oldest live one.
                q.retain(|b| !b.exhausted());
                if let Some(b) = q.first() {
                    break b.clone();
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        batch.work();
    }
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let shared = Arc::new(Shared { queue: Mutex::new(Vec::new()), work_cv: Condvar::new() });
        // The submitting thread always executes bands itself, so budget n
        // needs n-1 workers. Workers are detached and park on `work_cv`
        // between batches; they die with the process.
        let workers = threads::budget().saturating_sub(1);
        for i in 0..workers {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name(format!("mlorc-pool-{i}"))
                .spawn(move || worker_loop(sh))
                .expect("spawn pool worker");
        }
        obs::registry::POOL_WORKERS.set(workers as u64);
        Pool { shared, workers }
    })
}

/// Number of persistent worker threads (0 until the first parallel call
/// lazily starts the pool, then `threads::budget() - 1`). Diagnostics
/// only; never initializes the pool itself.
pub fn worker_count() -> usize {
    POOL.get().map_or(0, |p| p.workers)
}

// ------------------------------------------------------------- entry point

/// The band plan for a kernel of `madds` multiply-adds over `rows`
/// independent output rows: `(nbands, rows_per)`. Band `b` covers rows
/// `b*rows_per .. min(rows, (b+1)*rows_per)` — identical to the spawn-era
/// `chunks_mut` partition, so banding stays bit-deterministic. Callers
/// that need per-band scratch (the fused applies) size it with this.
pub fn plan(rows: usize, madds: usize) -> (usize, usize) {
    let nt = if on_worker() { 1 } else { threads::for_work(madds, rows) };
    if nt <= 1 || rows == 0 {
        return (1, rows.max(1));
    }
    let rows_per = rows.div_ceil(nt);
    (rows.div_ceil(rows_per), rows_per)
}

/// Run `f(band_idx, row_range)` over the band plan for (`rows`, `madds`),
/// in parallel on the persistent pool when the work warrants it. Returns
/// after every band has finished. Single entry point for all band-parallel
/// kernels (three GEMM variants + two fused applies).
pub fn par_row_bands<F>(rows: usize, madds: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    if rows == 0 {
        return;
    }
    let (nbands, rows_per) = plan(rows, madds);
    if nbands <= 1 {
        f(0, 0..rows);
        return;
    }
    // Pool dispatch metrics live only on this multi-band path: the inline
    // fast path above stays untouched (zero instrumentation cost for
    // small kernels). The span covers submit + own work + latch wait.
    let _dispatch_span = obs::span(&obs::registry::POOL_DISPATCH_US);
    obs::registry::POOL_DISPATCHES.add(1);
    obs::registry::POOL_BANDS.add(nbands as u64);
    // Erase the closure's lifetime: we block on the latch below, so the
    // borrow outlives every dereference (see `Batch::f`).
    let f_ref: &(dyn Fn(usize, Range<usize>) + Sync) = &f;
    let f_static: &'static (dyn Fn(usize, Range<usize>) + Sync) =
        unsafe { std::mem::transmute(f_ref) };
    let batch = Arc::new(Batch {
        f: f_static,
        rows,
        rows_per,
        nbands,
        next: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        finished: Mutex::new(0),
        done_cv: Condvar::new(),
    });
    let p = pool();
    {
        let mut q = p.shared.queue.lock().unwrap();
        q.push(batch.clone());
    }
    p.shared.work_cv.notify_all();
    // Work alongside the pool, then wait for stragglers. The wait span
    // isolates straggler time (caller idle at the latch) from the total
    // dispatch wall above — the gap between the two distributions is
    // worker utilization.
    batch.work();
    {
        let _wait_span = obs::span(&obs::registry::POOL_WAIT_US);
        let mut fin = batch.finished.lock().unwrap();
        while *fin < nbands {
            fin = batch.done_cv.wait(fin).unwrap();
        }
    }
    // Workers drop exhausted batches lazily; make sure ours is gone even
    // if no worker wakes again.
    p.shared.queue.lock().unwrap().retain(|b| !Arc::ptr_eq(b, &batch));
    if batch.panicked.load(Ordering::SeqCst) {
        panic!("par_row_bands: a band closure panicked");
    }
}

// -------------------------------------------------------------- BandedMut

/// A mutable f32 slice that band closures may carve disjoint row ranges
/// out of. `Send + Sync` so it can be captured by the shared band closure;
/// soundness rests on the `par_row_bands` contract that band row ranges
/// are disjoint and each band index runs exactly once.
pub struct BandedMut<'a> {
    ptr: *mut f32,
    len: usize,
    _life: std::marker::PhantomData<&'a mut [f32]>,
}

unsafe impl Send for BandedMut<'_> {}
unsafe impl Sync for BandedMut<'_> {}

impl<'a> BandedMut<'a> {
    pub fn new(s: &'a mut [f32]) -> BandedMut<'a> {
        BandedMut { ptr: s.as_mut_ptr(), len: s.len(), _life: std::marker::PhantomData }
    }

    /// The sub-slice holding rows `r` of width `width` (elements
    /// `r.start*width .. r.end*width`).
    ///
    /// # Safety
    /// Caller must guarantee no two live borrows overlap — inside
    /// `par_row_bands` that holds when every band uses its own `r` (bands
    /// are disjoint) and a distinct `width`-consistent layout.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn rows(&self, r: Range<usize>, width: usize) -> &mut [f32] {
        let lo = r.start * width;
        let hi = r.end * width;
        // Hard assert (once per band, not per element): callers size
        // per-band scratch from a separate `plan()` call, and a plan/
        // execution divergence must panic rather than corrupt the heap.
        assert!(lo <= hi && hi <= self.len, "band slice {lo}..{hi} of {}", self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

// ---------------------------------------------------------- shape classes

/// Band-parallel execution over the stacked row space of `members`
/// equally-shaped members of `rows` rows each. The plan treats the class
/// as one `members * rows`-row kernel (so dispatch cost is paid once per
/// class, not per member), but every claimed band is split at member
/// boundaries before reaching `f(band_idx, member_idx, row_range)` — a
/// single invocation always covers rows of exactly one member.
///
/// Bit-determinism: the per-row arithmetic of every banded kernel is
/// independent of where its band starts (that is the `plan` contract), so
/// splitting a band at a member boundary produces the same bits as running
/// the member's rows in any other banding — including the scalar
/// per-member call.
pub fn par_stacked_rows<F>(members: usize, rows: usize, madds: usize, f: F)
where
    F: Fn(usize, usize, Range<usize>) + Sync,
{
    if members == 0 || rows == 0 {
        return;
    }
    par_row_bands(members * rows, madds, move |band, flat| {
        let mut lo = flat.start;
        while lo < flat.end {
            let member = lo / rows;
            let hi = flat.end.min((member + 1) * rows);
            f(band, member, (lo - member * rows)..(hi - member * rows));
            lo = hi;
        }
    });
}

/// Run one task per member on the pool, claiming member indices from an
/// atomic cursor. Each participating thread takes one scratch slot
/// (take-once, like the per-band workspaces of the old per-parameter
/// stepper) and reuses it across every member it claims. Used for
/// per-member work that is inherently serial inside a member (MGS QR, the
/// scalar-step fallback) but independent across members.
pub fn par_member_tasks<S, F>(slots: Vec<S>, members: usize, f: F)
where
    S: Send,
    F: Fn(usize, &mut S) + Sync,
{
    if members == 0 || slots.is_empty() {
        return;
    }
    let nslots = slots.len().min(members);
    let slots: Vec<Mutex<Option<S>>> =
        slots.into_iter().take(nslots).map(|s| Mutex::new(Some(s))).collect();
    let next = AtomicUsize::new(0);
    threads::with_budget(nslots, || {
        par_row_bands(nslots, usize::MAX / 4, |_, range| {
            for si in range {
                let Some(mut slot) = slots[si].lock().unwrap().take() else { continue };
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= members {
                        break;
                    }
                    f(i, &mut slot);
                }
            }
        });
    });
}

/// Row-range access into the buffers of a shape class: `members` equally
/// sized `f32` buffers, addressed as (member, row range). The stacked
/// sibling of [`BandedMut`] — same soundness argument, with
/// `par_stacked_rows` guaranteeing that no two live borrows of one
/// member's rows overlap.
pub struct StackedMut<'a> {
    ptrs: Vec<*mut f32>,
    member_len: usize,
    _life: std::marker::PhantomData<&'a mut [f32]>,
}

unsafe impl Send for StackedMut<'_> {}
unsafe impl Sync for StackedMut<'_> {}

impl<'a> StackedMut<'a> {
    /// Wrap one mutable buffer per member; every buffer must have exactly
    /// `member_len` elements (shape classes are uniform by construction).
    pub fn new<I>(members: I, member_len: usize) -> StackedMut<'a>
    where
        I: Iterator<Item = &'a mut [f32]>,
    {
        let ptrs = members
            .map(|s| {
                assert_eq!(s.len(), member_len, "stacked member buffer length");
                s.as_mut_ptr()
            })
            .collect();
        StackedMut { ptrs, member_len, _life: std::marker::PhantomData }
    }

    /// The sub-slice holding rows `r` (width `width`) of member `member`.
    ///
    /// # Safety
    /// As [`BandedMut::rows`]: no two live borrows may overlap. Inside
    /// `par_stacked_rows` that holds because bands are disjoint in the
    /// stacked row space and each (member, range) pair runs exactly once.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn rows(&self, member: usize, r: Range<usize>, width: usize) -> &mut [f32] {
        let lo = r.start * width;
        let hi = r.end * width;
        assert!(
            lo <= hi && hi <= self.member_len,
            "stacked slice {lo}..{hi} of {}",
            self.member_len
        );
        std::slice::from_raw_parts_mut(self.ptrs[member].add(lo), hi - lo)
    }
}

/// Whole-item mutable access across threads for member-granular tasks
/// (one task owns one item for its whole duration). Soundness rests on
/// the `par_member_tasks` contract that every index is claimed exactly
/// once.
pub struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    pub fn new(s: &'a mut [T]) -> DisjointMut<'a, T> {
        DisjointMut { ptr: s.as_mut_ptr(), len: s.len(), _life: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable reference to item `i`.
    ///
    /// # Safety
    /// Caller must guarantee no two live borrows of the same index — holds
    /// when each index is claimed by exactly one `par_member_tasks` task.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn item(&self, i: usize) -> &mut T {
        assert!(i < self.len, "disjoint item {i} of {}", self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_matches_chunks_mut_partition() {
        // div_ceil banding: 10 rows over 4 threads -> 3,3,3,1.
        threads::with_budget(4, || {
            let (nb, rp) = plan(10, usize::MAX / 4);
            assert_eq!((nb, rp), (4, 3));
        });
        // Tiny work stays single-banded regardless of budget.
        let (nb, _) = plan(10, 8);
        assert_eq!(nb, 1);
    }

    #[test]
    fn bands_cover_rows_exactly_once() {
        threads::with_budget(3, || {
            let rows = 17;
            let mut hits = vec![0.0f32; rows];
            let banded = BandedMut::new(&mut hits);
            par_row_bands(rows, usize::MAX / 4, |_, r| {
                let h = unsafe { banded.rows(r, 1) };
                for x in h.iter_mut() {
                    *x += 1.0;
                }
            });
            assert!(hits.iter().all(|&h| h == 1.0), "{hits:?}");
        });
    }

    #[test]
    fn serial_scope_runs_inline() {
        threads::serial(|| {
            let (nb, _) = plan(1024, usize::MAX / 4);
            assert_eq!(nb, 1);
        });
    }

    #[test]
    fn stacked_rows_cover_every_member_exactly_once() {
        threads::with_budget(3, || {
            let members = 5;
            let rows = 7;
            let mut bufs: Vec<Vec<f32>> = vec![vec![0.0; rows]; members];
            let stacked =
                StackedMut::new(bufs.iter_mut().map(|b| b.as_mut_slice()), rows);
            par_stacked_rows(members, rows, usize::MAX / 4, |_, m, r| {
                let h = unsafe { stacked.rows(m, r.clone(), 1) };
                for (x, i) in h.iter_mut().zip(r) {
                    *x += (m * rows + i) as f32 + 1.0;
                }
            });
            for (m, buf) in bufs.iter().enumerate() {
                for (i, x) in buf.iter().enumerate() {
                    assert_eq!(*x, (m * rows + i) as f32 + 1.0, "member {m} row {i}");
                }
            }
        });
    }

    #[test]
    fn member_tasks_claim_each_member_once() {
        threads::with_budget(4, || {
            let members = 13;
            let mut hits = vec![0u32; members];
            let out = DisjointMut::new(&mut hits);
            let slots: Vec<usize> = vec![0, 0, 0, 0];
            par_member_tasks(slots, members, |i, _slot| {
                *unsafe { out.item(i) } += 1;
            });
            assert!(hits.iter().all(|&h| h == 1), "{hits:?}");
        });
    }

    #[test]
    fn more_bands_than_workers_still_complete() {
        // with_budget can exceed the physical worker count; the claim
        // cursor drains everything regardless.
        threads::with_budget(8, || {
            let rows = 64;
            let mut out = vec![0.0f32; rows];
            let banded = BandedMut::new(&mut out);
            par_row_bands(rows, usize::MAX / 4, |_, r| {
                let o = unsafe { banded.rows(r.clone(), 1) };
                for (x, i) in o.iter_mut().zip(r) {
                    *x = i as f32;
                }
            });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i as f32);
            }
        });
    }
}
