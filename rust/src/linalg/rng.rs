//! xoshiro256++ PRNG + Box-Muller Gaussian sampling.
//!
//! The vendor set has no `rand` crate; this is the single source of
//! randomness for the whole coordinator (data generation, init, Omega
//! matrices), so a run is reproducible from one u64 seed.

use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (the xoshiro authors' recommended seeding).
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    /// Derive an independent stream (per-parameter Omega streams etc.).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method without the rejection refinement — bias is
        // < 2^-32 for the n values used here (vocab sizes, task counts).
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= 1e-300 {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self, scale: f32) -> f32 {
        (self.normal() as f32) * scale
    }

    /// Gaussian matrix (used for RSVD Omega inputs and weight init).
    pub fn gaussian_tensor(&mut self, shape: &[usize], scale: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| self.normal_f32(scale)).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Exact stream position for checkpointing: the four xoshiro words
    /// plus the cached Box-Muller spare (as raw bits, so the restore is
    /// bit-exact). A restored stream continues the draw sequence as if it
    /// had never been interrupted.
    pub fn snapshot(&self) -> ([u64; 4], Option<u64>) {
        (self.s, self.spare.map(f64::to_bits))
    }

    /// Rebuild a stream from [`Rng::snapshot`] output.
    pub fn from_snapshot(s: [u64; 4], spare_bits: Option<u64>) -> Rng {
        Rng { s, spare: spare_bits.map(f64::from_bits) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn split_streams_diverge() {
        let mut r = Rng::new(5);
        let mut a = r.split(1);
        let mut b = r.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn snapshot_resumes_mid_stream() {
        // Consume an odd number of normals so the Box-Muller spare is
        // populated, snapshot, then check the restored stream continues
        // bit-identically (both the u64 and the Gaussian paths).
        let mut r = Rng::new(17);
        for _ in 0..7 {
            r.normal();
        }
        let (s, spare) = r.snapshot();
        assert!(spare.is_some(), "odd draw count must cache a spare");
        let mut restored = Rng::from_snapshot(s, spare);
        for _ in 0..16 {
            assert_eq!(r.normal().to_bits(), restored.normal().to_bits());
        }
        assert_eq!(r.next_u64(), restored.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
