//! Singular values via one-sided Jacobi — powers the spectral probe
//! (top-8 singular-value concentration, Figures 1 and 4).
//!
//! One-sided Jacobi orthogonalizes the columns of A by plane rotations;
//! on convergence the column norms are the singular values. Robust, simple
//! and accurate for the sizes the probe sees (<= vocab x d_model).

use crate::tensor::Tensor;

/// All singular values of a 2-D tensor, descending.
pub fn singular_values(a: &Tensor) -> Vec<f32> {
    let (m, n) = a.dims2().expect("singular_values input");
    // Work on the transpose when n > m: fewer columns to rotate, same
    // nonzero spectrum.
    let work = if n > m { a.transpose2().unwrap() } else { a.clone() };
    let (rows, cols) = work.dims2().unwrap();
    // column-major copy
    let mut c: Vec<Vec<f64>> = (0..cols)
        .map(|j| (0..rows).map(|i| work.at2(i, j) as f64).collect())
        .collect();

    let max_sweeps = 30;
    let tol = 1e-12;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..cols {
            for q in (p + 1)..cols {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..rows {
                    app += c[p][i] * c[p][i];
                    aqq += c[q][i] * c[q][i];
                    apq += c[p][i] * c[q][i];
                }
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation that zeroes the (p,q) inner product.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let cs = 1.0 / (1.0 + t * t).sqrt();
                let sn = cs * t;
                for i in 0..rows {
                    let vp = c[p][i];
                    let vq = c[q][i];
                    c[p][i] = cs * vp - sn * vq;
                    c[q][i] = sn * vp + cs * vq;
                }
            }
        }
        if off == 0.0 {
            break;
        }
    }

    let mut sv: Vec<f32> = c
        .iter()
        .map(|col| (col.iter().map(|x| x * x).sum::<f64>()).sqrt() as f32)
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

/// The paper's concentration statistic: sum of top-k singular values over
/// the total sum (Figure 1). Returns 1.0 for a zero matrix (degenerate but
/// well-defined: "all mass in the top k").
pub fn top_k_ratio(a: &Tensor, k: usize) -> f32 {
    let sv = singular_values(a);
    let total: f64 = sv.iter().map(|x| *x as f64).sum();
    if total <= 0.0 {
        return 1.0;
    }
    let top: f64 = sv.iter().take(k).map(|x| *x as f64).sum();
    (top / total) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, Rng};

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Tensor::zeros(&[4, 4]);
        for (i, v) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            a.set2(i, i, *v);
        }
        let sv = singular_values(&a);
        for (got, want) in sv.iter().zip([4.0, 3.0, 2.0, 1.0]) {
            assert!((got - want).abs() < 1e-5);
        }
    }

    #[test]
    fn rank_one_matrix() {
        // A = u v^T has a single nonzero singular value ||u|| * ||v||.
        let mut rng = Rng::new(1);
        let u = rng.gaussian_tensor(&[12, 1], 1.0);
        let v = rng.gaussian_tensor(&[1, 9], 1.0);
        let a = matmul(&u, &v);
        let sv = singular_values(&a);
        let want = u.norm_fro() * v.norm_fro();
        assert!((sv[0] - want).abs() / want < 1e-4);
        assert!(sv[1] < 1e-4 * want);
    }

    #[test]
    fn frobenius_identity() {
        // sum of squared singular values == ||A||_F^2
        let mut rng = Rng::new(2);
        for shape in [[10, 7], [7, 10], [16, 16]] {
            let a = rng.gaussian_tensor(&shape, 1.0);
            let sv = singular_values(&a);
            let ss: f64 = sv.iter().map(|x| (*x as f64).powi(2)).sum();
            let f2 = (a.norm_fro() as f64).powi(2);
            assert!((ss - f2).abs() / f2 < 1e-4, "{ss} vs {f2}");
        }
    }

    #[test]
    fn orthogonal_invariance_and_descending() {
        let mut rng = Rng::new(3);
        let a = rng.gaussian_tensor(&[20, 8], 1.0);
        let sv = singular_values(&a);
        for w in sv.windows(2) {
            assert!(w[0] >= w[1]);
        }
        // rotating columns by Q (orthonormal) preserves singular values
        let q = crate::linalg::mgs_qr(&rng.gaussian_tensor(&[8, 8], 1.0));
        let aq = matmul(&a, &q);
        let sv2 = singular_values(&aq);
        for (x, y) in sv.iter().zip(&sv2) {
            assert!((x - y).abs() < 1e-3 * sv[0]);
        }
    }

    #[test]
    fn top_k_ratio_bounds_and_lowrank() {
        let mut rng = Rng::new(4);
        let u = rng.gaussian_tensor(&[32, 2], 1.0);
        let v = rng.gaussian_tensor(&[2, 24], 1.0);
        let lowrank = matmul(&u, &v);
        // rank-2 matrix: top-8 ratio must be ~1
        assert!(top_k_ratio(&lowrank, 8) > 0.999);
        let noise = rng.gaussian_tensor(&[32, 24], 1.0);
        let r = top_k_ratio(&noise, 8);
        assert!(r > 0.0 && r < 1.0);
        assert_eq!(top_k_ratio(&Tensor::zeros(&[8, 8]), 8), 1.0);
    }
}
