//! Test-support code compiled into the library so unit tests, integration
//! tests and benches share it.

pub mod prop;
