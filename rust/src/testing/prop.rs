//! Mini property-testing harness (the vendor set has no `proptest`).
//!
//! `check(n, f)` runs `f` against `n` independently seeded RNGs; the
//! closure builds its own random case from the RNG and returns
//! `Err(description)` on violation. Failures report the *case seed* so a
//! failing case replays deterministically:
//!
//! ```text
//! property failed (replay with seed 0x000000000000002a): ...
//! ```
//!
//! Set `MLORC_PROP_SEED` to replay one specific case, and
//! `MLORC_PROP_CASES` to scale case counts up in long runs.

use crate::linalg::Rng;

pub type PropResult = Result<(), String>;

/// Run `f` over `n` seeded cases (scaled by `MLORC_PROP_CASES`).
pub fn check(n: usize, f: impl Fn(&mut Rng) -> PropResult) {
    if let Ok(seed_s) = std::env::var("MLORC_PROP_SEED") {
        let seed = parse_seed(&seed_s);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed (replay with seed {seed:#018x}): {msg}");
        }
        return;
    }
    let scale: usize = std::env::var("MLORC_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    for case in 0..(n * scale) {
        let seed = 0x5EED_0000u64 ^ (case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed (replay with seed {seed:#018x}): {msg}");
        }
    }
}

fn parse_seed(s: &str) -> u64 {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).expect("bad hex MLORC_PROP_SEED")
    } else {
        s.parse().expect("bad MLORC_PROP_SEED")
    }
}

pub fn assert_lt(a: f64, b: f64, what: &str) -> PropResult {
    if a < b {
        Ok(())
    } else {
        Err(format!("{what}: expected {a} < {b}"))
    }
}

pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    let denom = b.abs().max(1.0);
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (rel {})", (a - b).abs() / denom))
    }
}

pub fn assert_true(cond: bool, what: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        check(10, |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    #[should_panic(expected = "replay with seed")]
    fn failing_property_reports_seed() {
        check(5, |rng| {
            let x = rng.uniform();
            assert_lt(x, -1.0, "impossible")
        });
    }
}
