//! Per-parameter optimizer state, mirrored host-side between step-graph
//! executions. The variant set matches the step graphs in
//! `python/compile/optim_steps.py`.
//!
//! Besides the graph path, every state — including the projection-based
//! GaLore/LDAdamW baselines — can step itself entirely on the host
//! through [`OptState::host_step`], backed by the cross-validated
//! reference optimizers in `optim` (the same `*_core` free functions the
//! reference state structs delegate to). [`host_step_all`] fans a batch of such updates
//! out over a small scoped thread pool; because each job owns its
//! parameter, state and Omega RNG stream, and the linalg kernels are
//! bit-deterministic across thread counts, the parallel schedule produces
//! results bit-identical to stepping sequentially.

use anyhow::{bail, Result};

use crate::config::Method;
use crate::linalg::{threads, Rng, Workspace};
use crate::optim::{
    adamw_host_step, galore_core, galore_refresh_projector, ldadamw_core, lion_host_step,
    mlorc_adamw_core, mlorc_lion_core, mlorc_m_core, mlorc_v_core, OptHp,
};
use crate::runtime::{ParamSpec, Preset};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub enum OptState {
    /// parameter is frozen (LoRA base weights)
    Frozen,
    AdamW { m: Tensor, v: Tensor },
    Lion { m: Tensor },
    MlorcAdamW { mq: Tensor, mb: Tensor, vq: Tensor, vb: Tensor },
    MlorcLion { mq: Tensor, mb: Tensor },
    MlorcM { mq: Tensor, mb: Tensor, v: Tensor },
    MlorcV { m: Tensor, vq: Tensor, vb: Tensor },
    Galore { p: Tensor, m_lo: Tensor, v_lo: Tensor, left: bool, refreshed: bool },
    LdAdamW { p: Tensor, m_lo: Tensor, v_lo: Tensor, e: Tensor, left: bool },
}

impl OptState {
    /// Construct the state a parameter needs under `method`.
    /// `compressed` decides matrix-vs-plain routing (vectors, embeddings,
    /// heads and LoRA adapters always take the plain path).
    pub fn for_param(method: Method, spec: &ParamSpec, preset: &Preset) -> Result<OptState> {
        let l = preset.model.l();
        let shape = &spec.shape;
        let plain = || -> OptState {
            match method.plain_step() {
                "lion" => OptState::Lion { m: Tensor::zeros(shape) },
                _ => OptState::AdamW { m: Tensor::zeros(shape), v: Tensor::zeros(shape) },
            }
        };
        if !spec.compressed || shape.len() == 1 {
            return Ok(plain());
        }
        let (m, n) = (shape[0], shape[1]);
        Ok(match method {
            Method::FullAdamW | Method::LoraAdamW => plain(),
            Method::FullLion | Method::LoraLion => plain(),
            Method::MlorcAdamW => OptState::MlorcAdamW {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
                vq: Tensor::zeros(&[m, l]),
                vb: Tensor::zeros(&[l, n]),
            },
            Method::MlorcLion => OptState::MlorcLion {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
            },
            Method::MlorcM => OptState::MlorcM {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
                v: Tensor::zeros(shape),
            },
            Method::MlorcV => OptState::MlorcV {
                m: Tensor::zeros(shape),
                vq: Tensor::zeros(&[m, l]),
                vb: Tensor::zeros(&[l, n]),
            },
            Method::Galore => {
                let left = m <= n;
                let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
                OptState::Galore {
                    p: Tensor::zeros(&pshape),
                    m_lo: Tensor::zeros(&rshape),
                    v_lo: Tensor::zeros(&rshape),
                    left,
                    refreshed: false,
                }
            }
            Method::LdAdamW => {
                let left = m <= n;
                let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
                OptState::LdAdamW {
                    p: Tensor::zeros(&pshape),
                    m_lo: Tensor::zeros(&rshape),
                    v_lo: Tensor::zeros(&rshape),
                    e: Tensor::zeros(shape),
                    left,
                }
            }
        })
    }

    /// Which step-graph method name updates this state.
    pub fn step_method(&self) -> Result<&'static str> {
        Ok(match self {
            OptState::Frozen => bail!("frozen param has no step"),
            OptState::AdamW { .. } => "adamw",
            OptState::Lion { .. } => "lion",
            OptState::MlorcAdamW { .. } => "mlorc_adamw",
            OptState::MlorcLion { .. } => "mlorc_lion",
            OptState::MlorcM { .. } => "mlorc_m",
            OptState::MlorcV { .. } => "mlorc_v",
            OptState::Galore { .. } => "galore",
            OptState::LdAdamW { .. } => "ldadamw",
        })
    }

    /// Optimizer-state footprint in bytes (the Table 1/3 quantity).
    pub fn state_bytes(&self) -> usize {
        match self {
            OptState::Frozen => 0,
            OptState::AdamW { m, v } => m.size_bytes() + v.size_bytes(),
            OptState::Lion { m } => m.size_bytes(),
            OptState::MlorcAdamW { mq, mb, vq, vb } => {
                mq.size_bytes() + mb.size_bytes() + vq.size_bytes() + vb.size_bytes()
            }
            OptState::MlorcLion { mq, mb } => mq.size_bytes() + mb.size_bytes(),
            OptState::MlorcM { mq, mb, v } => mq.size_bytes() + mb.size_bytes() + v.size_bytes(),
            OptState::MlorcV { m, vq, vb } => m.size_bytes() + vq.size_bytes() + vb.size_bytes(),
            OptState::Galore { p, m_lo, v_lo, .. } => {
                p.size_bytes() + m_lo.size_bytes() + v_lo.size_bytes()
            }
            OptState::LdAdamW { p, m_lo, v_lo, e, .. } => {
                p.size_bytes() + m_lo.size_bytes() + v_lo.size_bytes() + e.size_bytes()
            }
        }
    }

    /// Reconstructed first moment (spectral probe).
    pub fn first_moment(&self) -> Option<Tensor> {
        match self {
            OptState::AdamW { m, .. } | OptState::MlorcV { m, .. } => Some(m.clone()),
            OptState::Lion { m } => Some(m.clone()),
            OptState::MlorcAdamW { mq, mb, .. }
            | OptState::MlorcLion { mq, mb }
            | OptState::MlorcM { mq, mb, .. } => Some(crate::linalg::matmul(mq, mb)),
            _ => None,
        }
    }

    /// Reconstructed second moment (spectral probe).
    pub fn second_moment(&self) -> Option<Tensor> {
        match self {
            OptState::AdamW { v, .. } | OptState::MlorcM { v, .. } => Some(v.clone()),
            OptState::MlorcAdamW { vq, vb, .. } | OptState::MlorcV { vq, vb, .. } => {
                Some(crate::linalg::matmul(vq, vb))
            }
            _ => None,
        }
    }

    /// Hyper-parameters of the step this state takes — identical to the
    /// manifest hparams of the matching step graph (pinned by
    /// `cross_validate::hparams_match_rust_defaults`).
    pub fn host_hp(&self) -> OptHp {
        match self {
            OptState::Lion { .. } => OptHp::lion(),
            OptState::MlorcLion { .. } => OptHp::lion(),
            OptState::MlorcAdamW { .. } | OptState::MlorcM { .. } | OptState::MlorcV { .. } => {
                OptHp::mlorc_adamw()
            }
            _ => OptHp::adamw(),
        }
    }

    /// One optimizer step entirely on the host, using the reference
    /// mirrors (factored fast path for the MLorc family). `t` is 1-based;
    /// `rng` is this parameter's own Omega stream; scratch comes from the
    /// caller's `ws` pool.
    pub fn host_step(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<()> {
        let hp = self.host_hp();
        match self {
            OptState::Frozen => {}
            OptState::AdamW { m, v } => adamw_host_step(w, g, m, v, lr, t, &hp),
            OptState::Lion { m } => lion_host_step(w, g, m, lr, &hp),
            OptState::MlorcAdamW { mq, mb, vq, vb } => {
                let (_, n) = w.dims2()?;
                let l = mq.shape[1];
                let om_m = rng.gaussian_tensor(&[n, l], 1.0);
                let om_v = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_adamw_core(w, g, mq, mb, vq, vb, t, lr, &hp, &om_m, &om_v, ws);
            }
            OptState::MlorcLion { mq, mb } => {
                let (_, n) = w.dims2()?;
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_lion_core(w, g, mq, mb, lr, &hp, &om, ws);
            }
            OptState::MlorcM { mq, mb, v } => {
                let (_, n) = w.dims2()?;
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_m_core(w, g, mq, mb, v, t, lr, &hp, &om, ws);
            }
            OptState::MlorcV { m, vq, vb } => {
                let (_, n) = w.dims2()?;
                let l = vq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_v_core(w, g, m, vq, vb, t, lr, &hp, &om, ws);
            }
            OptState::Galore { p, m_lo, v_lo, left, refreshed } => {
                // Refresh cadence lives with the caller (the trainer clears
                // `refreshed` every `galore_update_freq` steps, mirroring
                // the graph path); the Omega draw happens only on refresh,
                // keeping the per-parameter stream schedule-independent.
                let l = p.shape[1];
                if !*refreshed {
                    galore_refresh_projector(p, g, *left, l, rng);
                    *refreshed = true;
                }
                galore_core(w, g, p, m_lo, v_lo, *left, t, lr, &hp);
            }
            OptState::LdAdamW { p, m_lo, v_lo, e, left } => {
                let l = p.shape[1];
                ldadamw_core(w, g, p, m_lo, v_lo, e, *left, l, t, lr, &hp, rng);
            }
        }
        Ok(())
    }
}

/// One host optimizer update: a parameter, its gradient, state and Omega
/// stream, bundled so a batch can be distributed across threads.
pub struct HostStepJob<'a> {
    pub w: &'a mut Tensor,
    pub grad: Tensor,
    pub state: &'a mut OptState,
    pub rng: &'a mut Rng,
    pub lr: f32,
    /// 1-based step count for bias corrections.
    pub t: usize,
}

/// Run every job, fanned out over at most `workspaces.len()` scoped
/// threads (contiguous chunks). Worker threads run their linalg kernels
/// in serial mode to avoid nested oversubscription; since the kernels are
/// bit-deterministic across thread counts and jobs are fully independent,
/// the result is bit-identical to sequential stepping in job order.
pub fn host_step_all(jobs: &mut [HostStepJob], workspaces: &mut [Workspace]) -> Result<()> {
    if jobs.is_empty() {
        return Ok(());
    }
    assert!(!workspaces.is_empty(), "host_step_all needs at least one workspace");
    let nt = workspaces.len().min(jobs.len());
    if nt <= 1 {
        let ws = &mut workspaces[0];
        for job in jobs.iter_mut() {
            job.state.host_step(job.w, &job.grad, job.lr, job.t, job.rng, ws)?;
        }
        return Ok(());
    }
    let chunk = jobs.len().div_ceil(nt);
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (band, ws) in jobs.chunks_mut(chunk).zip(workspaces.iter_mut()) {
            handles.push(s.spawn(move || {
                threads::serial(|| {
                    for job in band.iter_mut() {
                        job.state.host_step(job.w, &job.grad, job.lr, job.t, job.rng, ws)?;
                    }
                    Ok(())
                })
            }));
        }
        handles.into_iter().map(|h| h.join().expect("host step worker panicked")).collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn mat_spec(m: usize, n: usize) -> ParamSpec {
        ParamSpec {
            name: "w".into(),
            shape: vec![m, n],
            kind: "matrix".into(),
            compressed: true,
        }
    }

    fn fake_preset(rank: usize) -> Preset {
        // minimal synthetic preset for state-shape tests
        use crate::runtime::ModelDims;
        Preset {
            model: ModelDims {
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                vocab: 16,
                seq: 8,
                batch: 2,
                rank,
                oversample: 0,
                d_ff: 16,
                n_cls: 2,
            },
            params: vec![],
            lora_params: vec![],
            graphs: Default::default(),
            opt_steps: Default::default(),
        }
    }

    #[test]
    fn memory_ordering_matches_table1() {
        // For a (m, n) matrix at rank r: full AdamW state = 2mn floats;
        // MLorc-AdamW = 2r(m+n); Lion = mn; MLorc-Lion = r(m+n);
        // LDAdamW >= mn (error buffer).
        let preset = fake_preset(4);
        let spec = mat_spec(64, 256);
        let bytes = |m: Method| OptState::for_param(m, &spec, &preset).unwrap().state_bytes();
        let full = bytes(Method::FullAdamW);
        let mlorc = bytes(Method::MlorcAdamW);
        let galore = bytes(Method::Galore);
        let ld = bytes(Method::LdAdamW);
        assert_eq!(full, 2 * 64 * 256 * 4);
        assert_eq!(mlorc, 2 * 4 * (64 + 256) * 4);
        assert!(mlorc < full / 10);
        assert!(galore < full / 10);
        assert!(ld > 64 * 256 * 4, "error feedback dominates");
        assert_eq!(bytes(Method::MlorcLion), 4 * (64 + 256) * 4);
    }

    #[test]
    fn vectors_always_plain() {
        let preset = fake_preset(4);
        let vec_spec = ParamSpec {
            name: "ln".into(),
            shape: vec![64],
            kind: "vector".into(),
            compressed: false,
        };
        let st = OptState::for_param(Method::MlorcAdamW, &vec_spec, &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "adamw");
        let st = OptState::for_param(Method::MlorcLion, &vec_spec, &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "lion");
    }

    #[test]
    fn galore_projects_short_side() {
        let preset = fake_preset(4);
        let tall = OptState::for_param(Method::Galore, &mat_spec(256, 64), &preset).unwrap();
        match tall {
            OptState::Galore { p, left, .. } => {
                assert!(!left);
                assert_eq!(p.shape, vec![64, 4]);
            }
            _ => panic!(),
        }
    }
}
