//! Per-parameter optimizer state, mirrored host-side between step-graph
//! executions. The variant set matches the step graphs in
//! `python/compile/optim_steps.py`.
//!
//! Besides the graph path, every state — including the projection-based
//! GaLore/LDAdamW baselines — can step itself entirely on the host
//! through [`OptState::host_step`], backed by the cross-validated
//! reference optimizers in `optim` (the same `*_core` free functions the
//! reference state structs delegate to). [`host_step_all`] fans a batch
//! of such updates out over the persistent worker pool (`linalg::pool`);
//! because each job owns its parameter, state and Omega RNG stream, and
//! the linalg kernels are bit-deterministic across thread counts, the
//! parallel schedule produces results bit-identical to stepping
//! sequentially.
//!
//! Every variant also serializes to the v2 checkpoint format
//! ([`OptState::tensor_fields`] / [`OptState::ckpt_meta`] /
//! [`OptState::from_ckpt`]) — MLorc's compressed Q/B momentum factors are
//! the whole first/second-moment state, which is what makes
//! checkpoint-every-few-steps cheap enough for the serve scheduler.

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::config::Method;
use crate::linalg::{pool, threads, Rng, Workspace};
use crate::optim::{
    adamw_host_step, galore_core, galore_refresh_projector, ldadamw_core, lion_host_step,
    mlorc_adamw_core, mlorc_lion_core, mlorc_m_core, mlorc_v_core, OptHp,
};
use crate::runtime::{ParamSpec, Preset};
use crate::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub enum OptState {
    /// parameter is frozen (LoRA base weights)
    Frozen,
    AdamW { m: Tensor, v: Tensor },
    Lion { m: Tensor },
    MlorcAdamW { mq: Tensor, mb: Tensor, vq: Tensor, vb: Tensor },
    MlorcLion { mq: Tensor, mb: Tensor },
    MlorcM { mq: Tensor, mb: Tensor, v: Tensor },
    MlorcV { m: Tensor, vq: Tensor, vb: Tensor },
    Galore { p: Tensor, m_lo: Tensor, v_lo: Tensor, left: bool, refreshed: bool },
    LdAdamW { p: Tensor, m_lo: Tensor, v_lo: Tensor, e: Tensor, left: bool },
}

impl OptState {
    /// Construct the state a parameter needs under `method`.
    /// `compressed` decides matrix-vs-plain routing (vectors, embeddings,
    /// heads and LoRA adapters always take the plain path).
    pub fn for_param(method: Method, spec: &ParamSpec, preset: &Preset) -> Result<OptState> {
        OptState::for_param_with_l(method, spec, preset.model.l())
    }

    /// Like [`OptState::for_param`] but with the sketch width `l` given
    /// directly — for callers without a manifest preset (the serve host
    /// engine builds its parameter fleet from shapes alone).
    pub fn for_param_with_l(method: Method, spec: &ParamSpec, l: usize) -> Result<OptState> {
        let shape = &spec.shape;
        let plain = || -> OptState {
            match method.plain_step() {
                "lion" => OptState::Lion { m: Tensor::zeros(shape) },
                _ => OptState::AdamW { m: Tensor::zeros(shape), v: Tensor::zeros(shape) },
            }
        };
        if !spec.compressed || shape.len() == 1 {
            return Ok(plain());
        }
        let (m, n) = (shape[0], shape[1]);
        Ok(match method {
            Method::FullAdamW | Method::LoraAdamW => plain(),
            Method::FullLion | Method::LoraLion => plain(),
            Method::MlorcAdamW => OptState::MlorcAdamW {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
                vq: Tensor::zeros(&[m, l]),
                vb: Tensor::zeros(&[l, n]),
            },
            Method::MlorcLion => OptState::MlorcLion {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
            },
            Method::MlorcM => OptState::MlorcM {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
                v: Tensor::zeros(shape),
            },
            Method::MlorcV => OptState::MlorcV {
                m: Tensor::zeros(shape),
                vq: Tensor::zeros(&[m, l]),
                vb: Tensor::zeros(&[l, n]),
            },
            Method::Galore => {
                let left = m <= n;
                let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
                OptState::Galore {
                    p: Tensor::zeros(&pshape),
                    m_lo: Tensor::zeros(&rshape),
                    v_lo: Tensor::zeros(&rshape),
                    left,
                    refreshed: false,
                }
            }
            Method::LdAdamW => {
                let left = m <= n;
                let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
                OptState::LdAdamW {
                    p: Tensor::zeros(&pshape),
                    m_lo: Tensor::zeros(&rshape),
                    v_lo: Tensor::zeros(&rshape),
                    e: Tensor::zeros(shape),
                    left,
                }
            }
        })
    }

    /// Which step-graph method name updates this state.
    pub fn step_method(&self) -> Result<&'static str> {
        Ok(match self {
            OptState::Frozen => bail!("frozen param has no step"),
            OptState::AdamW { .. } => "adamw",
            OptState::Lion { .. } => "lion",
            OptState::MlorcAdamW { .. } => "mlorc_adamw",
            OptState::MlorcLion { .. } => "mlorc_lion",
            OptState::MlorcM { .. } => "mlorc_m",
            OptState::MlorcV { .. } => "mlorc_v",
            OptState::Galore { .. } => "galore",
            OptState::LdAdamW { .. } => "ldadamw",
        })
    }

    /// Stable variant tag used by checkpoint metadata (v2 format).
    pub fn variant_name(&self) -> &'static str {
        match self {
            OptState::Frozen => "frozen",
            OptState::AdamW { .. } => "adamw",
            OptState::Lion { .. } => "lion",
            OptState::MlorcAdamW { .. } => "mlorc_adamw",
            OptState::MlorcLion { .. } => "mlorc_lion",
            OptState::MlorcM { .. } => "mlorc_m",
            OptState::MlorcV { .. } => "mlorc_v",
            OptState::Galore { .. } => "galore",
            OptState::LdAdamW { .. } => "ldadamw",
        }
    }

    /// The state's tensor fields under stable names, in declared order —
    /// checkpoint v2 stores each as `<param>/<field>` in `opt_state.rten`.
    pub fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        match self {
            OptState::Frozen => vec![],
            OptState::AdamW { m, v } => vec![("m", m), ("v", v)],
            OptState::Lion { m } => vec![("m", m)],
            OptState::MlorcAdamW { mq, mb, vq, vb } => {
                vec![("mq", mq), ("mb", mb), ("vq", vq), ("vb", vb)]
            }
            OptState::MlorcLion { mq, mb } => vec![("mq", mq), ("mb", mb)],
            OptState::MlorcM { mq, mb, v } => vec![("mq", mq), ("mb", mb), ("v", v)],
            OptState::MlorcV { m, vq, vb } => vec![("m", m), ("vq", vq), ("vb", vb)],
            OptState::Galore { p, m_lo, v_lo, .. } => {
                vec![("p", p), ("m_lo", m_lo), ("v_lo", v_lo)]
            }
            OptState::LdAdamW { p, m_lo, v_lo, e, .. } => {
                vec![("p", p), ("m_lo", m_lo), ("v_lo", v_lo), ("e", e)]
            }
        }
    }

    /// Checkpoint metadata: the variant tag plus every non-tensor flag
    /// ([`OptState::from_ckpt`] is the inverse).
    pub fn ckpt_meta(&self) -> Json {
        let mut meta = Json::obj(vec![("variant", Json::str(self.variant_name()))]);
        match self {
            OptState::Galore { left, refreshed, .. } => {
                meta.set("left", Json::Bool(*left));
                meta.set("refreshed", Json::Bool(*refreshed));
            }
            OptState::LdAdamW { left, .. } => {
                meta.set("left", Json::Bool(*left));
            }
            _ => {}
        }
        meta
    }

    /// Rebuild a state from checkpoint metadata plus a tensor lookup
    /// (`take(field)` yields the stored `<param>/<field>` tensor).
    pub fn from_ckpt(
        meta: &Json,
        mut take: impl FnMut(&'static str) -> Result<Tensor>,
    ) -> Result<OptState> {
        let variant = meta.req("variant")?.as_str()?;
        Ok(match variant {
            "frozen" => OptState::Frozen,
            "adamw" => OptState::AdamW { m: take("m")?, v: take("v")? },
            "lion" => OptState::Lion { m: take("m")? },
            "mlorc_adamw" => OptState::MlorcAdamW {
                mq: take("mq")?,
                mb: take("mb")?,
                vq: take("vq")?,
                vb: take("vb")?,
            },
            "mlorc_lion" => OptState::MlorcLion { mq: take("mq")?, mb: take("mb")? },
            "mlorc_m" => OptState::MlorcM { mq: take("mq")?, mb: take("mb")?, v: take("v")? },
            "mlorc_v" => OptState::MlorcV { m: take("m")?, vq: take("vq")?, vb: take("vb")? },
            "galore" => OptState::Galore {
                p: take("p")?,
                m_lo: take("m_lo")?,
                v_lo: take("v_lo")?,
                left: meta.req("left")?.as_bool()?,
                refreshed: meta.req("refreshed")?.as_bool()?,
            },
            "ldadamw" => OptState::LdAdamW {
                p: take("p")?,
                m_lo: take("m_lo")?,
                v_lo: take("v_lo")?,
                e: take("e")?,
                left: meta.req("left")?.as_bool()?,
            },
            other => bail!("unknown optimizer state variant '{other}' in checkpoint"),
        })
    }

    /// Optimizer-state footprint in bytes (the Table 1/3 quantity).
    pub fn state_bytes(&self) -> usize {
        match self {
            OptState::Frozen => 0,
            OptState::AdamW { m, v } => m.size_bytes() + v.size_bytes(),
            OptState::Lion { m } => m.size_bytes(),
            OptState::MlorcAdamW { mq, mb, vq, vb } => {
                mq.size_bytes() + mb.size_bytes() + vq.size_bytes() + vb.size_bytes()
            }
            OptState::MlorcLion { mq, mb } => mq.size_bytes() + mb.size_bytes(),
            OptState::MlorcM { mq, mb, v } => mq.size_bytes() + mb.size_bytes() + v.size_bytes(),
            OptState::MlorcV { m, vq, vb } => m.size_bytes() + vq.size_bytes() + vb.size_bytes(),
            OptState::Galore { p, m_lo, v_lo, .. } => {
                p.size_bytes() + m_lo.size_bytes() + v_lo.size_bytes()
            }
            OptState::LdAdamW { p, m_lo, v_lo, e, .. } => {
                p.size_bytes() + m_lo.size_bytes() + v_lo.size_bytes() + e.size_bytes()
            }
        }
    }

    /// Reconstructed first moment (spectral probe).
    pub fn first_moment(&self) -> Option<Tensor> {
        match self {
            OptState::AdamW { m, .. } | OptState::MlorcV { m, .. } => Some(m.clone()),
            OptState::Lion { m } => Some(m.clone()),
            OptState::MlorcAdamW { mq, mb, .. }
            | OptState::MlorcLion { mq, mb }
            | OptState::MlorcM { mq, mb, .. } => Some(crate::linalg::matmul(mq, mb)),
            _ => None,
        }
    }

    /// Reconstructed second moment (spectral probe).
    pub fn second_moment(&self) -> Option<Tensor> {
        match self {
            OptState::AdamW { v, .. } | OptState::MlorcM { v, .. } => Some(v.clone()),
            OptState::MlorcAdamW { vq, vb, .. } | OptState::MlorcV { vq, vb, .. } => {
                Some(crate::linalg::matmul(vq, vb))
            }
            _ => None,
        }
    }

    /// Hyper-parameters of the step this state takes — identical to the
    /// manifest hparams of the matching step graph (pinned by
    /// `cross_validate::hparams_match_rust_defaults`).
    pub fn host_hp(&self) -> OptHp {
        match self {
            OptState::Lion { .. } => OptHp::lion(),
            OptState::MlorcLion { .. } => OptHp::lion(),
            OptState::MlorcAdamW { .. } | OptState::MlorcM { .. } | OptState::MlorcV { .. } => {
                OptHp::mlorc_adamw()
            }
            _ => OptHp::adamw(),
        }
    }

    /// One optimizer step entirely on the host, using the reference
    /// mirrors (factored fast path for the MLorc family). `t` is 1-based;
    /// `rng` is this parameter's own Omega stream; scratch comes from the
    /// caller's `ws` pool.
    pub fn host_step(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<()> {
        let hp = self.host_hp();
        match self {
            OptState::Frozen => {}
            OptState::AdamW { m, v } => adamw_host_step(w, g, m, v, lr, t, &hp),
            OptState::Lion { m } => lion_host_step(w, g, m, lr, &hp),
            OptState::MlorcAdamW { mq, mb, vq, vb } => {
                let (_, n) = w.dims2()?;
                let l = mq.shape[1];
                let om_m = rng.gaussian_tensor(&[n, l], 1.0);
                let om_v = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_adamw_core(w, g, mq, mb, vq, vb, t, lr, &hp, &om_m, &om_v, ws);
            }
            OptState::MlorcLion { mq, mb } => {
                let (_, n) = w.dims2()?;
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_lion_core(w, g, mq, mb, lr, &hp, &om, ws);
            }
            OptState::MlorcM { mq, mb, v } => {
                let (_, n) = w.dims2()?;
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_m_core(w, g, mq, mb, v, t, lr, &hp, &om, ws);
            }
            OptState::MlorcV { m, vq, vb } => {
                let (_, n) = w.dims2()?;
                let l = vq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_v_core(w, g, m, vq, vb, t, lr, &hp, &om, ws);
            }
            OptState::Galore { p, m_lo, v_lo, left, refreshed } => {
                // Refresh cadence lives with the caller (the trainer clears
                // `refreshed` every `galore_update_freq` steps, mirroring
                // the graph path); the Omega draw happens only on refresh,
                // keeping the per-parameter stream schedule-independent.
                let l = p.shape[1];
                if !*refreshed {
                    galore_refresh_projector(p, g, *left, l, rng);
                    *refreshed = true;
                }
                galore_core(w, g, p, m_lo, v_lo, *left, t, lr, &hp);
            }
            OptState::LdAdamW { p, m_lo, v_lo, e, left } => {
                let l = p.shape[1];
                ldadamw_core(w, g, p, m_lo, v_lo, e, *left, l, t, lr, &hp, rng);
            }
        }
        Ok(())
    }
}

/// One host optimizer update: a parameter, its gradient, state and Omega
/// stream, bundled so a batch can be distributed across threads.
pub struct HostStepJob<'a> {
    pub w: &'a mut Tensor,
    pub grad: Tensor,
    pub state: &'a mut OptState,
    pub rng: &'a mut Rng,
    pub lr: f32,
    /// 1-based step count for bias corrections.
    pub t: usize,
}

/// Run every job, fanned out over the persistent worker pool
/// (`linalg::pool`) in contiguous chunks of at most `workspaces.len()`
/// bands — no per-call thread spawns. Band closures run their linalg
/// kernels in serial mode to avoid nested oversubscription; since the
/// kernels are bit-deterministic across thread counts and jobs are fully
/// independent, the result is bit-identical to sequential stepping in job
/// order (asserted by `tests/host_parallel.rs`).
pub fn host_step_all(jobs: &mut [HostStepJob], workspaces: &mut [Workspace]) -> Result<()> {
    if jobs.is_empty() {
        return Ok(());
    }
    assert!(!workspaces.is_empty(), "host_step_all needs at least one workspace");
    let nt = workspaces.len().min(jobs.len());
    if nt <= 1 {
        let ws = &mut workspaces[0];
        for job in jobs.iter_mut() {
            job.state.host_step(job.w, &job.grad, job.lr, job.t, job.rng, ws)?;
        }
        return Ok(());
    }
    // Same contiguous div_ceil partition as the spawn-era scaffold; each
    // band pairs a job chunk with its own workspace, handed to exactly
    // one band closure through a take-once slot.
    let chunk = jobs.len().div_ceil(nt);
    let bands: Vec<_> = jobs
        .chunks_mut(chunk)
        .zip(workspaces.iter_mut())
        .map(|(band, ws)| Mutex::new(Some((band, ws))))
        .collect();
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let nbands = bands.len();
    // Pin the band plan to exactly `nbands` one-row bands. When the pool
    // runs the batch inline (serial scope / nested call) a single closure
    // invocation receives the whole index range, so it drains every band.
    threads::with_budget(nbands, || {
        pool::par_row_bands(nbands, usize::MAX / 4, |_, range| {
            for idx in range {
                let Some((band, ws)) = bands[idx].lock().unwrap().take() else {
                    continue;
                };
                threads::serial(|| {
                    for job in band.iter_mut() {
                        let r =
                            job.state.host_step(job.w, &job.grad, job.lr, job.t, job.rng, ws);
                        if let Err(e) = r {
                            let mut slot = first_err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            return;
                        }
                    }
                });
            }
        });
    });
    match first_err.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn mat_spec(m: usize, n: usize) -> ParamSpec {
        ParamSpec {
            name: "w".into(),
            shape: vec![m, n],
            kind: "matrix".into(),
            compressed: true,
        }
    }

    fn fake_preset(rank: usize) -> Preset {
        // minimal synthetic preset for state-shape tests
        use crate::runtime::ModelDims;
        Preset {
            model: ModelDims {
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                vocab: 16,
                seq: 8,
                batch: 2,
                rank,
                oversample: 0,
                d_ff: 16,
                n_cls: 2,
            },
            params: vec![],
            lora_params: vec![],
            graphs: Default::default(),
            opt_steps: Default::default(),
        }
    }

    #[test]
    fn memory_ordering_matches_table1() {
        // For a (m, n) matrix at rank r: full AdamW state = 2mn floats;
        // MLorc-AdamW = 2r(m+n); Lion = mn; MLorc-Lion = r(m+n);
        // LDAdamW >= mn (error buffer).
        let preset = fake_preset(4);
        let spec = mat_spec(64, 256);
        let bytes = |m: Method| OptState::for_param(m, &spec, &preset).unwrap().state_bytes();
        let full = bytes(Method::FullAdamW);
        let mlorc = bytes(Method::MlorcAdamW);
        let galore = bytes(Method::Galore);
        let ld = bytes(Method::LdAdamW);
        assert_eq!(full, 2 * 64 * 256 * 4);
        assert_eq!(mlorc, 2 * 4 * (64 + 256) * 4);
        assert!(mlorc < full / 10);
        assert!(galore < full / 10);
        assert!(ld > 64 * 256 * 4, "error feedback dominates");
        assert_eq!(bytes(Method::MlorcLion), 4 * (64 + 256) * 4);
    }

    #[test]
    fn vectors_always_plain() {
        let preset = fake_preset(4);
        let vec_spec = ParamSpec {
            name: "ln".into(),
            shape: vec![64],
            kind: "vector".into(),
            compressed: false,
        };
        let st = OptState::for_param(Method::MlorcAdamW, &vec_spec, &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "adamw");
        let st = OptState::for_param(Method::MlorcLion, &vec_spec, &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "lion");
    }

    #[test]
    fn ckpt_meta_roundtrip_all_variants() {
        // Every variant must survive meta + tensor-field serialization;
        // flags (left/refreshed) and tensor shapes are the load-bearing
        // part, byte-exactness is covered by tests/checkpoint_v2.rs.
        let preset = fake_preset(4);
        let spec = mat_spec(12, 40);
        for &method in Method::all() {
            let st = OptState::for_param(method, &spec, &preset).unwrap();
            let meta = st.ckpt_meta();
            let fields: std::collections::BTreeMap<&'static str, Tensor> =
                st.tensor_fields().into_iter().map(|(k, t)| (k, t.clone())).collect();
            let back = OptState::from_ckpt(&meta, |k| {
                fields.get(k).cloned().ok_or_else(|| anyhow::anyhow!("missing field {k}"))
            })
            .unwrap();
            assert_eq!(back.variant_name(), st.variant_name(), "{method:?}");
            assert_eq!(back.state_bytes(), st.state_bytes(), "{method:?}");
        }
        assert!(OptState::from_ckpt(
            &Json::obj(vec![("variant", Json::str("sgd"))]),
            |_| Ok(Tensor::zeros(&[1]))
        )
        .is_err());
    }

    #[test]
    fn galore_projects_short_side() {
        let preset = fake_preset(4);
        let tall = OptState::for_param(Method::Galore, &mat_spec(256, 64), &preset).unwrap();
        match tall {
            OptState::Galore { p, left, .. } => {
                assert!(!left);
                assert_eq!(p.shape, vec![64, 4]);
            }
            _ => panic!(),
        }
    }
}
