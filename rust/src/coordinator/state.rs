//! Per-parameter optimizer state, mirrored host-side between step-graph
//! executions.
//!
//! Since the optimizer-matrix refactor this is a thin shell over the
//! trait-based core in `optim`: a parameter is either [`OptState::Frozen`]
//! (LoRA base weights) or an [`MatrixOpt`] — one registered
//! (update rule × momentum compressor) variant plus the compressor-owned
//! state tensors. Every dispatch that used to be a ten-arm `match` here
//! (stepping, checkpoint fields, state bytes, spectral reconstruction,
//! graph input/output layout) now delegates to the variant's
//! `UpdateRule`/`MomentumCompressor`, so registering a new method in
//! `optim::registry` needs no change in this file or its consumers.
//!
//! Besides the graph path, every state can step itself entirely on the
//! host through [`OptState::host_step`], backed by the cross-validated
//! `*_core` kernels the compressors route to. [`host_step_all`] plans a
//! batch of such updates into *shape classes* — jobs sharing (variant,
//! weight shape, state-field shapes) — and steps each class through
//! `optim::step_class`, which runs QB-factored classes as stacked banded
//! kernel invocations over the persistent worker pool (`linalg::pool`)
//! and everything else as per-member pool tasks; because each job owns
//! its parameter, state and Omega RNG stream, and the linalg kernels are
//! bit-deterministic, the batched schedule produces results bit-identical
//! to stepping sequentially.
//!
//! Every state also serializes to the v2 checkpoint format
//! ([`OptState::tensor_fields`] / [`OptState::ckpt_meta`] /
//! [`OptState::from_ckpt`]) under the same variant tags and field names
//! as before the refactor — old v2 checkpoints keep loading byte-for-byte.

use anyhow::{bail, Result};

use crate::config::Method;
use crate::linalg::{Rng, Workspace};
use crate::optim::registry::{self, MatrixOpt};
use crate::optim::{step_class, ClassJob, GaloreProjector};
use crate::runtime::{ParamSpec, Preset};
use crate::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub enum OptState {
    /// parameter is frozen (LoRA base weights)
    Frozen,
    /// one registered (rule × compressor) variant with its state
    Opt(MatrixOpt),
}

impl OptState {
    /// Construct the state a parameter needs under `method`.
    /// `compressed` decides matrix-vs-plain routing (vectors, embeddings,
    /// heads and LoRA adapters always take the plain path).
    pub fn for_param(method: Method, spec: &ParamSpec, preset: &Preset) -> Result<OptState> {
        OptState::for_param_with_l(method, spec, preset.model.l())
    }

    /// Like [`OptState::for_param`] but with the sketch width `l` given
    /// directly — for callers without a manifest preset (the serve host
    /// engine builds its parameter fleet from shapes alone).
    pub fn for_param_with_l(method: Method, spec: &ParamSpec, l: usize) -> Result<OptState> {
        OptState::for_param_cfg(method, spec, l, 1)
    }

    /// Full-control constructor: sketch width `l` plus the adaptive-rank
    /// floor (`--rank-min`; only adaptive-rank layouts read it).
    pub fn for_param_cfg(
        method: Method,
        spec: &ParamSpec,
        l: usize,
        rank_min: usize,
    ) -> Result<OptState> {
        let desc = method.desc();
        let numel: usize = spec.shape.iter().product();
        let variant_id = if spec.compressed && spec.shape.len() == 2 {
            desc.matrix
        } else if desc.fold
            && spec.shape.len() == 1
            && registry::effective_shape(numel, l).is_some()
        {
            // Foldable 1D parameter under a folding method: route through
            // the matrix variant via the 2D effective shape (the
            // exemplars' `vector_reshape`). Unfoldable shapes (prime
            // length, short side under `l`) keep the plain path.
            desc.matrix
        } else {
            desc.plain
        };
        let v = registry::variant(variant_id)?;
        Ok(OptState::Opt(v.build_opts(&spec.shape, l, rank_min)?))
    }

    /// Build a fresh zero state for an explicit variant id (tests, tools).
    pub fn for_variant(variant_id: &str, shape: &[usize], l: usize) -> Result<OptState> {
        Ok(OptState::Opt(registry::variant(variant_id)?.build(shape, l)?))
    }

    pub fn is_frozen(&self) -> bool {
        matches!(self, OptState::Frozen)
    }

    fn opt(&self) -> Option<&MatrixOpt> {
        match self {
            OptState::Frozen => None,
            OptState::Opt(mo) => Some(mo),
        }
    }

    /// Which step-graph method name updates this state.
    pub fn step_method(&self) -> Result<&'static str> {
        match self.opt() {
            None => bail!("frozen param has no step"),
            Some(mo) => Ok(mo.variant().id),
        }
    }

    /// Stable variant tag used by checkpoint metadata (v2 format).
    pub fn variant_name(&self) -> &'static str {
        match self.opt() {
            None => "frozen",
            Some(mo) => mo.variant().id,
        }
    }

    /// Whether this state's apply is bias-corrected — decides if its step
    /// graph takes `c1`/`c2` scalars after `lr`.
    pub fn bias_corrected(&self) -> bool {
        self.opt().map(|mo| mo.rule().bias_corrected()).unwrap_or(false)
    }

    /// The state's tensor fields under stable names, in declared order —
    /// checkpoint v2 stores each as `<param>/<field>`, and the step graph
    /// takes them (in this order) right after `w` and `grad`.
    pub fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        match self.opt() {
            None => vec![],
            Some(mo) => mo.tensor_fields(),
        }
    }

    /// Mutable view of every tensor field, same names and order.
    pub fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        match self {
            OptState::Frozen => vec![],
            OptState::Opt(mo) => mo.tensor_fields_mut(),
        }
    }

    /// Raw u8 fields (quantized code planes), checkpoint v2's dtype-2
    /// entries; empty for unquantized layouts.
    pub fn u8_fields(&self) -> Vec<(&'static str, &crate::tensor::TensorU8)> {
        match self.opt() {
            None => vec![],
            Some(mo) => mo.comp().u8_fields(),
        }
    }

    /// bf16 planes (stochastic-rounding weight layouts), checkpoint v2's
    /// dtype-3 entries; empty for f32-weight layouts.
    pub fn bf16_fields(&self) -> Vec<(&'static str, &crate::tensor::TensorBf16)> {
        match self.opt() {
            None => vec![],
            Some(mo) => mo.bf16_fields(),
        }
    }

    /// How many times this state shrank its factor rank (adaptive-rank
    /// layouts only).
    pub fn shrink_events(&self) -> usize {
        self.opt().map(|mo| mo.comp().shrink_events()).unwrap_or(0)
    }

    /// The fields this state's step graph returns updated, in output
    /// order (GaLore's projector is a graph constant and excluded).
    pub fn graph_output_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        match self {
            OptState::Frozen => vec![],
            OptState::Opt(mo) => mo.comp_mut().graph_output_fields_mut(),
        }
    }

    /// Shapes of the Gaussian test matrices the step graph takes after
    /// the state fields, in draw order.
    pub fn omega_graph_shapes(&self) -> Vec<[usize; 2]> {
        match self.opt() {
            None => vec![],
            Some(mo) => mo.comp().omega_graph_shapes(),
        }
    }

    /// Mark a cached projector stale (GaLore refresh cadence); no-op for
    /// every other layout.
    pub fn invalidate_projector(&mut self) {
        if let OptState::Opt(mo) = self {
            mo.comp_mut().invalidate_projector();
        }
    }

    /// Mutable access to a GaLore projector state, if that is this
    /// state's layout — the trainer's graph path refreshes `p` through
    /// the dedicated `galore_project` graph.
    pub fn galore_mut(&mut self) -> Option<&mut GaloreProjector> {
        match self {
            OptState::Frozen => None,
            OptState::Opt(mo) => mo.comp_mut().as_galore_mut(),
        }
    }

    /// Checkpoint metadata: the variant tag plus every non-tensor flag
    /// ([`OptState::from_ckpt`] is the inverse).
    pub fn ckpt_meta(&self) -> Json {
        let mut meta = Json::obj(vec![("variant", Json::str(self.variant_name()))]);
        if let Some(mo) = self.opt() {
            mo.ckpt_meta_into(&mut meta);
        }
        meta
    }

    /// Rebuild a state from checkpoint metadata plus a tensor lookup
    /// (`take(field)` yields the stored `<param>/<field>` tensor).
    /// Quantized layouts need [`OptState::from_ckpt_full`].
    pub fn from_ckpt(
        meta: &Json,
        take: impl FnMut(&'static str) -> Result<Tensor>,
    ) -> Result<OptState> {
        OptState::from_ckpt_full(
            meta,
            take,
            |field| bail!("layout wants u8 tensor '{field}' but this source has only f32 tensors"),
            |field| bail!("layout wants bf16 plane '{field}' but this source has only f32 tensors"),
        )
    }

    /// [`OptState::from_ckpt`] with u8 and bf16 lookups for quantized
    /// layouts' code planes and stochastic-rounding weight planes.
    pub fn from_ckpt_full(
        meta: &Json,
        mut take: impl FnMut(&'static str) -> Result<Tensor>,
        mut take_u8: impl FnMut(&'static str) -> Result<crate::tensor::TensorU8>,
        mut take_b16: impl FnMut(&'static str) -> Result<crate::tensor::TensorBf16>,
    ) -> Result<OptState> {
        let variant = meta.req("variant")?.as_str()?;
        if variant == "frozen" {
            return Ok(OptState::Frozen);
        }
        let desc = registry::variant(variant)
            .map_err(|_| anyhow::anyhow!("unknown optimizer state variant '{variant}' in checkpoint"))?;
        Ok(OptState::Opt(desc.decode(meta, &mut take, &mut take_u8, &mut take_b16)?))
    }

    /// Optimizer-state footprint in bytes (the Table 1/3 quantity).
    pub fn state_bytes(&self) -> usize {
        self.opt().map(|mo| mo.state_bytes()).unwrap_or(0)
    }

    /// Reconstructed first moment (spectral probe).
    pub fn first_moment(&self) -> Option<Tensor> {
        self.opt().and_then(|mo| mo.comp().first_moment())
    }

    /// Reconstructed second moment (spectral probe).
    pub fn second_moment(&self) -> Option<Tensor> {
        self.opt().and_then(|mo| mo.comp().second_moment())
    }

    /// One optimizer step entirely on the host, using the reference
    /// mirrors (factored fast path for the MLorc family). `t` is 1-based;
    /// `rng` is this parameter's own Omega stream; scratch comes from the
    /// caller's `ws` pool.
    pub fn host_step(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<()> {
        match self {
            OptState::Frozen => Ok(()),
            OptState::Opt(mo) => mo.step(w, g, lr, t, rng, ws),
        }
    }
}

/// One host optimizer update: a parameter, its gradient, state and Omega
/// stream, bundled so a batch can be planned into shape classes. The
/// gradient is borrowed — callers keep ownership and clone nothing.
pub struct HostStepJob<'a> {
    pub w: &'a mut Tensor,
    pub grad: &'a Tensor,
    pub state: &'a mut OptState,
    pub rng: &'a mut Rng,
    pub lr: f32,
    /// 1-based step count for bias corrections.
    pub t: usize,
}

/// Step every job, batched by shape class. Jobs sharing (variant, weight
/// shape, state-field shapes) are handed as one group to
/// `optim::step_class`: QB-factored classes run through the stacked class
/// kernels — one banded invocation per algorithm phase for the whole
/// class, bands claimed atomically across members — and every other
/// layout falls back to per-member pool tasks with serial kernels.
/// Classes run in first-occurrence order, members in job order; since
/// members only ever touch their own state and the linalg kernels are
/// bit-deterministic across thread counts and band boundaries, the result
/// is bit-identical to stepping sequentially in job order (asserted by
/// `tests/host_parallel.rs` for every registered method).
pub fn host_step_all(jobs: &mut [HostStepJob], workspaces: &mut [Workspace]) -> Result<()> {
    if jobs.is_empty() {
        return Ok(());
    }
    assert!(!workspaces.is_empty(), "host_step_all needs at least one workspace");
    // Shape-class plan. The key is the variant plus the weight and every
    // state tensor shape, so the stacked kernels only ever see uniform
    // members (e.g. AdaRank states whose live ranks have diverged land in
    // different classes). Frozen params have no step and are skipped.
    let mut classes: Vec<((&'static str, Vec<usize>), Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        if job.state.is_frozen() {
            continue;
        }
        let mut dims: Vec<usize> = job.w.shape.clone();
        for (_, t) in job.state.tensor_fields() {
            dims.push(usize::MAX); // field separator — shapes can't collide
            dims.extend_from_slice(&t.shape);
        }
        let key = (job.state.variant_name(), dims);
        match classes.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => classes.push((key, vec![i])),
        }
    }
    let mut slots: Vec<Option<&mut HostStepJob>> = jobs.iter_mut().map(Some).collect();
    for (_, idxs) in classes {
        let mut members: Vec<ClassJob> = Vec::with_capacity(idxs.len());
        for i in idxs {
            let job = slots[i].take().expect("job planned into two classes");
            let HostStepJob { w, grad, state, rng, lr, t } = job;
            let OptState::Opt(opt) = &mut **state else { continue };
            members.push(ClassJob {
                w: &mut **w,
                g: &**grad,
                opt,
                rng: &mut **rng,
                lr: *lr,
                t: *t,
            });
        }
        step_class(&mut members, workspaces)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn mat_spec(m: usize, n: usize) -> ParamSpec {
        ParamSpec {
            name: "w".into(),
            shape: vec![m, n],
            kind: "matrix".into(),
            compressed: true,
        }
    }

    fn fake_preset(rank: usize) -> Preset {
        // minimal synthetic preset for state-shape tests
        use crate::runtime::ModelDims;
        Preset {
            model: ModelDims {
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                vocab: 16,
                seq: 8,
                batch: 2,
                rank,
                oversample: 0,
                d_ff: 16,
                n_cls: 2,
            },
            params: vec![],
            lora_params: vec![],
            graphs: Default::default(),
            opt_steps: Default::default(),
        }
    }

    #[test]
    fn memory_ordering_matches_table1() {
        // For a (m, n) matrix at rank r: full AdamW state = 2mn floats;
        // MLorc-AdamW = 2r(m+n); Lion = mn; MLorc-Lion = r(m+n);
        // LDAdamW >= mn (error buffer).
        let preset = fake_preset(4);
        let spec = mat_spec(64, 256);
        let bytes = |m: Method| OptState::for_param(m, &spec, &preset).unwrap().state_bytes();
        let full = bytes(Method::FullAdamW);
        let mlorc = bytes(Method::MlorcAdamW);
        let galore = bytes(Method::Galore);
        let ld = bytes(Method::LdAdamW);
        assert_eq!(full, 2 * 64 * 256 * 4);
        assert_eq!(mlorc, 2 * 4 * (64 + 256) * 4);
        assert!(mlorc < full / 10);
        assert!(galore < full / 10);
        assert!(ld > 64 * 256 * 4, "error feedback dominates");
        assert_eq!(bytes(Method::MlorcLion), 4 * (64 + 256) * 4);
        // the registry combos for free: SGDM momenta are single-moment
        assert_eq!(bytes(Method::MlorcSgdM), 4 * (64 + 256) * 4);
        assert_eq!(bytes(Method::FullSgdM), 64 * 256 * 4);
    }

    #[test]
    fn vectors_always_plain() {
        let preset = fake_preset(4);
        let vec_spec = ParamSpec {
            name: "ln".into(),
            shape: vec![64],
            kind: "vector".into(),
            compressed: false,
        };
        let st = OptState::for_param(Method::MlorcAdamW, &vec_spec, &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "adamw");
        let st = OptState::for_param(Method::MlorcLion, &vec_spec, &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "lion");
        let st = OptState::for_param(Method::MlorcSgdM, &vec_spec, &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "sgdm");
    }

    #[test]
    fn fold_methods_route_foldable_vectors_through_matrix_variant() {
        let preset = fake_preset(4);
        let vec_spec = |n: usize| ParamSpec {
            name: "ln".into(),
            shape: vec![n],
            kind: "vector".into(),
            compressed: false,
        };
        let st = OptState::for_param(Method::MlorcProdigy, &vec_spec(32), &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "mlorc_prodigy");
        let st = OptState::for_param(Method::MlorcAdamWBf16, &vec_spec(32), &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "mlorc_adamw_bf16");
        // prime length has no effective shape: plain fallback
        let st = OptState::for_param(Method::MlorcProdigy, &vec_spec(13), &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "prodigy");
        // non-fold methods keep every vector on the plain path
        let st = OptState::for_param(Method::MlorcAdamW, &vec_spec(32), &preset).unwrap();
        assert_eq!(st.step_method().unwrap(), "adamw");
    }

    #[test]
    fn ckpt_meta_roundtrip_all_variants() {
        // Every registered method's state must survive meta + tensor-field
        // serialization; flags (left/refreshed) and tensor shapes are the
        // load-bearing part, byte-exactness is covered by
        // tests/checkpoint_v2.rs and tests/optim_matrix.rs.
        let preset = fake_preset(4);
        let spec = mat_spec(12, 40);
        for &method in Method::all() {
            let st = OptState::for_param(method, &spec, &preset).unwrap();
            let meta = st.ckpt_meta();
            let fields: std::collections::BTreeMap<&'static str, Tensor> =
                st.tensor_fields().into_iter().map(|(k, t)| (k, t.clone())).collect();
            let u8s: std::collections::BTreeMap<&'static str, crate::tensor::TensorU8> =
                st.u8_fields().into_iter().map(|(k, t)| (k, t.clone())).collect();
            let b16s: std::collections::BTreeMap<&'static str, crate::tensor::TensorBf16> =
                st.bf16_fields().into_iter().map(|(k, t)| (k, t.clone())).collect();
            let back = OptState::from_ckpt_full(
                &meta,
                |k| fields.get(k).cloned().ok_or_else(|| anyhow::anyhow!("missing field {k}")),
                |k| u8s.get(k).cloned().ok_or_else(|| anyhow::anyhow!("missing u8 field {k}")),
                |k| {
                    b16s.get(k).cloned().ok_or_else(|| anyhow::anyhow!("missing bf16 field {k}"))
                },
            )
            .unwrap();
            assert_eq!(back.variant_name(), st.variant_name(), "{method:?}");
            assert_eq!(back.state_bytes(), st.state_bytes(), "{method:?}");
        }
        assert!(OptState::from_ckpt(
            &Json::obj(vec![("variant", Json::str("sgd"))]),
            |_| Ok(Tensor::zeros(&[1]))
        )
        .is_err());
    }

    #[test]
    fn galore_projects_short_side() {
        let preset = fake_preset(4);
        let mut tall = OptState::for_param(Method::Galore, &mat_spec(256, 64), &preset).unwrap();
        let gal = tall.galore_mut().expect("galore layout");
        assert!(!gal.left);
        assert_eq!(gal.p.shape, vec![64, 4]);
        // non-projector layouts have no galore surface
        let mut mlorc =
            OptState::for_param(Method::MlorcAdamW, &mat_spec(256, 64), &preset).unwrap();
        assert!(mlorc.galore_mut().is_none());
    }
}
