//! Memory accountant — regenerates Table 1 (analytic formulas), Table 3
//! (peak footprint per method) and Table 6 (per-layer updates vs LoRA).
//!
//! Two views:
//!  * analytic: closed-form float counts per category from the manifest
//!    param table (exactly Table 1's algebra);
//!  * measured: bytes actually resident in the coordinator (weights +
//!    optimizer state + gradients), with gradient residency depending on
//!    the per-layer-update mode, plus a documented activation model.

use crate::config::Method;
use crate::runtime::Preset;

#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    pub method: String,
    pub weights_bytes: usize,
    pub opt_state_bytes: usize,
    /// peak gradient residency: all grads (standard) or the largest single
    /// parameter's gradient (per-layer weight updates, Lv et al. 2024)
    pub grads_peak_bytes: usize,
    /// activation model: batch * seq * d * (attn+mlp live buffers/layer)
    pub activations_bytes: usize,
    pub lora_extra_weights_bytes: usize,
}

impl MemoryReport {
    pub fn total(&self) -> usize {
        self.weights_bytes
            + self.opt_state_bytes
            + self.grads_peak_bytes
            + self.activations_bytes
            + self.lora_extra_weights_bytes
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("method", Json::str(self.method.clone())),
            ("weights_bytes", Json::num(self.weights_bytes as f64)),
            ("opt_state_bytes", Json::num(self.opt_state_bytes as f64)),
            ("grads_peak_bytes", Json::num(self.grads_peak_bytes as f64)),
            ("activations_bytes", Json::num(self.activations_bytes as f64)),
            ("lora_extra_weights_bytes", Json::num(self.lora_extra_weights_bytes as f64)),
            ("total_bytes", Json::num(self.total() as f64)),
        ])
    }
}

pub struct MemoryAccountant;

impl MemoryAccountant {
    /// Table 1 row for one (m, n) matrix parameter: (weights, opt_state)
    /// float counts — derived from the registered variant's layout, so
    /// every (rule × compressor) combination gets its row for free.
    pub fn table1_row(method: Method, m: usize, n: usize, r: usize) -> (usize, usize) {
        use crate::optim::registry;
        if method.is_lora() {
            // rank-r adapters carry the gradients; moments are dense on
            // the adapter shapes
            let adapters = m * r + n * r;
            let nm = registry::variant(method.plain_step())
                .expect("registered methods only reference registered variants")
                .n_moments();
            return (m * n + adapters, nm * adapters);
        }
        let v = registry::variant(method.matrix_step())
            .expect("registered methods only reference registered variants");
        (m * n, v.state_floats(m, n, r))
    }

    /// Optimizer-state *bytes* for one compressed (m, n) matrix — what
    /// Table 1 actually compares once quantized layouts exist (their
    /// elements are 1-byte codes, so a float count under-represents the
    /// savings by 4x).
    pub fn table1_row_opt_bytes(method: Method, m: usize, n: usize, r: usize) -> usize {
        use crate::optim::registry;
        if method.is_lora() {
            let (_, o) = Self::table1_row(method, m, n, r);
            return 4 * o;
        }
        registry::variant(method.matrix_step())
            .expect("registered methods only reference registered variants")
            .state_bytes(m, n, r)
    }

    /// Whole-model report under the analytic model.
    pub fn analytic(preset: &Preset, method: Method, per_layer: bool, with_head: bool) -> MemoryReport {
        let r = preset.model.rank + preset.model.oversample;
        let mut weights = 0usize;
        let mut opt_bytes = 0usize;
        let mut grads_all = 0usize;
        let mut grads_max = 0usize;
        let mut lora_extra = 0usize;
        for p in &preset.params {
            if p.kind == "head" && !with_head {
                continue;
            }
            let numel = p.numel();
            weights += numel;
            if p.compressed && p.shape.len() == 2 {
                let (m, n) = (p.shape[0], p.shape[1]);
                let (w, _) = Self::table1_row(method, m, n, r);
                // byte-accurate: quantized layouts store 1-byte codes
                opt_bytes += Self::table1_row_opt_bytes(method, m, n, r);
                lora_extra += w - m * n; // nonzero only for LoRA
                if method.is_lora() {
                    // only adapters get gradients
                    grads_all += m * r + n * r;
                    grads_max = grads_max.max(m * r + n * r);
                } else {
                    grads_all += numel;
                    grads_max = grads_max.max(numel);
                }
            } else {
                // uncompressed path: one dense buffer per rule moment
                let factor = crate::optim::registry::variant(method.plain_step())
                    .map(|v| v.n_moments())
                    .unwrap_or(2);
                if method.is_lora() && p.kind != "head" {
                    // frozen under LoRA: no grads, no state
                } else {
                    opt_bytes += 4 * factor * numel;
                    grads_all += numel;
                    grads_max = grads_max.max(numel);
                }
            }
        }
        let d = preset.model.d_model;
        let (b, t) = (preset.model.batch, preset.model.seq);
        // live-activation model per layer with gradient checkpointing
        // (paper setting): residual stream + attn scores dominate.
        let act = b * t * d * 8 + b * preset.model.n_heads * t * t * 2;
        MemoryReport {
            method: method.name().to_string(),
            weights_bytes: 4 * weights,
            opt_state_bytes: opt_bytes,
            grads_peak_bytes: 4 * if per_layer { grads_max } else { grads_all },
            activations_bytes: 4 * act * preset.model.n_layers.min(2), // checkpointed
            lora_extra_weights_bytes: 4 * lora_extra,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_formulas_match_paper() {
        // Table 1 with W in R^{m x n}, rank r
        let (m, n, r) = (1024, 4096, 4);
        let (w, o) = MemoryAccountant::table1_row(Method::FullAdamW, m, n, r);
        assert_eq!((w, o), (m * n, 2 * m * n));
        let (w, o) = MemoryAccountant::table1_row(Method::LoraAdamW, m, n, r);
        assert_eq!((w, o), (m * n + m * r + n * r, 2 * m * r + 2 * n * r));
        let (w, o) = MemoryAccountant::table1_row(Method::Galore, m, n, r);
        // paper: mr (projector) + 2nr (states), written for m <= n
        assert_eq!((w, o), (m * n, m * r + 2 * n * r));
        let (w, o) = MemoryAccountant::table1_row(Method::MlorcAdamW, m, n, r);
        assert_eq!((w, o), (m * n, 2 * m * r + 2 * n * r));
    }

    #[test]
    fn mlorc_equals_lora_opt_state() {
        // the paper's point: same optimizer-state budget at equal rank
        let (m, n, r) = (768, 3072, 4);
        let (_, lora) = MemoryAccountant::table1_row(Method::LoraAdamW, m, n, r);
        let (_, mlorc) = MemoryAccountant::table1_row(Method::MlorcAdamW, m, n, r);
        assert_eq!(lora, mlorc);
        // and LDAdamW pays the full-size error buffer on top
        let (_, ld) = MemoryAccountant::table1_row(Method::LdAdamW, m, n, r);
        assert!(ld > m * n);
    }

    #[test]
    fn quantized_row_is_quarter_of_factored_bytes() {
        // mlorc_q8 stores 1-byte codes + per-block scales: ~1/4 of the
        // f32 factored row, and far under the 0.3x-of-dense-AdamW line.
        let (m, n, r) = (512, 128, 4);
        let f32_row = MemoryAccountant::table1_row_opt_bytes(Method::MlorcAdamW, m, n, r);
        let q8_row = MemoryAccountant::table1_row_opt_bytes(Method::MlorcQ8, m, n, r);
        let dense_row = MemoryAccountant::table1_row_opt_bytes(Method::FullAdamW, m, n, r);
        assert!(q8_row < f32_row / 3, "q8 {q8_row}B vs f32 factored {f32_row}B");
        assert!(
            10 * q8_row <= 3 * dense_row,
            "q8 {q8_row}B must be <= 0.3x dense AdamW {dense_row}B"
        );
        // adaptive rank starts at the factored footprint (upper bound)
        let ada = MemoryAccountant::table1_row_opt_bytes(Method::MlorcAdaRank, m, n, r);
        assert_eq!(ada, f32_row);
    }
}
