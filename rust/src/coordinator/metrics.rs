//! Run metrics: loss curve, eval points, spectral records, wall-clock —
//! serialized to results/<run>.json for the bench harness and plots.

use std::path::Path;

use anyhow::Result;

use crate::util::fsutil;
use crate::util::json::Json;

use super::memory::MemoryReport;
use super::spectral::SpectralRecord;

#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub millis: f64,
}

#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub loss: f32,
    /// token accuracy (LM) or classification accuracy
    pub accuracy: f32,
    /// exact-match rate (LM tasks; = accuracy for classification)
    pub exact_match: f32,
}

#[derive(Debug, Clone, Default)]
pub struct MetricsLog {
    pub run_name: String,
    pub config: Option<Json>,
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub spectral: Vec<SpectralRecord>,
    pub memory: Option<MemoryReport>,
    pub wall_secs: f64,
    pub opt_secs: f64,
    pub fwd_bwd_secs: f64,
}

impl MetricsLog {
    pub fn new(run_name: &str) -> MetricsLog {
        MetricsLog { run_name: run_name.to_string(), ..Default::default() }
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.steps.last().map(|s| s.loss)
    }

    /// Mean loss over the last k steps (smoother than the single final
    /// minibatch).
    pub fn smoothed_final_loss(&self, k: usize) -> Option<f32> {
        if self.steps.is_empty() {
            return None;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(k)..];
        Some(tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32)
    }

    pub fn final_eval(&self) -> Option<&EvalRecord> {
        self.evals.last()
    }

    pub fn to_json(&self) -> Json {
        let steps = Json::arr(self.steps.iter().map(|s| {
            Json::obj(vec![
                ("step", Json::num(s.step as f64)),
                ("loss", Json::num(s.loss as f64)),
                ("lr", Json::num(s.lr as f64)),
                ("millis", Json::num(s.millis)),
            ])
        }));
        let evals = Json::arr(self.evals.iter().map(|e| {
            Json::obj(vec![
                ("step", Json::num(e.step as f64)),
                ("loss", Json::num(e.loss as f64)),
                ("accuracy", Json::num(e.accuracy as f64)),
                ("exact_match", Json::num(e.exact_match as f64)),
            ])
        }));
        let spectral = Json::arr(self.spectral.iter().map(|s| s.to_json()));
        let mut obj = Json::obj(vec![
            ("run_name", Json::str(self.run_name.clone())),
            ("steps", steps),
            ("evals", evals),
            ("spectral", spectral),
            ("wall_secs", Json::num(self.wall_secs)),
            ("opt_secs", Json::num(self.opt_secs)),
            ("fwd_bwd_secs", Json::num(self.fwd_bwd_secs)),
        ]);
        if let Some(cfg) = &self.config {
            obj.set("config", cfg.clone());
        }
        if let Some(mem) = &self.memory {
            obj.set("memory", mem.to_json());
        }
        obj
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        fsutil::write_atomic(path, self.to_json().to_string_pretty().as_bytes())
    }

    /// Loss curve as CSV (step, loss) — easy plotting.
    pub fn loss_csv(&self) -> String {
        let mut out = String::from("step,loss,lr\n");
        for s in &self.steps {
            out.push_str(&format!("{},{},{}\n", s.step, s.loss, s.lr));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothing_and_serialization() {
        let mut m = MetricsLog::new("t");
        for i in 0..10 {
            m.steps.push(StepRecord { step: i, loss: 10.0 - i as f32, lr: 1e-3, millis: 1.0 });
        }
        assert_eq!(m.final_loss(), Some(1.0));
        assert!((m.smoothed_final_loss(4).unwrap() - 2.5).abs() < 1e-6);
        let j = m.to_json();
        assert_eq!(j.req("steps").unwrap().as_arr().unwrap().len(), 10);
        // round-trips through the JSON module
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back.req("run_name").unwrap().as_str().unwrap(), "t");
        let csv = m.loss_csv();
        assert!(csv.lines().count() == 11);
    }
}
