//! Double-buffered async checkpoint writer.
//!
//! The cadence path of a training loop used to pay the whole v2 save —
//! rten encode, CRC-32, atomic writes, fsync, `LATEST` flip, prune —
//! inline, which is exactly the cost MLorc's factored-momentum
//! compression was supposed to make negligible. [`CkptWriter`] keeps the
//! split from `checkpoint.rs` honest at runtime: the step loop only runs
//! [`capture_snapshot`](super::capture_snapshot) (a memcpy into one of
//! [`SCRATCH_BUFFERS`] reusable [`SnapshotBuf`]s), and a dedicated
//! writer thread runs [`commit_snapshot_rotated`](super::commit_snapshot_rotated)
//! for each queued buffer in submission order.
//!
//! Backpressure: with both buffers in flight, [`CkptWriter::submit`]
//! blocks until a commit completes (counted in
//! `ckpt.backpressure_stalls`); otherwise the step loop never waits on
//! IO. `ckpt.inflight` gauges the queue depth.
//!
//! Error and crash semantics are the synchronous path's: every commit's
//! `Result` comes back through a [`CommitOutcome`] (from `submit`'s
//! opportunistic reclaim, [`CkptWriter::drain`] or the hard
//! [`CkptWriter::join`]), so callers surface writer-thread failures
//! (ENOSPC, rename faults) into their normal retry path; `kill`
//! failpoints exit the whole process from the writer thread just as they
//! would inline. Callers MUST `join` before any point whose semantics
//! depend on "the save is on disk": job finish, terminal transitions,
//! and the `ckpt_cadence` crash hook (see `serve::scheduler::drive`).
//! Dropping the writer joins the thread but discards outcomes — join
//! first when errors matter.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::obs::registry;

use super::checkpoint::{commit_snapshot_rotated, SnapshotBuf};

/// How many reusable scratch buffers (and so in-flight commits) the
/// writer runs with. Two is the double-buffering sweet spot: one being
/// filled while one commits; a third would only hide a writer that
/// cannot keep up with the cadence at all.
pub const SCRATCH_BUFFERS: usize = 2;

/// The result of one background commit, in submission order.
pub struct CommitOutcome {
    /// The step the committed snapshot captured.
    pub step: usize,
    /// The snapshot directory on success; the writer-thread error
    /// (ENOSPC, rename failure, fsync failure) otherwise.
    pub dir: Result<PathBuf>,
}

type Done = (SnapshotBuf, usize, Result<PathBuf>);

/// Background committer for one rotated checkpoint root. See the module
/// docs for the contract.
pub struct CkptWriter {
    work_tx: Option<SyncSender<SnapshotBuf>>,
    done_rx: Receiver<Done>,
    free: Vec<SnapshotBuf>,
    in_flight: usize,
    handle: Option<JoinHandle<()>>,
}

impl CkptWriter {
    /// Spawn the writer thread for `root`.
    pub fn new(root: &Path) -> CkptWriter {
        let root = root.to_path_buf();
        let (work_tx, work_rx) = sync_channel::<SnapshotBuf>(SCRATCH_BUFFERS);
        let (done_tx, done_rx) = std::sync::mpsc::channel::<Done>();
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || {
                while let Ok(buf) = work_rx.recv() {
                    let res = commit_snapshot_rotated(&root, &buf);
                    let step = buf.step();
                    if done_tx.send((buf, step, res)).is_err() {
                        return;
                    }
                }
            })
            .expect("spawning the checkpoint writer thread");
        CkptWriter {
            work_tx: Some(work_tx),
            done_rx,
            free: (0..SCRATCH_BUFFERS).map(|_| SnapshotBuf::default()).collect(),
            in_flight: 0,
            handle: Some(handle),
        }
    }

    fn reclaim(&mut self, (buf, step, res): Done, out: &mut Vec<CommitOutcome>) {
        self.in_flight -= 1;
        registry::CKPT_INFLIGHT.set(self.in_flight as u64);
        self.free.push(buf);
        out.push(CommitOutcome { step, dir: res });
    }

    /// Capture into a free scratch buffer via `capture` and queue its
    /// commit. Blocks only when both buffers are in flight (recorded as
    /// a `ckpt.backpressure_stalls` hit). Completions reclaimed along
    /// the way are returned so the caller can surface their results —
    /// an empty vec just means nothing had finished yet.
    pub fn submit(
        &mut self,
        capture: impl FnOnce(&mut SnapshotBuf) -> Result<()>,
    ) -> Result<Vec<CommitOutcome>> {
        let mut done = Vec::new();
        if self.free.is_empty() {
            registry::CKPT_BACKPRESSURE_STALLS.add(1);
            let msg = self
                .done_rx
                .recv()
                .map_err(|_| anyhow!("checkpoint writer thread died"))?;
            self.reclaim(msg, &mut done);
        }
        // opportunistic, non-blocking reclaim keeps outcome latency low
        // even when backpressure never triggers
        while let Ok(msg) = self.done_rx.try_recv() {
            self.reclaim(msg, &mut done);
        }
        let mut buf = self.free.pop().expect("a scratch buffer is free here");
        if let Err(e) = capture(&mut buf) {
            self.free.push(buf);
            return Err(e);
        }
        self.work_tx
            .as_ref()
            .expect("writer channel open until finish/drop")
            .send(buf)
            .map_err(|_| anyhow!("checkpoint writer thread died"))?;
        self.in_flight += 1;
        registry::CKPT_INFLIGHT.set(self.in_flight as u64);
        Ok(done)
    }

    /// Non-blocking: collect every commit that has completed so far.
    pub fn drain(&mut self) -> Vec<CommitOutcome> {
        let mut done = Vec::new();
        loop {
            match self.done_rx.try_recv() {
                Ok(msg) => self.reclaim(msg, &mut done),
                Err(TryRecvError::Empty | TryRecvError::Disconnected) => return done,
            }
        }
    }

    /// Hard join: block until every submitted commit has completed and
    /// return their outcomes. This is the barrier callers place at job
    /// finish, terminal transitions and `ckpt_cadence` failpoint
    /// boundaries.
    pub fn join(&mut self) -> Result<Vec<CommitOutcome>> {
        let mut done = Vec::new();
        while self.in_flight > 0 {
            let msg = self
                .done_rx
                .recv()
                .map_err(|_| anyhow!("checkpoint writer thread died"))?;
            self.reclaim(msg, &mut done);
        }
        Ok(done)
    }
}

impl Drop for CkptWriter {
    fn drop(&mut self) {
        // closing the work channel stops the thread after the queue
        // empties; outcomes still in the done channel are discarded, so
        // error-sensitive callers join() before dropping
        self.work_tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        registry::CKPT_INFLIGHT.set(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, RunConfig, TaskKind};
    use crate::coordinator::{capture_snapshot, resolve_checkpoint_dir, OptSnapshot, ParamStore};
    use crate::linalg::Rng;
    use crate::runtime::ParamSpec;
    use crate::tensor::Tensor;

    fn store(fill: f32) -> ParamStore {
        ParamStore {
            specs: vec![ParamSpec {
                name: "w".into(),
                shape: vec![3, 2],
                kind: "matrix".into(),
                compressed: true,
            }],
            values: vec![Tensor::full(&[3, 2], fill)],
        }
    }

    #[test]
    fn async_commits_land_in_order_and_join_reports_each() {
        let root =
            std::env::temp_dir().join(format!("mlorc_ckpt_writer_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let rng = Rng::new(0);
        let mut w = CkptWriter::new(&root);
        let mut outcomes = Vec::new();
        for step in [4usize, 8, 12] {
            let params = store(step as f32);
            let snap = OptSnapshot { opt: vec![], rng_data: &rng, omega: &[] };
            outcomes.extend(
                w.submit(|buf| capture_snapshot(buf, step, &cfg, &params, None, &snap)).unwrap(),
            );
        }
        outcomes.extend(w.join().unwrap());
        drop(w);
        let steps: Vec<usize> = outcomes.iter().map(|o| o.step).collect();
        assert_eq!(steps, vec![4, 8, 12]);
        for o in &outcomes {
            o.dir.as_ref().unwrap();
        }
        // LATEST points at the newest snapshot; older ones pruned to the
        // retention window
        let resolved = resolve_checkpoint_dir(&root).unwrap();
        assert!(resolved.ends_with("step-00000012"), "{resolved:?}");
        assert!(!root.join("step-00000004").exists());
        assert!(root.join("step-00000008").exists());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
