//! Spectral probe — the Figure 1/4 machinery: during training, measure
//! the ratio of the top-k singular values to the total spectrum for the
//! gradient, first moment and second moment of tracked matrix parameters.
//!
//! Uses the pure-rust Jacobi SVD; probing is restricted to (d, d)
//! attention matrices by default to keep the probe O(d^3) per record.

use crate::linalg::svd::top_k_ratio;
use crate::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct SpectralRecord {
    pub step: usize,
    /// mean over tracked params of top-k ratio
    pub grad_ratio: f32,
    pub m_ratio: f32,
    pub v_ratio: f32,
    pub n_tracked: usize,
}

impl SpectralRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("grad_ratio", Json::num(self.grad_ratio as f64)),
            ("m_ratio", Json::num(self.m_ratio as f64)),
            ("v_ratio", Json::num(self.v_ratio as f64)),
            ("n_tracked", Json::num(self.n_tracked as f64)),
        ])
    }
}

pub struct SpectralProbe {
    pub k: usize,
    /// parameter-name predicate: which matrices to track
    tracked: Vec<String>,
}

impl SpectralProbe {
    /// Track the attention projections of the first two blocks (square
    /// (d, d) matrices — cheap to SVD, representative per Figure 4).
    pub fn default_for(param_names: &[String]) -> SpectralProbe {
        let tracked: Vec<String> = param_names
            .iter()
            .filter(|n| {
                (n.starts_with("blk0.") || n.starts_with("blk1."))
                    && (n.ends_with(".wq") || n.ends_with(".wv"))
            })
            .cloned()
            .collect();
        SpectralProbe { k: 8, tracked }
    }

    pub fn tracked(&self) -> &[String] {
        &self.tracked
    }

    /// One record from (name -> (grad, m, v)) fetches.
    pub fn record(
        &self,
        step: usize,
        entries: &[(Tensor, Option<Tensor>, Option<Tensor>)],
    ) -> SpectralRecord {
        let mut gr = 0.0f32;
        let mut mr = 0.0f32;
        let mut vr = 0.0f32;
        let mut mcount = 0usize;
        let mut vcount = 0usize;
        for (g, m, v) in entries {
            gr += top_k_ratio(g, self.k);
            if let Some(m) = m {
                mr += top_k_ratio(m, self.k);
                mcount += 1;
            }
            if let Some(v) = v {
                vr += top_k_ratio(v, self.k);
                vcount += 1;
            }
        }
        let n = entries.len().max(1);
        SpectralRecord {
            step,
            grad_ratio: gr / n as f32,
            m_ratio: if mcount > 0 { mr / mcount as f32 } else { 0.0 },
            v_ratio: if vcount > 0 { vr / vcount as f32 } else { 0.0 },
            n_tracked: entries.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, Rng};

    #[test]
    fn tracks_expected_params() {
        let names: Vec<String> = [
            "tok_emb", "blk0.wq", "blk0.wk", "blk0.wv", "blk0.w1", "blk1.wq", "blk2.wq", "lnf_g",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let probe = SpectralProbe::default_for(&names);
        assert_eq!(probe.tracked(), &["blk0.wq", "blk0.wv", "blk1.wq"]);
    }

    #[test]
    fn second_moment_of_lowrank_grad_is_more_concentrated() {
        // the paper's Figure 1 qualitative claim: v = EMA(g^2) has an even
        // stronger low-rank structure when g is (approximately) low-rank
        let mut rng = Rng::new(0);
        let u = rng.gaussian_tensor(&[48, 3], 1.0);
        let w = rng.gaussian_tensor(&[3, 48], 1.0);
        let mut g = matmul(&u, &w);
        let noise = rng.gaussian_tensor(&[48, 48], 0.3);
        g.axpy(1.0, &noise, 1.0);
        let v = g.map(|x| x * x);
        let probe = SpectralProbe { k: 8, tracked: vec![] };
        let rec = probe.record(0, &[(g.clone(), Some(g.clone()), Some(v))]);
        assert!(rec.v_ratio > rec.grad_ratio, "{} vs {}", rec.v_ratio, rec.grad_ratio);
        assert_eq!(rec.m_ratio, rec.grad_ratio);
    }
}
