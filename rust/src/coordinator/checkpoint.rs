//! Checkpointing: parameters (and LoRA adapters) to RTEN + a JSON sidecar
//! with the run config, so a run can resume or be evaluated later.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::util::fsutil;
use crate::util::json::Json;
use crate::tensor::write_rten;

use super::params::ParamStore;

pub fn save_checkpoint(
    dir: &Path,
    step: usize,
    cfg: &RunConfig,
    params: &ParamStore,
    adapters: Option<&ParamStore>,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut tensors = BTreeMap::new();
    for (spec, val) in params.specs.iter().zip(&params.values) {
        tensors.insert(spec.name.clone(), val.clone());
    }
    if let Some(a) = adapters {
        for (spec, val) in a.specs.iter().zip(&a.values) {
            tensors.insert(spec.name.clone(), val.clone());
        }
    }
    write_rten(&dir.join("params.rten"), &tensors)?;
    let meta = Json::obj(vec![
        ("step", Json::num(step as f64)),
        ("config", cfg.to_json()),
        ("n_tensors", Json::num(tensors.len() as f64)),
    ]);
    fsutil::write_atomic(&dir.join("meta.json"), meta.to_string_pretty().as_bytes())
}

pub fn load_checkpoint(dir: &Path, params: &mut ParamStore) -> Result<usize> {
    let meta = Json::from_file(&dir.join("meta.json"))?;
    let step = meta.req("step")?.as_usize()?;
    let tensors = crate::tensor::read_rten(&dir.join("params.rten"))
        .with_context(|| format!("checkpoint at {}", dir.display()))?;
    for (spec, val) in params.specs.iter().zip(params.values.iter_mut()) {
        match tensors.get(&spec.name) {
            Some(t) => {
                if t.shape != spec.shape {
                    bail!(
                        "checkpoint tensor '{}' has shape {:?}, expected {:?}",
                        spec.name,
                        t.shape,
                        spec.shape
                    );
                }
                *val = t.clone();
            }
            None => bail!("checkpoint missing tensor '{}'", spec.name),
        }
    }
    Ok(step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, TaskKind};
    use crate::runtime::ParamSpec;
    use crate::tensor::Tensor;

    fn store() -> ParamStore {
        ParamStore {
            specs: vec![
                ParamSpec { name: "a".into(), shape: vec![2, 3], kind: "matrix".into(), compressed: true },
                ParamSpec { name: "b".into(), shape: vec![4], kind: "vector".into(), compressed: false },
            ],
            values: vec![
                Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
                Tensor::full(&[4], 7.0),
            ],
        }
    }

    #[test]
    fn roundtrip_and_shape_guard() {
        let dir = std::env::temp_dir().join(format!("mlorc_ckpt_{}", std::process::id()));
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let orig = store();
        save_checkpoint(&dir, 42, &cfg, &orig, None).unwrap();
        let mut loaded = store();
        loaded.values[0] = Tensor::zeros(&[2, 3]);
        let step = load_checkpoint(&dir, &mut loaded).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded.values[0], orig.values[0]);
        // shape mismatch must fail loudly
        let mut wrong = store();
        wrong.specs[0].shape = vec![3, 2];
        wrong.values[0] = Tensor::zeros(&[3, 2]);
        assert!(load_checkpoint(&dir, &mut wrong).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
