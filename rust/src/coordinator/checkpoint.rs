//! Checkpointing.
//!
//! Two formats coexist:
//!
//! * **v1** (`save_checkpoint`/`load_checkpoint`): parameters (and LoRA
//!   adapters) to RTEN + a JSON sidecar with the run config. Evaluation
//!   snapshots only — v1 silently drops every optimizer state, so a v1
//!   directory cannot resume training dynamics.
//! * **v2** (`save_checkpoint_v2`/`load_checkpoint_v2`): v1's tensors
//!   plus the full `OptState` of every trainable parameter (MLorc Q/B
//!   momentum factors, AdamW/Lion moments, GaLore/LDAdamW projectors and
//!   flags), the data RNG and per-parameter Omega stream positions, and
//!   the step count — everything needed to resume a killed run with
//!   training dynamics bit-identical to an uninterrupted one. MLorc is
//!   what makes this cheap: the momentum of matrix parameters is stored
//!   as rank-l factors, so the whole optimizer state is a few percent of
//!   the full-AdamW footprint (see `MemoryAccountant`).
//!
//! Crash safety: every file goes through `write_atomic`, and the rotated
//! writer (`save_checkpoint_v2_rotated`) puts each snapshot in its own
//! `step-NNNNNNNN/` subdirectory, flipping the `LATEST` pointer only
//! after the snapshot is fully on disk — a kill mid-write can never
//! corrupt the snapshot a restart resumes from. Commit markers and the
//! `LATEST` flip are followed by a parent-directory fsync, so a
//! committed snapshot also survives power loss.
//!
//! Every save is split into a cheap **capture** ([`capture_snapshot`]
//! into an owned [`SnapshotBuf`] — a memcpy, timed as `ckpt.snapshot_us`)
//! and an expensive **commit** ([`commit_snapshot_rotated`] — encode,
//! CRC, write, flip, fsync, prune, timed as `ckpt.commit_us`). The
//! synchronous writers run both halves inline; the double-buffered
//! background writer ([`super::CkptWriter`]) runs commits on a dedicated
//! thread so the step loop pays only the capture
//! (`docs/checkpoint-v2.md`, "Async commit pipeline").
//!
//! Integrity: every RTEN file carries a CRC-32 footer, and each v2
//! snapshot additionally writes `manifest.json` — per-file byte counts
//! and checksums plus a hash over the whole file list — before the
//! `meta.json` commit marker. [`verify_snapshot`] replays those checks,
//! and [`resolve_checkpoint_dir_verified`] degrades gracefully: when
//! `LATEST` is torn or its target fails verification, resume falls back
//! to the newest intact `step-*` snapshot instead of crashing or loading
//! garbage (`docs/checkpoint-v2.md`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::linalg::Rng;
use crate::obs;
use crate::tensor::{
    read_rten, read_rten_entries, rten_bytes, rten_entry_bytes, write_rten, RtenEntry, Tensor,
};
use crate::util::fsutil;
use crate::util::json::Json;

use super::params::ParamStore;
use super::state::OptState;

// ------------------------------------------------------------------- v1

pub fn save_checkpoint(
    dir: &Path,
    step: usize,
    cfg: &RunConfig,
    params: &ParamStore,
    adapters: Option<&ParamStore>,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let tensors = collect_params(params, adapters);
    write_rten(&dir.join("params.rten"), &tensors)?;
    let meta = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("step", Json::num(step as f64)),
        ("config", cfg.to_json()),
        ("n_tensors", Json::num(tensors.len() as f64)),
    ]);
    fsutil::write_atomic(&dir.join("meta.json"), meta.to_string_pretty().as_bytes())
}

/// Load parameters (+ step count) from a v1 *or* v2 directory — both
/// carry `params.rten`. Optimizer state, if any, is ignored.
pub fn load_checkpoint(dir: &Path, params: &mut ParamStore) -> Result<usize> {
    let meta = Json::from_file(&dir.join("meta.json"))?;
    let step = meta.req("step")?.as_usize()?;
    let tensors = read_rten(&dir.join("params.rten"))
        .with_context(|| format!("checkpoint at {}", dir.display()))?;
    restore_store(&tensors, params)?;
    Ok(step)
}

fn collect_params(params: &ParamStore, adapters: Option<&ParamStore>) -> BTreeMap<String, Tensor> {
    let mut tensors = BTreeMap::new();
    for (spec, val) in params.specs.iter().zip(&params.values) {
        tensors.insert(spec.name.clone(), val.clone());
    }
    if let Some(a) = adapters {
        for (spec, val) in a.specs.iter().zip(&a.values) {
            tensors.insert(spec.name.clone(), val.clone());
        }
    }
    tensors
}

fn restore_store(tensors: &BTreeMap<String, Tensor>, store: &mut ParamStore) -> Result<()> {
    for (spec, val) in store.specs.iter().zip(store.values.iter_mut()) {
        match tensors.get(&spec.name) {
            Some(t) => {
                if t.shape != spec.shape {
                    bail!(
                        "checkpoint tensor '{}' has shape {:?}, expected {:?}",
                        spec.name,
                        t.shape,
                        spec.shape
                    );
                }
                *val = t.clone();
            }
            None => bail!("checkpoint missing tensor '{}'", spec.name),
        }
    }
    Ok(())
}

// ------------------------------------------------------------------- v2

/// Everything the v2 format persists beyond the raw parameter tensors.
pub struct OptSnapshot<'a> {
    /// (trainable parameter name, its state), in trainable order.
    pub opt: Vec<(String, &'a OptState)>,
    /// Data/batch RNG stream position.
    pub rng_data: &'a Rng,
    /// Per-trainable Omega stream positions, in trainable order.
    pub omega: &'a [Rng],
}

/// A loaded v2 checkpoint (parameters are restored in place; the rest is
/// returned for the trainer to adopt).
pub struct CheckpointV2 {
    pub step: usize,
    pub config: RunConfig,
    pub rng_data: Rng,
    pub omega: Vec<Rng>,
    pub opt: BTreeMap<String, OptState>,
}

/// Owned capture of everything one v2 snapshot persists — the scratch
/// half of the snapshot/commit split. [`capture_snapshot`] fills it from
/// live trainer state (reusing the previous capture's allocations, so a
/// steady-state cadence is a straight memcpy); [`commit_snapshot`] /
/// [`commit_snapshot_rotated`] do the expensive half (rten encode,
/// CRC-32, atomic writes, fsync, `LATEST` flip, prune) from the buffer
/// alone — on the caller's thread or a background writer
/// ([`super::CkptWriter`]), bit-identically either way.
pub struct SnapshotBuf {
    step: usize,
    cfg: Option<RunConfig>,
    params: BTreeMap<String, Tensor>,
    opt_entries: BTreeMap<String, RtenEntry>,
    opt_meta: Json,
    rng: Json,
}

impl Default for SnapshotBuf {
    fn default() -> SnapshotBuf {
        SnapshotBuf {
            step: 0,
            cfg: None,
            params: BTreeMap::new(),
            opt_entries: BTreeMap::new(),
            opt_meta: Json::Null,
            rng: Json::Null,
        }
    }
}

impl SnapshotBuf {
    /// The step this buffer captured (meaningful once filled).
    pub fn step(&self) -> usize {
        self.step
    }
}

/// Copy `src` into `dst[name]`, stealing a matching-shape allocation
/// from `prev` (the buffer's previous capture) when possible.
fn copy_tensor(
    prev: &mut BTreeMap<String, Tensor>,
    dst: &mut BTreeMap<String, Tensor>,
    name: &str,
    src: &Tensor,
) {
    let t = match prev.remove(name) {
        Some(mut t) if t.shape == src.shape => {
            t.data.copy_from_slice(&src.data);
            t
        }
        _ => src.clone(),
    };
    dst.insert(name.to_string(), t);
}

/// The cheap, step-path half of a v2 save: copy parameters, every
/// `OptState` tensor field and u8 quant plane, the RNG snapshots and the
/// per-state `ckpt_meta` into `buf`. No encoding, checksumming or IO
/// happens here — the buffer is trivially consistent the moment this
/// returns, and [`commit_snapshot`] can run on another thread.
pub fn capture_snapshot(
    buf: &mut SnapshotBuf,
    step: usize,
    cfg: &RunConfig,
    params: &ParamStore,
    adapters: Option<&ParamStore>,
    snap: &OptSnapshot,
) -> Result<()> {
    let _span = obs::span(&obs::registry::CKPT_SNAPSHOT_US);
    if snap.opt.len() != snap.omega.len() {
        bail!("{} opt states but {} omega streams", snap.opt.len(), snap.omega.len());
    }
    buf.step = step;
    buf.cfg = Some(cfg.clone());

    let mut prev = std::mem::take(&mut buf.params);
    for (spec, val) in params.specs.iter().zip(&params.values) {
        copy_tensor(&mut prev, &mut buf.params, &spec.name, val);
    }
    if let Some(a) = adapters {
        for (spec, val) in a.specs.iter().zip(&a.values) {
            copy_tensor(&mut prev, &mut buf.params, &spec.name, val);
        }
    }

    let mut prev_opt = std::mem::take(&mut buf.opt_entries);
    let mut opt_meta = Json::Obj(BTreeMap::new());
    for (name, state) in &snap.opt {
        opt_meta.set(name, state.ckpt_meta());
        for (field, t) in state.tensor_fields() {
            let key = format!("{name}/{field}");
            let e = match prev_opt.remove(&key) {
                Some(RtenEntry::F32(mut old)) if old.shape == t.shape => {
                    old.data.copy_from_slice(&t.data);
                    RtenEntry::F32(old)
                }
                _ => RtenEntry::F32(t.clone()),
            };
            buf.opt_entries.insert(key, e);
        }
        // quantized layouts add their u8 code planes as dtype-2 entries
        for (field, t) in state.u8_fields() {
            let key = format!("{name}/{field}");
            let e = match prev_opt.remove(&key) {
                Some(RtenEntry::U8(mut old)) if old.shape == t.shape => {
                    old.data.copy_from_slice(&t.data);
                    RtenEntry::U8(old)
                }
                _ => RtenEntry::U8(t.clone()),
            };
            buf.opt_entries.insert(key, e);
        }
        // stochastic-rounding layouts add their bf16 weight planes as
        // dtype-3 entries
        for (field, t) in state.bf16_fields() {
            let key = format!("{name}/{field}");
            let e = match prev_opt.remove(&key) {
                Some(RtenEntry::Bf16(mut old)) if old.shape == t.shape => {
                    old.data.copy_from_slice(&t.data);
                    RtenEntry::Bf16(old)
                }
                _ => RtenEntry::Bf16(t.clone()),
            };
            buf.opt_entries.insert(key, e);
        }
    }
    buf.opt_meta = opt_meta;
    let omega = Json::arr(snap.omega.iter().map(rng_to_json));
    buf.rng = Json::obj(vec![("data", rng_to_json(snap.rng_data)), ("omega", omega)]);
    Ok(())
}

/// The expensive half of a v2 save: encode, checksum and atomically
/// write a captured [`SnapshotBuf`] into `dir`, then fsync the snapshot
/// directory so the `meta.json` commit marker survives power loss.
/// `meta.json` is written last and is the commit marker: loaders refuse
/// a directory without it.
pub fn commit_snapshot(dir: &Path, buf: &SnapshotBuf) -> Result<()> {
    let cfg =
        buf.cfg.as_ref().context("snapshot buffer was never captured (capture before commit)")?;
    std::fs::create_dir_all(dir)?;
    let params_bytes = rten_bytes(&buf.params)?;
    fsutil::write_atomic_site(&dir.join("params.rten"), &params_bytes, "ckpt_write")?;
    let opt_bytes = rten_entry_bytes(&buf.opt_entries)?;
    fsutil::write_atomic_site(&dir.join("opt_state.rten"), &opt_bytes, "ckpt_write")?;

    let meta = Json::obj(vec![
        ("version", Json::num(2.0)),
        ("step", Json::num(buf.step as f64)),
        ("config", cfg.to_json()),
        ("n_tensors", Json::num(buf.params.len() as f64)),
        ("opt_states", buf.opt_meta.clone()),
        ("rng", buf.rng.clone()),
    ]);
    let meta_bytes = meta.to_string_pretty().into_bytes();

    // manifest before meta: the commit marker lands last, so a snapshot
    // with meta.json always has a manifest to verify against. Checksums
    // come from the in-memory payloads, not a read-back — a torn write
    // therefore cannot forge a matching manifest.
    let manifest = snapshot_manifest(&[
        ("meta.json", &meta_bytes),
        ("opt_state.rten", &opt_bytes),
        ("params.rten", &params_bytes),
    ]);
    fsutil::write_atomic_site(
        &dir.join("manifest.json"),
        manifest.to_string_pretty().as_bytes(),
        "ckpt_write",
    )?;
    fsutil::write_atomic_site(&dir.join("meta.json"), &meta_bytes, "ckpt_write")?;
    // The renames above order each file's data before its name, but the
    // names themselves are only durable once the directory is synced.
    fsutil::fsync_dir(dir)
}

/// Write a full v2 snapshot into `dir` synchronously — capture + commit
/// in one call, through the same split the async writer uses, so the
/// bytes on disk are identical either way.
pub fn save_checkpoint_v2(
    dir: &Path,
    step: usize,
    cfg: &RunConfig,
    params: &ParamStore,
    adapters: Option<&ParamStore>,
    snap: &OptSnapshot,
) -> Result<()> {
    let mut buf = SnapshotBuf::default();
    capture_snapshot(&mut buf, step, cfg, params, adapters, snap)?;
    commit_snapshot(dir, &buf)
}

/// Build the `manifest.json` document: per-file byte counts + CRC-32,
/// plus a snapshot-wide hash over the sorted `name:crc` list.
fn snapshot_manifest(files: &[(&str, &[u8])]) -> Json {
    let mut entries: Vec<(&str, Json)> = Vec::new();
    let mut lines = String::new();
    for &(name, bytes) in files {
        let crc = fsutil::crc32(bytes);
        entries.push((
            name,
            Json::obj(vec![
                ("bytes", Json::num(bytes.len() as f64)),
                ("crc32", Json::str(format!("{crc:08x}"))),
            ]),
        ));
        lines.push_str(&format!("{name}:{crc:08x}\n"));
    }
    Json::obj(vec![
        ("version", Json::num(1.0)),
        ("files", Json::obj(entries)),
        ("snapshot_crc32", Json::str(format!("{:08x}", fsutil::crc32(lines.as_bytes())))),
    ])
}

/// Replay a snapshot's integrity checks: `meta.json` must exist and
/// parse, and when `manifest.json` is present (every snapshot written
/// since it was introduced) each listed file must match its recorded
/// byte count and CRC-32, and the file list must match the snapshot
/// hash. Pre-manifest snapshots pass on a parsable `meta.json` alone.
pub fn verify_snapshot(dir: &Path) -> Result<()> {
    let meta_path = dir.join("meta.json");
    if !meta_path.exists() {
        bail!("snapshot {} has no meta.json (incomplete or not a snapshot)", dir.display());
    }
    Json::from_file(&meta_path).with_context(|| format!("parsing {}", meta_path.display()))?;
    let man_path = dir.join("manifest.json");
    if !man_path.exists() {
        return Ok(()); // pre-manifest snapshot: nothing more to check
    }
    let man = Json::from_file(&man_path)
        .with_context(|| format!("parsing {}", man_path.display()))?;
    let mut lines = String::new();
    for (name, entry) in man.req("files")?.as_obj()? {
        let fpath = dir.join(name);
        let bytes = std::fs::read(&fpath)
            .with_context(|| format!("manifest lists {} but it is unreadable", fpath.display()))?;
        let want_len = entry.req("bytes")?.as_usize()?;
        if bytes.len() != want_len {
            bail!(
                "{}: {} bytes on disk, manifest says {} — torn or corrupt",
                fpath.display(),
                bytes.len(),
                want_len
            );
        }
        let want_crc =
            u32::from_str_radix(entry.req("crc32")?.as_str()?, 16).context("manifest crc32")?;
        let got = fsutil::crc32(&bytes);
        if got != want_crc {
            bail!(
                "{}: CRC-32 {got:08x} != manifest {want_crc:08x} — torn or corrupt",
                fpath.display()
            );
        }
        lines.push_str(&format!("{name}:{want_crc:08x}\n"));
    }
    let want_hash = u32::from_str_radix(man.req("snapshot_crc32")?.as_str()?, 16)
        .context("manifest snapshot_crc32")?;
    if fsutil::crc32(lines.as_bytes()) != want_hash {
        bail!("snapshot {}: manifest file-list hash mismatch", dir.display());
    }
    Ok(())
}

/// Load a v2 checkpoint: parameters (and adapters) are restored in place,
/// optimizer states / RNG positions / step come back in [`CheckpointV2`].
///
/// A v1 directory fails with a structured "this is v1" error instead of a
/// confusing shape/missing-tensor mismatch: v1's `save_checkpoint`
/// dropped all optimizer state, so there is nothing to resume from.
pub fn load_checkpoint_v2(
    dir: &Path,
    params: &mut ParamStore,
    adapters: Option<&mut ParamStore>,
) -> Result<CheckpointV2> {
    let meta = Json::from_file(&dir.join("meta.json"))?;
    let version = match meta.get("version") {
        Some(v) => v.as_usize()?,
        None => 1, // pre-versioning checkpoints are v1 by definition
    };
    if version < 2 {
        bail!(
            "checkpoint at {} is format v1: parameters only — v1 `save_checkpoint` \
             dropped every optimizer state, so it cannot resume training dynamics. \
             Load it with `load_checkpoint` (params + step) and restart the \
             optimizer, or re-checkpoint with the v2 writer.",
            dir.display()
        );
    }
    if version > 2 {
        bail!(
            "checkpoint at {} is format v{version}, newer than this binary understands (v2)",
            dir.display()
        );
    }
    let step = meta.req("step")?.as_usize()?;
    let config = RunConfig::from_json(meta.req("config")?)?;

    let tensors = read_rten(&dir.join("params.rten"))
        .with_context(|| format!("checkpoint at {}", dir.display()))?;
    restore_store(&tensors, params)?;
    if let Some(a) = adapters {
        restore_store(&tensors, a)?;
    }

    let opt_tensors = read_rten_entries(&dir.join("opt_state.rten"))
        .with_context(|| format!("checkpoint at {}", dir.display()))?;
    let mut opt = BTreeMap::new();
    for (name, state_meta) in meta.req("opt_states")?.as_obj()? {
        let state = OptState::from_ckpt_full(
            state_meta,
            |field| {
                let key = format!("{name}/{field}");
                match opt_tensors.get(&key) {
                    Some(RtenEntry::F32(t)) => Ok(t.clone()),
                    Some(_) => bail!("optimizer tensor '{key}' is not f32"),
                    None => bail!("checkpoint missing optimizer tensor '{key}'"),
                }
            },
            |field| {
                let key = format!("{name}/{field}");
                match opt_tensors.get(&key) {
                    Some(RtenEntry::U8(t)) => Ok(t.clone()),
                    Some(_) => bail!("optimizer tensor '{key}' is not u8"),
                    None => bail!("checkpoint missing optimizer tensor '{key}'"),
                }
            },
            |field| {
                let key = format!("{name}/{field}");
                match opt_tensors.get(&key) {
                    Some(RtenEntry::Bf16(t)) => Ok(t.clone()),
                    Some(_) => bail!("optimizer tensor '{key}' is not bf16"),
                    None => bail!("checkpoint missing optimizer tensor '{key}'"),
                }
            },
        )
        .with_context(|| format!("optimizer state for '{name}'"))?;
        opt.insert(name.clone(), state);
    }

    let rng = meta.req("rng")?;
    let rng_data = rng_from_json(rng.req("data")?).context("data rng")?;
    let omega = rng
        .req("omega")?
        .as_arr()?
        .iter()
        .map(rng_from_json)
        .collect::<Result<Vec<_>>>()
        .context("omega rng streams")?;

    Ok(CheckpointV2 { step, config, rng_data, omega, opt })
}

/// Resolve + load a v2 checkpoint and validate it against a live run:
/// same preset/method/task and a matching Omega stream count are
/// required; a seed mismatch only warns (the checkpoint's streams win).
/// Parameters (and adapters) are restored in place; optimizer states,
/// RNG streams and the step count come back for the caller to adopt.
/// Shared by `Trainer::resume_from` and the serve host engine so the
/// resume contract cannot drift between them.
pub fn load_for_resume(
    dir: &Path,
    cfg: &RunConfig,
    params: &mut ParamStore,
    adapters: Option<&mut ParamStore>,
    n_streams: usize,
) -> Result<CheckpointV2> {
    let snap_dir = resolve_checkpoint_dir_verified(dir)?;
    let ck = load_checkpoint_v2(&snap_dir, params, adapters)?;
    if ck.config.method != cfg.method
        || ck.config.preset != cfg.preset
        || ck.config.task != cfg.task
    {
        bail!(
            "checkpoint at {} is a {}/{}/{} run; this run is {}/{}/{}",
            snap_dir.display(),
            ck.config.preset,
            ck.config.method.name(),
            ck.config.task.name(),
            cfg.preset,
            cfg.method.name(),
            cfg.task.name()
        );
    }
    if ck.config.seed != cfg.seed {
        log::warn!(
            "resume: checkpoint seed {} != run seed {}; continuing with the checkpoint's streams",
            ck.config.seed,
            cfg.seed
        );
    }
    if ck.omega.len() != n_streams {
        bail!(
            "checkpoint has {} omega streams for {} trainable parameters",
            ck.omega.len(),
            n_streams
        );
    }
    Ok(ck)
}

// -------------------------------------------------------------- rotation

/// How many `step-*` snapshots a rotated checkpoint root retains.
const KEEP_SNAPSHOTS: usize = 2;

fn snapshot_name(step: usize) -> String {
    format!("step-{step:08}")
}

/// The rotated commit: write a captured [`SnapshotBuf`] into
/// `root/step-NNNNNNNN/`, flip `root/LATEST` to it, fsync the root so
/// the flip is power-loss durable, then prune all but the newest
/// [`KEEP_SNAPSHOTS`] snapshots. This is the function the async writer
/// thread runs; returns the snapshot directory.
pub fn commit_snapshot_rotated(root: &Path, buf: &SnapshotBuf) -> Result<PathBuf> {
    let _span = obs::span(&obs::registry::CKPT_COMMIT_US);
    obs::registry::CKPT_SAVES.add(1);
    let name = snapshot_name(buf.step);
    let dir = root.join(&name);
    commit_snapshot(&dir, buf)?;
    fsutil::write_atomic_site(&root.join("LATEST"), name.as_bytes(), "latest_write")?;
    // LATEST's rename, like the snapshot files', needs the parent
    // directory synced before it survives power loss.
    fsutil::fsync_dir(root)?;
    prune_snapshots(root, &name);
    Ok(dir)
}

/// Crash-safe cadence writer: capture + rotated commit in one
/// synchronous call (the `--checkpoint-sync` path, and every one-off
/// save). Returns the snapshot directory.
pub fn save_checkpoint_v2_rotated(
    root: &Path,
    step: usize,
    cfg: &RunConfig,
    params: &ParamStore,
    adapters: Option<&ParamStore>,
    snap: &OptSnapshot,
) -> Result<PathBuf> {
    // One span covers the whole cadence cost a synchronous training loop
    // pays: capture + snapshot write + LATEST flip + prune.
    let _span = obs::span(&obs::registry::CKPT_SAVE_US);
    let mut buf = SnapshotBuf::default();
    capture_snapshot(&mut buf, step, cfg, params, adapters, snap)?;
    commit_snapshot_rotated(root, &buf)
}

/// Best-effort removal of stale snapshots — never the `LATEST` target.
/// Runs on the writer thread in async mode and may race a concurrent
/// `mlorc fsck --repair` on the same root: the on-disk `LATEST` is
/// re-read so a just-repointed target is never pruned, and a snapshot
/// that vanishes underneath us (fsck dropped it first) is not an error.
fn prune_snapshots(root: &Path, latest: &str) {
    let Ok(entries) = std::fs::read_dir(root) else { return };
    let on_disk = std::fs::read_to_string(root.join("LATEST"))
        .map(|s| s.trim().to_string())
        .unwrap_or_default();
    let mut snaps: Vec<String> = entries
        .flatten()
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("step-") && n.as_str() != latest && n.as_str() != on_disk)
        .collect();
    snaps.sort();
    // `latest` itself is excluded above, so keep the newest
    // KEEP_SNAPSHOTS - 1 of the rest.
    let keep = KEEP_SNAPSHOTS.saturating_sub(1);
    let drop_n = snaps.len().saturating_sub(keep);
    for name in snaps.into_iter().take(drop_n) {
        match std::fs::remove_dir_all(root.join(&name)) {
            Ok(()) => {}
            // already gone: lost a benign race with fsck --repair or a
            // peer's prune — removal was the goal either way
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => log::warn!("could not prune old checkpoint {name}: {e}"),
        }
    }
}

/// True if `dir` is a loadable checkpoint: either a direct snapshot or a
/// rotated root with a `LATEST` pointer.
pub fn has_checkpoint(dir: &Path) -> bool {
    dir.join("meta.json").exists() || dir.join("LATEST").exists()
}

/// Resolve a user-supplied path to the concrete snapshot directory:
/// accepts a direct snapshot (`meta.json` present) or a rotated root
/// (follows `LATEST`).
pub fn resolve_checkpoint_dir(dir: &Path) -> Result<PathBuf> {
    if dir.join("meta.json").exists() {
        return Ok(dir.to_path_buf());
    }
    let latest = dir.join("LATEST");
    if latest.exists() {
        let name = std::fs::read_to_string(&latest)
            .with_context(|| format!("reading {}", latest.display()))?;
        let snap = dir.join(name.trim());
        if !snap.join("meta.json").exists() {
            bail!(
                "checkpoint root {} points at '{}' but that snapshot has no meta.json",
                dir.display(),
                name.trim()
            );
        }
        return Ok(snap);
    }
    bail!("no checkpoint at {} (neither meta.json nor LATEST found)", dir.display())
}

/// [`resolve_checkpoint_dir`] with integrity verification and graceful
/// degradation: a direct snapshot must verify; a rotated root first tries
/// the `LATEST` target and, when `LATEST` is torn or its target fails
/// verification, falls back to the newest `step-*` snapshot that passes
/// [`verify_snapshot`]. Errors only when no intact snapshot exists.
pub fn resolve_checkpoint_dir_verified(dir: &Path) -> Result<PathBuf> {
    if dir.join("meta.json").exists() {
        verify_snapshot(dir).with_context(|| format!("checkpoint at {}", dir.display()))?;
        return Ok(dir.to_path_buf());
    }
    let latest = dir.join("LATEST");
    if !latest.exists() {
        bail!("no checkpoint at {} (neither meta.json nor LATEST found)", dir.display());
    }
    let mut tried: Option<String> = None;
    match std::fs::read_to_string(&latest) {
        Ok(name) => {
            let name = name.trim().to_string();
            match verify_snapshot(&dir.join(&name)) {
                Ok(()) => return Ok(dir.join(&name)),
                Err(e) => {
                    log::warn!(
                        "checkpoint root {}: LATEST -> '{}' failed verification ({e:#}); \
                         scanning for the newest intact snapshot",
                        dir.display(),
                        name
                    );
                    tried = Some(name);
                }
            }
        }
        Err(e) => {
            log::warn!(
                "checkpoint root {}: LATEST is unreadable ({e}); \
                 scanning for the newest intact snapshot",
                dir.display()
            );
        }
    }
    let mut snaps: Vec<String> = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .flatten()
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("step-"))
        .collect();
    snaps.sort();
    for name in snaps.iter().rev() {
        if tried.as_deref() == Some(name.as_str()) {
            continue;
        }
        let snap = dir.join(name);
        match verify_snapshot(&snap) {
            Ok(()) => {
                log::warn!(
                    "checkpoint root {}: resuming from intact snapshot '{name}'",
                    dir.display()
                );
                return Ok(snap);
            }
            Err(e) => {
                log::warn!(
                    "checkpoint root {}: snapshot '{name}' failed verification ({e:#})",
                    dir.display()
                );
            }
        }
    }
    bail!(
        "checkpoint root {} has no intact snapshot \
         (LATEST and every step-* candidate failed verification)",
        dir.display()
    )
}

// ------------------------------------------------------------ rng <-> json

fn rng_to_json(r: &Rng) -> Json {
    let (s, spare) = r.snapshot();
    let words: Vec<Json> = s.iter().map(|w| Json::str(format!("{w:016x}"))).collect();
    Json::obj(vec![
        ("s", Json::Arr(words)),
        (
            "spare",
            match spare {
                Some(bits) => Json::str(format!("{bits:016x}")),
                None => Json::Null,
            },
        ),
    ])
}

fn rng_from_json(j: &Json) -> Result<Rng> {
    let words = j.req("s")?.as_arr()?;
    if words.len() != 4 {
        bail!("rng state wants 4 words, got {}", words.len());
    }
    let mut s = [0u64; 4];
    for (slot, w) in s.iter_mut().zip(words) {
        *slot = u64::from_str_radix(w.as_str()?, 16).context("rng state word")?;
    }
    let spare = match j.req("spare")? {
        Json::Null => None,
        v => Some(u64::from_str_radix(v.as_str()?, 16).context("rng spare bits")?),
    };
    Ok(Rng::from_snapshot(s, spare))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, TaskKind};
    use crate::runtime::ParamSpec;
    use crate::tensor::Tensor;

    fn store() -> ParamStore {
        ParamStore {
            specs: vec![
                ParamSpec { name: "a".into(), shape: vec![2, 3], kind: "matrix".into(), compressed: true },
                ParamSpec { name: "b".into(), shape: vec![4], kind: "vector".into(), compressed: false },
            ],
            values: vec![
                Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
                Tensor::full(&[4], 7.0),
            ],
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mlorc_ckpt_{tag}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_and_shape_guard() {
        let dir = tmp("v1");
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let orig = store();
        save_checkpoint(&dir, 42, &cfg, &orig, None).unwrap();
        let mut loaded = store();
        loaded.values[0] = Tensor::zeros(&[2, 3]);
        let step = load_checkpoint(&dir, &mut loaded).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded.values[0], orig.values[0]);
        // shape mismatch must fail loudly
        let mut wrong = store();
        wrong.specs[0].shape = vec![3, 2];
        wrong.values[0] = Tensor::zeros(&[3, 2]);
        assert!(load_checkpoint(&dir, &mut wrong).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v2_load_of_v1_dir_is_a_structured_error() {
        let dir = tmp("v1_as_v2");
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let orig = store();
        save_checkpoint(&dir, 3, &cfg, &orig, None).unwrap();
        let mut loaded = store();
        let err = load_checkpoint_v2(&dir, &mut loaded, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("format v1"), "unhelpful error: {msg}");
        assert!(msg.contains("optimizer state"), "unhelpful error: {msg}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Build a state with given field tensors through the checkpoint
    /// decoder (the registry owns construction now).
    fn state_with(variant: &str, fields: &[(&str, Tensor)]) -> OptState {
        let meta = Json::obj(vec![("variant", Json::str(variant))]);
        OptState::from_ckpt(&meta, |name| {
            fields
                .iter()
                .find(|(f, _)| *f == name)
                .map(|(_, t)| t.clone())
                .ok_or_else(|| anyhow::anyhow!("missing field {name}"))
        })
        .unwrap()
    }

    #[test]
    fn v2_roundtrip_with_opt_state_and_rng() {
        let dir = tmp("v2");
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let orig = store();
        let mut rng = Rng::new(9);
        let mq = rng.gaussian_tensor(&[2, 2], 1.0);
        let state = state_with(
            "mlorc_lion",
            &[("mq", mq.clone()), ("mb", rng.gaussian_tensor(&[2, 3], 1.0))],
        );
        let vstate =
            state_with("adamw", &[("m", Tensor::zeros(&[4])), ("v", Tensor::full(&[4], 0.5))]);
        let mut data_rng = Rng::new(1);
        data_rng.normal(); // advance + populate the Box-Muller spare
        let omega = vec![Rng::new(2), Rng::new(3)];
        let snap = OptSnapshot {
            opt: vec![("a".to_string(), &state), ("b".to_string(), &vstate)],
            rng_data: &data_rng,
            omega: &omega,
        };
        save_checkpoint_v2(&dir, 7, &cfg, &orig, None, &snap).unwrap();

        let mut loaded = store();
        loaded.values[0] = Tensor::zeros(&[2, 3]);
        let back = load_checkpoint_v2(&dir, &mut loaded, None).unwrap();
        assert_eq!(back.step, 7);
        assert_eq!(loaded.values[0], orig.values[0]);
        assert_eq!(back.rng_data.snapshot(), data_rng.snapshot());
        assert_eq!(back.omega.len(), 2);
        assert_eq!(back.omega[1].snapshot(), omega[1].snapshot());
        let got = back.opt.get("a").unwrap();
        assert_eq!(got.variant_name(), "mlorc_lion");
        let fields = got.tensor_fields();
        let (_, q) = fields.iter().find(|(n, _)| *n == "mq").expect("mq field");
        assert_eq!(q.data, mq.data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reused_buffer_commit_is_bitwise_identical_to_sync_save() {
        let dir_sync = tmp("split_sync");
        let dir_async = tmp("split_async");
        let _ = std::fs::remove_dir_all(&dir_sync);
        let _ = std::fs::remove_dir_all(&dir_async);
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let orig = store();
        let mut rng = Rng::new(9);
        let state = state_with(
            "mlorc_lion",
            &[
                ("mq", rng.gaussian_tensor(&[2, 2], 1.0)),
                ("mb", rng.gaussian_tensor(&[2, 3], 1.0)),
            ],
        );
        let vstate =
            state_with("adamw", &[("m", Tensor::zeros(&[4])), ("v", Tensor::full(&[4], 0.5))]);
        let data_rng = Rng::new(1);
        let omega = vec![Rng::new(2), Rng::new(3)];
        let snap = OptSnapshot {
            opt: vec![("a".to_string(), &state), ("b".to_string(), &vstate)],
            rng_data: &data_rng,
            omega: &omega,
        };
        save_checkpoint_v2(&dir_sync, 7, &cfg, &orig, None, &snap).unwrap();

        // Pre-dirty the scratch buffer with a different capture of the
        // same shapes, so the second capture exercises the
        // allocation-reuse (memcpy) path, then commit and compare bytes.
        let mut decoy = store();
        for v in decoy.values.iter_mut() {
            for x in v.data.iter_mut() {
                *x += 100.0;
            }
        }
        let decoy_state = state_with(
            "mlorc_lion",
            &[("mq", Tensor::full(&[2, 2], -1.0)), ("mb", Tensor::full(&[2, 3], -2.0))],
        );
        let decoy_v =
            state_with("adamw", &[("m", Tensor::full(&[4], 9.0)), ("v", Tensor::full(&[4], 8.0))]);
        let decoy_rng = Rng::new(77);
        let decoy_omega = vec![Rng::new(5), Rng::new(6)];
        let decoy_snap = OptSnapshot {
            opt: vec![("a".to_string(), &decoy_state), ("b".to_string(), &decoy_v)],
            rng_data: &decoy_rng,
            omega: &decoy_omega,
        };
        let mut buf = SnapshotBuf::default();
        capture_snapshot(&mut buf, 3, &cfg, &decoy, None, &decoy_snap).unwrap();
        capture_snapshot(&mut buf, 7, &cfg, &orig, None, &snap).unwrap();
        assert_eq!(buf.step(), 7);
        commit_snapshot(&dir_async, &buf).unwrap();

        for f in ["params.rten", "opt_state.rten", "manifest.json", "meta.json"] {
            let a = std::fs::read(dir_sync.join(f)).unwrap();
            let b = std::fs::read(dir_async.join(f)).unwrap();
            assert_eq!(a, b, "{f} differs between sync save and buffered commit");
        }
        std::fs::remove_dir_all(&dir_sync).unwrap();
        std::fs::remove_dir_all(&dir_async).unwrap();
    }

    #[test]
    fn rotation_keeps_latest_and_prunes() {
        let root = tmp("rot");
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let orig = store();
        let rng = Rng::new(0);
        let snap = OptSnapshot { opt: vec![], rng_data: &rng, omega: &[] };
        for step in [5usize, 10, 15] {
            save_checkpoint_v2_rotated(&root, step, &cfg, &orig, None, &snap).unwrap();
        }
        let resolved = resolve_checkpoint_dir(&root).unwrap();
        assert!(resolved.ends_with("step-00000015"));
        assert!(!root.join("step-00000005").exists(), "oldest snapshot not pruned");
        assert!(root.join("step-00000010").exists(), "previous snapshot must be kept");
        let mut loaded = store();
        let back = load_checkpoint_v2(&resolved, &mut loaded, None).unwrap();
        assert_eq!(back.step, 15);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn manifest_verification_and_torn_latest_fallback() {
        let root = tmp("verify");
        let _ = std::fs::remove_dir_all(&root);
        let cfg = RunConfig::new("nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
        let orig = store();
        let rng = Rng::new(0);
        let snap = OptSnapshot { opt: vec![], rng_data: &rng, omega: &[] };
        for step in [5usize, 10] {
            save_checkpoint_v2_rotated(&root, step, &cfg, &orig, None, &snap).unwrap();
        }
        // intact snapshots verify and resolve to the LATEST target
        verify_snapshot(&root.join("step-00000010")).unwrap();
        let resolved = resolve_checkpoint_dir_verified(&root).unwrap();
        assert!(resolved.ends_with("step-00000010"));

        // garbage LATEST: fall back to the newest intact snapshot
        std::fs::write(root.join("LATEST"), b"step-999").unwrap();
        let resolved = resolve_checkpoint_dir_verified(&root).unwrap();
        assert!(resolved.ends_with("step-00000010"));

        // corrupt the newest snapshot's payload: verification catches it
        // and resolution degrades to the previous snapshot
        let victim = root.join("step-00000010/params.rten");
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        assert!(verify_snapshot(&root.join("step-00000010")).is_err());
        std::fs::write(root.join("LATEST"), b"step-00000010").unwrap();
        let resolved = resolve_checkpoint_dir_verified(&root).unwrap();
        assert!(resolved.ends_with("step-00000005"), "{resolved:?}");

        // no intact snapshot left: structured error, not garbage
        std::fs::remove_dir_all(root.join("step-00000005")).unwrap();
        assert!(resolve_checkpoint_dir_verified(&root).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
