//! Layer-3 coordinator — the training orchestrator.
//!
//! Owns the loop: batch -> fwd/bwd graph -> per-layer optimizer step
//! graphs -> metrics/eval/checkpoint. All randomness (init, data, Omega)
//! derives from the run seed; Python never executes here.

mod checkpoint;
mod ckpt_writer;
mod memory;
mod metrics;
mod params;
mod spectral;
mod state;
mod trainer;

pub use checkpoint::{
    capture_snapshot, commit_snapshot, commit_snapshot_rotated, has_checkpoint, load_checkpoint,
    load_checkpoint_v2, load_for_resume, resolve_checkpoint_dir, resolve_checkpoint_dir_verified,
    save_checkpoint, save_checkpoint_v2, save_checkpoint_v2_rotated, verify_snapshot, CheckpointV2,
    OptSnapshot, SnapshotBuf,
};
pub use ckpt_writer::{CkptWriter, CommitOutcome, SCRATCH_BUFFERS};
pub use memory::{MemoryAccountant, MemoryReport};
pub use metrics::{EvalRecord, MetricsLog, StepRecord};
pub use params::ParamStore;
pub use spectral::{SpectralProbe, SpectralRecord};
pub use state::{host_step_all, HostStepJob, OptState};
pub use trainer::{EvalSummary, TrainOutcome, Trainer};
