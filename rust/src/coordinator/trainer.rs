//! The training orchestrator: one `Trainer` drives one run — data, fwd/bwd
//! graph, per-layer optimizer step graphs, eval, metrics, spectral probe.
//!
//! Per-layer weight updates (Lv et al., 2024; paper §3.2.2): gradients are
//! consumed and freed parameter-by-parameter in layer order, so peak
//! gradient residency is one parameter, not the whole model (the memory
//! accountant models both modes; Table 6).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::data::{self, LmDataset};
use crate::linalg::{Rng, Workspace};
use crate::optim::OptHp;
use crate::runtime::{GraphSpec, Preset, Runtime, ValRef};
use crate::tensor::Tensor;

use super::checkpoint::{self, OptSnapshot};
use super::ckpt_writer::CkptWriter;
use super::memory::{MemoryAccountant, MemoryReport};
use super::metrics::{EvalRecord, MetricsLog, StepRecord};
use super::params::ParamStore;
use super::spectral::SpectralProbe;
use super::state::{host_step_all, HostStepJob, OptState};

/// Where a trainable parameter lives.
#[derive(Debug, Clone, Copy)]
enum Store {
    Base(usize),
    Adapter(usize),
}

/// Per-worker `Workspace` retention cap, applied after every host step.
/// Generous next to the tiny-preset scratch high-water mark (~1 MB dense
/// v_t at 512×512) but a hard ceiling against one-off large parameters.
const HOST_WS_TRIM_BYTES: usize = 8 << 20;

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub preset: Preset,
    pub cfg: RunConfig,
    pub params: ParamStore,
    pub adapters: Option<ParamStore>,
    states: Vec<OptState>,
    trainable: Vec<Store>,
    lm_data: Option<Box<dyn LmDataset>>,
    cls_data: Option<crate::data::SynGlueTask>,
    rng_data: Rng,
    /// One Omega stream per trainable parameter: draws are independent of
    /// the order parameters are stepped in, which is what lets the host
    /// path fan updates out over threads bit-identically to sequential.
    omega_streams: Vec<Rng>,
    /// Per-worker scratch pools for host-side stepping.
    host_ws: Vec<Workspace>,
    pub metrics: MetricsLog,
    pub probe: Option<SpectralProbe>,
    step: usize,
    fwd_spec: GraphSpec,
    eval_spec: GraphSpec,
}

#[derive(Debug, Clone)]
pub struct EvalSummary {
    pub loss: f32,
    pub accuracy: f32,
    pub exact_match: f32,
}

#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub final_loss: f32,
    pub eval: Option<EvalSummary>,
    pub wall_secs: f64,
    pub memory_measured: MemoryReport,
    pub memory_analytic: MemoryReport,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, preset: &Preset, mut cfg: RunConfig) -> Result<Trainer<'rt>> {
        // Normalize once so both the graph path and the host path see a
        // sane refresh cadence (freq 0 would be a div-by-zero at use).
        cfg.galore_update_freq = cfg.galore_update_freq.max(1);
        // Registry combos without lowered step graphs are host-only for
        // now; fail at construction instead of at the first step.
        if !cfg.method.desc().graphed && !cfg.host_opt {
            bail!(
                "method '{}' has no lowered step graphs yet — run it with --host-opt \
                 (host stepping) or through the serve host engine",
                cfg.method.name()
            );
        }
        let mut rng = Rng::new(cfg.seed);
        let mut init_rng = rng.split(1);
        let rng_data = rng.split(2);
        let mut rng_omega = rng.split(3);

        let is_cls = cfg.task.is_classification();
        let is_lora = cfg.method.is_lora();
        let params = ParamStore::init(preset, is_cls, &mut init_rng);
        let adapters = if is_lora {
            Some(ParamStore::init_lora(preset, &mut init_rng))
        } else {
            None
        };

        // Trainable set = what the fwd/bwd graph returns gradients for,
        // in exactly its output order.
        let mut trainable = Vec::new();
        if is_lora {
            if is_cls {
                // cls_lora_fwd_bwd: loss, g:cls_head, g:adapters...
                let head_idx = params
                    .specs
                    .iter()
                    .position(|s| s.kind == "head")
                    .context("preset has no cls head")?;
                trainable.push(Store::Base(head_idx));
            }
            for i in 0..adapters.as_ref().unwrap().len() {
                trainable.push(Store::Adapter(i));
            }
        } else {
            for i in 0..params.len() {
                trainable.push(Store::Base(i));
            }
        }

        // Optimizer state per trainable param.
        let mut states = Vec::with_capacity(trainable.len());
        for st in &trainable {
            let spec = match st {
                Store::Base(i) => &params.specs[*i],
                Store::Adapter(i) => &adapters.as_ref().unwrap().specs[*i],
            };
            states.push(OptState::for_param_cfg(
                cfg.method,
                spec,
                preset.model.l(),
                cfg.rank_min,
            )?);
        }

        // Independent per-parameter Omega streams (see field docs).
        let omega_streams: Vec<Rng> =
            (0..trainable.len()).map(|i| rng_omega.split(i as u64 + 1)).collect();
        let pool = if cfg.opt_threads > 0 {
            cfg.opt_threads
        } else {
            crate::linalg::threads::budget()
        };
        let host_ws: Vec<Workspace> = (0..pool.max(1)).map(|_| Workspace::new()).collect();

        let graph_name = match (is_cls, is_lora) {
            (false, false) => "fwd_bwd",
            (false, true) => "lora_fwd_bwd",
            (true, false) => "cls_fwd_bwd",
            (true, true) => "cls_lora_fwd_bwd",
        };
        let eval_name = match (is_cls, is_lora) {
            (false, false) => "eval",
            (false, true) => "lora_eval",
            (true, false) => "cls_eval",
            (true, true) => "cls_lora_eval",
        };
        let fwd_spec = preset.graph(graph_name)?.clone();
        let eval_spec = preset.graph(eval_name)?.clone();

        let (lm_data, cls_data) = if is_cls {
            (None, Some(data::cls_dataset(cfg.task, preset.model.seq, cfg.seed)))
        } else {
            (Some(data::lm_dataset(cfg.task, preset.model.seq, cfg.seed)), None)
        };

        let probe = if cfg.spectral_every > 0 {
            let names: Vec<String> = params.specs.iter().map(|s| s.name.clone()).collect();
            Some(SpectralProbe::default_for(&names))
        } else {
            None
        };

        let mut metrics = MetricsLog::new(&format!(
            "{}_{}_{}",
            cfg.preset,
            cfg.method.name(),
            cfg.task.name()
        ));
        metrics.config = Some(cfg.to_json());

        Ok(Trainer {
            rt,
            preset: preset.clone(),
            cfg,
            params,
            adapters,
            states,
            trainable,
            lm_data,
            cls_data,
            rng_data,
            omega_streams,
            host_ws,
            metrics,
            probe,
            step: 0,
            fwd_spec,
            eval_spec,
        })
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Total adaptive-rank shrink events across all parameter states (0
    /// for fixed-rank layouts) — surfaced by `mlorc status`.
    pub fn opt_shrink_events(&self) -> usize {
        self.states.iter().map(|s| s.shrink_events()).sum()
    }

    /// Write a full v2 snapshot (params, every `OptState`, RNG stream
    /// positions, step count) into the rotated checkpoint root `dir`.
    pub fn save_full_checkpoint(&self, dir: &Path) -> Result<()> {
        let opt: Vec<(String, &OptState)> = (0..self.trainable.len())
            .map(|i| (self.trainable_spec(i).name.clone(), &self.states[i]))
            .collect();
        let snap = OptSnapshot { opt, rng_data: &self.rng_data, omega: &self.omega_streams };
        checkpoint::save_checkpoint_v2_rotated(
            dir,
            self.step,
            &self.cfg,
            &self.params,
            self.adapters.as_ref(),
            &snap,
        )?;
        Ok(())
    }

    /// The step-path half of an async save: copy the full v2 snapshot
    /// state into `buf` for a [`CkptWriter`](super::CkptWriter) to
    /// commit in the background. Same capture `save_full_checkpoint`
    /// runs inline, so the bytes on disk are bit-identical either way.
    pub fn capture_snapshot(&self, buf: &mut checkpoint::SnapshotBuf) -> Result<()> {
        let opt: Vec<(String, &OptState)> = (0..self.trainable.len())
            .map(|i| (self.trainable_spec(i).name.clone(), &self.states[i]))
            .collect();
        let snap = OptSnapshot { opt, rng_data: &self.rng_data, omega: &self.omega_streams };
        checkpoint::capture_snapshot(
            buf,
            self.step,
            &self.cfg,
            &self.params,
            self.adapters.as_ref(),
            &snap,
        )
    }

    /// Resume this trainer from a v2 checkpoint (direct snapshot dir or
    /// rotated root): restores params/adapters, every optimizer state,
    /// the data + Omega RNG stream positions and the step count, so the
    /// continued run is bit-identical to one that was never interrupted.
    /// Returns the restored step count.
    pub fn resume_from(&mut self, dir: &Path) -> Result<usize> {
        let ck = checkpoint::load_for_resume(
            dir,
            &self.cfg,
            &mut self.params,
            self.adapters.as_mut(),
            self.omega_streams.len(),
        )?;
        for i in 0..self.trainable.len() {
            let name = self.trainable_spec(i).name.clone();
            match ck.opt.get(&name) {
                Some(st) => self.states[i] = st.clone(),
                None => bail!("checkpoint missing optimizer state for '{name}'"),
            }
        }
        self.omega_streams = ck.omega;
        self.rng_data = ck.rng_data;
        self.step = ck.step;
        Ok(ck.step)
    }

    fn trainable_spec(&self, i: usize) -> &crate::runtime::ParamSpec {
        match self.trainable[i] {
            Store::Base(j) => &self.params.specs[j],
            Store::Adapter(j) => &self.adapters.as_ref().unwrap().specs[j],
        }
    }

    fn trainable_value(&self, i: usize) -> &Tensor {
        match self.trainable[i] {
            Store::Base(j) => &self.params.values[j],
            Store::Adapter(j) => &self.adapters.as_ref().unwrap().values[j],
        }
    }

    fn set_trainable_value(&mut self, i: usize, t: Tensor) {
        match self.trainable[i] {
            Store::Base(j) => self.params.values[j] = t,
            Store::Adapter(j) => self.adapters.as_mut().unwrap().values[j] = t,
        }
    }

    /// Graph inputs: (tokens, targets/labels, *base[, *adapters]).
    fn graph_inputs<'a>(
        &'a self,
        tokens: &'a crate::tensor::TensorI32,
        second: &'a crate::tensor::TensorI32,
    ) -> Vec<ValRef<'a>> {
        let mut inputs: Vec<ValRef> =
            Vec::with_capacity(2 + self.params.len() + self.adapters.as_ref().map_or(0, |a| a.len()));
        inputs.push(tokens.into());
        inputs.push(second.into());
        for v in &self.params.values {
            inputs.push(v.into());
        }
        if let Some(a) = &self.adapters {
            for v in &a.values {
                inputs.push(v.into());
            }
        }
        inputs
    }

    /// One training step. Returns the minibatch loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let t0 = Instant::now();
        let dims = self.preset.model;
        let step = self.step;
        let lr = self.cfg.peak_lr * self.cfg.schedule.factor(step);

        // ---- batch + fwd/bwd ------------------------------------------
        let (tokens, second, batch_lm) = if let Some(ds) = &self.lm_data {
            let b = data::batcher::make_lm_batch(ds.as_ref(), dims.batch, &mut self.rng_data);
            (b.tokens.clone(), b.targets.clone(), Some(b))
        } else {
            let ds = self.cls_data.as_ref().unwrap();
            let b = data::batcher::make_cls_batch(ds, dims.batch, &mut self.rng_data);
            (b.tokens.clone(), b.labels.clone(), None)
        };
        let _ = batch_lm; // answer regions only needed at eval time
        let fwd_t0 = Instant::now();
        let g = self.rt.load(&self.fwd_spec)?;
        let inputs = self.graph_inputs(&tokens, &second);
        let mut outs = self.rt.execute_refs(&g, &inputs)?;
        drop(inputs);
        let fwd_secs = fwd_t0.elapsed().as_secs_f64();
        let loss = outs[0].scalar()?;
        if !loss.is_finite() {
            bail!("loss diverged (non-finite) at step {step} — lower the learning rate");
        }
        let grads: Vec<Tensor> = outs
            .drain(1..)
            .map(|v| v.into_f32())
            .collect::<Result<Vec<_>>>()?;
        if grads.len() != self.trainable.len() {
            bail!("graph returned {} grads for {} trainables", grads.len(), self.trainable.len());
        }

        // ---- spectral probe (before the state mutates) -----------------
        let probe_now = self
            .probe
            .as_ref()
            .map(|_| self.cfg.spectral_every > 0 && step % self.cfg.spectral_every == 0)
            .unwrap_or(false);
        if probe_now {
            self.record_spectral(step, &grads)?;
        }

        // ---- per-layer optimizer updates -------------------------------
        let opt_t0 = Instant::now();
        if self.cfg.host_opt {
            // Host stepping: all states update through the rust reference
            // mirrors, batched by shape class across the worker pool.
            // Trades per-layer gradient residency for parallelism; results
            // are bit-identical to stepping sequentially (per-parameter
            // Omega streams).
            self.apply_updates_host(&grads, lr, step)?;
        } else {
            // Consume gradients in order, freeing each after its update —
            // the per-layer weight update schedule.
            let mut grads = grads.into_iter();
            for i in 0..self.trainable.len() {
                let grad = grads.next().unwrap();
                self.apply_update(i, grad, lr, step)?;
                // grad dropped here (per-layer residency)
            }
        }
        let opt_secs = opt_t0.elapsed().as_secs_f64();

        self.step += 1;
        self.metrics.fwd_bwd_secs += fwd_secs;
        self.metrics.opt_secs += opt_secs;
        self.metrics.steps.push(StepRecord {
            step,
            loss,
            lr,
            millis: t0.elapsed().as_secs_f64() * 1e3,
        });
        Ok(loss)
    }

    /// Update one trainable parameter via its step graph.
    ///
    /// The input/output layout is variant-generic (no per-method match):
    /// inputs are `w, grad, <state fields in declared order>, <omega
    /// draws>, lr[, c1, c2]` (the bias-correction scalars only for
    /// bias-corrected rules), outputs are `w'` followed by the state
    /// fields the graph updates — exactly the convention every lowered
    /// step graph already follows.
    fn apply_update(&mut self, i: usize, grad: Tensor, lr: f32, step: usize) -> Result<()> {
        let spec = self.trainable_spec(i).clone();
        // Perf (§Perf L3): 1-D parameters are a few hundred floats — a PJRT
        // dispatch costs more than the math. Update them host-side with the
        // cross-validated rust mirror of the same step.
        if spec.shape.len() == 1 {
            return self.apply_vector_update_host(i, &grad, lr, step);
        }
        if self.states[i].is_frozen() {
            return Ok(());
        }
        let key = spec.shape_key();
        let method = self.states[i].step_method()?;
        let sg = self.preset.opt_step(method, &key)?.clone();
        let hp = OptHp::from_json(&sg.hparams);
        let t = (step + 1) as i32;
        let c1 = 1.0 / (1.0 - hp.beta1.powi(t));
        let c2 = 1.0 / (1.0 - hp.beta2.powi(t));
        let lr_t = Tensor::scalar(lr);
        let c1_t = Tensor::scalar(c1);
        let c2_t = Tensor::scalar(c2);
        let l = self.preset.model.l();

        // GaLore projector refresh on schedule (its own graph; the step
        // graph treats `p` as a constant).
        let refresh_left = match self.states[i].galore_mut() {
            Some(gal) => {
                if !gal.refreshed || step % self.cfg.galore_update_freq == 0 {
                    Some(gal.left)
                } else {
                    None
                }
            }
            None => None,
        };
        if let Some(left) = refresh_left {
            let proj_spec = self.preset.opt_step("galore_project", &key)?.clone();
            let om_shape = if left {
                [spec.shape[1], l]
            } else {
                [spec.shape[0], l]
            };
            let om = self.omega_streams[i].gaussian_tensor(&om_shape, 1.0);
            let outs = self
                .rt
                .run_refs(&proj_spec, &[(&grad).into(), (&om).into()])?;
            let gal = self.states[i].galore_mut().expect("layout cannot change mid-step");
            gal.p = outs.into_iter().next().unwrap().into_f32()?;
            gal.refreshed = true;
        }

        // Pre-draw the Gaussian test matrices this state's graph takes
        // (the RNG is a disjoint field, but `trainable_value` borrows all
        // of self).
        let omegas: Vec<Tensor> = {
            let shapes = self.states[i].omega_graph_shapes();
            let stream = &mut self.omega_streams[i];
            shapes.iter().map(|s| stream.gaussian_tensor(s, 1.0)).collect()
        };

        // Assemble inputs per the step-graph convention and execute.
        let w = self.trainable_value(i);
        let state = &self.states[i];
        let mut inputs: Vec<ValRef> = Vec::with_capacity(4 + 6 + omegas.len());
        inputs.push(w.into());
        inputs.push((&grad).into());
        for (_, tensor) in state.tensor_fields() {
            inputs.push(tensor.into());
        }
        for om in &omegas {
            inputs.push(om.into());
        }
        inputs.push((&lr_t).into());
        if state.bias_corrected() {
            inputs.push((&c1_t).into());
            inputs.push((&c2_t).into());
        }
        let outs = self.rt.run_refs(&sg, &inputs)?;
        drop(inputs);

        // Scatter outputs back: w', then the graph-updated fields in
        // declared order.
        let mut it = outs.into_iter();
        let w_new = it.next().context("step graph returned nothing")?.into_f32()?;
        self.set_trainable_value(i, w_new);
        for (name, slot) in self.states[i].graph_output_fields_mut() {
            *slot = it
                .next()
                .with_context(|| format!("step graph '{method}' missing output '{name}'"))?
                .into_f32()?;
        }
        Ok(())
    }

    /// Host stepping: update every trainable parameter through the rust
    /// reference optimizers, planned into shape classes and batched over
    /// the worker pool (`host_step_all`). Each job owns its parameter
    /// tensor, state and Omega stream and borrows its gradient, so the
    /// schedule cannot change results (asserted by
    /// `tests/host_parallel.rs`).
    fn apply_updates_host(&mut self, grads: &[Tensor], lr: f32, step: usize) -> Result<()> {
        let t = step + 1;
        let galore_refresh_due = step % self.cfg.galore_update_freq == 0;
        let Trainer { params, adapters, states, omega_streams, trainable, host_ws, .. } = self;
        // GaLore projector cadence, mirroring the graph path: a stale
        // projector makes `host_step` re-derive P from this step's
        // gradient (no-op for layouts without one).
        if galore_refresh_due {
            for state in states.iter_mut() {
                state.invalidate_projector();
            }
        }
        let mut base_refs: Vec<Option<&mut Tensor>> =
            params.values.iter_mut().map(Some).collect();
        let mut adapter_refs: Vec<Option<&mut Tensor>> = match adapters {
            Some(a) => a.values.iter_mut().map(Some).collect(),
            None => Vec::new(),
        };
        let mut jobs: Vec<HostStepJob> = Vec::with_capacity(states.len());
        let zipped = states
            .iter_mut()
            .zip(omega_streams.iter_mut())
            .zip(trainable.iter())
            .zip(grads.iter());
        for (((state, rng), store), grad) in zipped {
            if state.is_frozen() {
                continue;
            }
            let w = match store {
                Store::Base(j) => base_refs[*j].take().expect("base param stepped twice"),
                Store::Adapter(j) => {
                    adapter_refs[*j].take().expect("adapter param stepped twice")
                }
            };
            jobs.push(HostStepJob { w, grad, state, rng, lr, t });
        }
        host_step_all(&mut jobs, host_ws)?;
        // Bound scratch retention: the pools keep their largest buffers
        // (e.g. the dense v_t of the biggest parameter) between steps;
        // trim so a one-off large tensor cannot pin memory forever.
        for ws in host_ws.iter_mut() {
            ws.trim(HOST_WS_TRIM_BYTES);
        }
        Ok(())
    }

    /// Host-side update for 1-D params (same math as the plain step
    /// graphs; agreement enforced by `optim` unit tests + cross-validation).
    /// Plain states are `Dense` layouts, so `host_step` draws nothing from
    /// the Omega stream — identical stream schedule to the graph path.
    fn apply_vector_update_host(&mut self, i: usize, g: &Tensor, lr: f32, step: usize) -> Result<()> {
        let t = step + 1;
        let mut w = match self.trainable[i] {
            Store::Base(j) => std::mem::replace(&mut self.params.values[j], Tensor::zeros(&[0])),
            Store::Adapter(j) => {
                std::mem::replace(&mut self.adapters.as_mut().unwrap().values[j], Tensor::zeros(&[0]))
            }
        };
        {
            let Trainer { states, omega_streams, host_ws, .. } = self;
            states[i].host_step(&mut w, g, lr, t, &mut omega_streams[i], &mut host_ws[0])?;
        }
        self.set_trainable_value(i, w);
        Ok(())
    }

    fn record_spectral(&mut self, step: usize, grads: &[Tensor]) -> Result<()> {
        let Some(probe) = &self.probe else { return Ok(()) };
        let mut entries = Vec::new();
        for (i, st) in self.trainable.iter().enumerate() {
            let spec = match st {
                Store::Base(j) => &self.params.specs[*j],
                Store::Adapter(j) => &self.adapters.as_ref().unwrap().specs[*j],
            };
            if probe.tracked().contains(&spec.name) {
                entries.push((
                    grads[i].clone(),
                    self.states[i].first_moment(),
                    self.states[i].second_moment(),
                ));
            }
        }
        if !entries.is_empty() {
            let rec = probe.record(step, &entries);
            log::debug!(
                "spectral step {step}: g={:.3} m={:.3} v={:.3}",
                rec.grad_ratio,
                rec.m_ratio,
                rec.v_ratio
            );
            self.metrics.spectral.push(rec);
        }
        Ok(())
    }

    /// Held-out evaluation over `cfg.eval_batches` batches.
    pub fn evaluate(&mut self) -> Result<EvalSummary> {
        let dims = self.preset.model;
        let mut rng = data::eval_rng(self.cfg.seed ^ (self.step as u64));
        let g = self.rt.load(&self.eval_spec)?;
        let mut loss_sum = 0.0f32;
        let mut acc_sum = 0.0f32;
        let mut em_sum = 0.0f32;
        let n = self.cfg.eval_batches.max(1);
        for _ in 0..n {
            if let Some(ds) = &self.lm_data {
                let b = data::batcher::make_lm_batch(ds.as_ref(), dims.batch, &mut rng);
                let inputs = self.graph_inputs(&b.tokens, &b.targets);
                let outs = self.rt.execute_refs(&g, &inputs)?;
                loss_sum += outs[0].scalar()?;
                let mask = outs[1].as_f32()?;
                acc_sum += data::batcher::token_accuracy(&b, mask);
                em_sum += data::batcher::exact_match(&b, mask);
            } else {
                let ds = self.cls_data.as_ref().unwrap();
                let b = data::batcher::make_cls_batch(ds, dims.batch, &mut rng);
                let inputs = self.graph_inputs(&b.tokens, &b.labels);
                let outs = self.rt.execute_refs(&g, &inputs)?;
                loss_sum += outs[0].scalar()?;
                let correct = outs[1].as_f32()?;
                let acc = correct.data.iter().sum::<f32>() / correct.len() as f32;
                acc_sum += acc;
                em_sum += acc;
            }
        }
        let summary = EvalSummary {
            loss: loss_sum / n as f32,
            accuracy: acc_sum / n as f32,
            exact_match: em_sum / n as f32,
        };
        self.metrics.evals.push(EvalRecord {
            step: self.step,
            loss: summary.loss,
            accuracy: summary.accuracy,
            exact_match: summary.exact_match,
        });
        Ok(summary)
    }

    /// Measured memory report from live state.
    pub fn memory_measured(&self) -> MemoryReport {
        let grads_all: usize = (0..self.trainable.len())
            .map(|i| self.trainable_spec(i).numel() * 4)
            .sum();
        let grads_max: usize = (0..self.trainable.len())
            .map(|i| self.trainable_spec(i).numel() * 4)
            .max()
            .unwrap_or(0);
        let analytic = MemoryAccountant::analytic(
            &self.preset,
            self.cfg.method,
            self.cfg.per_layer_updates,
            self.cfg.task.is_classification(),
        );
        MemoryReport {
            method: self.cfg.method.name().to_string(),
            weights_bytes: self.params.total_bytes()
                + self.adapters.as_ref().map_or(0, |a| a.total_bytes()),
            opt_state_bytes: self.states.iter().map(|s| s.state_bytes()).sum(),
            grads_peak_bytes: if self.cfg.per_layer_updates { grads_max } else { grads_all },
            activations_bytes: analytic.activations_bytes,
            lora_extra_weights_bytes: 0, // adapters counted in weights above
        }
    }

    /// Full training run with logging/eval cadence; returns the outcome.
    pub fn train(&mut self) -> Result<TrainOutcome> {
        self.train_with_checkpoints(0, None)
    }

    /// [`Trainer::train`] with a periodic checkpoint hook: every `every`
    /// steps (0 = off) a full v2 snapshot goes into the rotated root
    /// `ckpt_root`; a final snapshot is always written when a root is
    /// given. Starts from the current step, so a resumed trainer
    /// continues instead of restarting. Cadence saves run through the
    /// async double-buffered writer (bit-identical to inline saves).
    pub fn train_with_checkpoints(
        &mut self,
        every: usize,
        ckpt_root: Option<&Path>,
    ) -> Result<TrainOutcome> {
        self.train_with_checkpoint_mode(every, ckpt_root, false)
    }

    /// [`Trainer::train_with_checkpoints`] with the cadence writer mode
    /// explicit: `sync` forces the old inline path (the CLI's
    /// `--checkpoint-sync` escape hatch). In async mode the step loop
    /// only pays the snapshot capture; commits run on the background
    /// writer thread, whose errors surface at the next cadence or at the
    /// hard join before the final (always inline) snapshot.
    pub fn train_with_checkpoint_mode(
        &mut self,
        every: usize,
        ckpt_root: Option<&Path>,
        sync: bool,
    ) -> Result<TrainOutcome> {
        let mut writer = match (ckpt_root, every > 0 && !sync) {
            (Some(root), true) => Some(CkptWriter::new(root)),
            _ => None,
        };
        let t0 = Instant::now();
        let total = self.cfg.steps;
        let start = self.step;
        let mut last_eval = None;
        for s in start..total {
            let loss = self.train_step()?;
            if self.cfg.log_every > 0 && s % self.cfg.log_every == 0 {
                log::info!(
                    "[{}] step {s}/{total} loss {loss:.4} lr {:.2e}",
                    self.metrics.run_name,
                    self.cfg.peak_lr * self.cfg.schedule.factor(s),
                );
            }
            if let Some(root) = ckpt_root {
                if every > 0 && (s + 1) % every == 0 && s + 1 < total {
                    match writer.as_mut() {
                        Some(w) => {
                            for oc in w.submit(|b| self.capture_snapshot(b))? {
                                oc.dir?;
                            }
                            for oc in w.drain() {
                                oc.dir?;
                            }
                        }
                        None => self.save_full_checkpoint(root)?,
                    }
                }
            }
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                let ev = self.evaluate()?;
                log::info!(
                    "[{}] eval @ {s}: loss {:.4} acc {:.3} em {:.3}",
                    self.metrics.run_name,
                    ev.loss,
                    ev.accuracy,
                    ev.exact_match
                );
                last_eval = Some(ev);
            }
        }
        // hard join before the final inline snapshot: a writer-thread
        // failure must fail the run, not vanish with the writer
        if let Some(w) = writer.as_mut() {
            for oc in w.join()? {
                oc.dir?;
            }
        }
        drop(writer);
        if let Some(root) = ckpt_root {
            self.save_full_checkpoint(root)?;
        }
        if self.cfg.eval_every == 0 || total % self.cfg.eval_every.max(1) != 0 {
            last_eval = Some(self.evaluate()?);
        }
        self.metrics.wall_secs = t0.elapsed().as_secs_f64();
        self.metrics.memory = Some(self.memory_measured());
        Ok(TrainOutcome {
            final_loss: self.metrics.smoothed_final_loss(10).unwrap_or(f32::NAN),
            eval: last_eval,
            wall_secs: self.metrics.wall_secs,
            memory_measured: self.memory_measured(),
            memory_analytic: MemoryAccountant::analytic(
                &self.preset,
                self.cfg.method,
                self.cfg.per_layer_updates,
                self.cfg.task.is_classification(),
            ),
        })
    }
}
