//! Parameter storage and initialization.
//!
//! Order always follows the manifest param table — the same order the
//! fwd/bwd graph inputs and gradient outputs use.

use anyhow::Result;

use crate::linalg::Rng;
use crate::runtime::{ParamSpec, Preset};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct ParamStore {
    pub specs: Vec<ParamSpec>,
    pub values: Vec<Tensor>,
}

impl ParamStore {
    /// Initialize per the documented scheme (mirrors model.init_params):
    /// N(0, 0.02) for matrices/embeddings, residual-out projections (wo,
    /// w2) scaled by 1/sqrt(2 L), LN gains 1, LN biases 0.
    pub fn init(preset: &Preset, with_head: bool, rng: &mut Rng) -> ParamStore {
        let n_layers = preset.model.n_layers as f32;
        let mut specs = Vec::new();
        let mut values = Vec::new();
        for p in &preset.params {
            if p.kind == "head" && !with_head {
                continue;
            }
            let t = if p.kind == "vector" {
                if p.name.ends_with("_g") {
                    Tensor::full(&p.shape, 1.0)
                } else {
                    Tensor::zeros(&p.shape)
                }
            } else {
                let mut scale = 0.02;
                if p.name.ends_with(".wo") || p.name.ends_with(".w2") {
                    scale /= (2.0 * n_layers).sqrt();
                }
                rng.gaussian_tensor(&p.shape, scale)
            };
            specs.push(p.clone());
            values.push(t);
        }
        ParamStore { specs, values }
    }

    /// LoRA adapters: A ~ N(0, 0.02), B = 0 (Hu et al., 2022).
    pub fn init_lora(preset: &Preset, rng: &mut Rng) -> ParamStore {
        let mut specs = Vec::new();
        let mut values = Vec::new();
        for p in &preset.lora_params {
            let t = if p.name.ends_with("lora_B") {
                Tensor::zeros(&p.shape)
            } else {
                rng.gaussian_tensor(&p.shape, 0.02)
            };
            specs.push(p.clone());
            values.push(t);
        }
        ParamStore { specs, values }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn total_bytes(&self) -> usize {
        self.values.iter().map(|t| t.size_bytes()).sum()
    }

    pub fn n_params(&self) -> usize {
        self.values.iter().map(|t| t.len()).sum()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        let i = self
            .specs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("no param '{name}'"))?;
        Ok(&self.values[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::util::fsutil;

    fn nano_preset() -> Option<Preset> {
        let dir = fsutil::artifacts_dir().ok()?;
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Manifest::load(&dir).ok()?.preset("nano").ok().cloned()
    }

    #[test]
    fn init_matches_manifest_counts() {
        let Some(preset) = nano_preset() else { return };
        let mut rng = Rng::new(0);
        let store = ParamStore::init(&preset, false, &mut rng);
        assert_eq!(store.len(), preset.lm_params().len());
        assert_eq!(store.n_params(), preset.model.n_params());
        let with_head = ParamStore::init(&preset, true, &mut Rng::new(0));
        assert_eq!(with_head.len(), store.len() + 1);
    }

    #[test]
    fn ln_gains_one_biases_zero_lora_b_zero() {
        let Some(preset) = nano_preset() else { return };
        let mut rng = Rng::new(0);
        let store = ParamStore::init(&preset, false, &mut rng);
        let g = store.get("blk0.ln1_g").unwrap();
        assert!(g.data.iter().all(|&x| x == 1.0));
        let b = store.get("blk0.ln1_b").unwrap();
        assert!(b.data.iter().all(|&x| x == 0.0));
        let lora = ParamStore::init_lora(&preset, &mut rng);
        let bzero = lora.get("blk0.wq.lora_B").unwrap();
        assert!(bzero.data.iter().all(|&x| x == 0.0));
        let a = lora.get("blk0.wq.lora_A").unwrap();
        assert!(a.norm_fro() > 0.0);
    }

    #[test]
    fn residual_projections_scaled_down() {
        let Some(preset) = nano_preset() else { return };
        let mut rng = Rng::new(0);
        let store = ParamStore::init(&preset, false, &mut rng);
        let wq = store.get("blk0.wq").unwrap();
        let wo = store.get("blk0.wo").unwrap();
        let sq = wq.norm_fro() / (wq.len() as f32).sqrt();
        let so = wo.norm_fro() / (wo.len() as f32).sqrt();
        assert!(so < sq * 0.8, "wo rms {so} vs wq rms {sq}");
    }
}
