//! LDAdamW reference (Robert et al., 2024, simplified per DESIGN.md):
//! per-step projector from the error-compensated gradient, rotation-aware
//! low-dimensional Adam state, full-size error-feedback buffer.
//!
//! The step math lives in the free function [`ldadamw_core`], shared
//! verbatim by the reference state struct below and the coordinator's
//! host stepping (`OptState::host_step`).

use crate::linalg::{matmul, matmul_a_bt, matmul_at_b, mgs_qr, Rng};
use crate::tensor::Tensor;

use super::{bias_corrections, OptHp};

/// One LDAdamW step on raw state tensors. Draws the per-step Gaussian
/// test matrix for the fresh projector from `rng`; `l` is the projector
/// rank (p has `l` columns), `t` is 1-based.
#[allow(clippy::too_many_arguments)]
pub fn ldadamw_core(
    w: &mut Tensor,
    g: &Tensor,
    p: &mut Tensor,
    m_lo: &mut Tensor,
    v_lo: &mut Tensor,
    e: &mut Tensor,
    left: bool,
    l: usize,
    t: usize,
    lr: f32,
    hp: &OptHp,
    rng: &mut Rng,
) {
    let (m, n) = g.dims2().unwrap();
    // error-compensated gradient
    let mut a = g.clone();
    a.axpy(1.0, e, 1.0);
    // fresh projector from a's range
    let p_new = if left {
        let om = rng.gaussian_tensor(&[n, l], 1.0);
        mgs_qr(&matmul(&a, &om))
    } else {
        let om = rng.gaussian_tensor(&[m, l], 1.0);
        mgs_qr(&matmul_at_b(&a, &om))
    };
    let rot = matmul_at_b(&p_new, p); // (l, l)
    let r = if left { matmul_at_b(&p_new, &a) } else { matmul(&a, &p_new) };
    // rotate old state into the new basis
    let m_rot = if left { matmul(&rot, m_lo) } else { matmul_a_bt(m_lo, &rot) };
    let v_rot = if left { matmul(&rot, v_lo) } else { matmul_a_bt(v_lo, &rot) };
    for ((mi, mr), ri) in m_lo.data.iter_mut().zip(&m_rot.data).zip(&r.data) {
        *mi = hp.beta1 * mr + (1.0 - hp.beta1) * ri;
    }
    for ((vi, vr), ri) in v_lo.data.iter_mut().zip(&v_rot.data).zip(&r.data) {
        *vi = hp.beta2 * vr.abs() + (1.0 - hp.beta2) * ri * ri;
    }
    // error feedback: what the projection dropped (a is dead past here,
    // so it becomes the new buffer instead of being cloned)
    let recon = if left { matmul(&p_new, &r) } else { matmul_a_bt(&r, &p_new) };
    *e = a;
    e.axpy(-1.0, &recon, 1.0);
    *p = p_new;
    // update
    let (c1, c2) = bias_corrections(hp, t);
    let mut nhat = m_lo.clone();
    for (ni, vi) in nhat.data.iter_mut().zip(&v_lo.data) {
        *ni = (*ni * c1) / ((vi * c2).sqrt() + hp.eps);
    }
    let full = if left { matmul(p, &nhat) } else { matmul_a_bt(&nhat, p) };
    for (wi, fi) in w.data.iter_mut().zip(&full.data) {
        *wi -= lr * (fi + hp.weight_decay * *wi);
    }
}

#[derive(Debug, Clone)]
pub struct LdAdamWState {
    pub p: Tensor,
    pub m_lo: Tensor,
    pub v_lo: Tensor,
    /// full-size error feedback — the memory cost Table 3 exposes
    pub e: Tensor,
    pub left: bool,
    pub l: usize,
    pub t: usize,
}

impl LdAdamWState {
    pub fn new(shape: &[usize], l: usize) -> LdAdamWState {
        let (m, n) = (shape[0], shape[1]);
        let left = m <= n;
        let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
        LdAdamWState {
            p: {
                // start from a valid orthonormal basis so rotations are
                // well-defined at t=1
                let mut t = Tensor::zeros(&pshape);
                for i in 0..l.min(pshape[0]) {
                    t.set2(i, i, 1.0);
                }
                t
            },
            m_lo: Tensor::zeros(&rshape),
            v_lo: Tensor::zeros(&rshape),
            e: Tensor::zeros(shape),
            left,
            l,
            t: 0,
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.p.size_bytes() + self.m_lo.size_bytes() + self.v_lo.size_bytes() + self.e.size_bytes()
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        self.t += 1;
        ldadamw_core(
            w,
            g,
            &mut self.p,
            &mut self.m_lo,
            &mut self.v_lo,
            &mut self.e,
            self.left,
            self.l,
            self.t,
            lr,
            hp,
            rng,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_feedback_identity() {
        // a = P r + e' exactly (projection split)
        let hp = OptHp::adamw();
        let mut rng = Rng::new(0);
        let mut st = LdAdamWState::new(&[10, 20], 4);
        let g = rng.gaussian_tensor(&[10, 20], 1.0);
        let mut w = Tensor::zeros(&[10, 20]);
        st.step(&mut w, &g, 1e-3, &hp, &mut rng);
        // after first step e0 = 0, so a = g; recon + e' must equal g
        let r = matmul_at_b(&st.p, &g);
        let mut recon = matmul(&st.p, &r);
        recon.axpy(1.0, &st.e, 1.0);
        assert!(recon.rel_err(&g) < 1e-4, "rel {}", recon.rel_err(&g));
    }

    #[test]
    fn error_accumulates_then_compensates() {
        // with error feedback, the *cumulative* update approaches the
        // cumulative projected-plus-residual gradient; just check e stays
        // bounded rather than exploding
        let hp = OptHp::adamw();
        let mut rng = Rng::new(1);
        let mut st = LdAdamWState::new(&[8, 16], 2);
        let mut w = Tensor::zeros(&[8, 16]);
        let mut max_e = 0.0f32;
        for _ in 0..50 {
            let g = rng.gaussian_tensor(&[8, 16], 1.0);
            st.step(&mut w, &g, 1e-3, &hp, &mut rng);
            max_e = max_e.max(st.e.norm_fro());
        }
        let gn = (8.0f32 * 16.0).sqrt();
        assert!(max_e < 4.0 * gn, "error feedback diverged: {max_e}");
    }

    #[test]
    fn converges_on_quadratic() {
        let hp = OptHp::adamw();
        let mut rng = Rng::new(2);
        let target = rng.gaussian_tensor(&[8, 12], 1.0);
        let mut w = Tensor::zeros(&[8, 12]);
        let mut st = LdAdamWState::new(&[8, 12], 4);
        for _ in 0..800 {
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            st.step(&mut w, &g, 0.02, &hp, &mut rng);
        }
        assert!(w.rel_err(&target) < 0.1, "rel {}", w.rel_err(&target));
    }
}
