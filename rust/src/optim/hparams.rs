//! Optimizer hyper-parameters — must stay in lock-step with
//! `python/compile/configs.py::HPARAMS` (the manifest records the python
//! side; `rust/tests/cross_validate.rs` asserts the two agree).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptHp {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub galore_scale: f32,
    pub lora_alpha: f32,
    /// Adam-atan2 apply: `a·atan2(m̂, √v̂)` replaces `m̂/(√v̂+eps)` —
    /// eps-free and bounded (exemplar `use_atan2`). AdamW-family rules
    /// only; composable with any compressor.
    pub use_atan2: bool,
    /// Grams-style update: the step direction is `sign(g)`, the magnitude
    /// the Adam update's (exemplar `use_grams`).
    pub use_grams: bool,
    /// OrthoGrad: project the gradient orthogonal to the weight (norm
    /// preserved) before the step (exemplar `use_orthograd`).
    pub use_orthograd: bool,
}

impl OptHp {
    pub fn adamw() -> OptHp {
        OptHp {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            galore_scale: 0.25,
            lora_alpha: 16.0,
            use_atan2: false,
            use_grams: false,
            use_orthograd: false,
        }
    }

    /// Paper: MLorc-AdamW uses beta1 = 0.8 to damp RSVD approximation
    /// error accumulation (Section 4.1).
    pub fn mlorc_adamw() -> OptHp {
        OptHp { beta1: 0.8, ..OptHp::adamw() }
    }

    pub fn lion() -> OptHp {
        OptHp { beta1: 0.9, beta2: 0.99, ..OptHp::adamw() }
    }

    /// SGD with EMA momentum: only `beta1` and `weight_decay` are read.
    pub fn sgdm() -> OptHp {
        OptHp::adamw()
    }

    /// Prodigy D-adaptation runs on the exemplar's betas (0.9, 0.999);
    /// D-specific constants (`d0`, `slice_p`, ...) are fixed in
    /// `rules::prodigy` rather than per-run hyper-parameters.
    pub fn prodigy() -> OptHp {
        OptHp::adamw()
    }

    /// The modifier spellings: MLorc-AdamW with exactly one exemplar flag
    /// flipped on.
    pub fn mlorc_adamw_atan2() -> OptHp {
        OptHp { use_atan2: true, ..OptHp::mlorc_adamw() }
    }

    pub fn mlorc_adamw_grams() -> OptHp {
        OptHp { use_grams: true, ..OptHp::mlorc_adamw() }
    }

    pub fn mlorc_adamw_orthograd() -> OptHp {
        OptHp { use_orthograd: true, ..OptHp::mlorc_adamw() }
    }

    /// Host hyper-parameters of a method's matrix step — resolved
    /// through the registry's variant table instead of a match ladder.
    pub fn for_method(method: crate::config::Method) -> OptHp {
        let v = crate::optim::registry::variant(method.matrix_step())
            .expect("registered methods only reference registered variants");
        (v.hp)()
    }

    /// From a manifest step-graph hparams blob.
    pub fn from_json(j: &crate::util::json::Json) -> OptHp {
        let f = |k: &str, d: f32| {
            j.get(k).and_then(|v| v.as_f64().ok()).map(|x| x as f32).unwrap_or(d)
        };
        let b = |k: &str| j.get(k).and_then(|v| v.as_bool().ok()).unwrap_or(false);
        OptHp {
            beta1: f("beta1", 0.9),
            beta2: f("beta2", 0.999),
            eps: f("eps", 1e-8),
            weight_decay: f("weight_decay", 0.0),
            galore_scale: f("galore_scale", 0.25),
            lora_alpha: f("lora_alpha", 16.0),
            use_atan2: b("use_atan2"),
            use_grams: b("use_grams"),
            use_orthograd: b("use_orthograd"),
        }
    }
}
