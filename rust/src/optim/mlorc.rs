//! MLorc reference implementations: Algorithm 1 (AdamW), Algorithm 2
//! (Lion) and the Table 7 ablations (compress-m-only / compress-v-only).
//!
//! State is the QB factor pair per momentum — identical to the lowered
//! graphs; Omega draws come from a caller-provided RNG stream so the HLO
//! cross-validation can feed the *same* Omega to both implementations.

use crate::linalg::{matmul, rsvd_qb, Rng};
use crate::tensor::Tensor;

use super::lion::sign;
use super::{adamw_apply, bias_corrections, OptHp};

/// Eq. (2): ReLU(recon) + zeta * 1{recon < 0}, zeta = |mean of negative
/// part| — repairs compression-induced negatives in the second moment.
pub fn zeta_fix(recon: &mut Tensor) {
    let mut negsum = 0.0f64;
    let mut negcnt = 0usize;
    for x in &recon.data {
        if *x < 0.0 {
            negsum += -*x as f64;
            negcnt += 1;
        }
    }
    let zeta = (negsum / negcnt.max(1) as f64) as f32;
    for x in recon.data.iter_mut() {
        if *x < 0.0 {
            *x = zeta;
        }
    }
}

#[derive(Debug, Clone)]
pub struct MlorcAdamWState {
    pub mq: Tensor,
    pub mb: Tensor,
    pub vq: Tensor,
    pub vb: Tensor,
    pub l: usize,
    pub t: usize,
}

impl MlorcAdamWState {
    pub fn new(shape: &[usize], l: usize) -> MlorcAdamWState {
        let (m, n) = (shape[0], shape[1]);
        MlorcAdamWState {
            mq: Tensor::zeros(&[m, l]),
            mb: Tensor::zeros(&[l, n]),
            vq: Tensor::zeros(&[m, l]),
            vb: Tensor::zeros(&[l, n]),
            l,
            t: 0,
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.mq.size_bytes() + self.mb.size_bytes() + self.vq.size_bytes() + self.vb.size_bytes()
    }

    /// Algorithm 1, lines 5-15. `rng` supplies the two Omega draws.
    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        self.t += 1;
        let (_, n) = w.dims2().expect("mlorc on 2-D params only");
        // lines 6+9: m_t = beta1 * reconstruct + (1-beta1) g
        let mut mt = matmul(&self.mq, &self.mb);
        mt.axpy(1.0 - hp.beta1, g, hp.beta1);
        // lines 7-8+10: v_t = beta2 * fix(reconstruct) + (1-beta2) g^2
        let mut vt = matmul(&self.vq, &self.vb);
        zeta_fix(&mut vt);
        for (vi, gi) in vt.data.iter_mut().zip(&g.data) {
            *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
        }
        // lines 11-12: recompress
        let om_m = rng.gaussian_tensor(&[n, self.l], 1.0);
        let om_v = rng.gaussian_tensor(&[n, self.l], 1.0);
        let (mq, mb) = rsvd_qb(&mt, &om_m);
        let (vq, vb) = rsvd_qb(&vt, &om_v);
        self.mq = mq;
        self.mb = mb;
        self.vq = vq;
        self.vb = vb;
        // lines 13-15: update with the *exact* m_t, v_t
        let (c1, c2) = bias_corrections(hp, self.t);
        adamw_apply(w, &mt, &vt, lr, c1, c2, hp);
    }
}

#[derive(Debug, Clone)]
pub struct MlorcLionState {
    pub mq: Tensor,
    pub mb: Tensor,
    pub l: usize,
    pub t: usize,
}

impl MlorcLionState {
    pub fn new(shape: &[usize], l: usize) -> MlorcLionState {
        MlorcLionState {
            mq: Tensor::zeros(&[shape[0], l]),
            mb: Tensor::zeros(&[l, shape[1]]),
            l,
            t: 0,
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.mq.size_bytes() + self.mb.size_bytes()
    }

    /// Algorithm 2, lines 5-10.
    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        self.t += 1;
        let (_, n) = w.dims2().expect("mlorc on 2-D params only");
        let recon = matmul(&self.mq, &self.mb); // line 6
        // line 10 uses c_t = beta1 recon + (1-beta1) g
        for ((wi, ri), gi) in w.data.iter_mut().zip(&recon.data).zip(&g.data) {
            let c = hp.beta1 * ri + (1.0 - hp.beta1) * gi;
            *wi -= lr * (sign(c) + hp.weight_decay * *wi);
        }
        // line 8: m_t = beta2 recon + (1-beta2) g, then line 9 recompress
        let mut mt = recon;
        mt.axpy(1.0 - hp.beta2, g, hp.beta2);
        let om = rng.gaussian_tensor(&[n, self.l], 1.0);
        let (mq, mb) = rsvd_qb(&mt, &om);
        self.mq = mq;
        self.mb = mb;
    }
}

/// Table 7 ablation: compress m only, keep v exact.
#[derive(Debug, Clone)]
pub struct MlorcMState {
    pub mq: Tensor,
    pub mb: Tensor,
    pub v: Tensor,
    pub l: usize,
    pub t: usize,
}

impl MlorcMState {
    pub fn new(shape: &[usize], l: usize) -> MlorcMState {
        MlorcMState {
            mq: Tensor::zeros(&[shape[0], l]),
            mb: Tensor::zeros(&[l, shape[1]]),
            v: Tensor::zeros(shape),
            l,
            t: 0,
        }
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        self.t += 1;
        let (_, n) = w.dims2().unwrap();
        let mut mt = matmul(&self.mq, &self.mb);
        mt.axpy(1.0 - hp.beta1, g, hp.beta1);
        for (vi, gi) in self.v.data.iter_mut().zip(&g.data) {
            *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
        }
        let om = rng.gaussian_tensor(&[n, self.l], 1.0);
        let (mq, mb) = rsvd_qb(&mt, &om);
        self.mq = mq;
        self.mb = mb;
        let (c1, c2) = bias_corrections(hp, self.t);
        adamw_apply(w, &mt, &self.v, lr, c1, c2, hp);
    }
}

/// Table 7 ablation: compress v only, keep m exact.
#[derive(Debug, Clone)]
pub struct MlorcVState {
    pub m: Tensor,
    pub vq: Tensor,
    pub vb: Tensor,
    pub l: usize,
    pub t: usize,
}

impl MlorcVState {
    pub fn new(shape: &[usize], l: usize) -> MlorcVState {
        MlorcVState {
            m: Tensor::zeros(shape),
            vq: Tensor::zeros(&[shape[0], l]),
            vb: Tensor::zeros(&[l, shape[1]]),
            l,
            t: 0,
        }
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        self.t += 1;
        let (_, n) = w.dims2().unwrap();
        for (mi, gi) in self.m.data.iter_mut().zip(&g.data) {
            *mi = hp.beta1 * *mi + (1.0 - hp.beta1) * gi;
        }
        let mut vt = matmul(&self.vq, &self.vb);
        zeta_fix(&mut vt);
        for (vi, gi) in vt.data.iter_mut().zip(&g.data) {
            *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
        }
        let om = rng.gaussian_tensor(&[n, self.l], 1.0);
        let (vq, vb) = rsvd_qb(&vt, &om);
        self.vq = vq;
        self.vb = vb;
        let (c1, c2) = bias_corrections(hp, self.t);
        adamw_apply(w, &self.m, &vt, lr, c1, c2, hp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AdamWState;

    #[test]
    fn zeta_fix_matches_paper_formula() {
        let mut t = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.0, -4.0, 5.0, 0.0]).unwrap();
        zeta_fix(&mut t);
        // zeta = (2+4)/2 = 3; negatives replaced by 3
        assert_eq!(t.data, vec![1.0, 3.0, 3.0, 3.0, 5.0, 0.0]);
        let mut ok = Tensor::new(vec![1, 3], vec![1.0, 2.0, 0.5]).unwrap();
        zeta_fix(&mut ok);
        assert_eq!(ok.data, vec![1.0, 2.0, 0.5]); // identity on nonneg input
    }

    #[test]
    fn full_rank_mlorc_equals_adamw() {
        // l = min(m, n): compression is lossless, trajectories coincide.
        let hp = OptHp::mlorc_adamw();
        let shape = [10usize, 10];
        let mut rng = Rng::new(0);
        let mut w1 = rng.gaussian_tensor(&shape, 1.0);
        let mut w2 = w1.clone();
        let mut mlorc = MlorcAdamWState::new(&shape, 10);
        let mut adamw = AdamWState::new(&shape);
        let mut om_rng = Rng::new(99);
        for _ in 0..5 {
            let g = rng.gaussian_tensor(&shape, 1.0);
            mlorc.step(&mut w1, &g, 1e-2, &hp, &mut om_rng);
            adamw.step(&mut w2, &g, 1e-2, &hp);
            assert!(w1.rel_err(&w2) < 1e-4, "rel {}", w1.rel_err(&w2));
        }
    }

    #[test]
    fn mlorc_adamw_converges_on_lowrank_quadratic() {
        // f(W) = 0.5 || W - W* ||^2 with rank-2 W*: gradients are low-rank
        // plus the current iterate, matching the paper's regime.
        let hp = OptHp::mlorc_adamw();
        let mut rng = Rng::new(1);
        let u = rng.gaussian_tensor(&[12, 2], 1.0);
        let v = rng.gaussian_tensor(&[2, 16], 1.0);
        let target = matmul(&u, &v);
        let mut w = Tensor::zeros(&[12, 16]);
        let mut st = MlorcAdamWState::new(&[12, 16], 4);
        let mut om_rng = Rng::new(7);
        for _ in 0..600 {
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            st.step(&mut w, &g, 0.05, &hp, &mut om_rng);
        }
        assert!(w.rel_err(&target) < 0.08, "rel {}", w.rel_err(&target));
    }

    #[test]
    fn mlorc_lion_update_magnitude() {
        let hp = OptHp::lion();
        let mut rng = Rng::new(2);
        let g = rng.gaussian_tensor(&[8, 8], 1.0);
        let mut w = Tensor::zeros(&[8, 8]);
        let mut st = MlorcLionState::new(&[8, 8], 4);
        st.step(&mut w, &g, 0.01, &hp, &mut rng);
        for (wi, gi) in w.data.iter().zip(&g.data) {
            if gi.abs() > 1e-6 {
                assert!((wi.abs() - 0.01).abs() < 1e-7);
                assert_eq!(wi.signum(), -gi.signum());
            }
        }
    }

    #[test]
    fn ablations_track_their_exact_half() {
        let hp = OptHp::mlorc_adamw();
        let mut rng = Rng::new(3);
        let g = rng.gaussian_tensor(&[6, 6], 1.0);
        let mut w = Tensor::zeros(&[6, 6]);
        let mut mm = MlorcMState::new(&[6, 6], 2);
        mm.step(&mut w, &g, 1e-3, &hp, &mut rng);
        for (vi, gi) in mm.v.data.iter().zip(&g.data) {
            assert!((vi - (1.0 - hp.beta2) * gi * gi).abs() < 1e-9);
        }
        let mut mv = MlorcVState::new(&[6, 6], 2);
        let mut w2 = Tensor::zeros(&[6, 6]);
        mv.step(&mut w2, &g, 1e-3, &hp, &mut rng);
        for (mi, gi) in mv.m.data.iter().zip(&g.data) {
            assert!((mi - (1.0 - hp.beta1) * gi).abs() < 1e-7);
        }
    }
}
