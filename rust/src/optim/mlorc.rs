//! MLorc reference implementations: Algorithm 1 (AdamW), Algorithm 2
//! (Lion) and the Table 7 ablations (compress-m-only / compress-v-only).
//!
//! State is the QB factor pair per momentum — identical to the lowered
//! graphs; Omega draws come from a caller-provided RNG stream so the HLO
//! cross-validation can feed the *same* Omega to both implementations.
//!
//! ## Host fast path
//!
//! Every step recompresses `m_t = β·Q_prev B_prev + (1−β)·G`. The factor
//! structure is exploited end to end (`linalg::rsvd::rsvd_qb_factored`):
//! the sketch and projection collapse onto small O((m+n)·l²) GEMMs plus
//! the two unavoidable thin gradient contractions `G Ω` / `Qᵀ G`, and the
//! single remaining dense reconstruction is *fused* into the AdamW/Lion
//! apply — no m×n first-moment buffer exists at any point. The second
//! moment keeps a dense v_t scratch because the ζ-fix (Eq. 2) is
//! nonlinear, but its reconstruction GEMM is the only one per step that
//! materializes an m×n intermediate (asserted by `factored_step_gemm_audit`
//! below and re-checked by `bench_opt_step`). All scratch comes from a
//! per-state [`Workspace`], so steady-state steps allocate nothing.
//! Footprint note: that pool retains its largest scratch (the dense v_t
//! buffer for the AdamW/V variants) between steps — the usual speed/memory
//! trade of pooling; `state_bytes()` reports the algorithmic O((m+n)·l)
//! state only. The coordinator does not pay per-parameter retention: its
//! `OptState` tensors step through a small set of *shared* per-worker
//! workspaces (`Trainer::host_ws`).
//!
//! The pre-optimization algorithm shape is kept as
//! [`mlorc_adamw_step_direct`] — the bench baseline and the equivalence
//! oracle for the fast path.

// The fused-apply bands use index loops over raw row slices on purpose
// (see linalg/matmul.rs — same banding-determinism rationale).
#![allow(clippy::needless_range_loop)]

use crate::linalg::pool::{self, BandedMut};
use crate::linalg::{
    flops, matmul, matmul_class_into, matmul_into, rsvd_qb, rsvd_qb_class, rsvd_qb_factored,
    rsvd_qb_factored_class, rsvd_qb_ws, simd, Rng, Workspace,
};
use crate::obs;
use crate::tensor::Tensor;

use super::lion::sign;
use super::{adamw_apply, bias_corrections, OptHp};

/// Eq. (2): ReLU(recon) + zeta * 1{recon < 0}, zeta = |mean of negative
/// part| — repairs compression-induced negatives in the second moment.
pub fn zeta_fix(recon: &mut Tensor) {
    let mut negsum = 0.0f64;
    let mut negcnt = 0usize;
    for x in &recon.data {
        if *x < 0.0 {
            negsum += -*x as f64;
            negcnt += 1;
        }
    }
    let zeta = (negsum / negcnt.max(1) as f64) as f32;
    for x in recon.data.iter_mut() {
        if *x < 0.0 {
            *x = zeta;
        }
    }
}

// ------------------------------------------------------------------ cores
//
// Free functions over raw state tensors, shared by the reference state
// structs below and the coordinator's parallel host stepping
// (`coordinator::state::OptState::host_step`).

/// Dense second moment: v_t = beta2 * zeta_fix(vq vb) + (1-beta2) * g².
/// The ζ-fix needs the global negative-part mean, so this moment cannot
/// ride the factored path; its reconstruction is the step's one dense GEMM.
fn second_moment_dense(vt: &mut Tensor, vq: &Tensor, vb: &Tensor, g: &Tensor, beta2: f32) {
    matmul_into(vt, vq, vb);
    zeta_fix(vt);
    for (vi, gi) in vt.data.iter_mut().zip(&g.data) {
        *vi = beta2 * *vi + (1.0 - beta2) * gi * gi;
    }
}

/// Fused reconstruction + AdamW apply: per element,
/// `m_t = beta1·(mq mb) + (1−beta1)·g`, then
/// `w -= lr·(c1·m_t / (sqrt(c2·v_t) + eps) + wd·w)` — one pass over W, G
/// and v_t; the reconstruction lives in an n-wide register/L1 row only.
/// Public (with [`fused_adamw_band`]) so `bench_opt_step` can measure the
/// pooled apply against a PR-1-era spawn-scaffold reference.
#[allow(clippy::too_many_arguments)]
pub fn fused_recon_adamw_apply(
    w: &mut Tensor,
    g: &Tensor,
    vt: &Tensor,
    mq: &Tensor,
    mb: &Tensor,
    beta1: f32,
    lr: f32,
    c1: f32,
    c2: f32,
    hp: &OptHp,
    ws: &mut Workspace,
) {
    let (m, n) = w.dims2().expect("fused apply weight");
    let (_, l) = mq.dims2().expect("fused apply mq");
    flops::record("fused_recon_adamw", m, l, n);
    if m == 0 || n == 0 {
        return;
    }
    // One reconstruction-row buffer per band; the plan is recomputed
    // identically inside par_row_bands (pure function of rows/madds).
    let madds = m * n * (l + 4);
    let (nbands, _) = pool::plan(m, madds);
    let mut scratch = ws.take(nbands * n);
    {
        let w_bands = BandedMut::new(&mut w.data);
        let s_bands = BandedMut::new(&mut scratch);
        let (gd, vtd, mqd, mbd) = (&g.data[..], &vt.data[..], &mq.data[..], &mb.data[..]);
        pool::par_row_bands(m, madds, move |band, r| {
            let w_band = unsafe { w_bands.rows(r.clone(), n) };
            let row_buf = unsafe { s_bands.rows(band..band + 1, n) };
            fused_adamw_band(
                w_band,
                &gd[r.start * n..r.end * n],
                &vtd[r.start * n..r.end * n],
                &mqd[r.start * l..r.end * l],
                mbd,
                row_buf,
                l,
                n,
                beta1,
                lr,
                c1,
                c2,
                hp,
            );
        });
    }
    ws.give(scratch);
}

/// One band of the fused AdamW apply (rows of `w`/`g`/`vt`/`mq` with a
/// shared `mb` and one n-wide reconstruction row buffer). Public for the
/// bench spawn baseline only.
#[allow(clippy::too_many_arguments)]
pub fn fused_adamw_band(
    w: &mut [f32],
    g: &[f32],
    vt: &[f32],
    mq: &[f32],
    mb: &[f32],
    row: &mut [f32],
    l: usize,
    n: usize,
    beta1: f32,
    lr: f32,
    c1: f32,
    c2: f32,
    hp: &OptHp,
) {
    let rows = w.len() / n;
    let row = &mut row[..n];
    for i in 0..rows {
        // reconstruction row: row = mq[i, :] @ mb
        row.fill(0.0);
        let arow = &mq[i * l..(i + 1) * l];
        for (p, &av) in arow.iter().enumerate() {
            simd::axpy(row, av, &mb[p * n..(p + 1) * n]);
        }
        // apply epilogue
        let wrow = &mut w[i * n..(i + 1) * n];
        let grow = &g[i * n..(i + 1) * n];
        let vrow = &vt[i * n..(i + 1) * n];
        for (((wi, &gi), &vi), &ri) in wrow.iter_mut().zip(grow).zip(vrow).zip(row.iter()) {
            let mt = beta1 * ri + (1.0 - beta1) * gi;
            let mhat = mt * c1;
            let vhat = vi * c2;
            let dir = if hp.use_atan2 {
                super::ATAN2_SCALE * mhat.atan2(vhat.sqrt())
            } else {
                mhat / (vhat.sqrt() + hp.eps)
            };
            *wi -= lr * (dir + hp.weight_decay * *wi);
        }
    }
}

/// Fused reconstruction + Lion apply: per element
/// `c = beta1·(mq mb) + (1−beta1)·g`, `w -= lr·(sign(c) + wd·w)`.
#[allow(clippy::too_many_arguments)]
pub fn fused_recon_lion_apply(
    w: &mut Tensor,
    g: &Tensor,
    mq: &Tensor,
    mb: &Tensor,
    beta1: f32,
    lr: f32,
    hp: &OptHp,
    ws: &mut Workspace,
) {
    let (m, n) = w.dims2().expect("fused lion weight");
    let (_, l) = mq.dims2().expect("fused lion mq");
    flops::record("fused_recon_lion", m, l, n);
    if m == 0 || n == 0 {
        return;
    }
    let madds = m * n * (l + 2);
    let (nbands, _) = pool::plan(m, madds);
    let mut scratch = ws.take(nbands * n);
    {
        let w_bands = BandedMut::new(&mut w.data);
        let s_bands = BandedMut::new(&mut scratch);
        let (gd, mqd, mbd) = (&g.data[..], &mq.data[..], &mb.data[..]);
        pool::par_row_bands(m, madds, move |band, r| {
            let w_band = unsafe { w_bands.rows(r.clone(), n) };
            let row_buf = unsafe { s_bands.rows(band..band + 1, n) };
            fused_lion_band(
                w_band,
                &gd[r.start * n..r.end * n],
                &mqd[r.start * l..r.end * l],
                mbd,
                row_buf,
                l,
                n,
                beta1,
                lr,
                hp,
            );
        });
    }
    ws.give(scratch);
}

/// One band of the fused Lion apply. Public for the bench spawn baseline
/// only.
#[allow(clippy::too_many_arguments)]
pub fn fused_lion_band(
    w: &mut [f32],
    g: &[f32],
    mq: &[f32],
    mb: &[f32],
    row: &mut [f32],
    l: usize,
    n: usize,
    beta1: f32,
    lr: f32,
    hp: &OptHp,
) {
    let rows = w.len() / n;
    let row = &mut row[..n];
    for i in 0..rows {
        row.fill(0.0);
        let arow = &mq[i * l..(i + 1) * l];
        for (p, &av) in arow.iter().enumerate() {
            simd::axpy(row, av, &mb[p * n..(p + 1) * n]);
        }
        let wrow = &mut w[i * n..(i + 1) * n];
        let grow = &g[i * n..(i + 1) * n];
        for ((wi, &gi), &ri) in wrow.iter_mut().zip(grow).zip(row.iter()) {
            let c = beta1 * ri + (1.0 - beta1) * gi;
            *wi -= lr * (sign(c) + hp.weight_decay * *wi);
        }
    }
}

/// Fused reconstruction + SGD-momentum apply: per element
/// `m_t = beta1·(mq mb) + (1−beta1)·g`, `w -= lr·(m_t + wd·w)` — the
/// exact m_t from the old factors, like the AdamW fused apply.
#[allow(clippy::too_many_arguments)]
pub fn fused_recon_sgdm_apply(
    w: &mut Tensor,
    g: &Tensor,
    mq: &Tensor,
    mb: &Tensor,
    beta1: f32,
    lr: f32,
    hp: &OptHp,
    ws: &mut Workspace,
) {
    let (m, n) = w.dims2().expect("fused sgdm weight");
    let (_, l) = mq.dims2().expect("fused sgdm mq");
    flops::record("fused_recon_sgdm", m, l, n);
    if m == 0 || n == 0 {
        return;
    }
    let madds = m * n * (l + 2);
    let (nbands, _) = pool::plan(m, madds);
    let mut scratch = ws.take(nbands * n);
    {
        let w_bands = BandedMut::new(&mut w.data);
        let s_bands = BandedMut::new(&mut scratch);
        let (gd, mqd, mbd) = (&g.data[..], &mq.data[..], &mb.data[..]);
        pool::par_row_bands(m, madds, move |band, r| {
            let w_band = unsafe { w_bands.rows(r.clone(), n) };
            let row_buf = unsafe { s_bands.rows(band..band + 1, n) };
            fused_sgdm_band(
                w_band,
                &gd[r.start * n..r.end * n],
                &mqd[r.start * l..r.end * l],
                mbd,
                row_buf,
                l,
                n,
                beta1,
                lr,
                hp,
            );
        });
    }
    ws.give(scratch);
}

/// One band of the fused SGD-momentum apply.
#[allow(clippy::too_many_arguments)]
pub fn fused_sgdm_band(
    w: &mut [f32],
    g: &[f32],
    mq: &[f32],
    mb: &[f32],
    row: &mut [f32],
    l: usize,
    n: usize,
    beta1: f32,
    lr: f32,
    hp: &OptHp,
) {
    let rows = w.len() / n;
    let row = &mut row[..n];
    for i in 0..rows {
        row.fill(0.0);
        let arow = &mq[i * l..(i + 1) * l];
        for (p, &av) in arow.iter().enumerate() {
            simd::axpy(row, av, &mb[p * n..(p + 1) * n]);
        }
        let wrow = &mut w[i * n..(i + 1) * n];
        let grow = &g[i * n..(i + 1) * n];
        for ((wi, &gi), &ri) in wrow.iter_mut().zip(grow).zip(row.iter()) {
            let mt = beta1 * ri + (1.0 - beta1) * gi;
            *wi -= lr * (mt + hp.weight_decay * *wi);
        }
    }
}

/// One MLorc-SGDM step on raw state tensors: the momentum is a single
/// linear EMA, so (like Lion's) it rides the factored recompression, and
/// the apply fuses the exact-m_t reconstruction. The combo the trait
/// split makes free — no paper algorithm box, same kernel skeleton.
#[allow(clippy::too_many_arguments)]
pub fn mlorc_sgdm_core(
    w: &mut Tensor,
    g: &Tensor,
    mq: &mut Tensor,
    mb: &mut Tensor,
    lr: f32,
    hp: &OptHp,
    om: &Tensor,
    ws: &mut Workspace,
) {
    // apply from the exact m_t = beta1 recon + (1-beta1) g (old factors)
    fused_recon_sgdm_apply(w, g, mq, mb, hp.beta1, lr, hp, ws);
    // recompress the same m_t, factored
    let (mq2, mb2) = rsvd_qb_factored(mq, mb, hp.beta1, g, om, ws);
    ws.give_tensor(std::mem::replace(mq, mq2));
    ws.give_tensor(std::mem::replace(mb, mb2));
}

/// One MLorc-AdamW step (Algorithm 1, lines 5-15) on raw state tensors.
#[allow(clippy::too_many_arguments)]
pub fn mlorc_adamw_core(
    w: &mut Tensor,
    g: &Tensor,
    mq: &mut Tensor,
    mb: &mut Tensor,
    vq: &mut Tensor,
    vb: &mut Tensor,
    t: usize,
    lr: f32,
    hp: &OptHp,
    om_m: &Tensor,
    om_v: &Tensor,
    ws: &mut Workspace,
) {
    let (m, n) = w.dims2().expect("mlorc on 2-D params only");
    // lines 7-8+10: dense v_t (ζ-fix blocks the factored path)
    let mut vt = ws.take_tensor(&[m, n]);
    second_moment_dense(&mut vt, vq, vb, g, hp.beta2);
    let (vq2, vb2) = rsvd_qb_ws(&vt, om_v, ws);
    // lines 6+9+11: factored recompression of m_t — old factors intact
    let (mq2, mb2) = rsvd_qb_factored(mq, mb, hp.beta1, g, om_m, ws);
    // lines 13-15: apply with the *exact* m_t (fused recon) and v_t
    let (c1, c2) = bias_corrections(hp, t);
    fused_recon_adamw_apply(w, g, &vt, mq, mb, hp.beta1, lr, c1, c2, hp, ws);
    ws.give_tensor(vt);
    ws.give_tensor(std::mem::replace(mq, mq2));
    ws.give_tensor(std::mem::replace(mb, mb2));
    ws.give_tensor(std::mem::replace(vq, vq2));
    ws.give_tensor(std::mem::replace(vb, vb2));
}

/// One MLorc-Lion step (Algorithm 2, lines 5-10) on raw state tensors.
#[allow(clippy::too_many_arguments)]
pub fn mlorc_lion_core(
    w: &mut Tensor,
    g: &Tensor,
    mq: &mut Tensor,
    mb: &mut Tensor,
    lr: f32,
    hp: &OptHp,
    om: &Tensor,
    ws: &mut Workspace,
) {
    // line 10: update from c_t = beta1 recon + (1-beta1) g (old factors)
    fused_recon_lion_apply(w, g, mq, mb, hp.beta1, lr, hp, ws);
    // lines 8-9: m_t = beta2 recon + (1-beta2) g, recompressed factored
    let (mq2, mb2) = rsvd_qb_factored(mq, mb, hp.beta2, g, om, ws);
    ws.give_tensor(std::mem::replace(mq, mq2));
    ws.give_tensor(std::mem::replace(mb, mb2));
}

/// Table 7 compress-m-only step on raw state tensors.
#[allow(clippy::too_many_arguments)]
pub fn mlorc_m_core(
    w: &mut Tensor,
    g: &Tensor,
    mq: &mut Tensor,
    mb: &mut Tensor,
    v: &mut Tensor,
    t: usize,
    lr: f32,
    hp: &OptHp,
    om: &Tensor,
    ws: &mut Workspace,
) {
    for (vi, gi) in v.data.iter_mut().zip(&g.data) {
        *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
    }
    let (mq2, mb2) = rsvd_qb_factored(mq, mb, hp.beta1, g, om, ws);
    let (c1, c2) = bias_corrections(hp, t);
    fused_recon_adamw_apply(w, g, v, mq, mb, hp.beta1, lr, c1, c2, hp, ws);
    ws.give_tensor(std::mem::replace(mq, mq2));
    ws.give_tensor(std::mem::replace(mb, mb2));
}

/// Table 7 compress-v-only step on raw state tensors.
#[allow(clippy::too_many_arguments)]
pub fn mlorc_v_core(
    w: &mut Tensor,
    g: &Tensor,
    m_exact: &mut Tensor,
    vq: &mut Tensor,
    vb: &mut Tensor,
    t: usize,
    lr: f32,
    hp: &OptHp,
    om: &Tensor,
    ws: &mut Workspace,
) {
    let (m, n) = w.dims2().expect("mlorc on 2-D params only");
    for (mi, gi) in m_exact.data.iter_mut().zip(&g.data) {
        *mi = hp.beta1 * *mi + (1.0 - hp.beta1) * gi;
    }
    let mut vt = ws.take_tensor(&[m, n]);
    second_moment_dense(&mut vt, vq, vb, g, hp.beta2);
    let (vq2, vb2) = rsvd_qb_ws(&vt, om, ws);
    let (c1, c2) = bias_corrections(hp, t);
    adamw_apply(w, m_exact, &vt, lr, c1, c2, hp);
    ws.give_tensor(vt);
    ws.give_tensor(std::mem::replace(vq, vq2));
    ws.give_tensor(std::mem::replace(vb, vb2));
}

/// The pre-optimization MLorc-AdamW step shape: materialize both
/// reconstructions, recompress directly, apply separately. Kept as the
/// bench baseline and the equivalence oracle for the fast path.
#[allow(clippy::too_many_arguments)]
pub fn mlorc_adamw_step_direct(
    w: &mut Tensor,
    g: &Tensor,
    mq: &mut Tensor,
    mb: &mut Tensor,
    vq: &mut Tensor,
    vb: &mut Tensor,
    t: usize,
    lr: f32,
    hp: &OptHp,
    om_m: &Tensor,
    om_v: &Tensor,
) {
    let mut mt = matmul(mq, mb);
    mt.axpy(1.0 - hp.beta1, g, hp.beta1);
    let mut vt = matmul(vq, vb);
    zeta_fix(&mut vt);
    for (vi, gi) in vt.data.iter_mut().zip(&g.data) {
        *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
    }
    let (mq2, mb2) = rsvd_qb(&mt, om_m);
    let (vq2, vb2) = rsvd_qb(&vt, om_v);
    *mq = mq2;
    *mb = mb2;
    *vq = vq2;
    *vb = vb2;
    let (c1, c2) = bias_corrections(hp, t);
    adamw_apply(w, &mt, &vt, lr, c1, c2, hp);
}

// ------------------------------------------------- batched shape-class cores
//
// Class variants of the cores above: every phase (dense v reconstruction,
// ζ-fix + EMA, sketch, MGS QR, projection, fused apply) runs once for a
// whole shape class via the stacked linalg entry points, so pool dispatch
// and band planning are paid per class instead of per parameter. Per
// member the arithmetic, phase order, and Ω consumption are exactly the
// scalar cores' — bit-identity is pinned by `tests/host_parallel.rs`.

/// One member of a batched QB-layout step: the weight/gradient pair, the
/// per-moment factor pairs (m first, then v where present), and the
/// pre-drawn Ω per moment (drawn by the caller in moment order, so the
/// per-parameter RNG streams see exactly the scalar path's consumption).
pub struct QbClassJob<'a> {
    pub w: &'a mut Tensor,
    pub g: &'a Tensor,
    pub lr: f32,
    pub t: usize,
    pub factors: Vec<(&'a mut Tensor, &'a mut Tensor)>,
    pub omegas: Vec<Tensor>,
}

#[derive(Clone, Copy)]
enum ApplyKind {
    AdamW,
    Lion,
    Sgdm,
}

/// Raw per-member operand pointers for the stacked fused apply. Collected
/// in one `iter_mut` pass over the jobs *before* the parallel region, and
/// the jobs are untouched while bands run — the same disjointness argument
/// as [`BandedMut`], per member.
struct ApplyRow {
    w: *mut f32,
    g: *const f32,
    vt: *const f32,
    mq: *const f32,
    mb: *const f32,
    lr: f32,
    c1: f32,
    c2: f32,
}

struct ApplyTable(Vec<ApplyRow>);

unsafe impl Send for ApplyTable {}
unsafe impl Sync for ApplyTable {}

/// Stacked fused reconstruct-apply: one banded invocation over the class's
/// `members * m` weight rows. Per-band scratch is one n-wide row buffer,
/// reused across the members a band crosses (fully overwritten per row).
fn fused_apply_class(
    kind: ApplyKind,
    jobs: &mut [QbClassJob],
    vts: Option<&[Tensor]>,
    hp: &OptHp,
    ws0: &mut Workspace,
) {
    let _span = obs::span(&obs::registry::STEP_FUSED_APPLY_US);
    let count = jobs.len();
    let (m, n) = jobs[0].w.dims2().expect("fused class weight");
    let l = jobs[0].factors[0].0.shape[1];
    let name = match kind {
        ApplyKind::AdamW => "fused_recon_adamw",
        ApplyKind::Lion => "fused_recon_lion",
        ApplyKind::Sgdm => "fused_recon_sgdm",
    };
    for _ in 0..count {
        flops::record(name, m, l, n);
    }
    if m == 0 || n == 0 {
        return;
    }
    let mut rows: Vec<ApplyRow> = Vec::with_capacity(count);
    for (i, j) in jobs.iter_mut().enumerate() {
        let (c1, c2) = match kind {
            ApplyKind::AdamW => bias_corrections(hp, j.t),
            _ => (1.0, 1.0),
        };
        rows.push(ApplyRow {
            w: j.w.data.as_mut_ptr(),
            g: j.g.data.as_ptr(),
            vt: vts.map_or(std::ptr::null(), |v| v[i].data.as_ptr()),
            mq: j.factors[0].0.data.as_ptr(),
            mb: j.factors[0].1.data.as_ptr(),
            lr: j.lr,
            c1,
            c2,
        });
    }
    let table = ApplyTable(rows);
    let extra = match kind {
        ApplyKind::AdamW => 4,
        ApplyKind::Lion | ApplyKind::Sgdm => 2,
    };
    let madds = count * m * n * (l + extra);
    let (nbands, _) = pool::plan(count * m, madds);
    let mut scratch = ws0.take(nbands * n);
    {
        let s_bands = BandedMut::new(&mut scratch);
        let beta1 = hp.beta1;
        pool::par_stacked_rows(count, m, madds, move |band, i, r| {
            let row_buf = unsafe { s_bands.rows(band..band + 1, n) };
            let member = &table.0[i];
            let rows_here = r.end - r.start;
            let w = unsafe {
                std::slice::from_raw_parts_mut(member.w.add(r.start * n), rows_here * n)
            };
            let g =
                unsafe { std::slice::from_raw_parts(member.g.add(r.start * n), rows_here * n) };
            let mq =
                unsafe { std::slice::from_raw_parts(member.mq.add(r.start * l), rows_here * l) };
            let mb = unsafe { std::slice::from_raw_parts(member.mb, l * n) };
            match kind {
                ApplyKind::AdamW => {
                    let vt = unsafe {
                        std::slice::from_raw_parts(member.vt.add(r.start * n), rows_here * n)
                    };
                    fused_adamw_band(
                        w, g, vt, mq, mb, row_buf, l, n, beta1, member.lr, member.c1, member.c2,
                        hp,
                    );
                }
                ApplyKind::Lion => {
                    fused_lion_band(w, g, mq, mb, row_buf, l, n, beta1, member.lr, hp);
                }
                ApplyKind::Sgdm => {
                    fused_sgdm_band(w, g, mq, mb, row_buf, l, n, beta1, member.lr, hp);
                }
            }
        });
    }
    ws0.give(scratch);
}

/// Batched [`mlorc_adamw_core`] over a shape class (factors = [m, v],
/// omegas = [Ω_m, Ω_v] per member).
pub fn mlorc_adamw_core_class(jobs: &mut [QbClassJob], hp: &OptHp, workspaces: &mut [Workspace]) {
    let count = jobs.len();
    if count == 0 {
        return;
    }
    let (m, n) = jobs[0].w.dims2().expect("mlorc on 2-D params only");
    // dense v_t per member: one stacked reconstruction GEMM, then the
    // ζ-fix + EMA per member (ζ needs each member's global negative-part
    // mean, so it cannot be fused into the banded GEMM).
    let mut vts: Vec<Tensor> = (0..count).map(|_| workspaces[0].take_tensor(&[m, n])).collect();
    {
        let _span = obs::span(&obs::registry::STEP_RECONSTRUCT_US);
        let vqs: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[1].0).collect();
        let vbs: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[1].1).collect();
        matmul_class_into(&mut vts, &vqs, &vbs);
    }
    {
        let beta2 = hp.beta2;
        let out = pool::DisjointMut::new(&mut vts);
        let jref: &[QbClassJob] = jobs;
        pool::par_row_bands(count, count * m * n, |_, range| {
            for i in range {
                let vt = unsafe { out.item(i) };
                zeta_fix(vt);
                for (vi, gi) in vt.data.iter_mut().zip(&jref[i].g.data) {
                    *vi = beta2 * *vi + (1.0 - beta2) * gi * gi;
                }
            }
        });
    }
    // recompress v from the dense v_t (direct path, stacked)
    let new_v = {
        let vt_refs: Vec<&Tensor> = vts.iter().collect();
        let om_v: Vec<&Tensor> = jobs.iter().map(|j| &j.omegas[1]).collect();
        rsvd_qb_class(&vt_refs, &om_v, workspaces)
    };
    // factored recompression of m_t — old factors intact for the apply
    let new_m = {
        let qps: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[0].0).collect();
        let bps: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[0].1).collect();
        let gs: Vec<&Tensor> = jobs.iter().map(|j| j.g).collect();
        let om_m: Vec<&Tensor> = jobs.iter().map(|j| &j.omegas[0]).collect();
        rsvd_qb_factored_class(&qps, &bps, hp.beta1, &gs, &om_m, workspaces)
    };
    // apply with the exact m_t (old factors, fused recon) and dense v_t
    fused_apply_class(ApplyKind::AdamW, jobs, Some(&vts), hp, &mut workspaces[0]);
    for vt in vts {
        workspaces[0].give_tensor(vt);
    }
    for ((job, (mq2, mb2)), (vq2, vb2)) in jobs.iter_mut().zip(new_m).zip(new_v) {
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[0].0, mq2));
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[0].1, mb2));
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[1].0, vq2));
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[1].1, vb2));
    }
}

/// Batched [`mlorc_lion_core`] over a shape class (single m moment).
pub fn mlorc_lion_core_class(jobs: &mut [QbClassJob], hp: &OptHp, workspaces: &mut [Workspace]) {
    if jobs.is_empty() {
        return;
    }
    fused_apply_class(ApplyKind::Lion, jobs, None, hp, &mut workspaces[0]);
    let new_m = {
        let qps: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[0].0).collect();
        let bps: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[0].1).collect();
        let gs: Vec<&Tensor> = jobs.iter().map(|j| j.g).collect();
        let oms: Vec<&Tensor> = jobs.iter().map(|j| &j.omegas[0]).collect();
        rsvd_qb_factored_class(&qps, &bps, hp.beta2, &gs, &oms, workspaces)
    };
    for (job, (mq2, mb2)) in jobs.iter_mut().zip(new_m) {
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[0].0, mq2));
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[0].1, mb2));
    }
}

/// Batched [`mlorc_sgdm_core`] over a shape class (single m moment).
pub fn mlorc_sgdm_core_class(jobs: &mut [QbClassJob], hp: &OptHp, workspaces: &mut [Workspace]) {
    if jobs.is_empty() {
        return;
    }
    fused_apply_class(ApplyKind::Sgdm, jobs, None, hp, &mut workspaces[0]);
    let new_m = {
        let qps: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[0].0).collect();
        let bps: Vec<&Tensor> = jobs.iter().map(|j| &*j.factors[0].1).collect();
        let gs: Vec<&Tensor> = jobs.iter().map(|j| j.g).collect();
        let oms: Vec<&Tensor> = jobs.iter().map(|j| &j.omegas[0]).collect();
        rsvd_qb_factored_class(&qps, &bps, hp.beta1, &gs, &oms, workspaces)
    };
    for (job, (mq2, mb2)) in jobs.iter_mut().zip(new_m) {
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[0].0, mq2));
        workspaces[0].give_tensor(std::mem::replace(&mut *job.factors[0].1, mb2));
    }
}

// ------------------------------------------------------------ state structs

#[derive(Debug, Clone)]
pub struct MlorcAdamWState {
    pub mq: Tensor,
    pub mb: Tensor,
    pub vq: Tensor,
    pub vb: Tensor,
    pub l: usize,
    pub t: usize,
    ws: Workspace,
}

impl MlorcAdamWState {
    pub fn new(shape: &[usize], l: usize) -> MlorcAdamWState {
        let (m, n) = (shape[0], shape[1]);
        MlorcAdamWState {
            mq: Tensor::zeros(&[m, l]),
            mb: Tensor::zeros(&[l, n]),
            vq: Tensor::zeros(&[m, l]),
            vb: Tensor::zeros(&[l, n]),
            l,
            t: 0,
            ws: Workspace::new(),
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.mq.size_bytes() + self.mb.size_bytes() + self.vq.size_bytes() + self.vb.size_bytes()
    }

    /// Algorithm 1, lines 5-15. `rng` supplies the two Omega draws.
    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        let (_, n) = w.dims2().expect("mlorc on 2-D params only");
        let om_m = rng.gaussian_tensor(&[n, self.l], 1.0);
        let om_v = rng.gaussian_tensor(&[n, self.l], 1.0);
        self.step_with_omegas(w, g, lr, hp, &om_m, &om_v);
    }

    /// Step with caller-provided Omega draws (benches, cross-validation).
    pub fn step_with_omegas(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        hp: &OptHp,
        om_m: &Tensor,
        om_v: &Tensor,
    ) {
        self.t += 1;
        mlorc_adamw_core(
            w, g, &mut self.mq, &mut self.mb, &mut self.vq, &mut self.vb, self.t, lr, hp, om_m,
            om_v, &mut self.ws,
        );
    }
}

#[derive(Debug, Clone)]
pub struct MlorcLionState {
    pub mq: Tensor,
    pub mb: Tensor,
    pub l: usize,
    pub t: usize,
    ws: Workspace,
}

impl MlorcLionState {
    pub fn new(shape: &[usize], l: usize) -> MlorcLionState {
        MlorcLionState {
            mq: Tensor::zeros(&[shape[0], l]),
            mb: Tensor::zeros(&[l, shape[1]]),
            l,
            t: 0,
            ws: Workspace::new(),
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.mq.size_bytes() + self.mb.size_bytes()
    }

    /// Algorithm 2, lines 5-10.
    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        let (_, n) = w.dims2().expect("mlorc on 2-D params only");
        let om = rng.gaussian_tensor(&[n, self.l], 1.0);
        self.step_with_omega(w, g, lr, hp, &om);
    }

    /// Step with a caller-provided Omega draw.
    pub fn step_with_omega(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        hp: &OptHp,
        om: &Tensor,
    ) {
        self.t += 1;
        mlorc_lion_core(w, g, &mut self.mq, &mut self.mb, lr, hp, om, &mut self.ws);
    }
}

/// Table 7 ablation: compress m only, keep v exact.
#[derive(Debug, Clone)]
pub struct MlorcMState {
    pub mq: Tensor,
    pub mb: Tensor,
    pub v: Tensor,
    pub l: usize,
    pub t: usize,
    ws: Workspace,
}

impl MlorcMState {
    pub fn new(shape: &[usize], l: usize) -> MlorcMState {
        MlorcMState {
            mq: Tensor::zeros(&[shape[0], l]),
            mb: Tensor::zeros(&[l, shape[1]]),
            v: Tensor::zeros(shape),
            l,
            t: 0,
            ws: Workspace::new(),
        }
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        self.t += 1;
        let (_, n) = w.dims2().unwrap();
        let om = rng.gaussian_tensor(&[n, self.l], 1.0);
        mlorc_m_core(
            w, g, &mut self.mq, &mut self.mb, &mut self.v, self.t, lr, hp, &om, &mut self.ws,
        );
    }
}

/// Table 7 ablation: compress v only, keep m exact.
#[derive(Debug, Clone)]
pub struct MlorcVState {
    pub m: Tensor,
    pub vq: Tensor,
    pub vb: Tensor,
    pub l: usize,
    pub t: usize,
    ws: Workspace,
}

impl MlorcVState {
    pub fn new(shape: &[usize], l: usize) -> MlorcVState {
        MlorcVState {
            m: Tensor::zeros(shape),
            vq: Tensor::zeros(&[shape[0], l]),
            vb: Tensor::zeros(&[l, shape[1]]),
            l,
            t: 0,
            ws: Workspace::new(),
        }
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        self.t += 1;
        let (_, n) = w.dims2().unwrap();
        let om = rng.gaussian_tensor(&[n, self.l], 1.0);
        mlorc_v_core(
            w, g, &mut self.m, &mut self.vq, &mut self.vb, self.t, lr, hp, &om, &mut self.ws,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AdamWState;

    #[test]
    fn zeta_fix_matches_paper_formula() {
        let mut t = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.0, -4.0, 5.0, 0.0]).unwrap();
        zeta_fix(&mut t);
        // zeta = (2+4)/2 = 3; negatives replaced by 3
        assert_eq!(t.data, vec![1.0, 3.0, 3.0, 3.0, 5.0, 0.0]);
        let mut ok = Tensor::new(vec![1, 3], vec![1.0, 2.0, 0.5]).unwrap();
        zeta_fix(&mut ok);
        assert_eq!(ok.data, vec![1.0, 2.0, 0.5]); // identity on nonneg input
    }

    #[test]
    fn full_rank_mlorc_equals_adamw() {
        // l = min(m, n): compression is lossless, trajectories coincide.
        let hp = OptHp::mlorc_adamw();
        let shape = [10usize, 10];
        let mut rng = Rng::new(0);
        let mut w1 = rng.gaussian_tensor(&shape, 1.0);
        let mut w2 = w1.clone();
        let mut mlorc = MlorcAdamWState::new(&shape, 10);
        let mut adamw = AdamWState::new(&shape);
        let mut om_rng = Rng::new(99);
        for _ in 0..5 {
            let g = rng.gaussian_tensor(&shape, 1.0);
            mlorc.step(&mut w1, &g, 1e-2, &hp, &mut om_rng);
            adamw.step(&mut w2, &g, 1e-2, &hp);
            assert!(w1.rel_err(&w2) < 1e-4, "rel {}", w1.rel_err(&w2));
        }
    }

    #[test]
    fn full_rank_mlorc_sgdm_equals_dense_sgdm() {
        // l = min(m, n): compression is lossless, so the factored SGDM
        // step must track the dense reference kernel.
        let hp = OptHp::sgdm();
        let shape = [9usize, 9];
        let mut rng = Rng::new(4);
        let mut w1 = rng.gaussian_tensor(&shape, 1.0);
        let mut w2 = w1.clone();
        let (mut mq, mut mb) = (Tensor::zeros(&[9, 9]), Tensor::zeros(&[9, 9]));
        let mut m_dense = Tensor::zeros(&shape);
        let mut ws = Workspace::new();
        let mut om_rng = Rng::new(77);
        for _ in 0..5 {
            let g = rng.gaussian_tensor(&shape, 1.0);
            let om = om_rng.gaussian_tensor(&[9, 9], 1.0);
            mlorc_sgdm_core(&mut w1, &g, &mut mq, &mut mb, 1e-2, &hp, &om, &mut ws);
            crate::optim::sgdm_host_step(&mut w2, &g, &mut m_dense, 1e-2, &hp);
            assert!(w1.rel_err(&w2) < 1e-4, "rel {}", w1.rel_err(&w2));
        }
    }

    #[test]
    fn fast_path_matches_direct_step() {
        // The factored+fused step must track the materialized direct step
        // given identical Omega draws — same algorithm, different schedule.
        let hp = OptHp::mlorc_adamw();
        let (m, n, l) = (24, 17, 4);
        let mut rng = Rng::new(5);
        let mut w_fast = rng.gaussian_tensor(&[m, n], 0.5);
        let mut w_dir = w_fast.clone();
        let mut fast = MlorcAdamWState::new(&[m, n], l);
        let (mut mq, mut mb) = (Tensor::zeros(&[m, l]), Tensor::zeros(&[l, n]));
        let (mut vq, mut vb) = (Tensor::zeros(&[m, l]), Tensor::zeros(&[l, n]));
        for t in 1..=4 {
            let g = rng.gaussian_tensor(&[m, n], 1.0);
            let om_m = rng.gaussian_tensor(&[n, l], 1.0);
            let om_v = rng.gaussian_tensor(&[n, l], 1.0);
            fast.step_with_omegas(&mut w_fast, &g, 1e-2, &hp, &om_m, &om_v);
            mlorc_adamw_step_direct(
                &mut w_dir, &g, &mut mq, &mut mb, &mut vq, &mut vb, t, 1e-2, &hp, &om_m, &om_v,
            );
            let rel = w_fast.rel_err(&w_dir);
            assert!(rel < 5e-3, "step {t}: rel {rel}");
        }
    }

    #[test]
    fn factored_step_gemm_audit() {
        // Acceptance shape of the fast path: per moment exactly one
        // O(m·n·l) GEMM touches a dense m×n result — the fused m-moment
        // reconstruction and the v-moment reconstruction — while every
        // sketch/projection GEMM has a thin output (≤ max(m,n)·l elems).
        let hp = OptHp::mlorc_adamw();
        let (m, n, l) = (40, 24, 4);
        let mut rng = Rng::new(3);
        let mut w = rng.gaussian_tensor(&[m, n], 0.5);
        let mut st = MlorcAdamWState::new(&[m, n], l);
        let g = rng.gaussian_tensor(&[m, n], 1.0);
        let om_m = rng.gaussian_tensor(&[n, l], 1.0);
        let om_v = rng.gaussian_tensor(&[n, l], 1.0);
        // warm the state so both moments have nonzero factors
        st.step_with_omegas(&mut w, &g, 1e-2, &hp, &om_m, &om_v);

        flops::start_recording();
        st.step_with_omegas(&mut w, &g, 1e-2, &hp, &om_m, &om_v);
        let recs = flops::finish_recording();

        let dense = m * n;
        let thin_cap = m.max(n) * l;
        let dense_nonfused: Vec<_> =
            recs.iter().filter(|r| !r.is_fused() && r.out_elems() == dense).collect();
        let fused: Vec<_> = recs.iter().filter(|r| r.is_fused()).collect();
        assert_eq!(dense_nonfused.len(), 1, "one dense recon (v moment): {recs:?}");
        assert_eq!(dense_nonfused[0].inner, l, "the dense recon is the O(m·n·l) QB product");
        assert_eq!(fused.len(), 1, "one fused recon (m moment): {recs:?}");
        for r in recs.iter().filter(|r| !r.is_fused() && r.out_elems() != dense) {
            assert!(
                r.out_elems() <= thin_cap,
                "sketch/projection GEMM must be thin: {r:?}"
            );
        }

        // Contrast: the direct step materializes both reconstructions.
        let (mut mq, mut mb) = (st.mq.clone(), st.mb.clone());
        let (mut vq, mut vb) = (st.vq.clone(), st.vb.clone());
        flops::start_recording();
        mlorc_adamw_step_direct(
            &mut w, &g, &mut mq, &mut mb, &mut vq, &mut vb, 3, 1e-2, &hp, &om_m, &om_v,
        );
        let direct = flops::finish_recording();
        let direct_dense = direct.iter().filter(|r| r.out_elems() == dense).count();
        assert_eq!(direct_dense, 2, "direct path reconstructs both moments: {direct:?}");
    }

    #[test]
    fn steady_state_steps_do_not_allocate() {
        let hp = OptHp::mlorc_adamw();
        let (m, n, l) = (32, 20, 4);
        let mut rng = Rng::new(8);
        let mut w = rng.gaussian_tensor(&[m, n], 0.5);
        let mut st = MlorcAdamWState::new(&[m, n], l);
        for _ in 0..3 {
            let g = rng.gaussian_tensor(&[m, n], 1.0);
            st.step(&mut w, &g, 1e-2, &hp, &mut rng);
        }
        let warm = st.ws.reuse_ratio();
        assert!(warm > 0.5, "workspace reuse after warmup: {warm}");
    }

    #[test]
    fn mlorc_adamw_converges_on_lowrank_quadratic() {
        // f(W) = 0.5 || W - W* ||^2 with rank-2 W*: gradients are low-rank
        // plus the current iterate, matching the paper's regime.
        let hp = OptHp::mlorc_adamw();
        let mut rng = Rng::new(1);
        let u = rng.gaussian_tensor(&[12, 2], 1.0);
        let v = rng.gaussian_tensor(&[2, 16], 1.0);
        let target = matmul(&u, &v);
        let mut w = Tensor::zeros(&[12, 16]);
        let mut st = MlorcAdamWState::new(&[12, 16], 4);
        let mut om_rng = Rng::new(7);
        for _ in 0..600 {
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            st.step(&mut w, &g, 0.05, &hp, &mut om_rng);
        }
        assert!(w.rel_err(&target) < 0.08, "rel {}", w.rel_err(&target));
    }

    #[test]
    fn mlorc_lion_update_magnitude() {
        let hp = OptHp::lion();
        let mut rng = Rng::new(2);
        let g = rng.gaussian_tensor(&[8, 8], 1.0);
        let mut w = Tensor::zeros(&[8, 8]);
        let mut st = MlorcLionState::new(&[8, 8], 4);
        st.step(&mut w, &g, 0.01, &hp, &mut rng);
        for (wi, gi) in w.data.iter().zip(&g.data) {
            if gi.abs() > 1e-6 {
                assert!((wi.abs() - 0.01).abs() < 1e-7);
                assert_eq!(wi.signum(), -gi.signum());
            }
        }
    }

    #[test]
    fn ablations_track_their_exact_half() {
        let hp = OptHp::mlorc_adamw();
        let mut rng = Rng::new(3);
        let g = rng.gaussian_tensor(&[6, 6], 1.0);
        let mut w = Tensor::zeros(&[6, 6]);
        let mut mm = MlorcMState::new(&[6, 6], 2);
        mm.step(&mut w, &g, 1e-3, &hp, &mut rng);
        for (vi, gi) in mm.v.data.iter().zip(&g.data) {
            assert!((vi - (1.0 - hp.beta2) * gi * gi).abs() < 1e-9);
        }
        let mut mv = MlorcVState::new(&[6, 6], 2);
        let mut w2 = Tensor::zeros(&[6, 6]);
        mv.step(&mut w2, &g, 1e-3, &hp, &mut rng);
        for (mi, gi) in mv.m.data.iter().zip(&g.data) {
            assert!((mi - (1.0 - hp.beta1) * gi).abs() < 1e-7);
        }
    }
}
