//! Pure-rust reference optimizers — host mirrors of the Layer-2 step
//! graphs (`python/compile/optim_steps.py`).
//!
//! Purposes:
//!  * cross-validation: `rust/tests/cross_validate.rs` runs the HLO step
//!    graphs and these mirrors side by side and asserts agreement — three
//!    independent implementations (jnp ref, Pallas, rust) must coincide;
//!  * the Theorem 3.3 experiment (`bench --experiment theory`) optimizes a
//!    synthetic smooth objective entirely on the host;
//!  * the coordinator's host stepping mode (`RunConfig::host_opt`), which
//!    updates per-parameter states through the `*_core` functions below in
//!    parallel across a thread pool;
//!  * unit/property tests of algebraic invariants with no PJRT dependency.
//!
//! Since the optimizer-matrix refactor the module also owns the
//! trait-based dispatch core: [`rules`] (the `UpdateRule` axis),
//! [`compress`] (the `MomentumCompressor` axis, which routes each
//! rule × layout pair to the `*_core` kernels) and [`registry`] (the
//! method/variant tables everything resolves through, plus the
//! [`Method`] handle re-exported as `config::Method`).

mod adamw;
pub mod bf16;
pub mod compress;
mod galore;
mod hparams;
mod ldadamw;
mod lion;
mod mlorc;
pub mod quant;
pub mod registry;
pub mod rules;

pub use adamw::AdamWState;
pub use bf16::{bf16_to_f32, f32_to_bf16_stochastic, round_to_nearest};
pub use compress::{
    step_class, AdaRank, ClassJob, Dense, GaloreProjector, LdProj, MomentStore,
    MomentumCompressor, RsvdQb, ADARANK_TAIL_FRAC,
};
pub use galore::{galore_core, galore_lion_core, galore_refresh_projector, GaloreState};
pub use hparams::OptHp;
pub use ldadamw::{ldadamw_core, LdAdamWState};
pub use lion::LionState;
pub use mlorc::{
    fused_adamw_band, fused_lion_band, fused_recon_adamw_apply, fused_recon_lion_apply,
    fused_recon_sgdm_apply, fused_sgdm_band, mlorc_adamw_core, mlorc_adamw_step_direct,
    mlorc_lion_core, mlorc_m_core, mlorc_sgdm_core, mlorc_v_core, zeta_fix, MlorcAdamWState,
    MlorcLionState, MlorcMState, MlorcVState,
};
pub use quant::{QTensor, QuantQb, Q8_BLOCK};
pub use registry::{CompKind, MatrixOpt, Method, MethodDesc, VariantDesc};
pub use rules::{
    orthogonalize_gradient, prodigy_bc, rule, sgdm_host_step, ProdigyState, RuleKind, UpdateRule,
};

use crate::tensor::Tensor;

/// Bias corrections c1 = 1/(1-beta1^t), c2 = 1/(1-beta2^t), t >= 1.
pub fn bias_corrections(hp: &OptHp, t: usize) -> (f32, f32) {
    let t = t as i32;
    (
        1.0 / (1.0 - hp.beta1.powi(t)),
        1.0 / (1.0 - hp.beta2.powi(t)),
    )
}

/// Adam-atan2 scale `a = 4/π`: `a·atan2(m̂, √v̂)` matches `m̂/√v̂` to first
/// order near zero while staying bounded and eps-free.
pub const ATAN2_SCALE: f32 = 1.273_239_5;

/// AdamW apply: w -= lr * (m*c1 / (sqrt(v*c2) + eps) + wd * w).
/// With `hp.use_atan2`, the ratio is replaced by the bounded eps-free
/// `ATAN2_SCALE * atan2(m̂, √v̂)` (same modifier branch as the fused
/// factored kernel in `mlorc::fused_adamw_band`).
/// Public so benches and external baselines measure the exact same apply.
pub fn adamw_apply(w: &mut Tensor, m: &Tensor, v: &Tensor, lr: f32, c1: f32, c2: f32, hp: &OptHp) {
    for ((wi, mi), vi) in w.data.iter_mut().zip(&m.data).zip(&v.data) {
        let mhat = mi * c1;
        let vhat = vi * c2;
        let dir = if hp.use_atan2 {
            ATAN2_SCALE * mhat.atan2(vhat.sqrt())
        } else {
            mhat / (vhat.sqrt() + hp.eps)
        };
        *wi -= lr * (dir + hp.weight_decay * *wi);
    }
}

/// One uncompressed AdamW step over raw state tensors (any shape) — the
/// host mirror of the `adamw` step graph, shared by the trainer's vector
/// path and `OptState::host_step`.
pub fn adamw_host_step(
    w: &mut Tensor,
    g: &Tensor,
    m: &mut Tensor,
    v: &mut Tensor,
    lr: f32,
    t: usize,
    hp: &OptHp,
) {
    for (mi, gi) in m.data.iter_mut().zip(&g.data) {
        *mi = hp.beta1 * *mi + (1.0 - hp.beta1) * gi;
    }
    for (vi, gi) in v.data.iter_mut().zip(&g.data) {
        *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
    }
    let (c1, c2) = bias_corrections(hp, t);
    adamw_apply(w, m, v, lr, c1, c2, hp);
}

/// One uncompressed Lion step over raw state tensors — host mirror of the
/// `lion` step graph (update from old momentum, then decay it).
pub fn lion_host_step(w: &mut Tensor, g: &Tensor, m: &mut Tensor, lr: f32, hp: &OptHp) {
    for ((wi, mi), gi) in w.data.iter_mut().zip(&m.data).zip(&g.data) {
        let c = hp.beta1 * mi + (1.0 - hp.beta1) * gi;
        *wi -= lr * (lion::sign(c) + hp.weight_decay * *wi);
    }
    for (mi, gi) in m.data.iter_mut().zip(&g.data) {
        *mi = hp.beta2 * *mi + (1.0 - hp.beta2) * gi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_corrections_shrink_to_one() {
        let hp = OptHp::adamw();
        let (c1a, c2a) = bias_corrections(&hp, 1);
        let (c1b, c2b) = bias_corrections(&hp, 10_000);
        assert!(c1a > c1b && c2a > c2b);
        assert!((c1b - 1.0).abs() < 1e-3);
        assert!((c2b - 1.0).abs() < 0.01);
        // step 1: c1 = 1/(1-beta1)
        assert!((c1a - 1.0 / (1.0 - hp.beta1)).abs() < 1e-4);
    }

    #[test]
    fn host_steps_match_reference_states() {
        let hp = OptHp::adamw();
        let mut rng = crate::linalg::Rng::new(4);
        let g = rng.gaussian_tensor(&[6, 5], 1.0);
        let mut w1 = rng.gaussian_tensor(&[6, 5], 1.0);
        let mut w2 = w1.clone();
        let mut st = AdamWState::new(&[6, 5]);
        let (mut m, mut v) = (Tensor::zeros(&[6, 5]), Tensor::zeros(&[6, 5]));
        for t in 1..=3 {
            st.step(&mut w1, &g, 1e-2, &hp);
            adamw_host_step(&mut w2, &g, &mut m, &mut v, 1e-2, t, &hp);
            assert_eq!(w1.data, w2.data, "adamw host step must be bit-identical");
        }

        let hp = OptHp::lion();
        let mut l1 = rng.gaussian_tensor(&[4, 4], 1.0);
        let mut l2 = l1.clone();
        let mut lst = LionState::new(&[4, 4]);
        let mut lm = Tensor::zeros(&[4, 4]);
        for _ in 0..3 {
            lst.step(&mut l1, &g_sub(&g), 1e-2, &hp);
            lion_host_step(&mut l2, &g_sub(&g), &mut lm, 1e-2, &hp);
            assert_eq!(l1.data, l2.data, "lion host step must be bit-identical");
        }
    }

    fn g_sub(g: &Tensor) -> Tensor {
        Tensor::new(vec![4, 4], g.data[..16].to_vec()).unwrap()
    }
}
