//! Pure-rust reference optimizers — host mirrors of the Layer-2 step
//! graphs (`python/compile/optim_steps.py`).
//!
//! Purposes:
//!  * cross-validation: `rust/tests/cross_validate.rs` runs the HLO step
//!    graphs and these mirrors side by side and asserts agreement — three
//!    independent implementations (jnp ref, Pallas, rust) must coincide;
//!  * the Theorem 3.3 experiment (`bench --experiment theory`) optimizes a
//!    synthetic smooth objective entirely on the host;
//!  * unit/property tests of algebraic invariants with no PJRT dependency.

mod adamw;
mod galore;
mod hparams;
mod ldadamw;
mod lion;
mod mlorc;

pub use adamw::AdamWState;
pub use galore::GaloreState;
pub use hparams::OptHp;
pub use ldadamw::LdAdamWState;
pub use lion::LionState;
pub use mlorc::{zeta_fix, MlorcAdamWState, MlorcLionState, MlorcMState, MlorcVState};

use crate::tensor::Tensor;

/// Bias corrections c1 = 1/(1-beta1^t), c2 = 1/(1-beta2^t), t >= 1.
pub fn bias_corrections(hp: &OptHp, t: usize) -> (f32, f32) {
    let t = t as i32;
    (
        1.0 / (1.0 - hp.beta1.powi(t)),
        1.0 / (1.0 - hp.beta2.powi(t)),
    )
}

/// AdamW apply: w -= lr * (m*c1 / (sqrt(v*c2) + eps) + wd * w).
pub(crate) fn adamw_apply(w: &mut Tensor, m: &Tensor, v: &Tensor, lr: f32, c1: f32, c2: f32, hp: &OptHp) {
    for ((wi, mi), vi) in w.data.iter_mut().zip(&m.data).zip(&v.data) {
        let mhat = mi * c1;
        let vhat = vi * c2;
        *wi -= lr * (mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * *wi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_corrections_shrink_to_one() {
        let hp = OptHp::adamw();
        let (c1a, c2a) = bias_corrections(&hp, 1);
        let (c1b, c2b) = bias_corrections(&hp, 10_000);
        assert!(c1a > c1b && c2a > c2b);
        assert!((c1b - 1.0).abs() < 1e-3);
        assert!((c2b - 1.0).abs() < 0.01);
        // step 1: c1 = 1/(1-beta1)
        assert!((c1a - 1.0 / (1.0 - hp.beta1)).abs() < 1e-4);
    }
}
