//! The optimizer registry: every (update rule × momentum compressor)
//! combination the system serves, as data.
//!
//! Two tables:
//!
//!  * [`VARIANTS`] — one [`VariantDesc`] per concrete state layout. A
//!    variant id is simultaneously the checkpoint-v2 `variant` tag and
//!    the step-graph method name, and carries the rule tag, the
//!    compressor layout and the host hyper-parameters. The variant is
//!    the single constructor/decoder for per-parameter state
//!    ([`VariantDesc::build`] / [`VariantDesc::decode`]).
//!  * [`METHODS`] — one [`MethodDesc`] per CLI-level method id (the rows
//!    of the paper's tables): which variant compressed matrix parameters
//!    take, which variant the plain path (vectors, embeddings, heads,
//!    LoRA adapters) takes, the LoRA routing flag and the default LR.
//!
//! The CLI, trainer, checkpoint loader, serve host engine and bench
//! harness all resolve methods through [`Method`] — adding a method is
//! one `MethodDesc` line here (plus, for a genuinely new rule or
//! compressor, one impl in `rules.rs` / `compress.rs`). `mlorc_sgdm`,
//! `galore_lion` and the dense `full_sgdm` baseline exist exactly this
//! way.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::tensor::{Tensor, TensorBf16, TensorU8};
use crate::util::json::Json;

use super::bf16;
use super::compress::{
    AdaRank, Dense, GaloreProjector, LdProj, MomentStore, MomentumCompressor, RsvdQb,
};
use super::quant::{QMoment, QTensor, QuantQb, Q8_BLOCK, Q8_NAMES};
use super::rules::{self, orthogonalize_gradient, ProdigyState, RuleKind, UpdateRule};
use super::OptHp;

// ------------------------------------------------------------- variants

/// Compressor layout tag — const-constructible so the variant table can
/// be a static. `RsvdQb`'s mask says which rule moments are factored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompKind {
    Dense,
    RsvdQb { factored: &'static [bool] },
    /// RsvdQb with an online per-parameter rank schedule (all moments
    /// factored; rank only shrinks, floored at `--rank-min`).
    AdaRank,
    /// RsvdQb with 8-bit blockwise-quantized factors (all moments).
    QuantQb,
    Galore,
    LdProj,
}

/// One concrete (rule × compressor) state layout.
#[derive(Debug)]
pub struct VariantDesc {
    /// Checkpoint `variant` tag == step-graph method name.
    pub id: &'static str,
    pub rule: RuleKind,
    pub comp: CompKind,
    /// Host-path hyper-parameters (the graph path reads the manifest's).
    pub hp: fn() -> OptHp,
    /// Master weights stored as a bf16 plane with stochastic rounding —
    /// an opt-in weight layout on top of the momentum compression
    /// (`optim::bf16`; checkpoint dtype-3 plane `w16`).
    pub bf16: bool,
}

/// Shorthand for the 15 pre-wave rows: f32 weights, no wrappers.
const NO_BF16: bool = false;

pub static VARIANTS: &[VariantDesc] = &[
    VariantDesc {
        id: "adamw",
        rule: RuleKind::AdamW,
        comp: CompKind::Dense,
        hp: OptHp::adamw,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "lion",
        rule: RuleKind::Lion,
        comp: CompKind::Dense,
        hp: OptHp::lion,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "sgdm",
        rule: RuleKind::SgdM,
        comp: CompKind::Dense,
        hp: OptHp::sgdm,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_adamw",
        rule: RuleKind::AdamW,
        comp: CompKind::RsvdQb { factored: &[true, true] },
        hp: OptHp::mlorc_adamw,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_m",
        rule: RuleKind::AdamW,
        comp: CompKind::RsvdQb { factored: &[true, false] },
        hp: OptHp::mlorc_adamw,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_v",
        rule: RuleKind::AdamW,
        comp: CompKind::RsvdQb { factored: &[false, true] },
        hp: OptHp::mlorc_adamw,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_lion",
        rule: RuleKind::Lion,
        comp: CompKind::RsvdQb { factored: &[true] },
        hp: OptHp::lion,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_sgdm",
        rule: RuleKind::SgdM,
        comp: CompKind::RsvdQb { factored: &[true] },
        hp: OptHp::sgdm,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_adarank",
        rule: RuleKind::AdamW,
        comp: CompKind::AdaRank,
        hp: OptHp::mlorc_adamw,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_adarank_lion",
        rule: RuleKind::Lion,
        comp: CompKind::AdaRank,
        hp: OptHp::lion,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_q8",
        rule: RuleKind::AdamW,
        comp: CompKind::QuantQb,
        hp: OptHp::mlorc_adamw,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_q8_lion",
        rule: RuleKind::Lion,
        comp: CompKind::QuantQb,
        hp: OptHp::lion,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "galore",
        rule: RuleKind::AdamW,
        comp: CompKind::Galore,
        hp: OptHp::adamw,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "galore_lion",
        rule: RuleKind::Lion,
        comp: CompKind::Galore,
        hp: OptHp::lion,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "ldadamw",
        rule: RuleKind::AdamW,
        comp: CompKind::LdProj,
        hp: OptHp::adamw,
        bf16: NO_BF16,
    },
    // -- the second optimizer wave: Prodigy D-adaptation, bf16 stochastic-
    //    rounding weights, and the update-rule modifier spellings --------
    VariantDesc {
        id: "prodigy",
        rule: RuleKind::Prodigy,
        comp: CompKind::Dense,
        hp: OptHp::prodigy,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_prodigy",
        rule: RuleKind::Prodigy,
        comp: CompKind::RsvdQb { factored: &[true, true] },
        hp: OptHp::prodigy,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "adamw_bf16",
        rule: RuleKind::AdamW,
        comp: CompKind::Dense,
        hp: OptHp::adamw,
        bf16: true,
    },
    VariantDesc {
        id: "mlorc_adamw_bf16",
        rule: RuleKind::AdamW,
        comp: CompKind::RsvdQb { factored: &[true, true] },
        hp: OptHp::mlorc_adamw,
        bf16: true,
    },
    VariantDesc {
        id: "mlorc_adamw_atan2",
        rule: RuleKind::AdamW,
        comp: CompKind::RsvdQb { factored: &[true, true] },
        hp: OptHp::mlorc_adamw_atan2,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_adamw_grams",
        rule: RuleKind::AdamW,
        comp: CompKind::RsvdQb { factored: &[true, true] },
        hp: OptHp::mlorc_adamw_grams,
        bf16: NO_BF16,
    },
    VariantDesc {
        id: "mlorc_adamw_ortho",
        rule: RuleKind::AdamW,
        comp: CompKind::RsvdQb { factored: &[true, true] },
        hp: OptHp::mlorc_adamw_orthograd,
        bf16: NO_BF16,
    },
];

/// Look a state layout up by its stable id.
pub fn variant(id: &str) -> Result<&'static VariantDesc> {
    VARIANTS
        .iter()
        .find(|v| v.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown optimizer state variant '{id}'"))
}

/// The exemplars' `vector_reshape` trick: the 2D *effective shape* a 1D
/// parameter of `numel` elements folds into so factored compressors
/// apply — `[a, numel/a]` for the largest divisor `a ≤ √numel`. Returns
/// `None` when no useful fold exists: `numel` prime (`a` would be 1) or
/// the short side under the sketch rank `l` (the factors would be larger
/// than the dense momentum they replace).
pub fn effective_shape(numel: usize, l: usize) -> Option<[usize; 2]> {
    let mut best = 1usize;
    let mut a = 1usize;
    while a * a <= numel {
        if numel % a == 0 {
            best = a;
        }
        a += 1;
    }
    if best < 2 || best < l {
        return None;
    }
    Some([best, numel / best])
}

/// Exact f32 round-trip through checkpoint metadata: bit pattern as hex.
fn f32_hex(x: f32) -> String {
    format!("{:08x}", x.to_bits())
}

fn f32_from_hex_meta(meta: &Json, key: &str) -> Result<f32> {
    let s = meta.req(key)?.as_str()?;
    Ok(f32::from_bits(u32::from_str_radix(s, 16)?))
}

impl VariantDesc {
    pub fn rule(&self) -> &'static dyn UpdateRule {
        rules::rule(self.rule)
    }

    pub fn n_moments(&self) -> usize {
        self.rule().n_moments()
    }

    /// Fresh zero state for a parameter of `shape`; `l` is the sketch /
    /// projector rank. Adaptive-rank layouts floor at rank 1 here — use
    /// [`VariantDesc::build_opts`] to set `--rank-min`.
    pub fn build(&'static self, shape: &[usize], l: usize) -> Result<MatrixOpt> {
        self.build_opts(shape, l, 1)
    }

    /// [`VariantDesc::build`] with the adaptive-rank floor given
    /// explicitly (ignored by fixed-rank layouts).
    pub fn build_opts(
        &'static self,
        shape: &[usize],
        l: usize,
        rank_min: usize,
    ) -> Result<MatrixOpt> {
        let rule = self.rule();
        // 1D parameters under a non-dense layout fold through their 2D
        // effective shape (the exemplars' `vector_reshape`): the
        // compressor state is built on `[a, b]`, the weight keeps its 1D
        // shape and `MatrixOpt::step` swaps the view per step.
        let eff;
        let folded;
        let shape: &[usize] = if shape.len() == 1 && self.comp != CompKind::Dense {
            match effective_shape(shape[0], l) {
                Some([a, b]) => {
                    eff = vec![a, b];
                    folded = Some([a, b]);
                    &eff
                }
                None => bail!(
                    "variant '{}': 1D parameter of {} elements has no rank-{} effective shape",
                    self.id,
                    shape[0],
                    l
                ),
            }
        } else {
            folded = None;
            shape
        };
        let comp: Box<dyn MomentumCompressor> = match self.comp {
            CompKind::Dense => Box::new(Dense::new(rule, shape)),
            CompKind::RsvdQb { factored } => {
                if factored.len() != rule.n_moments() {
                    bail!(
                        "variant '{}': {} factored-mask entries for a {}-moment rule",
                        self.id,
                        factored.len(),
                        rule.n_moments()
                    );
                }
                Box::new(RsvdQb::new(factored, shape, l)?)
            }
            CompKind::AdaRank => Box::new(AdaRank::new(rule.n_moments(), shape, l, rank_min)?),
            CompKind::QuantQb => Box::new(QuantQb::new(rule.n_moments(), shape, l)?),
            CompKind::Galore => Box::new(GaloreProjector::new(rule.n_moments(), shape, l)?),
            CompKind::LdProj => Box::new(LdProj::new(shape, l)?),
        };
        let numel: usize = shape.iter().product();
        // Wrapper states are allocated eagerly (zeros) so the live
        // footprint equals the closed-form accounting from step 0;
        // content is captured at t == 1 inside `MatrixOpt::step`.
        let prodigy = match self.rule {
            RuleKind::Prodigy => Some(ProdigyState::new(numel)),
            _ => None,
        };
        let w_bf16 = if self.bf16 { Some(TensorBf16::zeros(shape)) } else { None };
        Ok(MatrixOpt { variant: self, comp, prodigy, w_bf16, folded })
    }

    /// Rebuild state from checkpoint metadata plus tensor lookups
    /// (`take(field)` yields the stored `<param>/<field>` f32 tensor,
    /// `take_u8` its u8 counterpart for quantized layouts, `take_b16`
    /// the bf16 weight plane). The inverse of
    /// `MatrixOpt::{tensor_fields, u8_fields, bf16_fields, ckpt_meta_into}`.
    pub fn decode(
        &'static self,
        meta: &Json,
        take: &mut dyn FnMut(&'static str) -> Result<Tensor>,
        take_u8: &mut dyn FnMut(&'static str) -> Result<TensorU8>,
        take_b16: &mut dyn FnMut(&'static str) -> Result<TensorBf16>,
    ) -> Result<MatrixOpt> {
        let rule = self.rule();
        let comp: Box<dyn MomentumCompressor> = match self.comp {
            CompKind::Dense => {
                let names = rule.moment_names();
                let moments =
                    names.iter().map(|&n| take(n)).collect::<Result<Vec<_>>>()?;
                Box::new(Dense::from_parts(names, moments))
            }
            CompKind::RsvdQb { factored } => {
                let mut stores = Vec::with_capacity(factored.len());
                for (k, &f) in factored.iter().enumerate() {
                    // same table the encode side (RsvdQb::tensor_fields) uses
                    let (dense, qn, bn) = super::compress::QB_NAMES[k];
                    stores.push(if f {
                        MomentStore::Factored { q: take(qn)?, b: take(bn)? }
                    } else {
                        MomentStore::Dense(take(dense)?)
                    });
                }
                Box::new(RsvdQb::from_stores(stores))
            }
            CompKind::AdaRank => {
                let mut stores = Vec::with_capacity(rule.n_moments());
                for k in 0..rule.n_moments() {
                    let (_, qn, bn) = super::compress::QB_NAMES[k];
                    // shapes carry the current (possibly shrunken) rank
                    stores.push((take(qn)?, take(bn)?));
                }
                Box::new(AdaRank::from_parts(
                    stores,
                    meta.req("rank_min")?.as_usize()?,
                    meta.req("shrinks")?.as_usize()?,
                ))
            }
            CompKind::QuantQb => {
                let block = match meta.get("q8_block") {
                    Some(v) => v.as_usize()?,
                    None => Q8_BLOCK,
                };
                let mut moments = Vec::with_capacity(rule.n_moments());
                for k in 0..rule.n_moments() {
                    let (q_q8, q_sc, b_q8, b_sc) = Q8_NAMES[k];
                    moments.push(QMoment {
                        q: QTensor::from_parts(take_u8(q_q8)?, take(q_sc)?, block)?,
                        b: QTensor::from_parts(take_u8(b_q8)?, take(b_sc)?, block)?,
                    });
                }
                Box::new(QuantQb::from_moments(moments, block))
            }
            CompKind::Galore => {
                let p = take("p")?;
                let mut lo = vec![take("m_lo")?];
                if rule.n_moments() > 1 {
                    lo.push(take("v_lo")?);
                }
                Box::new(GaloreProjector::from_parts(
                    p,
                    lo,
                    meta.req("left")?.as_bool()?,
                    meta.req("refreshed")?.as_bool()?,
                ))
            }
            CompKind::LdProj => Box::new(LdProj {
                p: take("p")?,
                m_lo: take("m_lo")?,
                v_lo: take("v_lo")?,
                e: take("e")?,
                left: meta.req("left")?.as_bool()?,
            }),
        };
        let prodigy = match self.rule {
            RuleKind::Prodigy => Some(ProdigyState {
                d: f32_from_hex_meta(meta, "prodigy_d")?,
                d_num: f32_from_hex_meta(meta, "prodigy_dnum")?,
                p0: take("p0")?,
                s: take("s")?,
            }),
            _ => None,
        };
        let w_bf16 = if self.bf16 { Some(take_b16("w16")?) } else { None };
        let folded = match (meta.get("folded_rows"), meta.get("folded_cols")) {
            (Some(r), Some(c)) => Some([r.as_usize()?, c.as_usize()?]),
            _ => None,
        };
        Ok(MatrixOpt { variant: self, comp, prodigy, w_bf16, folded })
    }

    /// Optimizer-state *element* count for one (m, n) matrix at rank `r`
    /// — the closed-form Table 1 column, derived from the layout instead
    /// of hand-written per method. For quantized layouts the elements are
    /// codes, not floats — use [`VariantDesc::state_bytes`] for memory;
    /// for adaptive-rank layouts this is the upper bound at the initial
    /// rank (the live rank only shrinks).
    pub fn state_floats(&self, m: usize, n: usize, r: usize) -> usize {
        let nm = self.n_moments();
        match self.comp {
            CompKind::Dense => nm * m * n,
            CompKind::RsvdQb { factored } => factored
                .iter()
                .map(|&f| if f { r * (m + n) } else { m * n })
                .sum(),
            // every moment factored (rank shrinks at runtime) / quantized
            CompKind::AdaRank | CompKind::QuantQb => nm * r * (m + n),
            // projector on the short side + nm low-dim moments
            CompKind::Galore => m.min(n) * r + nm * m.max(n) * r,
            // like galore, plus the full-size error-feedback buffer
            CompKind::LdProj => m.min(n) * r + nm * m.max(n) * r + m * n,
        }
    }

    /// Optimizer-state footprint in *bytes* for one (m, n) matrix at rank
    /// `r` — 4x [`VariantDesc::state_floats`] for f32 layouts; quantized
    /// layouts pay 1 byte per code plus one f32 scale per
    /// [`Q8_BLOCK`]-element block of each factor.
    pub fn state_bytes(&self, m: usize, n: usize, r: usize) -> usize {
        match self.comp {
            CompKind::QuantQb => {
                let (q_elems, b_elems) = (m * r, r * n);
                let scales =
                    q_elems.div_ceil(Q8_BLOCK).max(1) + b_elems.div_ceil(Q8_BLOCK).max(1);
                self.n_moments() * (q_elems + b_elems + 4 * scales)
            }
            _ => 4 * self.state_floats(m, n, r),
        }
    }

    /// Bytes of wrapper state this variant keeps *outside* the momentum
    /// compressor for a `numel`-element parameter: Prodigy's sliced
    /// statistics (`p0`, `s`) plus its two scalars, and the bf16 weight
    /// plane. Zero for every pre-wave variant.
    pub fn wrapper_bytes(&self, numel: usize) -> usize {
        let mut b = 0;
        if self.rule == RuleKind::Prodigy {
            b += 4 * (2 * ProdigyState::sliced_len(numel) + 2);
        }
        if self.bf16 {
            b += 2 * numel;
        }
        b
    }
}

// ------------------------------------------------------------ MatrixOpt

/// One parameter's optimizer: a variant (rule × compressor) plus the
/// compressor-owned state. Owns the checkpoint-v2 surface, `state_bytes`,
/// RNG-stream handling (draws are delegated to the compressor so the
/// schedule is layout-defined) and the fused reconstruct-apply routing.
#[derive(Debug)]
pub struct MatrixOpt {
    variant: &'static VariantDesc,
    comp: Box<dyn MomentumCompressor>,
    /// Prodigy D-adaptation state when `variant.rule == Prodigy`.
    prodigy: Option<ProdigyState>,
    /// bf16 master-weight plane when `variant.bf16` (`optim::bf16`).
    w_bf16: Option<TensorBf16>,
    /// 2D effective shape a 1D parameter folds through per step
    /// ([`effective_shape`]); `None` for genuinely-2D parameters.
    folded: Option<[usize; 2]>,
}

impl Clone for MatrixOpt {
    fn clone(&self) -> MatrixOpt {
        MatrixOpt {
            variant: self.variant,
            comp: self.comp.clone_box(),
            prodigy: self.prodigy.clone(),
            w_bf16: self.w_bf16.clone(),
            folded: self.folded,
        }
    }
}

/// Grams sign convention: `sign(0) = 0`, so a zero gradient zeroes the
/// displacement rather than keeping the Adam step.
fn grams_sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

impl MatrixOpt {
    pub fn variant(&self) -> &'static VariantDesc {
        self.variant
    }

    pub fn rule(&self) -> &'static dyn UpdateRule {
        self.variant.rule()
    }

    /// Host-path hyper-parameters of this state's step.
    pub fn hp(&self) -> OptHp {
        (self.variant.hp)()
    }

    pub fn comp(&self) -> &dyn MomentumCompressor {
        self.comp.as_ref()
    }

    pub fn comp_mut(&mut self) -> &mut dyn MomentumCompressor {
        self.comp.as_mut()
    }

    /// The fold this parameter routes through, if any.
    pub fn folded(&self) -> Option<[usize; 2]> {
        self.folded
    }

    /// Whether this state must step through the full [`MatrixOpt::step`]
    /// orchestration (Prodigy rewrite, bf16 plane, fold view, modifier
    /// transforms) rather than the shape-class fused kernels — the
    /// batched path checks this before any compressor downcast.
    pub fn needs_member_step(&self) -> bool {
        let hp = self.hp();
        self.prodigy.is_some()
            || self.w_bf16.is_some()
            || self.folded.is_some()
            || hp.use_atan2
            || hp.use_grams
            || hp.use_orthograd
    }

    /// Checkpoint-v2 f32 fields: the compressor's, plus Prodigy's sliced
    /// statistics when the rule carries them.
    pub fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        let mut f = self.comp.tensor_fields();
        if let Some(ps) = &self.prodigy {
            f.push(("p0", &ps.p0));
            f.push(("s", &ps.s));
        }
        f
    }

    pub fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        let mut f = self.comp.tensor_fields_mut();
        if let Some(ps) = &mut self.prodigy {
            f.push(("p0", &mut ps.p0));
            f.push(("s", &mut ps.s));
        }
        f
    }

    /// Checkpoint-v2 bf16 planes (dtype 3): the stochastic-rounding
    /// weight plane, when this variant stores one.
    pub fn bf16_fields(&self) -> Vec<(&'static str, &TensorBf16)> {
        self.w_bf16.iter().map(|p| ("w16", p)).collect()
    }

    pub fn bf16_fields_mut(&mut self) -> Vec<(&'static str, &mut TensorBf16)> {
        self.w_bf16.iter_mut().map(|p| ("w16", p)).collect()
    }

    /// Per-parameter checkpoint metadata: the compressor's flags plus the
    /// wrapper scalars — Prodigy's `d`/`d_num` as exact bit-pattern hex
    /// strings (the meta json must round-trip bit-identically) and the
    /// fold dimensions.
    pub fn ckpt_meta_into(&self, j: &mut Json) {
        self.comp.flags_into(j);
        if let Some(ps) = &self.prodigy {
            j.set("prodigy_d", Json::str(f32_hex(ps.d)));
            j.set("prodigy_dnum", Json::str(f32_hex(ps.d_num)));
        }
        if let Some([a, b]) = self.folded {
            j.set("folded_rows", Json::num(a as f64));
            j.set("folded_cols", Json::num(b as f64));
        }
    }

    /// Live optimizer-state footprint: compressor state plus wrapper
    /// state (Prodigy statistics, bf16 plane).
    pub fn state_bytes(&self) -> usize {
        let mut b = self.comp.state_bytes();
        if let Some(ps) = &self.prodigy {
            b += 4 * (ps.p0.data.len() + ps.s.data.len() + 2);
        }
        if let Some(p) = &self.w_bf16 {
            b += p.size_bytes();
        }
        b
    }

    /// One optimizer step entirely on the host. `t` is 1-based; `rng` is
    /// this parameter's own Omega stream (bf16 rounding draws come from
    /// the same stream, *after* the compressor's sketch draws).
    pub fn step(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut crate::linalg::Rng,
        ws: &mut crate::linalg::Workspace,
    ) -> Result<()> {
        let hp = self.hp();

        // 1D fold: swap the weight's view to the 2D effective shape for
        // the duration of the step (data is contiguous row-major, so the
        // swap is free) and mirror the gradient.
        let unfolded = match self.folded {
            Some([a, b]) => Some(std::mem::replace(&mut w.shape, vec![a, b])),
            None => None,
        };
        let folded_g;
        let mut g_cur: &Tensor = if let Some([a, b]) = self.folded {
            folded_g = Tensor::new(vec![a, b], g.data.clone())?;
            &folded_g
        } else {
            g
        };

        // Seed the bf16 plane from the incoming weights once, snapping
        // the working copy onto the bf16 grid before the first step.
        if t == 1 {
            if let Some(plane) = self.w_bf16.as_mut() {
                bf16::seed_plane(w, plane);
            }
        }

        let ortho_g;
        if hp.use_orthograd {
            ortho_g = orthogonalize_gradient(w, g_cur);
            g_cur = &ortho_g;
        }

        let w_before = if hp.use_grams { Some(w.data.clone()) } else { None };

        // Prodigy: update the D estimate, then reduce the inner step to
        // the stock bias-corrected AdamW kernel on D-scaled inputs —
        //   g' = d·g, lr' = d·lr, eps' = √c2·d²·eps, wd' = bc·wd
        // (the moments become d·m and d²·v, so √(c2·v') = d·√c2·√v and
        // the d² on eps factors the denominator as d·√c2·(√v + d·eps))
        // reproduces the reference  dlr·m/(√v + d·eps)  exactly, so
        // every compressor composes with D-adaptation, no new kernel.
        let mut rule = self.variant.rule();
        let mut hp_eff = hp;
        let mut lr_eff = lr;
        let scaled_g;
        if let Some(ps) = self.prodigy.as_mut() {
            let d = ps.update(&w.data, &g_cur.data, lr, t, &hp);
            let (_, c2) = super::bias_corrections(&hp, t);
            hp_eff.eps = c2.sqrt() * d * d * hp.eps;
            hp_eff.weight_decay = rules::prodigy_bc(&hp, t) * hp.weight_decay;
            lr_eff = d * lr;
            let mut sg = g_cur.clone();
            for x in sg.data.iter_mut() {
                *x *= d;
            }
            scaled_g = sg;
            g_cur = &scaled_g;
            rule = rules::rule(RuleKind::AdamW);
        }

        let res = self.comp.step(rule, &hp_eff, w, g_cur, lr_eff, t, rng, ws);

        if res.is_ok() {
            // Grams: keep the Adam step's magnitude, take the gradient's
            // sign — w = w0 - |Δ|·sign(g), elementwise.
            if let Some(w0) = &w_before {
                for ((wi, w0i), gi) in w.data.iter_mut().zip(w0).zip(&g_cur.data) {
                    *wi = w0i - (*wi - w0i).abs() * grams_sign(*gi);
                }
            }
            // Store back through stochastic rounding and snap the working
            // copy, so the visible weights always live on the bf16 grid.
            if let Some(plane) = self.w_bf16.as_mut() {
                bf16::store_stochastic(w, plane, rng);
            }
        }

        if let Some(s) = unfolded {
            w.shape = s;
        }
        res
    }
}

// -------------------------------------------------------------- methods

/// One CLI-level optimization method — a row of the paper's tables.
#[derive(Debug)]
pub struct MethodDesc {
    pub id: &'static str,
    pub aliases: &'static [&'static str],
    /// Variant for *compressed matrix* parameters.
    pub matrix: &'static str,
    /// Variant for vectors/embeddings/heads (and LoRA adapters).
    pub plain: &'static str,
    /// Uses the LoRA adapter graphs instead of full fwd/bwd.
    pub lora: bool,
    /// Whether AOT-lowered step graphs exist for this method's variants.
    /// Host-only methods (the post-refactor combos) need `--host-opt` or
    /// the serve host engine until their graphs are lowered.
    pub graphed: bool,
    /// Route foldable 1D parameters through the matrix variant via their
    /// 2D [`effective_shape`] (the exemplars' `vector_reshape`) instead
    /// of the plain dense path. Unfoldable 1D shapes (prime length,
    /// short side under the sketch rank) still fall back to `plain`.
    pub fold: bool,
    /// Paper-tuned default peak LR for the math-chain-style LM task.
    pub default_lr: f32,
}

pub const FULL_ADAMW: MethodDesc = MethodDesc {
    id: "full_adamw",
    aliases: &["full", "adamw"],
    matrix: "adamw",
    plain: "adamw",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 4e-4,
};
pub const FULL_LION: MethodDesc = MethodDesc {
    id: "full_lion",
    aliases: &["lion"],
    matrix: "lion",
    plain: "lion",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 5e-5,
};
pub const MLORC_ADAMW: MethodDesc = MethodDesc {
    id: "mlorc_adamw",
    aliases: &["mlorc"],
    matrix: "mlorc_adamw",
    plain: "adamw",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 7e-4,
};
pub const MLORC_LION: MethodDesc = MethodDesc {
    id: "mlorc_lion",
    aliases: &[],
    matrix: "mlorc_lion",
    plain: "lion",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 5e-5,
};
pub const MLORC_M: MethodDesc = MethodDesc {
    id: "mlorc_m",
    aliases: &[],
    matrix: "mlorc_m",
    plain: "adamw",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 7e-4,
};
pub const MLORC_V: MethodDesc = MethodDesc {
    id: "mlorc_v",
    aliases: &[],
    matrix: "mlorc_v",
    plain: "adamw",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 7e-4,
};
pub const LORA_ADAMW: MethodDesc = MethodDesc {
    id: "lora_adamw",
    aliases: &["lora"],
    matrix: "adamw",
    plain: "adamw",
    lora: true,
    graphed: true,
    fold: false,
    default_lr: 2e-3,
};
pub const LORA_LION: MethodDesc = MethodDesc {
    id: "lora_lion",
    aliases: &[],
    matrix: "lion",
    plain: "lion",
    lora: true,
    graphed: true,
    fold: false,
    default_lr: 2e-4,
};
pub const GALORE: MethodDesc = MethodDesc {
    id: "galore",
    aliases: &[],
    matrix: "galore",
    plain: "adamw",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 3e-3,
};
pub const LDADAMW: MethodDesc = MethodDesc {
    id: "ldadamw",
    aliases: &[],
    matrix: "ldadamw",
    plain: "adamw",
    lora: false,
    graphed: true,
    fold: false,
    default_lr: 1e-3,
};
// Combinations the trait split makes free: SGD-momentum under MLorc
// compression, a dense SGDM baseline, and GaLore × Lion.
pub const FULL_SGDM: MethodDesc = MethodDesc {
    id: "full_sgdm",
    aliases: &["sgdm"],
    matrix: "sgdm",
    plain: "sgdm",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 1e-2,
};
pub const MLORC_SGDM: MethodDesc = MethodDesc {
    id: "mlorc_sgdm",
    aliases: &[],
    matrix: "mlorc_sgdm",
    plain: "sgdm",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 1e-2,
};
pub const GALORE_LION: MethodDesc = MethodDesc {
    id: "galore_lion",
    aliases: &[],
    matrix: "galore_lion",
    plain: "lion",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 2e-4,
};
// The second wave of compressors the trait seam was built for: an
// adaptive-rank RsvdQb (rank shrinks online from the retained spectral
// energy of B) and 8-bit blockwise-quantized factors — each composed
// with both AdamW and Lion in one line here.
pub const MLORC_ADARANK: MethodDesc = MethodDesc {
    id: "mlorc_adarank",
    aliases: &["adarank"],
    matrix: "mlorc_adarank",
    plain: "adamw",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 7e-4,
};
pub const MLORC_ADARANK_LION: MethodDesc = MethodDesc {
    id: "mlorc_adarank_lion",
    aliases: &[],
    matrix: "mlorc_adarank_lion",
    plain: "lion",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 5e-5,
};
pub const MLORC_Q8: MethodDesc = MethodDesc {
    id: "mlorc_q8",
    aliases: &["q8"],
    matrix: "mlorc_q8",
    plain: "adamw",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 7e-4,
};
pub const MLORC_Q8_LION: MethodDesc = MethodDesc {
    id: "mlorc_q8_lion",
    aliases: &[],
    matrix: "mlorc_q8_lion",
    plain: "lion",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 5e-5,
};
// The second *optimizer* wave: Prodigy D-adaptation under MLorc
// compression (exemplar `MLorc_Prodigy`), bf16 stochastic-rounding
// master weights, and the exemplars' one-flag update modifiers — all
// host-only until their step graphs are lowered. The Prodigy and bf16
// rows also fold 1D parameters through their effective shapes.
pub const MLORC_PRODIGY: MethodDesc = MethodDesc {
    id: "mlorc_prodigy",
    aliases: &["prodigy"],
    matrix: "mlorc_prodigy",
    plain: "prodigy",
    lora: false,
    graphed: false,
    fold: true,
    // D-adaptation: lr is a multiplier on the learned D, not a rate.
    default_lr: 1.0,
};
pub const MLORC_ADAMW_BF16: MethodDesc = MethodDesc {
    id: "mlorc_adamw_bf16",
    aliases: &["bf16"],
    matrix: "mlorc_adamw_bf16",
    plain: "adamw_bf16",
    lora: false,
    graphed: false,
    fold: true,
    default_lr: 7e-4,
};
pub const MLORC_ADAMW_ATAN2: MethodDesc = MethodDesc {
    id: "mlorc_adamw_atan2",
    aliases: &["atan2"],
    matrix: "mlorc_adamw_atan2",
    plain: "adamw",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 7e-4,
};
pub const MLORC_ADAMW_GRAMS: MethodDesc = MethodDesc {
    id: "mlorc_adamw_grams",
    aliases: &["grams"],
    matrix: "mlorc_adamw_grams",
    plain: "adamw",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 7e-4,
};
pub const MLORC_ADAMW_ORTHO: MethodDesc = MethodDesc {
    id: "mlorc_adamw_ortho",
    aliases: &["orthograd"],
    matrix: "mlorc_adamw_ortho",
    plain: "adamw",
    lora: false,
    graphed: false,
    fold: false,
    default_lr: 7e-4,
};

/// Every registered method, pre-existing ids first (table/report order).
pub static METHODS: &[&MethodDesc] = &[
    &FULL_ADAMW,
    &FULL_LION,
    &MLORC_ADAMW,
    &MLORC_LION,
    &MLORC_M,
    &MLORC_V,
    &LORA_ADAMW,
    &LORA_LION,
    &GALORE,
    &LDADAMW,
    &FULL_SGDM,
    &MLORC_SGDM,
    &GALORE_LION,
    &MLORC_ADARANK,
    &MLORC_ADARANK_LION,
    &MLORC_Q8,
    &MLORC_Q8_LION,
    &MLORC_PRODIGY,
    &MLORC_ADAMW_BF16,
    &MLORC_ADAMW_ATAN2,
    &MLORC_ADAMW_GRAMS,
    &MLORC_ADAMW_ORTHO,
];

/// Optimization method handle — compares, hashes and prints by id, so
/// the descriptor constants below can live anywhere in memory.
#[derive(Clone, Copy)]
pub struct Method(&'static MethodDesc);

impl PartialEq for Method {
    fn eq(&self, other: &Method) -> bool {
        std::ptr::eq(self.0, other.0) || self.0.id == other.0.id
    }
}

impl Eq for Method {}

impl std::hash::Hash for Method {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.0.id.hash(state);
    }
}

impl std::fmt::Debug for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0.id)
    }
}

#[allow(non_upper_case_globals)]
impl Method {
    // Named handles, kept under the historical variant spellings so
    // expression-position call sites read unchanged.
    pub const FullAdamW: Method = Method(&FULL_ADAMW);
    pub const FullLion: Method = Method(&FULL_LION);
    pub const FullSgdM: Method = Method(&FULL_SGDM);
    pub const MlorcAdamW: Method = Method(&MLORC_ADAMW);
    pub const MlorcLion: Method = Method(&MLORC_LION);
    pub const MlorcM: Method = Method(&MLORC_M);
    pub const MlorcV: Method = Method(&MLORC_V);
    pub const MlorcSgdM: Method = Method(&MLORC_SGDM);
    pub const LoraAdamW: Method = Method(&LORA_ADAMW);
    pub const LoraLion: Method = Method(&LORA_LION);
    pub const Galore: Method = Method(&GALORE);
    pub const GaloreLion: Method = Method(&GALORE_LION);
    pub const LdAdamW: Method = Method(&LDADAMW);
    pub const MlorcAdaRank: Method = Method(&MLORC_ADARANK);
    pub const MlorcQ8: Method = Method(&MLORC_Q8);
    pub const MlorcProdigy: Method = Method(&MLORC_PRODIGY);
    pub const MlorcAdamWBf16: Method = Method(&MLORC_ADAMW_BF16);

    pub fn name(&self) -> &'static str {
        self.0.id
    }

    pub fn desc(&self) -> &'static MethodDesc {
        self.0
    }

    /// Resolve a method id or alias through the registry.
    pub fn parse(s: &str) -> Result<Method> {
        for &d in METHODS {
            if d.id == s || d.aliases.iter().any(|a| *a == s) {
                return Ok(Method(d));
            }
        }
        bail!("unknown method '{s}'")
    }

    /// Every registered method, registry order.
    pub fn all() -> &'static [Method] {
        static ALL: OnceLock<Vec<Method>> = OnceLock::new();
        ALL.get_or_init(|| METHODS.iter().map(|&d| Method(d)).collect())
    }

    /// Uses the LoRA adapter graphs instead of full fwd/bwd.
    pub fn is_lora(&self) -> bool {
        self.0.lora
    }

    /// Variant (== step-graph method name) for *compressed matrix*
    /// parameters.
    pub fn matrix_step(&self) -> &'static str {
        self.0.matrix
    }

    /// Variant for vectors/embeddings/heads (always uncompressed).
    pub fn plain_step(&self) -> &'static str {
        self.0.plain
    }

    /// Whether foldable 1D parameters route through the matrix variant
    /// via their 2D [`effective_shape`].
    pub fn fold(&self) -> bool {
        self.0.fold
    }

    /// Paper-tuned default peak LR for the math-chain-style LM task
    /// (Table 8 analog; confirmed by our own sweep in `table8`).
    pub fn default_lr(&self) -> f32 {
        self.0.default_lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_method_resolves_to_registered_variants() {
        for &m in Method::all() {
            let d = m.desc();
            assert!(variant(d.matrix).is_ok(), "{}: matrix variant '{}'", d.id, d.matrix);
            assert!(variant(d.plain).is_ok(), "{}: plain variant '{}'", d.id, d.plain);
            // plain-path layouts must be dense (vectors can't be factored)
            assert_eq!(variant(d.plain).unwrap().comp, CompKind::Dense, "{}", d.id);
            assert_eq!(Method::parse(d.id).unwrap(), m);
            for alias in d.aliases {
                assert_eq!(Method::parse(alias).unwrap(), m, "alias '{alias}'");
            }
        }
        assert!(Method::parse("sgd").is_err());
    }

    #[test]
    fn acceptance_ids_resolve() {
        // The five pre-existing ids the issue pins, plus the new combos.
        for id in ["mlorc_adamw", "mlorc_lion", "galore", "ldadamw", "adamw"] {
            assert!(Method::parse(id).is_ok(), "{id}");
        }
        assert_eq!(Method::parse("adamw").unwrap(), Method::FullAdamW);
        assert_eq!(Method::parse("mlorc_sgdm").unwrap(), Method::MlorcSgdM);
        assert_eq!(Method::parse("galore_lion").unwrap(), Method::GaloreLion);
        // PR 5 registrations: adaptive-rank + quantized compressors, each
        // composed with AdamW and Lion.
        assert_eq!(Method::parse("mlorc_adarank").unwrap(), Method::MlorcAdaRank);
        assert_eq!(Method::parse("adarank").unwrap(), Method::MlorcAdaRank);
        assert_eq!(Method::parse("mlorc_q8").unwrap(), Method::MlorcQ8);
        assert_eq!(Method::parse("q8").unwrap(), Method::MlorcQ8);
        assert!(Method::parse("mlorc_adarank_lion").is_ok());
        assert!(Method::parse("mlorc_q8_lion").is_ok());
        // The second optimizer wave: Prodigy, bf16 weights, modifiers.
        assert_eq!(Method::parse("mlorc_prodigy").unwrap(), Method::MlorcProdigy);
        assert_eq!(Method::parse("prodigy").unwrap(), Method::MlorcProdigy);
        assert_eq!(Method::parse("mlorc_adamw_bf16").unwrap(), Method::MlorcAdamWBf16);
        assert_eq!(Method::parse("bf16").unwrap(), Method::MlorcAdamWBf16);
        for id in ["atan2", "grams", "orthograd"] {
            assert!(Method::parse(id).is_ok(), "{id}");
        }
    }

    #[test]
    fn effective_shape_prefers_squarest_fold() {
        assert_eq!(effective_shape(16, 4), Some([4, 4]));
        assert_eq!(effective_shape(32, 4), Some([4, 8]));
        assert_eq!(effective_shape(64, 4), Some([8, 8]));
        assert_eq!(effective_shape(64, 8), Some([8, 8]));
        // primes have no divisor >= 2 below their square root
        assert_eq!(effective_shape(13, 2), None);
        // short side under the sketch rank: fold would not compress
        assert_eq!(effective_shape(32, 5), None);
    }

    #[test]
    fn fold_builds_factored_state_for_1d_params() {
        let v = variant("mlorc_prodigy").unwrap();
        let mo = v.build(&[32], 4).unwrap();
        assert_eq!(mo.folded(), Some([4, 8]));
        assert!(mo.needs_member_step());
        // factored fields exist on the effective shape
        let fields = mo.tensor_fields();
        assert!(fields.iter().any(|(n, t)| *n == "mq" && t.shape == [4, 4]));
        // prodigy statistics ride along (sliced: ceil(32/11) = 3)
        assert!(fields.iter().any(|(n, t)| *n == "p0" && t.data.len() == 3));
        // dense layouts never fold
        assert_eq!(variant("adamw").unwrap().build(&[32], 4).unwrap().folded(), None);
        // unfoldable 1D shapes refuse to build factored state
        assert!(variant("mlorc_adamw").unwrap().build(&[13], 4).is_err());
    }

    #[test]
    fn variant_masks_are_rule_consistent() {
        for v in VARIANTS {
            if let CompKind::RsvdQb { factored } = v.comp {
                assert_eq!(
                    factored.len(),
                    v.n_moments(),
                    "variant '{}' mask length vs rule moments",
                    v.id
                );
            }
            // every variant must build on a representative matrix shape
            assert!(v.build(&[8, 6], 2).is_ok(), "variant '{}' build", v.id);
        }
    }

    #[test]
    fn state_floats_match_table1_formulas() {
        let (m, n, r) = (1024usize, 4096usize, 4usize);
        assert_eq!(variant("adamw").unwrap().state_floats(m, n, r), 2 * m * n);
        assert_eq!(variant("lion").unwrap().state_floats(m, n, r), m * n);
        assert_eq!(variant("sgdm").unwrap().state_floats(m, n, r), m * n);
        assert_eq!(
            variant("mlorc_adamw").unwrap().state_floats(m, n, r),
            2 * r * (m + n)
        );
        assert_eq!(variant("mlorc_lion").unwrap().state_floats(m, n, r), r * (m + n));
        assert_eq!(
            variant("mlorc_m").unwrap().state_floats(m, n, r),
            r * (m + n) + m * n
        );
        assert_eq!(variant("galore").unwrap().state_floats(m, n, r), m * r + 2 * n * r);
        assert_eq!(variant("galore_lion").unwrap().state_floats(m, n, r), m * r + n * r);
        assert_eq!(
            variant("ldadamw").unwrap().state_floats(m, n, r),
            m * r + 2 * n * r + m * n
        );
        // new layouts: adaptive rank counts its initial-rank upper bound,
        // quantized counts codes (so bytes, not 4x elements)
        assert_eq!(
            variant("mlorc_adarank").unwrap().state_floats(m, n, r),
            2 * r * (m + n)
        );
        assert_eq!(variant("mlorc_q8").unwrap().state_floats(m, n, r), 2 * r * (m + n));
        let q8_bytes = variant("mlorc_q8").unwrap().state_bytes(m, n, r);
        assert!(q8_bytes < 4 * 2 * r * (m + n) / 3, "q8 bytes {q8_bytes}");
        // f32 layouts: bytes are exactly 4x the element count
        assert_eq!(
            variant("mlorc_adamw").unwrap().state_bytes(m, n, r),
            4 * 2 * r * (m + n)
        );
    }
}
