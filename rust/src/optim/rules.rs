//! `UpdateRule` — the *update-rule* axis of the optimizer matrix.
//!
//! The paper's claim is that momentum compression "generalizes well
//! across different optimizers": the compression strategy (how momentum
//! is *stored*) and the update rule (how the step is *computed* from
//! momentum) are orthogonal. This module owns the second axis. A rule
//! declares how many EMA moment buffers it tracks, whether its apply is
//! bias-corrected (so the step graphs take `c1`/`c2` scalars), and the
//! dense reference step over raw moment tensors — the kernel the
//! [`Dense`](super::compress::Dense) passthrough compressor and the
//! trainer's 1-D vector path call.
//!
//! Compressed paths do not go through `dense_step`: each
//! `MomentumCompressor` routes (rule × layout) to the fused `*_core`
//! kernels (`mlorc_adamw_core`, `galore_core`, ...) so the pre-refactor
//! bit patterns are preserved exactly (pinned by
//! `tests/optim_matrix.rs`).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::{adamw_host_step, lion_host_step, OptHp};

/// The registered update rules. A `Copy` tag (rather than a trait object
/// in every state) so the registry's variant table is const-constructible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleKind {
    AdamW,
    Lion,
    /// SGD with (EMA-form) momentum: `m = β1·m + (1−β1)·g`,
    /// `w -= lr·(m + wd·w)`.
    SgdM,
    /// Prodigy D-adaptation over AdamW moments (Mishchenko & Defazio).
    /// The per-parameter D estimate lives in [`ProdigyState`] on the
    /// `MatrixOpt`; the inner moment update is exactly the AdamW kernel
    /// on D-scaled inputs, so every compressor layout composes with it
    /// unchanged.
    Prodigy,
}

/// One optimizer update rule — AdamW, Lion, SGD-momentum. Implementations
/// are stateless unit structs; per-parameter state lives in the
/// compressor (`MomentumCompressor`), which decides how the rule's moment
/// buffers are stored.
pub trait UpdateRule: std::fmt::Debug + Send + Sync {
    fn kind(&self) -> RuleKind;

    /// Stable id (`adamw` | `lion` | `sgdm`).
    fn id(&self) -> &'static str;

    /// How many EMA moment buffers the rule tracks (AdamW: 2, Lion: 1,
    /// SGDM: 1).
    fn n_moments(&self) -> usize;

    /// Checkpoint/graph field names of the dense moment buffers, in
    /// declared order (`["m", "v"]` for AdamW, `["m"]` for Lion/SGDM).
    fn moment_names(&self) -> &'static [&'static str];

    /// Whether the apply is bias-corrected — decides if the step graphs
    /// (and the scalar tail of their input list) carry `c1`/`c2`.
    fn bias_corrected(&self) -> bool;

    /// One dense reference step over raw state tensors of any shape —
    /// the host mirror of the rule's plain step graph. `moments` come in
    /// `moment_names` order; `t` is 1-based.
    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        t: usize,
        hp: &OptHp,
    ) -> Result<()>;
}

/// One plain SGD-momentum step over raw state tensors (EMA form, so the
/// factored recompression `β·QB + (1−β)·G` applies verbatim to its
/// momentum). Shared by [`SgdMomentumRule`] and `mlorc_sgdm_core`'s
/// cross-validation tests.
pub fn sgdm_host_step(w: &mut Tensor, g: &Tensor, m: &mut Tensor, lr: f32, hp: &OptHp) {
    for (mi, gi) in m.data.iter_mut().zip(&g.data) {
        *mi = hp.beta1 * *mi + (1.0 - hp.beta1) * gi;
    }
    for (wi, mi) in w.data.iter_mut().zip(&m.data) {
        *wi -= lr * (*mi + hp.weight_decay * *wi);
    }
}

// ------------------------------------------------------------- prodigy

/// Prodigy's initial D estimate (`d0` in the exemplar).
pub const PRODIGY_D0: f32 = 1e-6;
/// Multiplier on the D estimate (`d_coef`); the exemplar default is 1.
pub const PRODIGY_D_COEF: f32 = 1.0;
/// D-adaptation statistics are computed on every `slice_p`-th element of
/// the flattened parameter (the exemplar's memory-saving subsample).
pub const PRODIGY_SLICE_P: usize = 11;

/// Prodigy's bias-correction factor `√(1−β2^t) / (1−β1^t)` — the scale
/// that turns `d·lr` into the effective step size `dlr`.
pub fn prodigy_bc(hp: &OptHp, t: usize) -> f32 {
    let t = t as i32;
    (1.0 - hp.beta2.powi(t)).sqrt() / (1.0 - hp.beta1.powi(t))
}

/// Per-parameter Prodigy D-adaptation state: the running D estimate, its
/// EMA numerator, the sliced reference weights `p0` (captured at t==1)
/// and the sliced denominator accumulator `s`. Tensor fields checkpoint
/// as `p0`/`s` next to the compressor's moment fields; `d`/`d_num` ride
/// in the checkpoint metadata as exact f32 bit patterns.
#[derive(Debug, Clone, PartialEq)]
pub struct ProdigyState {
    pub d: f32,
    pub d_num: f32,
    pub p0: Tensor,
    pub s: Tensor,
}

impl ProdigyState {
    /// Length of the every-`slice_p`-th subsample of a `numel` parameter.
    pub fn sliced_len(numel: usize) -> usize {
        numel.div_ceil(PRODIGY_SLICE_P)
    }

    pub fn new(numel: usize) -> ProdigyState {
        let k = ProdigyState::sliced_len(numel);
        ProdigyState { d: PRODIGY_D0, d_num: 0.0, p0: Tensor::zeros(&[k]), s: Tensor::zeros(&[k]) }
    }

    /// One D-adaptation update, called once per step with the *pre-update*
    /// weights and raw gradient (`t` 1-based; captures `p0` at t==1).
    /// Returns the D estimate the step's inner update must use — the value
    /// on entry; the refreshed estimate takes effect next step, exactly
    /// the reference schedule. D is monotone non-decreasing
    /// (`growth_rate = ∞`), pinned by `tests/optim_wave.rs`.
    pub fn update(&mut self, w: &[f32], g: &[f32], lr: f32, t: usize, hp: &OptHp) -> f32 {
        debug_assert_eq!(w.len(), g.len());
        if t == 1 {
            for (k, i) in (0..w.len()).step_by(PRODIGY_SLICE_P).enumerate() {
                self.p0.data[k] = w[i];
            }
        }
        let d = self.d;
        let beta3 = (hp.beta2 as f64).sqrt();
        let dlr = (d * lr * prodigy_bc(hp, t)) as f64;
        let dd0 = (d / PRODIGY_D0) as f64;
        let mut dot = 0f64;
        for (k, i) in (0..w.len()).step_by(PRODIGY_SLICE_P).enumerate() {
            dot += g[i] as f64 * (self.p0.data[k] as f64 - w[i] as f64);
        }
        self.d_num = (beta3 * self.d_num as f64 + dd0 * dlr * dot) as f32;
        let mut denom = 0f64;
        for (k, i) in (0..w.len()).step_by(PRODIGY_SLICE_P).enumerate() {
            let sk = beta3 * self.s.data[k] as f64 + dd0 * dlr * g[i] as f64;
            self.s.data[k] = sk as f32;
            denom += sk.abs();
        }
        // zero gradients leave D untouched (the exemplar's denom==0 skip)
        if denom > 0.0 {
            let d_hat = (PRODIGY_D_COEF as f64 * self.d_num as f64 / denom) as f32;
            self.d = self.d.max(d_hat);
        }
        d
    }
}

#[derive(Debug)]
pub struct ProdigyRule;

impl UpdateRule for ProdigyRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Prodigy
    }

    fn id(&self) -> &'static str {
        "prodigy"
    }

    // AdamW's moment layout — the whole point: any compressor that can
    // store AdamW moments can store Prodigy's.
    fn n_moments(&self) -> usize {
        2
    }

    fn moment_names(&self) -> &'static [&'static str] {
        &["m", "v"]
    }

    fn bias_corrected(&self) -> bool {
        true
    }

    fn dense_step(
        &self,
        _w: &mut Tensor,
        _g: &Tensor,
        _moments: &mut [&mut Tensor],
        _lr: f32,
        _t: usize,
        _hp: &OptHp,
    ) -> Result<()> {
        // Unreachable by construction: `MatrixOpt::step` rewrites Prodigy
        // to the AdamW rule on D-scaled inputs before any compressor
        // (including Dense) dispatches. Reaching this means a caller
        // bypassed the D-adaptation orchestration — fail loudly.
        bail!("prodigy steps through MatrixOpt's D-adaptation orchestration, not dense_step")
    }
}

/// OrthoGrad (`use_orthograd`): project `g` orthogonal to `w`, then rescale
/// back to `‖g‖` so the step magnitude is untouched. Dot products and norms
/// accumulate in f64 so the projection is deterministic across layouts; the
/// `1e-30` guards mirror the exemplar and keep `w = 0` / `g ⟂ w` exact.
pub fn orthogonalize_gradient(w: &Tensor, g: &Tensor) -> Tensor {
    let mut wg = 0.0f64;
    let mut ww = 0.0f64;
    for (wi, gi) in w.data.iter().zip(&g.data) {
        wg += *wi as f64 * *gi as f64;
        ww += *wi as f64 * *wi as f64;
    }
    let proj = (wg / (ww + 1e-30)) as f32;
    let mut out = g.clone();
    for (oi, wi) in out.data.iter_mut().zip(&w.data) {
        *oi -= proj * wi;
    }
    let mut gn = 0.0f64;
    let mut on = 0.0f64;
    for (gi, oi) in g.data.iter().zip(&out.data) {
        gn += *gi as f64 * *gi as f64;
        on += *oi as f64 * *oi as f64;
    }
    let scale = (gn.sqrt() / (on.sqrt() + 1e-30)) as f32;
    for oi in out.data.iter_mut() {
        *oi *= scale;
    }
    out
}

#[derive(Debug)]
pub struct AdamWRule;

impl UpdateRule for AdamWRule {
    fn kind(&self) -> RuleKind {
        RuleKind::AdamW
    }

    fn id(&self) -> &'static str {
        "adamw"
    }

    fn n_moments(&self) -> usize {
        2
    }

    fn moment_names(&self) -> &'static [&'static str] {
        &["m", "v"]
    }

    fn bias_corrected(&self) -> bool {
        true
    }

    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        t: usize,
        hp: &OptHp,
    ) -> Result<()> {
        match moments {
            [m, v] => {
                adamw_host_step(w, g, m, v, lr, t, hp);
                Ok(())
            }
            _ => bail!("adamw rule wants 2 moment buffers, got {}", moments.len()),
        }
    }
}

#[derive(Debug)]
pub struct LionRule;

impl UpdateRule for LionRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Lion
    }

    fn id(&self) -> &'static str {
        "lion"
    }

    fn n_moments(&self) -> usize {
        1
    }

    fn moment_names(&self) -> &'static [&'static str] {
        &["m"]
    }

    fn bias_corrected(&self) -> bool {
        false
    }

    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        _t: usize,
        hp: &OptHp,
    ) -> Result<()> {
        match moments {
            [m] => {
                lion_host_step(w, g, m, lr, hp);
                Ok(())
            }
            _ => bail!("lion rule wants 1 moment buffer, got {}", moments.len()),
        }
    }
}

#[derive(Debug)]
pub struct SgdMomentumRule;

impl UpdateRule for SgdMomentumRule {
    fn kind(&self) -> RuleKind {
        RuleKind::SgdM
    }

    fn id(&self) -> &'static str {
        "sgdm"
    }

    fn n_moments(&self) -> usize {
        1
    }

    fn moment_names(&self) -> &'static [&'static str] {
        &["m"]
    }

    fn bias_corrected(&self) -> bool {
        false
    }

    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        _t: usize,
        hp: &OptHp,
    ) -> Result<()> {
        match moments {
            [m] => {
                sgdm_host_step(w, g, m, lr, hp);
                Ok(())
            }
            _ => bail!("sgdm rule wants 1 moment buffer, got {}", moments.len()),
        }
    }
}

static ADAMW: AdamWRule = AdamWRule;
static LION: LionRule = LionRule;
static SGDM: SgdMomentumRule = SgdMomentumRule;
static PRODIGY: ProdigyRule = ProdigyRule;

/// The shared rule instance for a tag (rules are stateless).
pub fn rule(kind: RuleKind) -> &'static dyn UpdateRule {
    match kind {
        RuleKind::AdamW => &ADAMW,
        RuleKind::Lion => &LION,
        RuleKind::SgdM => &SGDM,
        RuleKind::Prodigy => &PRODIGY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    #[test]
    fn rule_tags_and_moment_counts() {
        for (kind, id, n, bc) in [
            (RuleKind::AdamW, "adamw", 2, true),
            (RuleKind::Lion, "lion", 1, false),
            (RuleKind::SgdM, "sgdm", 1, false),
            (RuleKind::Prodigy, "prodigy", 2, true),
        ] {
            let r = rule(kind);
            assert_eq!(r.kind(), kind);
            assert_eq!(r.id(), id);
            assert_eq!(r.n_moments(), n);
            assert_eq!(r.moment_names().len(), n);
            assert_eq!(r.bias_corrected(), bc);
            assert_eq!(r.moment_names()[0], "m");
        }
    }

    #[test]
    fn dense_steps_match_reference_kernels() {
        let mut rng = Rng::new(3);
        let g = rng.gaussian_tensor(&[5, 7], 1.0);

        // AdamW through the trait == adamw_host_step directly.
        let hp = OptHp::adamw();
        let mut w1 = rng.gaussian_tensor(&[5, 7], 1.0);
        let mut w2 = w1.clone();
        let (mut m1, mut v1) = (Tensor::zeros(&[5, 7]), Tensor::zeros(&[5, 7]));
        let (mut m2, mut v2) = (Tensor::zeros(&[5, 7]), Tensor::zeros(&[5, 7]));
        for t in 1..=3 {
            rule(RuleKind::AdamW)
                .dense_step(&mut w1, &g, &mut [&mut m1, &mut v1], 1e-2, t, &hp)
                .unwrap();
            adamw_host_step(&mut w2, &g, &mut m2, &mut v2, 1e-2, t, &hp);
            assert_eq!(w1.data, w2.data);
        }

        // Wrong moment count is a loud error, not a silent misstep.
        let err = rule(RuleKind::AdamW).dense_step(&mut w1, &g, &mut [&mut m1], 1e-2, 1, &hp);
        assert!(err.is_err());
    }

    #[test]
    fn sgdm_first_step_is_scaled_gradient() {
        let hp = OptHp::sgdm();
        let mut rng = Rng::new(1);
        let g = rng.gaussian_tensor(&[4, 4], 1.0);
        let mut w = Tensor::zeros(&[4, 4]);
        let mut m = Tensor::zeros(&[4, 4]);
        sgdm_host_step(&mut w, &g, &mut m, 0.1, &hp);
        for ((wi, mi), gi) in w.data.iter().zip(&m.data).zip(&g.data) {
            assert!((mi - (1.0 - hp.beta1) * gi).abs() < 1e-7);
            assert!((wi + 0.1 * mi).abs() < 1e-7, "w must move by -lr*m");
        }
    }

    #[test]
    fn sgdm_converges_on_quadratic() {
        let hp = OptHp::sgdm();
        let mut rng = Rng::new(2);
        let target = rng.gaussian_tensor(&[6, 6], 1.0);
        let mut w = Tensor::zeros(&[6, 6]);
        let mut m = Tensor::zeros(&[6, 6]);
        for _ in 0..400 {
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            sgdm_host_step(&mut w, &g, &mut m, 0.05, &hp);
        }
        assert!(w.rel_err(&target) < 0.05, "rel {}", w.rel_err(&target));
    }
}
