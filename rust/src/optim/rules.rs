//! `UpdateRule` — the *update-rule* axis of the optimizer matrix.
//!
//! The paper's claim is that momentum compression "generalizes well
//! across different optimizers": the compression strategy (how momentum
//! is *stored*) and the update rule (how the step is *computed* from
//! momentum) are orthogonal. This module owns the second axis. A rule
//! declares how many EMA moment buffers it tracks, whether its apply is
//! bias-corrected (so the step graphs take `c1`/`c2` scalars), and the
//! dense reference step over raw moment tensors — the kernel the
//! [`Dense`](super::compress::Dense) passthrough compressor and the
//! trainer's 1-D vector path call.
//!
//! Compressed paths do not go through `dense_step`: each
//! `MomentumCompressor` routes (rule × layout) to the fused `*_core`
//! kernels (`mlorc_adamw_core`, `galore_core`, ...) so the pre-refactor
//! bit patterns are preserved exactly (pinned by
//! `tests/optim_matrix.rs`).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::{adamw_host_step, lion_host_step, OptHp};

/// The registered update rules. A `Copy` tag (rather than a trait object
/// in every state) so the registry's variant table is const-constructible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleKind {
    AdamW,
    Lion,
    /// SGD with (EMA-form) momentum: `m = β1·m + (1−β1)·g`,
    /// `w -= lr·(m + wd·w)`.
    SgdM,
}

/// One optimizer update rule — AdamW, Lion, SGD-momentum. Implementations
/// are stateless unit structs; per-parameter state lives in the
/// compressor (`MomentumCompressor`), which decides how the rule's moment
/// buffers are stored.
pub trait UpdateRule: std::fmt::Debug + Send + Sync {
    fn kind(&self) -> RuleKind;

    /// Stable id (`adamw` | `lion` | `sgdm`).
    fn id(&self) -> &'static str;

    /// How many EMA moment buffers the rule tracks (AdamW: 2, Lion: 1,
    /// SGDM: 1).
    fn n_moments(&self) -> usize;

    /// Checkpoint/graph field names of the dense moment buffers, in
    /// declared order (`["m", "v"]` for AdamW, `["m"]` for Lion/SGDM).
    fn moment_names(&self) -> &'static [&'static str];

    /// Whether the apply is bias-corrected — decides if the step graphs
    /// (and the scalar tail of their input list) carry `c1`/`c2`.
    fn bias_corrected(&self) -> bool;

    /// One dense reference step over raw state tensors of any shape —
    /// the host mirror of the rule's plain step graph. `moments` come in
    /// `moment_names` order; `t` is 1-based.
    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        t: usize,
        hp: &OptHp,
    ) -> Result<()>;
}

/// One plain SGD-momentum step over raw state tensors (EMA form, so the
/// factored recompression `β·QB + (1−β)·G` applies verbatim to its
/// momentum). Shared by [`SgdMomentumRule`] and `mlorc_sgdm_core`'s
/// cross-validation tests.
pub fn sgdm_host_step(w: &mut Tensor, g: &Tensor, m: &mut Tensor, lr: f32, hp: &OptHp) {
    for (mi, gi) in m.data.iter_mut().zip(&g.data) {
        *mi = hp.beta1 * *mi + (1.0 - hp.beta1) * gi;
    }
    for (wi, mi) in w.data.iter_mut().zip(&m.data) {
        *wi -= lr * (*mi + hp.weight_decay * *wi);
    }
}

#[derive(Debug)]
pub struct AdamWRule;

impl UpdateRule for AdamWRule {
    fn kind(&self) -> RuleKind {
        RuleKind::AdamW
    }

    fn id(&self) -> &'static str {
        "adamw"
    }

    fn n_moments(&self) -> usize {
        2
    }

    fn moment_names(&self) -> &'static [&'static str] {
        &["m", "v"]
    }

    fn bias_corrected(&self) -> bool {
        true
    }

    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        t: usize,
        hp: &OptHp,
    ) -> Result<()> {
        match moments {
            [m, v] => {
                adamw_host_step(w, g, m, v, lr, t, hp);
                Ok(())
            }
            _ => bail!("adamw rule wants 2 moment buffers, got {}", moments.len()),
        }
    }
}

#[derive(Debug)]
pub struct LionRule;

impl UpdateRule for LionRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Lion
    }

    fn id(&self) -> &'static str {
        "lion"
    }

    fn n_moments(&self) -> usize {
        1
    }

    fn moment_names(&self) -> &'static [&'static str] {
        &["m"]
    }

    fn bias_corrected(&self) -> bool {
        false
    }

    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        _t: usize,
        hp: &OptHp,
    ) -> Result<()> {
        match moments {
            [m] => {
                lion_host_step(w, g, m, lr, hp);
                Ok(())
            }
            _ => bail!("lion rule wants 1 moment buffer, got {}", moments.len()),
        }
    }
}

#[derive(Debug)]
pub struct SgdMomentumRule;

impl UpdateRule for SgdMomentumRule {
    fn kind(&self) -> RuleKind {
        RuleKind::SgdM
    }

    fn id(&self) -> &'static str {
        "sgdm"
    }

    fn n_moments(&self) -> usize {
        1
    }

    fn moment_names(&self) -> &'static [&'static str] {
        &["m"]
    }

    fn bias_corrected(&self) -> bool {
        false
    }

    fn dense_step(
        &self,
        w: &mut Tensor,
        g: &Tensor,
        moments: &mut [&mut Tensor],
        lr: f32,
        _t: usize,
        hp: &OptHp,
    ) -> Result<()> {
        match moments {
            [m] => {
                sgdm_host_step(w, g, m, lr, hp);
                Ok(())
            }
            _ => bail!("sgdm rule wants 1 moment buffer, got {}", moments.len()),
        }
    }
}

static ADAMW: AdamWRule = AdamWRule;
static LION: LionRule = LionRule;
static SGDM: SgdMomentumRule = SgdMomentumRule;

/// The shared rule instance for a tag (rules are stateless).
pub fn rule(kind: RuleKind) -> &'static dyn UpdateRule {
    match kind {
        RuleKind::AdamW => &ADAMW,
        RuleKind::Lion => &LION,
        RuleKind::SgdM => &SGDM,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    #[test]
    fn rule_tags_and_moment_counts() {
        for (kind, id, n, bc) in [
            (RuleKind::AdamW, "adamw", 2, true),
            (RuleKind::Lion, "lion", 1, false),
            (RuleKind::SgdM, "sgdm", 1, false),
        ] {
            let r = rule(kind);
            assert_eq!(r.kind(), kind);
            assert_eq!(r.id(), id);
            assert_eq!(r.n_moments(), n);
            assert_eq!(r.moment_names().len(), n);
            assert_eq!(r.bias_corrected(), bc);
            assert_eq!(r.moment_names()[0], "m");
        }
    }

    #[test]
    fn dense_steps_match_reference_kernels() {
        let mut rng = Rng::new(3);
        let g = rng.gaussian_tensor(&[5, 7], 1.0);

        // AdamW through the trait == adamw_host_step directly.
        let hp = OptHp::adamw();
        let mut w1 = rng.gaussian_tensor(&[5, 7], 1.0);
        let mut w2 = w1.clone();
        let (mut m1, mut v1) = (Tensor::zeros(&[5, 7]), Tensor::zeros(&[5, 7]));
        let (mut m2, mut v2) = (Tensor::zeros(&[5, 7]), Tensor::zeros(&[5, 7]));
        for t in 1..=3 {
            rule(RuleKind::AdamW)
                .dense_step(&mut w1, &g, &mut [&mut m1, &mut v1], 1e-2, t, &hp)
                .unwrap();
            adamw_host_step(&mut w2, &g, &mut m2, &mut v2, 1e-2, t, &hp);
            assert_eq!(w1.data, w2.data);
        }

        // Wrong moment count is a loud error, not a silent misstep.
        let err = rule(RuleKind::AdamW).dense_step(&mut w1, &g, &mut [&mut m1], 1e-2, 1, &hp);
        assert!(err.is_err());
    }

    #[test]
    fn sgdm_first_step_is_scaled_gradient() {
        let hp = OptHp::sgdm();
        let mut rng = Rng::new(1);
        let g = rng.gaussian_tensor(&[4, 4], 1.0);
        let mut w = Tensor::zeros(&[4, 4]);
        let mut m = Tensor::zeros(&[4, 4]);
        sgdm_host_step(&mut w, &g, &mut m, 0.1, &hp);
        for ((wi, mi), gi) in w.data.iter().zip(&m.data).zip(&g.data) {
            assert!((mi - (1.0 - hp.beta1) * gi).abs() < 1e-7);
            assert!((wi + 0.1 * mi).abs() < 1e-7, "w must move by -lr*m");
        }
    }

    #[test]
    fn sgdm_converges_on_quadratic() {
        let hp = OptHp::sgdm();
        let mut rng = Rng::new(2);
        let target = rng.gaussian_tensor(&[6, 6], 1.0);
        let mut w = Tensor::zeros(&[6, 6]);
        let mut m = Tensor::zeros(&[6, 6]);
        for _ in 0..400 {
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            sgdm_host_step(&mut w, &g, &mut m, 0.05, &hp);
        }
        assert!(w.rel_err(&target) < 0.05, "rel {}", w.rel_err(&target));
    }
}
