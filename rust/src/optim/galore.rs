//! GaLore reference (Zhao et al., 2024): AdamW in a gradient-derived
//! low-rank subspace, projector refreshed every T steps. Projects the
//! *shorter* side, like the official implementation.
//!
//! The math lives in the free functions [`galore_refresh_projector`] and
//! [`galore_core`], shared verbatim by the reference state struct below
//! and the coordinator's host stepping (`OptState::host_step`) — one
//! implementation, cross-validated once.

use crate::linalg::{matmul, matmul_a_bt, matmul_at_b, mgs_qr, Rng};
use crate::tensor::Tensor;

use super::{bias_corrections, OptHp};

/// Refresh the projector from the gradient's dominant subspace:
/// randomized range finder of G (left) or Gᵀ (right) at rank `l` — the
/// stand-in for the paper's exact SVD, same dominant subspace up to the
/// RSVD tail bound. Draws one Gaussian test matrix from `rng`.
pub fn galore_refresh_projector(p: &mut Tensor, g: &Tensor, left: bool, l: usize, rng: &mut Rng) {
    let (m, n) = g.dims2().unwrap();
    *p = if left {
        let om = rng.gaussian_tensor(&[n, l], 1.0);
        mgs_qr(&matmul(g, &om))
    } else {
        let om = rng.gaussian_tensor(&[m, l], 1.0);
        mgs_qr(&matmul_at_b(g, &om))
    };
}

/// One GaLore step on raw state tensors (projector already current):
/// project the gradient, Adam moments in the subspace, project the
/// normalized update back. `t` is 1-based (bias corrections).
///
/// Unlike the MLorc cores this baseline allocates its intermediates
/// per call — it exists for coverage and cross-validation, not as a hot
/// path; route through a `Workspace` only if it ever becomes one.
#[allow(clippy::too_many_arguments)]
pub fn galore_core(
    w: &mut Tensor,
    g: &Tensor,
    p: &Tensor,
    m_lo: &mut Tensor,
    v_lo: &mut Tensor,
    left: bool,
    t: usize,
    lr: f32,
    hp: &OptHp,
) {
    let r = if left { matmul_at_b(p, g) } else { matmul(g, p) };
    for (mi, ri) in m_lo.data.iter_mut().zip(&r.data) {
        *mi = hp.beta1 * *mi + (1.0 - hp.beta1) * ri;
    }
    for (vi, ri) in v_lo.data.iter_mut().zip(&r.data) {
        *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * ri * ri;
    }
    let (c1, c2) = bias_corrections(hp, t);
    let mut nhat = m_lo.clone();
    for (ni, vi) in nhat.data.iter_mut().zip(&v_lo.data) {
        *ni = (*ni * c1) / ((vi * c2).sqrt() + hp.eps);
    }
    let full = if left { matmul(p, &nhat) } else { matmul_a_bt(&nhat, p) };
    for (wi, fi) in w.data.iter_mut().zip(&full.data) {
        *wi -= lr * (hp.galore_scale * fi + hp.weight_decay * *wi);
    }
}

/// One GaLore × Lion step on raw state tensors (projector already
/// current): project the gradient, form the Lion interpolant in the
/// subspace, take its sign *in the subspace*, project back. Momentum is
/// the single low-dim EMA, decayed with beta2 after the update like the
/// dense Lion kernel. The combo the trait split makes free.
#[allow(clippy::too_many_arguments)]
pub fn galore_lion_core(
    w: &mut Tensor,
    g: &Tensor,
    p: &Tensor,
    m_lo: &mut Tensor,
    left: bool,
    lr: f32,
    hp: &OptHp,
) {
    let r = if left { matmul_at_b(p, g) } else { matmul(g, p) };
    let mut c = m_lo.clone();
    for (ci, ri) in c.data.iter_mut().zip(&r.data) {
        *ci = super::lion::sign(hp.beta1 * *ci + (1.0 - hp.beta1) * ri);
    }
    let full = if left { matmul(p, &c) } else { matmul_a_bt(&c, p) };
    for (wi, fi) in w.data.iter_mut().zip(&full.data) {
        *wi -= lr * (hp.galore_scale * fi + hp.weight_decay * *wi);
    }
    for (mi, ri) in m_lo.data.iter_mut().zip(&r.data) {
        *mi = hp.beta2 * *mi + (1.0 - hp.beta2) * ri;
    }
}

#[derive(Debug, Clone)]
pub struct GaloreState {
    /// projector: (m, l) when left (m <= n), else (n, l)
    pub p: Tensor,
    pub m_lo: Tensor,
    pub v_lo: Tensor,
    pub left: bool,
    pub l: usize,
    pub update_freq: usize,
    pub t: usize,
}

impl GaloreState {
    pub fn new(shape: &[usize], l: usize, update_freq: usize) -> GaloreState {
        let (m, n) = (shape[0], shape[1]);
        let left = m <= n;
        let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
        GaloreState {
            p: Tensor::zeros(&pshape),
            m_lo: Tensor::zeros(&rshape),
            v_lo: Tensor::zeros(&rshape),
            left,
            l,
            update_freq,
            t: 0,
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.p.size_bytes() + self.m_lo.size_bytes() + self.v_lo.size_bytes()
    }

    /// Randomized range finder of the gradient (stand-in for the paper's
    /// exact SVD; same dominant subspace up to the RSVD tail bound).
    pub fn refresh_projector(&mut self, g: &Tensor, rng: &mut Rng) {
        galore_refresh_projector(&mut self.p, g, self.left, self.l, rng);
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp, rng: &mut Rng) {
        if self.t % self.update_freq == 0 {
            self.refresh_projector(g, rng);
        }
        self.t += 1;
        galore_core(w, g, &self.p, &mut self.m_lo, &mut self.v_lo, self.left, self.t, lr, hp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projector_sides() {
        let wide = GaloreState::new(&[8, 32], 4, 10);
        assert!(wide.left);
        assert_eq!(wide.p.shape, vec![8, 4]);
        assert_eq!(wide.m_lo.shape, vec![4, 32]);
        let tall = GaloreState::new(&[32, 8], 4, 10);
        assert!(!tall.left);
        assert_eq!(tall.p.shape, vec![8, 4]);
        assert_eq!(tall.m_lo.shape, vec![32, 4]);
    }

    #[test]
    fn update_stays_in_projector_range() {
        let hp = OptHp::adamw();
        let mut rng = Rng::new(0);
        let mut st = GaloreState::new(&[6, 24], 2, 100);
        let g = rng.gaussian_tensor(&[6, 24], 1.0);
        let w0 = rng.gaussian_tensor(&[6, 24], 1.0);
        let mut w = w0.clone();
        st.step(&mut w, &g, 0.1, &hp, &mut rng);
        // delta = w - w0 must lie in col-space of P: (I - P P^T) delta = 0
        let mut delta = w.clone();
        delta.axpy(-1.0, &w0, 1.0);
        let proj = matmul(&st.p, &matmul_at_b(&st.p, &delta));
        assert!(delta.rel_err(&proj) < 1e-4, "rel {}", delta.rel_err(&proj));
    }

    #[test]
    fn lion_update_stays_in_projector_range() {
        // galore_lion_core's update must lie in the projector's range,
        // same invariant as the AdamW combo.
        let hp = OptHp::lion();
        let mut rng = Rng::new(3);
        let g = rng.gaussian_tensor(&[6, 24], 1.0);
        let mut p = Tensor::zeros(&[6, 2]);
        galore_refresh_projector(&mut p, &g, true, 2, &mut rng);
        let w0 = rng.gaussian_tensor(&[6, 24], 1.0);
        let mut w = w0.clone();
        let mut m_lo = Tensor::zeros(&[2, 24]);
        galore_lion_core(&mut w, &g, &p, &mut m_lo, true, 0.1, &hp);
        let mut delta = w.clone();
        delta.axpy(-1.0, &w0, 1.0);
        let proj = matmul(&p, &matmul_at_b(&p, &delta));
        assert!(delta.rel_err(&proj) < 1e-4, "rel {}", delta.rel_err(&proj));
        // momentum decayed with beta2 from zero: (1 - beta2) * r
        let r = matmul_at_b(&p, &g);
        for (mi, ri) in m_lo.data.iter().zip(&r.data) {
            assert!((mi - (1.0 - hp.beta2) * ri).abs() < 1e-6);
        }
    }

    #[test]
    fn converges_on_lowrank_quadratic() {
        let hp = OptHp::adamw();
        let mut rng = Rng::new(1);
        let u = rng.gaussian_tensor(&[12, 2], 1.0);
        let v = rng.gaussian_tensor(&[2, 16], 1.0);
        let target = matmul(&u, &v);
        let mut w = Tensor::zeros(&[12, 16]);
        let mut st = GaloreState::new(&[12, 16], 4, 50);
        for _ in 0..1500 {
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            st.step(&mut w, &g, 0.05, &hp, &mut rng);
        }
        // galore_scale 0.25 slows it; generous threshold
        assert!(w.rel_err(&target) < 0.2, "rel {}", w.rel_err(&target));
    }
}
