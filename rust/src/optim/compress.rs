//! `MomentumCompressor` — the *storage* axis of the optimizer matrix.
//!
//! A compressor owns the per-parameter optimizer state and decides how an
//! update rule's moment buffers are kept between steps:
//!
//!  * [`Dense`] — uncompressed passthrough (any tensor shape; the vector
//!    path and the Full baselines);
//!  * [`RsvdQb`] — MLorc's factored Q/B recompression, with a per-moment
//!    factored/dense mask so the Table 7 ablations (compress-m-only /
//!    compress-v-only) are just different masks;
//!  * [`AdaRank`] — RsvdQb with an online rank schedule: directions in a
//!    negligible tail of B's spectral energy are dropped, floored at
//!    `--rank-min` (AdaRankGrad-style);
//!  * [`QuantQb`](super::quant::QuantQb) — RsvdQb with both factors held
//!    as 8-bit blockwise-quantized codes between steps (`optim::quant`);
//!  * [`GaloreProjector`] — GaLore's gradient-subspace projection with a
//!    cadence-refreshed projector;
//!  * [`LdProj`] — LDAdamW's per-step projector + error-feedback buffer.
//!
//! `step` owns the fused reconstruct-apply routing: each (rule × layout)
//! pair dispatches to the exact pre-refactor `*_core` kernel
//! (`mlorc_adamw_core`, `galore_core`, `ldadamw_core`, ...), including
//! the Omega draw order from the parameter's RNG stream — which is what
//! keeps every pre-existing method bit-identical through the trait seam
//! (pinned by `tests/optim_matrix.rs`). Combinations without a kernel
//! fail loudly at step time rather than silently approximating.

// `step` threads (rule, hp, w, g, lr, t, rng, ws) through one seam on
// purpose — it is the single dispatch surface of the optimizer matrix.
#![allow(clippy::too_many_arguments)]

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::linalg::{matmul, pool, threads, Rng, Workspace};
use crate::obs;
use crate::tensor::{Tensor, TensorU8};
use crate::util::json::Json;

use super::mlorc::{
    mlorc_adamw_core_class, mlorc_lion_core_class, mlorc_sgdm_core_class, QbClassJob,
};
use super::quant::QuantQb;
use super::registry::MatrixOpt;
use super::rules::{RuleKind, UpdateRule};
use super::{
    galore_core, galore_lion_core, galore_refresh_projector, ldadamw_core, mlorc_adamw_core,
    mlorc_lion_core, mlorc_m_core, mlorc_sgdm_core, mlorc_v_core, OptHp,
};

/// How one parameter's momentum is stored and stepped. Implementations
/// also own the checkpoint-v2 surface of the state: stable tensor field
/// names (in declared order) plus any non-tensor flags.
#[allow(clippy::too_many_arguments)]
pub trait MomentumCompressor: std::fmt::Debug + Send + Sync {
    /// Stable id (`dense` | `rsvd_qb` | `adarank` | `quant_qb` |
    /// `galore` | `ldproj`).
    fn id(&self) -> &'static str;

    /// The state's tensor fields under stable names, in declared order —
    /// checkpoint v2 stores each as `<param>/<field>`, and the step
    /// graphs take them (in this order) right after `w` and `grad`.
    fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)>;

    /// Mutable view of every tensor field, same names and order.
    fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)>;

    /// Raw u8 tensor fields (8-bit quantized code planes), stored by
    /// checkpoint v2 as `<param>/<field>` dtype-2 entries next to the f32
    /// fields. Empty for unquantized layouts.
    fn u8_fields(&self) -> Vec<(&'static str, &TensorU8)> {
        vec![]
    }

    /// Mutable view of every u8 field, same names and order.
    fn u8_fields_mut(&mut self) -> Vec<(&'static str, &mut TensorU8)> {
        vec![]
    }

    /// How many times this state shrank its factor rank (adaptive-rank
    /// layouts); surfaced through checkpoints and `mlorc status`.
    fn shrink_events(&self) -> usize {
        0
    }

    /// The fields a step graph returns updated, in output order.
    /// Projector compressors exclude fields the graph treats as
    /// constants (GaLore's `p` is refreshed by its own graph).
    fn graph_output_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        self.tensor_fields_mut()
    }

    /// Non-tensor flags for checkpoint metadata (inverse lives in the
    /// registry's variant decoder).
    fn flags_into(&self, _meta: &mut Json) {}

    /// Optimizer-state footprint in bytes (the Table 1/3 quantity): every
    /// f32 field plus every quantized u8 code plane.
    fn state_bytes(&self) -> usize {
        self.tensor_fields().iter().map(|(_, t)| t.size_bytes()).sum::<usize>()
            + self.u8_fields().iter().map(|(_, t)| t.size_bytes()).sum::<usize>()
    }

    /// Reconstructed first moment, if the layout has one (spectral probe).
    fn first_moment(&self) -> Option<Tensor> {
        None
    }

    /// Reconstructed second moment, if the layout has one.
    fn second_moment(&self) -> Option<Tensor> {
        None
    }

    /// Shapes of the Gaussian test matrices the *step graph* takes after
    /// the state fields, in draw order. Host-side draws happen inside
    /// `step` (same count and order).
    fn omega_graph_shapes(&self) -> Vec<[usize; 2]> {
        vec![]
    }

    /// Cadence hook: mark a cached projector stale so the next step
    /// re-derives it from that step's gradient. No-op for compressors
    /// without one.
    fn invalidate_projector(&mut self) {}

    /// Downcast hook for the trainer's graph-path projector refresh.
    fn as_galore_mut(&mut self) -> Option<&mut GaloreProjector> {
        None
    }

    /// Downcast hook for the shape-class batched stepping path
    /// ([`step_class`] routes on the concrete layout).
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;

    /// One optimizer step entirely on the host: route (rule × layout) to
    /// the matching fused kernel. `t` is 1-based; `rng` is the
    /// parameter's own Omega stream; scratch comes from `ws`.
    fn step(
        &mut self,
        rule: &'static dyn UpdateRule,
        hp: &OptHp,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<()>;

    fn clone_box(&self) -> Box<dyn MomentumCompressor>;
}

// ---------------------------------------------------------------- dense

/// Uncompressed passthrough: one dense buffer per rule moment. Works on
/// any tensor shape; this is the vector path and the Full baselines.
#[derive(Debug, Clone)]
pub struct Dense {
    names: &'static [&'static str],
    moments: Vec<Tensor>,
}

impl Dense {
    pub fn new(rule: &dyn UpdateRule, shape: &[usize]) -> Dense {
        Dense {
            names: rule.moment_names(),
            moments: (0..rule.n_moments()).map(|_| Tensor::zeros(shape)).collect(),
        }
    }

    /// Rebuild from checkpoint tensors (names must match the rule's).
    pub fn from_parts(names: &'static [&'static str], moments: Vec<Tensor>) -> Dense {
        Dense { names, moments }
    }
}

impl MomentumCompressor for Dense {
    fn id(&self) -> &'static str {
        "dense"
    }

    fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        self.names.iter().copied().zip(self.moments.iter()).collect()
    }

    fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        self.names.iter().copied().zip(self.moments.iter_mut()).collect()
    }

    fn first_moment(&self) -> Option<Tensor> {
        self.moments.first().cloned()
    }

    fn second_moment(&self) -> Option<Tensor> {
        // only rules whose second buffer is a second moment ("v")
        if self.names.get(1) == Some(&"v") {
            self.moments.get(1).cloned()
        } else {
            None
        }
    }

    fn step(
        &mut self,
        rule: &'static dyn UpdateRule,
        hp: &OptHp,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        _rng: &mut Rng,
        _ws: &mut Workspace,
    ) -> Result<()> {
        let mut refs: Vec<&mut Tensor> = self.moments.iter_mut().collect();
        rule.dense_step(w, g, &mut refs, lr, t, hp)
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn MomentumCompressor> {
        Box::new(self.clone())
    }
}

// --------------------------------------------------------------- rsvd_qb

/// Storage of one rule moment under [`RsvdQb`].
#[derive(Debug, Clone)]
pub enum MomentStore {
    /// Rank-l factors: `q` is (m, l), `b` is (l, n).
    Factored { q: Tensor, b: Tensor },
    /// Kept dense (the uncompressed half of a Table 7 ablation).
    Dense(Tensor),
}

/// Checkpoint field names per moment slot: (dense, q-factor, b-factor).
/// Shared with the registry's variant decoder so encode and decode can
/// never disagree.
pub(crate) const QB_NAMES: [(&str, &str, &str); 2] = [("m", "mq", "mb"), ("v", "vq", "vb")];

/// MLorc's factored Q/B recompression with a per-moment factored/dense
/// mask: `[true, true]` is MLorc-AdamW, `[true]` MLorc-Lion/SGDM, and
/// `[true, false]` / `[false, true]` the Table 7 ablations.
#[derive(Debug, Clone)]
pub struct RsvdQb {
    stores: Vec<MomentStore>,
}

impl RsvdQb {
    pub fn new(factored: &[bool], shape: &[usize], l: usize) -> Result<RsvdQb> {
        if shape.len() != 2 {
            bail!("rsvd_qb compression needs a 2-D parameter, got shape {shape:?}");
        }
        if factored.len() > QB_NAMES.len() {
            bail!("rsvd_qb supports at most {} moments", QB_NAMES.len());
        }
        let (m, n) = (shape[0], shape[1]);
        let stores = factored
            .iter()
            .map(|&f| {
                if f {
                    MomentStore::Factored {
                        q: Tensor::zeros(&[m, l]),
                        b: Tensor::zeros(&[l, n]),
                    }
                } else {
                    MomentStore::Dense(Tensor::zeros(&[m, n]))
                }
            })
            .collect();
        Ok(RsvdQb { stores })
    }

    pub fn from_stores(stores: Vec<MomentStore>) -> RsvdQb {
        RsvdQb { stores }
    }
}

impl MomentumCompressor for RsvdQb {
    fn id(&self) -> &'static str {
        "rsvd_qb"
    }

    fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        let mut out = Vec::new();
        for (k, store) in self.stores.iter().enumerate() {
            let (dense, qn, bn) = QB_NAMES[k];
            match store {
                MomentStore::Factored { q, b } => {
                    out.push((qn, q));
                    out.push((bn, b));
                }
                MomentStore::Dense(t) => out.push((dense, t)),
            }
        }
        out
    }

    fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        let mut out = Vec::new();
        for (k, store) in self.stores.iter_mut().enumerate() {
            let (dense, qn, bn) = QB_NAMES[k];
            match store {
                MomentStore::Factored { q, b } => {
                    out.push((qn, &mut *q));
                    out.push((bn, &mut *b));
                }
                MomentStore::Dense(t) => out.push((dense, &mut *t)),
            }
        }
        out
    }

    fn first_moment(&self) -> Option<Tensor> {
        match self.stores.first()? {
            MomentStore::Factored { q, b } => Some(matmul(q, b)),
            MomentStore::Dense(t) => Some(t.clone()),
        }
    }

    fn second_moment(&self) -> Option<Tensor> {
        match self.stores.get(1)? {
            MomentStore::Factored { q, b } => Some(matmul(q, b)),
            MomentStore::Dense(t) => Some(t.clone()),
        }
    }

    fn omega_graph_shapes(&self) -> Vec<[usize; 2]> {
        self.stores
            .iter()
            .filter_map(|s| match s {
                MomentStore::Factored { q, b } => Some([b.shape[1], q.shape[1]]),
                MomentStore::Dense(_) => None,
            })
            .collect()
    }

    fn step(
        &mut self,
        rule: &'static dyn UpdateRule,
        hp: &OptHp,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<()> {
        use MomentStore::{Dense as D, Factored as F};
        let (_, n) = w.dims2()?;
        // Fused reconstruct-apply routing. Omega draws happen here, right
        // before the kernel, in moment order — the exact pre-refactor
        // stream schedule.
        match (rule.kind(), &mut self.stores[..]) {
            (RuleKind::AdamW, [F { q: mq, b: mb }, F { q: vq, b: vb }]) => {
                let l = mq.shape[1];
                let om_m = rng.gaussian_tensor(&[n, l], 1.0);
                let om_v = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_adamw_core(w, g, mq, mb, vq, vb, t, lr, hp, &om_m, &om_v, ws);
            }
            (RuleKind::AdamW, [F { q: mq, b: mb }, D(v)]) => {
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_m_core(w, g, mq, mb, v, t, lr, hp, &om, ws);
            }
            (RuleKind::AdamW, [D(m), F { q: vq, b: vb }]) => {
                let l = vq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_v_core(w, g, m, vq, vb, t, lr, hp, &om, ws);
            }
            (RuleKind::Lion, [F { q: mq, b: mb }]) => {
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_lion_core(w, g, mq, mb, lr, hp, &om, ws);
            }
            (RuleKind::SgdM, [F { q: mq, b: mb }]) => {
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_sgdm_core(w, g, mq, mb, lr, hp, &om, ws);
            }
            _ => bail!(
                "no fused kernel for rule '{}' with this rsvd_qb moment layout",
                rule.id()
            ),
        }
        Ok(())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn MomentumCompressor> {
        Box::new(self.clone())
    }
}

// --------------------------------------------------------------- adarank

/// Tail-energy fraction under which [`AdaRank`] drops factor directions:
/// the largest set of lowest-energy B rows whose cumulative energy is at
/// most this fraction of the total goes away (AdaRankGrad, 2410.17881:
/// gradient — and so momentum — rank decays as training converges).
pub const ADARANK_TAIL_FRAC: f32 = 0.01;

/// `RsvdQb` with an online per-parameter rank schedule. Every moment is
/// factored; after each recompression the retained spectral energy of the
/// new B is inspected (Q is column-orthonormal, so direction i's energy
/// is `||B[i, :]||²`), and directions in a negligible tail are dropped —
/// Q loses the column, B the row, and the next step's Omega draw shrinks
/// with them. Rank only ever decreases, floored at `rank_min`
/// (`--rank-min`); shrink events count into checkpoints and `mlorc
/// status`.
#[derive(Debug, Clone)]
pub struct AdaRank {
    /// (q, b) per rule moment — always factored.
    stores: Vec<(Tensor, Tensor)>,
    pub rank_min: usize,
    pub shrinks: usize,
}

impl AdaRank {
    pub fn new(n_moments: usize, shape: &[usize], l: usize, rank_min: usize) -> Result<AdaRank> {
        if shape.len() != 2 {
            bail!("adarank compression needs a 2-D parameter, got shape {shape:?}");
        }
        if n_moments > QB_NAMES.len() {
            bail!("adarank supports at most {} moments", QB_NAMES.len());
        }
        let (m, n) = (shape[0], shape[1]);
        let rank_min = rank_min.clamp(1, l.max(1));
        let stores = (0..n_moments)
            .map(|_| (Tensor::zeros(&[m, l]), Tensor::zeros(&[l, n])))
            .collect();
        Ok(AdaRank { stores, rank_min, shrinks: 0 })
    }

    pub fn from_parts(stores: Vec<(Tensor, Tensor)>, rank_min: usize, shrinks: usize) -> AdaRank {
        AdaRank { stores, rank_min, shrinks }
    }

    /// Current factor rank of each moment (shapes are the source of truth).
    pub fn ranks(&self) -> Vec<usize> {
        self.stores.iter().map(|(q, _)| q.shape[1]).collect()
    }

    /// Drop the lowest-energy directions of one (q, b) pair whose
    /// cumulative B-row energy stays within [`ADARANK_TAIL_FRAC`] of the
    /// total, never going below `rank_min`. Returns true if the rank
    /// shrank. Deterministic: energies sort by (value, index).
    fn shrink_pair(q: &mut Tensor, b: &mut Tensor, rank_min: usize) -> bool {
        let (m, l) = q.dims2().expect("adarank q");
        let (_, n) = b.dims2().expect("adarank b");
        if l <= rank_min {
            return false;
        }
        let energy: Vec<f64> = (0..l)
            .map(|i| b.data[i * n..(i + 1) * n].iter().map(|x| (*x as f64) * (*x as f64)).sum())
            .collect();
        let total: f64 = energy.iter().sum();
        let budget = ADARANK_TAIL_FRAC as f64 * total;
        let mut order: Vec<usize> = (0..l).collect();
        order.sort_by(|&a, &bi| {
            energy[a].partial_cmp(&energy[bi]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&bi))
        });
        let mut drop = vec![false; l];
        let mut cum = 0.0f64;
        let mut kept = l;
        for &i in &order {
            if kept == rank_min || cum + energy[i] > budget {
                break;
            }
            cum += energy[i];
            drop[i] = true;
            kept -= 1;
        }
        if kept == l {
            return false;
        }
        let mut q2 = Tensor::zeros(&[m, kept]);
        let mut b2 = Tensor::zeros(&[kept, n]);
        let keep: Vec<usize> = (0..l).filter(|i| !drop[*i]).collect();
        for (jn, &jo) in keep.iter().enumerate() {
            for r in 0..m {
                q2.data[r * kept + jn] = q.data[r * l + jo];
            }
            b2.data[jn * n..(jn + 1) * n].copy_from_slice(&b.data[jo * n..(jo + 1) * n]);
        }
        *q = q2;
        *b = b2;
        true
    }
}

impl MomentumCompressor for AdaRank {
    fn id(&self) -> &'static str {
        "adarank"
    }

    fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        let mut out = Vec::new();
        for (k, (q, b)) in self.stores.iter().enumerate() {
            let (_, qn, bn) = QB_NAMES[k];
            out.push((qn, q));
            out.push((bn, b));
        }
        out
    }

    fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        let mut out = Vec::new();
        for (k, (q, b)) in self.stores.iter_mut().enumerate() {
            let (_, qn, bn) = QB_NAMES[k];
            out.push((qn, &mut *q));
            out.push((bn, &mut *b));
        }
        out
    }

    fn flags_into(&self, meta: &mut Json) {
        meta.set("rank_min", Json::num(self.rank_min as f64));
        meta.set("shrinks", Json::num(self.shrinks as f64));
    }

    fn shrink_events(&self) -> usize {
        self.shrinks
    }

    fn first_moment(&self) -> Option<Tensor> {
        self.stores.first().map(|(q, b)| matmul(q, b))
    }

    fn second_moment(&self) -> Option<Tensor> {
        self.stores.get(1).map(|(q, b)| matmul(q, b))
    }

    fn omega_graph_shapes(&self) -> Vec<[usize; 2]> {
        self.stores.iter().map(|(q, b)| [b.shape[1], q.shape[1]]).collect()
    }

    fn step(
        &mut self,
        rule: &'static dyn UpdateRule,
        hp: &OptHp,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<()> {
        let (_, n) = w.dims2()?;
        // Same kernels and Omega schedule as RsvdQb at each moment's
        // *current* rank, then the adaptation pass.
        match (rule.kind(), &mut self.stores[..]) {
            (RuleKind::AdamW, [(mq, mb), (vq, vb)]) => {
                let om_m = rng.gaussian_tensor(&[n, mq.shape[1]], 1.0);
                let om_v = rng.gaussian_tensor(&[n, vq.shape[1]], 1.0);
                mlorc_adamw_core(w, g, mq, mb, vq, vb, t, lr, hp, &om_m, &om_v, ws);
            }
            (RuleKind::Lion, [(mq, mb)]) => {
                let om = rng.gaussian_tensor(&[n, mq.shape[1]], 1.0);
                mlorc_lion_core(w, g, mq, mb, lr, hp, &om, ws);
            }
            (RuleKind::SgdM, [(mq, mb)]) => {
                let om = rng.gaussian_tensor(&[n, mq.shape[1]], 1.0);
                mlorc_sgdm_core(w, g, mq, mb, lr, hp, &om, ws);
            }
            _ => bail!(
                "no adaptive-rank kernel for rule '{}' with {} moment(s)",
                rule.id(),
                self.stores.len()
            ),
        }
        let rank_min = self.rank_min;
        let mut shrank = false;
        for (q, b) in self.stores.iter_mut() {
            shrank |= AdaRank::shrink_pair(q, b, rank_min);
        }
        if shrank {
            self.shrinks += 1;
        }
        Ok(())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn MomentumCompressor> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------- galore

/// GaLore: moments live in a low-rank subspace spanned by a projector `p`
/// refreshed from the gradient on a cadence the *caller* owns (the
/// trainer clears `refreshed` every `galore_update_freq` steps).
#[derive(Debug, Clone)]
pub struct GaloreProjector {
    /// (m, l) when `left` (m <= n), else (n, l).
    pub p: Tensor,
    /// Low-dim moment buffers, one per rule moment (`m_lo`[, `v_lo`]).
    lo: Vec<Tensor>,
    pub left: bool,
    pub refreshed: bool,
}

/// Low-dim moment field names per slot.
const LO_NAMES: [&str; 2] = ["m_lo", "v_lo"];

impl GaloreProjector {
    pub fn new(n_moments: usize, shape: &[usize], l: usize) -> Result<GaloreProjector> {
        if shape.len() != 2 {
            bail!("galore projection needs a 2-D parameter, got shape {shape:?}");
        }
        if n_moments > LO_NAMES.len() {
            bail!("galore supports at most {} moments", LO_NAMES.len());
        }
        let (m, n) = (shape[0], shape[1]);
        let left = m <= n;
        let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
        Ok(GaloreProjector {
            p: Tensor::zeros(&pshape),
            lo: (0..n_moments).map(|_| Tensor::zeros(&rshape)).collect(),
            left,
            refreshed: false,
        })
    }

    pub fn from_parts(p: Tensor, lo: Vec<Tensor>, left: bool, refreshed: bool) -> GaloreProjector {
        GaloreProjector { p, lo, left, refreshed }
    }
}

impl MomentumCompressor for GaloreProjector {
    fn id(&self) -> &'static str {
        "galore"
    }

    fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        let mut out = vec![("p", &self.p)];
        for (k, t) in self.lo.iter().enumerate() {
            out.push((LO_NAMES[k], t));
        }
        out
    }

    fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        let mut out = vec![("p", &mut self.p)];
        for (k, t) in self.lo.iter_mut().enumerate() {
            out.push((LO_NAMES[k], t));
        }
        out
    }

    fn graph_output_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        // The step graph treats the projector as a constant; it is
        // refreshed by its own `galore_project` graph.
        self.tensor_fields_mut().into_iter().filter(|(name, _)| *name != "p").collect()
    }

    fn flags_into(&self, meta: &mut Json) {
        meta.set("left", Json::Bool(self.left));
        meta.set("refreshed", Json::Bool(self.refreshed));
    }

    fn invalidate_projector(&mut self) {
        self.refreshed = false;
    }

    fn as_galore_mut(&mut self) -> Option<&mut GaloreProjector> {
        Some(self)
    }

    fn step(
        &mut self,
        rule: &'static dyn UpdateRule,
        hp: &OptHp,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        _ws: &mut Workspace,
    ) -> Result<()> {
        // Refresh cadence lives with the caller (it clears `refreshed`
        // every `galore_update_freq` steps, mirroring the graph path);
        // the Omega draw happens only on refresh, keeping the
        // per-parameter stream schedule-independent.
        let l = self.p.shape[1];
        if !self.refreshed {
            galore_refresh_projector(&mut self.p, g, self.left, l, rng);
            self.refreshed = true;
        }
        match (rule.kind(), &mut self.lo[..]) {
            (RuleKind::AdamW, [m_lo, v_lo]) => {
                galore_core(w, g, &self.p, m_lo, v_lo, self.left, t, lr, hp);
            }
            (RuleKind::Lion, [m_lo]) => {
                galore_lion_core(w, g, &self.p, m_lo, self.left, lr, hp);
            }
            _ => bail!(
                "no subspace kernel for rule '{}' with {} galore moment(s)",
                rule.id(),
                self.lo.len()
            ),
        }
        Ok(())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn MomentumCompressor> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------- ldproj

/// LDAdamW: per-step projector from the error-compensated gradient,
/// rotation-aware low-dim Adam state, full-size error-feedback buffer.
/// The rotation's `|·|` on the second moment is Adam-specific, so this
/// compressor only pairs with the AdamW rule.
#[derive(Debug, Clone)]
pub struct LdProj {
    pub p: Tensor,
    pub m_lo: Tensor,
    pub v_lo: Tensor,
    /// full-size error feedback — the memory cost Table 3 exposes
    pub e: Tensor,
    pub left: bool,
}

impl LdProj {
    pub fn new(shape: &[usize], l: usize) -> Result<LdProj> {
        if shape.len() != 2 {
            bail!("ldproj compression needs a 2-D parameter, got shape {shape:?}");
        }
        let (m, n) = (shape[0], shape[1]);
        let left = m <= n;
        let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
        Ok(LdProj {
            p: Tensor::zeros(&pshape),
            m_lo: Tensor::zeros(&rshape),
            v_lo: Tensor::zeros(&rshape),
            e: Tensor::zeros(shape),
            left,
        })
    }
}

impl MomentumCompressor for LdProj {
    fn id(&self) -> &'static str {
        "ldproj"
    }

    fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        vec![("p", &self.p), ("m_lo", &self.m_lo), ("v_lo", &self.v_lo), ("e", &self.e)]
    }

    fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        vec![
            ("p", &mut self.p),
            ("m_lo", &mut self.m_lo),
            ("v_lo", &mut self.v_lo),
            ("e", &mut self.e),
        ]
    }

    fn flags_into(&self, meta: &mut Json) {
        meta.set("left", Json::Bool(self.left));
    }

    fn omega_graph_shapes(&self) -> Vec<[usize; 2]> {
        let l = self.p.shape[1];
        let (m, n) = (self.e.shape[0], self.e.shape[1]);
        if self.left {
            vec![[n, l]]
        } else {
            vec![[m, l]]
        }
    }

    fn step(
        &mut self,
        rule: &'static dyn UpdateRule,
        hp: &OptHp,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        _ws: &mut Workspace,
    ) -> Result<()> {
        if rule.kind() != RuleKind::AdamW {
            bail!("ldproj's rotation-aware state is AdamW-specific (got rule '{}')", rule.id());
        }
        let l = self.p.shape[1];
        ldadamw_core(
            w,
            g,
            &mut self.p,
            &mut self.m_lo,
            &mut self.v_lo,
            &mut self.e,
            self.left,
            l,
            t,
            lr,
            hp,
            rng,
        );
        Ok(())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn MomentumCompressor> {
        Box::new(self.clone())
    }
}

// ----------------------------------------------------------- shape class

/// One member of a shape-class batched step: the parameter, its gradient,
/// its optimizer state and its own Omega RNG stream. The planner
/// (`coordinator::state::host_step_all`) guarantees every member of a
/// class shares (variant, weight shape, state-field shapes).
pub struct ClassJob<'a> {
    pub w: &'a mut Tensor,
    pub g: &'a Tensor,
    pub opt: &'a mut MatrixOpt,
    pub rng: &'a mut Rng,
    pub lr: f32,
    pub t: usize,
}

/// Step a whole shape class at once. QB-factored layouts (`RsvdQb` with
/// every moment factored, `AdaRank`, `QuantQb`) run through the stacked
/// class kernels — one banded invocation per phase for the entire class.
/// Everything else (dense, projector and masked layouts) falls back to
/// one scalar step per member, executed as atomically-claimed pool tasks
/// with per-task serial kernels. Both routes are bit-identical to calling
/// [`MatrixOpt::step`] member by member in job order: per member the
/// arithmetic, phase order and Omega consumption are exactly the scalar
/// path's, and members only ever touch their own state
/// (`tests/host_parallel.rs` pins this for every registered method).
pub fn step_class(jobs: &mut [ClassJob], workspaces: &mut [Workspace]) -> Result<()> {
    if jobs.is_empty() {
        return Ok(());
    }
    assert!(!workspaces.is_empty(), "step_class needs at least one workspace");
    let _span = obs::span(&obs::registry::STEP_CLASS_US);
    obs::registry::STEP_CLASSES.add(1);
    obs::registry::STEP_MEMBERS.add(jobs.len() as u64);
    if jobs.len() == 1 {
        // Size-1 class: scalar step with full kernel-level parallelism
        // (the per-member fallback would force serial kernels).
        let j = &mut jobs[0];
        return j.opt.step(j.w, j.g, j.lr, j.t, j.rng, &mut workspaces[0]);
    }
    enum Route {
        Qb,
        Quant,
        Members,
    }
    let kind = jobs[0].opt.rule().kind();
    let hp = jobs[0].opt.hp();
    let route = if jobs[0].opt.needs_member_step() {
        // Wrapper-carrying states (Prodigy, bf16 planes, folds, modifier
        // flags) need the full MatrixOpt orchestration around the
        // compressor — decided before any compressor downcast.
        Route::Members
    } else {
        let any = jobs[0].opt.comp_mut().as_any_mut();
        if let Some(qb) = any.downcast_ref::<RsvdQb>() {
            if qb.stores.iter().all(|s| matches!(s, MomentStore::Factored { .. })) {
                Route::Qb
            } else {
                Route::Members
            }
        } else if any.is::<AdaRank>() {
            Route::Qb
        } else if any.is::<QuantQb>() {
            Route::Quant
        } else {
            Route::Members
        }
    };
    match route {
        Route::Qb => step_class_qb(jobs, &hp, kind, workspaces),
        Route::Quant => step_class_quant(jobs, &hp, kind, workspaces),
        Route::Members => step_class_members(jobs, workspaces),
    }
}

/// Batched route for f32 QB-factored layouts (`RsvdQb` all-factored,
/// `AdaRank`): gather every member's factor pairs, draw each member's
/// Omegas from its own stream (moment order — the scalar schedule), run
/// the stacked class core, then the per-member AdaRank adaptation pass.
fn step_class_qb(
    jobs: &mut [ClassJob],
    hp: &OptHp,
    kind: RuleKind,
    workspaces: &mut [Workspace],
) -> Result<()> {
    {
        let mut qjobs: Vec<QbClassJob> = Vec::with_capacity(jobs.len());
        for j in jobs.iter_mut() {
            let ClassJob { w, g, opt, rng, lr, t } = j;
            let (_, n) = w.dims2()?;
            let any = opt.comp_mut().as_any_mut();
            let factors: Vec<(&mut Tensor, &mut Tensor)> = if any.is::<AdaRank>() {
                let ar = any.downcast_mut::<AdaRank>().expect("adarank downcast");
                ar.stores.iter_mut().map(|(q, b)| (&mut *q, &mut *b)).collect()
            } else {
                let qb = any.downcast_mut::<RsvdQb>().expect("rsvd_qb downcast");
                let mut out = Vec::with_capacity(qb.stores.len());
                for store in qb.stores.iter_mut() {
                    match store {
                        MomentStore::Factored { q, b } => out.push((&mut *q, &mut *b)),
                        MomentStore::Dense(_) => {
                            bail!("masked rsvd_qb member reached the batched QB path")
                        }
                    }
                }
                out
            };
            let omegas: Vec<Tensor> = factors
                .iter()
                .map(|(q, _)| rng.gaussian_tensor(&[n, q.shape[1]], 1.0))
                .collect();
            qjobs.push(QbClassJob { w: &mut **w, g: &**g, lr: *lr, t: *t, factors, omegas });
        }
        match (kind, qjobs[0].factors.len()) {
            (RuleKind::AdamW, 2) => mlorc_adamw_core_class(&mut qjobs, hp, workspaces),
            (RuleKind::Lion, 1) => mlorc_lion_core_class(&mut qjobs, hp, workspaces),
            (RuleKind::SgdM, 1) => mlorc_sgdm_core_class(&mut qjobs, hp, workspaces),
            (_, nm) => bail!("no batched QB kernel for this rule with {nm} moment(s)"),
        }
    }
    // AdaRank adaptation, per member in job order — exactly the scalar
    // step's trailing pass.
    for j in jobs.iter_mut() {
        if let Some(ar) = j.opt.comp_mut().as_any_mut().downcast_mut::<AdaRank>() {
            let rank_min = ar.rank_min;
            let mut shrank = false;
            for (q, b) in ar.stores.iter_mut() {
                shrank |= AdaRank::shrink_pair(q, b, rank_min);
            }
            if shrank {
                ar.shrinks += 1;
            }
        }
    }
    Ok(())
}

/// Batched route for `QuantQb`: dequantize every member's factors into
/// pooled scratch (before the Omega draws, like the scalar step), run the
/// same stacked class core as the f32 route, requantize in place.
fn step_class_quant(
    jobs: &mut [ClassJob],
    hp: &OptHp,
    kind: RuleKind,
    workspaces: &mut [Workspace],
) -> Result<()> {
    let expect = match kind {
        RuleKind::AdamW => 2,
        RuleKind::Lion | RuleKind::SgdM => 1,
    };
    let mut deq: Vec<Vec<(Tensor, Tensor)>> = Vec::with_capacity(jobs.len());
    for j in jobs.iter_mut() {
        let qq =
            j.opt.comp_mut().as_any_mut().downcast_mut::<QuantQb>().expect("quant_qb downcast");
        if qq.n_moments() != expect {
            bail!(
                "no quantized batched kernel for rule '{}' with {} q8 moment(s)",
                jobs_rule_id(kind),
                qq.n_moments()
            );
        }
        deq.push((0..expect).map(|k| qq.dequantized(k, &mut workspaces[0])).collect());
    }
    {
        let mut qjobs: Vec<QbClassJob> = Vec::with_capacity(jobs.len());
        for (j, pairs) in jobs.iter_mut().zip(deq.iter_mut()) {
            let ClassJob { w, g, rng, lr, t, .. } = j;
            let (_, n) = w.dims2()?;
            let factors: Vec<(&mut Tensor, &mut Tensor)> =
                pairs.iter_mut().map(|(q, b)| (&mut *q, &mut *b)).collect();
            let omegas: Vec<Tensor> = factors
                .iter()
                .map(|(q, _)| rng.gaussian_tensor(&[n, q.shape[1]], 1.0))
                .collect();
            qjobs.push(QbClassJob { w: &mut **w, g: &**g, lr: *lr, t: *t, factors, omegas });
        }
        match kind {
            RuleKind::AdamW => mlorc_adamw_core_class(&mut qjobs, hp, workspaces),
            RuleKind::Lion => mlorc_lion_core_class(&mut qjobs, hp, workspaces),
            RuleKind::SgdM => mlorc_sgdm_core_class(&mut qjobs, hp, workspaces),
        }
    }
    for (j, pairs) in jobs.iter_mut().zip(deq) {
        let qq =
            j.opt.comp_mut().as_any_mut().downcast_mut::<QuantQb>().expect("quant_qb downcast");
        for (k, (q, b)) in pairs.into_iter().enumerate() {
            qq.requantize(k, &q, &b);
            workspaces[0].give_tensor(q);
            workspaces[0].give_tensor(b);
        }
    }
    Ok(())
}

fn jobs_rule_id(kind: RuleKind) -> &'static str {
    super::rules::rule(kind).id()
}

/// Fallback route: one scalar step per member, each claimed atomically by
/// a pool task and run with serial kernels (member-level parallelism, as
/// the pre-planner hot path did — but only for layouts without a stacked
/// kernel). The first error wins; later members are skipped.
fn step_class_members(jobs: &mut [ClassJob], workspaces: &mut [Workspace]) -> Result<()> {
    let nslots = workspaces.len().min(jobs.len());
    if nslots <= 1 {
        for j in jobs.iter_mut() {
            j.opt.step(j.w, j.g, j.lr, j.t, j.rng, &mut workspaces[0])?;
        }
        return Ok(());
    }
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let tasks = pool::DisjointMut::new(jobs);
    let slots: Vec<&mut Workspace> = workspaces.iter_mut().take(nslots).collect();
    pool::par_member_tasks(slots, tasks.len(), |i, ws| {
        if first_err.lock().unwrap().is_some() {
            return;
        }
        let j = unsafe { tasks.item(i) };
        let r = threads::serial(|| j.opt.step(j.w, j.g, j.lr, j.t, j.rng, ws));
        if let Err(e) = r {
            let mut slot = first_err.lock().unwrap();
            if slot.is_none() {
                *slot = Some(e);
            }
        }
    });
    match first_err.into_inner().expect("step_class error mutex") {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::rules::{rule, RuleKind};

    #[test]
    fn field_names_match_checkpoint_v2_layout() {
        // The on-disk field names of every layout are a stable contract
        // (old v2 checkpoints must keep loading).
        let both = RsvdQb::new(&[true, true], &[6, 8], 2).unwrap();
        let names: Vec<_> = both.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["mq", "mb", "vq", "vb"]);
        let m_only = RsvdQb::new(&[true, false], &[6, 8], 2).unwrap();
        let names: Vec<_> = m_only.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["mq", "mb", "v"]);
        let v_only = RsvdQb::new(&[false, true], &[6, 8], 2).unwrap();
        let names: Vec<_> = v_only.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["m", "vq", "vb"]);
        let gal = GaloreProjector::new(2, &[6, 8], 2).unwrap();
        let names: Vec<_> = gal.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["p", "m_lo", "v_lo"]);
        let ld = LdProj::new(&[6, 8], 2).unwrap();
        let names: Vec<_> = ld.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["p", "m_lo", "v_lo", "e"]);
        let dense = Dense::new(rule(RuleKind::AdamW), &[6, 8]);
        let names: Vec<_> = dense.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["m", "v"]);
        // adaptive rank reuses the factored slot names (shapes carry the
        // live rank)
        let ada = AdaRank::new(2, &[6, 8], 2, 1).unwrap();
        let names: Vec<_> = ada.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["mq", "mb", "vq", "vb"]);
    }

    #[test]
    fn galore_graph_outputs_exclude_projector() {
        let mut gal = GaloreProjector::new(2, &[6, 8], 2).unwrap();
        let names: Vec<_> = gal.graph_output_fields_mut().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["m_lo", "v_lo"]);
    }

    #[test]
    fn omega_shapes_follow_factored_moments() {
        let both = RsvdQb::new(&[true, true], &[6, 8], 2).unwrap();
        assert_eq!(both.omega_graph_shapes(), vec![[8, 2], [8, 2]]);
        let v_only = RsvdQb::new(&[false, true], &[6, 8], 2).unwrap();
        assert_eq!(v_only.omega_graph_shapes(), vec![[8, 2]]);
        // LDAdamW: one draw, on the projected side.
        let tall = LdProj::new(&[20, 6], 2).unwrap();
        assert!(!tall.left);
        assert_eq!(tall.omega_graph_shapes(), vec![[20, 2]]);
    }

    #[test]
    fn unsupported_combo_fails_loudly() {
        let hp = OptHp::lion();
        let mut rng = Rng::new(0);
        let mut w = rng.gaussian_tensor(&[6, 8], 1.0);
        let g = rng.gaussian_tensor(&[6, 8], 1.0);
        let mut ws = Workspace::new();
        // Lion (1 moment) against a 2-moment factored layout has no kernel.
        let mut qb = RsvdQb::new(&[true, true], &[6, 8], 2).unwrap();
        let err = qb
            .step(rule(RuleKind::Lion), &hp, &mut w, &g, 1e-2, 1, &mut rng, &mut ws)
            .unwrap_err();
        assert!(format!("{err:#}").contains("lion"), "{err:#}");
        // LDAdamW is AdamW-only.
        let mut ld = LdProj::new(&[6, 8], 2).unwrap();
        assert!(ld
            .step(rule(RuleKind::SgdM), &hp, &mut w, &g, 1e-2, 1, &mut rng, &mut ws)
            .is_err());
    }
}
