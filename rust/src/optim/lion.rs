//! Uncompressed Lion (Chen et al., 2023) — baseline "Full (Lion)".

use crate::tensor::Tensor;

use super::OptHp;

#[derive(Debug, Clone)]
pub struct LionState {
    pub m: Tensor,
    pub t: usize,
}

impl LionState {
    pub fn new(shape: &[usize]) -> LionState {
        LionState { m: Tensor::zeros(shape), t: 0 }
    }

    pub fn state_bytes(&self) -> usize {
        self.m.size_bytes()
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp) {
        self.t += 1;
        for ((wi, mi), gi) in w.data.iter_mut().zip(&self.m.data).zip(&g.data) {
            let c = hp.beta1 * mi + (1.0 - hp.beta1) * gi;
            *wi -= lr * (sign(c) + hp.weight_decay * *wi);
        }
        for (mi, gi) in self.m.data.iter_mut().zip(&g.data) {
            *mi = hp.beta2 * *mi + (1.0 - hp.beta2) * gi;
        }
    }
}

#[inline]
pub(crate) fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    #[test]
    fn update_magnitude_is_lr() {
        let hp = OptHp::lion();
        let mut rng = Rng::new(0);
        let g = rng.gaussian_tensor(&[16], 1.0);
        let mut w = Tensor::zeros(&[16]);
        let mut st = LionState::new(&[16]);
        st.step(&mut w, &g, 0.01, &hp);
        for (wi, gi) in w.data.iter().zip(&g.data) {
            if *gi != 0.0 {
                assert!((wi.abs() - 0.01).abs() < 1e-7);
                assert_eq!(wi.signum(), -gi.signum());
            }
        }
    }

    #[test]
    fn momentum_uses_beta2() {
        let hp = OptHp::lion();
        let g = Tensor::full(&[2], 1.0);
        let mut w = Tensor::zeros(&[2]);
        let mut st = LionState::new(&[2]);
        st.step(&mut w, &g, 0.01, &hp);
        assert!((st.m.data[0] - (1.0 - hp.beta2)).abs() < 1e-7);
    }

    #[test]
    fn converges_on_quadratic() {
        let hp = OptHp::lion();
        let mut rng = Rng::new(1);
        let target = rng.gaussian_tensor(&[4, 4], 1.0);
        let mut w = Tensor::zeros(&[4, 4]);
        let mut st = LionState::new(&[4, 4]);
        let mut lr = 0.05;
        for step in 0..400 {
            if step % 100 == 99 {
                lr *= 0.3; // sign updates need decay to settle
            }
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            st.step(&mut w, &g, lr, &hp);
        }
        assert!(w.rel_err(&target) < 0.1, "rel {}", w.rel_err(&target));
    }
}
