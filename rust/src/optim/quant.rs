//! 8-bit blockwise quantization of MLorc's momentum factors.
//!
//! MLorc already cuts the momentum of an (m, n) matrix from O(m·n) to the
//! rank-l factor pair Q (m, l) / B (l, n). [`QuantQb`] pushes that budget
//! ~4x further ("Taming Momentum", arXiv:2602.24283): between steps each
//! factor is held as symmetric int8 codes with one f32 absmax scale per
//! [`Q8_BLOCK`]-element block, and the step dequantizes the factors into
//! pooled scratch, runs the *same* fused reconstruct-apply kernels as
//! [`RsvdQb`](super::compress::RsvdQb) (`mlorc_adamw_core`,
//! `mlorc_lion_core`, `mlorc_sgdm_core`), and requantizes the fresh
//! factors. Because the stored state *is* the quantized form, a
//! checkpoint roundtrip of codes + scales resumes bit-identically — the
//! property `tests/optim_matrix.rs` pins for every registered method.
//!
//! Quantization error is bounded per element by half a code step,
//! `absmax(block) / 254`, verified as a property test in
//! `tests/quant_adarank.rs`.

// `step` threads the same 8-argument seam as every other compressor (see
// compress.rs — it is the single dispatch surface of the optimizer
// matrix).
#![allow(clippy::too_many_arguments)]

use anyhow::{bail, Result};

use crate::linalg::{matmul, Rng, Workspace};
use crate::tensor::{Tensor, TensorU8};
use crate::util::json::Json;

use super::compress::MomentumCompressor;
use super::rules::{RuleKind, UpdateRule};
use super::{mlorc_adamw_core, mlorc_lion_core, mlorc_sgdm_core, OptHp};

/// Elements per quantization block (one f32 absmax scale each). 64 keeps
/// the scale overhead at 1/16th of the code bytes.
pub const Q8_BLOCK: usize = 64;

/// One blockwise-quantized f32 tensor: symmetric int8 codes (stored as
/// raw bytes) plus one f32 scale per block of [`Q8_BLOCK`] consecutive
/// row-major elements.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    /// int8 codes in two's complement, same shape as the logical tensor.
    pub codes: TensorU8,
    /// per-block scales, shape `[ceil(len / block)]`.
    pub scales: Tensor,
    pub block: usize,
}

impl QTensor {
    /// Quantize `t`: per block, `scale = absmax / 127`,
    /// `code = round(x / scale)` clamped to ±127. An all-zero block gets
    /// scale 0 and zero codes.
    pub fn quantize(t: &Tensor, block: usize) -> QTensor {
        assert!(block > 0, "quantization block must be positive");
        let n = t.data.len();
        let nblocks = n.div_ceil(block).max(1);
        let mut q = QTensor {
            codes: TensorU8 { shape: t.shape.clone(), data: vec![0u8; n] },
            scales: Tensor { shape: vec![nblocks], data: vec![0f32; nblocks] },
            block,
        };
        q.quantize_into(t);
        q
    }

    /// Requantize `t` into this tensor's existing code/scale buffers
    /// (same shape) — the steady-state path allocates nothing, matching
    /// the repo's Workspace-pooled hot-path discipline.
    pub fn quantize_into(&mut self, t: &Tensor) {
        assert_eq!(t.shape, self.codes.shape, "quantize_into shape mismatch");
        for (bi, chunk) in t.data.chunks(self.block).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            let base = bi * self.block;
            if absmax == 0.0 {
                self.scales.data[bi] = 0.0;
                self.codes.data[base..base + chunk.len()].fill(0);
                continue;
            }
            let scale = absmax / 127.0;
            self.scales.data[bi] = scale;
            let inv = 1.0 / scale;
            for (j, &x) in chunk.iter().enumerate() {
                let c = (x * inv).round().clamp(-127.0, 127.0) as i8;
                self.codes.data[base + j] = c as u8;
            }
        }
    }

    /// Rebuild from checkpoint fields; validates the scale count.
    pub fn from_parts(codes: TensorU8, scales: Tensor, block: usize) -> Result<QTensor> {
        if block == 0 {
            bail!("quantization block must be positive");
        }
        let want = codes.len().div_ceil(block).max(1);
        if scales.len() != want {
            bail!(
                "quantized tensor with {} codes at block {block} wants {want} scales, got {}",
                codes.len(),
                want,
                scales.len()
            );
        }
        Ok(QTensor { codes, scales, block })
    }

    pub fn shape(&self) -> &[usize] {
        &self.codes.shape
    }

    /// Dequantize into a pre-shaped tensor: `x = i8(code) * scale`.
    pub fn dequantize_into(&self, out: &mut Tensor) {
        assert_eq!(out.shape, self.codes.shape, "dequantize shape mismatch");
        for (bi, chunk) in self.codes.data.chunks(self.block).enumerate() {
            let scale = self.scales.data[bi];
            let base = bi * self.block;
            for (j, &c) in chunk.iter().enumerate() {
                out.data[base + j] = (c as i8) as f32 * scale;
            }
        }
    }

    pub fn dequantize(&self) -> Tensor {
        let mut out = Tensor::zeros(&self.codes.shape);
        self.dequantize_into(&mut out);
        out
    }

    /// 1 byte per code + 4 per block scale — the Table 1/3 quantity.
    pub fn size_bytes(&self) -> usize {
        self.codes.size_bytes() + self.scales.size_bytes()
    }
}

// --------------------------------------------------------------- quant_qb

/// Checkpoint field names per moment slot:
/// (q codes, q scales, b codes, b scales). Shared with the registry's
/// variant decoder so encode and decode can never disagree.
pub(crate) const Q8_NAMES: [(&str, &str, &str, &str); 2] =
    [("mq_q8", "mq_sc", "mb_q8", "mb_sc"), ("vq_q8", "vq_sc", "vb_q8", "vb_sc")];

/// One rule moment held as a quantized Q/B factor pair.
#[derive(Debug, Clone)]
pub struct QMoment {
    pub q: QTensor,
    pub b: QTensor,
}

/// MLorc's factored recompression with both factors of every moment
/// blockwise-quantized to 8 bits between steps. Composes with any rule
/// whose moments are linear EMAs through the same fused kernels as
/// `RsvdQb`; the state layout (and so `state_bytes`) is ~1/4 of the f32
/// factored one.
#[derive(Debug, Clone)]
pub struct QuantQb {
    moments: Vec<QMoment>,
    block: usize,
}

impl QuantQb {
    pub fn new(n_moments: usize, shape: &[usize], l: usize) -> Result<QuantQb> {
        if shape.len() != 2 {
            bail!("q8 compression needs a 2-D parameter, got shape {shape:?}");
        }
        if n_moments > Q8_NAMES.len() {
            bail!("q8 supports at most {} moments", Q8_NAMES.len());
        }
        let (m, n) = (shape[0], shape[1]);
        let moments = (0..n_moments)
            .map(|_| QMoment {
                q: QTensor::quantize(&Tensor::zeros(&[m, l]), Q8_BLOCK),
                b: QTensor::quantize(&Tensor::zeros(&[l, n]), Q8_BLOCK),
            })
            .collect();
        Ok(QuantQb { moments, block: Q8_BLOCK })
    }

    pub fn from_moments(moments: Vec<QMoment>, block: usize) -> QuantQb {
        QuantQb { moments, block }
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Moment slots held (2 for AdamW, 1 for Lion/SGDM) — the batched
    /// stepping route validates the rule against this.
    pub(crate) fn n_moments(&self) -> usize {
        self.moments.len()
    }

    /// Dequantize one moment's factors into pooled scratch.
    pub(crate) fn dequantized(&self, k: usize, ws: &mut Workspace) -> (Tensor, Tensor) {
        let mm = &self.moments[k];
        let mut q = ws.take_tensor(mm.q.shape());
        let mut b = ws.take_tensor(mm.b.shape());
        mm.q.dequantize_into(&mut q);
        mm.b.dequantize_into(&mut b);
        (q, b)
    }

    /// Requantize one moment from freshly updated factors, in place —
    /// QuantQb's factor shapes are fixed, so the existing code/scale
    /// buffers are reused (no per-step allocation).
    pub(crate) fn requantize(&mut self, k: usize, q: &Tensor, b: &Tensor) {
        self.moments[k].q.quantize_into(q);
        self.moments[k].b.quantize_into(b);
    }
}

impl MomentumCompressor for QuantQb {
    fn id(&self) -> &'static str {
        "quant_qb"
    }

    fn tensor_fields(&self) -> Vec<(&'static str, &Tensor)> {
        let mut out = Vec::new();
        for (k, mm) in self.moments.iter().enumerate() {
            let (_, q_sc, _, b_sc) = Q8_NAMES[k];
            out.push((q_sc, &mm.q.scales));
            out.push((b_sc, &mm.b.scales));
        }
        out
    }

    fn tensor_fields_mut(&mut self) -> Vec<(&'static str, &mut Tensor)> {
        let mut out = Vec::new();
        for (k, mm) in self.moments.iter_mut().enumerate() {
            let (_, q_sc, _, b_sc) = Q8_NAMES[k];
            out.push((q_sc, &mut mm.q.scales));
            out.push((b_sc, &mut mm.b.scales));
        }
        out
    }

    fn u8_fields(&self) -> Vec<(&'static str, &TensorU8)> {
        let mut out = Vec::new();
        for (k, mm) in self.moments.iter().enumerate() {
            let (q_q8, _, b_q8, _) = Q8_NAMES[k];
            out.push((q_q8, &mm.q.codes));
            out.push((b_q8, &mm.b.codes));
        }
        out
    }

    fn u8_fields_mut(&mut self) -> Vec<(&'static str, &mut TensorU8)> {
        let mut out = Vec::new();
        for (k, mm) in self.moments.iter_mut().enumerate() {
            let (q_q8, _, b_q8, _) = Q8_NAMES[k];
            out.push((q_q8, &mut mm.q.codes));
            out.push((b_q8, &mut mm.b.codes));
        }
        out
    }

    fn flags_into(&self, meta: &mut Json) {
        meta.set("q8_block", Json::num(self.block as f64));
    }

    fn first_moment(&self) -> Option<Tensor> {
        let mm = self.moments.first()?;
        Some(matmul(&mm.q.dequantize(), &mm.b.dequantize()))
    }

    fn second_moment(&self) -> Option<Tensor> {
        let mm = self.moments.get(1)?;
        Some(matmul(&mm.q.dequantize(), &mm.b.dequantize()))
    }

    fn omega_graph_shapes(&self) -> Vec<[usize; 2]> {
        self.moments
            .iter()
            .map(|mm| [mm.b.shape()[1], mm.q.shape()[1]])
            .collect()
    }

    fn step(
        &mut self,
        rule: &'static dyn UpdateRule,
        hp: &OptHp,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<()> {
        let (_, n) = w.dims2()?;
        // Same Omega draw schedule as RsvdQb: one [n, l] draw per moment,
        // in moment order, right before the kernel.
        match (rule.kind(), self.moments.len()) {
            (RuleKind::AdamW, 2) => {
                let (mut mq, mut mb) = self.dequantized(0, ws);
                let (mut vq, mut vb) = self.dequantized(1, ws);
                let l_m = mq.shape[1];
                let l_v = vq.shape[1];
                let om_m = rng.gaussian_tensor(&[n, l_m], 1.0);
                let om_v = rng.gaussian_tensor(&[n, l_v], 1.0);
                mlorc_adamw_core(
                    w, g, &mut mq, &mut mb, &mut vq, &mut vb, t, lr, hp, &om_m, &om_v, ws,
                );
                self.requantize(0, &mq, &mb);
                self.requantize(1, &vq, &vb);
                for buf in [mq, mb, vq, vb] {
                    ws.give_tensor(buf);
                }
            }
            (RuleKind::Lion, 1) => {
                let (mut mq, mut mb) = self.dequantized(0, ws);
                let om = rng.gaussian_tensor(&[n, mq.shape[1]], 1.0);
                mlorc_lion_core(w, g, &mut mq, &mut mb, lr, hp, &om, ws);
                self.requantize(0, &mq, &mb);
                ws.give_tensor(mq);
                ws.give_tensor(mb);
            }
            (RuleKind::SgdM, 1) => {
                let (mut mq, mut mb) = self.dequantized(0, ws);
                let om = rng.gaussian_tensor(&[n, mq.shape[1]], 1.0);
                mlorc_sgdm_core(w, g, &mut mq, &mut mb, lr, hp, &om, ws);
                self.requantize(0, &mq, &mb);
                ws.give_tensor(mq);
                ws.give_tensor(mb);
            }
            _ => bail!(
                "no quantized kernel for rule '{}' with {} q8 moment(s)",
                rule.id(),
                self.moments.len()
            ),
        }
        Ok(())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn MomentumCompressor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::rules::rule;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut rng = Rng::new(11);
        let t = rng.gaussian_tensor(&[13, 17], 2.0);
        let q = QTensor::quantize(&t, Q8_BLOCK);
        let back = q.dequantize();
        for (bi, chunk) in t.data.chunks(Q8_BLOCK).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            for (j, &x) in chunk.iter().enumerate() {
                let err = (x - back.data[bi * Q8_BLOCK + j]).abs();
                assert!(err <= absmax / 253.0, "block {bi} elem {j}: err {err}");
            }
        }
    }

    #[test]
    fn zero_blocks_stay_zero() {
        let t = Tensor::zeros(&[4, 40]);
        let q = QTensor::quantize(&t, Q8_BLOCK);
        assert!(q.scales.data.iter().all(|s| *s == 0.0));
        assert_eq!(q.dequantize().data, t.data);
    }

    #[test]
    fn quantize_into_resets_stale_state() {
        // The in-place hot path must fully overwrite the previous step's
        // codes and scales — including blocks that became all-zero.
        let mut rng = Rng::new(21);
        let a = rng.gaussian_tensor(&[3, 50], 1.0);
        let b = rng.gaussian_tensor(&[3, 50], 0.3);
        let mut q = QTensor::quantize(&a, Q8_BLOCK);
        q.quantize_into(&b);
        let fresh = QTensor::quantize(&b, Q8_BLOCK);
        assert_eq!(q, fresh, "in-place requantize must equal a fresh quantize");
        q.quantize_into(&Tensor::zeros(&[3, 50]));
        assert!(q.scales.data.iter().all(|s| *s == 0.0));
        assert!(q.codes.data.iter().all(|c| *c == 0));
    }

    #[test]
    fn state_bytes_quarter_of_f32_factors() {
        let q8 = QuantQb::new(2, &[512, 128], 4).unwrap();
        let f32_bytes = 2 * 4 * (512 + 128) * 4; // RsvdQb: 2 moments of r(m+n) floats
        let got = q8.state_bytes();
        assert!(
            got < f32_bytes / 3,
            "q8 state {got}B vs f32 factored {f32_bytes}B"
        );
    }

    #[test]
    fn field_names_are_stable() {
        let q8 = QuantQb::new(2, &[6, 8], 2).unwrap();
        let names: Vec<_> = q8.tensor_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["mq_sc", "mb_sc", "vq_sc", "vb_sc"]);
        let names: Vec<_> = q8.u8_fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["mq_q8", "mb_q8", "vq_q8", "vb_q8"]);
    }

    #[test]
    fn unsupported_combo_fails_loudly() {
        let hp = OptHp::lion();
        let mut rng = Rng::new(0);
        let mut w = rng.gaussian_tensor(&[6, 8], 1.0);
        let g = rng.gaussian_tensor(&[6, 8], 1.0);
        let mut ws = Workspace::new();
        let mut q8 = QuantQb::new(2, &[6, 8], 2).unwrap();
        let err = q8
            .step(rule(RuleKind::Lion), &hp, &mut w, &g, 1e-2, 1, &mut rng, &mut ws)
            .unwrap_err();
        assert!(format!("{err:#}").contains("lion"), "{err:#}");
    }
}
