//! bf16 weight planes with stochastic rounding.
//!
//! Mirrors the exemplar `bf16_stochastic_rounding.add_stochastic_`: the
//! master copy of each parameter lives as bf16 bit patterns (upper 16 bits
//! of the f32), and every store rounds stochastically — the low 16 bits of
//! the f32 are compared against a uniform u16 draw, so the *expected* stored
//! value equals the unrounded f32. That unbiasedness is what lets a bf16
//! weight layout train without the systematic drift round-to-nearest would
//! accumulate over thousands of tiny updates.
//!
//! Randomness comes from the caller's per-parameter `Rng` stream (drawn
//! *after* the step's Omega draws), so runs stay deterministic and
//! kill/resume stays bit-identical — the draw schedule is part of the
//! checkpoint contract, like the Omega schedule (`docs/checkpoint-v2.md`).
//!
//! Weights on this layout always sit on the bf16 grid: after each store the
//! f32 working copy is refreshed by the exact bf16→f32 widening, so the
//! next step's gradient is computed against exactly what the plane holds.

use crate::linalg::Rng;
use crate::tensor::{Tensor, TensorBf16};

/// Exact widening: bf16 bits are the upper half of the f32 bits.
#[inline]
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// Round-to-nearest-even — the degenerate (variance-free) case of the
/// stochastic rounder, used to seed the plane from f32 initialization.
#[inline]
pub fn round_to_nearest(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep NaN a NaN: force a quiet-bit so truncation can't yield Inf
        return ((bits >> 16) as u16) | 0x0040;
    }
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    (rounded >> 16) as u16
}

/// Stochastic rounding: add a uniform u16 to the discarded mantissa bits
/// and truncate. E[result] == x exactly (the round-up probability is the
/// discarded fraction), which `tests/optim_wave.rs` pins statistically.
#[inline]
pub fn f32_to_bf16_stochastic(x: f32, r: u16) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    (bits.wrapping_add(r as u32) >> 16) as u16
}

/// Seed `plane` from `w` with round-to-nearest, then snap `w` onto the
/// bf16 grid so the working copy and the plane agree exactly.
pub fn seed_plane(w: &mut Tensor, plane: &mut TensorBf16) {
    debug_assert_eq!(w.len(), plane.len());
    for (x, p) in w.data.iter_mut().zip(plane.data.iter_mut()) {
        *p = round_to_nearest(*x);
        *x = bf16_to_f32(*p);
    }
}

/// Store `w` into `plane` with stochastic rounding (one u16 draw per
/// element, low 16 bits of `next_u64`, in element order), then snap `w`
/// back onto the bf16 grid. The analog of the exemplar `add_stochastic_`
/// applied after the optimizer's f32 update.
pub fn store_stochastic(w: &mut Tensor, plane: &mut TensorBf16, rng: &mut Rng) {
    debug_assert_eq!(w.len(), plane.len());
    for (x, p) in w.data.iter_mut().zip(plane.data.iter_mut()) {
        let r = rng.next_u64() as u16;
        *p = f32_to_bf16_stochastic(*x, r);
        *x = bf16_to_f32(*p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widening_is_exact_on_the_grid() {
        for bits in [0x0000u16, 0x3f80, 0xbf80, 0x4000, 0x7f80, 0xff80] {
            assert_eq!(round_to_nearest(bf16_to_f32(bits)), bits);
        }
    }

    #[test]
    fn nearest_ties_to_even() {
        // exactly halfway between bf16 grid points: mantissa low half 0x8000
        let lo = f32::from_bits(0x3f80_0000); // 1.0
        let hi = f32::from_bits(0x3f81_0000);
        let mid = f32::from_bits(0x3f80_8000);
        assert_eq!(bf16_to_f32(round_to_nearest(mid)), lo); // 0x3f80 is even
        let mid2 = f32::from_bits(0x3f81_8000);
        assert_eq!(bf16_to_f32(round_to_nearest(mid2)), f32::from_bits(0x3f82_0000));
        assert!(hi > lo);
    }

    #[test]
    fn stochastic_extremes() {
        let x = f32::from_bits(0x3f80_0001); // just above 1.0
        assert_eq!(f32_to_bf16_stochastic(x, 0), 0x3f80); // never rounds up with r=0
        assert_eq!(f32_to_bf16_stochastic(x, 0xFFFF), 0x3f81); // always up with r=max
        let exact = 1.0f32;
        assert_eq!(f32_to_bf16_stochastic(exact, 0xFFFF), 0x3f80); // on-grid never moves
    }

    #[test]
    fn nan_stays_nan() {
        assert!(bf16_to_f32(round_to_nearest(f32::NAN)).is_nan());
        assert!(bf16_to_f32(f32_to_bf16_stochastic(f32::NAN, 0xFFFF)).is_nan());
    }

    #[test]
    fn store_snaps_working_copy() {
        let mut w = Tensor::new(vec![3], vec![1.000_01, -2.333, 0.5]).unwrap();
        let mut plane = TensorBf16::zeros(&[3]);
        let mut rng = Rng::new(7);
        store_stochastic(&mut w, &mut plane, &mut rng);
        for (x, p) in w.data.iter().zip(&plane.data) {
            assert_eq!(*x, bf16_to_f32(*p));
        }
    }
}
