//! Uncompressed AdamW — baseline ("Full (AdamW)") and the path for vector
//! parameters, embeddings, heads and LoRA adapters.

use crate::tensor::Tensor;

use super::{adamw_apply, bias_corrections, OptHp};

#[derive(Debug, Clone)]
pub struct AdamWState {
    pub m: Tensor,
    pub v: Tensor,
    pub t: usize,
}

impl AdamWState {
    pub fn new(shape: &[usize]) -> AdamWState {
        AdamWState { m: Tensor::zeros(shape), v: Tensor::zeros(shape), t: 0 }
    }

    pub fn state_bytes(&self) -> usize {
        self.m.size_bytes() + self.v.size_bytes()
    }

    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, hp: &OptHp) {
        self.t += 1;
        for (mi, gi) in self.m.data.iter_mut().zip(&g.data) {
            *mi = hp.beta1 * *mi + (1.0 - hp.beta1) * gi;
        }
        for (vi, gi) in self.v.data.iter_mut().zip(&g.data) {
            *vi = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
        }
        let (c1, c2) = bias_corrections(hp, self.t);
        adamw_apply(w, &self.m, &self.v, lr, c1, c2, hp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    #[test]
    fn first_step_moves_against_gradient_sign() {
        let hp = OptHp::adamw();
        let mut rng = Rng::new(0);
        let g = rng.gaussian_tensor(&[8, 8], 1.0);
        let mut w = Tensor::zeros(&[8, 8]);
        let mut st = AdamWState::new(&[8, 8]);
        st.step(&mut w, &g, 0.1, &hp);
        for (wi, gi) in w.data.iter().zip(&g.data) {
            if gi.abs() > 1e-3 {
                assert!(wi.signum() == -gi.signum(), "{wi} vs {gi}");
                // bias-corrected first step has magnitude ~ lr
                assert!((wi.abs() - 0.1).abs() < 0.01);
            }
        }
    }

    #[test]
    fn converges_on_quadratic() {
        // f(w) = 0.5 ||w - w*||^2
        let hp = OptHp::adamw();
        let mut rng = Rng::new(1);
        let target = rng.gaussian_tensor(&[4, 4], 1.0);
        let mut w = Tensor::zeros(&[4, 4]);
        let mut st = AdamWState::new(&[4, 4]);
        for _ in 0..400 {
            let mut g = w.clone();
            g.axpy(-1.0, &target, 1.0);
            st.step(&mut w, &g, 0.05, &hp);
        }
        assert!(w.rel_err(&target) < 0.05, "rel {}", w.rel_err(&target));
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let hp = OptHp { weight_decay: 0.5, ..OptHp::adamw() };
        let mut w = Tensor::full(&[4], 1.0);
        let g = Tensor::zeros(&[4]);
        let mut st = AdamWState::new(&[4]);
        st.step(&mut w, &g, 0.1, &hp);
        assert!(w.data.iter().all(|&x| x < 1.0 && x > 0.9));
    }
}
